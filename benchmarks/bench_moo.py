"""MOO algorithm benchmarks (paper Figs. 4, 10a–f).

* dag_aggregation — HMOOC1/2/3 hypervolume + solving time (Fig 10a,b).
* moo_comparison  — HMOOC3 vs WS/Evo/PF, fine-grained space (Fig 10c–e).
* granularity     — query-level (coarse) baselines vs HMOOC3 (Fig 10f).
* ws_coverage     — Weighted-Sum Pareto-coverage collapse (Fig 4).

Hypervolumes are computed in the per-query normalized objective space over
the union of all methods' solutions (reference point 1.1, so 1.0 == the
whole normalized box), matching the paper's percent-HV presentation.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core.moo.baselines import solve_evo, solve_pf, solve_ws
from repro.core.moo.hmooc import HMOOCConfig, hmooc_solve
from repro.core.moo.pareto import hypervolume_2d, pareto_mask_np
from repro.core.tuning.objectives import StageObjectives

from .common import eval_queries, get_model


def _norm_hv(fronts: Dict[str, np.ndarray]) -> Dict[str, float]:
    allF = np.concatenate([f for f in fronts.values() if f.size], 0)
    lo, hi = allF.min(0), allF.max(0)
    span = np.where(hi > lo, hi - lo, 1.0)
    out = {}
    for name, F in fronts.items():
        Fn = (F - lo) / span
        out[name] = hypervolume_2d(Fn, np.array([1.1, 1.1]))
    return out


def run_dag_aggregation(bench: str = "tpch", n_queries: int = 12,
                        use_model: bool = True, seed: int = 0) -> List[dict]:
    model = get_model(bench, "subq")[0] if use_model else None
    rows = []
    agg = {m: {"hv": [], "t": []} for m in ("hmooc1", "hmooc2", "hmooc3")}
    for q in eval_queries(bench)[:n_queries]:
        obj = StageObjectives(q, model=model)
        fronts, times = {}, {}
        for method in agg:
            cfg = HMOOCConfig(dag_method=method, seed=seed)
            r = hmooc_solve(obj.stage_eval, obj.m, obj.d_c, obj.d_ps, cfg,
                            snap_c=obj.snap_c, snap_ps=obj.snap_ps)
            fronts[method] = r.front
            times[method] = r.solve_time
        hvs = _norm_hv(fronts)
        for m in agg:
            agg[m]["hv"].append(hvs[m])
            agg[m]["t"].append(times[m])
    for m, d in agg.items():
        rows.append({"bench": bench, "method": m,
                     "hv": float(np.mean(d["hv"])),
                     "solve_time_s": float(np.mean(d["t"])),
                     "max_time_s": float(np.max(d["t"]))})
    return rows


def run_moo_comparison(bench: str = "tpch", n_queries: int = 10,
                       fine: bool = True, use_model: bool = True,
                       seed: int = 0) -> List[dict]:
    model = get_model(bench, "subq")[0] if use_model else None
    per_method: Dict[str, Dict[str, list]] = {}
    for q in eval_queries(bench)[:n_queries]:
        obj = StageObjectives(q, model=model)
        fronts, times = {}, {}
        cfg = HMOOCConfig(dag_method="hmooc3", seed=seed)
        r = hmooc_solve(obj.stage_eval, obj.m, obj.d_c, obj.d_ps, cfg,
                        snap_c=obj.snap_c, snap_ps=obj.snap_ps)
        fronts["hmooc3"] = r.front
        times["hmooc3"] = r.solve_time
        ev, D = (obj.query_eval_fine() if fine else obj.query_eval_coarse())
        for name, fn, kw in (
                ("ws", solve_ws, dict(n_samples=10000, n_weights=11)),
                ("evo", solve_evo, dict(pop=100, n_evals=500)),
                ("pf", solve_pf, dict(n_points=9))):
            F, U, dt, ne = fn(ev, D, seed=seed, **kw)
            fronts[name] = F
            times[name] = dt
        hvs = _norm_hv(fronts)
        for m in fronts:
            d = per_method.setdefault(m, {"hv": [], "t": []})
            d["hv"].append(hvs[m])
            d["t"].append(times[m])
    rows = []
    for m, d in per_method.items():
        rows.append({"bench": bench, "space": "fine" if fine else "coarse",
                     "method": m, "hv": float(np.mean(d["hv"])),
                     "solve_time_s": float(np.mean(d["t"])),
                     "max_time_s": float(np.max(d["t"]))})
    return rows


def run_ws_coverage(bench: str = "tpch", template: int = 1,
                    use_model: bool = True, seed: int = 0) -> List[dict]:
    q = eval_queries(bench)[template]
    model = get_model(bench, "subq")[0] if use_model else None
    obj = StageObjectives(q, model=model)
    ev, D = obj.query_eval_coarse()
    rows = []
    for nw in (11, 101):
        F, U, dt, ne = solve_ws(ev, D, n_samples=10000, n_weights=nw,
                                seed=seed)
        distinct = np.unique(F.round(7), axis=0).shape[0]
        rows.append({"bench": bench, "query": q.qid, "method": f"ws_{nw}",
                     "distinct_solutions": int(distinct),
                     "solve_time_s": dt})
    cfg = HMOOCConfig(dag_method="hmooc3", seed=seed)
    r = hmooc_solve(obj.stage_eval, obj.m, obj.d_c, obj.d_ps, cfg,
                    snap_c=obj.snap_c, snap_ps=obj.snap_ps)
    rows.append({"bench": bench, "query": q.qid, "method": "hmooc3",
                 "distinct_solutions": int(
                     np.unique(r.front.round(7), axis=0).shape[0]),
                 "solve_time_s": r.solve_time})
    return rows
