"""Roofline table from the dry-run artifacts (deliverable g).

Reads ``results/dryrun/*.json`` and reports, per (arch × shape × mesh):
the three roofline terms, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs,
and the roofline fraction (compute term / bound term — 1.0 means the cell
runs at the compute roofline).
"""
from __future__ import annotations

import glob
import json
import os
from typing import List

from .common import RESULTS


def run_roofline(pattern: str = "*") -> List[dict]:
    rows = []
    for path in sorted(glob.glob(
            os.path.join(RESULTS, "dryrun", f"{pattern}.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("status") == "skipped":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": "-", "status": "skipped",
                         "reason": r["reason"][:60]})
            continue
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": "-", "status": "error"})
            continue
        rf = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "ok",
            "compute_ms": round(rf["compute_s"] * 1e3, 2),
            "memory_ms": round(rf["memory_s"] * 1e3, 2),
            "collective_ms": round(rf["collective_s"] * 1e3, 2),
            "dominant": rf["dominant"],
            "roofline_fraction": round(rf["compute_s"]
                                       / max(rf["bound_s"], 1e-12), 4),
            "useful_flops_ratio": round(r["useful_flops_ratio"], 3),
            "peak_gb_per_dev": r["memory"]["peak_per_device_gb"],
            "compile_s": r["t_compile_s"],
        })
    return rows
