"""Streaming-admission server benchmark: throughput + tail latency.

Feeds a timed Poisson arrival stream (oracle backend, no trained model)
through two admission policies on the same simulated clock (arrivals are
simulated; optimizer work advances the clock by measured wall time):

* ``batch32``  — the batch-only baseline: requests accumulate into fixed
  batches of 32 (PR 1/PR 2's fixed-batch serving shape; mid-session
  admission off), each batch runs ``tune_batch`` → ``RuntimeSession``.
* ``server``   — ``repro.serve.OptimizerServer``: deadline-aware
  micro-batches under the paper's 1 s solve budget, with late arrivals
  admitted into the running session between fusion rounds.

Reports throughput (queries / makespan) and p50/p99/max of the
arrival-to-final-plan latency, plus the arrival-to-θ compile-solve
latency the paper's budget is stated against.  Also verifies the
streaming path's outputs are bit-identical to the offline ``tune_batch``
→ ``RuntimeSession.run_batch`` pipeline.

``run_overload`` (``--overload``) is the PR-5 overload scenario: one
tenant per SLO class, aggregate arrival rate swept past the measured
serving capacity — strict sheds and keeps its p99 ≤ budget, degrade
resolves via the cheap compile path, best-effort absorbs the queueing,
and surviving outputs stay bit-identical to the offline pipeline.

``run_model_solve`` (``--model-solve``) is the PR-6 jitted-solve scenario:
the trained subQ model replaces the oracle objective and the batched
accelerator-resident solve path (``TuningService(jit_solve=None)``) is
measured against the legacy sequential path (``jit_solve=False``) on the
same batch — throughput ratio, bit-identity, the recompilation bound
(compiled signatures ≤ shape buckets across a varying-batch sweep), and
p99 solve latency under a model-backed 64 q/s arrival stream.

``run_fleet`` (``--fleet``) is the PR-9 multi-worker scenario: the same
overload-class tenant mix served by an ``OptimizerFleet`` at worker
counts ``--workers N...`` under a calibrated, contention-scaled
``ServiceTimeModel`` — aggregate qps and strict-tenant p99 vs N, cache
hit rates by routing policy (affinity vs random vs single), and
per-tenant bit-identity of survivors with the offline pipeline at every
(worker count, policy).

Run:  PYTHONPATH=src python benchmarks/bench_server.py
      PYTHONPATH=src python benchmarks/bench_server.py --smoke   # CI
      PYTHONPATH=src python benchmarks/bench_server.py --overload
      PYTHONPATH=src python benchmarks/bench_server.py --smoke --model-solve
      PYTHONPATH=src python benchmarks/bench_server.py --fleet --workers 1 2 4
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import time
from typing import Optional

import numpy as np

from repro.core.moo.hmooc import HMOOCConfig
from repro.queryengine.scenarios import scenario_matrix
from repro.queryengine.workloads import (ArrivalModel, TenantSpec,
                                         multi_tenant_stream, serving_stream)
from repro.serve import (CandidatePoolCache, ElasticPolicy, OptimizerFleet,
                         OptimizerServer, RuntimeSession, ServerConfig,
                         ServiceTimeModel, TuningService)

try:
    from .common import save_bench
except ImportError:          # standalone: python benchmarks/bench_server.py
    from common import save_bench

WEIGHTS = (0.9, 0.1)

# Serving-tuned solver budget: the paper sizes Algorithm 1's sampling
# (LHS pools, clusters, bank caps) so a solve fits the 1–2 s cloud budget;
# this config does the same for this host class.  Halving the offline
# defaults keeps micro-batch solves well inside the 1 s end-to-end budget
# the benchmark asserts against.
SERVING_CFG = dict(n_c_init=32, n_clusters=6, n_p_pool=128, n_c_enrich=32,
                   max_bank=24)


def _offline_reference(requests, cfg: HMOOCConfig):
    queries = [r.query for r in requests]
    cts = TuningService(cfg=cfg).tune_batch(queries, WEIGHTS)
    return RuntimeSession(weights=WEIGHTS).run_batch(queries, cts)


def _identical(served, offline) -> bool:
    for s, ref in zip(served, offline):
        got = s.result
        for f, g in ((got.theta_p_eff, ref.theta_p_eff),
                     (got.theta_s_eff, ref.theta_s_eff),
                     (got.final_join, ref.final_join),
                     (got.sim.ana_latency, ref.sim.ana_latency),
                     (got.sim.actual_latency, ref.sim.actual_latency),
                     (got.sim.io_gb, ref.sim.io_gb),
                     (got.sim.cost, ref.sim.cost)):
            if not np.array_equal(f, g):
                return False
    return True


def run(bench: str = "tpch", n: int = 64, rate_qps: float = 16.0,
        max_batch: int = 8, budget_s: float = 1.0,
        baseline_batch: int = 32, seed: int = 0,
        cfg: Optional[HMOOCConfig] = None, check: bool = True) -> dict:
    cfg = cfg if cfg is not None else HMOOCConfig(seed=seed, **SERVING_CFG)
    requests = serving_stream(
        bench, n, seed=seed,
        arrivals=ArrivalModel(kind="poisson", rate_qps=rate_qps))

    # --- streaming server (deadline-aware micro-batches) -------------------
    srv = OptimizerServer(
        config=ServerConfig(max_batch=max_batch, solve_budget_s=budget_s),
        weights=WEIGHTS, cfg=cfg)
    served = srv.serve(requests)
    server_rep = srv.latency_report(served)

    # --- batch-only baseline on the same clock model -----------------------
    base = OptimizerServer(
        config=ServerConfig(max_batch=baseline_batch,
                            solve_budget_s=math.inf,
                            admit_mid_session=False),
        weights=WEIGHTS, cfg=cfg)
    base_served = base.serve(requests)
    base_rep = base.latency_report(base_served)

    outputs_identical = True
    if check:
        offline = _offline_reference(requests, cfg)
        outputs_identical = (_identical(served, offline)
                             and _identical(base_served, offline))

    return {
        "bench": bench,
        "n_queries": n,
        "rate_qps": rate_qps,
        "max_batch": max_batch,
        "budget_s": budget_s,
        "baseline_batch": baseline_batch,
        "outputs_identical": outputs_identical,
        "server": server_rep,
        "batch32_baseline": base_rep,
        "speedup_qps_vs_batch32": server_rep["qps"] / base_rep["qps"],
        "p99_plan_latency_reduction_vs_batch32":
            base_rep["plan_latency_s"]["p99"]
            / server_rep["plan_latency_s"]["p99"],
        "p99_under_budget": server_rep["plan_latency_s"]["p99"] < budget_s,
    }


# Per-tenant preference spread for the multi-tenant scenario: from
# latency-heavy to cost-heavy users (UDAO-style per-user weights).
TENANT_PREFS = [(0.9, 0.1), (0.7, 0.3), (0.5, 0.5), (0.2, 0.8), (0.1, 0.9)]


def run_tenants(bench: str = "tpch", n: int = 64, rate_qps: float = 16.0,
                n_tenants: int = 4, max_batch: int = 8, budget_s: float = 1.0,
                seed: int = 0, cfg: Optional[HMOOCConfig] = None,
                check: bool = True) -> dict:
    """Multi-tenant streaming scenario at equal aggregate load.

    ``n_tenants`` tenants with different preference weights (and one
    double-share, one priority tenant) split the same total arrival rate
    and query count as the single-stream run; reports per-tenant p99 plan
    latency, the Jain fairness index over those tails, whether any tenant
    regresses vs the single anonymous stream, and per-tenant parity with
    the offline pipeline solved under that tenant's own weights.
    """
    cfg = cfg if cfg is not None else HMOOCConfig(seed=seed, **SERVING_CFG)
    sc = ServerConfig(max_batch=max_batch, solve_budget_s=budget_s)

    # --- single-stream baseline at the same aggregate load -----------------
    base_reqs = serving_stream(
        bench, n, seed=seed,
        arrivals=ArrivalModel(kind="poisson", rate_qps=rate_qps))
    base_srv = OptimizerServer(config=sc, weights=WEIGHTS, cfg=cfg)
    base_rep = base_srv.latency_report(base_srv.serve(base_reqs))

    # --- the tenant mix ----------------------------------------------------
    specs = [TenantSpec(
        name=f"t{i}", weights=TENANT_PREFS[i % len(TENANT_PREFS)],
        arrivals=ArrivalModel(kind="poisson", rate_qps=rate_qps / n_tenants),
        share=2.0 if i == 0 else 1.0,
        priority=1 if i == 1 and n_tenants > 1 else 0) for i in range(n_tenants)]
    # Distribute the remainder so the aggregate query count exactly equals
    # the single-stream baseline's.
    counts = [n // n_tenants + (1 if i < n % n_tenants else 0)
              for i in range(n_tenants)]
    reqs = multi_tenant_stream(bench, specs, counts, seed=seed)
    srv = OptimizerServer(config=sc, weights=WEIGHTS, cfg=cfg, tenants=specs)
    served = srv.serve(reqs)
    rep = srv.latency_report(served)

    per_tenant_identical = True
    if check:
        for spec in specs:
            sub = [s for s in served if s.tenant == spec.name]
            queries = [s.request.query for s in sub]
            cts = TuningService(cfg=cfg).tune_batch(queries, spec.weights)
            ref = RuntimeSession(weights=spec.weights).run_batch(queries, cts)
            if not _identical(sub, ref):
                per_tenant_identical = False

    p99s = {s.name: rep["tenants"][s.name]["plan_latency_s"]["p99"]
            for s in specs}
    base_p99 = base_rep["plan_latency_s"]["p99"]
    return {
        "bench": bench,
        "n_queries": len(reqs),
        "n_tenants": n_tenants,
        "aggregate_rate_qps": rate_qps,
        "max_batch": max_batch,
        "budget_s": budget_s,
        "tenant_specs": [{"name": s.name, "weights": list(s.weights),
                          "share": s.share, "priority": s.priority,
                          "rate_qps": s.arrivals.rate_qps} for s in specs],
        "outputs_identical_per_tenant": per_tenant_identical,
        "tenants": rep["tenants"],
        "fairness_jain": rep["fairness_jain"],
        "tenant_p99_plan_latency_s": p99s,
        "baseline_single_stream_p99_s": base_p99,
        "max_tenant_p99_s": max(p99s.values()),
        "no_tenant_p99_regression":
            max(p99s.values()) <= base_p99 * 1.05,
        "server": {k: rep[k] for k in ("n_queries", "n_micro_batches",
                                       "qps", "plan_latency_s",
                                       "solve_latency_s")},
    }


def _overload_specs(rate_qps: float, budget_s: float = 1.0,
                    slo_override: Optional[str] = None):
    """One tenant per SLO class, equal rates, UDAO-style distinct weights.

    The strict and degrade tenants carry the hard ``budget_s`` promise;
    the best-effort tenant's budget is soft (10×): it made no latency
    promise, so its backlog must not flood the overdue-promotion lane and
    starve the tenants that did.  The strict tenant also sits in a higher
    priority tier — a tenant paying for a hard SLO composes first, so it
    sheds only the genuinely unabsorbable excess rather than everything
    the flooded classes crowd out.  ``slo_override`` builds the
    counterfactual mix (same names, weights, rates, budgets and
    priorities, every tenant forced to one class — e.g. all best_effort,
    the pre-PR-5 behavior)."""
    return [TenantSpec(
        name=slo, slo=slo_override if slo_override is not None else slo,
        weights=TENANT_PREFS[i % len(TENANT_PREFS)],
        solve_budget_s=(10 * budget_s if slo == "best_effort" else budget_s),
        priority=1 if slo == "strict" else 0,
        arrivals=ArrivalModel(kind="poisson", rate_qps=rate_qps / 3))
        for i, slo in enumerate(("strict", "degrade", "best_effort"))]


def measure_capacity(bench: str = "tpch", n: int = 48, max_batch: int = 8,
                     budget_s: float = 1.0, seed: int = 0,
                     cfg: Optional[HMOOCConfig] = None) -> float:
    """Measured warm serving capacity (queries/s) for the overload mix.

    Serves the three-tenant mix (all best-effort — calibration must not
    shed) at a low rate twice on one server and derives capacity from the
    *second* pass's recorded per-flush clock charges (total admission
    window over total queries): the steady-state rate at which the warmed
    caches absorb this traffic shape.  The overload scenario sweeps the
    arrival rate past this — a genuinely unabsorbable load, not just a
    cold-cache transient.
    """
    cfg = cfg if cfg is not None else HMOOCConfig(seed=seed, **SERVING_CFG)
    specs = _overload_specs(8.0, budget_s=budget_s,
                            slo_override="best_effort")
    srv = OptimizerServer(
        config=ServerConfig(max_batch=max_batch, solve_budget_s=budget_s),
        weights=WEIGHTS, cfg=cfg, tenants=specs)
    counts = [n // 3 + (1 if i < n % 3 else 0) for i in range(3)]
    srv.serve(multi_tenant_stream(bench, specs, counts, seed=seed))
    srv.serve(multi_tenant_stream(bench, specs, counts, seed=seed + 1))
    windows = srv.last_run.flush_windows
    busy = sum(dt for dt, _ in windows)
    return sum(b for _, b in windows) / busy if busy else float("inf")


def run_overload(bench: str = "tpch", n: int = 96,
                 overload_factor: float = 2.0, max_batch: int = 8,
                 budget_s: float = 1.0, seed: int = 0,
                 cfg: Optional[HMOOCConfig] = None, check: bool = True,
                 capacity_qps: Optional[float] = None,
                 calib_n: int = 48) -> dict:
    """Overload scenario: arrival rate swept past measured capacity.

    Three tenants — one per SLO class — split an aggregate arrival rate of
    ``overload_factor ×`` the measured serving capacity.  The server must
    *adapt* instead of queueing unboundedly: the strict tenant sheds its
    unmeetable requests and keeps its served p99 plan latency ≤ its
    budget, the degrade tenant resolves every admission through the cheap
    compile path (zero fresh Algorithm 1 solves), and the best-effort
    tenant absorbs the queueing.  Reports per-class shed/degrade rates and
    goodput, plus per-tenant parity of surviving full-quality queries with
    the offline pipeline.
    """
    cfg = cfg if cfg is not None else HMOOCConfig(seed=seed, **SERVING_CFG)
    if capacity_qps is None:
        capacity_qps = measure_capacity(bench, n=calib_n,
                                        max_batch=max_batch,
                                        budget_s=budget_s, seed=seed,
                                        cfg=cfg)
    rate = overload_factor * capacity_qps
    specs = _overload_specs(rate, budget_s=budget_s)
    counts = [n // 3 + (1 if i < n % 3 else 0) for i in range(3)]
    reqs = multi_tenant_stream(bench, specs, counts, seed=seed)

    # Counterfactual baseline: the identical stream with every tenant
    # forced best_effort (the pre-PR-5 server: queue unboundedly, blow
    # budgets silently).  What overload *adaptation* buys is the delta.
    base_specs = _overload_specs(rate, budget_s=budget_s,
                                 slo_override="best_effort")
    base_srv = OptimizerServer(
        config=ServerConfig(max_batch=max_batch, solve_budget_s=budget_s),
        weights=WEIGHTS, cfg=cfg, tenants=base_specs)
    base_rep = base_srv.latency_report(base_srv.serve(reqs))

    srv = OptimizerServer(
        config=ServerConfig(max_batch=max_batch, solve_budget_s=budget_s),
        weights=WEIGHTS, cfg=cfg, tenants=specs)
    # Count Algorithm 1 bank builds during the serve, attributing any that
    # fire inside the degraded path (every degraded admission resolves via
    # a response hit or ``TuningService._tune_cheap``): degraded traffic
    # must trigger exactly zero — cached banks or the Spark defaults only.
    from repro.core.moo import hmooc as hmooc_mod
    bank_builds = [0]
    degraded_bank_builds = [0]
    orig_opt = hmooc_mod._optimize_rep_banks
    orig_cheap = srv.tuning._tune_cheap

    def _counting_opt(*a, **kw):
        bank_builds[0] += 1
        return orig_opt(*a, **kw)

    def _counting_cheap(*a, **kw):
        before = bank_builds[0]
        out = orig_cheap(*a, **kw)
        degraded_bank_builds[0] += bank_builds[0] - before
        return out

    hmooc_mod._optimize_rep_banks = _counting_opt
    srv.tuning._tune_cheap = _counting_cheap
    try:
        served = srv.serve(reqs)
    finally:
        hmooc_mod._optimize_rep_banks = orig_opt
        srv.tuning._tune_cheap = orig_cheap
    rep = srv.latency_report(served)
    totals = srv.tuning.totals

    survivors_identical = True
    if check:
        # Surviving full-quality queries bit-match the offline pipeline
        # under their tenant's weights — shedding/degrading the rest never
        # perturbed them.
        for spec in specs:
            sub = [s for s in served
                   if s.tenant == spec.name and s.status == "served"]
            if not sub:
                continue
            queries = [s.request.query for s in sub]
            cts = TuningService(cfg=cfg).tune_batch(queries, spec.weights)
            ref = RuntimeSession(weights=spec.weights).run_batch(queries, cts)
            if not _identical(sub, ref):
                survivors_identical = False

    strict = rep["tenants"]["strict"]
    degrade = rep["tenants"]["degrade"]
    base_strict_p99 = base_rep["tenants"]["strict"]["plan_latency_s"]["p99"]
    return {
        "bench": bench,
        "n_queries": len(reqs),
        "capacity_qps": capacity_qps,
        "overload_factor": overload_factor,
        "aggregate_rate_qps": rate,
        "max_batch": max_batch,
        "budget_s": budget_s,
        "tenants": rep["tenants"],
        "goodput": rep["goodput"],
        "shed_rate": rep["shed_rate"],
        "degrade_rate": rep["degrade_rate"],
        "fairness_jain": rep["fairness_jain"],
        "strict_p99_plan_latency_s": strict["plan_latency_s"]["p99"],
        "strict_p99_under_budget":
            (not math.isfinite(strict["plan_latency_s"]["p99"]))
            or strict["plan_latency_s"]["p99"] <= strict["budget_s"],
        "strict_shed_rate": strict["shed_rate"],
        "strict_goodput": strict["goodput"],
        "degrade_rate_degrade_tenant": degrade["degrade_rate"],
        "cheap_solves": totals.n_cheap,
        "default_theta_solves": totals.n_default_theta,
        "full_solves": totals.n_solved,
        "fresh_bank_builds": bank_builds[0],
        "degraded_bank_builds": degraded_bank_builds[0],
        "degraded_zero_fresh_solves": degraded_bank_builds[0] == 0,
        "survivors_identical": survivors_identical,
        "strict_n_finished": strict["n_finished"],
        # Every request reached exactly one terminal outcome with the
        # right artifacts: shed ⇒ rejected unsolved, otherwise a realized
        # result — nothing lost, nothing half-served.
        "outcomes_accounted": all(
            (s.status == "shed" and s.ct is None and s.result is None)
            or (s.status in ("served", "degraded")
                and s.result is not None and math.isfinite(s.finished_s))
            for s in served),
        "best_effort_all_served":
            rep["tenants"]["best_effort"]["n_finished"]
            == rep["tenants"]["best_effort"]["n_queries"],
        # The no-adaptation counterfactual (all tenants best_effort): the
        # strict tenant's tail without shedding, and overall goodput.
        "baseline_no_slo": {
            "strict_p99_plan_latency_s": base_strict_p99,
            "goodput": base_rep["goodput"],
            "plan_p99_s": base_rep["plan_latency_s"]["p99"],
        },
        "strict_p99_reduction_vs_no_slo":
            (base_strict_p99 / strict["plan_latency_s"]["p99"]
             if math.isfinite(strict["plan_latency_s"]["p99"])
             and strict["plan_latency_s"]["p99"] > 0 else math.nan),
    }


def _replay_reference(served, cfg: HMOOCConfig) -> dict:
    """Offline one-at-a-time replay of every full-quality survivor under
    its request's stamped weights (shared exact caches — sharing cannot
    change outputs under the golden contract)."""
    svc = TuningService(cfg=cfg)
    pools = CandidatePoolCache()
    out = {}
    for s in served:
        if s.status != "served":
            continue
        w = tuple(s.request.weights) if s.request.weights is not None \
            else WEIGHTS
        ct = svc.tune_batch([s.request.query], w)[0]
        sess = RuntimeSession(weights=w, pool_cache=pools)
        out[s.rid] = sess.run_batch([s.request.query], [ct])[0]
    return out


def _survivors_replay_identical(served, cfg: HMOOCConfig) -> bool:
    ref = _replay_reference(served, cfg)
    return _identical([s for s in served if s.status == "served"],
                      [ref[s.rid] for s in served if s.status == "served"])


def _p99_no_worse(elastic_p99: float, static_p99: float,
                  budget_s: float = 0.0, tol: float = 1.05,
                  slack_s: float = 0.01) -> bool:
    """NaN-safe tail comparison: vacuously true unless both tails exist.

    Both p99s condition on *served* requests, which penalizes the policy
    that rescues deadline-edge requests the other one sheds: the rescued
    heads land just under their budget and inflate the served tail.  A
    strict tail inside the SLO ``budget_s`` is therefore "no worse" by
    definition — every served strict head met its contract — so the
    comparison is against ``max(static tail + band, budget)``.
    """
    if not (math.isfinite(elastic_p99) and math.isfinite(static_p99)):
        return True
    return elastic_p99 <= max(static_p99 * tol + slack_s, budget_s)


def _calibrate_clock(bench: str, cfg: HMOOCConfig, caps, n: int = 24,
                     seed: int = 987, passes: int = 3):
    """Warm every batch-size bucket and calibrate a ServiceTimeModel.

    Serves an all-at-once burst at each cap on a throwaway server: the
    first pass compile-warms the jit batch bucket (a fresh bucket costs
    orders of magnitude more than a warm solve), then ``passes`` more
    passes measure warm per-flush windows.  The lower-quartile warm
    window per exact batch size becomes a knot of the returned model —
    the robust estimate of *achievable* cost, immune to a contention
    spike polluting one pass — the per-round cost is estimated from
    the non-flush remainder of the measured serve walls, and the cheap
    per-member cost (response-cache hit / degraded path) from re-serving
    a warm server the same burst.  Scenario
    serves then *charge this model* instead of live wall time, so the
    elastic-vs-static comparison is a pure function of the stream and
    the configs — host jitter calibrates the model once instead of
    perturbing every admission decision.

    Returns ``(model, queries_served, rounds_run)`` so callers can pace
    load consistently *in the model's world* (see ``run_scenarios``).
    """
    windows = {}
    wall_rest, rounds, queries = 0.0, 0, 0
    # Calibrate on *unique* queries only: a duplicate in the burst hits
    # the exact response cache and serves in ~0.5 ms, and a handful of
    # those pollute the lower quantiles with costs no fresh solve can
    # achieve.  (Scenario serves still enjoy cache hits — the model just
    # prices every flush at the honest solve cost.)
    base = serving_stream(bench, 2 * n, seed=seed,
                          arrivals=ArrivalModel(kind="fixed", rate_qps=1e6))
    seen, uniq = set(), []
    for r in base:
        key = r.query.fingerprint() if hasattr(r.query, "fingerprint") \
            else (r.query.qid, getattr(r.query, "variant", 0))
        if key in seen:
            continue
        seen.add(key)
        uniq.append(r)
    uniq = uniq[:n]
    for cap in caps:
        for attempt in range(1 + passes):
            reqs = [dataclasses.replace(r, rid=i, arrival_s=0.0)
                    for i, r in enumerate(uniq)]
            srv = OptimizerServer(
                config=ServerConfig(max_batch=cap, solve_budget_s=math.inf,
                                    admit_mid_session=False),
                weights=WEIGHTS, cfg=cfg)
            srv.serve(reqs)
            if attempt == 0:
                continue                      # warm-up pass: discard
            st = srv.last_run
            for w, size in st.flush_windows:
                windows.setdefault(size, []).append(w)
            wall_rest += max(
                0.0, st.wall_time_s - sum(w for w, _ in st.flush_windows))
            rounds += st.rounds
            queries += n
    knots = tuple((size, float(np.percentile(ws, 25)))
                  for size, ws in sorted(windows.items()))
    # cheap_s: per-query cost of a flush member that skips the full
    # solver (exact response-cache hit / degraded path).  Serve the same
    # burst repeatedly through ONE server — the tuning service's response
    # cache persists across serve() calls, so every pass after the first
    # is pure cache hits at cap 1 (one member per flush).
    srv = OptimizerServer(
        config=ServerConfig(max_batch=1, solve_budget_s=math.inf,
                            admit_mid_session=False),
        weights=WEIGHTS, cfg=cfg)
    cheap_ws = []
    for attempt in range(1 + passes):
        reqs = [dataclasses.replace(r, rid=i, arrival_s=0.0)
                for i, r in enumerate(uniq)]
        srv.serve(reqs)
        if attempt == 0:
            continue                          # cache-filling pass: discard
        cheap_ws.extend(w for w, _ in srv.last_run.flush_windows)
    model = ServiceTimeModel(
        flush_points=knots,
        round_s=wall_rest / rounds if rounds else 0.0,
        cheap_s=float(np.median(cheap_ws)) if cheap_ws else 0.0)
    return model, queries, rounds


def run_scenarios(bench: str = "tpch", n_per_tenant: int = 24,
                  max_batch: int = 1, budget_s: float = 0.3, seed: int = 0,
                  cfg: Optional[HMOOCConfig] = None, check: bool = True,
                  capacity_qps: Optional[float] = None, calib_n: int = 24,
                  load_factor: float = 0.7, elastic_ceiling: int = 2,
                  n_windows: int = 4) -> dict:
    """Nonstationary scenario matrix: elastic vs static capacity.

    Runs every (arrival shape × event timeline) scenario from
    :func:`repro.queryengine.scenarios.scenario_matrix` — diurnal /
    flash-crowd / ramp arrivals crossed with steady / preference-shift /
    churn timelines — through the *same* stream twice: once with a static
    batch cap of ``max_batch`` and once with the elastic controller
    allowed to scale the cap up to ``elastic_ceiling × max_batch`` off
    its queue-delay forecast (plus preemptive degradation).  The static
    cap is the latency-optimized small batch you would provision for
    steady load; under pressure the controller scales toward the host's
    throughput-optimal batch size and arms preemptive degradation, so
    backlog drains sooner and strict heads stop shedding (the elastic
    floor equals the static cap, so the two policies are *identical*
    until the queue-delay forecast engages).  The base per-tenant rate
    is calibrated so aggregate steady load sits at ``load_factor ×``
    measured capacity (genuine sustained overload — elasticity must
    *win* something, not just idle); the flash-crowd spike then pushes
    ~4× past even that.  The tight default ``budget_s`` (vs the 1 s
    single-stream default) makes budgets bind inside these short
    calibrated streams.

    The default regime is sized from the host's calibrated batch curve:
    steady load at ``0.7 ×`` the cap-1 capacity (static keeps up with
    slack; the nonstationary peaks are what overload it) and an elastic
    ceiling of ``2 × max_batch`` — the knee of the measured curve, where
    batching roughly halves per-query solve cost without the long flush
    windows that inflate the served strict tail.

    Both policies serve under a :class:`repro.serve.ServiceTimeModel`
    calibrated once from warm measured flush windows
    (:func:`_calibrate_clock`), so each (scenario, policy) outcome is
    deterministic given the calibration — the comparison measures the
    *control policy*, not per-flush host jitter.

    Reports per scenario: goodput / strict-tenant p99 / shed·degrade·
    rate-limited rates under both policies, the elastic cap trajectory,
    a phase-resolved windowed latency report, and replay-equivalence of
    both servers' surviving outputs against the offline per-request
    pipeline (the tentpole invariant, checked across shift and churn
    boundaries).  Headline: on the flash-crowd scenarios the elastic
    controller beats static capacity on goodput with strict-tenant p99
    no worse.
    """
    cfg = cfg if cfg is not None else HMOOCConfig(seed=seed, **SERVING_CFG)
    # Capacity events inside the matrix raise the server's *base* cap
    # (the churn timeline models executors joining) — a base above the
    # elastic ceiling passes through the controller unclamped, but the
    # clock model still needs calibrated knots at those batch sizes.
    event_caps = {e.max_batch for spec in scenario_matrix(
                      benchmark=bench, n_per_tenant=1, rate_qps=1.0)
                  for e in spec.events if e.kind == "capacity"}
    elastic_cap = elastic_ceiling * max_batch
    clock, calib_queries, calib_rounds = _calibrate_clock(
        bench, cfg,
        sorted({1, 2, max_batch, elastic_cap // 2, elastic_cap}
               | event_caps),
        n=calib_n)
    if capacity_qps is None:
        # Capacity in the *model's* world — the world the scenario serves
        # are clocked in.  (A separately wall-measured capacity can
        # disagree with the calibrated model by 2× under host contention,
        # silently shifting the load regime the bench was sized for.)
        # Measured by deterministically draining a representative *mixed*
        # backlog (duplicates included — a realistic tenant stream repeats
        # templates, and repeats are served from the response cache at
        # cheap_s, not the solve curve) through a throwaway static server
        # clocked by the calibrated model.  An analytic full-solve-only
        # estimate undershoots true capacity ~3× on streams this
        # duplicate-heavy, leaving every scenario underloaded.
        probe = [dataclasses.replace(r, rid=i, arrival_s=0.0)
                 for i, r in enumerate(serving_stream(
                     bench, 3 * n_per_tenant, seed=seed + 17,
                     arrivals=ArrivalModel(kind="fixed", rate_qps=1e6)))]
        psrv = OptimizerServer(
            config=ServerConfig(max_batch=max_batch,
                                solve_budget_s=math.inf, clock=clock),
            weights=WEIGHTS, cfg=cfg)
        pserved = psrv.serve(probe)
        makespan = max(s.finished_s for s in pserved)
        capacity_qps = len(probe) / makespan if makespan > 0 else 1.0
    rate_qps = load_factor * capacity_qps / 3.0   # 3 tenants per scenario
    matrix = scenario_matrix(benchmark=bench, n_per_tenant=n_per_tenant,
                             rate_qps=rate_qps)
    # Seed the per-query solve reserve from the *measured* warm capacity
    # instead of the conservative 0.25 s default: with tight budgets the
    # default reserve (× E[batch]) exceeds the whole budget and sheds
    # every strict head before the EWMA can adapt.
    reserve_s = 2.0 / capacity_qps
    static_cfg = ServerConfig(max_batch=max_batch, solve_budget_s=budget_s,
                              solve_reserve_s=reserve_s, clock=clock)
    elastic_cfg = ServerConfig(
        max_batch=max_batch, solve_budget_s=budget_s,
        solve_reserve_s=reserve_s, clock=clock,
        elastic=ElasticPolicy(min_batch=max_batch, max_batch=elastic_cap,
                              target_delay_s=0.25 * budget_s))

    scenarios = {}
    for spec in matrix:
        sc = spec.build(seed=seed)
        span = (max(r.arrival_s for r in sc.requests)
                - min(r.arrival_s for r in sc.requests))

        def _serve(server_cfg):
            """One deterministic serve: the config's ServiceTimeModel
            charges the simulated clock, so re-running this is a no-op —
            no repetitions or medians needed."""
            srv = OptimizerServer(config=server_cfg, weights=WEIGHTS,
                                  cfg=cfg, tenants=sc.tenants)
            served = srv.serve(sc.requests,
                               capacity_events=sc.capacity_events)
            rep = srv.latency_report(
                served, window_s=span / n_windows + 1e-9)
            strict = rep["tenants"]["strict"]
            return {
                "goodput": rep["goodput"],
                "shed_rate": rep["shed_rate"],
                "degrade_rate": rep["degrade_rate"],
                "rate_limited_rate": rep["rate_limited_rate"],
                "plan_p99_s": rep["plan_latency_s"]["p99"],
                "strict_p99_s": strict["plan_latency_s"]["p99"],
                "strict_goodput": strict["goodput"],
                "flush_caps": list(srv.last_run.flush_caps),
                "windows": rep["windows"],
                "replay_identical":
                    _survivors_replay_identical(served, cfg)
                    if check else None,
            }

        st, el = _serve(static_cfg), _serve(elastic_cfg)
        scenarios[spec.name] = {
            "n_requests": len(sc.requests),
            "n_tenants": len(sc.tenants),
            "n_capacity_events": len(sc.capacity_events),
            "static": st,
            "elastic": el,
            "elastic_goodput_gain": el["goodput"] - st["goodput"],
            "elastic_strict_p99_no_worse":
                _p99_no_worse(el["strict_p99_s"], st["strict_p99_s"],
                              budget_s=budget_s),
            "elastic_cap_engaged": max(el["flush_caps"], default=0)
                > max_batch,
        }

    flash = {k: v for k, v in scenarios.items()
             if k.startswith("flash_crowd")}
    # Pooled flash-crowd headline: mean goodput over the three flash-crowd
    # timelines under each policy (deterministic given the calibration).
    flash_static = float(np.mean(
        [v["static"]["goodput"] for v in flash.values()]))
    flash_elastic = float(np.mean(
        [v["elastic"]["goodput"] for v in flash.values()]))
    return {
        "bench": bench,
        "n_per_tenant": n_per_tenant,
        "capacity_qps": capacity_qps,
        "per_tenant_rate_qps": rate_qps,
        "load_factor": load_factor,
        "max_batch": max_batch,
        "elastic_max_batch": elastic_cap,
        "budget_s": budget_s,
        "clock_model": {"flush_points": [list(p) for p in clock.flush_points],
                        "round_s": clock.round_s, "cheap_s": clock.cheap_s},
        "scenarios": scenarios,
        "replay_identical_all": all(
            v[p]["replay_identical"] is not False for v in scenarios.values()
            for p in ("static", "elastic")),
        "flash_crowd_goodput_static": flash_static,
        "flash_crowd_goodput_elastic": flash_elastic,
        "flash_crowd_elastic_beats_static": flash_elastic > flash_static,
        "flash_crowd_strict_p99_no_worse": all(
            v["elastic_strict_p99_no_worse"] for v in flash.values()),
    }


# Modeled co-location contention for the fleet scenario: replicas share
# the host, so each one's optimizer work slows as the fleet widens.  A
# mild sublinear curve (8 replicas cost ~1.3x per solve) — the scaling
# headline must survive honest contention, not assume a free lunch.
FLEET_WORKER_SCALE = ((1, 1.0), (4, 1.15), (8, 1.3))


def _fleet_survivors_identical(served, specs, cfg: HMOOCConfig) -> bool:
    """Per-tenant golden check: full-quality survivors bit-match the
    offline pipeline solved under that tenant's weights."""
    for spec in specs:
        sub = [s for s in served
               if s.tenant == spec.name and s.status == "served"]
        if not sub:
            continue
        queries = [s.request.query for s in sub]
        cts = TuningService(cfg=cfg).tune_batch(queries, spec.weights)
        ref = RuntimeSession(weights=spec.weights).run_batch(queries, cts)
        if not _identical(sub, ref):
            return False
    return True


def run_fleet(bench: str = "tpch", n: int = 96, workers=(1, 2, 4),
              max_batch: int = 8, budget_s: float = 1.0, seed: int = 0,
              cfg: Optional[HMOOCConfig] = None, check: bool = True,
              load_factor: float = 2.0, calib_n: int = 24,
              steal_factor: float = 1.0) -> dict:
    """Multi-worker fleet scaling: qps + strict p99 vs N, hit rate by policy.

    The overload tenant mix (one tenant per SLO class) arrives at
    ``load_factor ×`` the measured single-worker capacity — a load one
    worker genuinely cannot absorb — and is served by fresh
    ``OptimizerFleet`` instances at each worker count in ``workers``
    under affinity and random routing (plus the ``single`` policy
    baseline, which pins everything to worker 0 at the widest fleet).
    All serves charge one :class:`ServiceTimeModel` calibrated from warm
    measured flush windows and re-priced per fleet width by the modeled
    co-location contention curve (``FLEET_WORKER_SCALE``), so every
    (worker count, policy) outcome is deterministic given the
    calibration.  Work stealing is enabled at ``steal_factor × budget``:
    when the owning worker's backlog forecast exceeds that, the request
    goes to the least-loaded worker instead.

    Claims reported per (N, policy): aggregate qps (should scale with N
    until arrivals bound it), strict-tenant p99 and shed rate (shedding
    should collapse as width absorbs the overload), response-cache hit
    rate and effective-set warm rate (affinity should beat random — the
    router exists to keep template traffic on its owning worker's
    caches), steal count, and per-tenant bit-identity of survivors with
    the offline pipeline (the golden invariant under any sharding).
    """
    cfg = cfg if cfg is not None else HMOOCConfig(seed=seed, **SERVING_CFG)
    n_max = max(workers)
    clock, _, _ = _calibrate_clock(
        bench, cfg, sorted({1, 2, max_batch}), n=calib_n)
    clock = dataclasses.replace(clock, worker_scale=FLEET_WORKER_SCALE)
    # Single-worker capacity in the model's world: deterministically drain
    # a representative mixed backlog (duplicates included) through a
    # throwaway model-clocked server — same rationale as run_scenarios.
    probe = [dataclasses.replace(r, rid=i, arrival_s=0.0)
             for i, r in enumerate(serving_stream(
                 bench, n, seed=seed + 17,
                 arrivals=ArrivalModel(kind="fixed", rate_qps=1e6)))]
    psrv = OptimizerServer(
        config=ServerConfig(max_batch=max_batch, solve_budget_s=math.inf,
                            clock=clock),
        weights=WEIGHTS, cfg=cfg)
    pserved = psrv.serve(probe)
    pspan = max(s.finished_s for s in pserved)
    capacity_qps = len(probe) / pspan if pspan > 0 else 1.0
    rate = load_factor * capacity_qps
    specs = _overload_specs(rate, budget_s=budget_s)
    counts = [n // 3 + (1 if i < n % 3 else 0) for i in range(3)]
    reqs = multi_tenant_stream(bench, specs, counts, seed=seed)
    reserve_s = 2.0 / capacity_qps
    server_cfg = ServerConfig(max_batch=max_batch, solve_budget_s=budget_s,
                              solve_reserve_s=reserve_s, clock=clock)

    def _one(n_workers: int, policy: str) -> dict:
        fleet = OptimizerFleet(
            n_workers=n_workers, config=server_cfg, weights=WEIGHTS,
            cfg=cfg, tenants=specs, policy=policy,
            steal_delay_s=steal_factor * budget_s, seed=seed)
        served = fleet.serve(reqs)
        rep = fleet.latency_report(served)
        caches = fleet.cache_report()
        strict = rep["tenants"]["strict"]
        return {
            "n_workers": n_workers,
            "policy": policy,
            "qps": rep["qps"],
            "makespan_s": rep["makespan_s"],
            "goodput": rep["goodput"],
            "shed_rate": rep["shed_rate"],
            "strict_p99_s": strict["plan_latency_s"]["p99"],
            "strict_shed_rate": strict["shed_rate"],
            "n_stolen": rep["n_stolen"],
            "worker_counts": rep["worker_counts"],
            "response_hit_rate": caches["response"]["hit_rate"],
            "eset_warm_rate": caches["effective_set"]["warm_rate"],
            "survivors_identical":
                _fleet_survivors_identical(served, specs, cfg)
                if check else None,
        }

    curve = {str(nw): {p: _one(nw, p) for p in ("affinity", "random")}
             for nw in workers}
    single = _one(n_max, "single")
    qps1 = curve[str(workers[0])]["affinity"]["qps"]
    scaling = {nw: curve[nw]["affinity"]["qps"] / qps1 for nw in curve}
    wide = [nw for nw in curve if int(nw) > 1]
    return {
        "bench": bench,
        "n_queries": len(reqs),
        "workers": list(workers),
        "capacity_qps": capacity_qps,
        "aggregate_rate_qps": rate,
        "load_factor": load_factor,
        "max_batch": max_batch,
        "budget_s": budget_s,
        "steal_delay_s": steal_factor * budget_s,
        "worker_scale": [list(p) for p in FLEET_WORKER_SCALE],
        "clock_model": {"flush_points": [list(p) for p in
                                         clock.flush_points],
                        "round_s": clock.round_s, "cheap_s": clock.cheap_s},
        "curve": curve,
        "single_policy": single,
        "qps_scaling_vs_1": scaling,
        "qps_scales_with_workers":
            scaling[str(n_max)] == max(scaling.values())
            and scaling[str(n_max)] > 1.0 if len(workers) > 1 else True,
        "affinity_hit_rate_ge_random": all(
            curve[nw]["affinity"]["eset_warm_rate"]
            >= curve[nw]["random"]["eset_warm_rate"] - 1e-12
            and curve[nw]["affinity"]["response_hit_rate"]
            >= curve[nw]["random"]["response_hit_rate"] - 1e-12
            for nw in wide),
        "survivors_identical_all": all(
            v[p]["survivors_identical"] is not False
            for v in curve.values() for p in v) and
            single["survivors_identical"] is not False,
    }


def _train_bench_model(bench: str = "tpch", seed: int = 0, steps: int = 60,
                       n_queries: int = 8, n_conf: int = 6):
    """Briefly trained default-architecture subQ PerfModel.

    Trained inline (not via ``common.get_model``'s 1500-step budget) so
    the standalone smoke path stays minutes-free: solve *throughput* and
    bit-identity do not depend on model fit, only on a real learned
    backend — default GTN/regressor sizes, nonzero input-sensitive
    predictions.
    """
    from repro.core.models.training import build_dataset, train_model
    from repro.queryengine.trace import collect_traces
    from repro.queryengine.workloads import default_workload

    queries = default_workload(bench, 2)[:n_queries]
    traces = collect_traces(queries, n_conf, seed=seed)
    ds, mcfg = build_dataset(traces, "subq")
    return train_model(ds, mcfg, steps=steps, batch=128, seed=seed)


def _clone_model(model):
    """Same weights, fresh jit caches — clean per-path signature accounting.

    The clone's fingerprint equals the original's (content hash), so cache
    semantics are unchanged; only the compile counters start from zero.
    """
    from repro.core.models.perf_model import PerfModel

    return PerfModel(model.cfg, params=model.params,
                     target_stats=model.target_stats)


def _ct_identical(a, b) -> bool:
    return (a.choice == b.choice
            and all(np.array_equal(x, y) for x, y in (
                (a.front, b.front), (a.theta_c, b.theta_c),
                (a.theta_p_sub, b.theta_p_sub),
                (a.theta_s_sub, b.theta_s_sub),
                (a.theta_p0, b.theta_p0), (a.theta_s0, b.theta_s0))))


def run_model_solve(bench: str = "tpch", batch: int = 32,
                    n_batches: int = 4, rate_qps: float = 64.0,
                    n_stream: int = 96, max_batch: int = 8,
                    budget_s: float = 1.0, seed: int = 0,
                    cfg: Optional[HMOOCConfig] = None,
                    model=None, steps: int = 60,
                    sweep=(1, 2, 3, 5, 8, 13), check: bool = True) -> dict:
    """Model-backed jitted solve vs the legacy sequential path.

    Four claims, one scenario each:

    * **solve throughput** — ``n_batches`` successive batches of ``batch``
      fresh queries each, through a legacy (``jit_solve=False``) and a
      batched (default) service with its own model clone.  GTN embeddings
      are prefetched outside the timer: ``embed_many`` is the same code
      path bit-for-bit in both variants, and the tentpole changed the
      *solve*.  The first batch is the compile-inclusive number; later
      batches expose the legacy pathology the jit path fixes — regressor
      row counts are data-dependent (cluster × bank sizes vary per
      query), so the legacy path keeps compiling fresh signatures on
      every new batch while the batched path reuses its bucket ladder.
      The ≥5× target is stated against the sustained throughput (all
      ``n_batches``); the first batch is also reported on its own.
    * **bit identity** — per-query results of the two paths compare equal
      on every batch.
    * **recompilation bound** — a varying-batch sweep (dedup off) on the
      jit-path model, then ``compile_stats()``: compiled signatures must
      not exceed the shape buckets actually seen.
    * **tail latency** — a model-backed ``OptimizerServer`` stream at
      ``rate_qps``; reports p99 solve latency and the solve throughput
      inside flush windows (``ServerStats.tune_windows``).
    """
    cfg = cfg if cfg is not None else HMOOCConfig(seed=seed, **SERVING_CFG)
    base = model if model is not None else _train_bench_model(
        bench, seed=seed, steps=steps)
    m_legacy, m_jit = _clone_model(base), _clone_model(base)

    batches = [list(serving_stream(bench, batch, seed=seed + 1 + k))
               for k in range(n_batches)]

    def _run(m, jit_solve):
        svc = TuningService(model=m, cfg=cfg, jit_solve=jit_solve)
        times, results = [], []
        for qs in batches:
            m.embed_many([(q, i) for q in qs for i in range(q.n_subqs)])
            t0 = time.perf_counter()
            results.append(svc.tune_batch(qs, WEIGHTS))
            times.append(time.perf_counter() - t0)
        return times, results

    legacy_times, legacy_results = _run(m_legacy, False)
    jit_times, jit_results = _run(m_jit, None)
    speedup = legacy_times[0] / jit_times[0]
    speedup_sustained = sum(legacy_times) / sum(jit_times)

    outputs_identical = True
    if check:
        outputs_identical = all(
            _ct_identical(a, b)
            for ra, rb in zip(legacy_results, jit_results)
            for a, b in zip(ra, rb))

    # Varying-batch sweep on the jit-path model: every size lands in a
    # pow2 bucket, so signatures stay ≤ buckets however sizes vary.
    stream = list(serving_stream(bench, sum(sweep), seed=seed + 2))
    svc = TuningService(model=m_jit, cfg=cfg, dedupe=False)
    for size in sweep:
        chunk, stream = stream[:size], stream[size:]
        svc.tune_batch(chunk, WEIGHTS)
    cstats = m_jit.compile_stats()
    lstats = m_legacy.compile_stats()
    compile_bound_ok = (
        cstats["head_compiles"] <= len(cstats["head_buckets"])
        and cstats["embed_compiles"] <= len(cstats["embed_buckets"]))
    from repro.kernels.fused_solve import SEEN_BUCKETS

    # Model-backed streaming at the target arrival rate.
    srv = OptimizerServer(
        config=ServerConfig(max_batch=max_batch, solve_budget_s=budget_s),
        weights=WEIGHTS, cfg=cfg, model=_clone_model(base))
    served = srv.serve(serving_stream(
        bench, n_stream, seed=seed + 3,
        arrivals=ArrivalModel(kind="poisson", rate_qps=rate_qps)))
    rep = srv.latency_report(served)
    tw = srv.last_run.tune_windows
    solve_busy = sum(dt for dt, _ in tw)

    return {
        "bench": bench,
        "batch": batch,
        "n_batches": n_batches,
        "legacy_batch_s": legacy_times,
        "jit_batch_s": jit_times,
        "legacy_qps": batch / legacy_times[0],
        "jit_qps": batch / jit_times[0],
        "legacy_qps_sustained": batch * n_batches / sum(legacy_times),
        "jit_qps_sustained": batch * n_batches / sum(jit_times),
        "legacy_head_compiles": lstats["head_compiles"],
        "speedup_batched_vs_legacy": speedup,
        "speedup_sustained": speedup_sustained,
        "speedup_target_5x": speedup_sustained >= 5.0,
        "outputs_identical": outputs_identical,
        "sweep_batch_sizes": list(sweep),
        "head_compiles": cstats["head_compiles"],
        "head_buckets": [list(b) for b in cstats["head_buckets"]],
        "embed_compiles": cstats["embed_compiles"],
        "embed_buckets": cstats["embed_buckets"],
        "fused_buckets_seen": sorted(list(b) for b in SEEN_BUCKETS),
        "compile_bound_ok": compile_bound_ok,
        "stream": {
            "rate_qps": rate_qps,
            "n_queries": n_stream,
            "max_batch": max_batch,
            "budget_s": budget_s,
            "qps": rep["qps"],
            "plan_latency_s": rep["plan_latency_s"],
            "solve_latency_s": rep["solve_latency_s"],
            "solve_qps_in_flushes":
                (sum(b for _, b in tw) / solve_busy
                 if solve_busy else float("inf")),
            "p99_solve_under_budget":
                rep["solve_latency_s"]["p99"] < budget_s,
        },
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="tpch", choices=["tpch", "tpcds"])
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--rate-qps", type=float, default=16.0)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--budget-s", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tenants", type=int, nargs="?", const=4, default=0,
                    help="run the multi-tenant scenario with N tenants "
                         "(default 4 when given without a value)")
    ap.add_argument("--overload", action="store_true",
                    help="run the overload-shedding scenario (arrival rate "
                         "swept past measured capacity, one tenant per SLO "
                         "class)")
    ap.add_argument("--overload-factor", type=float, default=2.0)
    ap.add_argument("--scenarios", action="store_true",
                    help="run the nonstationary scenario matrix (arrival "
                         "shapes × event timelines), elastic vs static "
                         "capacity, with replay-equivalence checks")
    ap.add_argument("--model-solve", action="store_true",
                    help="run the model-backed jitted-solve scenario only "
                         "(batched vs legacy throughput, bit-identity, "
                         "recompilation bound, 64 q/s stream)")
    ap.add_argument("--fleet", action="store_true",
                    help="run the multi-worker fleet scenario (qps + strict "
                         "p99 vs worker count, cache hit rate by routing "
                         "policy, per-tenant bit-identity)")
    ap.add_argument("--workers", type=int, nargs="+", default=[1, 2, 4],
                    help="fleet worker counts to sweep (with --fleet)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI; checks streaming-path parity "
                         "and the solve budget, skips artifact write")
    args = ap.parse_args()

    if args.smoke:
        # Shared CI runners are noisy: configure the paper's upper-end 2 s
        # budget (typical smoke solves are ~0.2 s, so this still catches a
        # real hot-path regression without wall-clock flakes).
        budget = max(args.budget_s, 2.0)
        cfg = HMOOCConfig(n_c_init=16, n_clusters=4, n_p_pool=48,
                          n_c_enrich=12, max_bank=12, seed=args.seed)
        if args.scenarios:
            res = run_scenarios(args.bench, n_per_tenant=4, max_batch=2,
                                budget_s=budget, seed=args.seed, cfg=cfg,
                                calib_n=12)
            print(json.dumps(res, indent=2))
            if not res["replay_identical_all"]:
                raise SystemExit(
                    "scenario streams diverge from the offline per-request "
                    "replay (static or elastic server)")
            # At smoke load both policies should clear nearly everything;
            # the band absorbs one request's worth of wall-clock jitter.
            bad = [k for k, v in res["scenarios"].items()
                   if v["elastic_goodput_gain"] < -0.1]
            if bad:
                raise SystemExit(f"elastic capacity lost goodput vs static "
                                 f"on: {bad}")
            if not res["flash_crowd_strict_p99_no_worse"]:
                raise SystemExit("elastic capacity worsened strict-tenant "
                                 "p99 on a flash-crowd scenario")
            print("scenarios smoke ok")
            return
        if args.model_solve:
            res = run_model_solve(args.bench, batch=8, n_batches=2,
                                  rate_qps=40.0, n_stream=12, max_batch=4,
                                  budget_s=budget, seed=args.seed, cfg=cfg,
                                  steps=30, sweep=(1, 3, 2, 5))
            print(json.dumps(res, indent=2))
            if not res["outputs_identical"]:
                raise SystemExit("batched jitted solve diverges from the "
                                 "legacy sequential path")
            if not res["compile_bound_ok"]:
                raise SystemExit(
                    f"recompilation bound violated: "
                    f"{res['head_compiles']} head signatures for "
                    f"{len(res['head_buckets'])} buckets, "
                    f"{res['embed_compiles']} embed signatures for "
                    f"{len(res['embed_buckets'])} buckets")
            if not res["stream"]["p99_solve_under_budget"]:
                raise SystemExit(
                    f"model-backed p99 solve latency "
                    f"{res['stream']['solve_latency_s']['p99']:.3f}s "
                    f"breaches the {budget:.1f}s budget")
            print(f"model-solve smoke ok "
                  f"({res['speedup_batched_vs_legacy']:.2f}x batched vs "
                  f"legacy at batch {res['batch']})")
            return
        if args.fleet:
            res = run_fleet(args.bench, n=18,
                            workers=tuple(args.workers[:2]) or (1, 2),
                            max_batch=4, budget_s=budget, seed=args.seed,
                            cfg=cfg, calib_n=12)
            print(json.dumps(res, indent=2))
            if not res["survivors_identical_all"]:
                raise SystemExit(
                    "fleet sharding perturbed surviving queries' outputs "
                    "vs the offline per-tenant pipeline")
            if not res["qps_scales_with_workers"]:
                raise SystemExit(
                    f"aggregate qps failed to scale with worker count: "
                    f"{res['qps_scaling_vs_1']}")
            if not res["affinity_hit_rate_ge_random"]:
                raise SystemExit(
                    "affinity routing lost to random routing on cache hit "
                    "rate — the template-affinity ring is not keeping "
                    "templates on their owning workers")
            print("fleet smoke ok")
            return
        if args.overload:
            res = run_overload(args.bench, n=18,
                               overload_factor=args.overload_factor,
                               max_batch=4, budget_s=budget, seed=args.seed,
                               cfg=cfg, calib_n=12)
            print(json.dumps(res, indent=2))
            if not res["outcomes_accounted"]:
                raise SystemExit("some requests lost or half-served under "
                                 "overload (status/artifact mismatch)")
            if not res["survivors_identical"]:
                raise SystemExit("overload perturbed surviving queries' "
                                 "outputs vs the offline pipeline")
            if not res["degraded_zero_fresh_solves"]:
                raise SystemExit("degraded admissions triggered fresh "
                                 "Algorithm 1 bank builds")
            if not res["strict_p99_under_budget"]:
                raise SystemExit(
                    f"strict tenant p99 plan latency "
                    f"{res['strict_p99_plan_latency_s']:.3f}s breached its "
                    f"{budget:.1f}s budget under overload "
                    f"({res['strict_n_finished']} finished)")
            print("overload smoke ok")
            return
        if args.tenants:
            res = run_tenants(args.bench, n=16, rate_qps=40.0,
                              n_tenants=args.tenants, max_batch=4,
                              budget_s=budget, seed=args.seed, cfg=cfg)
            print(json.dumps(res, indent=2))
            if not res["outputs_identical_per_tenant"]:
                raise SystemExit("multi-tenant outputs diverge from the "
                                 "per-tenant offline pipeline")
            # Fairness smoke: gross starvation shows up as a collapsed Jain
            # index; the threshold is loose because smoke-sized tails are
            # noisy on shared CI runners.
            if not (res["fairness_jain"] >= 0.5):
                raise SystemExit(
                    f"Jain fairness collapsed: {res['fairness_jain']:.3f}")
            print("tenants smoke ok")
            return
        res = run(args.bench, n=16, rate_qps=40.0, max_batch=4,
                  budget_s=budget, baseline_batch=8, seed=args.seed,
                  cfg=cfg)
        print(json.dumps(res, indent=2))
        if not res["outputs_identical"]:
            raise SystemExit("streaming-admission outputs diverge from the "
                             "offline pipeline")
        if res["server"]["solve_latency_s"]["max"] >= budget:
            raise SystemExit(
                f"max solve latency "
                f"{res['server']['solve_latency_s']['max']:.3f}s breaches "
                f"the {budget:.1f}s budget")
        print("smoke ok")
        return

    if args.model_solve:
        res = run_model_solve(args.bench, seed=args.seed,
                              budget_s=args.budget_s,
                              max_batch=args.max_batch)
        print(json.dumps(res, indent=2))
        print(f"\nmodel-solve: {res['speedup_batched_vs_legacy']:.2f}x "
              f"batched vs legacy at batch {res['batch']} "
              f"({res['jit_qps']:.1f} vs {res['legacy_qps']:.1f} q/s, "
              f"sustained {res['speedup_sustained']:.2f}x, legacy compiled "
              f"{res['legacy_head_compiles']} signatures vs "
              f"{res['head_compiles']}) | "
              f"identical: {res['outputs_identical']} | signatures "
              f"head {res['head_compiles']}/{len(res['head_buckets'])} "
              f"embed {res['embed_compiles']}/{len(res['embed_buckets'])} "
              f"(bound ok: {res['compile_bound_ok']}) | stream @ "
              f"{res['stream']['rate_qps']:.0f} q/s solve p99 "
              f"{res['stream']['solve_latency_s']['p99'] * 1e3:.0f} ms")
        for p in save_bench("server_model_solve", res):
            print(f"wrote {p}")
        return

    if args.scenarios:
        # The scenario bench carries its own calibrated regime (single-
        # query static cap, tight budget, load paced off the model-world
        # drain capacity); the generic --max-batch/--budget-s knobs
        # don't apply.
        res = run_scenarios(args.bench, seed=args.seed)
        print(json.dumps(res, indent=2))
        print(f"\nscenarios ({len(res['scenarios'])}, load "
              f"{res['load_factor']:.1f}x capacity "
              f"{res['capacity_qps']:.1f} q/s): flash-crowd goodput "
              f"static {res['flash_crowd_goodput_static']:.2f} → elastic "
              f"{res['flash_crowd_goodput_elastic']:.2f} | strict p99 no "
              f"worse: {res['flash_crowd_strict_p99_no_worse']} | replay "
              f"identical: {res['replay_identical_all']}")
        for p in save_bench("server_scenarios", res):
            print(f"wrote {p}")
        return

    if args.fleet:
        res = run_fleet(args.bench, n=args.n, workers=tuple(args.workers),
                        max_batch=args.max_batch, budget_s=args.budget_s,
                        seed=args.seed)
        print(json.dumps(res, indent=2))
        n_max = str(max(args.workers))
        top = res["curve"][n_max]["affinity"]
        print(f"\nfleet (load {res['load_factor']:.1f}x capacity "
              f"{res['capacity_qps']:.1f} q/s): qps scaling vs 1 worker "
              f"{res['qps_scaling_vs_1']} | affinity@{n_max}: "
              f"{top['qps']:.1f} q/s, strict p99 "
              f"{top['strict_p99_s'] * 1e3:.0f} ms, warm rate "
              f"{top['eset_warm_rate']:.2f} vs random "
              f"{res['curve'][n_max]['random']['eset_warm_rate']:.2f} | "
              f"affinity >= random hit rate: "
              f"{res['affinity_hit_rate_ge_random']} | survivors "
              f"identical: {res['survivors_identical_all']}")
        for p in save_bench("server_fleet", res):
            print(f"wrote {p}")
        return

    if args.overload:
        res = run_overload(args.bench, n=args.n,
                           overload_factor=args.overload_factor,
                           max_batch=args.max_batch, budget_s=args.budget_s,
                           seed=args.seed)
        print(json.dumps(res, indent=2))
        print(f"\noverload ({res['overload_factor']:.1f}x capacity "
              f"{res['capacity_qps']:.1f} q/s): strict shed rate "
              f"{res['strict_shed_rate']:.2f}, strict p99 "
              f"{res['strict_p99_plan_latency_s'] * 1e3:.0f} ms "
              f"(≤ budget: {res['strict_p99_under_budget']}) | goodput "
              f"{res['goodput']:.2f} | degraded cheap/default "
              f"{res['cheap_solves']}/{res['default_theta_solves']} | "
              f"survivors identical: {res['survivors_identical']}")
        for p in save_bench("server_overload", res):
            print(f"wrote {p}")
        return

    res = run(args.bench, n=args.n, rate_qps=args.rate_qps,
              max_batch=args.max_batch, budget_s=args.budget_s,
              seed=args.seed)
    res["tenants_scenario"] = run_tenants(
        args.bench, n=args.n, rate_qps=args.rate_qps,
        n_tenants=args.tenants or 4, max_batch=args.max_batch,
        budget_s=args.budget_s, seed=args.seed)
    res["overload_scenario"] = run_overload(
        args.bench, n=args.n, max_batch=args.max_batch,
        budget_s=args.budget_s, seed=args.seed)
    res["model_solve"] = run_model_solve(
        args.bench, seed=args.seed, budget_s=args.budget_s,
        max_batch=args.max_batch)
    res["scenarios"] = run_scenarios(args.bench, seed=args.seed)
    res["fleet_scaling"] = run_fleet(
        args.bench, n=args.n, workers=tuple(args.workers),
        max_batch=args.max_batch, budget_s=args.budget_s, seed=args.seed)
    print(json.dumps(res, indent=2))
    s, b = res["server"], res["batch32_baseline"]
    print(f"\nserver: {s['qps']:.1f} q/s, plan p99 "
          f"{s['plan_latency_s']['p99'] * 1e3:.0f} ms | batch-32 baseline: "
          f"{b['qps']:.1f} q/s, plan p99 "
          f"{b['plan_latency_s']['p99'] * 1e3:.0f} ms | "
          f"{res['speedup_qps_vs_batch32']:.2f}x qps, "
          f"{res['p99_plan_latency_reduction_vs_batch32']:.1f}x lower p99 | "
          f"identical: {res['outputs_identical']} | "
          f"p99 under {res['budget_s']:.1f}s budget: "
          f"{res['p99_under_budget']}")
    tn = res["tenants_scenario"]
    print(f"tenants ({tn['n_tenants']}, same aggregate load): "
          f"max per-tenant plan p99 {tn['max_tenant_p99_s'] * 1e3:.0f} ms "
          f"vs single-stream {tn['baseline_single_stream_p99_s'] * 1e3:.0f}"
          f" ms | Jain {tn['fairness_jain']:.3f} | per-tenant identical: "
          f"{tn['outputs_identical_per_tenant']} | no p99 regression: "
          f"{tn['no_tenant_p99_regression']}")
    ov = res["overload_scenario"]
    print(f"overload ({ov['overload_factor']:.1f}x capacity "
          f"{ov['capacity_qps']:.1f} q/s): strict shed rate "
          f"{ov['strict_shed_rate']:.2f}, strict p99 "
          f"{ov['strict_p99_plan_latency_s'] * 1e3:.0f} ms "
          f"(≤ budget: {ov['strict_p99_under_budget']}) | goodput "
          f"{ov['goodput']:.2f} | survivors identical: "
          f"{ov['survivors_identical']}")
    ms = res["model_solve"]
    print(f"model-solve: {ms['speedup_batched_vs_legacy']:.2f}x batched vs "
          f"legacy at batch {ms['batch']} | identical: "
          f"{ms['outputs_identical']} | compile bound ok: "
          f"{ms['compile_bound_ok']} | stream @ "
          f"{ms['stream']['rate_qps']:.0f} q/s solve p99 "
          f"{ms['stream']['solve_latency_s']['p99'] * 1e3:.0f} ms")
    sn = res["scenarios"]
    print(f"scenarios ({len(sn['scenarios'])}): flash-crowd goodput "
          f"static {sn['flash_crowd_goodput_static']:.2f} → elastic "
          f"{sn['flash_crowd_goodput_elastic']:.2f} (beats static: "
          f"{sn['flash_crowd_elastic_beats_static']}, strict p99 no "
          f"worse: {sn['flash_crowd_strict_p99_no_worse']}) | replay "
          f"identical: {sn['replay_identical_all']}")
    fl = res["fleet_scaling"]
    print(f"fleet: qps scaling vs 1 worker {fl['qps_scaling_vs_1']} | "
          f"affinity >= random hit rate: "
          f"{fl['affinity_hit_rate_ge_random']} | survivors identical: "
          f"{fl['survivors_identical_all']}")
    for p in save_bench("server", res, headline=True):
        print(f"wrote {p}")


if __name__ == "__main__":
    main()
