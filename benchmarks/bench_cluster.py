"""Cluster-autotuner benchmark (beyond-paper feature) + kernel microbench."""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.cluster.autotune import autotune
from repro.core.moo.pareto import hypervolume_2d


def run_cluster_autotune(archs=("qwen2-72b", "dbrx-132b", "rwkv6-1.6b"),
                         shape: str = "train_4k") -> List[dict]:
    rows = []
    for arch in archs:
        for w in [(0.9, 0.1), (0.5, 0.5), (0.1, 0.9)]:
            plan = autotune(arch, shape, weights=w)
            F = plan.front
            lo, hi = F.min(0), F.max(0)
            span = np.where(hi > lo, hi - lo, 1.0)
            hv = hypervolume_2d((F - lo) / span, np.array([1.1, 1.1]))
            rows.append({
                "arch": arch, "shape": shape,
                "weights": f"{w[0]}/{w[1]}",
                "chips": int(plan.theta_c["n_chips"]),
                "tp": int(plan.theta_c["model_par"]),
                "carry_shard": bool(plan.theta_c["act_shard_model"]),
                "pred_ms_per_step": round(plan.predicted[0] * 1e3, 1),
                "pred_usd_per_step": round(plan.predicted[1], 5),
                "front_size": F.shape[0],
                "front_hv": round(hv, 4),
                "solve_time_s": round(plan.solve_time, 3),
            })
    return rows


def run_kernels() -> List[dict]:
    """Kernel microbenches (interpret mode on CPU — correctness + call
    overhead; on-TPU timing is the deployment path)."""
    import jax.numpy as jnp
    from repro.kernels.flash_attention.ops import attention_ref, \
        flash_attention
    from repro.kernels.pareto_filter.ops import pareto_filter, \
        pareto_mask_ref
    from repro.kernels.ws_reduce.ops import ws_reduce, ws_reduce_ref
    rng = np.random.default_rng(0)
    rows = []

    F = jnp.asarray(rng.random((512, 2)).astype(np.float32))
    valid = jnp.ones(512, bool)
    for name, fn in [("pareto_filter[512x2]",
                      lambda: pareto_filter(F, valid)),
                     ("pareto_ref[512x2]",
                      lambda: pareto_mask_ref(F, valid))]:
        fn()
        t0 = time.perf_counter()
        for _ in range(3):
            fn()
        rows.append({"kernel": name,
                     "us_per_call": (time.perf_counter() - t0) / 3 * 1e6})

    Fb = jnp.asarray(rng.random((8, 128, 2)).astype(np.float32))
    W = jnp.asarray(rng.random((11, 2)).astype(np.float32))
    for name, fn in [("ws_reduce[8x128x2,w11]",
                      lambda: ws_reduce(Fb, W)),
                     ("ws_reduce_ref", lambda: ws_reduce_ref(Fb, W))]:
        fn()
        t0 = time.perf_counter()
        for _ in range(3):
            fn()
        rows.append({"kernel": name,
                     "us_per_call": (time.perf_counter() - t0) / 3 * 1e6})

    q = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    for name, fn in [("flash_attn[256,GQA2]",
                      lambda: flash_attention(q, k, v, causal=True)),
                     ("attn_ref", lambda: attention_ref(q, k, v,
                                                        causal=True))]:
        fn()
        t0 = time.perf_counter()
        for _ in range(3):
            fn()
        rows.append({"kernel": name,
                     "us_per_call": (time.perf_counter() - t0) / 3 * 1e6})
    return rows
