"""Shared benchmark context: workloads, trained-model artifacts, caching.

Model artifacts are trained once and cached under ``results/models/`` so
repeated benchmark runs (and the end-to-end evaluation) reuse them.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.models.perf_model import ModelConfig, PerfModel
from repro.core.models.training import (build_dataset, evaluate,
                                        train_model)
from repro.queryengine.trace import TraceSet, collect_traces
from repro.queryengine.workloads import default_workload, make_benchmark

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results")

# Fast-mode budgets (full mode quadruples steps & configs).
FAST = {"tpch": dict(variants=3, confs=32, steps=1500, lqp_steps=500),
        "tpcds": dict(variants=1, confs=24, steps=1500, lqp_steps=1200)}


def results_dir(*parts: str) -> str:
    d = os.path.join(RESULTS, *parts)
    os.makedirs(d, exist_ok=True)
    return d


REPO_ROOT = os.path.dirname(RESULTS)


def save_bench(name: str, payload, *, headline: bool = False) -> list:
    """Single writer for benchmark artifacts.

    Canonical path is ``results/bench/<name>.json`` (what ``benchmarks.run``
    and the standalone scripts both use).  ``headline=True`` additionally
    mirrors the payload to ``BENCH_<name>.json`` at the repo root — a
    generated copy for README links, produced by this one code path so the
    two files cannot drift.
    """
    paths = [os.path.join(results_dir("bench"), f"{name}.json")]
    if headline:
        paths.append(os.path.join(REPO_ROOT, f"BENCH_{name}.json"))
    for p in paths:
        with open(p, "w") as f:
            json.dump(payload, f, indent=2, default=str)
    return paths


_TRACE_CACHE: Dict[str, TraceSet] = {}


def get_traces(bench: str, fast: bool = True) -> TraceSet:
    if bench not in _TRACE_CACHE:
        cfg = FAST[bench]
        qs = default_workload(bench, cfg["variants"], seed=0)
        _TRACE_CACHE[bench] = collect_traces(qs, cfg["confs"], seed=0)
    return _TRACE_CACHE[bench]


_MODEL_CACHE: Dict[Tuple[str, str], Tuple[PerfModel, object, object]] = {}


def get_model(bench: str, kind: str, fast: bool = True,
              verbose: bool = True):
    """(model, dataset, metrics) for one benchmark × target kind."""
    key = (bench, kind)
    if key in _MODEL_CACHE:
        return _MODEL_CACHE[key]
    traces = get_traces(bench, fast)
    ds, cfg = build_dataset(traces, kind, seed=0)
    path = os.path.join(results_dir("models"), f"{bench}_{kind}.npz")
    budget = FAST[bench]
    steps = budget["lqp_steps"] if kind == "lqp" else budget["steps"]
    if os.path.exists(path):
        model = PerfModel.load(cfg, path)
        if verbose:
            print(f"  [models] loaded {bench}/{kind} from cache")
    else:
        t0 = time.time()
        bs = 64 if kind == "lqp" else 512
        model = train_model(ds, cfg, steps=steps, batch=bs, seed=0)
        model.save(path)
        if verbose:
            print(f"  [models] trained {bench}/{kind} "
                  f"({steps} steps, {time.time()-t0:.0f}s)")
    met = evaluate(model, ds)
    _MODEL_CACHE[key] = (model, ds, met)
    return _MODEL_CACHE[key]


def eval_queries(bench: str):
    return make_benchmark(bench)
