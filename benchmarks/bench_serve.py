"""Serving benchmark: single-query latency + batched tuning throughput.

Measures, on the oracle objective (no trained model needed):

* ``legacy``  — the pre-refactor HMOOC solver (Python loops over
  representatives × subQs, per-keep DAG gathers) run one query at a time:
  the "single-query-loop" baseline.
* ``single``  — the vectorized solver, one ``compile_time_optimize`` per
  query, no cache.
* ``batch N`` — ``repro.serve.tune_batch`` over a production-like
  repeated-template stream at batch sizes 1 / 8 / 32 with a shared
  effective-set cache + request dedup.

Also verifies, for every benchmark query, that the batched service returns
exactly the same Pareto front (same points, any order) as the sequential
solver.

Run:  PYTHONPATH=src python benchmarks/bench_serve.py
(artifacts: results/bench/serve.json + the BENCH_serve.json headline
mirror, both written by benchmarks.common.save_bench)
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List, Tuple

import numpy as np

from repro.core.moo.clustering import kmeans_fit
from repro.core.moo.hmooc import (HMOOCConfig, _crossover, _lhs, _snap_unique,
                                  dag_aggregate)
from repro.core.moo.pareto import pareto_mask_np
from repro.core.moo.wun import wun_select
from repro.core.tuning.compile_time import compile_time_optimize
from repro.core.tuning.objectives import StageObjectives
from repro.queryengine.workloads import make_benchmark, serving_stream
from repro.serve import TuningService

try:
    from .common import save_bench
except ImportError:          # standalone: python benchmarks/bench_serve.py
    from common import save_bench


# ---------------------------------------------------------------------------
# Pre-refactor solver (the seed repo's loop structure), kept as the baseline
# ---------------------------------------------------------------------------

def _legacy_pareto_bank(F, cap):
    mask = pareto_mask_np(F)
    idx = np.nonzero(mask)[0]
    if idx.size > cap:
        order = idx[np.argsort(F[idx, 0])]
        keep = np.linspace(0, order.size - 1, cap).round().astype(int)
        idx = order[keep]
    return idx


def _legacy_subq_tuning(stage_eval, m, d_c, d_ps, cfg, snap_c, snap_ps, rng):
    """Algorithm 1 with the original per-(representative, subQ) loops."""
    Uc0 = _snap_unique(_lhs(rng, cfg.n_c_init, d_c), snap_c)
    km, labels0 = kmeans_fit(Uc0, cfg.n_clusters, rng)
    reps = snap_c(km.centers) if snap_c is not None else km.centers
    pool = _lhs(rng, cfg.n_p_pool, d_ps)
    if snap_ps is not None:
        pool = snap_ps(pool)
    C = reps.shape[0]
    opt_idx, k_obj = [], 2
    for r in range(C):                        # C × m stage_eval calls
        Tc = np.tile(reps[r], (pool.shape[0], 1))
        per_subq = []
        for i in range(m):
            F = stage_eval(i, Tc, pool)
            k_obj = F.shape[1]
            per_subq.append(_legacy_pareto_bank(F, cfg.max_bank))
        opt_idx.append(per_subq)

    def assign(Uc, labels):                   # up to C × m more calls
        N, B = Uc.shape[0], cfg.max_bank
        F_bank = np.full((N, m, B, k_obj), np.inf)
        idx_bank = np.full((N, m, B), -1, int)
        for r in range(C):
            members = np.nonzero(labels == r)[0]
            if members.size == 0:
                continue
            for i in range(m):
                sel = opt_idx[r][i]
                if sel.size == 0:
                    continue
                nb = min(sel.size, B)
                sel = sel[:nb]
                Tc = np.repeat(Uc[members], nb, axis=0)
                Tp = np.tile(pool[sel], (members.size, 1))
                F = stage_eval(i, Tc, Tp).reshape(members.size, nb, k_obj)
                F_bank[members, i, :nb] = F
                idx_bank[members, i, :nb] = sel
        return F_bank, idx_bank

    F0, I0 = assign(Uc0, labels0)
    Uc1 = _crossover(Uc0, cfg.n_c_enrich, d_c, rng)
    if snap_c is not None and Uc1.size:
        Uc1 = _snap_unique(Uc1, snap_c)
    if Uc1.size:
        dup = (Uc1[:, None, :] == Uc0[None, :, :]).all(-1).any(1)
        Uc1 = Uc1[~dup]
    if Uc1.size:
        F1, I1 = assign(Uc1, km.assign(Uc1))
        return (np.concatenate([Uc0, Uc1]), pool,
                np.concatenate([F0, F1]), np.concatenate([I0, I1]))
    return Uc0, pool, F0, I0


def legacy_optimize(query, weights, cfg) -> Tuple[np.ndarray, int]:
    """Pre-refactor single-query compile-time solve (oracle objective)."""
    obj = StageObjectives(query)
    rng = np.random.default_rng(cfg.seed)
    Uc, pool, F_bank, idx_bank = _legacy_subq_tuning(
        obj.stage_eval, obj.m, obj.d_c, obj.d_ps, cfg,
        obj.snap_c, obj.snap_ps, rng)
    front, theta_c, theta_ps = dag_aggregate(
        Uc, pool, F_bank, idx_bank, cfg.dag_method,
        n_ws_weights=cfg.n_ws_weights)
    choice, _ = wun_select(front, np.asarray(weights))
    return front, choice


# ---------------------------------------------------------------------------
# Benchmark
# ---------------------------------------------------------------------------

def run(bench: str, cfg: HMOOCConfig, batch_sizes: List[int],
        stream_len: int, seed: int) -> dict:
    weights = (0.9, 0.1)
    eval_qs = make_benchmark(bench)
    stream = serving_stream(bench, stream_len, seed=seed)

    # --- correctness: batched front == sequential front, every query -------
    svc = TuningService(cfg=cfg)
    batched = svc.tune_batch(eval_qs, weights)
    fronts_identical = True
    max_solve_ms = 0.0
    for q, r in zip(eval_qs, batched):
        ref = compile_time_optimize(q, weights=weights, cfg=cfg)
        a = np.sort(r.front.view([('f0', float), ('f1', float)]), axis=0)
        b = np.sort(ref.front.view([('f0', float), ('f1', float)]), axis=0)
        if a.shape != b.shape or not np.array_equal(a, b):
            fronts_identical = False
        max_solve_ms = max(max_solve_ms, 1e3 * ref.solve_time)

    # --- legacy single-query loop ------------------------------------------
    t0 = time.perf_counter()
    for q in stream:
        legacy_optimize(q, weights, cfg)
    t_legacy = time.perf_counter() - t0

    # --- vectorized solver, one query at a time, no cache ------------------
    t0 = time.perf_counter()
    for q in stream:
        compile_time_optimize(q, weights=weights, cfg=cfg)
    t_single = time.perf_counter() - t0

    # --- batched service ---------------------------------------------------
    per_batch = {}
    for bs in batch_sizes:
        svc = TuningService(cfg=cfg)       # fresh cache per setting
        t0 = time.perf_counter()
        for lo in range(0, len(stream), bs):
            svc.tune_batch(stream[lo:lo + bs], weights)
        dt = time.perf_counter() - t0
        per_batch[bs] = {
            "qps": len(stream) / dt,
            "total_s": dt,
            "cache": svc.cache.stats(),
        }

    legacy_qps = len(stream) / t_legacy
    bs_top = max(batch_sizes)
    return {
        "bench": bench,
        "stream_len": len(stream),
        "n_eval_queries": len(eval_qs),
        "config": {"n_c_init": cfg.n_c_init, "n_p_pool": cfg.n_p_pool,
                   "dag_method": cfg.dag_method, "seed": cfg.seed},
        "fronts_identical": fronts_identical,
        "max_single_solve_ms": max_solve_ms,
        "legacy_qps": legacy_qps,
        "legacy_ms_per_query": 1e3 * t_legacy / len(stream),
        "single_qps": len(stream) / t_single,
        "single_ms_per_query": 1e3 * t_single / len(stream),
        "batched": {str(bs): per_batch[bs] for bs in batch_sizes},
        "speedup_batch_top_vs_legacy":
            per_batch[bs_top]["qps"] / legacy_qps,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="tpch", choices=["tpch", "tpcds"])
    ap.add_argument("--batch-sizes", type=int, nargs="+", default=[1, 8, 32])
    ap.add_argument("--stream-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = HMOOCConfig(seed=args.seed)
    res = run(args.bench, cfg, sorted(args.batch_sizes), args.stream_len,
              args.seed)
    print(json.dumps(res, indent=2))
    top = str(max(args.batch_sizes))
    print(f"\nlegacy loop: {res['legacy_qps']:.2f} q/s | "
          f"vectorized single: {res['single_qps']:.2f} q/s | "
          f"batch {top}: {res['batched'][top]['qps']:.2f} q/s "
          f"({res['speedup_batch_top_vs_legacy']:.1f}x vs legacy) | "
          f"fronts identical: {res['fronts_identical']} | "
          f"max solve {res['max_single_solve_ms']:.0f} ms")
    for p in save_bench("serve", res, headline=True):
        print(f"wrote {p}")


if __name__ == "__main__":
    main()
