"""Benchmark orchestrator: one entry per paper table/figure (+ ours).

    PYTHONPATH=src python -m benchmarks.run [--only NAME[,NAME...]]
        [--bench tpch|tpcds|both] [--oracle] [--full]

Prints one CSV block per benchmark and writes JSON to results/bench/.

Benchmarks → paper artifacts:
  model_accuracy    Table 3      GTN+regressor WMAPE/P50/P90/Corr/Xput
  dag_aggregation   Fig 10(a,b)  HMOOC1/2/3 HV + solving time
  moo_comparison    Fig 10(c–e)  HMOOC3 vs WS/Evo/PF (fine-grained)
  granularity       Fig 10(f)    query-level baselines vs HMOOC3
  ws_coverage       Fig 4        WS front-collapse pathology
  end_to_end        Table 4      latency reduction @ (0.9, 0.1)
  adaptability      Table 5      preference sweep vs SO-FW
  pruning           §5.2         runtime-request pruning rates
  serve             (ours)       batched tuning-service throughput
  runtime           (ours)       batched runtime re-optimization service
  server            (ours)       streaming-admission server latency/throughput
  server_tenants    (ours)       multi-tenant fairness + per-tenant p99/Jain
  server_overload   (ours)       overload shedding: SLO classes past capacity
  server_model_solve (ours)      jitted model-backed solve vs legacy path
  server_scenarios  (ours)       nonstationary scenarios: elastic vs static
  server_fleet      (ours)       multi-worker fleet qps scaling + routing
  roofline          (ours)       per-cell dry-run roofline table
  cluster_autotune  (ours)       HMOOC on the JAX cluster itself
  kernels           (ours)       Pallas kernel microbenches
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Callable, Dict, List

from .common import save_bench


def _print_rows(name: str, rows: List[dict]) -> None:
    print(f"\n=== {name} ===")
    if not rows:
        print("(no rows)")
        return
    keys = list(rows[0].keys())
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in keys))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--bench", default="tpch",
                    choices=["tpch", "tpcds", "both"])
    ap.add_argument("--oracle", action="store_true",
                    help="use simulator-on-estimates objectives (no models)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    benches = ["tpch", "tpcds"] if args.bench == "both" else [args.bench]
    use_model = not args.oracle
    nq = None if args.full else 10

    from . import bench_cluster, bench_end_to_end, bench_models, bench_moo, \
        bench_roofline, bench_runtime, bench_serve, bench_server
    from repro.core.moo.hmooc import HMOOCConfig

    registry: Dict[str, Callable[[], List[dict]]] = {
        "model_accuracy": lambda: bench_models.run_model_accuracy(
            ("tpch", "tpcds")),   # Table 3 covers both benchmarks
        "dag_aggregation": lambda: [r for b in benches for r in
                                    bench_moo.run_dag_aggregation(
                                        b, n_queries=nq or 22,
                                        use_model=use_model)],
        "moo_comparison": lambda: [r for b in benches for r in
                                   bench_moo.run_moo_comparison(
                                       b, n_queries=nq or 22, fine=True,
                                       use_model=use_model)],
        "granularity": lambda: [r for b in benches for r in
                                bench_moo.run_moo_comparison(
                                    b, n_queries=nq or 22, fine=False,
                                    use_model=use_model)],
        "ws_coverage": lambda: [r for b in benches for r in
                                bench_moo.run_ws_coverage(
                                    b, use_model=use_model)],
        "end_to_end": lambda: [r for b in benches for r in
                               bench_end_to_end.run_end_to_end(
                                   b, n_queries=None if args.full else 22,
                                   use_model=use_model)],
        "adaptability": lambda: [r for b in benches for r in
                                 bench_end_to_end.run_adaptability(
                                     b, n_queries=None if args.full else 22,
                                     use_model=use_model)],
        "pruning": lambda: [r for b in ("tpch", "tpcds") for r in
                            bench_end_to_end.run_pruning(b)],
        "serve": lambda: [bench_serve.run(
            b, HMOOCConfig(), [1, 8, 32], stream_len=64, seed=0)
            for b in benches],
        "runtime": lambda: [bench_runtime.run(
            b, n_queries=32 if args.full else 16) for b in benches],
        "server": lambda: [bench_server.run(
            b, n=64 if args.full else 32) for b in benches],
        "server_tenants": lambda: [bench_server.run_tenants(
            b, n=64 if args.full else 32) for b in benches],
        "server_overload": lambda: [bench_server.run_overload(
            b, n=96 if args.full else 48) for b in benches],
        "server_model_solve": lambda: [bench_server.run_model_solve(
            b, n_batches=4 if args.full else 2) for b in benches],
        # n_per_tenant=24 in both modes: shorter streams sit under the
        # pressure regime the elastic-vs-static comparison is sized for.
        "server_scenarios": lambda: [bench_server.run_scenarios(b)
                                     for b in benches],
        "server_fleet": lambda: [bench_server.run_fleet(
            b, n=96 if args.full else 48) for b in benches],
        "roofline": bench_roofline.run_roofline,
        "cluster_autotune": bench_cluster.run_cluster_autotune,
        "kernels": bench_cluster.run_kernels,
    }

    only = args.only.split(",") if args.only else list(registry)
    summary = {}
    for name in only:
        if name not in registry:
            print(f"unknown benchmark: {name}", file=sys.stderr)
            continue
        t0 = time.time()
        try:
            rows = registry[name]()
        except Exception as exc:  # noqa: BLE001 — report and continue
            print(f"\n=== {name} === FAILED: {type(exc).__name__}: {exc}")
            summary[name] = "failed"
            continue
        _print_rows(name, rows)
        save_bench(name, rows)
        summary[name] = f"{len(rows)} rows, {time.time()-t0:.0f}s"
    print("\n=== summary ===")
    for k, v in summary.items():
        print(f"{k}: {v}")


if __name__ == "__main__":
    main()
