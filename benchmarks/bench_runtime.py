"""Runtime re-optimization serving benchmark (§5.2 at scale).

Measures, on the oracle backend (no trained model needed), the AQE-triggered
θp/θs re-tuning of a batch of concurrent queries:

* ``loop``  — the per-query path: ``make_runtime_optimizers`` +
  ``run_with_aqe`` for each query in sequence (synchronous callbacks).
* ``batch`` — ``repro.serve.RuntimeSession.run_batch``: the same queries
  driven through the request/response protocol with cross-query fusion
  (one stage-core / model call per fusion group per round, fused
  realization, shared candidate pools).

Also verifies per-query outputs are bit-identical between the two paths
(θ_eff, final joins, request counts, simulated latency/IO/cost).

Run:  PYTHONPATH=src python benchmarks/bench_runtime.py
      PYTHONPATH=src python benchmarks/bench_runtime.py --smoke   # CI
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List

import numpy as np

from repro.core.moo.hmooc import HMOOCConfig
from repro.core.tuning.runtime import make_runtime_optimizers
from repro.queryengine.aqe import AQEResult, run_with_aqe
from repro.queryengine.workloads import serving_stream
from repro.serve import RuntimeSession, TuningService

try:
    from .common import save_bench
except ImportError:          # standalone: python benchmarks/bench_runtime.py
    from common import save_bench

WEIGHTS = (0.9, 0.1)


def _loop(queries, compiled, n_candidates: int,
          seed: int) -> List[AQEResult]:
    out = []
    for q, ct in zip(queries, compiled):
        lqp_o, qs_o = make_runtime_optimizers(
            q, ct.theta_c, seed_theta_p=ct.theta_p_sub,
            seed_theta_s=ct.theta_s_sub, weights=WEIGHTS,
            n_candidates=n_candidates, seed=seed)
        out.append(run_with_aqe(q, ct.theta_c, ct.theta_p0, ct.theta_s0,
                                lqp_optimizer=lqp_o, qs_optimizer=qs_o))
    return out


def _identical(a: List[AQEResult], b: List[AQEResult]) -> bool:
    for x, y in zip(a, b):
        for f, g in ((x.theta_p_eff, y.theta_p_eff),
                     (x.theta_s_eff, y.theta_s_eff),
                     (x.final_join, y.final_join),
                     (x.sim.ana_latency, y.sim.ana_latency),
                     (x.sim.actual_latency, y.sim.actual_latency),
                     (x.sim.io_gb, y.sim.io_gb),
                     (x.sim.cost, y.sim.cost)):
            if not np.array_equal(f, g):
                return False
        if (x.requests_sent, x.requests_total) != (y.requests_sent,
                                                   y.requests_total):
            return False
    return True


def run(bench: str = "tpch", n_queries: int = 32, n_candidates: int = 64,
        repeats: int = 5, seed: int = 0) -> dict:
    queries = serving_stream(bench, n_queries, seed=seed)
    svc = TuningService(cfg=HMOOCConfig(seed=seed))
    t0 = time.perf_counter()
    compiled = svc.tune_batch(queries, WEIGHTS)
    t_compile = time.perf_counter() - t0

    # Correctness first: the fused session must bit-match the loop.
    loop_res = _loop(queries, compiled, n_candidates, seed)
    sess = RuntimeSession(weights=WEIGHTS, n_candidates=n_candidates,
                          seed=seed)
    batch_res = sess.run_batch(queries, compiled)
    identical = _identical(loop_res, batch_res)

    t_loop = min(_timed(
        lambda: _loop(queries, compiled, n_candidates, seed), repeats))
    t_batch = min(_timed(
        lambda: RuntimeSession(weights=WEIGHTS, n_candidates=n_candidates,
                               seed=seed).run_batch(queries, compiled),
        repeats))

    req_sent = sum(r.requests_sent for r in batch_res)
    req_total = sum(r.requests_total for r in batch_res)
    st = sess.last_batch
    return {
        "bench": bench,
        "n_queries": n_queries,
        "n_candidates": n_candidates,
        "compile_batch_s": t_compile,
        "requests_sent": req_sent,
        "requests_total": req_total,
        "prune_rate": 1.0 - req_sent / req_total,
        "outputs_identical": identical,
        "loop_s": t_loop,
        "batch_s": t_batch,
        "loop_rps": req_sent / t_loop,
        "batch_rps": req_sent / t_batch,
        "loop_qps": n_queries / t_loop,
        "batch_qps": n_queries / t_batch,
        "speedup_batch_vs_loop": t_loop / t_batch,
        "mean_query_latency_s": float(np.mean(
            [r.sim.actual_latency[0] for r in batch_res])),
        "session": {"rounds": st.rounds, "fused_calls": st.fused_calls},
    }


def _timed(fn, repeats: int) -> List[float]:
    out = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        out.append(time.perf_counter() - t0)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="tpch", choices=["tpch", "tpcds"])
    ap.add_argument("--n-queries", type=int, default=32)
    ap.add_argument("--n-candidates", type=int, default=64)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI; checks correctness, skips "
                         "artifact write")
    args = ap.parse_args()

    if args.smoke:
        res = run(args.bench, n_queries=6, n_candidates=16, repeats=1,
                  seed=args.seed)
        print(json.dumps(res, indent=2))
        if not res["outputs_identical"]:
            raise SystemExit("batched runtime outputs diverge from the "
                             "per-query loop")
        print("smoke ok")
        return

    res = run(args.bench, args.n_queries, args.n_candidates, args.repeats,
              args.seed)
    print(json.dumps(res, indent=2))
    print(f"\nloop: {res['loop_rps']:.0f} req/s | "
          f"batch: {res['batch_rps']:.0f} req/s "
          f"({res['speedup_batch_vs_loop']:.1f}x) | "
          f"prune rate {res['prune_rate']:.2f} | "
          f"identical: {res['outputs_identical']}")
    for p in save_bench("runtime", res, headline=True):
        print(f"wrote {p}")


if __name__ == "__main__":
    main()
