"""End-to-end evaluation (paper Tables 4–5, §6.3).

Executes the *chosen* configurations in the ground-truth simulator with AQE
on, comparing:

  default  — Spark defaults.
  mo_ws    — MO-WS: query-level weighted-sum over the model objectives (the
             paper's strongest prior baseline), WUN pick.
  so_fw    — fixed-weight single-objective scalarization (Table 5 rival).
  hmooc3   — compile-time fine-grained HMOOC3 + submission aggregation.
  hmooc3+  — + runtime optimization during AQE.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.moo.baselines import solve_so_fw, solve_ws
from repro.core.moo.hmooc import HMOOCConfig
from repro.core.moo.wun import wun_select
from repro.core.tuning.compile_time import compile_time_optimize
from repro.core.tuning.objectives import StageObjectives
from repro.core.tuning.runtime import make_runtime_optimizers
from repro.queryengine.aqe import run_with_aqe
from repro.queryengine.simulator import default_theta
from repro.queryengine.workloads import make_benchmark

from .common import eval_queries, get_model


def _coarse_pick(obj: StageObjectives, weights, method: str, seed: int
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, float]:
    """Query-level baseline pick → (θc, θp, θs) raw + solve time."""
    ev, D = obj.query_eval_coarse()
    if method == "mo_ws":
        F, U, dt, _ = solve_ws(ev, D, n_samples=10000, n_weights=11,
                               seed=seed)
        i, _ = wun_select(F, np.asarray(weights))
        u = U[i]
    else:  # so_fw
        F, U, dt, _ = solve_so_fw(ev, D, np.asarray(weights),
                                  n_samples=10000, seed=seed)
        u = U[0]
    tc, tp, ts = obj.split_raw(u[None, :obj.d_c],
                               u[None, obj.d_c:])
    return tc[0], tp[0], ts[0], dt


def run_end_to_end(bench: str = "tpch", weights=(0.9, 0.1),
                   methods=("default", "mo_ws", "hmooc3", "hmooc3+"),
                   n_queries: Optional[int] = None, use_model: bool = True,
                   seed: int = 0) -> List[dict]:
    model = get_model(bench, "subq")[0] if use_model else None
    queries = eval_queries(bench)
    if n_queries:
        queries = queries[:n_queries]

    lat: Dict[str, list] = {m: [] for m in methods}
    cost: Dict[str, list] = {m: [] for m in methods}
    stime: Dict[str, list] = {m: [] for m in methods}

    for q in queries:
        tc0, tp0, ts0 = default_theta(1)
        for m in methods:
            t0 = time.perf_counter()
            if m == "default":
                r = run_with_aqe(q, tc0[0], tp0[0], ts0[0])
                st = 0.0
            elif m in ("mo_ws", "so_fw"):
                obj = StageObjectives(q, model=model)
                tc, tp, ts, st = _coarse_pick(obj, weights, m, seed)
                r = run_with_aqe(q, tc, tp, ts)
            else:
                ct = compile_time_optimize(
                    q, model=model, weights=weights,
                    cfg=HMOOCConfig(dag_method="hmooc3", seed=seed))
                st = ct.solve_time
                if m == "hmooc3":
                    r = run_with_aqe(q, ct.theta_c, ct.theta_p0, ct.theta_s0)
                else:
                    t1 = time.perf_counter()
                    lqp_o, qs_o = make_runtime_optimizers(
                        q, ct.theta_c, seed_theta_p=ct.theta_p_sub,
                        seed_theta_s=ct.theta_s_sub,
                        model_subq=model, model_qs=model, weights=weights,
                        seed=seed)
                    r = run_with_aqe(q, ct.theta_c, ct.theta_p0,
                                     ct.theta_s0, lqp_optimizer=lqp_o,
                                     qs_optimizer=qs_o)
                    st += (time.perf_counter() - t1) * 0.5  # runtime share
            lat[m].append(float(r.sim.actual_latency[0]))
            cost[m].append(float(r.sim.cost[0]))
            stime[m].append(st)

    rows = []
    base_l = np.array(lat["default"])
    base_c = np.array(cost["default"])
    for m in methods:
        L = np.array(lat[m])
        C = np.array(cost[m])
        S = np.array(stime[m])
        rows.append({
            "bench": bench, "method": m,
            "weights": f"{weights[0]}/{weights[1]}",
            "total_lat_reduction": float(1 - L.sum() / base_l.sum()),
            "avg_lat_reduction": float(np.mean(1 - L / base_l)),
            "avg_cost_reduction": float(np.mean(1 - C / base_c)),
            "coverage_1s": float(np.mean(S <= 1.0)),
            "coverage_2s": float(np.mean(S <= 2.0)),
            "avg_solve_s": float(S.mean()),
            "max_solve_s": float(S.max()),
        })
    return rows


def run_adaptability(bench: str = "tpch", use_model: bool = True,
                     n_queries: Optional[int] = 22, seed: int = 0
                     ) -> List[dict]:
    """Paper Table 5: preference sweep, SO-FW vs HMOOC3+."""
    rows = []
    for w in [(0.0, 1.0), (0.1, 0.9), (0.5, 0.5), (0.9, 0.1), (1.0, 0.0)]:
        r = run_end_to_end(bench, weights=w,
                           methods=("default", "so_fw", "hmooc3+"),
                           n_queries=n_queries, use_model=use_model,
                           seed=seed)
        for row in r:
            if row["method"] != "default":
                rows.append(row)
    return rows


def run_pruning(bench: str = "tpch") -> List[dict]:
    """§5.2: runtime-request pruning rates."""
    tc, tp, ts = default_theta(1)
    sent = tot = 0
    for q in make_benchmark(bench):
        r = run_with_aqe(q, tc[0], tp[0], ts[0], prune=True)
        sent += r.requests_sent
        tot += r.requests_total
    return [{"bench": bench, "requests_sent": sent, "requests_total": tot,
             "prune_rate": 1 - sent / tot}]
