"""Model-accuracy benchmark (paper Table 3)."""
from __future__ import annotations

from typing import List

from .common import get_model


def run_model_accuracy(benches=("tpch", "tpcds")) -> List[dict]:
    rows = []
    for bench in benches:
        for kind in ("subq", "qs", "lqp"):
            model, ds, met = get_model(bench, kind)
            rows.append({
                "bench": bench, "target": kind,
                "lat_wmape": round(float(met.wmape[0]), 3),
                "lat_p50": round(float(met.p50[0]), 3),
                "lat_p90": round(float(met.p90[0]), 3),
                "lat_corr": round(float(met.corr[0]), 3),
                "io_wmape": round(float(met.wmape[1]), 3),
                "io_p50": round(float(met.p50[1]), 3),
                "io_corr": round(float(met.corr[1]), 3),
                "xput_k_per_s": round(met.xput / 1e3, 0),
            })
    return rows
