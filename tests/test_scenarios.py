"""Nonstationary scenario engine + elastic capacity: replay-equivalence.

The tentpole invariant: every scenario is a pure function of its seeds, so
for every (arrival shape × event timeline) scenario in the matrix the
streamed server's *surviving* per-tenant outputs are bit-identical to an
offline one-at-a-time replay under each request's stamped weights — the
golden-determinism contract of PRs 3–6 extended to time-varying arrivals,
mid-stream preference shifts, tenant churn, capacity changes, elastic
batch caps, preemptive degradation, and token-bucket door rejections all
at once.  (Which requests survive at full quality is timing-dependent
under overload; *what* a survivor is served never is.)
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.core.moo.hmooc import HMOOCConfig
from repro.queryengine.scenarios import (ARRIVAL_SHAPES, TIMELINES,
                                         CapacityEvent, ScenarioEvent,
                                         ScenarioSpec, scenario_matrix)
from repro.queryengine.workloads import (ArrivalModel, StreamRequest,
                                         TenantSpec, make_query,
                                         serving_stream)
from repro.serve import (CandidatePoolCache, ElasticController,
                         ElasticPolicy, OptimizerServer, RuntimeSession,
                         ServerConfig, ServiceTimeModel, TuningService)

CFG = HMOOCConfig(n_c_init=16, n_clusters=4, n_p_pool=48, n_c_enrich=12,
                  max_bank=12, seed=3)
WEIGHTS = (0.9, 0.1)

MATRIX = scenario_matrix(n_per_tenant=4, rate_qps=40.0)


def _same(got, ref):
    np.testing.assert_array_equal(got.theta_p_eff, ref.theta_p_eff)
    np.testing.assert_array_equal(got.theta_s_eff, ref.theta_s_eff)
    np.testing.assert_array_equal(got.final_join, ref.final_join)
    np.testing.assert_array_equal(got.sim.ana_latency, ref.sim.ana_latency)
    np.testing.assert_array_equal(got.sim.actual_latency,
                                  ref.sim.actual_latency)
    np.testing.assert_array_equal(got.sim.io_gb, ref.sim.io_gb)
    np.testing.assert_array_equal(got.sim.cost, ref.sim.cost)


def _offline_replay(served):
    """One-at-a-time offline reference for every full-quality survivor,
    solved under the request's stamped weights (shared exact caches — the
    golden contract says sharing cannot change outputs)."""
    svc = TuningService(cfg=CFG)
    pools = CandidatePoolCache()
    out = {}
    for s in served:
        if s.status != "served":
            continue
        w = tuple(s.request.weights) if s.request.weights is not None \
            else WEIGHTS
        ct = svc.tune_batch([s.request.query], w)[0]
        sess = RuntimeSession(weights=w, pool_cache=pools)
        out[s.rid] = sess.run_batch([s.request.query], [ct])[0]
    return out


# ---------------------------------------------------------------------------
# Tentpole: golden replay-equivalence across the full scenario matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", MATRIX, ids=[m.name for m in MATRIX])
def test_replay_equivalence_matrix(spec):
    """Streamed serve (elastic capacity + capacity events + rate limits +
    SLO triage all armed) vs offline one-at-a-time replay: surviving
    outputs bit-identical per request, including across preference-shift
    and churn boundaries."""
    sc = spec.build(seed=2)
    srv = OptimizerServer(
        config=ServerConfig(max_batch=4,
                            elastic=ElasticPolicy(max_batch=16)),
        weights=WEIGHTS, cfg=CFG, tenants=sc.tenants)
    served = srv.serve(sc.requests, capacity_events=sc.capacity_events)
    assert len(served) == len(sc.requests)
    assert all(s.status in ("served", "degraded", "shed", "rate_limited")
               for s in served)
    survivors = [s for s in served if s.status == "served"]
    assert survivors, "scenario served nothing at full quality"
    ref = _offline_replay(served)
    for s in survivors:
        _same(s.result, ref[s.rid])
    # Rejected requests never produced a plan; everything else did.
    for s in served:
        if s.status in ("shed", "rate_limited"):
            assert s.result is None and s.ct is None
        else:
            assert s.result is not None
            assert math.isfinite(s.finished_s)


def test_pref_shift_replays_identically_on_both_sides():
    """The stale-θ regression at matrix scale: a scenario whose tenants
    flip latency↔cost preferences mid-stream replays bit-identically on
    *both* sides of the shift boundary."""
    spec = [m for m in MATRIX if m.name == "diurnal-pref_shift"][0]
    sc = spec.build(seed=5)
    shift_at = min(e.at_s for e in spec.events)
    # A deterministic charged clock guarantees survivors on both sides of
    # the shift regardless of host timing (measured wall charges can shed
    # a whole side of the boundary on a slow run).
    clock = ServiceTimeModel(flush_points=((1, 0.005), (4, 0.01)),
                             round_s=0.0005)
    srv = OptimizerServer(config=ServerConfig(max_batch=4, clock=clock),
                          weights=WEIGHTS, cfg=CFG, tenants=sc.tenants)
    served = srv.serve(sc.requests)
    pre = [s for s in served if s.status == "served"
           and s.arrival_s < shift_at]
    post = [s for s in served if s.status == "served"
            and s.arrival_s >= shift_at]
    assert pre and post, "need survivors on both sides of the shift"
    ref = _offline_replay(served)
    for s in pre + post:
        _same(s.result, ref[s.rid])


# ---------------------------------------------------------------------------
# Scenario builds: seed-purity, event semantics
# ---------------------------------------------------------------------------

def _fingerprint(sc):
    return [(r.rid, r.tenant, r.arrival_s, r.query.qid, r.weights)
            for r in sc.requests]


@pytest.mark.parametrize("name", [m.name for m in MATRIX])
def test_scenario_build_is_seed_pure(name):
    spec = [m for m in MATRIX if m.name == name][0]
    a, b = spec.build(seed=3), spec.build(seed=3)
    assert _fingerprint(a) == _fingerprint(b)
    assert a.capacity_events == b.capacity_events
    assert [t.name for t in a.tenants] == [t.name for t in b.tenants]
    other = spec.build(seed=4)
    assert _fingerprint(a) != _fingerprint(other)
    times = [r.arrival_s for r in a.requests]
    assert times == sorted(times)
    assert [r.rid for r in a.requests] == list(range(len(a.requests)))


def test_weight_shift_stamped_per_request():
    spec = [m for m in MATRIX if m.name == "ramp-pref_shift"][0]
    sc = spec.build(seed=2)
    by_ev = {e.tenant: e for e in spec.events}
    for tname, ev in by_ev.items():
        orig = [t for t in spec.tenants if t.name == tname][0].weights
        for r in sc.requests:
            if r.tenant != tname:
                continue
            want = ev.weights if r.arrival_s >= ev.at_s else orig
            assert r.weights == want, (r.rid, r.arrival_s)


def test_churn_join_leave_semantics():
    spec = [m for m in MATRIX if m.name == "flash_crowd-churn"][0]
    sc = spec.build(seed=2)
    join_at = [e.at_s for e in spec.events if e.kind == "join"][0]
    leave_at = [e.at_s for e in spec.events if e.kind == "leave"][0]
    joiner = [r for r in sc.requests if r.tenant == "joiner"]
    leaver = [r for r in sc.requests if r.tenant == "be"]
    assert joiner and all(r.arrival_s >= join_at for r in joiner)
    assert all(r.arrival_s < leave_at for r in leaver)
    assert "joiner" in [t.name for t in sc.tenants]
    assert sc.capacity_events == tuple(sorted(
        (CapacityEvent(e.at_s, e.max_batch) for e in spec.events
         if e.kind == "capacity"), key=lambda c: c.at_s))


def test_scenario_validation():
    t = TenantSpec(name="a")
    with pytest.raises(ValueError, match="unknown event kind"):
        ScenarioEvent(at_s=0.0, kind="bogus")
    with pytest.raises(ValueError, match="tenant= and weights="):
        ScenarioEvent(at_s=0.0, kind="weights", tenant="a")
    with pytest.raises(ValueError, match="needs spec"):
        ScenarioEvent(at_s=0.0, kind="join")
    with pytest.raises(ValueError, match="!= spec name"):
        ScenarioEvent(at_s=0.0, kind="join", tenant="b", spec=t)
    with pytest.raises(ValueError, match="needs tenant"):
        ScenarioEvent(at_s=0.0, kind="leave")
    with pytest.raises(ValueError, match="max_batch"):
        ScenarioEvent(at_s=0.0, kind="capacity", max_batch=0)
    with pytest.raises(ValueError, match="finite"):
        ScenarioEvent(at_s=math.inf, kind="leave", tenant="a")
    with pytest.raises(ValueError, match="at least one tenant"):
        ScenarioSpec(name="x")
    with pytest.raises(ValueError, match="duplicate tenant"):
        ScenarioSpec(name="x", tenants=(t,), events=(
            ScenarioEvent(at_s=0.0, kind="join", spec=TenantSpec(name="a")),))
    with pytest.raises(ValueError, match="unknown tenant"):
        ScenarioSpec(name="x", tenants=(t,), events=(
            ScenarioEvent(at_s=0.0, kind="leave", tenant="ghost"),))


# ---------------------------------------------------------------------------
# Nonstationary arrival models
# ---------------------------------------------------------------------------

def test_nonstationary_arrival_kinds_reproducible_and_sorted():
    for kind in ("diurnal", "spike", "ramp"):
        m = ArrivalModel(kind=kind, rate_qps=20.0)
        a, b = m.draw(64, seed=7), m.draw(64, seed=7)
        np.testing.assert_array_equal(a, b)
        assert (np.diff(a) >= 0).all()
        assert a.shape == (64,) and a[0] >= 0.0
        assert not np.array_equal(a, m.draw(64, seed=8))


def test_spike_concentrates_arrivals_in_the_window():
    m = ArrivalModel(kind="spike", rate_qps=5.0, spike_at_s=2.0,
                     spike_dur_s=2.0, spike_factor=8.0)
    t = m.draw(400, seed=1)
    hot = ((t >= 2.0) & (t < 4.0)).sum()
    # 2 s at 40 qps ≈ 80 arrivals vs 5 qps elsewhere.
    pre = (t < 2.0).sum()
    assert hot > 3 * pre
    assert m.rate_at(3.0) == pytest.approx(40.0)
    assert m.rate_at(1.0) == pytest.approx(5.0)
    assert m.rate_at(4.0) == pytest.approx(5.0)   # half-open window


def test_diurnal_rate_curve_and_bounds():
    m = ArrivalModel(kind="diurnal", rate_qps=10.0, period_s=40.0,
                     amplitude=0.5)
    assert m.rate_at(0.0) == pytest.approx(10.0)
    assert m.rate_at(10.0) == pytest.approx(15.0)   # sin peak at T/4
    assert m.rate_at(30.0) == pytest.approx(5.0)    # trough at 3T/4
    t = m.draw(200, seed=3)
    assert (np.diff(t) >= 0).all()
    # Instantaneous rate stays within the envelope used for thinning.
    for x in np.linspace(0.0, 80.0, 41):
        assert 0.0 < m.rate_at(float(x)) <= m._max_rate() + 1e-12


def test_ramp_rate_holds_after_ramp():
    m = ArrivalModel(kind="ramp", rate_qps=4.0, ramp_to_qps=16.0,
                     ramp_dur_s=2.0)
    assert m.rate_at(0.0) == pytest.approx(4.0)
    assert m.rate_at(1.0) == pytest.approx(10.0)
    assert m.rate_at(2.0) == pytest.approx(16.0)
    assert m.rate_at(50.0) == pytest.approx(16.0)   # holds, no overshoot


def test_nonstationary_validation():
    with pytest.raises(ValueError, match="amplitude"):
        ArrivalModel(kind="diurnal", amplitude=1.0).draw(3)
    with pytest.raises(ValueError, match="period_s"):
        ArrivalModel(kind="diurnal", period_s=0.0).draw(3)
    with pytest.raises(ValueError, match="spike_factor"):
        ArrivalModel(kind="spike", spike_factor=0.0).draw(3)
    with pytest.raises(ValueError, match="ramp_to_qps"):
        ArrivalModel(kind="ramp", ramp_to_qps=-1.0).rate_at(0.0)


# ---------------------------------------------------------------------------
# Stale-weight regression: a shift never serves a stale-weight θ
# ---------------------------------------------------------------------------

def test_weight_shift_never_serves_stale_theta():
    """The same query on both sides of a preference shift: the post-shift
    request must be a fresh solve under the new weights (the ResponseCache
    key carries the weights — a stale hit would be a cache-key bug), and
    each side bit-matches its own offline solve."""
    q = make_query("tpch", 8, variant=1)
    reqs = [StreamRequest(rid=0, query=q, arrival_s=0.0, tenant="t",
                          weights=(0.99, 0.01)),
            StreamRequest(rid=1, query=q, arrival_s=0.05, tenant="t",
                          weights=(0.01, 0.99))]
    srv = OptimizerServer(
        config=ServerConfig(max_batch=1), weights=WEIGHTS, cfg=CFG,
        tenants=[TenantSpec(name="t", weights=(0.99, 0.01))])
    served = srv.serve(reqs)
    # Two solves, zero cross-boundary hits: the shift key-misses the cache.
    assert srv.tuning._results.misses == 2
    assert srv.tuning._results.hits == 0
    pre, post = served
    assert pre.ct.choice != post.ct.choice or not np.array_equal(
        pre.ct.theta_c, post.ct.theta_c)
    for s, w in ((pre, (0.99, 0.01)), (post, (0.01, 0.99))):
        ref = TuningService(cfg=CFG).tune_batch([q], w)[0]
        assert s.ct.choice == ref.choice
        np.testing.assert_array_equal(s.ct.theta_c, ref.theta_c)
    # Replaying the same shifted stream hits the cache per-side — the
    # weights dimension separates the entries, it doesn't disable reuse.
    srv.serve(reqs)
    assert srv.tuning._results.hits == 2


# ---------------------------------------------------------------------------
# Elastic capacity control + capacity events
# ---------------------------------------------------------------------------

def test_capacity_events_bound_flush_sizes():
    stream = serving_stream("tpch", 12, seed=11,
                            arrivals=ArrivalModel(kind="poisson",
                                                  rate_qps=60.0))
    srv = OptimizerServer(config=ServerConfig(max_batch=6),
                          weights=WEIGHTS, cfg=CFG)
    served = srv.serve(stream, capacity_events=[(0.0, 2), (0.15, 6)])
    assert all(s.result is not None for s in served)
    st = srv.last_run
    assert len(st.flush_caps) == len(st.flush_windows) >= 2
    for (_, n), cap in zip(st.flush_windows, st.flush_caps):
        assert n <= cap
    assert min(st.flush_caps) == 2          # the dip actually applied
    # Outputs unchanged by the capacity dance (golden contract).
    queries = [r.query for r in stream]
    cts = TuningService(cfg=CFG).tune_batch(queries, WEIGHTS)
    ref = RuntimeSession(weights=WEIGHTS).run_batch(queries, cts)
    for s, r in zip(served, ref):
        _same(s.result, r)


def test_elastic_controller_raises_cap_under_pressure():
    """A burst at t=0 with a tiny base cap: the queue-delay forecast rises
    while solving, so the elastic cap must exceed the base cap at some
    flush — and survivors still bit-match offline."""
    stream = [dataclasses.replace(r, arrival_s=0.0)
              for r in serving_stream("tpch", 12, seed=9,
                                      arrivals=ArrivalModel(rate_qps=40.0))]
    srv = OptimizerServer(
        config=ServerConfig(
            max_batch=2, admit_mid_session=False,
            elastic=ElasticPolicy(max_batch=8, target_delay_s=0.01,
                                  ewma=1.0)),
        weights=WEIGHTS, cfg=CFG)
    served = srv.serve(stream)
    assert all(s.result is not None for s in served)
    assert max(srv.last_run.flush_caps) > 2
    queries = [r.query for r in stream]
    cts = TuningService(cfg=CFG).tune_batch(queries, WEIGHTS)
    ref = RuntimeSession(weights=WEIGHTS).run_batch(queries, cts)
    for s, r in zip(served, ref):
        _same(s.result, r)


def test_preemptive_degradation_engages_before_deadline():
    """With elastic control and a saturated forecast, a degrade-class head
    whose budget is *not yet* blown is still routed to the cheap path when
    the forecast headroom is gone (the PR-5 next-step: degrade before the
    budget blows, not at the post-mortem)."""
    from repro.serve import TenantScheduler
    sched = TenantScheduler(
        [TenantSpec(name="d", slo="degrade", solve_budget_s=1.0)],
        reserve_q_s=0.2)
    sched.enqueue("d", "x", 0.0)
    # At t=0.3 with E[n]=1: deadline = 0+1.0−0.2 = 0.8 → meetable now, so
    # plain compose serves it at full quality...
    assert sched.compose(0.3, cap=4) == [("d", "x", False)]
    # ...but with a 0.6 s lead (forecast pressure), the same head degrades.
    sched.enqueue("d", "y", 0.0)
    assert sched.compose(0.3, cap=4, degrade_lead_s=0.6) == \
        [("d", "y", True)]


def test_elastic_policy_validation():
    with pytest.raises(ValueError, match="min_batch"):
        ElasticPolicy(min_batch=4, max_batch=2)
    with pytest.raises(ValueError, match="target_delay_s"):
        ElasticPolicy(target_delay_s=0.0)
    with pytest.raises(ValueError, match="ewma"):
        ElasticPolicy(ewma=0.0)
    with pytest.raises(ValueError, match="degrade_frac"):
        ElasticPolicy(degrade_frac=1.5)
    ctl = ElasticController(ElasticPolicy(max_batch=8))
    assert ctl.batch_cap(4) == 4                     # no pressure: base cap


# ---------------------------------------------------------------------------
# Deterministic charged-time model (ServiceTimeModel)
# ---------------------------------------------------------------------------

def test_clock_model_interpolates_and_validates():
    m = ServiceTimeModel(flush_points=((8, 0.08), (2, 0.02), (4, 0.04)),
                         round_s=0.001)
    assert m.flush_points == ((2, 0.02), (4, 0.04), (8, 0.08))  # sorted
    assert m.flush_s(3) == pytest.approx(0.03)       # interior interpolation
    assert m.flush_s(16) == pytest.approx(0.16)      # extrapolate last seg
    assert m.flush_s(1) == pytest.approx(0.01)       # extrapolate first seg
    assert ServiceTimeModel(flush_points=((4, 0.1),)).flush_s(99) == 0.1
    # Extrapolation below the first knot clamps at zero, never negative.
    down = ServiceTimeModel(flush_points=((4, 0.01), (8, 0.5)))
    assert down.flush_s(1) == 0.0
    # Cheap members (cache hits / degraded paths) are priced at cheap_s,
    # not on the solve curve; the full-solve remainder interpolates as
    # usual, and an all-cheap flush costs no solve at all.
    c = ServiceTimeModel(flush_points=((2, 0.02), (4, 0.04)), cheap_s=0.001)
    assert c.flush_s(4, n_cheap=1) == pytest.approx(0.03 + 0.001)
    assert c.flush_s(4, n_cheap=4) == pytest.approx(0.004)
    assert c.flush_s(4, n_cheap=99) == pytest.approx(0.004)   # clamped to n
    assert c.flush_s(4, n_cheap=-3) == c.flush_s(4)           # clamped to 0
    with pytest.raises(ValueError, match="finite"):
        ServiceTimeModel(flush_points=((1, 0.1),), cheap_s=-0.1)
    with pytest.raises(ValueError, match="at least one knot"):
        ServiceTimeModel(flush_points=())
    with pytest.raises(ValueError, match="unique"):
        ServiceTimeModel(flush_points=((2, 0.1), (2, 0.2)))
    with pytest.raises(ValueError, match=">= 1"):
        ServiceTimeModel(flush_points=((0, 0.1),))
    with pytest.raises(ValueError, match="finite"):
        ServiceTimeModel(flush_points=((1, math.nan),))
    with pytest.raises(ValueError, match="finite"):
        ServiceTimeModel(flush_points=((1, 0.1),), round_s=-1.0)


def test_clock_model_makes_the_admission_timeline_deterministic():
    """With a ServiceTimeModel charged instead of measured wall time, two
    serves of the same scenario agree on *everything* — statuses, flush
    sizes and caps, charged windows, and every per-request lifecycle
    timestamp — not just on outputs.  (This is what lets the scenario
    benchmark compare elastic vs static capacity free of host jitter.)"""
    spec = [m for m in MATRIX if m.name == "flash_crowd-churn"][0]
    sc = spec.build(seed=9)
    clock = ServiceTimeModel(flush_points=((1, 0.01), (4, 0.03), (16, 0.1)),
                             round_s=0.002, cheap_s=0.0005)
    cfgv = ServerConfig(max_batch=4, solve_budget_s=0.5, clock=clock,
                        elastic=ElasticPolicy(min_batch=4, max_batch=16,
                                              target_delay_s=0.1))

    def once():
        srv = OptimizerServer(config=cfgv, weights=WEIGHTS, cfg=CFG,
                              tenants=sc.tenants)
        served = srv.serve(sc.requests, capacity_events=sc.capacity_events)
        st = srv.last_run
        return ([(s.rid, s.status, s.admitted_s, s.compiled_s, s.finished_s)
                 for s in served],
                list(st.flush_windows), list(st.flush_caps))

    a, b = once(), once()
    # NaN-tolerant exact comparison (rejected requests carry NaN stamps).
    assert repr(a) == repr(b)
    # Every charged flush window is exactly the model's for *some* split
    # of the batch into full solves and cheap members, none measured.
    for w, size in a[1]:
        assert any(w == clock.flush_s(size, n_cheap=k)
                   for k in range(size + 1))


# ---------------------------------------------------------------------------
# Token-bucket rate limiting, end to end
# ---------------------------------------------------------------------------

def test_rate_limited_requests_door_rejected_deterministically():
    """Fixed arrivals at 4× the tenant's sustained rate with burst 1: the
    bucket admits exactly every 4th arrival; rejections are first-class
    outcomes (never enqueued, never solved) and the pattern is a pure
    function of the stream — identical across servers."""
    spec = TenantSpec(name="rl", weights=WEIGHTS, rate_limit_qps=5.0,
                      rate_limit_burst=1.0,
                      arrivals=ArrivalModel(kind="fixed", rate_qps=20.0))
    stream = [dataclasses.replace(r, tenant="rl")
              for r in serving_stream("tpch", 8, seed=21,
                                      arrivals=spec.arrivals)]

    def run():
        srv = OptimizerServer(config=ServerConfig(max_batch=4),
                              weights=WEIGHTS, cfg=CFG, tenants=[spec])
        return srv, srv.serve(stream)

    srv, served = run()
    statuses = [s.status for s in served]
    assert statuses == ["served", "rate_limited", "rate_limited",
                        "rate_limited"] * 2
    for s in served:
        if s.status == "rate_limited":
            assert s.ct is None and s.result is None
            assert s.finished_s == s.arrival_s
    assert srv.last_run.n_rate_limited == 6
    assert srv.scheduler.state("rl").n_rate_limited == 6
    assert srv.scheduler.state("rl").n_enqueued == 2
    rep = srv.latency_report(served)
    assert rep["n_rate_limited"] == 6
    assert rep["rate_limited_rate"] == pytest.approx(0.75)
    assert rep["n_finished"] == 2
    assert rep["goodput"] <= 0.25
    # Deterministic across servers (bucket clocked by arrivals, not wall).
    _, served2 = run()
    assert [s.status for s in served2] == statuses


def test_rate_limit_spec_validation():
    with pytest.raises(ValueError, match="rate_limit_qps"):
        TenantSpec(name="x", rate_limit_qps=0.0)
    with pytest.raises(ValueError, match="rate_limit_burst"):
        TenantSpec(name="x", rate_limit_qps=1.0, rate_limit_burst=0.5)


# ---------------------------------------------------------------------------
# Windowed latency report (satellite: phase-resolved metrics)
# ---------------------------------------------------------------------------

def test_windowed_report_partitions_and_separates_phases():
    spec = [m for m in MATRIX if m.name == "flash_crowd-steady"][0]
    sc = spec.build(seed=6)
    srv = OptimizerServer(config=ServerConfig(max_batch=4),
                          weights=WEIGHTS, cfg=CFG, tenants=sc.tenants)
    served = srv.serve(sc.requests)
    span = (max(s.arrival_s for s in served)
            - min(s.arrival_s for s in served))
    rep = srv.latency_report(served, window_s=span / 4 + 1e-9)
    ws = rep["windows"]
    assert len(ws) >= 2
    assert sum(w["n_arrived"] for w in ws) == len(served)
    assert sum(w["n_finished"] for w in ws) == rep["n_finished"]
    assert sum(w["n_shed"] for w in ws) == rep["n_shed"]
    for a, b in zip(ws, ws[1:]):
        assert b["t0_s"] == pytest.approx(a["t1_s"])
    for w in ws:
        if w["n_finished"]:
            assert math.isfinite(w["plan_latency_s"]["p99"])
            assert 0.0 <= w["goodput"] <= 1.0
    with pytest.raises(ValueError, match="window_s"):
        srv.latency_report(served, window_s=0.0)


def test_report_counts_follow_the_sample_not_the_run():
    """Regression (this PR): every count/rate in the report derives from
    the ``served`` argument, so a report over a slice (one tenant, one
    phase) is internally consistent — the old ``n_queries`` came from the
    whole last run and silently mixed samples."""
    spec = [m for m in MATRIX if m.name == "diurnal-steady"][0]
    sc = spec.build(seed=7)
    srv = OptimizerServer(config=ServerConfig(max_batch=4),
                          weights=WEIGHTS, cfg=CFG, tenants=sc.tenants)
    served = srv.serve(sc.requests)
    sub = [s for s in served if s.tenant == "deg"]
    rep = srv.latency_report(sub)
    assert rep["n_queries"] == len(sub) != len(served)
    assert rep["n_shed"] == sum(1 for s in sub if s.status == "shed")
    assert rep["n_finished"] <= len(sub)
