"""Property tests for Pareto primitives (hypothesis)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.moo.pareto import (hypervolume_2d, kung_2d_np, pareto_mask,
                                   pareto_mask_np)


def brute_mask(F):
    n = F.shape[0]
    out = np.ones(n, bool)
    for i in range(n):
        for j in range(n):
            if (F[j] <= F[i]).all() and (F[j] < F[i]).any():
                out[i] = False
                break
    return out


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 60), st.integers(2, 4), st.integers(0, 6),
       st.randoms(use_true_random=False))
def test_mask_matches_bruteforce(n, k, levels, rnd):
    rng = np.random.default_rng(rnd.randint(0, 2**31))
    # Integer grids force many ties/duplicates (the tricky cases).
    F = rng.integers(0, levels + 2, size=(n, k)).astype(float)
    assert (pareto_mask_np(F) == brute_mask(F)).all()


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 80), st.randoms(use_true_random=False))
def test_2d_sweep_matches_bruteforce(n, rnd):
    rng = np.random.default_rng(rnd.randint(0, 2**31))
    F = rng.integers(0, 7, size=(n, 2)).astype(float)
    got = pareto_mask_np(F)          # uses the sweep for n > 64
    assert (got == brute_mask(F)).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 40), st.randoms(use_true_random=False))
def test_jnp_mask_matches_np(n, rnd):
    rng = np.random.default_rng(rnd.randint(0, 2**31))
    F = rng.random((n, 2)).astype(np.float32)
    got = np.asarray(pareto_mask(F))
    assert (got == pareto_mask_np(F)).all()


def test_mask_scale_invariance():
    rng = np.random.default_rng(0)
    F = rng.random((50, 2))
    m1 = pareto_mask_np(F)
    m2 = pareto_mask_np(F * np.array([1000.0, 1e-3]) + 5)
    assert (m1 == m2).all()


def test_hypervolume_monotone_in_points():
    rng = np.random.default_rng(1)
    F = rng.random((30, 2))
    ref = np.array([2.0, 2.0])
    hv_all = hypervolume_2d(F, ref)
    hv_some = hypervolume_2d(F[:10], ref)
    assert hv_all >= hv_some - 1e-12
    assert hypervolume_2d(F[:0], ref) == 0.0
    # A single point dominating everything gives the max box.
    hv1 = hypervolume_2d(np.array([[0.0, 0.0]]), ref)
    assert hv1 == pytest.approx(4.0)


def test_invalid_rows_never_optimal_nor_dominating():
    F = np.array([[np.inf, 0.0], [1.0, 1.0], [2.0, 2.0]])
    m = pareto_mask_np(F)
    assert not m[0] and m[1] and not m[2]
    valid = np.array([True, False, True])
    m = pareto_mask_np(np.array([[0.0, 0.0], [1.0, 1.0], [2.0, 2.0]]), valid)
    assert m.tolist() == [True, False, False]
