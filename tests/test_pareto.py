"""Property tests for Pareto primitives (hypothesis)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.moo.pareto import (hypervolume_2d, kung_2d_np, pareto_mask,
                                   pareto_mask_np)


def brute_mask(F):
    n = F.shape[0]
    out = np.ones(n, bool)
    for i in range(n):
        for j in range(n):
            if (F[j] <= F[i]).all() and (F[j] < F[i]).any():
                out[i] = False
                break
    return out


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 60), st.integers(2, 4), st.integers(0, 6),
       st.randoms(use_true_random=False))
def test_mask_matches_bruteforce(n, k, levels, rnd):
    rng = np.random.default_rng(rnd.randint(0, 2**31))
    # Integer grids force many ties/duplicates (the tricky cases).
    F = rng.integers(0, levels + 2, size=(n, k)).astype(float)
    assert (pareto_mask_np(F) == brute_mask(F)).all()


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 80), st.randoms(use_true_random=False))
def test_2d_sweep_matches_bruteforce(n, rnd):
    rng = np.random.default_rng(rnd.randint(0, 2**31))
    F = rng.integers(0, 7, size=(n, 2)).astype(float)
    got = pareto_mask_np(F)          # uses the sweep for n > 64
    assert (got == brute_mask(F)).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 40), st.randoms(use_true_random=False))
def test_jnp_mask_matches_np(n, rnd):
    rng = np.random.default_rng(rnd.randint(0, 2**31))
    F = rng.random((n, 2)).astype(np.float32)
    got = np.asarray(pareto_mask(F))
    assert (got == pareto_mask_np(F)).all()


def test_mask_scale_invariance():
    rng = np.random.default_rng(0)
    F = rng.random((50, 2))
    m1 = pareto_mask_np(F)
    m2 = pareto_mask_np(F * np.array([1000.0, 1e-3]) + 5)
    assert (m1 == m2).all()


def test_hypervolume_monotone_in_points():
    rng = np.random.default_rng(1)
    F = rng.random((30, 2))
    ref = np.array([2.0, 2.0])
    hv_all = hypervolume_2d(F, ref)
    hv_some = hypervolume_2d(F[:10], ref)
    assert hv_all >= hv_some - 1e-12
    assert hypervolume_2d(F[:0], ref) == 0.0
    # A single point dominating everything gives the max box.
    hv1 = hypervolume_2d(np.array([[0.0, 0.0]]), ref)
    assert hv1 == pytest.approx(4.0)


def test_invalid_rows_never_optimal_nor_dominating():
    F = np.array([[np.inf, 0.0], [1.0, 1.0], [2.0, 2.0]])
    m = pareto_mask_np(F)
    assert not m[0] and m[1] and not m[2]
    valid = np.array([True, False, True])
    m = pareto_mask_np(np.array([[0.0, 0.0], [1.0, 1.0], [2.0, 2.0]]), valid)
    assert m.tolist() == [True, False, False]


def test_routing_thresholds_track_env_after_import(monkeypatch):
    """REPRO_PARETO_KERNEL_MIN_N flipped *after* import must take effect:
    the pre-existing lru_cache froze the threshold (and the backend answer)
    at first read, so a process re-tuned live kept stale routing."""
    import jax

    from repro.core.moo import pareto

    monkeypatch.delenv("REPRO_PARETO_KERNEL_MIN_N", raising=False)
    base = pareto._default_kernel_min_n()
    monkeypatch.setenv("REPRO_PARETO_KERNEL_MIN_N", "7")
    assert pareto._default_kernel_min_n() == 7
    monkeypatch.setenv("REPRO_PARETO_KERNEL_MIN_N", "123456")
    assert pareto._default_kernel_min_n() == 123456
    monkeypatch.delenv("REPRO_PARETO_KERNEL_MIN_N")
    assert pareto._default_kernel_min_n() == base
    # The backend answer is live too, not captured at import/first call.
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert pareto.backend() == "tpu"
    assert pareto._default_kernel_min_n() == 512
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert pareto.backend() == "cpu"


def test_env_flip_changes_routing_and_results_agree(monkeypatch):
    """Flipping the env threshold reroutes pareto_mask_fast to the kernel
    path, and on tie-free inputs the mask is unchanged."""
    from repro.core.moo import pareto

    rng = np.random.default_rng(5)
    F = np.round(rng.random((24, 2)), 3)        # f32-exact, tie-free cast
    monkeypatch.setattr(pareto, "_KERNEL_MIN_N", None)
    monkeypatch.setenv("REPRO_PARETO_KERNEL_MIN_N", str(1 << 30))
    np_mask = pareto.pareto_mask_fast(F)
    monkeypatch.setenv("REPRO_PARETO_KERNEL_MIN_N", "4")
    kernel_mask = pareto.pareto_mask_fast(F)
    np.testing.assert_array_equal(kernel_mask, np_mask)
    np.testing.assert_array_equal(np_mask, pareto_mask_np(F))
