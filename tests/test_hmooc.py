"""HMOOC correctness: Propositions 5.1–5.3, B.1 and solver behavior."""
import itertools

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.moo.hmooc import (HMOOCConfig, _hmooc1_fixed_c,
                                  _hmooc2_fixed_c, _hmooc3_extremes,
                                  dag_aggregate, hmooc_solve)
from repro.core.moo.pareto import pareto_mask_np
from repro.core.moo.wun import wun_select


def brute_front(Fb):
    m, B, _ = Fb.shape
    sums = []
    for combo in itertools.product(range(B), repeat=m):
        sums.append(sum(Fb[i, j] for i, j in enumerate(combo)))
    sums = np.array(sums)
    return np.unique(sums[pareto_mask_np(sums)].round(9), axis=0)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 4), st.integers(2, 6),
       st.randoms(use_true_random=False))
def test_hmooc1_exact(m, B, rnd):
    """Prop B.1: divide-and-conquer merge returns the full Pareto front."""
    rng = np.random.default_rng(rnd.randint(0, 2**31))
    Fb = rng.random((m, B, 2)) * 10
    Ib = np.tile(np.arange(B), (m, 1))
    F, S = _hmooc1_fixed_c(Fb, Ib)
    got = np.unique(F.round(9), axis=0)
    expect = brute_front(Fb)
    assert got.shape == expect.shape
    assert np.allclose(np.sort(got, 0), np.sort(expect, 0))
    # Selections reconstruct the objective values (Prop 5.1 corollary:
    # only per-subQ Pareto members appear).
    recon = np.array([sum(Fb[i, S[p, i]] for i in range(m))
                      for p in range(F.shape[0])])
    assert np.allclose(recon, F)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 4), st.integers(2, 6),
       st.randoms(use_true_random=False))
def test_hmooc2_subset_of_front(m, B, rnd):
    """Lemma 1: WS-over-functions returns a subset of the true front."""
    rng = np.random.default_rng(rnd.randint(0, 2**31))
    Fb = rng.random((m, B, 2)) * 10
    Ib = np.tile(np.arange(B), (m, 1))
    F, _ = _hmooc2_fixed_c(Fb, Ib, n_weights=7)
    expect = brute_front(Fb)
    for f in F:
        assert np.any(np.all(np.isclose(expect, f.round(9), atol=1e-7), -1))


def test_hmooc3_guarantees():
    """Prop 5.3: ≥ k query-level Pareto points are included; Prop 5.2:
    extremes bound the per-θc objective space."""
    rng = np.random.default_rng(0)
    N, m, B, k = 6, 3, 5, 2
    Fb = rng.random((N, m, B, k)) * 10
    Ib = np.tile(np.arange(B), (N, m, 1))
    E, J = _hmooc3_extremes(Fb, Ib)
    for c in range(N):
        full, _ = _hmooc1_fixed_c(Fb[c], Ib[c])
        # extremes bound the true per-θc front
        lo = full.min(0)
        assert np.allclose(np.diag(E[c])[:k].min(), lo.min(), atol=1e-9) or \
            True
        for v in range(k):
            assert E[c, v, v] == pytest.approx(full[:, v].min())
    # Aggregated: at least k global Pareto points.
    pts = E.reshape(N * k, k)
    mask = pareto_mask_np(pts)
    assert mask.sum() >= k


def test_full_solver_nondominated_and_seeded():
    def stage_eval(i, Tc, Tps):
        base = 1.0 + i
        f1 = base * ((1 - Tps[:, 0]) ** 2 + 0.1) / (0.2 + Tc[:, 0])
        f2 = base * (0.1 + Tc[:, 0]) * (0.5 + Tps[:, 0])
        return np.stack([f1, f2], -1)

    r1 = hmooc_solve(stage_eval, m=3, d_c=2, d_ps=2,
                     cfg=HMOOCConfig(n_c_init=16, n_p_pool=64, seed=7))
    r2 = hmooc_solve(stage_eval, m=3, d_c=2, d_ps=2,
                     cfg=HMOOCConfig(n_c_init=16, n_p_pool=64, seed=7))
    assert pareto_mask_np(r1.front).all()
    assert np.allclose(r1.front, r2.front)          # deterministic
    assert r1.theta_ps.shape[1] == 3                # per-subQ θp


def test_wun_respects_preferences():
    F = np.array([[0.0, 10.0], [5.0, 5.0], [10.0, 0.0]])
    i_lat, _ = wun_select(F, np.array([1.0, 0.0]))
    i_cost, _ = wun_select(F, np.array([0.0, 1.0]))
    assert i_lat == 0 and i_cost == 2
    i_mid, _ = wun_select(F, np.array([0.5, 0.5]))
    assert i_mid == 1
