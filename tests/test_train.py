"""Training loop, checkpointing, elastic machinery, data pipeline."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.archs.registry import build_model, get_smoke_config
from repro.data.pipeline import data_iterator, make_batch
from repro.launch.mesh import make_host_mesh
from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                    save_checkpoint)
from repro.train.elastic import assign_data_shards, plan_elastic_mesh
from repro.train.optimizer import OptConfig, wsd_schedule
from repro.train.train_loop import make_train_step, train_loop


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("glm4-9b")
    api = build_model(cfg)
    mesh = make_host_mesh()
    return cfg, api, mesh


def test_wsd_schedule_shape():
    cfg = OptConfig(lr=1e-3, total_steps=100, warmup_steps=10)
    lrs = [float(wsd_schedule(cfg, jnp.asarray(s)))
           for s in [0, 5, 10, 50, 89, 99]]
    assert lrs[0] < lrs[1] < lrs[2]           # warmup
    assert lrs[2] == pytest.approx(lrs[3])     # stable
    assert lrs[4] > lrs[5]                     # decay
    assert lrs[5] >= 0.09e-3                   # floor ≈ 0.1·lr


def test_train_loss_decreases(setup):
    cfg, api, mesh = setup
    it = data_iterator(cfg, global_batch=4, seq_len=32, seed=0)
    opt = OptConfig(lr=3e-3, total_steps=30, warmup_steps=3)
    out = train_loop(api, mesh, it, steps=30, opt_cfg=opt, log_every=1)
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0] * 0.9
    assert all(np.isfinite(l) for l in losses)


@pytest.mark.slow
def test_grad_accum_equivalence(setup):
    """accum=2 must give (numerically) the same update as accum=1."""
    cfg, api, mesh = setup
    b = make_batch(cfg, global_batch=4, seq_len=16, step=0)
    batch = {k: jnp.asarray(v) for k, v in b.items()}
    shape = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
    opt = OptConfig(lr=1e-3)
    f1 = make_train_step(api, mesh, shape, opt, accum=1, donate=False)
    f2 = make_train_step(api, mesh, shape, opt, accum=2, donate=False)
    p1, o1 = f1.init(jax.random.PRNGKey(0))
    p2, o2 = f2.init(jax.random.PRNGKey(0))
    p1n, _, m1 = f1.step(p1, o1, batch)
    p2n, _, m2 = f2.step(p2, o2, batch)
    # Microbatch statistics differ slightly (per-μb mean), but the update
    # direction/scale must agree closely.
    d1 = jax.tree_util.tree_leaves(p1n)[0] - jax.tree_util.tree_leaves(p1)[0]
    d2 = jax.tree_util.tree_leaves(p2n)[0] - jax.tree_util.tree_leaves(p2)[0]
    cos = float(jnp.sum(d1 * d2) /
                (jnp.linalg.norm(d1) * jnp.linalg.norm(d2) + 1e-12))
    assert cos > 0.98


def test_checkpoint_roundtrip_and_elastic(tmp_path, setup):
    cfg, api, mesh = setup
    params = api.init(jax.random.PRNGKey(3))
    save_checkpoint(str(tmp_path), 7, params)
    assert latest_step(str(tmp_path)) == 7
    like = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), {"params": params})
    restored, step = restore_checkpoint(str(tmp_path), like)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a, dtype=np.float32),
                                      np.asarray(b, dtype=np.float32))
    # Elastic mesh planning.
    (dp, tp), axes = plan_elastic_mesh(192, prefer_model=16)
    assert dp * tp <= 192 and tp == 16
    (dp, tp), _ = plan_elastic_mesh(8, prefer_model=16)
    assert dp * tp <= 8 and tp >= 1


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 64), st.integers(1, 12),
       st.lists(st.integers(0, 11), max_size=6, unique=True))
def test_straggler_reassignment(n_shards, n_hosts, stragglers):
    hosts = list(range(n_hosts))
    stragglers = [s for s in stragglers if s in hosts]
    if len(stragglers) == n_hosts:
        stragglers = stragglers[:-1]
    plan = assign_data_shards(n_shards, hosts, stragglers)
    # Every shard assigned exactly once, none to a straggler.
    got = sorted(s for shards in plan.values() for s in shards)
    assert got == list(range(n_shards))
    assert not (set(plan) & set(stragglers))
    # Deterministic.
    assert plan == assign_data_shards(n_shards, hosts, stragglers)


def test_data_pipeline_determinism_and_sharding():
    cfg = get_smoke_config("glm4-9b")
    a = make_batch(cfg, global_batch=8, seq_len=16, step=3, host=0,
                   n_hosts=2)
    b = make_batch(cfg, global_batch=8, seq_len=16, step=3, host=0,
                   n_hosts=2)
    c = make_batch(cfg, global_batch=8, seq_len=16, step=3, host=1,
                   n_hosts=2)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert (a["tokens"] != c["tokens"]).any()
    assert a["tokens"].shape == (4, 16)
    assert (a["labels"][:, -1] == -1).all()
