"""Property-test shim: real ``hypothesis`` when installed, else a
fixed-seed sweep.

Usage (drop-in for the subset of the hypothesis API these tests use)::

    from _hypothesis_compat import given, settings, st

When the ``hypothesis`` package is available the real decorators are
re-exported unchanged.  Otherwise ``@given`` turns the test into a
deterministic sweep: ``max_examples`` (from the paired ``@settings``)
example tuples are drawn from a per-test fixed-seed ``random.Random`` and
the body runs once per tuple, so the suite still collects and exercises the
same properties on a clean machine.
"""

import random
import zlib

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    _DEFAULT_EXAMPLES = 15

    class _Strategy:
        """A draw rule: ``sample(rnd: random.Random) -> value``."""

        def __init__(self, sample):
            self.sample = sample

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: r.random() < 0.5)

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda r: seq[r.randrange(len(seq))])

        @staticmethod
        def randoms(use_true_random=False):
            del use_true_random  # fallback is always seeded
            return _Strategy(lambda r: random.Random(r.randint(0, 2 ** 63)))

        @staticmethod
        def lists(elements, *, min_size=0, max_size=10, unique=False):
            def sample(r):
                size = r.randint(min_size, max_size)
                out = []
                for _ in range(size * 5):
                    if len(out) >= size:
                        break
                    v = elements.sample(r)
                    if unique and v in out:
                        continue
                    out.append(v)
                return out

            return _Strategy(sample)

    st = _Strategies()

    def settings(max_examples=_DEFAULT_EXAMPLES, **_ignored):
        def deco(fn):
            fn._fallback_max_examples = max_examples
            return fn

        return deco

    def given(*strategies):
        def deco(fn):
            # NB: no functools.wraps — pytest must see a zero-argument
            # signature, not the wrapped function's strategy parameters
            # (it would try to resolve them as fixtures).
            def wrapper():
                n = getattr(wrapper, "_fallback_max_examples",
                            _DEFAULT_EXAMPLES)
                seed = zlib.crc32(fn.__qualname__.encode())
                for ex in range(n):
                    rnd = random.Random(seed * 1000003 + ex)
                    vals = [s.sample(rnd) for s in strategies]
                    try:
                        fn(*vals)
                    except BaseException:
                        print(f"Falsifying fallback example "
                              f"{fn.__name__}[{ex}]: {vals!r}")
                        raise

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco
