"""Batched tuning service: bit-match vs sequential, kernel routing parity,
effective-set cache exactness."""
import numpy as np
import pytest

from repro.core.moo import hmooc, pareto
from repro.core.moo.hmooc import (HMOOCConfig, _pareto_bank, build_candidates,
                                  dag_aggregate, hmooc_solve)
from repro.core.moo.pareto import pareto_mask_fast, pareto_mask_np
from repro.core.tuning.compile_time import compile_time_optimize
from repro.queryengine.workloads import make_benchmark, serving_stream
from repro.serve import EffectiveSetCache, TuningService, tune_batch
from repro.serve.cache import query_fingerprint

CFG = HMOOCConfig(n_c_init=16, n_clusters=4, n_p_pool=48, n_c_enrich=12,
                  max_bank=12, seed=3)


@pytest.fixture(scope="module")
def queries():
    qs = make_benchmark("tpch")
    return [qs[1], qs[5], qs[8]]


# ---------------------------------------------------------------------------
# Tentpole: batched service
# ---------------------------------------------------------------------------

def test_tune_batch_bitmatches_sequential(queries):
    batch = tune_batch(queries, (0.9, 0.1), CFG)
    for q, got in zip(queries, batch):
        ref = compile_time_optimize(q, weights=(0.9, 0.1), cfg=CFG)
        np.testing.assert_array_equal(got.front, ref.front)
        assert got.choice == ref.choice
        np.testing.assert_array_equal(got.theta_c, ref.theta_c)
        np.testing.assert_array_equal(got.theta_p_sub, ref.theta_p_sub)
        np.testing.assert_array_equal(got.theta_s_sub, ref.theta_s_sub)
        np.testing.assert_array_equal(got.theta_p0, ref.theta_p0)
        np.testing.assert_array_equal(got.theta_s0, ref.theta_s0)


def test_tune_batch_dedupes_identical_requests(queries):
    q = queries[0]
    svc = TuningService(cfg=CFG)
    res = svc.tune_batch([q, q, q, queries[1]])
    assert svc.last_batch.n_solved == 2
    assert svc.last_batch.n_deduped == 2
    np.testing.assert_array_equal(res[0].front, res[2].front)


def test_tune_batch_per_query_weights(queries):
    q = queries[2]
    res = tune_batch([q, q], [(1.0, 0.0), (0.0, 1.0)], CFG, dedupe=True)
    # Same front, potentially different WUN picks; latency-weighted choice
    # must not be slower than the cost-weighted one.
    np.testing.assert_array_equal(res[0].front, res[1].front)
    assert res[0].chosen_objectives[0] <= res[1].chosen_objectives[0]


def test_effective_set_cache_hit_identical_theta(queries):
    q = queries[0]
    # dedupe=False bypasses the response cache so the warm request
    # exercises the effective-set reuse path end to end.
    svc = TuningService(cfg=CFG, dedupe=False)
    cold = svc.tune_batch([q])[0]
    assert svc.cache.stats()["misses"] == 1
    warm = svc.tune_batch([q])[0]
    assert svc.cache.stats()["hits"] >= 1
    np.testing.assert_array_equal(cold.front, warm.front)
    np.testing.assert_array_equal(cold.theta_c, warm.theta_c)
    np.testing.assert_array_equal(cold.theta_p_sub, warm.theta_p_sub)
    np.testing.assert_array_equal(cold.theta_s_sub, warm.theta_s_sub)
    # The warm solve skipped Algorithm 1's representative MOO.
    assert warm.n_evals < cold.n_evals


def test_cache_structure_hit_is_exact():
    from repro.queryengine.workloads import make_query
    q_v1 = make_query("tpch", 3, variant=1)
    q_v2 = make_query("tpch", 3, variant=2)
    svc = TuningService(cfg=CFG)
    svc.tune_batch([q_v1])
    got = svc.tune_batch([q_v2])[0]
    assert svc.cache.stats()["structure_hits"] == 1
    ref = compile_time_optimize(q_v2, cfg=CFG)
    np.testing.assert_array_equal(got.front, ref.front)
    np.testing.assert_array_equal(got.theta_c, ref.theta_c)


def test_candidates_are_query_independent():
    e1 = build_candidates(4, 6, CFG)
    e2 = build_candidates(4, 6, CFG)
    np.testing.assert_array_equal(e1.Uc, e2.Uc)
    np.testing.assert_array_equal(e1.labels, e2.labels)
    np.testing.assert_array_equal(e1.pool, e2.pool)


def test_fingerprint_distinguishes_variants():
    from repro.queryengine.workloads import make_query
    a = make_query("tpch", 3, variant=1)
    b = make_query("tpch", 3, variant=2)
    c = make_query("tpch", 3, variant=1)
    assert query_fingerprint(a) != query_fingerprint(b)
    assert query_fingerprint(a) == query_fingerprint(c)


def test_default_theta_result_is_spark_defaults():
    """The degraded-path fallback: Spark documentation defaults, believed
    objectives from one stage evaluation per subQ, no Algorithm 1."""
    from repro.core.tuning.compile_time import default_theta_result
    from repro.core.tuning.spark_space import (theta_c_space, theta_p_space,
                                               theta_s_space)
    q = make_benchmark("tpch")[2]
    res = default_theta_result(q)
    np.testing.assert_allclose(res.theta_c, theta_c_space().default_raw())
    # Every subQ runs the same default θp/θs row.
    for row in res.theta_p_sub:
        np.testing.assert_allclose(row, theta_p_space().default_raw())
    for row in res.theta_s_sub:
        np.testing.assert_allclose(row, theta_s_space().default_raw())
    assert res.front.shape == (1, 2) and res.choice == 0
    assert np.isfinite(res.front).all() and (res.front > 0).all()
    assert res.n_evals == q.n_subqs
    # Deterministic: same query → bit-identical fallback.
    res2 = default_theta_result(q)
    np.testing.assert_array_equal(res.front, res2.front)
    np.testing.assert_array_equal(res.theta_p_sub, res2.theta_p_sub)


def test_tune_batch_degraded_flags_validated(queries):
    svc = TuningService(cfg=CFG)
    with pytest.raises(ValueError, match="degrade flags"):
        svc.tune_batch(queries, (0.9, 0.1), degraded=[True])


def test_serving_stream_deterministic_and_repeats():
    s1 = serving_stream("tpch", 24, seed=5)
    s2 = serving_stream("tpch", 24, seed=5)
    assert [q.qid for q in s1] == [q.qid for q in s2]
    assert len({q.qid for q in s1}) < len(s1)   # traffic repeats templates


# ---------------------------------------------------------------------------
# Kernel routing parity (Pallas pareto_filter / ws_reduce vs numpy)
# ---------------------------------------------------------------------------

@pytest.fixture
def force_kernels(monkeypatch):
    monkeypatch.setattr(pareto, "_KERNEL_MIN_N", 0)
    monkeypatch.setattr(hmooc, "_WS_MIN_SCORES", 0)


def _f32_bank(rng, shape, scale=10.0):
    # float32-representable values: the kernel's f32 comparisons are then
    # exact, so masks must match the float64 numpy path bit-for-bit.
    return (rng.random(shape) * scale).astype(np.float32).astype(np.float64)


def test_pareto_mask_fast_kernel_matches_numpy(force_kernels):
    rng = np.random.default_rng(0)
    for n, k in [(1, 2), (5, 3), (64, 2), (200, 3), (513, 4)]:
        F = _f32_bank(rng, (n, k))
        F[rng.random(n) < 0.15] = np.inf          # all-inf rows
        assert (pareto_mask_fast(F) == pareto_mask_np(F)).all()


def test_pareto_bank_kernel_matches_numpy_with_cap(monkeypatch):
    rng = np.random.default_rng(1)
    f0 = np.sort(rng.random(100))
    F = np.stack([f0, 1.0 - f0], -1)              # 100 mutually nondominated
    monkeypatch.setattr(pareto, "_KERNEL_MIN_N", 0)
    idx_kernel = _pareto_bank(F, 16)
    monkeypatch.setattr(pareto, "_KERNEL_MIN_N", 1 << 30)
    idx_numpy = _pareto_bank(F, 16)
    assert idx_kernel.size == 16                  # cap applied
    np.testing.assert_array_equal(idx_kernel, idx_numpy)


@pytest.mark.parametrize("method", ["hmooc1", "hmooc2", "hmooc3"])
def test_dag_aggregate_kernel_matches_numpy(method, force_kernels,
                                            monkeypatch):
    rng = np.random.default_rng(2)
    N, m, B, k = 6, 3, 8, 2
    Fb = _f32_bank(rng, (N, m, B, k))
    Fb[0, 1] = np.inf                             # a subQ with an empty bank
    Fb[3, :, 5:] = np.inf                         # partially padded banks
    Ib = np.tile(np.arange(B), (N, m, 1))
    Uc = rng.random((N, 3))
    pool = rng.random((B, 4))
    got = dag_aggregate(Uc, pool, Fb, Ib, method)
    monkeypatch.setattr(pareto, "_KERNEL_MIN_N", 1 << 30)
    monkeypatch.setattr(hmooc, "_WS_MIN_SCORES", 1 << 60)
    ref = dag_aggregate(Uc, pool, Fb, Ib, method)
    for a, b in zip(got, ref):
        a2 = np.sort(a.reshape(a.shape[0], -1), axis=0)
        b2 = np.sort(b.reshape(b.shape[0], -1), axis=0)
        assert a2.shape == b2.shape
        np.testing.assert_allclose(a2, b2, atol=1e-6)


def test_hmooc_solve_kernel_path_front_matches(force_kernels, monkeypatch):
    def stage_eval(i, Tc, Tps):
        base = 1.0 + i
        f1 = base * ((1 - Tps[:, 0]) ** 2 + 0.1) / (0.2 + Tc[:, 0])
        f2 = base * (0.1 + Tc[:, 0]) * (0.5 + Tps[:, 0])
        out = np.stack([f1, f2], -1)
        return out.astype(np.float32).astype(np.float64)

    cfg = HMOOCConfig(n_c_init=12, n_clusters=3, n_p_pool=32, n_c_enrich=8,
                      max_bank=8, seed=1)
    kernel = hmooc_solve(stage_eval, m=3, d_c=2, d_ps=2, cfg=cfg)
    monkeypatch.setattr(pareto, "_KERNEL_MIN_N", 1 << 30)
    monkeypatch.setattr(hmooc, "_WS_MIN_SCORES", 1 << 60)
    ref = hmooc_solve(stage_eval, m=3, d_c=2, d_ps=2, cfg=cfg)
    np.testing.assert_allclose(np.sort(kernel.front, 0),
                               np.sort(ref.front, 0), atol=1e-6)


# ---------------------------------------------------------------------------
# Degraded-path accounting: the cached kind must travel with the entry
# ---------------------------------------------------------------------------

def test_degraded_kind_survives_bank_cache_eviction():
    """A cached cheap (bank-reuse) degraded result must keep reporting as
    cheap after the effective-set cache evicts the template — re-probing
    bank availability at hit time would relabel it as a default."""
    from repro.queryengine.workloads import make_query
    q_v1 = make_query("tpch", 3, variant=1)
    q_v2 = make_query("tpch", 3, variant=2)
    svc = TuningService(cfg=CFG)
    svc.tune_batch([q_v1])                       # seeds the template's banks
    res = svc.tune_batch([q_v2], degraded=[True])
    assert svc.last_batch.n_cheap == 1           # approximate bank reuse
    assert svc.last_batch.n_default_theta == 0
    svc.cache._entries.clear()                   # evict every template
    res2 = svc.tune_batch([q_v2], degraded=[True])
    assert svc.last_batch.n_cheap == 1           # still labeled cheap
    assert svc.last_batch.n_default_theta == 0
    np.testing.assert_array_equal(res[0].front, res2[0].front)


def test_degraded_kind_default_not_relabeled_when_banks_appear():
    """The reverse staleness: a cached default-θ degraded result stays
    labeled default even if template banks have shown up since."""
    from repro.queryengine.workloads import make_query
    q_v1 = make_query("tpch", 5, variant=1)
    q_v2 = make_query("tpch", 5, variant=2)
    svc = TuningService(cfg=CFG)
    res = svc.tune_batch([q_v2], degraded=[True])
    assert svc.last_batch.n_default_theta == 1   # no banks anywhere yet
    svc.tune_batch([q_v1])                       # banks appear (variant 1)
    res2 = svc.tune_batch([q_v2], degraded=[True])
    assert svc.last_batch.n_default_theta == 1   # cached default, says so
    assert svc.last_batch.n_cheap == 0
    np.testing.assert_array_equal(res[0].front, res2[0].front)


# ---------------------------------------------------------------------------
# Response-cache model identity: fingerprint keys, swap safety, eviction
# ---------------------------------------------------------------------------

def _tiny_perf_model(seed):
    from repro.core.models.gtn import GTNConfig
    from repro.core.models.perf_model import ModelConfig, PerfModel
    cfg = ModelConfig("subq", 19, gtn=GTNConfig(d_model=16, n_heads=2,
                                                n_layers=1, d_ff=32),
                      hidden=(16,))
    return PerfModel(cfg, seed=seed)


def test_response_cache_model_swap_and_clear(queries):
    from repro.core.models.perf_model import PerfModel
    from repro.serve.cache import model_fingerprint
    m1 = _tiny_perf_model(seed=0)
    m2 = _tiny_perf_model(seed=1)
    assert model_fingerprint(m1) != model_fingerprint(m2)
    q = queries[0]
    svc = TuningService(model=m1, cfg=CFG)
    r1 = svc.tune_batch([q])
    assert svc.last_batch.n_solved == 1
    # Retrained model swapped in: the old entry must never be served.
    svc.model = m2
    svc.tune_batch([q])
    assert svc.last_batch.n_solved == 1          # fresh solve, no stale hit
    # A *reloaded* copy of m1 (same weights, new object, new id) keeps its
    # entries valid: fingerprint identity, not object identity.
    m1b = PerfModel(m1.cfg, params=m1.params, target_stats=m1.target_stats)
    assert m1b is not m1
    assert model_fingerprint(m1b) == model_fingerprint(m1)
    svc.model = m1b
    r1b = svc.tune_batch([q])
    assert svc.last_batch.n_deduped == 1         # served m1's cached result
    np.testing.assert_array_equal(r1[0].front, r1b[0].front)
    # Retiring a model drops exactly its entries.
    n = svc._results.clear_model(model_fingerprint(m1))
    assert n == 1
    assert svc._results.stats()["model_evictions"] == 1
    svc.tune_batch([q])
    assert svc.last_batch.n_solved == 1          # entry gone, solved anew
