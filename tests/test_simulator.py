"""Simulator + workload + AQE invariants."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.tuning.spark_space import (theta_c_space, theta_p_space,
                                           theta_s_space)
from repro.queryengine.aqe import run_with_aqe
from repro.queryengine.plan import SubQ, topo_order
from repro.queryengine.simulator import (GB, JOIN_BHJ, JOIN_SHJ, JOIN_SMJ,
                                         default_theta, simulate_query,
                                         simulate_subq, upgrade_joins)
from repro.queryengine.workloads import make_benchmark, make_query


@pytest.fixture(scope="module")
def tpch():
    return make_benchmark("tpch")


def test_workload_shapes(tpch):
    assert len(tpch) == 22
    counts = [q.n_subqs for q in tpch]
    assert max(counts) == 12                   # paper: Q9-like has 12 subQs
    ds = make_benchmark("tpcds")
    assert len(ds) == 102
    assert max(q.n_subqs for q in ds) >= 40    # paper: up to 47

    for q in tpch:
        order = q.topo_subqs()
        assert sorted(order) == list(range(q.n_subqs))
        # agg subQ is last; scan subQs have no children
        for sq in q.subqs:
            if sq.kind == "scan":
                assert not sq.children


def test_workload_determinism():
    a = make_query("tpch", 3, variant=1)
    b = make_query("tpch", 3, variant=1)
    assert a.subqs[0].out_rows == b.subqs[0].out_rows
    c = make_query("tpch", 3, variant=2)
    assert any(x.out_rows != y.out_rows
               for x, y in zip(a.subqs, c.subqs))


def test_simulation_positive_and_finite(tpch):
    rng = np.random.default_rng(0)
    cs, ps, ss = theta_c_space(), theta_p_space(), theta_s_space()
    tc = cs.to_raw(cs.sample_lhs(rng, 16))
    tp = ps.to_raw(ps.sample_lhs(rng, 16))
    ts = ss.to_raw(ss.sample_lhs(rng, 16))
    for q in tpch[:5]:
        r = simulate_query(q, tc, tp, ts)
        for arr in (r.ana_latency, r.actual_latency, r.io_gb, r.cost):
            assert np.isfinite(arr).all() and (arr > 0).all()
        assert (r.actual_latency >= r.ana_latency * 0.99).all()


def test_analytical_tracks_actual(tpch):
    rng = np.random.default_rng(1)
    cs, ps, ss = theta_c_space(), theta_p_space(), theta_s_space()
    n = 64
    ana, act = [], []
    for q in tpch:
        tc = cs.to_raw(cs.sample_lhs(rng, n))
        tp = ps.to_raw(ps.sample_lhs(rng, n))
        ts = ss.to_raw(ss.sample_lhs(rng, n))
        r = simulate_query(q, tc, tp, ts)
        ana.extend(r.ana_latency)
        act.extend(r.actual_latency)
    corr = np.corrcoef(ana, act)[0, 1]
    assert corr > 0.85        # paper Fig. 5: 0.876–0.972


def test_join_upgrade_only_toward_broadcast():
    planned = np.array([JOIN_SMJ, JOIN_SHJ, JOIN_BHJ, -1.0])
    runtime = np.array([JOIN_BHJ, JOIN_SMJ, JOIN_SMJ, JOIN_BHJ])
    out = upgrade_joins(planned, runtime)
    assert out.tolist() == [JOIN_BHJ, JOIN_SHJ, JOIN_BHJ, -1.0]


def test_aqe_pruning_rates(tpch):
    tc, tp, ts = default_theta(1)
    sent = tot = 0
    for q in tpch:
        r = run_with_aqe(q, tc[0], tp[0], ts[0], prune=True)
        sent += r.requests_sent
        tot += r.requests_total
        r2 = run_with_aqe(q, tc[0], tp[0], ts[0], prune=False)
        assert r2.requests_sent >= r.requests_sent
    rate = 1 - sent / tot
    assert 0.5 < rate < 0.99   # paper §5.2: 86% (TPC-H)


def test_more_cores_not_slower_analytically(tpch):
    """Analytical latency = task-seconds / cores: monotone in cores."""
    q = tpch[8]
    tc, tp, ts = default_theta(2)
    tc[1, 2] = tc[0, 2] * 4       # 4× executors
    r = simulate_query(q, tc, tp, ts)
    assert r.ana_latency[1] < r.ana_latency[0]


def _join_subq(out_bytes: float, cpu_weight: float = 1.7) -> SubQ:
    return SubQ(
        sq_id=0, op_ids=[0], children=[], kind="join", root_op=0,
        input_rows=(1e6, 2e6), input_bytes=(2e9, 3e9),
        est_input_rows=(1e6, 2e6), est_input_bytes=(2e9, 3e9),
        out_rows=1e6, out_bytes=out_bytes, est_out_rows=1e6,
        est_out_bytes=out_bytes, cpu_weight=cpu_weight, skew=0.0, depth=1)


def test_join_cost_composition_weight_applied_once():
    """Regression: the join output-write term carries cpu_weight exactly
    once — growing out_bytes by Δ grows task-seconds by (Δ/GB)·0.25·w,
    not (Δ/GB)·0.25·w² (the weight used to be applied twice)."""
    w = 1.7
    tc, tp, ts = default_theta(1)
    algo = np.array([JOIN_SMJ])
    base = simulate_subq(_join_subq(1.0e9, w), tc, tp, ts, join_algo=algo)
    grown = simulate_subq(_join_subq(5.0e9, w), tc, tp, ts, join_algo=algo)
    delta = grown.task_seconds[0] - base.task_seconds[0]
    np.testing.assert_allclose(delta, (4.0e9 / GB) * 0.25 * w, rtol=1e-9)
    # Total join cost is linear in cpu_weight (quadratic under the old bug).
    w2 = simulate_subq(_join_subq(1.0e9, 2 * w), tc, tp, ts, join_algo=algo)
    w3 = simulate_subq(_join_subq(1.0e9, 3 * w), tc, tp, ts, join_algo=algo)
    d1 = w2.task_seconds[0] - base.task_seconds[0]
    d2 = w3.task_seconds[0] - w2.task_seconds[0]
    np.testing.assert_allclose(d1, d2, rtol=1e-9)


def test_skew_gate_uses_post_coalesce_parts():
    """Regression: the AQE skew-split gate sizes partitions from the
    post-coalesce count, so s1/s11 coalescing interacts with skew handling
    (it used to read raw s5, where this setup never splits)."""
    skew, B = 0.5, 10e9
    sq = SubQ(
        sq_id=0, op_ids=[0], children=[], kind="agg", root_op=0,
        input_rows=(1e7,), input_bytes=(B,),
        est_input_rows=(1e7,), est_input_bytes=(B,),
        out_rows=1e5, out_bytes=1e8, est_out_rows=1e5, est_out_bytes=1e8,
        cpu_weight=1.0, skew=skew, depth=1)
    tc, tp, ts = default_theta(1)
    tp[0, 4] = 2048.0     # s5: raw mean partition ≈ 4.9 MB → no split
    tp[0, 0] = 512.0      # s1: coalesce to ≈ 9 parts → ≈ 1.1 GB each
    tp[0, 5] = 256.0      # s6 threshold (MB)
    s7 = tp[0, 6]
    r = simulate_subq(sq, tc, tp, ts)
    # Reconstruct skew_eff from wall = waves · mean_task · (1 + 2.5·skew_eff).
    waves = np.ceil(r.n_tasks[0] / (tc[0, 0] * tc[0, 2]))
    mean_task = r.task_seconds[0] / r.n_tasks[0]
    skew_eff = (r.wall_latency[0] / (waves * mean_task) - 1.0) / 2.5
    assert r.n_tasks[0] < 20           # coalescing actually engaged
    np.testing.assert_allclose(skew_eff, skew / s7, rtol=1e-6)
