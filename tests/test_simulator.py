"""Simulator + workload + AQE invariants."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.tuning.spark_space import (theta_c_space, theta_p_space,
                                           theta_s_space)
from repro.queryengine.aqe import run_with_aqe
from repro.queryengine.plan import topo_order
from repro.queryengine.simulator import (JOIN_BHJ, JOIN_SHJ, JOIN_SMJ,
                                         default_theta, simulate_query,
                                         upgrade_joins)
from repro.queryengine.workloads import make_benchmark, make_query


@pytest.fixture(scope="module")
def tpch():
    return make_benchmark("tpch")


def test_workload_shapes(tpch):
    assert len(tpch) == 22
    counts = [q.n_subqs for q in tpch]
    assert max(counts) == 12                   # paper: Q9-like has 12 subQs
    ds = make_benchmark("tpcds")
    assert len(ds) == 102
    assert max(q.n_subqs for q in ds) >= 40    # paper: up to 47

    for q in tpch:
        order = q.topo_subqs()
        assert sorted(order) == list(range(q.n_subqs))
        # agg subQ is last; scan subQs have no children
        for sq in q.subqs:
            if sq.kind == "scan":
                assert not sq.children


def test_workload_determinism():
    a = make_query("tpch", 3, variant=1)
    b = make_query("tpch", 3, variant=1)
    assert a.subqs[0].out_rows == b.subqs[0].out_rows
    c = make_query("tpch", 3, variant=2)
    assert any(x.out_rows != y.out_rows
               for x, y in zip(a.subqs, c.subqs))


def test_simulation_positive_and_finite(tpch):
    rng = np.random.default_rng(0)
    cs, ps, ss = theta_c_space(), theta_p_space(), theta_s_space()
    tc = cs.to_raw(cs.sample_lhs(rng, 16))
    tp = ps.to_raw(ps.sample_lhs(rng, 16))
    ts = ss.to_raw(ss.sample_lhs(rng, 16))
    for q in tpch[:5]:
        r = simulate_query(q, tc, tp, ts)
        for arr in (r.ana_latency, r.actual_latency, r.io_gb, r.cost):
            assert np.isfinite(arr).all() and (arr > 0).all()
        assert (r.actual_latency >= r.ana_latency * 0.99).all()


def test_analytical_tracks_actual(tpch):
    rng = np.random.default_rng(1)
    cs, ps, ss = theta_c_space(), theta_p_space(), theta_s_space()
    n = 64
    ana, act = [], []
    for q in tpch:
        tc = cs.to_raw(cs.sample_lhs(rng, n))
        tp = ps.to_raw(ps.sample_lhs(rng, n))
        ts = ss.to_raw(ss.sample_lhs(rng, n))
        r = simulate_query(q, tc, tp, ts)
        ana.extend(r.ana_latency)
        act.extend(r.actual_latency)
    corr = np.corrcoef(ana, act)[0, 1]
    assert corr > 0.85        # paper Fig. 5: 0.876–0.972


def test_join_upgrade_only_toward_broadcast():
    planned = np.array([JOIN_SMJ, JOIN_SHJ, JOIN_BHJ, -1.0])
    runtime = np.array([JOIN_BHJ, JOIN_SMJ, JOIN_SMJ, JOIN_BHJ])
    out = upgrade_joins(planned, runtime)
    assert out.tolist() == [JOIN_BHJ, JOIN_SHJ, JOIN_BHJ, -1.0]


def test_aqe_pruning_rates(tpch):
    tc, tp, ts = default_theta(1)
    sent = tot = 0
    for q in tpch:
        r = run_with_aqe(q, tc[0], tp[0], ts[0], prune=True)
        sent += r.requests_sent
        tot += r.requests_total
        r2 = run_with_aqe(q, tc[0], tp[0], ts[0], prune=False)
        assert r2.requests_sent >= r.requests_sent
    rate = 1 - sent / tot
    assert 0.5 < rate < 0.99   # paper §5.2: 86% (TPC-H)


def test_more_cores_not_slower_analytically(tpch):
    """Analytical latency = task-seconds / cores: monotone in cores."""
    q = tpch[8]
    tc, tp, ts = default_theta(2)
    tc[1, 2] = tc[0, 2] * 4       # 4× executors
    r = simulate_query(q, tc, tp, ts)
    assert r.ana_latency[1] < r.ana_latency[0]
