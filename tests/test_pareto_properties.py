"""Property tests for the Pareto invariants the serving layer leans on.

Runs under real ``hypothesis`` when installed or the fixed-seed sweep shim
(``tests/_hypothesis_compat.py``) otherwise.  Three families:

* kernel/oracle agreement: the Pallas ``pareto_filter`` kernel and the
  pure-jnp ``ref.py`` oracle produce the same mask for random shapes and
  dtypes, including sizes straddling the env-gated routing threshold of
  ``pareto_mask_fast``;
* front soundness: every returned front is mutually non-dominated;
* dominance safety: an explicitly dominated point never survives
  ``pareto_mask_fast`` on either routing.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.moo import pareto
from repro.core.moo.pareto import pareto_mask_fast, pareto_mask_np
from repro.kernels.pareto_filter import pareto_filter, pareto_mask_ref


@pytest.fixture(autouse=True)
def _restore_kernel_threshold():
    """Tests below force the env-gated routing threshold directly; restore
    it afterwards (the production value is resolved lazily from the env)."""
    saved = pareto._KERNEL_MIN_N
    yield
    pareto._KERNEL_MIN_N = saved


def _random_objectives(seed: int, n: int, k: int, *, grid: int,
                       inf_frac: float) -> np.ndarray:
    """(n, k) f32-representable minimization objectives, some rows +inf.

    Small-integer grid values keep the kernel's float32 comparisons exact,
    so masks must match the float64 numpy path bit-for-bit.
    """
    rng = np.random.default_rng(seed)
    F = rng.integers(0, grid, size=(n, k)).astype(np.float64)
    F[rng.random(n) < inf_frac] = np.inf
    return F


def _mutually_nondominated(F: np.ndarray) -> bool:
    if F.shape[0] == 0:
        return True
    le = (F[:, None, :] <= F[None, :, :]).all(-1)
    lt = (F[:, None, :] < F[None, :, :]).any(-1)
    np.fill_diagonal(le, False)
    return not (le & lt).any()


# ---------------------------------------------------------------------------
# Kernel vs ref.py oracle
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 320),
       st.integers(2, 5), st.sampled_from(["float32", "float64"]),
       st.floats(0.0, 0.3))
def test_pareto_filter_kernel_matches_ref(seed, n, k, dtype, inf_frac):
    F = _random_objectives(seed, n, k, grid=7, inf_frac=inf_frac)
    Fj = jnp.asarray(F.astype(dtype))
    valid = jnp.asarray(np.isfinite(F).all(-1))
    got = np.asarray(pareto_filter(Fj, valid))
    ref = np.asarray(pareto_mask_ref(Fj, valid))
    np.testing.assert_array_equal(got, ref)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 40), st.integers(2, 4),
       st.integers(-3, 3))
def test_mask_fast_agrees_across_threshold(seed, n, k, delta):
    """Routing must not change the mask: force the env-gated threshold to
    land just below / at / just above the input size so the same input
    exercises the numpy path and the Pallas kernel path, and compare both
    against plain numpy."""
    F = _random_objectives(seed, n, k, grid=6, inf_frac=0.1)
    ref = pareto_mask_np(F)
    try:
        pareto._KERNEL_MIN_N = max(0, n + delta)
        np.testing.assert_array_equal(pareto_mask_fast(F), ref)
    finally:
        pareto._KERNEL_MIN_N = None


# ---------------------------------------------------------------------------
# Front soundness + dominance safety
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 300), st.integers(2, 5))
def test_front_is_mutually_nondominated(seed, n, k):
    F = _random_objectives(seed, n, k, grid=5, inf_frac=0.15)
    for mask in (pareto_mask_np(F), pareto_mask_fast(F)):
        front = F[np.asarray(mask)]
        assert _mutually_nondominated(front)
        # Idempotence: filtering a front returns the whole front.
        if front.shape[0]:
            assert pareto_mask_np(front).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 120), st.integers(2, 4),
       st.booleans())
def test_dominated_points_never_survive(seed, n, k, force_kernel):
    """Append one strictly-dominated copy of each finite row; none of the
    copies may survive pareto_mask_fast on either routing."""
    F = _random_objectives(seed, n, k, grid=8, inf_frac=0.1)
    finite = np.isfinite(F).all(-1)
    dominated = F[finite] + 1.0        # strictly worse in every objective
    if dominated.shape[0] == 0:
        return
    stacked = np.concatenate([F, dominated])
    try:
        if force_kernel:
            pareto._KERNEL_MIN_N = 0
        mask = np.asarray(pareto_mask_fast(stacked))
        assert not mask[n:].any()
        # The original rows' masks are unchanged by adding dominated points.
        np.testing.assert_array_equal(mask[:n], pareto_mask_fast(F))
    finally:
        pareto._KERNEL_MIN_N = None


# ---------------------------------------------------------------------------
# f32-tie routing: float64-distinct values that collide in float32 must not
# change the mask depending on which backend the batch routed to.
# ---------------------------------------------------------------------------

def test_f32_tie_hazard_detector():
    clean = np.array([[1.0, 2.0], [3.0, 4.0], [np.inf, np.inf]])
    assert not pareto._f32_tie_hazard(clean)
    # 1.0 and 1.0 + 1e-12 are distinct doubles, identical floats.
    hazard = np.array([[1.0, 2.0], [1.0 + 1e-12, 4.0]])
    assert pareto._f32_tie_hazard(hazard)
    # Infinities never count as collisions.
    assert not pareto._f32_tie_hazard(np.array([[np.inf, 1.0],
                                                [np.inf, 2.0]]))


def test_mask_fast_f32_tie_straddle_routes_to_numpy():
    """Engineered straddle: point b is strictly dominated in float64 but
    ties its dominator after the kernel's float32 cast.  Tie-tolerant
    routing must keep the float64 verdict on the kernel regime too."""
    pareto._KERNEL_MIN_N = 0          # kernel regime for every size
    F = np.array([[1.0, 2.0],
                  [1.0 + 1e-12, 2.0],         # dominated by row 0 (f64 only)
                  [0.5, 3.0]])
    got = pareto_mask_fast(F)
    np.testing.assert_array_equal(got, pareto_mask_np(F))
    np.testing.assert_array_equal(got, [True, False, True])


def test_mask_fast_f32_tie_straddle_large_n():
    """Same straddle buried in a large batch that would otherwise route to
    the kernel on its own size."""
    pareto._KERNEL_MIN_N = 0
    rng = np.random.default_rng(7)
    F = (rng.random((600, 2)) * 8 + 4).astype(np.float32).astype(np.float64)
    F[17] = (2.0, 2.0)
    F[401] = (2.0 + 4e-13, 2.0)       # f64-dominated, f32-tied with row 17
    got = pareto_mask_fast(F)
    np.testing.assert_array_equal(got, pareto_mask_np(F))
    assert got[17] and not got[401]
