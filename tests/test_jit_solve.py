"""Accelerator-resident jitted model-backed solve: parity + compile bounds.

The batched serving path (``TuningService(jit_solve=None/True)``) fuses
every (query, subQ, candidate) stage evaluation of a micro-batch into
bucket-padded ``PerfModel.predict_rows`` dispatches and drives the HMOOC
solves in lockstep.  These tests pin its two contracts:

* **bit identity** — per-query results, cache statistics and stored
  artifacts are exactly those of the legacy sequential path
  (``jit_solve=False``), including dedup, template reuse, per-tenant
  keying and degraded-query interleaving;
* **bounded recompilation** — across arbitrarily varying batch sizes the
  jitted functions compile at most one signature per shape bucket.
"""
import numpy as np
import pytest

from repro.core.moo.hmooc import HMOOCConfig
from repro.core.tuning.compile_time import default_theta_result
from repro.core.tuning.objectives import StageObjectives, fused_stage_eval
from repro.queryengine.workloads import make_benchmark, serving_stream
from repro.serve import TuningService

CFG = HMOOCConfig(n_c_init=16, n_clusters=4, n_p_pool=48, n_c_enrich=12,
                  max_bank=12, seed=3)


def _assert_ct_equal(a, b):
    np.testing.assert_array_equal(a.front, b.front)
    assert a.choice == b.choice
    np.testing.assert_array_equal(a.theta_c, b.theta_c)
    np.testing.assert_array_equal(a.theta_p_sub, b.theta_p_sub)
    np.testing.assert_array_equal(a.theta_s_sub, b.theta_s_sub)
    np.testing.assert_array_equal(a.theta_p0, b.theta_p0)
    np.testing.assert_array_equal(a.theta_s0, b.theta_s0)


def _stats_tuple(svc):
    s = svc.last_batch
    return (s.n_queries, s.n_solved, s.n_deduped, s.n_cheap,
            s.n_default_theta)


def test_jit_solve_bitmatches_legacy(smoke_perf_models):
    """Repeated-template stream: per-query results, response dedup and
    effective-set reuse all match the sequential path bit for bit."""
    model = smoke_perf_models["subq"]
    stream = serving_stream("tpch", 10, seed=5)   # repeats templates
    legacy = TuningService(model=model, cfg=CFG, jit_solve=False)
    jit = TuningService(model=model, cfg=CFG)
    ra = legacy.tune_batch(stream)
    rb = jit.tune_batch(stream)
    for a, b in zip(ra, rb):
        _assert_ct_equal(a, b)
    assert _stats_tuple(legacy) == _stats_tuple(jit)
    assert legacy.cache.stats() == jit.cache.stats()
    assert legacy._results.stats()["hits"] == jit._results.stats()["hits"]
    # Second identical batch: both fully deduped.
    ra2 = jit.tune_batch(stream)
    assert jit.last_batch.n_deduped == len(stream)
    for a, b in zip(rb, ra2):
        _assert_ct_equal(a, b)


def test_jit_solve_per_tenant_golden_determinism(smoke_perf_models):
    """Per-tenant keys and per-query weights survive the batched path
    unchanged: each tenant gets the pick its own weights select, identical
    to a sequential solve of the same request."""
    model = smoke_perf_models["subq"]
    qs = make_benchmark("tpch")
    queries = [qs[1], qs[1], qs[5]]
    tenants = ["a", "b", "a"]
    weights = [(1.0, 0.0), (0.0, 1.0), (0.5, 0.5)]
    legacy = TuningService(model=model, cfg=CFG, jit_solve=False)
    jit = TuningService(model=model, cfg=CFG)
    ra = legacy.tune_batch(queries, weights, tenants=tenants)
    rb = jit.tune_batch(queries, weights, tenants=tenants)
    for a, b in zip(ra, rb):
        _assert_ct_equal(a, b)
    assert _stats_tuple(legacy) == _stats_tuple(jit)
    # Same front across weights, picks chosen per request's own weights.
    np.testing.assert_array_equal(rb[0].front, rb[1].front)
    assert rb[0].chosen_objectives[0] <= rb[1].chosen_objectives[0]


def test_jit_solve_degraded_interleave_matches_legacy(smoke_perf_models):
    """Degraded queries act as barriers inside a batch; stats and results
    still match the sequential transcript exactly."""
    model = smoke_perf_models["subq"]
    stream = serving_stream("tpch", 8, seed=11)
    degraded = [False, True, False, False, True, False, False, False]
    legacy = TuningService(model=model, cfg=CFG, jit_solve=False)
    jit = TuningService(model=model, cfg=CFG)
    ra = legacy.tune_batch(stream, degraded=degraded)
    rb = jit.tune_batch(stream, degraded=degraded)
    for a, b in zip(ra, rb):
        _assert_ct_equal(a, b)
    assert _stats_tuple(legacy) == _stats_tuple(jit)
    assert legacy.cache.stats() == jit.cache.stats()


def test_jit_solve_recompilation_bound():
    """Across varying micro-batch sizes the jitted model functions compile
    at most one signature per shape bucket."""
    from test_serve import _tiny_perf_model
    model = _tiny_perf_model(seed=2)
    svc = TuningService(model=model, cfg=CFG, dedupe=False)
    stream = serving_stream("tpch", 12, seed=3)
    for size in (1, 3, 2, 5, 1):
        batch, stream = stream[:size], stream[size:]
        svc.tune_batch(batch)
    stats = model.compile_stats()
    assert stats["head_compiles"] == len(stats["head_buckets"])
    assert stats["embed_compiles"] == len(stats["embed_buckets"])


def test_default_theta_result_batched_equivalence(smoke_perf_models):
    """Satellite: the vectorized degraded fallback equals the historical
    per-subQ loop (model-backed).  One batched regressor dispatch replaces
    m batch-of-one calls; XLA's matvec-vs-matmul codegen may differ in the
    final float32 ulp, so equivalence is to float32 precision — the
    reduction order itself is unchanged (left-to-right over subQs)."""
    model = smoke_perf_models["subq"]
    q = make_benchmark("tpch")[2]
    res = default_theta_result(q, model=model)
    obj = StageObjectives(q, model=model)
    tc_u = obj.cs.default_unit()[None, :]
    tps_u = np.tile(np.concatenate([obj.ps.default_unit(),
                                    obj.ss.default_unit()]), (obj.m, 1))
    front = np.zeros((1, 2), np.float64)
    for i in range(obj.m):
        front[0] += obj.stage_eval(i, tc_u, tps_u[i:i + 1])[0]
    np.testing.assert_allclose(res.front, front, rtol=2e-6)
    assert res.n_evals == q.n_subqs
    # Determinism: repeated batched evaluations are bit-identical.
    res2 = default_theta_result(q, model=model)
    np.testing.assert_array_equal(res.front, res2.front)


def test_fused_stage_eval_matches_per_request(smoke_perf_models):
    """fused_stage_eval row slices equal the per-request stage_eval calls
    they replace, across queries and subQs in one dispatch."""
    model = smoke_perf_models["subq"]
    qs = make_benchmark("tpch")
    rng = np.random.default_rng(0)
    items, refs = [], []
    for q in (qs[1], qs[5]):
        obj = StageObjectives(q, model=model)
        for i in range(min(2, obj.m)):
            n = int(rng.integers(3, 9))
            Tc = rng.random((n, obj.d_c))
            Tps = rng.random((n, obj.d_ps))
            items.append((obj, i, Tc, Tps))
            refs.append(obj.stage_eval(i, Tc, Tps))
    got = fused_stage_eval(items)
    assert len(got) == len(refs)
    for g, r in zip(got, refs):
        np.testing.assert_array_equal(g, r)


def test_fused_stage_eval_oracle_fallback():
    """Oracle backend (model=None) falls back to per-request evaluation."""
    q = make_benchmark("tpch")[1]
    obj = StageObjectives(q)
    Tc = np.full((4, obj.d_c), 0.5)
    Tps = np.full((4, obj.d_ps), 0.5)
    got = fused_stage_eval([(obj, 0, Tc, Tps), (obj, 1, Tc, Tps)])
    np.testing.assert_array_equal(got[0], obj.stage_eval(0, Tc, Tps))
    np.testing.assert_array_equal(got[1], obj.stage_eval(1, Tc, Tps))
