"""Compile-time/runtime tuning pipeline + aggregation + cluster autotuner."""
import numpy as np
import pytest

from repro.core.moo.hmooc import HMOOCConfig
from repro.core.moo.pareto import pareto_mask_np
from repro.core.moo.baselines import solve_evo, solve_pf, solve_so_fw, \
    solve_ws
from repro.core.tuning.aggregation import aggregate_submission_theta
from repro.core.tuning.compile_time import compile_time_optimize
from repro.core.tuning.objectives import StageObjectives
from repro.core.tuning.runtime import make_runtime_optimizers
from repro.queryengine.aqe import run_with_aqe
from repro.queryengine.simulator import default_theta
from repro.queryengine.workloads import make_benchmark


@pytest.fixture(scope="module")
def q9():
    return make_benchmark("tpch")[8]


def test_compile_time_beats_default(q9):
    tc, tp, ts = default_theta(1)
    r_def = run_with_aqe(q9, tc[0], tp[0], ts[0])
    ct = compile_time_optimize(q9, weights=(0.9, 0.1),
                               cfg=HMOOCConfig(seed=0))
    r_opt = run_with_aqe(q9, ct.theta_c, ct.theta_p0, ct.theta_s0)
    assert r_opt.sim.actual_latency[0] < r_def.sim.actual_latency[0]
    assert ct.solve_time < 2.0      # paper's cloud constraint: 1–2 s


def test_runtime_opt_no_worse(q9):
    ct = compile_time_optimize(q9, weights=(0.9, 0.1),
                               cfg=HMOOCConfig(seed=0))
    r_ct = run_with_aqe(q9, ct.theta_c, ct.theta_p0, ct.theta_s0)
    lqp_o, qs_o = make_runtime_optimizers(
        q9, ct.theta_c, seed_theta_p=ct.theta_p_sub,
        seed_theta_s=ct.theta_s_sub, weights=(0.9, 0.1))
    r_rt = run_with_aqe(q9, ct.theta_c, ct.theta_p0, ct.theta_s0,
                        lqp_optimizer=lqp_o, qs_optimizer=qs_o)
    assert r_rt.sim.actual_latency[0] <= r_ct.sim.actual_latency[0] * 1.2


def test_aggregation_min_threshold_rule(q9):
    m = q9.n_subqs
    tp = np.tile(default_theta(1)[1][0], (m, 1))
    ts = np.tile(default_theta(1)[2][0], (m, 1))
    join_ids = [sq.sq_id for sq in q9.subqs if sq.kind == "join"]
    tp[join_ids, 3] = [500.0 + i for i in range(len(join_ids))]  # huge s4
    p0, s0 = aggregate_submission_theta(q9, tp, ts)
    assert p0[3] == 10.0                     # capped at the Spark default
    tp[join_ids, 3] = 2.0
    p0, _ = aggregate_submission_theta(q9, tp, ts)
    assert p0[3] == 2.0                      # min across joins below cap


def test_baselines_nondominated(q9):
    obj = StageObjectives(q9)
    ev, D = obj.query_eval_coarse()
    F, U, dt, ne = solve_ws(ev, D, n_samples=800, seed=0)
    assert pareto_mask_np(F).all() and F.shape[0] >= 1
    F, U, dt, ne = solve_evo(ev, D, pop=24, n_evals=96, seed=0)
    assert pareto_mask_np(F).all()
    F, U, dt, ne = solve_pf(ev, D, n_points=5, n_probe=128, seed=0)
    assert pareto_mask_np(F).all()
    F1, _, _, _ = solve_so_fw(ev, D, np.array([0.9, 0.1]),
                              n_samples=400, seed=0)
    assert F1.shape == (1, 2)


def test_cluster_autotuner_prefers_latency_with_weight():
    from repro.cluster.autotune import autotune
    fast = autotune("qwen2-72b", "train_4k", weights=(0.95, 0.05))
    cheap = autotune("qwen2-72b", "train_4k", weights=(0.05, 0.95))
    assert fast.predicted[0] <= cheap.predicted[0]
    assert pareto_mask_np(fast.front).all()
