"""Streaming-admission server: golden determinism, lifecycle, budget smoke.

The golden invariant (acceptance criterion): on the oracle backend the
``OptimizerServer`` output — final plans and objective values — is
bit-identical to the offline ``tune_batch`` → ``RuntimeSession.run_batch``
pipeline for the same workload, however the stream is sliced into
micro-batches and admission epochs.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.core.moo.hmooc import HMOOCConfig
from repro.queryengine.workloads import (ArrivalModel, StreamRequest,
                                         TenantSpec, make_query,
                                         multi_tenant_stream, serving_stream)
from repro.serve import (OptimizerServer, RuntimeSession, ServerConfig,
                         ServiceTimeModel, TuningService)

CFG = HMOOCConfig(n_c_init=16, n_clusters=4, n_p_pool=48, n_c_enrich=12,
                  max_bank=12, seed=3)
WEIGHTS = (0.9, 0.1)
N_STREAM = 14


@pytest.fixture(scope="module")
def timed_stream():
    return serving_stream("tpch", N_STREAM, seed=1,
                          arrivals=ArrivalModel(kind="poisson",
                                                rate_qps=40.0))


@pytest.fixture(scope="module")
def offline(timed_stream):
    """The batch-path reference: all queries at once through both halves."""
    queries = [r.query for r in timed_stream]
    cts = TuningService(cfg=CFG).tune_batch(queries, WEIGHTS)
    res = RuntimeSession(weights=WEIGHTS).run_batch(queries, cts)
    return cts, res


def _server(max_batch, **cfg_kw):
    return OptimizerServer(config=ServerConfig(max_batch=max_batch, **cfg_kw),
                           weights=WEIGHTS, cfg=CFG)


def _assert_same_outputs(served, offline_results):
    for s, ref in zip(served, offline_results):
        got = s.result
        np.testing.assert_array_equal(got.theta_p_eff, ref.theta_p_eff)
        np.testing.assert_array_equal(got.theta_s_eff, ref.theta_s_eff)
        np.testing.assert_array_equal(got.final_join, ref.final_join)
        np.testing.assert_array_equal(got.sim.ana_latency, ref.sim.ana_latency)
        np.testing.assert_array_equal(got.sim.actual_latency,
                                      ref.sim.actual_latency)
        np.testing.assert_array_equal(got.sim.io_gb, ref.sim.io_gb)
        np.testing.assert_array_equal(got.sim.cost, ref.sim.cost)
        assert got.requests_sent == ref.requests_sent
        assert got.requests_total == ref.requests_total


# ---------------------------------------------------------------------------
# Golden end-to-end determinism
# ---------------------------------------------------------------------------

def test_server_one_at_a_time_matches_batch_path(timed_stream, offline):
    _, ref = offline
    served = _server(max_batch=1).serve(timed_stream)
    _assert_same_outputs(served, ref)


def test_server_micro_batches_match_batch_path(timed_stream, offline):
    _, ref = offline
    served = _server(max_batch=4).serve(timed_stream)
    _assert_same_outputs(served, ref)


def test_server_shuffled_micro_batches_match(timed_stream, offline):
    """Shuffle which micro-batch each query lands in (permute the arrival
    stamps); per-rid outputs must not move."""
    _, ref = offline
    rng = np.random.default_rng(7)
    times = np.sort([r.arrival_s for r in timed_stream])
    perm = rng.permutation(len(timed_stream))
    shuffled = sorted(
        (dataclasses.replace(r, arrival_s=float(times[perm[i]]))
         for i, r in enumerate(timed_stream)),
        key=lambda r: r.arrival_s)
    served = _server(max_batch=5).serve(shuffled)
    by_rid = {s.rid: s for s in served}
    _assert_same_outputs([by_rid[r.rid] for r in timed_stream], ref)


def test_mid_session_admission_matches_batch_path(timed_stream, offline):
    """Force late arrivals into a running session: everything arrives at
    t=0 except a tail that lands mid-flight; outputs still bit-match."""
    _, ref = offline
    reqs = [dataclasses.replace(r, arrival_s=0.0 if r.rid < 10 else 1e-4)
            for r in timed_stream]
    srv = _server(max_batch=10, solve_reserve_s=0.0)
    served = srv.serve(reqs)
    by_rid = {s.rid: s for s in served}
    _assert_same_outputs([by_rid[r.rid] for r in timed_stream], ref)
    # The tail actually joined a live session (not a fresh batch).
    assert srv.last_run.n_joined_running >= 1
    assert any(s.joined_running for s in served)


def test_repeat_serve_shares_caches(timed_stream, offline):
    """A long-lived server keeps amortizing: a second identical stream is
    served entirely from the response cache (zero new solves) and returns
    identical results."""
    _, ref = offline
    srv = _server(max_batch=4)
    first = srv.serve(timed_stream)
    _assert_same_outputs(first, ref)
    solved_before = srv.tuning._results.misses
    second = srv.serve(timed_stream)
    _assert_same_outputs(second, ref)
    assert srv.tuning._results.misses == solved_before
    # Candidate pools were drawn exactly once across both epochs.
    assert srv.session.pool_cache.misses == 1


# ---------------------------------------------------------------------------
# Lifecycle / scheduling behavior
# ---------------------------------------------------------------------------

def test_server_latency_accounting(timed_stream):
    srv = _server(max_batch=4)
    served = srv.serve(timed_stream)
    rep = srv.latency_report(served)
    assert rep["n_queries"] == len(timed_stream)
    assert rep["n_micro_batches"] >= math.ceil(len(timed_stream) / 4)
    for s in served:
        assert s.arrival_s <= s.admitted_s <= s.compiled_s <= s.finished_s
    assert rep["plan_latency_s"]["p50"] > 0.0
    assert rep["plan_latency_s"]["max"] >= rep["plan_latency_s"]["p99"] >= \
        rep["plan_latency_s"]["p50"]


def test_deadline_flush_beats_full_batch(timed_stream):
    """With max_batch larger than the stream, only the solve-budget
    deadline can flush; every query must still be served."""
    srv = _server(max_batch=64, solve_budget_s=0.05, solve_reserve_s=0.0)
    served = srv.serve(timed_stream)
    assert all(s.result is not None for s in served)
    assert srv.last_run.n_micro_batches >= 1


def test_serve_refuses_foreign_active_session(timed_stream, offline):
    cts, _ = offline
    srv = _server(max_batch=4)
    srv.session.admit(timed_stream[0].query, cts[0])   # outside the server
    with pytest.raises(RuntimeError, match="idle session"):
        srv.serve(timed_stream)


def test_server_rejects_conflicting_construction(timed_stream):
    sess = RuntimeSession(weights=(0.9, 0.1))
    with pytest.raises(ValueError, match="conflicts"):
        OptimizerServer(session=sess, weights=(0.5, 0.5))
    # Matching weights alongside a prebuilt session are accepted.
    OptimizerServer(session=sess, weights=(0.9, 0.1))
    with pytest.raises(ValueError, match="not both"):
        OptimizerServer(tuning=TuningService(cfg=CFG), cfg=CFG)


def test_serve_rejects_duplicate_rids(timed_stream):
    dup = list(timed_stream) + [timed_stream[0]]
    with pytest.raises(ValueError, match="duplicate rids"):
        _server(max_batch=4).serve(dup)


def test_serve_and_report_handle_empty_stream():
    srv = _server(max_batch=4)
    assert srv.serve([]) == []
    rep = srv.latency_report([])
    assert rep["n_queries"] == 0
    assert math.isnan(rep["plan_latency_s"]["p99"])


def test_run_batch_refuses_active_session(timed_stream, offline):
    cts, _ = offline
    sess = RuntimeSession(weights=WEIGHTS)
    sess.admit(timed_stream[0].query, cts[0])
    with pytest.raises(RuntimeError, match="active"):
        sess.run_batch([timed_stream[1].query], [cts[1]])


def test_session_join_retire_interleaved(timed_stream, offline):
    """Drive the open-set lifecycle by hand: admit half, run one round,
    admit the rest, drain; per-query results equal the closed-batch run."""
    cts, ref = offline
    queries = [r.query for r in timed_stream]
    sess = RuntimeSession(weights=WEIGHTS)
    half = len(queries) // 2
    entries = [sess.admit(q, ct) for q, ct in
               zip(queries[:half], cts[:half])]
    sess.step_round()
    entries += [sess.admit(q, ct) for q, ct in
                zip(queries[half:], cts[half:])]
    while sess.step_round():
        pass
    retired = sess.retire_ready()
    assert sess.n_active == 0 and len(retired) == len(queries)
    results = sess.realize(entries)   # realize in admission order
    for got, want in zip(results, ref):
        np.testing.assert_array_equal(got.theta_p_eff, want.theta_p_eff)
        np.testing.assert_array_equal(got.final_join, want.final_join)
        np.testing.assert_array_equal(got.sim.cost, want.sim.cost)


# ---------------------------------------------------------------------------
# Arrival-model reproducibility (satellite: explicit arrival-time model)
# ---------------------------------------------------------------------------

def test_arrival_model_reproducible_and_sorted():
    a1 = serving_stream("tpch", 16, seed=5,
                        arrivals=ArrivalModel(kind="poisson", rate_qps=8.0))
    a2 = serving_stream("tpch", 16, seed=5,
                        arrivals=ArrivalModel(kind="poisson", rate_qps=8.0))
    assert all(isinstance(r, StreamRequest) for r in a1)
    assert [r.arrival_s for r in a1] == [r.arrival_s for r in a2]
    assert [r.query.qid for r in a1] == [r.query.qid for r in a2]
    times = [r.arrival_s for r in a1]
    assert times == sorted(times) and times[0] > 0.0
    # Different seed ⇒ different timing; same model kind keeps the mean rate.
    b = serving_stream("tpch", 16, seed=6,
                       arrivals=ArrivalModel(kind="poisson", rate_qps=8.0))
    assert [r.arrival_s for r in b] != times


def test_arrival_model_kinds():
    fixed = ArrivalModel(kind="fixed", rate_qps=4.0).draw(5, seed=0)
    np.testing.assert_allclose(np.diff(fixed), 0.25)
    uni = ArrivalModel(kind="uniform", rate_qps=4.0).draw(200, seed=0)
    assert (np.diff(uni) >= 0).all() and np.diff(uni).max() <= 0.5 + 1e-12
    with pytest.raises(ValueError):
        ArrivalModel(kind="bogus").draw(3)
    with pytest.raises(ValueError):
        ArrivalModel(rate_qps=0.0).draw(3)


def test_bench_server_smoke_meets_budget():
    """CI acceptance: the smoke-sized server run keeps every compile solve
    under the configured budget and stays parity with the offline pipeline
    on the oracle backend.  The budget is configured at the paper's 2 s
    upper end: typical smoke solves are ~0.2 s, so a real hot-path
    regression still trips it without wall-clock flakes on loaded CI."""
    import os
    import sys
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if root not in sys.path:
        sys.path.insert(0, root)
    from benchmarks import bench_server
    res = bench_server.run("tpch", n=12, rate_qps=40.0, max_batch=4,
                           budget_s=2.0, baseline_batch=6, seed=0, cfg=CFG)
    assert res["outputs_identical"]
    assert res["server"]["solve_latency_s"]["max"] < res["budget_s"]
    assert res["p99_under_budget"]


# ---------------------------------------------------------------------------
# Multi-tenant golden determinism (oracle backend)
# ---------------------------------------------------------------------------

def test_multi_tenant_per_tenant_parity_oracle():
    """N tenants with different preferences and arrival rates sharing one
    server: each tenant's output is bit-identical to the offline pipeline
    solved under that tenant's own weights — fairness shapes latency, never
    plans."""
    from repro.queryengine.workloads import TenantSpec, multi_tenant_stream
    specs = [TenantSpec(name="lat", weights=(0.9, 0.1), share=2.0,
                        arrivals=ArrivalModel(kind="poisson", rate_qps=30.0)),
             TenantSpec(name="bal", weights=(0.5, 0.5), priority=1,
                        arrivals=ArrivalModel(kind="poisson", rate_qps=20.0)),
             TenantSpec(name="cost", weights=(0.1, 0.9),
                        arrivals=ArrivalModel(kind="uniform", rate_qps=10.0))]
    reqs = multi_tenant_stream("tpch", specs, 4, seed=8)
    srv = OptimizerServer(config=ServerConfig(max_batch=4), weights=WEIGHTS,
                          cfg=CFG, tenants=specs)
    served = srv.serve(reqs)
    for spec in specs:
        sub = [s for s in served if s.tenant == spec.name]
        assert len(sub) == 4
        queries = [s.request.query for s in sub]
        cts = TuningService(cfg=CFG).tune_batch(queries, spec.weights)
        ref = RuntimeSession(weights=spec.weights).run_batch(queries, cts)
        _assert_same_outputs(sub, ref)


def test_tenant_weights_actually_change_picks():
    """Identical query served to latency-heavy and cost-heavy tenants must
    be solved under each tenant's own weights (equal picks would mean the
    preference vector was dropped somewhere along the path)."""
    import dataclasses as _dc
    from repro.queryengine.workloads import TenantSpec, make_query
    q = make_query("tpch", 8, variant=1)
    specs = [TenantSpec(name="lat", weights=(0.99, 0.01)),
             TenantSpec(name="cost", weights=(0.01, 0.99))]
    reqs = [StreamRequest(rid=0, query=q, arrival_s=0.0, tenant="lat"),
            StreamRequest(rid=1, query=q, arrival_s=0.0, tenant="cost")]
    srv = OptimizerServer(config=ServerConfig(max_batch=2), weights=WEIGHTS,
                          cfg=CFG, tenants=specs)
    served = srv.serve(reqs)
    lat, cost = served[0], served[1]
    assert lat.ct.choice != cost.ct.choice or not np.array_equal(
        lat.ct.theta_c, cost.ct.theta_c)
    # Each matches its own offline solve exactly.
    for s, w in ((lat, (0.99, 0.01)), (cost, (0.01, 0.99))):
        ref = TuningService(cfg=CFG).tune_batch([q], w)[0]
        assert s.ct.choice == ref.choice
        np.testing.assert_array_equal(s.ct.theta_c, ref.theta_c)


# ---------------------------------------------------------------------------
# Overload: shedding / degrading never perturbs surviving queries (oracle)
# ---------------------------------------------------------------------------

def _overload_specs():
    """Three SLO classes; strict/degrade budgets are unmeetable by
    construction (budget 0 < any positive reserve), so triage decisions
    are deterministic even though solve times are measured wall time."""
    return [
        TenantSpec(name="strict", slo="strict", solve_budget_s=0.0,
                   arrivals=ArrivalModel(kind="poisson", rate_qps=50.0)),
        TenantSpec(name="deg", slo="degrade", solve_budget_s=0.0,
                   arrivals=ArrivalModel(kind="poisson", rate_qps=50.0)),
        TenantSpec(name="be", slo="best_effort", weights=(0.5, 0.5),
                   arrivals=ArrivalModel(kind="poisson", rate_qps=50.0)),
    ]


def test_overload_shed_degrade_survivors_bit_identical():
    """Overloaded mixed-SLO stream: the strict tenant sheds everything
    (budget 0), the degrade tenant resolves via the cheap path, and every
    *surviving* full-quality query still bit-matches the offline pipeline
    under its tenant's weights — shedding/degrading shapes who gets served,
    never what the survivors are served."""
    specs = _overload_specs()
    reqs = multi_tenant_stream("tpch", specs, 5, seed=13)
    srv = OptimizerServer(config=ServerConfig(max_batch=4), weights=WEIGHTS,
                          cfg=CFG, tenants=specs)
    served = srv.serve(reqs)
    by = {name: [s for s in served if s.tenant == name]
          for name in ("strict", "deg", "be")}
    # Strict: all shed, first-class outcomes, nothing solved.
    assert [s.status for s in by["strict"]] == ["shed"] * 5
    assert all(s.ct is None and s.result is None for s in by["strict"])
    assert all(math.isfinite(s.finished_s) for s in by["strict"])
    assert srv.last_run.n_shed == 5
    # Degrade: all admitted via the cheap path, and they did resolve.
    assert [s.status for s in by["deg"]] == ["degraded"] * 5
    assert all(s.result is not None for s in by["deg"])
    assert srv.last_run.n_degraded == 5
    # Best-effort absorbed the queueing at full quality...
    assert [s.status for s in by["be"]] == ["served"] * 5
    # ...and its outputs bit-match the offline pipeline under its weights.
    queries = [s.request.query for s in by["be"]]
    cts = TuningService(cfg=CFG).tune_batch(queries, (0.5, 0.5))
    ref = RuntimeSession(weights=(0.5, 0.5)).run_batch(queries, cts)
    _assert_same_outputs(by["be"], ref)
    # Scheduler accounting matches the served statuses.
    assert srv.scheduler.state("strict").n_shed == 5
    assert srv.scheduler.state("deg").n_degraded == 5
    assert srv.last_run.tenant_slots == {"deg": 5, "be": 5}


def test_degraded_path_never_runs_fresh_algorithm1(monkeypatch):
    """Zero fresh Algorithm 1 bank builds for degraded queries: with a warm
    template cache the banks are reused across variants; with a cold cache
    the Spark-default θ is served — `_optimize_rep_banks` must not run
    either way."""
    from repro.core.moo import hmooc as hmooc_mod
    spec = TenantSpec(name="deg", slo="degrade", solve_budget_s=0.0,
                      arrivals=ArrivalModel(kind="poisson", rate_qps=50.0))
    reqs = multi_tenant_stream("tpch", [spec], 6, seed=14)
    srv = OptimizerServer(config=ServerConfig(max_batch=3), weights=WEIGHTS,
                          cfg=CFG, tenants=[spec])
    calls = []
    orig = hmooc_mod._optimize_rep_banks

    def spy(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    monkeypatch.setattr(hmooc_mod, "_optimize_rep_banks", spy)
    served = srv.serve(reqs)
    assert [s.status for s in served] == ["degraded"] * 6
    assert all(s.result is not None for s in served)
    assert not calls, "degraded solve triggered a fresh Algorithm 1 run"
    # Cold cache ⇒ at least one request fell back to the Spark defaults.
    assert srv.tuning.cache.stats()["peek_misses"] >= 1

    # Now warm the template cache with full solves of the same queries and
    # serve the degraded stream again: cheap solves reuse the banks — and
    # still zero fresh Algorithm 1 runs for the degraded traffic.
    monkeypatch.setattr(hmooc_mod, "_optimize_rep_banks", orig)
    queries = list({s.request.query.qid: s.request.query
                    for s in served}.values())
    srv.tuning.tune_batch(queries, WEIGHTS)          # full-quality warmup
    monkeypatch.setattr(hmooc_mod, "_optimize_rep_banks", spy)
    srv2_reqs = multi_tenant_stream("tpch", [spec], 6, seed=14)
    served2 = srv.serve(srv2_reqs)
    assert [s.status for s in served2] == ["degraded"] * 6
    assert not calls
    assert srv.tuning.cache.stats()["peek_hits"] >= 1


def test_degraded_exact_bank_reuse_matches_full_solve():
    """A degraded request whose template banks were computed from the
    *identical* query reuses them exactly: the cheap result equals the
    full solve bit for bit (the degrade path costs quality only across
    variants / cold caches)."""
    from repro.queryengine.workloads import make_query
    q = make_query("tpch", 4, variant=1)
    svc = TuningService(cfg=CFG)
    full = svc.tune_batch([q], WEIGHTS)[0]
    cheap = svc.tune_batch([q], WEIGHTS, degraded=[True])[0]
    # (The exact response cache may serve it directly; either way the
    # degraded result must be the full-quality one.)
    np.testing.assert_array_equal(cheap.front, full.front)
    assert cheap.choice == full.choice
    np.testing.assert_array_equal(cheap.theta_c, full.theta_c)
    np.testing.assert_array_equal(cheap.theta_p_sub, full.theta_p_sub)

    # And through a *fresh* service sharing only the effective-set cache
    # (no response cache hit): exact bank reuse, still bit-identical.
    svc2 = TuningService(cfg=CFG, cache=svc.cache)
    cheap2 = svc2.tune_batch([q], WEIGHTS, degraded=[True])[0]
    np.testing.assert_array_equal(cheap2.front, full.front)
    assert cheap2.choice == full.choice
    np.testing.assert_array_equal(cheap2.theta_c, full.theta_c)


def test_degraded_approx_results_never_served_to_full_requests():
    """Approximate degraded results live under a degrade-marked response
    key: a later full-quality request for the same (query, weights) must
    get a fresh exact solve, not the cross-variant approximation."""
    from repro.queryengine.workloads import make_query
    svc = TuningService(cfg=CFG)
    base = make_query("tpch", 4, variant=1)
    variant = make_query("tpch", 4, variant=2)
    svc.tune_batch([base], WEIGHTS)                   # warm template banks
    cheap = svc.tune_batch([variant], WEIGHTS, degraded=[True])[0]
    assert svc.last_batch.n_cheap == 1
    full = svc.tune_batch([variant], WEIGHTS)[0]
    assert svc.last_batch.n_solved == 1               # not served the approx
    ref = TuningService(cfg=CFG).tune_batch([variant], WEIGHTS)[0]
    np.testing.assert_array_equal(full.front, ref.front)
    assert full.choice == ref.choice
    # The approximation is reused for later degraded requests, though.
    again = svc.tune_batch([variant], WEIGHTS, degraded=[True])[0]
    np.testing.assert_array_equal(again.front, cheap.front)


def test_latency_report_mixed_finished_and_shed():
    """One shed query must not NaN-poison the report (PR-5 bugfix):
    percentiles and Jain aggregate over finished queries only, with shed
    counts reported alongside."""
    specs = [TenantSpec(name="strict", slo="strict", solve_budget_s=0.0,
                        arrivals=ArrivalModel(kind="poisson", rate_qps=40.0)),
             TenantSpec(name="be",
                        arrivals=ArrivalModel(kind="poisson", rate_qps=40.0))]
    reqs = multi_tenant_stream("tpch", specs, 4, seed=15)
    srv = OptimizerServer(config=ServerConfig(max_batch=4), weights=WEIGHTS,
                          cfg=CFG, tenants=specs)
    rep = srv.latency_report(srv.serve(reqs))
    assert rep["n_shed"] == 4 and rep["n_finished"] == 4
    assert rep["shed_rate"] == pytest.approx(0.5)
    for k in ("p50", "p99", "max", "mean"):
        assert math.isfinite(rep["plan_latency_s"][k])
        assert math.isfinite(rep["solve_latency_s"][k])
    assert math.isfinite(rep["fairness_jain"])        # strict tenant dropped
    assert 0.0 < rep["fairness_jain"] <= 1.0
    per = rep["tenants"]
    assert per["strict"]["n_shed"] == 4
    assert per["strict"]["goodput"] == 0.0
    assert math.isnan(per["strict"]["plan_latency_s"]["p99"])
    assert per["be"]["n_shed"] == 0
    assert math.isfinite(per["be"]["plan_latency_s"]["p99"])
    assert rep["goodput"] <= 0.5


def test_makespan_and_qps_ignore_rejection_timestamps():
    """A late rejection must not stretch the makespan (PR-9 bugfix): a
    shed request's finished_s is a rejection timestamp, not service, so a
    tail-shed stream whose last event is a rejection keeps the qps of the
    work actually served."""
    clock = ServiceTimeModel(flush_points=((1, 0.05), (8, 0.2)),
                             round_s=0.005, cheap_s=0.001)
    specs = [TenantSpec(name="strict", slo="strict", solve_budget_s=0.0),
             TenantSpec(name="be")]
    reqs = [StreamRequest(rid=i, query=make_query("tpch", i, variant=1),
                          arrival_s=0.0, tenant="be") for i in range(4)]
    reqs.append(StreamRequest(rid=4, query=make_query("tpch", 4, variant=1),
                              arrival_s=1000.0, tenant="strict"))
    srv = OptimizerServer(config=ServerConfig(max_batch=4, clock=clock),
                          weights=WEIGHTS, cfg=CFG, tenants=specs)
    served = srv.serve(reqs)
    assert [s.status for s in served] == ["served"] * 4 + ["shed"]
    assert served[-1].finished_s >= 1000.0             # rejection stamped
    st = srv.last_run
    assert st.n_finished == 4 and st.n_shed == 1
    last_served = max(s.finished_s for s in served[:4])
    assert st.makespan_s == pytest.approx(last_served)  # first arrival 0.0
    assert st.makespan_s < 100.0                        # not 1000+
    assert st.qps == pytest.approx(4 / st.makespan_s)
    assert srv.latency_report(served)["qps"] == st.qps


def test_service_time_model_worker_dimension():
    """Fleet co-location contention: every charged cost scales by the
    worker_scale multiplier at n_workers, and with_workers() re-prices
    the same calibration without touching it."""
    base = ServiceTimeModel(flush_points=((1, 0.1), (8, 0.4)), round_s=0.01,
                            cheap_s=0.002, worker_scale=((1, 1.0), (4, 1.25)))
    assert base.worker_mult() == pytest.approx(1.0)
    assert base.with_workers(2).worker_mult() == pytest.approx(1.0 + 0.25 / 3)
    four = base.with_workers(4)
    assert four.worker_mult() == pytest.approx(1.25)
    assert four.flush_s(1) == pytest.approx(base.flush_s(1) * 1.25)
    assert four.flush_s(4, 2) == pytest.approx(base.flush_s(4, 2) * 1.25)
    assert four.round_cost_s() == pytest.approx(base.round_s * 1.25)
    assert four.flush_points == base.flush_points       # calibration intact
    assert four.with_workers(1) == base                 # idempotent re-price
    # The single-knot default means no contention at any width.
    flat = ServiceTimeModel(flush_points=((1, 0.1),))
    assert flat.with_workers(8).flush_s(1) == pytest.approx(flat.flush_s(1))


def test_service_time_model_worker_validation():
    with pytest.raises(ValueError, match="worker-count knots"):
        ServiceTimeModel(flush_points=((1, 0.1),),
                         worker_scale=((1, 1.0), (1, 2.0)))
    with pytest.raises(ValueError, match="worker-count knots"):
        ServiceTimeModel(flush_points=((1, 0.1),), worker_scale=((0, 1.0),))
    with pytest.raises(ValueError, match="multipliers"):
        ServiceTimeModel(flush_points=((1, 0.1),), worker_scale=((1, 0.0),))
    with pytest.raises(ValueError, match="n_workers"):
        ServiceTimeModel(flush_points=((1, 0.1),)).with_workers(0)


def test_jain_index_ignores_nonfinite():
    from repro.serve import jain_index
    assert jain_index([1.0, 1.0, math.nan]) == pytest.approx(1.0)
    assert jain_index([2.0, math.inf, 2.0]) == pytest.approx(1.0)
    assert math.isnan(jain_index([math.nan]))
    assert math.isnan(jain_index([]))
    assert jain_index([1.0, 3.0]) == pytest.approx(16 / (2 * 10))


def test_query_seed_threads_through():
    base = serving_stream("tpch", 8, seed=2)
    same = serving_stream("tpch", 8, seed=2, query_seed=0)
    other = serving_stream("tpch", 8, seed=2, query_seed=9)
    assert [q.qid for q in base] == [q.qid for q in same]
    # Same template/variant choices, different query population.
    fp = lambda qs: [tuple(sq.out_rows for sq in q.subqs) for q in qs]
    assert fp(base) != fp(other)
