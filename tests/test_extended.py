"""Extended coverage: elastic resharding, HMOOC3⊆HMOOC1, windowed decode,
runtime step adaptation, pure-DP shardings."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.archs.common import param_specs
from repro.archs.registry import build_model, get_smoke_config
from repro.cluster.runtime_adapt import StepAdapter
from repro.core.moo.hmooc import _hmooc1_fixed_c, _hmooc3_extremes
from repro.core.moo.pareto import pareto_mask_np
from repro.launch.mesh import make_host_mesh
from repro.train.elastic import reshard_state


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 5), st.integers(2, 4), st.integers(2, 6),
       st.randoms(use_true_random=False))
def test_hmooc3_extremes_subset_of_exact_front(N, m, B, rnd):
    """Every HMOOC3 extreme point lies ON the exact per-θc Pareto front."""
    rng = np.random.default_rng(rnd.randint(0, 2**31))
    Fb = rng.random((N, m, B, 2)) * 10
    Ib = np.tile(np.arange(B), (N, m, 1))
    E, _ = _hmooc3_extremes(Fb, Ib)
    for c in range(N):
        full, _ = _hmooc1_fixed_c(Fb[c], Ib[c])
        for v in range(2):
            pt = E[c, v]
            on_front = np.any(np.all(np.isclose(full, pt, atol=1e-9), -1))
            assert on_front


def test_elastic_reshard_roundtrip():
    cfg = get_smoke_config("glm4-9b")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    params_shape = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    new_mesh = make_host_mesh((1, 1), ("data", "model"))
    moved = reshard_state(params, params_shape, new_mesh)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(moved)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_pure_dp_specs_have_no_model_axis():
    cfg = get_smoke_config("rwkv6-1.6b")
    api = build_model(cfg)
    shape = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    mesh = make_host_mesh((1, 1), ("data", "model"))
    specs = param_specs(shape, mesh, pure_dp=True)
    for s in jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: hasattr(x, "index")):
        for entry in s:
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            assert "model" not in axes or "data" in axes  # only via fsdp pair


@pytest.mark.slow
def test_windowed_decode_rolls():
    cfg = get_smoke_config("jamba-1.5-large-398b").with_(
        dtype="float32", window=8)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (1, 16)))
    cache = api.init_cache(1, 8)           # window-sized rolling cache
    lg, cache = api.forward(params, tokens, caches=cache)
    # Decode a few steps within the window.
    for t in range(16, 20):
        pos = jnp.full((1, 1), t)
        lg, cache = api.forward(params, tokens[:, :1], caches=cache,
                                positions=pos)
        assert np.isfinite(np.asarray(lg)).all()


def test_step_adapter_recommends_and_hysteresis():
    ad = StepAdapter(candidates=[1, 2, 4], min_gain=0.1, max_rejits=2)
    assert ad.recommend() is None
    for _ in range(3):
        ad.observe(4, 10.0)
    ad.observe(2, 5.0)                      # much faster
    ad.observe(4, 10.0)
    rec = ad.recommend()
    assert rec == 2
    # After exhausting the re-jit budget, stays put.
    ad._rejits = 2
    ad.observe(4, 50.0)
    assert ad.recommend() is None


@pytest.mark.slow
def test_rwkv_chunked_grad_matches_scan():
    cfg_s = get_smoke_config("rwkv6-1.6b").with_(dtype="float32",
                                                 rwkv_impl="scan")
    cfg_c = cfg_s.with_(rwkv_impl="chunked", rwkv_chunk=64)
    api_s, api_c = build_model(cfg_s), build_model(cfg_c)
    p = api_s.init(jax.random.PRNGKey(0))
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg_s.vocab, (1, 128)))
    batch = {"tokens": tokens, "labels": tokens}
    gs = jax.grad(api_s.loss)(p, batch)
    gc = jax.grad(api_c.loss)(p, batch)
    for a, b in zip(jax.tree_util.tree_leaves(gs),
                    jax.tree_util.tree_leaves(gc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-3)
