"""Cache correctness: LRU eviction, fingerprint stability, hit taxonomy.

Covers the three long-lived serving caches shared across micro-batches and
admission epochs: :class:`EffectiveSetCache`, :class:`CandidatePoolCache`,
and :class:`ResponseCache` — including their snapshot/restore contracts
(the fleet's process-external warm-start path).
"""
import pickle

import numpy as np
import pytest

from repro.core.moo.hmooc import HMOOCConfig, build_candidates
from repro.queryengine.workloads import make_query
from repro.serve import CandidatePoolCache, EffectiveSetCache, TuningService
from repro.serve.cache import query_fingerprint
from repro.serve.service import ResponseCache

CFG = HMOOCConfig(n_c_init=16, n_clusters=4, n_p_pool=48, n_c_enrich=12,
                  max_bank=12, seed=3)


# ---------------------------------------------------------------------------
# LRU eviction under capacity pressure
# ---------------------------------------------------------------------------

def test_effective_set_cache_lru_eviction():
    cache = EffectiveSetCache(max_entries=3)
    eset = build_candidates(4, 6, CFG)
    queries = [make_query("tpch", t) for t in range(5)]
    for q in queries[:3]:
        cache.store(q, CFG, eset)
    assert len(cache) == 3
    # Touch template 0 so template 1 becomes the LRU victim.
    assert cache.lookup(queries[0], CFG) is not None
    cache.store(queries[3], CFG, eset)
    assert len(cache) == 3
    assert cache.lookup(queries[1], CFG) is None        # evicted
    assert cache.lookup(queries[0], CFG) is not None    # recency preserved
    assert cache.lookup(queries[3], CFG) is not None
    # Storing an existing key replaces, never grows.
    cache.store(queries[3], CFG, eset)
    assert len(cache) == 3


def test_candidate_pool_cache_lru_eviction():
    cache = CandidatePoolCache(max_entries=2)
    p0 = cache.get(0, 8)
    cache.get(1, 8)
    cache.get(2, 8)                    # evicts (0, 8)
    assert len(cache) == 2
    assert cache.stats() == {"entries": 2, "hits": 0, "misses": 3}
    # Redraw after eviction is bit-identical — eviction never changes
    # results, only amortization.
    p0_again = cache.get(0, 8)
    assert cache.misses == 4
    np.testing.assert_array_equal(p0[0], p0_again[0])
    np.testing.assert_array_equal(p0[1], p0_again[1])
    # Recency: (0,8) touch above made (2,8) ... (0,8) the live set.
    cache.get(0, 8)
    assert cache.hits == 1


def test_response_cache_lru_and_stats():
    cache = ResponseCache(max_entries=2)
    cache.put("a", 1)
    cache.put("b", 2)
    assert cache.get("a") == 1          # refresh "a"; "b" is now LRU
    cache.put("c", 3)
    assert len(cache) == 2
    assert cache.get("b") is None       # evicted
    assert cache.get("a") == 1 and cache.get("c") == 3
    assert cache.stats() == {"entries": 2, "hits": 3, "misses": 1,
                             "model_evictions": 0}


def test_response_cache_shared_across_configs_is_safe():
    """A ResponseCache shared by differently-configured services must never
    cross-serve: the key includes cfg/cost/model, so each service solves
    and hits only its own entries."""
    other = HMOOCConfig(n_c_init=12, n_clusters=3, n_p_pool=32, n_c_enrich=8,
                        max_bank=8, seed=3)
    rc = ResponseCache()
    q = make_query("tpch", 3, variant=1)
    a = TuningService(cfg=CFG, response_cache=rc)
    b = TuningService(cfg=other, response_cache=rc)
    ra = a.tune_batch([q])[0]
    rb = b.tune_batch([q])[0]
    assert rc.misses == 2 and len(rc) == 2     # no cross-config hit
    # Warm replays hit only their own service's entry, exactly.
    ra2 = a.tune_batch([q])[0]
    rb2 = b.tune_batch([q])[0]
    assert rc.hits == 2
    np.testing.assert_array_equal(ra.front, ra2.front)
    np.testing.assert_array_equal(rb.front, rb2.front)
    np.testing.assert_array_equal(ra.theta_c, ra2.theta_c)
    np.testing.assert_array_equal(rb.theta_c, rb2.theta_c)


# ---------------------------------------------------------------------------
# Fingerprint stability
# ---------------------------------------------------------------------------

def test_query_fingerprint_stable_across_reconstructions():
    """Process-identical reconstructions (same generator inputs) must map
    to the same fingerprint — that is what makes cross-epoch exact hits
    sound — while any statistics perturbation must change it."""
    a = make_query("tpcds", 7, variant=2, seed=4)
    b = make_query("tpcds", 7, variant=2, seed=4)
    assert a is not b
    assert query_fingerprint(a) == query_fingerprint(b)
    assert query_fingerprint(a) != query_fingerprint(
        make_query("tpcds", 7, variant=3, seed=4))
    assert query_fingerprint(a) != query_fingerprint(
        make_query("tpcds", 7, variant=2, seed=5))
    # Sensitive to any single statistic the stage objectives read.
    import dataclasses
    c = make_query("tpcds", 7, variant=2, seed=4)
    sq = c.subqs[0]
    c.subqs[0] = dataclasses.replace(sq, out_rows=sq.out_rows + 1.0)
    assert query_fingerprint(c) != query_fingerprint(a)


# ---------------------------------------------------------------------------
# Structure-hit vs exact-hit distinction (reuse_banks_across_variants)
# ---------------------------------------------------------------------------

def _warm_and_lookup(reuse: bool):
    svc = TuningService(cfg=CFG, dedupe=False,
                        reuse_banks_across_variants=reuse)
    v1 = make_query("tpch", 3, variant=1)
    v2 = make_query("tpch", 3, variant=2)
    svc.tune_batch([v1])
    svc.tune_batch([v2])
    return svc


def test_structure_hit_vs_exact_hit_distinction():
    # Exact (default): a different variant of a cached template is a
    # structure hit — candidates reused, banks recomputed.
    svc = _warm_and_lookup(reuse=False)
    st = svc.cache.stats()
    assert st["structure_hits"] == 1 and st["approx_hits"] == 0
    # Approximate opt-in: the same traffic becomes an approx (bank-reuse)
    # hit instead.
    svc = _warm_and_lookup(reuse=True)
    st = svc.cache.stats()
    assert st["approx_hits"] == 1 and st["structure_hits"] == 0
    # Identical-query traffic is always an exact full hit in both modes.
    for reuse in (False, True):
        svc = TuningService(cfg=CFG, dedupe=False,
                            reuse_banks_across_variants=reuse)
        q = make_query("tpch", 3, variant=1)
        svc.tune_batch([q])
        svc.tune_batch([make_query("tpch", 3, variant=1)])
        assert svc.cache.stats()["hits"] == 1
        assert svc.cache.stats()["approx_hits"] == 0


def test_bank_reuse_not_restored_as_exact():
    """After an approximate cross-variant solve the stored fingerprint must
    still be the bank-origin query's: the variant must NOT later be served
    as an exact hit."""
    svc = _warm_and_lookup(reuse=True)
    v2 = make_query("tpch", 3, variant=2)
    svc.tune_batch([v2])
    st = svc.cache.stats()
    assert st["approx_hits"] == 2      # v2 again: still approximate
    assert st["hits"] == 0


# ---------------------------------------------------------------------------
# Multi-tenant cache isolation (tenant/preference key dimensions)
# ---------------------------------------------------------------------------

def test_peek_returns_banks_regardless_of_variant_policy():
    """The degraded-path probe: banks come back for any variant of a
    stored template (even with reuse_banks_across_variants=False), tagged
    exact only when the fingerprint matches; stats are tracked separately
    and the normal hit taxonomy is untouched."""
    cache = EffectiveSetCache(reuse_banks_across_variants=False)
    base = make_query("tpch", 2, variant=1)
    variant = make_query("tpch", 2, variant=2)
    svc = TuningService(cfg=CFG, cache=cache)
    assert cache.peek(base, CFG, svc.model, svc.cost) is None
    assert cache.stats()["peek_misses"] == 1
    svc.tune_batch([base], (0.9, 0.1))                 # stores banks
    eset, exact = cache.peek(base, CFG, svc.model, svc.cost)
    assert exact and eset.opt_idx is not None
    got = cache.peek(variant, CFG, svc.model, svc.cost)
    assert got is not None
    eset_v, exact_v = got
    assert not exact_v and eset_v.opt_idx is not None  # approximate reuse
    assert cache.stats()["peek_hits"] == 2
    # The normal lookup path still strips banks for the variant.
    assert cache.lookup(variant, CFG, svc.model, svc.cost).opt_idx is None
    assert cache.stats()["structure_hits"] == 1


def test_response_cache_isolates_tenants_with_different_weights():
    """Two tenants, byte-identical query structure, different preference
    vectors: neither may be served the other's weighted pick."""
    rc = ResponseCache()
    svc = TuningService(cfg=CFG, response_cache=rc)
    q = make_query("tpch", 5, variant=1)
    ra = svc.tune_batch([q], (0.9, 0.1), tenants=["a"])[0]
    rb = svc.tune_batch([q], (0.1, 0.9), tenants=["b"])[0]
    assert rc.hits == 0 and rc.misses == 2 and len(rc) == 2
    # The picks genuinely differ (different WUN choice under the weights)
    # or at minimum live under different entries; warm replays stay scoped.
    ra2 = svc.tune_batch([q], (0.9, 0.1), tenants=["a"])[0]
    rb2 = svc.tune_batch([q], (0.1, 0.9), tenants=["b"])[0]
    assert rc.hits == 2
    np.testing.assert_array_equal(ra.theta_c, ra2.theta_c)
    np.testing.assert_array_equal(rb.theta_c, rb2.theta_c)


def test_response_cache_isolates_tenants_even_with_same_weights():
    """The tenant id is its own key dimension: identical requests from
    different tenants never share an entry (structural no-leak guarantee,
    not merely a consequence of differing weights)."""
    rc = ResponseCache()
    svc = TuningService(cfg=CFG, response_cache=rc)
    q = make_query("tpch", 5, variant=1)
    ra = svc.tune_batch([q], (0.9, 0.1), tenants=["a"])[0]
    rb = svc.tune_batch([q], (0.9, 0.1), tenants=["b"])[0]
    assert rc.misses == 2 and rc.hits == 0 and len(rc) == 2
    # Isolation is structural, results still deterministic-identical.
    np.testing.assert_array_equal(ra.front, rb.front)
    # Same tenant, same request: exact hit.
    svc.tune_batch([q], (0.9, 0.1), tenants=["a"])
    assert rc.hits == 1


def test_same_tenant_keeps_hit_taxonomy():
    """Tenancy must not disturb the effective-set cache's exact/structure
    hit taxonomy — Algorithm 1 artifacts depend only on statistics and are
    safe to share across tenants."""
    svc = TuningService(cfg=CFG, dedupe=False)
    svc.tune_batch([make_query("tpch", 3, variant=1)], tenants=["a"])
    svc.tune_batch([make_query("tpch", 3, variant=1)], tenants=["a"])
    svc.tune_batch([make_query("tpch", 3, variant=2)], tenants=["a"])
    st = svc.cache.stats()
    assert st["hits"] == 1 and st["structure_hits"] == 1 \
        and st["approx_hits"] == 0
    # A second tenant's identical traffic also reuses the statistics-keyed
    # artifacts (no tenant data lives in them): variant 2 is now the stored
    # fingerprint, so tenant "b" gets an exact hit on it.
    svc.tune_batch([make_query("tpch", 3, variant=2)], tenants=["b"])
    assert svc.cache.stats()["hits"] == 2


def test_candidate_pool_cache_scope_isolation():
    cache = CandidatePoolCache()
    pa = cache.get(0, 8, scope="a")
    pb = cache.get(0, 8, scope="b")
    assert cache.misses == 2 and len(cache) == 2   # scoped entries
    # The draw ignores the scope: isolation costs storage, never results.
    np.testing.assert_array_equal(pa[0], pb[0])
    np.testing.assert_array_equal(pa[1], pb[1])
    assert cache.get(0, 8, scope="a") is pa and cache.hits == 1
    # Unscoped remains its own entry (anonymous single-stream traffic).
    cache.get(0, 8)
    assert cache.misses == 3


def test_tenants_arg_validated():
    svc = TuningService(cfg=CFG)
    q = make_query("tpch", 3, variant=1)
    with pytest.raises(ValueError, match="tenant ids"):
        svc.tune_batch([q], tenants=["a", "b"])


# ---------------------------------------------------------------------------
# Approx-hit shape guard (PR-9 bugfix)
# ---------------------------------------------------------------------------

def _query_with_other_shape(base):
    """A different variant of ``base``'s template whose plan has a
    different subQ count (the structure seed is not part of the template
    key, so such pairs share a cache entry)."""
    for seed in range(1, 64):
        q = make_query(base.benchmark, base.template, variant=2, seed=seed)
        if q.n_subqs != base.n_subqs:
            return q
    raise AssertionError("no differing-shape variant found")


def test_approx_hit_requires_matching_subq_count():
    """Cross-variant bank reuse is only shape-valid when the stored banks
    cover exactly the incoming query's subQ count — the same guard peek()
    enforces.  A shape-mismatched variant must fall back to a structure
    hit (candidates reused, banks stripped), never hand out banks indexed
    by another plan shape."""
    base = make_query("tpch", 3, variant=1, seed=0)
    other = _query_with_other_shape(base)
    cache = EffectiveSetCache(reuse_banks_across_variants=True)
    svc = TuningService(cfg=CFG, cache=cache, dedupe=False)
    svc.tune_batch([base])                             # stores banks
    got = cache.lookup(other, CFG, svc.model, svc.cost)
    assert got is not None and got.opt_idx is None     # banks stripped
    st = cache.stats()
    assert st["structure_hits"] == 1 and st["approx_hits"] == 0
    # Matching-shape variants still take the approximate path.
    same_shape = make_query("tpch", 3, variant=2, seed=0)
    assert same_shape.n_subqs == base.n_subqs
    assert cache.lookup(same_shape, CFG, svc.model,
                        svc.cost).opt_idx is not None
    assert cache.stats()["approx_hits"] == 1
    # And the shape-mismatched solve goes through cleanly end to end.
    svc.tune_batch([other])
    assert svc.last_batch.n_solved == 1


def test_candidate_pool_entries_are_immutable():
    """Cached pools are handed out by reference to every hit: an in-place
    mutation by one caller must raise instead of silently poisoning every
    other query and tenant sharing the draw (PR-9 bugfix)."""
    cache = CandidatePoolCache()
    pools = cache.get(0, 8)
    for a in pools:
        with pytest.raises(ValueError):
            a[0] = 0.0
    # The hit path returns the same frozen arrays.
    again = cache.get(0, 8)
    assert again is pools and cache.hits == 1
    for a in again:
        assert not a.flags.writeable
    # Consumers that need to modify must copy; the copy is writable.
    np.array(pools[0])[0] = 0.0


# ---------------------------------------------------------------------------
# Snapshot / restore (fleet warm-start contract)
# ---------------------------------------------------------------------------

def test_effective_set_cache_snapshot_restore_round_trip():
    q1 = make_query("tpch", 1, variant=1)
    q2 = make_query("tpch", 2, variant=1)
    svc = TuningService(cfg=CFG, dedupe=False)
    ref = svc.tune_batch([q1, q2])
    blob = svc.cache.snapshot()
    assert isinstance(blob, bytes)
    fresh = EffectiveSetCache()
    assert fresh.restore(blob) == 2 and len(fresh) == 2
    assert fresh.restore(blob) == 0                    # merge is idempotent
    # A service over the restored cache serves exact full hits,
    # bit-identical to the origin's solves.
    svc2 = TuningService(cfg=CFG, cache=fresh, dedupe=False)
    got = svc2.tune_batch([q1, q2])
    assert fresh.stats()["hits"] == 2 and fresh.stats()["misses"] == 0
    for g, r in zip(got, ref):
        np.testing.assert_array_equal(g.front, r.front)
        np.testing.assert_array_equal(g.theta_c, r.theta_c)
        assert g.choice == r.choice
    # max_entries is enforced from the cold end on restore.
    small = EffectiveSetCache(max_entries=1)
    assert small.restore(blob) == 2 and len(small) == 1


def test_restored_effective_sets_are_immutable():
    """Unpickling yields writable arrays, and a restored entry's arrays
    are shared by reference with every future hit — restore must re-freeze
    them, same as the pool cache (SN003 bug class)."""
    q = make_query("tpch", 1, variant=1)
    svc = TuningService(cfg=CFG, dedupe=False)
    svc.tune_batch([q])
    fresh = EffectiveSetCache()
    assert fresh.restore(svc.cache.snapshot()) == 1
    (entry,) = list(fresh._entries.values())
    es = entry.eset
    for a in (es.Uc, es.labels, es.reps, es.pool):
        assert not a.flags.writeable
        with pytest.raises(ValueError):
            a[...] = 0
    if es.opt_idx is not None:
        for bank in es.opt_idx:
            for idx in bank:
                assert not idx.flags.writeable


def test_effective_set_snapshot_excludes_id_pinned_entries():
    """Entries keyed by the id() fallback (models without a content
    fingerprint) are process-local by construction and must not travel;
    content-fingerprinted entries must."""
    class _NoFp:
        pass

    class _Fp:
        def fingerprint(self):
            return ("fp", 1)

    eset = build_candidates(4, 6, CFG)
    cache = EffectiveSetCache()
    cache.store(make_query("tpch", 0), CFG, eset, model=_NoFp())
    cache.store(make_query("tpch", 1), CFG, eset, model=_Fp())
    cache.store(make_query("tpch", 2), CFG, eset)      # no model: eligible
    fresh = EffectiveSetCache()
    assert fresh.restore(cache.snapshot()) == 2
    # The fingerprinted entry is addressable from a *different* live
    # object with the same content fingerprint.
    assert fresh.lookup(make_query("tpch", 1), CFG, _Fp()) is not None
    assert fresh.lookup(make_query("tpch", 0), CFG) is None


def test_candidate_pool_cache_snapshot_restore_round_trip():
    cache = CandidatePoolCache()
    p0 = cache.get(0, 8)
    cache.get(1, 8, scope="a")
    fresh = CandidatePoolCache()
    fresh.get(0, 8)                                    # existing entry wins
    assert fresh.restore(cache.snapshot()) == 1 and len(fresh) == 2
    hit = fresh.get(1, 8, scope="a")
    assert fresh.hits == 1                             # served from restore
    np.testing.assert_array_equal(hit[0], cache.get(1, 8, scope="a")[0])
    # Restored arrays are re-frozen.
    for a in hit:
        with pytest.raises(ValueError):
            a[0] = 0.0
    np.testing.assert_array_equal(fresh.get(0, 8)[0], p0[0])


def test_response_cache_snapshot_round_trip_serves_identically():
    rc = ResponseCache()
    svc = TuningService(cfg=CFG, response_cache=rc)
    q = make_query("tpch", 5, variant=1)
    ref = svc.tune_batch([q], (0.9, 0.1))[0]
    fresh = ResponseCache()
    assert fresh.restore(rc.snapshot()) == 1
    svc2 = TuningService(cfg=CFG, response_cache=fresh)
    got = svc2.tune_batch([q], (0.9, 0.1))[0]
    assert fresh.hits == 1 and fresh.misses == 0       # served from restore
    np.testing.assert_array_equal(got.front, ref.front)
    np.testing.assert_array_equal(got.theta_c, ref.theta_c)
    assert got.choice == ref.choice


def test_response_cache_snapshot_excludes_id_fallback_keys():
    """Response keys end with the model fingerprint; an int there is the
    id() fallback, meaningful only inside this process, and must stay
    home."""
    rc = ResponseCache()
    portable = ("t", "q1", 7, (0.9, 0.1), None, None, ("fp", 1))
    pinned = ("t", "q2", 7, (0.9, 0.1), None, None, 140234567)
    rc.put(portable, "portable")
    rc.put(pinned, "pinned")
    fresh = ResponseCache()
    assert fresh.restore(rc.snapshot()) == 1
    assert fresh.get(portable) == "portable"
    assert fresh.get(pinned) is None


def test_snapshot_blob_validation():
    eset_blob = EffectiveSetCache().snapshot()
    pools_blob = CandidatePoolCache().snapshot()
    # Kind mismatch: a pools blob cannot restore into an eset cache.
    with pytest.raises(ValueError, match="kind"):
        EffectiveSetCache().restore(pools_blob)
    with pytest.raises(ValueError, match="kind"):
        CandidatePoolCache().restore(eset_blob)
    with pytest.raises(ValueError, match="kind"):
        ResponseCache().restore(eset_blob)
    # Foreign and version-skewed blobs are rejected outright.
    with pytest.raises(ValueError, match="not a serving-cache snapshot"):
        EffectiveSetCache().restore(pickle.dumps({"format": "other"}))
    bad_ver = pickle.dumps({"format": "repro-cache-snapshot", "version": 99,
                            "kind": "eset", "entries": []})
    with pytest.raises(ValueError, match="version"):
        EffectiveSetCache().restore(bad_ver)
