"""Suite-wide fixtures and speedups.

* Smoke-model build caching: many tests rebuild the same smoke
  ``ArchConfig`` / ``ModelApi`` (both pure, stateless factories).  The
  registry functions are wrapped with session-lifetime memo tables here —
  conftest imports before any test module, so ``from repro.archs.registry
  import build_model`` inside tests binds the cached versions.
* ``slow`` marker: long-running end-to-end tests are excluded from tier-1
  by default (see pytest.ini ``addopts``); run them with ``-m slow``.
"""
from typing import Dict

import pytest

from repro.archs import registry as _registry

_orig_get_smoke_config = _registry.get_smoke_config
_orig_build_model = _registry.build_model

_cfg_cache: Dict[tuple, object] = {}
_model_cache: Dict[str, object] = {}


def _cached_get_smoke_config(arch_id, **overrides):
    key = (arch_id, tuple(sorted(overrides.items())))
    if key not in _cfg_cache:
        _cfg_cache[key] = _orig_get_smoke_config(arch_id, **overrides)
    return _cfg_cache[key]


def _cached_build_model(cfg):
    key = repr(cfg)
    if key not in _model_cache:
        _model_cache[key] = _orig_build_model(cfg)
    return _model_cache[key]


_registry.get_smoke_config = _cached_get_smoke_config
_registry.build_model = _cached_build_model


@pytest.fixture(scope="session")
def smoke_model_factory():
    """(arch_id, **overrides) -> (cfg, api), memoized for the session."""
    def factory(arch_id, **overrides):
        cfg = _cached_get_smoke_config(arch_id, **overrides)
        return cfg, _cached_build_model(cfg)

    return factory


_perf_model_cache: Dict[tuple, dict] = {}


def build_smoke_perf_models(n_queries: int = 8, n_conf: int = 6,
                            steps: int = 40) -> dict:
    """Tiny *trained* subQ/QS PerfModels for model-backed serving tests.

    One short training run per test session (memoized by size): enough
    optimization for the models to be a real learned backend — nonzero,
    input-sensitive predictions — while staying tier-1 fast.  The slow
    suite passes larger sizes for a better-fit variant.
    """
    key = (n_queries, n_conf, steps)
    if key not in _perf_model_cache:
        import dataclasses as _dc

        from repro.core.models.gtn import GTNConfig
        from repro.core.models.training import build_dataset, train_model
        from repro.queryengine.trace import collect_traces
        from repro.queryengine.workloads import default_workload

        queries = default_workload("tpch", 2)[:n_queries]
        traces = collect_traces(queries, n_conf, seed=0)
        gtn = GTNConfig(d_model=16, n_heads=2, n_layers=1, d_ff=32)
        models = {}
        for kind, seed in (("subq", 0), ("qs", 1)):
            ds, cfg = build_dataset(traces, kind)
            cfg = _dc.replace(cfg, gtn=gtn, hidden=(16,))
            models[kind] = train_model(ds, cfg, steps=steps, batch=128,
                                       seed=seed)
        _perf_model_cache[key] = models
    return _perf_model_cache[key]


@pytest.fixture(scope="session")
def smoke_perf_models():
    """{"subq": PerfModel, "qs": PerfModel}, trained once per session."""
    return build_smoke_perf_models()
