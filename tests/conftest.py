"""Suite-wide fixtures and speedups.

* Smoke-model build caching: many tests rebuild the same smoke
  ``ArchConfig`` / ``ModelApi`` (both pure, stateless factories).  The
  registry functions are wrapped with session-lifetime memo tables here —
  conftest imports before any test module, so ``from repro.archs.registry
  import build_model`` inside tests binds the cached versions.
* ``slow`` marker: long-running end-to-end tests are excluded from tier-1
  by default (see pytest.ini ``addopts``); run them with ``-m slow``.
"""
from typing import Dict

import pytest

from repro.archs import registry as _registry

_orig_get_smoke_config = _registry.get_smoke_config
_orig_build_model = _registry.build_model

_cfg_cache: Dict[tuple, object] = {}
_model_cache: Dict[str, object] = {}


def _cached_get_smoke_config(arch_id, **overrides):
    key = (arch_id, tuple(sorted(overrides.items())))
    if key not in _cfg_cache:
        _cfg_cache[key] = _orig_get_smoke_config(arch_id, **overrides)
    return _cfg_cache[key]


def _cached_build_model(cfg):
    key = repr(cfg)
    if key not in _model_cache:
        _model_cache[key] = _orig_build_model(cfg)
    return _model_cache[key]


_registry.get_smoke_config = _cached_get_smoke_config
_registry.build_model = _cached_build_model


@pytest.fixture(scope="session")
def smoke_model_factory():
    """(arch_id, **overrides) -> (cfg, api), memoized for the session."""
    def factory(arch_id, **overrides):
        cfg = _cached_get_smoke_config(arch_id, **overrides)
        return cfg, _cached_build_model(cfg)

    return factory
