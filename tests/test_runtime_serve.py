"""Runtime serving layer: batch bit-match, loop invariants, QS-model path."""
import numpy as np
import pytest

from repro.core.models.gtn import GTNConfig
from repro.core.models.perf_model import ModelConfig, PerfModel
from repro.core.moo import hmooc, pareto
from repro.core.moo.hmooc import HMOOCConfig
from repro.core.tuning.runtime import (make_runtime_optimizers,
                                       weighted_pick_batch)
from repro.queryengine.aqe import run_with_aqe
from repro.queryengine.simulator import default_theta
from repro.queryengine.workloads import make_benchmark, serving_stream
from repro.serve import CandidatePoolCache, RuntimeSession, TuningService

CFG = HMOOCConfig(n_c_init=16, n_clusters=4, n_p_pool=48, n_c_enrich=12,
                  max_bank=12, seed=3)
WEIGHTS = (0.9, 0.1)


@pytest.fixture(scope="module")
def stream():
    return serving_stream("tpch", 12, seed=1)


@pytest.fixture(scope="module")
def compiled(stream):
    return TuningService(cfg=CFG).tune_batch(stream, WEIGHTS)


def _loop_results(stream, compiled):
    out = []
    for q, ct in zip(stream, compiled):
        lqp_o, qs_o = make_runtime_optimizers(
            q, ct.theta_c, seed_theta_p=ct.theta_p_sub,
            seed_theta_s=ct.theta_s_sub, weights=WEIGHTS)
        out.append(run_with_aqe(q, ct.theta_c, ct.theta_p0, ct.theta_s0,
                                lqp_optimizer=lqp_o, qs_optimizer=qs_o))
    return out


# ---------------------------------------------------------------------------
# Tentpole: batched runtime session
# ---------------------------------------------------------------------------

def test_runtime_session_bitmatches_per_query(stream, compiled):
    """Fused serving output is bit-identical to the per-query loop
    (oracle backend): same θ_eff, joins, requests, and simulated outcome."""
    ref = _loop_results(stream, compiled)
    got = RuntimeSession(weights=WEIGHTS).run_batch(stream, compiled)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a.theta_p_eff, b.theta_p_eff)
        np.testing.assert_array_equal(a.theta_s_eff, b.theta_s_eff)
        np.testing.assert_array_equal(a.final_join, b.final_join)
        assert a.lqp_requests_sent == b.lqp_requests_sent
        assert a.qs_requests_sent == b.qs_requests_sent
        assert a.requests_total == b.requests_total
        np.testing.assert_array_equal(a.sim.ana_latency, b.sim.ana_latency)
        np.testing.assert_array_equal(a.sim.actual_latency,
                                      b.sim.actual_latency)
        np.testing.assert_array_equal(a.sim.io_gb, b.sim.io_gb)
        np.testing.assert_array_equal(a.sim.cost, b.sim.cost)


def test_runtime_session_stats_and_pool_reuse(stream, compiled):
    cache = CandidatePoolCache()
    sess = RuntimeSession(weights=WEIGHTS, pool_cache=cache)
    res = sess.run_batch(stream, compiled)
    st = sess.last_batch
    assert st.n_queries == len(stream)
    assert st.requests_sent == sum(r.requests_sent for r in res)
    assert 0.0 <= st.prune_rate <= 1.0
    # One LHS draw shared across every query in the batch.
    assert cache.misses == 1 and cache.hits == len(stream) - 1
    # Fusion actually happened: far fewer backend calls than requests.
    assert st.fused_calls < st.requests_sent


def test_tune_and_run_pipeline(stream):
    svc = TuningService(cfg=CFG)
    sess = RuntimeSession(weights=WEIGHTS)
    cts, res = sess.tune_and_run(stream, svc)
    assert len(cts) == len(res) == len(stream)
    for r in res:
        assert np.isfinite(r.sim.actual_latency).all()


# ---------------------------------------------------------------------------
# Runtime loop invariants
# ---------------------------------------------------------------------------

def test_aqe_never_demotes_planned_broadcast(stream, compiled):
    """AQE convertibility: the realized algorithm is never below the
    submission-planned one for any join, with or without re-tuning."""
    from repro.queryengine.simulator import plan_joins
    for res, q, ct in zip(RuntimeSession(weights=WEIGHTS)
                          .run_batch(stream, compiled), stream, compiled):
        planned = plan_joins(q, np.tile(ct.theta_p0, (q.n_subqs, 1))[None],
                             from_estimates=True)[0]
        for sq in q.subqs:
            if sq.kind == "join":
                assert res.final_join[sq.sq_id] >= planned[sq.sq_id]
            else:
                assert res.final_join[sq.sq_id] == -1.0


def test_prune_rate_bounds(stream):
    tc, tp, ts = default_theta(1)
    for q in stream:
        r = run_with_aqe(q, tc[0], tp[0], ts[0], prune=True)
        assert 0.0 <= r.prune_rate <= 1.0
        assert r.requests_sent <= r.requests_total
        r2 = run_with_aqe(q, tc[0], tp[0], ts[0], prune=False)
        assert r2.requests_sent >= r.requests_sent
        assert r2.requests_sent <= r2.requests_total


# ---------------------------------------------------------------------------
# QS-model path (bugfix: the runtime QS model used to be dead code)
# ---------------------------------------------------------------------------

def _smoke_models():
    gtn = GTNConfig(d_model=16, n_heads=2, n_layers=1, d_ff=32)
    msub = PerfModel(ModelConfig(kind="subq", theta_dim=19, gtn=gtn,
                                 hidden=(16,)), seed=0)
    mqs = PerfModel(ModelConfig(kind="qs", theta_dim=10, gtn=gtn,
                                hidden=(16,)), seed=1)
    return msub, mqs


class _Spy:
    def __init__(self, model):
        self.model = model
        self.calls = 0
        self._orig = model.predict
        model.predict = self._wrapped

    def _wrapped(self, *a, **kw):
        self.calls += 1
        return self._orig(*a, **kw)


def test_qs_model_drives_theta_s_decisions():
    q = make_benchmark("tpch")[8]
    msub, mqs = _smoke_models()
    spy_sub, spy_qs = _Spy(msub), _Spy(mqs)
    tc = default_theta(1)[0][0]
    lqp_o, qs_o = make_runtime_optimizers(
        q, tc, model_subq=msub, model_qs=mqs, weights=WEIGHTS,
        n_candidates=8)
    join = next(sq for sq in q.subqs if sq.kind == "join")
    ts = qs_o(query=q, subq=join, theta_c=tc,
              theta_s=default_theta(1)[2][0])
    assert ts.shape == (2,) and np.isfinite(ts).all()
    assert spy_qs.calls == 1          # θs decision goes to the QS model
    assert spy_sub.calls == 0
    tp = lqp_o(query=q, subq=join, theta_c=tc,
               theta_p=default_theta(1)[1][0])
    assert tp.shape == (9,) and np.isfinite(tp).all()
    assert spy_sub.calls == 1         # θp decision goes to the subQ model
    assert spy_qs.calls == 1


def test_runtime_model_backend_end_to_end(stream, compiled):
    """Model-backed session runs and matches the model-backed per-query
    loop (same models, same seeds → same decisions)."""
    msub, mqs = _smoke_models()
    sub = stream[:4]
    cts = compiled[:4]
    ref = []
    for q, ct in zip(sub, cts):
        lqp_o, qs_o = make_runtime_optimizers(
            q, ct.theta_c, seed_theta_p=ct.theta_p_sub,
            seed_theta_s=ct.theta_s_sub, model_subq=msub, model_qs=mqs,
            weights=WEIGHTS)
        ref.append(run_with_aqe(q, ct.theta_c, ct.theta_p0, ct.theta_s0,
                                lqp_optimizer=lqp_o, qs_optimizer=qs_o))
    got = RuntimeSession(model_subq=msub, model_qs=mqs,
                         weights=WEIGHTS).run_batch(sub, cts)
    for a, b in zip(ref, got):
        assert a.requests_sent == b.requests_sent
        np.testing.assert_allclose(a.theta_p_eff, b.theta_p_eff)
        np.testing.assert_allclose(a.theta_s_eff, b.theta_s_eff)
        np.testing.assert_array_equal(a.final_join, b.final_join)


# ---------------------------------------------------------------------------
# Kernel routing parity for the runtime pick
# ---------------------------------------------------------------------------

def test_weighted_pick_batch_kernel_matches_numpy(monkeypatch):
    rng = np.random.default_rng(0)
    Fs = [(rng.random((n, 2)) * 10).astype(np.float32).astype(np.float64)
          for n in (5, 66, 130, 257)]
    ref = weighted_pick_batch(Fs, WEIGHTS)
    monkeypatch.setattr(pareto, "_KERNEL_MIN_N", 0)
    monkeypatch.setattr(hmooc, "_WS_MIN_SCORES", 0)
    got = weighted_pick_batch(Fs, WEIGHTS)
    assert got == ref
