"""Fairness/starvation properties of multi-tenant admission.

Property sweeps (via ``_hypothesis_compat``: real hypothesis when
installed, fixed-seed sweep otherwise) drive :class:`TenantScheduler`
directly with synthetic items — no solver in the loop — so adversarial
tenant mixes are cheap to explore:

* conservation — per-tenant batch-slot accounting sums exactly to every
  batch's size, nothing is lost or double-counted, per-tenant FIFO order
  is preserved;
* no starvation — whatever the priority/share mix, a tenant's head
  request is composed into the very next batch once its deadline passes
  (overdue promotion outranks priority tiers), so no tenant waits
  unboundedly while another flushes;
* weighted fairness — deficit-round-robin long-run batch shares track the
  configured share ratios;
* the per-query reserve EWMA regression (PR-4 bugfix): one large batch
  must not inflate the deadline reserve applied to subsequent small
  batches.

Server-level tests then check single-tenant traffic through the
multi-tenant machinery reproduces the anonymous PR-3 path bit-identically,
and that mixed-tenant streams serve every request with conserved
accounting.
"""
import math

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.moo.hmooc import HMOOCConfig
from repro.queryengine.workloads import (ArrivalModel, StreamRequest,
                                         TenantSpec, multi_tenant_stream,
                                         serving_stream)
from repro.serve import (OptimizerServer, RuntimeSession, ServerConfig,
                         TenantScheduler, TuningService)

import dataclasses

CFG = HMOOCConfig(n_c_init=16, n_clusters=4, n_p_pool=48, n_c_enrich=12,
                  max_bank=12, seed=3)
WEIGHTS = (0.9, 0.1)


def _random_specs(rng, n_tenants):
    return [TenantSpec(name=f"t{i}",
                       share=float(rng.choice([0.5, 1.0, 2.0, 3.0])),
                       priority=int(rng.integers(0, 3)),
                       solve_budget_s=float(rng.choice([0.5, 1.0, 2.0])))
            for i in range(n_tenants)]


# ---------------------------------------------------------------------------
# Scheduler properties (synthetic items, no solver)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 4), st.integers(1, 8))
def test_conservation_and_fifo(seed, n_tenants, cap):
    """Random mixes: every batch's size equals the sum of per-tenant slot
    grants, nothing is lost, and each tenant drains in FIFO order."""
    rng = np.random.default_rng(seed)
    specs = _random_specs(rng, n_tenants)
    sched = TenantScheduler(specs, budget_s=1.0, reserve_q_s=0.1)
    n_items = int(rng.integers(1, 30))
    enq = {s.name: [] for s in specs}
    t = 0.0
    for k in range(n_items):
        t += float(rng.exponential(0.05))
        name = specs[int(rng.integers(0, n_tenants))].name
        sched.enqueue(name, ("item", name, k), t)
        enq[name].append(("item", name, k))
    deq = {s.name: [] for s in specs}
    now = t
    n_flushes = 0
    while sched.total_waiting():
        n_flushes += 1
        assert n_flushes < 10 * n_items + 10, "scheduler failed to drain"
        before = {s.name: s.slots_granted for s in sched.states()}
        picked = sched.compose(now, cap)
        assert 0 < len(picked) <= cap
        grants = {s.name: s.slots_granted - before[s.name]
                  for s in sched.states()}
        assert sum(grants.values()) == len(picked)       # conservation
        for name, item, _ in picked:
            deq[name].append(item)
        now += 0.01
    assert deq == enq                                    # FIFO per tenant
    for s in sched.states():
        assert s.n_dequeued == s.n_enqueued == len(enq[s.name])


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 6))
def test_no_starvation_overdue_beats_priority(seed, cap):
    """A low-priority head whose deadline has passed is composed into the
    very next batch, no matter how much higher-priority work floods in."""
    rng = np.random.default_rng(seed)
    low = TenantSpec(name="low", priority=0,
                     share=float(rng.choice([0.5, 1.0])),
                     solve_budget_s=1.0)
    high = TenantSpec(name="high", priority=int(rng.integers(1, 4)),
                      share=3.0, solve_budget_s=10.0)
    sched = TenantScheduler([low, high], reserve_q_s=0.0)
    sched.enqueue("low", "starved", 0.0)
    for k in range(50):
        sched.enqueue("high", f"h{k}", 0.0)
    # Before low's deadline, priority preempts: batches are pure high.
    picked = sched.compose(0.5, cap)
    assert all(name == "high" for name, _, _ in picked)
    # At/after the deadline the low head is promoted ahead of every tier.
    picked = sched.compose(1.0, cap)
    assert picked[0] == ("low", "starved", False)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 4), st.integers(1, 4))
def test_drr_shares_track_configured_ratio(seed, share_a, share_b):
    """Two saturated same-tier tenants split batch slots ~ share_a:share_b
    (no overdue promotion in play: budgets far in the future)."""
    del seed
    a = TenantSpec(name="a", share=float(share_a), solve_budget_s=1e9)
    b = TenantSpec(name="b", share=float(share_b), solve_budget_s=1e9)
    sched = TenantScheduler([a, b], reserve_q_s=0.0)
    n = 50 * (share_a + share_b)
    for k in range(n):
        sched.enqueue("a", k, 0.0)
        sched.enqueue("b", k, 0.0)
    grants = []
    while len(grants) < n:
        grants.extend(name for name, _, _ in sched.compose(0.0, 8))
    got_a = grants[:n].count("a")
    want_a = n * share_a / (share_a + share_b)
    # DRR quantization error is bounded by one quantum per pass.
    assert abs(got_a - want_a) <= 8 + share_a + share_b


def test_tiny_share_composes_in_bounded_passes():
    """A valid-but-minuscule share must not stall composition: credits are
    normalized per pass by the tier's largest share, so each slot costs
    O(1) passes even at share=1e-9 (regression: the unnormalized loop
    needed ~1/share passes)."""
    sched = TenantScheduler([TenantSpec(name="tiny", share=1e-9,
                                        solve_budget_s=1e9)],
                            reserve_q_s=0.0)
    for k in range(4):
        sched.enqueue("tiny", k, 0.0)
    assert [i for _, i, _ in sched.compose(0.0, 4)] == [0, 1, 2, 3]
    # Ratios still respected when a tiny share competes with a normal one.
    sched2 = TenantScheduler([TenantSpec(name="tiny", share=1e-9,
                                         solve_budget_s=1e9),
                              TenantSpec(name="big", share=1.0,
                                         solve_budget_s=1e9)],
                             reserve_q_s=0.0)
    for k in range(20):
        sched2.enqueue("tiny", k, 0.0)
        sched2.enqueue("big", k, 0.0)
    grants = [n for n, _, _ in sched2.compose(0.0, 8)]
    assert grants.count("big") >= 7       # tiny earns ≪ one slot per pass


def test_priority_tier_preempts_composition():
    sched = TenantScheduler([TenantSpec(name="hi", priority=2,
                                        solve_budget_s=1e9),
                             TenantSpec(name="lo", priority=0,
                                        solve_budget_s=1e9)],
                            reserve_q_s=0.0)
    for k in range(6):
        sched.enqueue("hi", k, 0.0)
        sched.enqueue("lo", k, 0.0)
    picked = sched.compose(0.0, 4)
    assert [name for name, _, _ in picked] == ["hi"] * 4
    # Once the high tier drains, the low tier gets the whole batch.
    sched.compose(0.0, 2)
    picked = sched.compose(0.0, 4)
    assert [name for name, _, _ in picked] == ["lo"] * 4


def test_unknown_tenant_auto_registered_with_defaults():
    sched = TenantScheduler([], budget_s=2.0, reserve_q_s=0.125)
    sched.enqueue("walk-in", "x", 1.0)
    st_ = sched.state("walk-in")
    assert st_.budget_s == 2.0 and st_.reserve_q_s == 0.125
    assert st_.weights is None and st_.priority == 0
    assert st_.slo == "best_effort"
    assert sched.compose(100.0, 4) == [("walk-in", "x", False)]


def test_duplicate_tenant_specs_rejected():
    with pytest.raises(ValueError, match="duplicate"):
        TenantScheduler([TenantSpec(name="a"), TenantSpec(name="a")])


# ---------------------------------------------------------------------------
# Overload triage: SLO classes, shed/degrade decisions (PR-5 tentpole)
# ---------------------------------------------------------------------------

def test_shed_unmeetable_pops_strict_only():
    """Only strict-SLO tenants shed; degrade/best_effort heads stay queued
    (degrade is handled at compose time, best_effort keeps waiting)."""
    sched = TenantScheduler(
        [TenantSpec(name="s", slo="strict", solve_budget_s=0.1),
         TenantSpec(name="d", slo="degrade", solve_budget_s=0.1),
         TenantSpec(name="b", slo="best_effort", solve_budget_s=0.1)],
        reserve_q_s=0.05)
    for name in ("s", "d", "b"):
        sched.enqueue(name, f"{name}0", 0.0)
        sched.enqueue(name, f"{name}1", 0.0)
    shed = sched.shed_unmeetable(10.0, cap=8)       # way past every budget
    assert shed == [("s", "s0"), ("s", "s1")]
    st = sched.state("s")
    assert st.n_shed == 2 and st.waiting == 0
    assert st.slots_granted == 0                    # shed ≠ batch slots
    # The others were untouched and compose with the right degrade flags.
    picked = sched.compose(10.0, cap=8)
    assert sorted((n, i, g) for n, i, g in picked) == [
        ("b", "b0", False), ("b", "b1", False),
        ("d", "d0", True), ("d", "d1", True)]
    assert sched.state("d").n_degraded == 2
    assert sched.state("b").n_degraded == 0


def test_shed_respects_expected_batch_scaling():
    """Unmeetable is `arrival + budget − reserve·E[n] < now` — with a big
    backlog the expected solve is longer, so heads shed earlier; and the
    expected size is re-derived as the pool drains, so shedding stops as
    soon as the remaining batch became small enough to meet the budget."""
    sched = TenantScheduler([TenantSpec(name="s", slo="strict",
                                        solve_budget_s=0.8)],
                            reserve_q_s=0.25)
    for k in range(4):
        sched.enqueue("s", k, 0.0)
    # E[4]: deadline = 0.8 − 4·0.25 = −0.2 < 0.05 → shed the head.  After
    # one shed E[3]: deadline = 0.8 − 0.75 = 0.05, NOT strictly < now →
    # the rest are meetable and must survive.
    shed = sched.shed_unmeetable(0.05, cap=8)
    assert [i for _, i in shed] == [0]
    assert sched.state("s").waiting == 3


def test_degrade_flag_sized_to_the_batch_being_composed():
    """The degrade check's E[n] counts already-picked slots plus the
    remaining pool: every member of one compose shares one flush window,
    so if the 4-item batch blows the budget, *all four* are admitted
    degraded — a remaining-pool-only E[n] would mark just the first and
    burn full solves into an already-blown budget."""
    sched = TenantScheduler([TenantSpec(name="d", slo="degrade",
                                        solve_budget_s=0.8)],
                            reserve_q_s=0.25)
    for k in range(4):
        sched.enqueue("d", k, 0.0)
    # E[n]=4 throughout: deadline = 0.8 − 4·0.25 = −0.2 < 0.05 for every
    # member of the batch.
    picked = sched.compose(0.05, cap=8)
    assert [i for _, i, _ in picked] == [0, 1, 2, 3]    # FIFO preserved
    assert [g for _, _, g in picked] == [True, True, True, True]
    assert sched.state("d").n_degraded == 4
    # A later, genuinely smaller batch is meetable again: nothing sticky.
    sched.enqueue("d", 4, 10.0)
    assert sched.compose(10.0, cap=8) == [("d", 4, False)]


def test_meetable_degrade_tenant_not_degraded():
    sched = TenantScheduler([TenantSpec(name="d", slo="degrade",
                                        solve_budget_s=10.0)],
                            reserve_q_s=0.1)
    sched.enqueue("d", "x", 0.0)
    assert sched.compose(0.0, cap=4) == [("d", "x", False)]
    assert sched.state("d").n_degraded == 0


def test_slo_class_validated():
    with pytest.raises(ValueError, match="SLO class"):
        TenantSpec(name="x", slo="bogus")


# ---------------------------------------------------------------------------
# DRR credit double-dip (PR-5 bugfix): overdue pops charge the deficit
# ---------------------------------------------------------------------------

def test_overdue_pop_consumes_banked_credit():
    """A tenant served via overdue promotion must pay for the slot out of
    its banked DRR credit (floored at the standard empty-queue reset of
    0), not keep it for a double-dip on the next normal pass."""
    a = TenantSpec(name="a", solve_budget_s=1.0)
    b = TenantSpec(name="b", solve_budget_s=1e9)
    sched = TenantScheduler([a, b], reserve_q_s=0.0)
    for k in range(4):
        sched.enqueue("a", f"a{k}", 0.0)
        sched.enqueue("b", f"b{k}", 100.0)
    sched.state("a").deficit = 1.0          # banked from earlier passes
    # a's head is overdue at t=2: promoted — and the banked credit is
    # spent by the promotion.
    picked = sched.compose(2.0, cap=2)
    assert picked[0].tenant == "a"
    assert sched.state("a").deficit == 0.0
    # Floor at the standard reset: promotion never drives credit negative.
    sched.state("a").deficit = 0.25
    picked = sched.compose(2.0, cap=1)
    assert picked[0].tenant == "a" and sched.state("a").deficit == 0.0


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 4), st.integers(1, 6))
def test_bursty_overdue_traffic_properties(seed, n_tenants, cap):
    """Fairness properties under bursty-*overdue* traffic: random mixes
    where a fraction of every tenant's arrivals are long past their budget
    (so overdue promotion, the deficit charge, and the drain-aware E[n]
    all exercise every compose).  Invariants: slot conservation, per-tenant
    FIFO, DRR credit never negative (promotion charges floor at the
    standard reset), and an emptied queue always resets its credit."""
    rng = np.random.default_rng(seed)
    specs = _random_specs(rng, n_tenants)
    sched = TenantScheduler(specs, budget_s=0.5, reserve_q_s=0.1)
    now = 100.0
    enq = {s.name: [] for s in specs}
    n_items = int(rng.integers(2, 40))
    for k in range(n_items):
        name = specs[int(rng.integers(0, n_tenants))].name
        # ~half the arrivals are stale: overdue (promoted) at compose time.
        arrival = 0.0 if rng.random() < 0.5 else now + 1.0
        sched.enqueue(name, ("item", name, k), arrival)
        enq[name].append(("item", name, k))
    deq = {s.name: [] for s in specs}
    n_flushes = 0
    while sched.total_waiting():
        n_flushes += 1
        assert n_flushes < 10 * n_items + 10, "scheduler failed to drain"
        before = {s.name: s.slots_granted for s in sched.states()}
        picked = sched.compose(now, cap)
        assert 0 < len(picked) <= cap
        grants = {s.name: s.slots_granted - before[s.name]
                  for s in sched.states()}
        assert sum(grants.values()) == len(picked)       # conservation
        for s in sched.states():
            assert s.deficit >= 0.0                      # charge floored
            if not s.queue:
                assert s.deficit == 0.0                  # standard reset
        for name, item, _ in picked:
            deq[name].append(item)
    assert deq == enq                                    # FIFO per tenant


# ---------------------------------------------------------------------------
# Per-query reserve EWMA (regression: batch size used to be ignored)
# ---------------------------------------------------------------------------

def test_reserve_normalized_per_query():
    """One large batch must not inflate the reserve applied to a later
    single-query flush: the EWMA tracks dt/n, not raw batch dt."""
    sched = TenantScheduler([], budget_s=1.0, reserve_q_s=0.25,
                            reserve_ewma=0.3)
    sched.note_solve(8.0, 8, ["a"])            # 1.0 s per query
    st_ = sched.state("a")
    assert st_.reserve_q_s == pytest.approx(0.7 * 0.25 + 0.3 * 1.0)
    # The buggy whole-batch EWMA would have been 0.7*0.25 + 0.3*8.0 = 2.575,
    # pushing a single waiting query's deadline before its own arrival.
    sched.enqueue("a", "x", arrival_s=10.0)
    dl = sched.next_deadline(cap=8)
    assert dl == pytest.approx(10.0 + 1.0 - st_.reserve_q_s)
    assert dl > 10.0                            # still after arrival
    # With more waiting, the deadline scales the per-query reserve back up
    # by the expected batch size.
    for k in range(3):
        sched.enqueue("a", k, arrival_s=10.0)
    assert sched.next_deadline(cap=8) == pytest.approx(
        10.0 + 1.0 - 4 * st_.reserve_q_s)


def test_reserve_tracks_full_charged_window():
    """Regression (PR-5): the reserve EWMA must be fed the *full* flush
    window the simulated clock charges — the batched solve plus each
    query's initial AQE planning step inside ``session.admit()`` — not
    just the ``tune_batch`` slice.  Replaying the EWMA over the recorded
    per-flush clock charges must land exactly on the live reserve, which
    is therefore ≥ the charged per-query clock cost folded at the EWMA
    rate (the old under-measurement made it strictly smaller)."""
    cfg = ServerConfig(max_batch=4, solve_reserve_s=0.0)
    srv = OptimizerServer(config=cfg, weights=WEIGHTS, cfg=CFG)
    stream = serving_stream("tpch", 10, seed=12,
                            arrivals=ArrivalModel(kind="poisson",
                                                  rate_qps=40.0))
    srv.serve(stream)
    windows = srv.last_run.flush_windows
    assert len(windows) >= 2
    a = srv.scheduler.reserve_ewma
    replay = cfg.solve_reserve_s
    for dt, n in windows:
        assert dt > 0 and n > 0
        replay = (1 - a) * replay + a * dt / n
    got = srv.scheduler.state("default").reserve_q_s
    assert got == pytest.approx(replay, rel=1e-9)
    assert srv.scheduler.default_reserve_q_s == pytest.approx(replay,
                                                              rel=1e-9)
    # Convexity: an EWMA of per-query charges (seeded at 0) dominates the
    # smallest charged per-query cost scaled by the folded-in weight — the
    # "reserve ≥ charged per-query clock cost" convergence guarantee.
    min_q = min(dt / n for dt, n in windows)
    assert got >= (1 - (1 - a) ** len(windows)) * min_q


def test_reserve_scales_only_own_tenant():
    sched = TenantScheduler([], budget_s=1.0, reserve_q_s=0.2)
    sched.note_solve(4.0, 4, ["a"])
    assert sched.state("a").reserve_q_s > 0.2
    # Fresh tenants seed from the updated global default, not the old seed.
    assert sched.state("b").reserve_q_s == sched.default_reserve_q_s


# ---------------------------------------------------------------------------
# Server level: single-tenant ≡ PR-3, mixed mixes all served + conserved
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def solo_stream():
    return serving_stream("tpch", 10, seed=4,
                          arrivals=ArrivalModel(kind="poisson",
                                                rate_qps=40.0))


def test_single_tenant_reproduces_anonymous_path(solo_stream):
    """The same stream served anonymously and under a named single tenant
    (same weights) yields bit-identical outputs and identical admission
    accounting — the multi-tenant machinery is a no-op at n_tenants=1."""
    anon = OptimizerServer(config=ServerConfig(max_batch=4), weights=WEIGHTS,
                           cfg=CFG)
    a = anon.serve(solo_stream)
    named_reqs = [dataclasses.replace(r, tenant="alice")
                  for r in solo_stream]
    named = OptimizerServer(
        config=ServerConfig(max_batch=4), weights=WEIGHTS, cfg=CFG,
        tenants=[TenantSpec(name="alice", weights=WEIGHTS)])
    b = named.serve(named_reqs)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x.result.theta_p_eff,
                                      y.result.theta_p_eff)
        np.testing.assert_array_equal(x.result.theta_s_eff,
                                      y.result.theta_s_eff)
        np.testing.assert_array_equal(x.result.final_join,
                                      y.result.final_join)
        np.testing.assert_array_equal(x.result.sim.cost, y.result.sim.cost)
    # (Batch *composition* depends on measured wall time and may differ
    # run to run; the invariant is that outputs and accounting do not.)
    assert sum(anon.last_run.tenant_slots.values()) == len(solo_stream)
    assert named.last_run.tenant_slots == {"alice": len(solo_stream)}


def test_mixed_tenant_stream_all_served_and_conserved():
    specs = [TenantSpec(name="a", weights=(0.9, 0.1), share=2.0,
                        arrivals=ArrivalModel(rate_qps=30.0)),
             TenantSpec(name="b", weights=(0.5, 0.5), priority=1,
                        arrivals=ArrivalModel(rate_qps=30.0)),
             TenantSpec(name="c", arrivals=ArrivalModel(rate_qps=15.0),
                        solve_budget_s=0.5)]
    reqs = multi_tenant_stream("tpch", specs, [5, 4, 3], seed=6)
    assert len(reqs) == 12
    assert [r.rid for r in reqs] == list(range(12))
    srv = OptimizerServer(config=ServerConfig(max_batch=4), weights=WEIGHTS,
                          cfg=CFG, tenants=specs)
    served = srv.serve(reqs)
    assert all(s.result is not None for s in served)
    assert all(math.isfinite(s.finished_s) for s in served)
    # Slot accounting conserves across the whole run.
    assert sum(srv.last_run.tenant_slots.values()) == len(reqs)
    assert srv.last_run.tenant_slots == {"a": 5, "b": 4, "c": 3}
    rep = srv.latency_report(served)
    assert set(rep["tenants"]) == {"a", "b", "c"}
    assert 0.0 < rep["fairness_jain"] <= 1.0
    # Tenant "c" (no weights configured) fell back to the server default.
    assert srv.tenant_weights("c") == WEIGHTS


def test_serve_refuses_nonempty_admission_queue(solo_stream):
    srv = OptimizerServer(config=ServerConfig(max_batch=4), weights=WEIGHTS,
                          cfg=CFG)
    srv.scheduler.enqueue("default", "stray", 0.0)
    with pytest.raises(RuntimeError, match="admission queue"):
        srv.serve(solo_stream)


def test_multi_tenant_stream_validation():
    with pytest.raises(ValueError, match="duplicate tenant"):
        multi_tenant_stream("tpch", [TenantSpec(name="x"),
                                     TenantSpec(name="x")], 2)
    with pytest.raises(ValueError, match="counts"):
        multi_tenant_stream("tpch", [TenantSpec(name="x")], [1, 2])
    with pytest.raises(ValueError, match="share"):
        TenantSpec(name="x", share=0.0)
    with pytest.raises(ValueError, match="non-empty"):
        TenantSpec(name="")


def test_multi_tenant_stream_reproducible_and_independent():
    specs = [TenantSpec(name="a", arrivals=ArrivalModel(rate_qps=10.0)),
             TenantSpec(name="b", arrivals=ArrivalModel(rate_qps=10.0))]
    r1 = multi_tenant_stream("tpch", specs, 6, seed=9)
    r2 = multi_tenant_stream("tpch", specs, 6, seed=9)
    assert [(r.tenant, r.arrival_s, r.query.qid) for r in r1] == \
           [(r.tenant, r.arrival_s, r.query.qid) for r in r2]
    times = [r.arrival_s for r in r1]
    assert times == sorted(times)
    # Tenants draw distinct populations/timings (independent seed streams).
    a = [r.query.qid for r in r1 if r.tenant == "a"]
    b = [r.query.qid for r in r1 if r.tenant == "b"]
    assert a != b
    assert all(isinstance(r, StreamRequest) for r in r1)


# ---------------------------------------------------------------------------
# Token-bucket properties (PR-8: per-tenant rate limiting at the door)
# ---------------------------------------------------------------------------

from repro.serve import ElasticController, ElasticPolicy, TokenBucket  # noqa: E402


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 8), st.floats(min_value=0.5, max_value=20.0))
def test_bucket_burst_is_the_instantaneous_cap(burst, rate):
    """A fresh bucket at a single instant admits exactly ``burst`` takes
    — never more, regardless of rate."""
    b = TokenBucket(rate_qps=rate, burst=float(burst))
    admitted = sum(b.take(0.0) for _ in range(burst + 5))
    assert admitted == burst


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(min_value=0.5, max_value=8.0),
       st.integers(1, 6))
def test_bucket_conserves_tokens(seed, rate, burst):
    """Over any arrival pattern, admissions never exceed the refill
    budget: ``admitted <= burst + elapsed * rate`` at every prefix."""
    rng = np.random.default_rng(seed)
    b = TokenBucket(rate_qps=rate, burst=float(burst))
    t, admitted = 0.0, 0
    for _ in range(60):
        t += float(rng.exponential(0.3))
        admitted += b.take(t)
        assert admitted <= burst + t * rate + 1e-9
        assert 0.0 <= b.tokens <= burst


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(min_value=0.5, max_value=8.0))
def test_bucket_no_starvation_after_idle(seed, rate):
    """However drained, one full refill interval (``1/rate``) always buys
    the next take — a tenant that backs off is never locked out."""
    rng = np.random.default_rng(seed)
    b = TokenBucket(rate_qps=rate, burst=2.0)
    t = 0.0
    for _ in range(20):
        t += float(rng.exponential(0.05))
        b.take(t)          # hammer the bucket (mostly rejected)
    t += 1.0 / rate + 1e-9
    assert b.take(t)


def test_bucket_ignores_clock_regressions():
    """An out-of-order arrival must not refill (monotone-clock guard) —
    otherwise replay order could mint tokens."""
    b = TokenBucket(rate_qps=1.0, burst=1.0)
    assert b.take(10.0)
    assert not b.take(10.5)
    assert not b.take(0.0)     # regression: no refill, no admit
    assert not b.take(10.6)    # and no token appeared meanwhile
    assert b.take(11.5)        # a full second after the last refill point


def test_bucket_validation():
    with pytest.raises(ValueError, match="rate_qps"):
        TokenBucket(rate_qps=0.0, burst=1.0)
    with pytest.raises(ValueError, match="burst"):
        TokenBucket(rate_qps=1.0, burst=0.0)


def test_admit_arrival_routes_and_counts():
    sched = TenantScheduler([TenantSpec(name="rl", rate_limit_qps=1.0,
                                        rate_limit_burst=1.0),
                             TenantSpec(name="free")])
    assert sched.admit_arrival("rl", "a", 0.0)
    assert not sched.admit_arrival("rl", "b", 0.1)
    assert sched.admit_arrival("rl", "c", 1.2)
    for i in range(5):       # no bucket → always admitted
        assert sched.admit_arrival("free", i, 0.0)
    rl, free = sched.state("rl"), sched.state("free")
    assert (rl.n_enqueued, rl.n_rate_limited) == (2, 1)
    assert (free.n_enqueued, free.n_rate_limited) == (5, 0)
    picked = sched.compose(0.0, cap=8) + sched.compose(0.0, cap=8)
    # Only admitted items reach composition; per-tenant FIFO is preserved.
    assert [it for name, it, _ in picked if name == "rl"] == ["a", "c"]
    assert [it for name, it, _ in picked if name == "free"] == list(range(5))


# ---------------------------------------------------------------------------
# Elastic-controller properties (PR-8: capacity follows the forecast)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 4), st.integers(4, 32),
       st.floats(min_value=0.05, max_value=2.0))
def test_elastic_monotone_in_forecast(seed, min_b, max_b, target):
    """The controller contract: a *higher* queue-delay forecast never
    lowers the batch cap, never raises headroom, and never shortens the
    degrade lead — so pressure only ever moves the knobs toward relief."""
    rng = np.random.default_rng(seed)
    pol = ElasticPolicy(min_batch=min_b, max_batch=max_b,
                        target_delay_s=target)
    base_cap, budget, reserve = 4, 1.0, 0.05
    forecasts = np.sort(rng.uniform(0.0, 5.0 * target, size=12))
    caps, heads, leads = [], [], []
    for f in forecasts:
        c = ElasticController(pol)
        c.forecast_s = float(f)
        caps.append(c.batch_cap(base_cap))
        heads.append(c.headroom_s(budget, reserve, base_cap))
        leads.append(c.degrade_lead_s(budget, reserve, base_cap))
    assert all(min_b <= c <= max_b for c in caps)
    assert all(b >= a for a, b in zip(caps, caps[1:]))
    assert all(b <= a + 1e-12 for a, b in zip(heads, heads[1:]))
    assert all(b >= a - 1e-12 for a, b in zip(leads, leads[1:]))
    assert all(0.0 <= l <= budget for l in leads)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(min_value=0.05, max_value=1.0))
def test_elastic_forecast_is_the_ewma_of_flush_delays(seed, alpha):
    rng = np.random.default_rng(seed)
    ctl = ElasticController(ElasticPolicy(ewma=alpha))
    ref = 0.0
    for d in rng.uniform(0.0, 2.0, size=10):
        ctl.note_flush(float(d))
        ref = (1 - alpha) * ref + alpha * float(d)
        assert ctl.forecast_s == pytest.approx(ref)
    assert ctl.n_windows == 10
    ctl.note_flush(-5.0)       # negative delay is clamped, not absorbed
    assert ctl.forecast_s >= 0.0


def test_elastic_no_pressure_means_base_cap():
    ctl = ElasticController(ElasticPolicy(min_batch=1, max_batch=32))
    assert ctl.forecast_s == 0.0
    for base in (1, 4, 32):
        assert ctl.batch_cap(base) == base
    assert ctl.degrade_lead_s(1.0, 0.05, 4) == 0.0


def test_elastic_ceiling_never_clamps_the_provisioned_base():
    """max_batch bounds the *scaling*, not the deployment: a capacity
    event raising the base cap above the elastic ceiling passes through
    unclamped (elasticity adds capacity, never subtracts it)."""
    ctl = ElasticController(ElasticPolicy(min_batch=1, max_batch=4,
                                          target_delay_s=0.1))
    assert ctl.batch_cap(8) == 8                 # base above ceiling
    ctl.forecast_s = 10.0                        # saturated pressure
    assert ctl.batch_cap(8) == 8                 # still the base, not 4
    assert ctl.batch_cap(1) == 4                 # scaling capped at 4
