"""Known-bad fixture: snapshot pack/restore safety violations."""


class BadCache:
    def __init__(self):
        self._entries = {}

    def snapshot(self):
        items = [(k, v) for k, v in self._entries.items()]
        return pack_snapshot("eset", items)

    def snapshot_pinned(self):
        items = [(id(v), v) for v in self._entries.values()
                 if v.model is None]
        return pack_snapshot("eset", items)

    def restore(self, blob):
        for k, es in unpack_snapshot(blob, "eset"):
            self._entries[k] = es
        return len(self._entries)

    def restore_pools(self, blob):
        for k, arrs in unpack_snapshot(blob, "pools"):
            for a in arrs:
                a.setflags(write=False)
            self._entries[k] = arrs
