"""Known-bad fixture: cache-key completeness.  Line numbers are pinned by
tests/test_analysis.py — edit both together."""


def template_key(q, cfg, cost):
    return (q.benchmark, q.template, cfg, cost)  # line 6: CK001 (no model fp)


def tune(cache, tenant, qid, weights):
    _ = (tenant, weights)
    key = (qid,)
    cache.put(key, 1)                            # line 12: CK002 x2
