"""Known-bad fixture: unkeyed context read across a helper boundary."""


class Store:
    def __init__(self):
        self._results = {}

    def put_result(self, key, value):
        self._results.put(key, value)


class Service:
    def __init__(self):
        self.store = Store()

    def answer(self, q, tenant):
        key = (q.qid,)
        value = solve(q, tenant)
        self.store.put_result(key, value)
        return value
