"""Fixture parity-test registry: exercises goodpkg only (badpkg -> KP002)."""
from fixture.kernels.goodpkg.ops import good_op
from fixture.kernels.goodpkg.ref import good_ref


def test_goodpkg_parity():
    assert good_op(3) == good_ref(3)
