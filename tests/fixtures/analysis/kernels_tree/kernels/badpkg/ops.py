"""Fixture kernel package with no ref.py and no parity test (KP001/KP002)."""


def bad_op(x):
    return x
