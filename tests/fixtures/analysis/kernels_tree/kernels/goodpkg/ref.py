"""Reference oracle for the goodpkg fixture."""


def good_ref(x):
    return x
