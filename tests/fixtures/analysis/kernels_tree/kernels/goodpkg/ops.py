"""Fixture kernel package with a ref and a registered parity test (clean)."""


def good_op(x):
    return x
