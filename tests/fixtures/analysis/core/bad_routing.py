"""Known-bad fixture: tie-blind f32 kernel routing.  Line numbers are
pinned by tests/test_analysis.py — edit both together."""


def route(F):
    from repro.kernels.ws_reduce import ws_reduce   # line 6: KP003
    return ws_reduce(F, F)


def guarded_route(F, tie_check):
    if _f32_tie_hazard(F):
        return None
    from repro.kernels.pareto_filter import pareto_filter   # guarded: clean
    return pareto_filter(F, F)


def _f32_tie_hazard(F):
    return False
