"""Known-bad fixture: trace hazards.  Line numbers are pinned by
tests/test_analysis.py — edit both together."""
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

ON_TPU = jax.default_backend() == "tpu"         # line 10: TH003
DEBUG = os.environ.get("FIXTURE_DEBUG", "0")    # line 11: TH003


@jax.jit
def branchy(x, n: int):
    if x > 0:                                   # line 16: TH001
        return x + n
    return x - n


@functools.partial(jax.jit, static_argnames=("mode",))
def syncy(x, mode):
    v = float(x)                                # line 23: TH002
    w = x.item()                                # line 24: TH002
    return v + w


@functools.lru_cache(maxsize=None)
def frozen_flag():                              # line 29: TH004
    return os.environ.get("FIXTURE_ROUTE", "np")


def dispatch(F):
    n = F.shape[0]
    buf = np.zeros((n, 4))                      # line 35: TH005
    buf[:n] = F
    return solve_pallas(jnp.asarray(buf))


def solve_pallas(x):
    return x
