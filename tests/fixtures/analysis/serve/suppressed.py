"""Fixture: inline suppressions — justified, unjustified, comment-line form.
Line numbers are pinned by tests/test_analysis.py — edit both together."""
import time


def stamp_ok():
    return time.time()  # repro: allow[DT001] feeds the reported stats only


def stamp_bare():
    # repro: allow[DT001]
    return time.time()  # line 12: suppressed, but SUP001 in strict
