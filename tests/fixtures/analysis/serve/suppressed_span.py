"""Fixture: an allow on line one of a multi-line statement covers its
continuation lines; a suppression silencing nothing is dead (SUP002)."""
import time


def measure():
    t = (  # repro: allow[DT001] fixture: simulated-clock shim, span test
        time.time()
    )
    return t


def clean():
    # repro: allow[DT002] fixture: nothing here draws randomness any more
    return 0
