"""Known-bad fixture: determinism leaks in a transcript-order path.
Line numbers are pinned by tests/test_analysis.py — edit both together."""
import random
import time

import numpy as np


def stamp():
    return time.time()                          # line 10: DT001


def draw():
    rng = np.random.default_rng()               # line 14: DT002
    x = np.random.normal()                      # line 15: DT002
    y = random.random()                         # line 16: DT002
    return rng, x, y


def iterate(names):
    out = []
    for n in set(names):                        # line 22: DT003
        out.append(n)
    pool = {1, 2, 3}
    return out + list(pool)                     # line 25: DT003
