"""Known-bad scenario-engine module: every way a scenario build can stop
being a pure function of its seeds.  Golden fixture for the determinism
checker's ``("queryengine", "scenarios.py")`` scope — NOT importable code.
"""
import time

import numpy as np


def build_events(specs):
    stamp = time.time()                      # DT001: wall-clock in a build
    rng = np.random.default_rng()            # DT002: unseeded rng
    jitter = np.random.uniform(0.0, 1.0)     # DT002: legacy global state
    tenants = {s.name for s in specs}
    out = []
    for name in tenants:                     # DT003: set iteration order
        out.append((name, stamp + jitter + rng.random()))
    return out
