"""Known-bad fixture: impure reads reachable from the serving entrypoints."""
import os
import time

from helpers import draw as lhs_draw
import helpers as hp


class OptimizerServer:
    def serve(self, stream):
        t0 = time.time()
        region = os.environ.get("CLOUD_REGION", "?")
        budget = os.environ.get("REPRO_SOLVE_BUDGET")
        out = [lhs_draw(q) for q in stream]
        hp.note(len(out))
        return out, t0, region, budget


def offline_report():
    # Not reachable from the serving entrypoints: must not be flagged.
    return time.time()
