"""Helpers reached through aliased imports from the fixture server."""
import numpy as np

_CALLS = 0


def draw(q):
    rng = np.random.default_rng()
    tag = id(q)
    return (tag, rng.normal())


def note(n):
    global _CALLS
    _CALLS = n
