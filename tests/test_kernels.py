"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import ml_dtypes
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.ops import attention_ref, flash_attention
from repro.kernels.pareto_filter.ops import pareto_filter, pareto_mask_ref
from repro.kernels.ws_reduce.ops import ws_reduce, ws_reduce_ref


@pytest.mark.parametrize("n,k", [(4, 2), (128, 2), (200, 3), (513, 4),
                                 (64, 8), (1, 2)])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_pareto_filter(n, k, dtype):
    rng = np.random.default_rng(n * 10 + k)
    F = jnp.asarray(rng.integers(0, 9, size=(n, k)).astype(dtype))
    valid = jnp.asarray(rng.random(n) > 0.15)
    got = pareto_filter(F, valid)
    ref = pareto_mask_ref(F, valid)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("m,B,k,nw", [(1, 8, 2, 3), (4, 130, 2, 11),
                                      (3, 48, 3, 33), (2, 256, 4, 128)])
def test_ws_reduce(m, B, k, nw):
    rng = np.random.default_rng(m * 100 + B)
    F = rng.random((m, B, k)).astype(np.float32)
    F[:, -2:] = np.inf                       # padded bank slots
    W = rng.random((nw, k)).astype(np.float32)
    v, i = ws_reduce(jnp.asarray(F), jnp.asarray(W))
    vr, ir = ws_reduce_ref(jnp.nan_to_num(jnp.asarray(F), posinf=1e30),
                           jnp.asarray(W))
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))


@pytest.mark.parametrize(
    "B,Hq,Hkv,Sq,Skv,D,causal",
    [(1, 4, 4, 128, 128, 64, True),
     (2, 8, 2, 256, 256, 64, True),      # GQA
     (1, 4, 1, 100, 100, 128, True),     # ragged + MQA
     (1, 4, 2, 1, 300, 64, False),       # decode
     (1, 8, 4, 96, 480, 64, True),       # continuation chunk
     (2, 2, 2, 64, 64, 128, False)])
def test_flash_attention_f32(B, Hq, Hkv, Sq, Skv, D, causal):
    rng = np.random.default_rng(Sq + Skv)
    q = jnp.asarray(rng.normal(size=(B, Hq, Sq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, Skv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, Skv, D)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(0)
    shape_q = (1, 4, 128, 128)
    q = jnp.asarray(rng.normal(size=shape_q).astype(ml_dtypes.bfloat16))
    k = jnp.asarray(rng.normal(size=shape_q).astype(ml_dtypes.bfloat16))
    v = jnp.asarray(rng.normal(size=shape_q).astype(ml_dtypes.bfloat16))
    got = flash_attention(q, k, v, causal=True)
    ref = attention_ref(q, k, v, causal=True)
    assert got.dtype == q.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2)


def test_backend_detection_resolves_at_call_time(monkeypatch):
    """The interpret default must track the *current* backend, not the one
    active when the ops module was imported (backends can be initialized or
    overridden after import)."""
    from repro.kernels.flash_attention import ops as fa_ops
    from repro.kernels.pareto_filter import ops as pf_ops
    from repro.kernels.ws_reduce import ops as ws_ops

    host = jax.default_backend()
    for ops in (fa_ops, pf_ops, ws_ops):
        assert ops._default_interpret() is (host != "tpu")
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    for ops in (fa_ops, pf_ops, ws_ops):
        assert ops._default_interpret() is False
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    for ops in (fa_ops, pf_ops, ws_ops):
        assert ops._default_interpret() is True


def test_fused_ws_front_composed_solve():
    """fused_solve: ws_reduce picks + objective sums + local/global Pareto
    composed under one jit, checked against a hand-computed case."""
    from repro.kernels.fused_solve import SEEN_BUCKETS, fused_ws_front

    N, m, B, k, nw = 3, 2, 2, 2, 4
    rng = np.random.default_rng(0)
    Fb = rng.random((N, m, B, k))
    Fb[:, :, 0] = Fb[:, :, 1] - 1.0   # bank 0 strictly dominates bank 1
    W = np.stack([np.linspace(0.1, 0.9, nw),
                  1.0 - np.linspace(0.1, 0.9, nw)], -1)
    Fn = Fb.astype(np.float32).astype(np.float64)
    jj, P_all, keep = fused_ws_front(Fn.astype(np.float32), Fb, W)
    assert jj.shape == (N, nw, m) and P_all.shape == (N, nw, k)
    assert (jj == 0).all()            # every weight picks the dominant bank
    np.testing.assert_allclose(P_all, np.broadcast_to(
        Fb[:, :, 0].sum(axis=1)[:, None, :], (N, nw, k)), rtol=1e-12)
    # Every weight row of a candidate lands on the same objective sum, so a
    # candidate either survives the global filter with all rows (duplicate
    # optima survive, matching the numpy dominance semantics) or with none.
    from repro.core.moo.pareto import pareto_mask_np
    cand_mask = pareto_mask_np(Fb[:, :, 0].sum(axis=1))
    np.testing.assert_array_equal(keep.any(axis=1), cand_mask)
    assert (keep.sum(axis=1)[cand_mask] == nw).all()
    assert any(b[0] >= N and b[1] >= m for b in SEEN_BUCKETS)


@pytest.mark.parametrize("N,m,B,k,nw", [(1, 1, 2, 2, 3), (3, 2, 8, 2, 11),
                                        (7, 3, 16, 2, 6), (33, 5, 4, 2, 4)])
def test_fused_ws_front_vs_ref(N, m, B, k, nw):
    """Parity: the fused jit against the pure-numpy oracle, including banks
    with padded (+inf) slots."""
    from repro.kernels.fused_solve import fused_ws_front, fused_ws_front_ref

    rng = np.random.default_rng(N * 1000 + m * 10 + B)
    Fb = rng.random((N, m, B, k))
    if B > 2:
        Fb[:, :, -1] = np.inf         # padded bank slot everywhere
        Fb[0, 0, -2] = np.inf
    W = np.stack([np.linspace(0.05, 0.95, nw),
                  1.0 - np.linspace(0.05, 0.95, nw)], -1)
    lo = np.nanmin(np.where(np.isfinite(Fb), Fb, np.nan), axis=(1, 2),
                   keepdims=True)
    hi = np.nanmax(np.where(np.isfinite(Fb), Fb, np.nan), axis=(1, 2),
                   keepdims=True)
    Fn = np.where(np.isfinite(Fb), (Fb - lo) / np.where(hi > lo, hi - lo,
                                                        1.0), 1e18)
    jj, P_all, keep = fused_ws_front(Fn.astype(np.float32), Fb, W)
    jr, Pr, kr = fused_ws_front_ref(Fn.astype(np.float32), Fb, W)
    np.testing.assert_array_equal(jj, jr)
    np.testing.assert_allclose(P_all, Pr, rtol=1e-12)
    np.testing.assert_array_equal(keep, kr)


def test_fused_ws_front_padding_invalid():
    """Padded candidates/subQs and non-finite banks never reach the front."""
    from repro.kernels.fused_solve import fused_ws_front

    rng = np.random.default_rng(1)
    N, m, B, k, nw = 5, 3, 4, 2, 6
    Fb = rng.random((N, m, B, k))
    Fb[2, 1] = np.inf                 # a subQ with an empty bank
    W = np.stack([np.linspace(0.05, 0.95, nw),
                  1.0 - np.linspace(0.05, 0.95, nw)], -1)
    Fn = Fb.astype(np.float32)
    jj, P_all, keep = fused_ws_front(Fn, Fb, W)
    assert not keep[2].any()          # invalid candidate filtered
    assert keep.any()                 # but the rest produce a front
    assert np.isfinite(P_all[keep]).all()
