"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import ml_dtypes
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.ops import attention_ref, flash_attention
from repro.kernels.pareto_filter.ops import pareto_filter, pareto_mask_ref
from repro.kernels.ws_reduce.ops import ws_reduce, ws_reduce_ref


@pytest.mark.parametrize("n,k", [(4, 2), (128, 2), (200, 3), (513, 4),
                                 (64, 8), (1, 2)])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_pareto_filter(n, k, dtype):
    rng = np.random.default_rng(n * 10 + k)
    F = jnp.asarray(rng.integers(0, 9, size=(n, k)).astype(dtype))
    valid = jnp.asarray(rng.random(n) > 0.15)
    got = pareto_filter(F, valid)
    ref = pareto_mask_ref(F, valid)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("m,B,k,nw", [(1, 8, 2, 3), (4, 130, 2, 11),
                                      (3, 48, 3, 33), (2, 256, 4, 128)])
def test_ws_reduce(m, B, k, nw):
    rng = np.random.default_rng(m * 100 + B)
    F = rng.random((m, B, k)).astype(np.float32)
    F[:, -2:] = np.inf                       # padded bank slots
    W = rng.random((nw, k)).astype(np.float32)
    v, i = ws_reduce(jnp.asarray(F), jnp.asarray(W))
    vr, ir = ws_reduce_ref(jnp.nan_to_num(jnp.asarray(F), posinf=1e30),
                           jnp.asarray(W))
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))


@pytest.mark.parametrize(
    "B,Hq,Hkv,Sq,Skv,D,causal",
    [(1, 4, 4, 128, 128, 64, True),
     (2, 8, 2, 256, 256, 64, True),      # GQA
     (1, 4, 1, 100, 100, 128, True),     # ragged + MQA
     (1, 4, 2, 1, 300, 64, False),       # decode
     (1, 8, 4, 96, 480, 64, True),       # continuation chunk
     (2, 2, 2, 64, 64, 128, False)])
def test_flash_attention_f32(B, Hq, Hkv, Sq, Skv, D, causal):
    rng = np.random.default_rng(Sq + Skv)
    q = jnp.asarray(rng.normal(size=(B, Hq, Sq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, Skv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, Skv, D)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(0)
    shape_q = (1, 4, 128, 128)
    q = jnp.asarray(rng.normal(size=shape_q).astype(ml_dtypes.bfloat16))
    k = jnp.asarray(rng.normal(size=shape_q).astype(ml_dtypes.bfloat16))
    v = jnp.asarray(rng.normal(size=shape_q).astype(ml_dtypes.bfloat16))
    got = flash_attention(q, k, v, causal=True)
    ref = attention_ref(q, k, v, causal=True)
    assert got.dtype == q.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2)
