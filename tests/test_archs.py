"""Per-architecture smoke tests (required deliverable f) + consistency."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.archs.blocks import apply_moe, init_moe, _attend, _attend_chunked
from repro.archs.registry import (ARCH_IDS, build_model, get_config,
                                  get_smoke_config)


# The heaviest smoke configs (compile-dominated) run only with -m slow;
# every model family keeps at least one tier-1 representative.
_SLOW_ARCHS = {"jamba-1.5-large-398b", "whisper-base", "minicpm-2b"}
ARCH_PARAMS = [pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_ARCHS
               else a for a in ARCH_IDS]


def _batch(cfg, B, S, rng):
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_patches, cfg.d_model)), jnp.float32)
    if cfg.family == "audio":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_seq, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch_id", ARCH_PARAMS)
def test_smoke_forward_and_train_step(arch_id):
    """Reduced config: one forward + one train step, shapes + no NaNs."""
    cfg = get_smoke_config(arch_id).with_(dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S = 2, 16
    batch = _batch(cfg, B, S, rng)
    logits, _ = api.forward(params, batch["tokens"],
                            patches=batch.get("patches"))
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()

    loss, grads = jax.value_and_grad(api.loss)(params, batch)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch_id", ARCH_PARAMS)
def test_prefill_decode_matches_forward(arch_id):
    cfg = get_smoke_config(arch_id).with_(dtype="float32")
    if cfg.n_experts:
        cfg = cfg.with_(capacity_factor=float(cfg.n_experts) / cfg.top_k)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    B, S = 2, 12
    batch = _batch(cfg, B, S, rng)
    full, _ = api.forward(params, batch["tokens"],
                          patches=batch.get("patches"))
    P = cfg.n_patches if cfg.family == "vlm" else 0
    cache = api.init_cache(B, 32 + P)
    lg, cache = api.forward(params, batch["tokens"][:, :8], caches=cache,
                            patches=batch.get("patches"))
    errs = [np.abs(np.asarray(lg) - np.asarray(full[:, :8])).max()]
    for t in range(8, S):
        pos = jnp.full((B, 1), t + P)
        lg, cache = api.forward(params, batch["tokens"][:, t:t + 1],
                                caches=cache, positions=pos)
        errs.append(np.abs(np.asarray(lg[:, 0])
                           - np.asarray(full[:, t])).max())
    assert max(errs) < 1e-3, f"{arch_id}: {max(errs)}"


def test_exact_full_configs_match_assignment():
    dims = {
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
    }
    for aid, (L, d, h, kv, f, v) in dims.items():
        c = get_config(aid)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff,
                c.vocab) == (L, d, h, kv, f, v), aid
    moe = get_config("dbrx-132b")
    assert (moe.n_experts, moe.top_k) == (16, 4)
    moe = get_config("moonshot-v1-16b-a3b")
    assert (moe.n_experts, moe.top_k) == (64, 6)
    jam = get_config("jamba-1.5-large-398b")
    assert (jam.n_experts, jam.top_k, jam.attn_every) == (16, 2, 8)
    assert get_config("qwen2-72b").qkv_bias


def test_moe_sort_equals_einsum_dispatch():
    cfg = get_smoke_config("dbrx-132b").with_(dtype="float32")
    cfg = cfg.with_(capacity_factor=float(cfg.n_experts) / cfg.top_k)
    p = init_moe(jax.random.PRNGKey(2), cfg)
    x = jnp.asarray(np.random.default_rng(2).normal(
        size=(3, 32, cfg.d_model)), jnp.float32)
    a = apply_moe(cfg, p, x, impl="sort")
    b = apply_moe(cfg, p, x, impl="einsum")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_chunked_attention_matches_einsum():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(2, 4, 256, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 2, 256, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 2, 256, 32)), jnp.float32)
    ref = _attend(q, k, v, causal=True, window=0, kv_len=None,
                  use_flash=False)
    got = _attend_chunked(q, k, v, causal=True, window=0, kv_len=None,
                          q_start=None, bq=64, bk=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-4)
