"""Model-backed serving parity: the PR-3 golden determinism suite re-run on
a trained :class:`PerfModel` backend (the path the paper actually serves).

The smoke models come from the session-cached trainer in ``conftest.py``
(tiny GTN + regressor, brief training on simulator traces) — real learned
backends, fast enough for tier-1.  The invariants:

* served plans and objectives are **bit-identical** to the offline
  model-backed ``tune_batch`` → ``RuntimeSession.run_batch`` pipeline,
  however the stream is sliced (all at once / one at a time / shuffled
  micro-batches);
* runtime θs decisions consume **nonzero γ** contention features on the
  model path (spy on the QS model) — §4.3's γ is no longer zeroed;
* ``gamma_mode="off"`` restores the zeroed-γ behavior, and
  ``gamma_mode="live"`` actually injects cross-query open-entry-set
  pressure (trading away determinism by design);
* multi-tenant model-backed serving is bit-identical to the offline
  pipeline *per tenant*, each under its own preference weights.

A larger trained-model variant of the golden parity runs under ``-m slow``.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.moo.hmooc import HMOOCConfig
from repro.queryengine.workloads import (ArrivalModel, TenantSpec,
                                         multi_tenant_stream, serving_stream)
from repro.serve import (OptimizerServer, RuntimeSession, ServerConfig,
                         TuningService)

from conftest import build_smoke_perf_models

CFG = HMOOCConfig(n_c_init=16, n_clusters=4, n_p_pool=48, n_c_enrich=12,
                  max_bank=12, seed=3)
WEIGHTS = (0.9, 0.1)
N_STREAM = 8


@pytest.fixture(scope="module")
def models(smoke_perf_models):
    return smoke_perf_models["subq"], smoke_perf_models["qs"]


@pytest.fixture(scope="module")
def timed_stream():
    return serving_stream("tpch", N_STREAM, seed=11,
                          arrivals=ArrivalModel(kind="poisson",
                                                rate_qps=40.0))


@pytest.fixture(scope="module")
def offline(timed_stream, models):
    """Offline model-backed reference: compile under the subQ model, run
    the runtime session under the subQ+QS models."""
    msub, mqs = models
    queries = [r.query for r in timed_stream]
    cts = TuningService(model=msub, cfg=CFG).tune_batch(queries, WEIGHTS)
    res = RuntimeSession(model_subq=msub, model_qs=mqs,
                         weights=WEIGHTS).run_batch(queries, cts)
    return cts, res


def _server(models, max_batch, **cfg_kw):
    msub, mqs = models
    return OptimizerServer(
        config=ServerConfig(max_batch=max_batch, **cfg_kw),
        tuning=TuningService(model=msub, cfg=CFG),
        session=RuntimeSession(model_subq=msub, model_qs=mqs,
                               weights=WEIGHTS))


def _assert_same_outputs(served, offline_results):
    for s, ref in zip(served, offline_results):
        got = s.result
        np.testing.assert_array_equal(got.theta_p_eff, ref.theta_p_eff)
        np.testing.assert_array_equal(got.theta_s_eff, ref.theta_s_eff)
        np.testing.assert_array_equal(got.final_join, ref.final_join)
        np.testing.assert_array_equal(got.sim.ana_latency, ref.sim.ana_latency)
        np.testing.assert_array_equal(got.sim.actual_latency,
                                      ref.sim.actual_latency)
        np.testing.assert_array_equal(got.sim.io_gb, ref.sim.io_gb)
        np.testing.assert_array_equal(got.sim.cost, ref.sim.cost)
        assert got.requests_sent == ref.requests_sent
        assert got.requests_total == ref.requests_total


# ---------------------------------------------------------------------------
# Golden determinism on the learned backend
# ---------------------------------------------------------------------------

def test_model_one_at_a_time_matches_offline(timed_stream, offline, models):
    _, ref = offline
    served = _server(models, max_batch=1).serve(timed_stream)
    _assert_same_outputs(served, ref)


def test_model_micro_batches_match_offline(timed_stream, offline, models):
    _, ref = offline
    served = _server(models, max_batch=3).serve(timed_stream)
    _assert_same_outputs(served, ref)


def test_model_shuffled_micro_batches_match(timed_stream, offline, models):
    _, ref = offline
    rng = np.random.default_rng(5)
    times = np.sort([r.arrival_s for r in timed_stream])
    perm = rng.permutation(len(timed_stream))
    shuffled = sorted(
        (dataclasses.replace(r, arrival_s=float(times[perm[i]]))
         for i, r in enumerate(timed_stream)),
        key=lambda r: r.arrival_s)
    served = _server(models, max_batch=3).serve(shuffled)
    by_rid = {s.rid: s for s in served}
    _assert_same_outputs([by_rid[r.rid] for r in timed_stream], ref)


def test_multi_tenant_model_backed_per_tenant_parity(models):
    """Two tenants, distinct preference vectors, one model-backed server:
    each tenant's served output bit-matches the offline model-backed
    pipeline solved under that tenant's own weights."""
    msub, mqs = models
    specs = [TenantSpec(name="lat", weights=(0.9, 0.1),
                        arrivals=ArrivalModel(rate_qps=25.0)),
             TenantSpec(name="cost", weights=(0.2, 0.8), priority=1,
                        arrivals=ArrivalModel(rate_qps=25.0))]
    reqs = multi_tenant_stream("tpch", specs, 4, seed=3)
    srv = OptimizerServer(
        config=ServerConfig(max_batch=3),
        tuning=TuningService(model=msub, cfg=CFG),
        session=RuntimeSession(model_subq=msub, model_qs=mqs,
                               weights=WEIGHTS),
        tenants=specs)
    served = srv.serve(reqs)
    for spec in specs:
        sub = [s for s in served if s.tenant == spec.name]
        assert len(sub) == 4
        queries = [s.request.query for s in sub]
        cts = TuningService(model=msub, cfg=CFG).tune_batch(
            queries, spec.weights)
        ref = RuntimeSession(model_subq=msub, model_qs=mqs,
                             weights=spec.weights).run_batch(queries, cts)
        _assert_same_outputs(sub, ref)


# ---------------------------------------------------------------------------
# Overload on the model backend: survivors still bit-match offline (PR-5)
# ---------------------------------------------------------------------------

def test_model_overload_survivors_bit_identical(models):
    """Overloaded mixed-SLO stream on the *model* backend: strict requests
    shed, degrade requests resolve via the cheap path, and the surviving
    full-quality queries bit-match the offline model-backed pipeline per
    tenant — the golden invariant holds under overload on both backends."""
    msub, mqs = models
    specs = [TenantSpec(name="strict", slo="strict", solve_budget_s=0.0,
                        arrivals=ArrivalModel(rate_qps=40.0)),
             TenantSpec(name="deg", slo="degrade", solve_budget_s=0.0,
                        arrivals=ArrivalModel(rate_qps=40.0)),
             TenantSpec(name="lat", weights=(0.9, 0.1),
                        arrivals=ArrivalModel(rate_qps=40.0)),
             TenantSpec(name="cost", weights=(0.2, 0.8),
                        arrivals=ArrivalModel(rate_qps=40.0))]
    reqs = multi_tenant_stream("tpch", specs, 4, seed=17)
    srv = OptimizerServer(
        config=ServerConfig(max_batch=3),
        tuning=TuningService(model=msub, cfg=CFG),
        session=RuntimeSession(model_subq=msub, model_qs=mqs,
                               weights=WEIGHTS),
        tenants=specs)
    served = srv.serve(reqs)
    by = {n: [s for s in served if s.tenant == n]
          for n in ("strict", "deg", "lat", "cost")}
    assert [s.status for s in by["strict"]] == ["shed"] * 4
    assert [s.status for s in by["deg"]] == ["degraded"] * 4
    assert all(s.result is not None for s in by["deg"])
    for name, w in (("lat", (0.9, 0.1)), ("cost", (0.2, 0.8))):
        sub = by[name]
        assert [s.status for s in sub] == ["served"] * 4
        queries = [s.request.query for s in sub]
        cts = TuningService(model=msub, cfg=CFG).tune_batch(queries, w)
        ref = RuntimeSession(model_subq=msub, model_qs=mqs,
                             weights=w).run_batch(queries, cts)
        _assert_same_outputs(sub, ref)


# ---------------------------------------------------------------------------
# γ contention features on the model path
# ---------------------------------------------------------------------------

class _NondSpy:
    """Wraps ``model.predict`` and records the nondecision rows it sees."""

    def __init__(self, model, monkeypatch):
        self.rows = []
        orig = model.predict

        def wrapped(emb, theta, nond):
            self.rows.append(np.array(nond, copy=True))
            return orig(emb, theta, nond)

        monkeypatch.setattr(model, "predict", wrapped)

    @property
    def gamma(self) -> np.ndarray:
        return np.concatenate(self.rows)[:, 8:12]


def test_qs_decisions_consume_nonzero_gamma(timed_stream, offline, models,
                                            monkeypatch):
    _, ref = offline
    msub, mqs = models
    spy = _NondSpy(mqs, monkeypatch)
    served = _server(models, max_batch=3).serve(timed_stream)
    _assert_same_outputs(served, ref)     # γ is deterministic: parity holds
    assert spy.rows, "QS model never consulted"
    g = spy.gamma
    assert np.isfinite(g).all()
    assert (np.abs(g).sum(axis=1) > 0).any(), \
        "runtime θs decisions saw only zeroed γ"


def test_gamma_off_restores_zeroed_features(timed_stream, models,
                                            monkeypatch):
    msub, mqs = models
    queries = [r.query for r in timed_stream[:4]]
    cts = TuningService(model=msub, cfg=CFG).tune_batch(queries, WEIGHTS)
    spy = _NondSpy(mqs, monkeypatch)
    RuntimeSession(model_subq=msub, model_qs=mqs, weights=WEIGHTS,
                   gamma_mode="off").run_batch(queries, cts)
    assert spy.rows and (spy.gamma == 0).all()


def test_gamma_live_adds_cross_query_pressure(timed_stream, models,
                                              monkeypatch):
    """Live mode injects open-entry-set pressure: with co-running queries
    the γ rows the model sees differ from (dominate) the structural ones."""
    msub, mqs = models
    queries = [r.query for r in timed_stream[:4]]
    cts = TuningService(model=msub, cfg=CFG).tune_batch(queries, WEIGHTS)

    spy_s = _NondSpy(mqs, monkeypatch)
    RuntimeSession(model_subq=msub, model_qs=mqs, weights=WEIGHTS,
                   gamma_mode="structural").run_batch(queries, cts)
    g_struct = spy_s.gamma

    spy_l = _NondSpy(mqs, monkeypatch)
    RuntimeSession(model_subq=msub, model_qs=mqs, weights=WEIGHTS,
                   gamma_mode="live").run_batch(queries, cts)
    g_live = spy_l.gamma

    assert g_live.shape[0] > 0
    # Task/work/sibling pressure can only grow with co-runners...
    assert g_live[:, :3].sum() > g_struct[:, :3].sum()
    # ...and at least one scored row actually saw a different vector.
    n = min(g_live.shape[0], g_struct.shape[0])
    assert not np.array_equal(g_live[:n], g_struct[:n])


def test_invalid_gamma_mode_rejected():
    with pytest.raises(ValueError, match="gamma_mode"):
        RuntimeSession(gamma_mode="bogus")


# ---------------------------------------------------------------------------
# Larger trained-model variant (slow)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_model_parity_larger_trained_models():
    models_big = build_smoke_perf_models(n_queries=16, n_conf=10, steps=200)
    msub, mqs = models_big["subq"], models_big["qs"]
    reqs = serving_stream("tpch", 12, seed=7,
                          arrivals=ArrivalModel(kind="poisson",
                                                rate_qps=30.0))
    queries = [r.query for r in reqs]
    cts = TuningService(model=msub, cfg=CFG).tune_batch(queries, WEIGHTS)
    ref = RuntimeSession(model_subq=msub, model_qs=mqs,
                         weights=WEIGHTS).run_batch(queries, cts)
    srv = OptimizerServer(
        config=ServerConfig(max_batch=4),
        tuning=TuningService(model=msub, cfg=CFG),
        session=RuntimeSession(model_subq=msub, model_qs=mqs,
                               weights=WEIGHTS))
    _assert_same_outputs(srv.serve(reqs), ref)
