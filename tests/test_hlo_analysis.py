"""HLO analyzer: collective/FLOPs parsing on a synthetic module."""
import numpy as np

from repro.launch.hlo_analysis import (collective_bytes, hlo_flops_bytes,
                                       roofline_terms)

HLO = """
HloModule test

%body.1 (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %dot.1 = f32[128,256] dot(%a.1, %b.1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %a.1 = f32[128,64] parameter(0)
  %b.1 = f32[64,256] parameter(1)
  %ar = f32[128,256] all-reduce(%dot.1), replica_groups={}
}

%cond.1 (p: (s32[], f32[8,8])) -> pred[] {
  %c = s32[] constant(10)
  %cmp = pred[] compare(%gte, %c), direction=LT
}

ENTRY %main.2 (x: f32[8,8]) -> f32[8,8] {
  %w = (s32[], f32[8,8]) while(%init), condition=%cond.1, body=%body.1
  %ag = bf16[4,1024] all-gather(%y), dimensions={1}
}
"""


def test_collective_bytes_loop_weighted():
    total, by_type = collective_bytes(HLO)
    # f32 collectives are priced as bf16 (TPU-equivalent traffic; the CPU
    # backend's f32-dot rewrite would otherwise inflate them 2x).
    ar = 128 * 256 * 2 * 10          # f32->2B all-reduce x trip 10
    ag = 4 * 1024 * 2                # bf16 all-gather x 1
    assert by_type["all-reduce"] == ar
    assert by_type["all-gather"] == ag
    assert total == ar + ag
    assert by_type["_raw_f32"] == 128 * 256 * 4 * 10 + ag


def test_flops_loop_weighted():
    flops, bytes_, per = hlo_flops_bytes(HLO)
    assert flops == 2 * 128 * 256 * 64 * 10   # dot × trip 10


def test_roofline_terms_math():
    t = roofline_terms(197e12 * 256, 819e9 * 256, 50e9 * 256, 256)
    assert abs(t.compute_s - 1.0) < 1e-9
    assert abs(t.memory_s - 1.0) < 1e-9
    assert abs(t.collective_s - 1.0) < 1e-9
    assert t.dominant in ("compute", "memory", "collective")
