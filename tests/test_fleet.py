"""Fleet serving: sharded golden determinism, routing, cache warm-start.

The fleet's acceptance invariant extends the server's: per-tenant served
outputs are bit-identical to the offline ``tune_batch`` →
``RuntimeSession.run_batch`` pipeline under ANY worker count and ANY
routing policy — sharding and work stealing change only latency, never
what is served.
"""
import dataclasses
import math
import pickle

import numpy as np
import pytest

from repro.core.moo.hmooc import HMOOCConfig
from repro.queryengine.workloads import (ArrivalModel, StreamRequest,
                                         TenantSpec, make_query,
                                         multi_tenant_stream, serving_stream)
from repro.serve import (CacheStore, FleetRouter, HashRing, OptimizerFleet,
                         RuntimeSession, ServerConfig, ServiceTimeModel,
                         TuningService, route_key)

CFG = HMOOCConfig(n_c_init=16, n_clusters=4, n_p_pool=48, n_c_enrich=12,
                  max_bank=12, seed=3)
WEIGHTS = (0.9, 0.1)
N_STREAM = 10
CLOCK = ServiceTimeModel(flush_points=((1, 0.05), (8, 0.2)), round_s=0.005,
                         cheap_s=0.001, worker_scale=((1, 1.0), (4, 1.25)))


@pytest.fixture(scope="module")
def timed_stream():
    return serving_stream("tpch", N_STREAM, seed=1,
                          arrivals=ArrivalModel(kind="poisson",
                                                rate_qps=40.0))


@pytest.fixture(scope="module")
def offline(timed_stream):
    """The batch-path reference: all queries at once through both halves."""
    queries = [r.query for r in timed_stream]
    cts = TuningService(cfg=CFG).tune_batch(queries, WEIGHTS)
    return RuntimeSession(weights=WEIGHTS).run_batch(queries, cts)


def _fleet(n_workers, **kw):
    kw.setdefault("config", ServerConfig(max_batch=4, clock=CLOCK))
    return OptimizerFleet(n_workers=n_workers, weights=WEIGHTS, cfg=CFG, **kw)


def _assert_same_outputs(served, offline_results):
    for s, ref in zip(served, offline_results):
        got = s.result
        np.testing.assert_array_equal(got.theta_p_eff, ref.theta_p_eff)
        np.testing.assert_array_equal(got.theta_s_eff, ref.theta_s_eff)
        np.testing.assert_array_equal(got.final_join, ref.final_join)
        np.testing.assert_array_equal(got.sim.ana_latency, ref.sim.ana_latency)
        np.testing.assert_array_equal(got.sim.cost, ref.sim.cost)


# ---------------------------------------------------------------------------
# Golden determinism under sharding (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["affinity", "random"])
@pytest.mark.parametrize("n_workers", [1, 2, 4])
def test_fleet_outputs_bit_identical_to_offline(timed_stream, offline,
                                                n_workers, policy):
    fleet = _fleet(n_workers, policy=policy)
    served = fleet.serve(timed_stream)
    _assert_same_outputs(served, offline)
    st = fleet.last_run
    assert st.n_finished == len(timed_stream)
    assert sum(st.worker_counts) == len(timed_stream)
    assert all(s.worker is not None and 0 <= s.worker < n_workers
               for s in served)
    assert st.qps > 0.0 and math.isfinite(st.makespan_s)


def test_fleet_work_stealing_preserves_outputs(timed_stream, offline):
    """Force heavy stealing (everything arrives at once, zero tolerated
    delay): requests leave their affinity owners, outputs still
    bit-match."""
    reqs = [dataclasses.replace(r, arrival_s=0.0) for r in timed_stream]
    fleet = _fleet(4, policy="affinity", steal_delay_s=0.0)
    served = fleet.serve(reqs)
    by_rid = {s.rid: s for s in served}
    _assert_same_outputs([by_rid[r.rid] for r in timed_stream], offline)
    st = fleet.last_run
    assert st.n_stolen > 0
    assert sum(1 for c in st.worker_counts if c) > 1   # genuinely spread


def test_fleet_replay_is_deterministic(timed_stream):
    """Two identical fleets over the same stream: identical assignments,
    statuses, timelines, and bits (serve() is a pure function of stream +
    config under a ServiceTimeModel)."""
    def run():
        return _fleet(2, policy="affinity", steal_delay_s=0.05) \
            .serve(timed_stream)

    for x, y in zip(run(), run()):
        assert x.worker == y.worker and x.status == y.status
        assert x.finished_s == y.finished_s
        np.testing.assert_array_equal(x.result.theta_p_eff,
                                      y.result.theta_p_eff)


def test_fleet_multi_tenant_survivor_parity():
    """Each tenant's output through a 2-worker fleet is bit-identical to
    the offline pipeline under that tenant's own weights."""
    specs = [TenantSpec(name="lat", weights=(0.9, 0.1),
                        arrivals=ArrivalModel(kind="poisson", rate_qps=30.0)),
             TenantSpec(name="cost", weights=(0.1, 0.9),
                        arrivals=ArrivalModel(kind="uniform", rate_qps=10.0))]
    reqs = multi_tenant_stream("tpch", specs, 3, seed=8)
    fleet = OptimizerFleet(n_workers=2,
                           config=ServerConfig(max_batch=4, clock=CLOCK),
                           weights=WEIGHTS, cfg=CFG, tenants=specs)
    served = fleet.serve(reqs)
    for spec in specs:
        sub = [s for s in served if s.tenant == spec.name]
        assert len(sub) == 3
        queries = [s.request.query for s in sub]
        cts = TuningService(cfg=CFG).tune_batch(queries, spec.weights)
        ref = RuntimeSession(weights=spec.weights).run_batch(queries, cts)
        _assert_same_outputs(sub, ref)


# ---------------------------------------------------------------------------
# Cache store: snapshot/warm-start round trip (satellite acceptance)
# ---------------------------------------------------------------------------

def test_fleet_warm_start_round_trip(tmp_path, timed_stream, offline):
    """Cold worker vs a worker restored from its published snapshots:
    bit-identical responses and the warm-replay hit taxonomy (everything
    from the response cache, zero new solver work) — through a file, so
    the warmth genuinely survives the process boundary."""
    store = CacheStore()
    cold = _fleet(1, cache_store=store)
    first = cold.serve(timed_stream)                   # publishes snapshots
    assert set(store.kinds()) == {"eset", "response", "pools"}
    second = cold.serve(timed_stream)                  # warm-replay reference

    path = tmp_path / "caches.pkl"
    store.save(path)
    loaded = CacheStore.load(path)
    assert loaded.kinds() == store.kinds()
    assert all(loaded.fetch(k) == store.fetch(k) for k in store.kinds())

    warm = _fleet(1, cache_store=loaded, publish_on_serve=False)
    srv = warm.workers[0]
    assert len(srv.tuning._results) > 0                # warm before serving
    third = warm.serve(timed_stream)
    _assert_same_outputs(third, offline)
    for a, b, c in zip(first, second, third):
        np.testing.assert_array_equal(a.result.theta_p_eff,
                                      c.result.theta_p_eff)
        np.testing.assert_array_equal(b.result.theta_p_eff,
                                      c.result.theta_p_eff)
    # Identical hit taxonomy to the cold worker's own warm replay: all
    # responses deduped, no effective-set misses, no fresh pool draws.
    assert srv.tuning._results.misses == 0
    assert srv.tuning._results.hits == len(timed_stream)
    assert srv.tuning.cache.stats()["misses"] == 0
    assert srv.session.pool_cache.misses == 0
    rep = warm.cache_report()
    assert rep["response"]["hit_rate"] == pytest.approx(1.0)


def test_fleet_publish_merges_across_workers(timed_stream):
    """A sharded fleet's published snapshot is the union of its workers'
    eligible entries; a 1-worker fleet warm-started from it replays the
    whole stream without solving."""
    store = CacheStore()
    sharded = _fleet(2, policy="affinity", cache_store=store)
    sharded.serve(timed_stream)
    warm = _fleet(1, cache_store=store, publish_on_serve=False)
    warm.serve(timed_stream)
    assert warm.workers[0].tuning._results.misses == 0
    assert warm.workers[0].session.pool_cache.misses == 0


def test_warm_start_never_changes_outputs(timed_stream, offline):
    """Cache warmth moves hit rates and timing only: a warm-started fleet
    and a cold fleet serve the same bits (restore entries are exact
    artifacts for their keys)."""
    store = CacheStore()
    _fleet(2, policy="random", cache_store=store).serve(timed_stream)
    warm = _fleet(2, policy="affinity", cache_store=store,
                  publish_on_serve=False)
    _assert_same_outputs(warm.serve(timed_stream), offline)


def test_cache_store_validation(tmp_path):
    store = CacheStore()
    with pytest.raises(ValueError, match="unknown cache kind"):
        store.publish("bogus", b"x")
    with pytest.raises(TypeError, match="bytes"):
        store.publish("eset", "not-bytes")
    assert store.fetch("eset") is None and store.kinds() == ()
    p = tmp_path / "foreign.pkl"
    with open(p, "wb") as f:
        pickle.dump({"format": "something-else"}, f)
    with pytest.raises(ValueError, match="not a cache-store"):
        CacheStore.load(p)
    p2 = tmp_path / "skewed.pkl"
    with open(p2, "wb") as f:
        pickle.dump({"format": "repro-cache-store", "version": 99,
                     "blobs": {}}, f)
    with pytest.raises(ValueError, match="version"):
        CacheStore.load(p2)


# ---------------------------------------------------------------------------
# Router / ring mechanics
# ---------------------------------------------------------------------------

def test_hash_ring_deterministic_and_consistent():
    keys = [(b, t) for b in ("tpch", "tpcds") for t in range(100)]
    owners4 = [HashRing(4).worker_for(k) for k in keys]
    assert owners4 == [HashRing(4).worker_for(k) for k in keys]
    assert set(owners4) == {0, 1, 2, 3}                # no dead workers
    # Consistency: growing 4 -> 5 moves only keys captured by the new
    # worker's points — nothing reshuffles between old workers.
    owners5 = [HashRing(5).worker_for(k) for k in keys]
    moved = [i for i, (a, b) in enumerate(zip(owners4, owners5)) if a != b]
    assert moved and len(moved) < len(keys) // 2
    assert all(owners5[i] == 4 for i in moved)
    with pytest.raises(ValueError, match="n_workers"):
        HashRing(0)
    with pytest.raises(ValueError, match="replicas"):
        HashRing(2, replicas=0)


def test_router_policies():
    reqs = [StreamRequest(rid=i, query=make_query("tpch", i % 3, variant=i),
                          arrival_s=0.01 * i) for i in range(9)]
    with pytest.raises(ValueError, match="routing policy"):
        FleetRouter(2, policy="bogus")
    with pytest.raises(ValueError, match="steal_delay_s"):
        FleetRouter(2, steal_delay_s=-1.0)
    assert FleetRouter(3, policy="single").assign(reqs) == [0] * 9
    rnd = FleetRouter(3, policy="random", seed=5).assign(reqs)
    assert rnd == FleetRouter(3, policy="random", seed=5).assign(reqs)
    assert rnd != FleetRouter(3, policy="random", seed=6).assign(reqs)
    # Strict affinity is exactly the ring over the template dims.
    aff = FleetRouter(3, policy="affinity").assign(reqs)
    ring = HashRing(3)
    assert aff == [ring.worker_for(route_key(r.query)) for r in reqs]
    # ... so every variant of one template shares a worker.
    for t in range(3):
        assert len({w for r, w in zip(reqs, aff)
                    if r.query.template == t}) == 1


def test_router_assignment_is_input_order_invariant():
    """Routing happens in (arrival_s, rid) order regardless of how the
    request list is permuted: per-rid assignments never move."""
    reqs = [StreamRequest(rid=i, query=make_query("tpch", i % 4, variant=i),
                          arrival_s=0.02 * (i % 5)) for i in range(12)]
    ref = dict(zip((r.rid for r in reqs),
                   FleetRouter(3, steal_delay_s=0.01).assign(reqs)))
    perm = list(reversed(reqs))
    got = dict(zip((r.rid for r in perm),
                   FleetRouter(3, steal_delay_s=0.01).assign(perm)))
    assert got == ref


def test_router_work_stealing_spreads_backlog():
    """Simultaneous arrivals of one hot template: strict affinity piles
    them on the owner; with a delay bound the backlog forecast sends the
    overflow to idle workers (ties to the lowest index)."""
    reqs = [StreamRequest(rid=i, query=make_query("tpch", 2, variant=i),
                          arrival_s=0.0) for i in range(6)]
    strict = FleetRouter(3, steal_delay_s=None, est_full_s=0.25)
    assert len(set(strict.assign(reqs))) == 1 and strict.n_stolen == 0
    steal = FleetRouter(3, steal_delay_s=0.1, est_full_s=0.25)
    out = steal.assign(reqs)
    assert steal.n_stolen > 0 and len(set(out)) == 3
    assert sum(steal.worker_counts) == len(reqs)
    # Spaced-out arrivals never exceed the delay bound: no stealing.
    spaced = [dataclasses.replace(r, arrival_s=0.3 * i)
              for i, r in enumerate(reqs)]
    relaxed = FleetRouter(3, steal_delay_s=0.1, est_full_s=0.25)
    assert len(set(relaxed.assign(spaced))) == 1 and relaxed.n_stolen == 0


# ---------------------------------------------------------------------------
# Construction / reporting plumbing
# ---------------------------------------------------------------------------

def test_fleet_construction_validation():
    with pytest.raises(ValueError, match="n_workers"):
        OptimizerFleet(n_workers=0, cfg=CFG)
    with pytest.raises(ValueError, match="routing policy"):
        OptimizerFleet(n_workers=2, cfg=CFG, policy="bogus")
    fleet = _fleet(4)
    # The clock is re-priced for co-located contention at fleet width.
    assert fleet.config.clock.n_workers == 4
    assert all(w.config.clock.n_workers == 4 for w in fleet.workers)
    with pytest.raises(RuntimeError, match="no cache store"):
        fleet.publish()


def test_fleet_reports(timed_stream):
    fleet = _fleet(2, policy="affinity")
    served = fleet.serve(timed_stream)
    rep = fleet.latency_report(served)
    assert rep["n_queries"] == len(timed_stream)
    assert rep["n_workers"] == 2 and rep["policy"] == "affinity"
    assert rep["worker_counts"] == fleet.last_run.worker_counts
    assert rep["qps"] == fleet.last_run.qps
    assert rep["n_micro_batches"] >= 1
    cr = fleet.cache_report()
    assert set(cr) == {"effective_set", "response", "pools"}
    assert 0.0 <= cr["effective_set"]["warm_rate"] <= 1.0
    assert 0.0 <= cr["response"]["hit_rate"] <= 1.0
