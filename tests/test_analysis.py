"""Golden tests for the repro.analysis invariant suite.

Each fixture under tests/fixtures/analysis/ is a known-bad file whose
exact (line, rule) findings are pinned here; the suite's gate contract is
pinned by the strict zero-findings run over the real src/ tree.
"""
import os
import subprocess
import sys
from pathlib import Path

from repro.analysis import run_paths
from repro.analysis.core import SourceFile, run_files
from repro.analysis import (cache_keys, determinism, kernel_parity,
                            trace_hazards)

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "fixtures" / "analysis"


def _findings(path, checker):
    return sorted((f.line, f.rule) for f in checker(SourceFile(path)))


def test_trace_hazard_fixture_golden():
    assert _findings(FIXTURES / "core" / "bad_trace.py",
                     trace_hazards.check) == [
        (10, "TH003"), (11, "TH003"), (16, "TH001"), (23, "TH002"),
        (24, "TH002"), (29, "TH004"), (35, "TH005")]


def test_determinism_fixture_golden():
    assert _findings(FIXTURES / "serve" / "bad_determinism.py",
                     determinism.check) == [
        (10, "DT001"), (14, "DT002"), (15, "DT002"), (16, "DT002"),
        (22, "DT003"), (25, "DT003")]


def test_determinism_scope_gate():
    # Same leak patterns outside serve/, core/moo/, core/tuning/ are not
    # transcript-ordered and must not be flagged.
    assert determinism.check(
        SourceFile(FIXTURES / "core" / "bad_trace.py")) == []


def test_determinism_scenario_fixture_golden():
    # The scenario engine is a single-module scope: a path-part sequence
    # ending in a .py part pins exactly queryengine/scenarios.py.
    path = FIXTURES / "queryengine" / "scenarios.py"
    assert determinism.in_scope(str(path))
    assert not determinism.in_scope(
        str(path.with_name("workloads.py")))
    assert _findings(path, determinism.check) == [
        (11, "DT001"), (12, "DT002"), (13, "DT002"), (16, "DT003")]


def test_cache_key_fixture_golden():
    assert _findings(FIXTURES / "bad_cache.py", cache_keys.check) == [
        (6, "CK001"), (12, "CK002"), (12, "CK002")]


def test_kernel_routing_fixture_golden():
    # `route` is tie-blind (KP003); `guarded_route` reaches a tie_hazard
    # check and is clean.
    assert _findings(FIXTURES / "core" / "bad_routing.py",
                     kernel_parity.check_file) == [(6, "KP003")]


def test_kernel_registry_fixture_golden():
    findings = kernel_parity.check_tree(
        [str(FIXTURES / "kernels_tree")],
        tests_dir=str(FIXTURES / "kernels_tree" / "parity_tests.py"))
    got = sorted((Path(f.path).parent.name, f.rule) for f in findings)
    assert got == [("badpkg", "KP001"), ("badpkg", "KP002")]


def test_suppression_strict_requires_reason():
    r = run_files([str(FIXTURES / "serve" / "suppressed.py")],
                  [determinism.check], strict=True)
    assert [(f.line, f.rule) for f in r.findings] == [(11, "SUP001")]
    assert sorted((f.line, f.rule) for f in r.suppressed) == [
        (7, "DT001"), (12, "DT001")]


def test_suppression_lax_mode_silences_all():
    r = run_files([str(FIXTURES / "serve" / "suppressed.py")],
                  [determinism.check], strict=False)
    assert r.findings == [] and len(r.suppressed) == 2


def test_src_tree_strict_clean():
    """The CI gate contract: the real tree has zero unsuppressed findings
    and every suppression carries a written justification."""
    result = run_paths([str(REPO / "src")], strict=True)
    assert not result.parse_errors
    assert [f.format() for f in result.findings] == []
    assert result.suppressed, "expected documented intentional exceptions"


def test_cli_exit_codes_and_report():
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    bad = subprocess.run(
        [sys.executable, "-m", "repro.analysis",
         str(FIXTURES / "serve" / "bad_determinism.py")],
        capture_output=True, text=True, env=env, cwd=REPO)
    assert bad.returncode == 1
    assert "DT001" in bad.stdout and "DT003" in bad.stdout
    assert "description" in bad.stdout          # per-rule summary table
    ok = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--strict",
         "--tests", str(FIXTURES / "kernels_tree" / "parity_tests.py"),
         str(FIXTURES / "kernels_tree" / "kernels" / "goodpkg")],
        capture_output=True, text=True, env=env, cwd=REPO)
    assert ok.returncode == 0, ok.stdout
