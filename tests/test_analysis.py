"""Golden tests for the repro.analysis invariant suite.

Each fixture under tests/fixtures/analysis/ is a known-bad file whose
exact (line, rule) findings are pinned here; the suite's gate contract is
pinned by the strict zero-findings run over the real src/ tree.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

from repro.analysis import run_paths
from repro.analysis.core import CallGraph, SourceFile, run_files
from repro.analysis import (cache_keys, determinism, kernel_parity,
                            replay_purity, snapshot_safety, trace_hazards)

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "fixtures" / "analysis"


def _findings(path, checker):
    return sorted((f.line, f.rule) for f in checker(SourceFile(path)))


def test_trace_hazard_fixture_golden():
    assert _findings(FIXTURES / "core" / "bad_trace.py",
                     trace_hazards.check) == [
        (10, "TH003"), (11, "TH003"), (16, "TH001"), (23, "TH002"),
        (24, "TH002"), (29, "TH004"), (35, "TH005")]


def test_determinism_fixture_golden():
    assert _findings(FIXTURES / "serve" / "bad_determinism.py",
                     determinism.check) == [
        (10, "DT001"), (14, "DT002"), (15, "DT002"), (16, "DT002"),
        (22, "DT003"), (25, "DT003")]


def test_determinism_scope_gate():
    # Same leak patterns outside serve/, core/moo/, core/tuning/ are not
    # transcript-ordered and must not be flagged.
    assert determinism.check(
        SourceFile(FIXTURES / "core" / "bad_trace.py")) == []


def test_determinism_scenario_fixture_golden():
    # The scenario engine is a single-module scope: a path-part sequence
    # ending in a .py part pins exactly queryengine/scenarios.py.
    path = FIXTURES / "queryengine" / "scenarios.py"
    assert determinism.in_scope(str(path))
    assert not determinism.in_scope(
        str(path.with_name("workloads.py")))
    assert _findings(path, determinism.check) == [
        (11, "DT001"), (12, "DT002"), (13, "DT002"), (16, "DT003")]


def test_cache_key_fixture_golden():
    assert _findings(FIXTURES / "bad_cache.py", cache_keys.check) == [
        (6, "CK001"), (12, "CK002"), (12, "CK002")]


def test_kernel_routing_fixture_golden():
    # `route` is tie-blind (KP003); `guarded_route` reaches a tie_hazard
    # check and is clean.
    assert _findings(FIXTURES / "core" / "bad_routing.py",
                     kernel_parity.check_file) == [(6, "KP003")]


def test_kernel_registry_fixture_golden():
    findings = kernel_parity.check_tree(
        [str(FIXTURES / "kernels_tree")],
        tests_dir=str(FIXTURES / "kernels_tree" / "parity_tests.py"))
    got = sorted((Path(f.path).parent.name, f.rule) for f in findings)
    assert got == [("badpkg", "KP001"), ("badpkg", "KP002")]


def test_call_graph_cycles_methods_aliases():
    a = SourceFile("proj/a.py", text="""\
import b as helper
from b import leaf as renamed


class Engine:
    def __init__(self):
        self.sink = Sink()

    def run(self, n):
        if n:
            return self.run(n - 1)
        self.sink.flush()
        return helper.step(n)


class Sink:
    def flush(self):
        return renamed()
""")
    b = SourceFile("proj/b.py", text="""\
def step(n):
    return mutual(n)


def mutual(n):
    return step(n - 1)


def leaf():
    return 0


def orphan():
    return leaf()
""")
    graph = CallGraph([a, b])
    assert graph.resolve("Engine.run") == ["proj.a.Engine.run"]
    # a class-name entrypoint expands to every method of the class
    assert set(graph.resolve("Engine")) == {
        "proj.a.Engine.__init__", "proj.a.Engine.run"}
    reach = graph.reachable_from(["Engine.run"])
    # self-recursion and b's mutual-recursion cycle both terminate; the
    # aliased module import (helper.step), aliased from-import (renamed
    # -> b.leaf) and the typed self.sink receiver all resolve.
    assert {"proj.a.Engine.run", "proj.a.Sink.flush", "proj.b.step",
            "proj.b.mutual", "proj.b.leaf"} <= reach
    assert "proj.b.orphan" not in reach
    assert "proj.a.Engine.run" in graph.callers("proj.b.step")


def test_replay_purity_fixture_golden():
    proj = FIXTURES / "rp_project"
    files = [SourceFile(p) for p in sorted(proj.glob("*.py"))]
    graph = CallGraph(files)
    got = sorted((Path(f.path).name, f.line, f.rule)
                 for f in replay_purity.check_project(files, graph))
    # offline_report's wall-clock read (server.py:21) is unreachable from
    # the entrypoints and must stay unflagged; the REPRO_* env read
    # (server.py:13) is the registered ambient-config namespace.
    assert got == [
        ("helpers.py", 8, "RP003"),
        ("helpers.py", 9, "RP004"),
        ("helpers.py", 14, "RP005"),
        ("server.py", 11, "RP001"),
        ("server.py", 12, "RP002"),
    ]


def test_snapshot_safety_fixture_golden():
    assert _findings(FIXTURES / "bad_snapshot.py",
                     snapshot_safety.check) == [
        (10, "SN001"), (15, "SN002"), (18, "SN003")]


def test_cache_key_interprocedural_golden():
    path = FIXTURES / "bad_cache_helper.py"
    src = SourceFile(path)
    graph = CallGraph([src])
    got = sorted((f.line, f.rule)
                 for f in cache_keys.check_project([src], graph))
    assert got == [(19, "CK002")]
    # the helper's own store site keeps its file-scoped trusted-parameter
    # exemption: the blame lands on the caller composing the key.
    assert _findings(path, cache_keys.check) == []


def test_multiline_suppression_span_and_dead_suppression():
    path = str(FIXTURES / "serve" / "suppressed_span.py")
    r = run_files([path], [determinism.check], strict=True)
    # the DT001 on the continuation line (8) is covered by the allow on
    # the statement's first line (7); the unused DT002 allow is dead.
    assert sorted((f.line, f.rule) for f in r.suppressed) == [(8, "DT001")]
    assert [(f.line, f.rule) for f in r.findings] == [(14, "SUP002")]
    lax = run_files([path], [determinism.check], strict=False)
    assert lax.findings == []
    # a scoped run that never activates DT002 must not call its
    # suppression dead
    scoped = run_files([path], [determinism.check], strict=True,
                       select=["DT001", "SUP"])
    assert [(f.line, f.rule) for f in scoped.findings] == []


def test_determinism_scope_covers_fleet_and_scenarios():
    fleet = REPO / "src" / "repro" / "serve" / "fleet.py"
    scenarios = REPO / "src" / "repro" / "queryengine" / "scenarios.py"
    assert determinism.in_scope(str(fleet))
    assert determinism.in_scope(str(scenarios))
    assert determinism.check(SourceFile(fleet)) == []
    assert determinism.check(SourceFile(scenarios)) == []


def test_suppression_strict_requires_reason():
    r = run_files([str(FIXTURES / "serve" / "suppressed.py")],
                  [determinism.check], strict=True)
    assert [(f.line, f.rule) for f in r.findings] == [(11, "SUP001")]
    assert sorted((f.line, f.rule) for f in r.suppressed) == [
        (7, "DT001"), (12, "DT001")]


def test_suppression_lax_mode_silences_all():
    r = run_files([str(FIXTURES / "serve" / "suppressed.py")],
                  [determinism.check], strict=False)
    assert r.findings == [] and len(r.suppressed) == 2


def test_src_tree_strict_clean():
    """The CI gate contract: the real tree has zero unsuppressed findings
    and every suppression carries a written justification."""
    result = run_paths([str(REPO / "src")], strict=True)
    assert not result.parse_errors
    assert [f.format() for f in result.findings] == []
    assert result.suppressed, "expected documented intentional exceptions"


def test_cli_exit_codes_and_report():
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    bad = subprocess.run(
        [sys.executable, "-m", "repro.analysis",
         str(FIXTURES / "serve" / "bad_determinism.py")],
        capture_output=True, text=True, env=env, cwd=REPO)
    assert bad.returncode == 1
    assert "DT001" in bad.stdout and "DT003" in bad.stdout
    assert "description" in bad.stdout          # per-rule summary table
    ok = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--strict",
         "--tests", str(FIXTURES / "kernels_tree" / "parity_tests.py"),
         str(FIXTURES / "kernels_tree" / "kernels" / "goodpkg")],
        capture_output=True, text=True, env=env, cwd=REPO)
    assert ok.returncode == 0, ok.stdout


def test_cli_rules_reference_covers_all_families():
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--rules"],
        capture_output=True, text=True, env=env, cwd=REPO)
    assert out.returncode == 0
    for rule in ("TH001", "CK001", "CK002", "DT001", "DT003", "KP001",
                 "KP003", "RP001", "RP002", "RP003", "RP004", "RP005",
                 "SN001", "SN002", "SN003", "SUP001", "SUP002"):
        assert rule in out.stdout, f"--rules table is missing {rule}"


def test_cli_json_and_select():
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--json",
         "--select", "DT",
         str(FIXTURES / "serve" / "bad_determinism.py")],
        capture_output=True, text=True, env=env, cwd=REPO)
    assert out.returncode == 1
    payload = json.loads(out.stdout)
    assert payload["ok"] is False
    rules = {f["rule"] for f in payload["findings"]}
    assert rules and rules <= {"DT001", "DT002", "DT003"}
    assert payload["summary"]["DT001"]["findings"] >= 1
    # selecting a family the file never hits yields a clean exit
    ok = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--json",
         "--select", "KP",
         str(FIXTURES / "serve" / "bad_determinism.py")],
        capture_output=True, text=True, env=env, cwd=REPO)
    assert ok.returncode == 0
    assert json.loads(ok.stdout)["ok"] is True


def test_docstring_allow_examples_are_not_suppressions():
    # `# repro: allow[...]` text inside a string/docstring must neither
    # register as a suppression nor be flagged dead (SUP002).
    src = SourceFile("x.py", text='''\
DOC = """
inline example:  # repro: allow[DT001] not a real comment
"""


def f():
    return DOC
''')
    assert src.suppressions == []
