"""GTN performance models: featurization, training sanity, persistence."""
import numpy as np
import jax
import pytest

from repro.core.models.features import (featurize_plan, featurize_subq,
                                        lap_positional_encoding)
from repro.core.models.training import build_dataset, evaluate, train_model
from repro.queryengine.trace import collect_traces
from repro.queryengine.workloads import default_workload, make_benchmark


@pytest.fixture(scope="module")
def traces():
    qs = default_workload("tpch", 2)[:24]
    return collect_traces(qs, 12, seed=0)


def test_featurization_shapes():
    q = make_benchmark("tpch")[2]
    X, pe, bias, mask = featurize_subq(q, 0, use_est=True, n_pad=4)
    assert X.shape == (4, 20) and pe.shape == (4, 4)
    assert bias.shape == (4, 4, 3) and mask.shape == (4,)
    X, pe, bias, mask = featurize_plan(q, use_est=False, n_pad=32)
    assert X.shape[0] == 32 and mask.sum() == len(q.ops)


def test_lap_pe_deterministic_and_orthogonalish():
    A = np.zeros((5, 5), np.float32)
    for i in range(4):
        A[i, i + 1] = 1.0
    p1 = lap_positional_encoding(A, 4)
    p2 = lap_positional_encoding(A, 4)
    np.testing.assert_array_equal(p1, p2)
    assert np.isfinite(p1).all()


@pytest.mark.slow
def test_model_trains_and_roundtrips(tmp_path, traces):
    ds, cfg = build_dataset(traces, "subq")
    m = train_model(ds, cfg, steps=150, batch=256, seed=0)
    met = evaluate(m, ds, split="test")
    assert met.corr[0] > 0.5          # latency correlates after brief training
    assert met.corr[1] > 0.8          # IO is easier (paper Table 3)
    assert met.xput > 1e4
    # persistence
    path = str(tmp_path / "model.npz")
    m.save(path)
    from repro.core.models.perf_model import PerfModel
    m2 = PerfModel.load(cfg, path)
    emb = m.embed(traces.queries[0], 0)
    theta = np.random.default_rng(0).random((8, cfg.theta_dim),
                                            ).astype(np.float32)
    nond = np.zeros(12, np.float32)
    np.testing.assert_allclose(m.predict(emb, theta, nond),
                               m2.predict(emb, theta, nond), rtol=1e-5)


def test_qs_and_lqp_datasets(traces):
    ds_qs, cfg_qs = build_dataset(traces, "qs")
    assert cfg_qs.theta_dim == 10            # θp dropped at runtime
    ds_lqp, cfg_lqp = build_dataset(traces, "lqp")
    assert cfg_lqp.theta_dim == 19
    assert ds_lqp.n == traces.q_theta_c.shape[0]
