"""Production meshes.

Single pod: 16×16 = 256 chips ("data", "model").
Multi-pod:  2×16×16 = 512 chips ("pod", "data", "model") — the "pod" axis
extends data parallelism across pods (gradient all-reduce crosses the pod
boundary; everything else stays intra-pod).

Defined as a FUNCTION so importing this module never touches jax device
state — the dry-run sets XLA_FLAGS before first jax init.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape: Optional[Tuple[int, ...]] = None,
                   axes: Tuple[str, ...] = ("data", "model")):
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1) if len(axes) == 2 else (n,)
    return jax.make_mesh(shape, axes)
