"""The assigned input-shape cells and their ShapeDtypeStruct specs.

Every LM arch gets 4 shapes; ``long_500k`` runs only for sub-quadratic
families (SSM / hybrid) — full-attention archs skip it (DESIGN.md
§Arch-applicability records the skip).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..archs.common import ArchConfig

__all__ = ["SHAPES", "ShapeCell", "cell_applicable", "train_input_specs",
           "serve_input_specs"]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def cell_applicable(cfg: ArchConfig, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return cfg.supports_long
    return True


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def train_input_specs(cfg: ArchConfig, cell: ShapeCell) -> Dict[str, Any]:
    B, S = cell.global_batch, cell.seq_len
    batch = {"tokens": _sds((B, S), jnp.int32),
             "labels": _sds((B, S), jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = _sds((B, cfg.n_patches, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        batch["patches"] = _sds((B, cfg.enc_seq, cfg.d_model), jnp.float32)
    return batch


def serve_input_specs(cfg: ArchConfig, cell: ShapeCell) -> Dict[str, Any]:
    B, S = cell.global_batch, cell.seq_len
    if cell.kind == "prefill":
        out = {"tokens": _sds((B, S), jnp.int32)}
        if cfg.family == "vlm":
            out["patches"] = _sds((B, cfg.n_patches, cfg.d_model),
                                  jnp.float32)
        if cfg.family == "audio":
            out["patches"] = _sds((B, cfg.enc_seq, cfg.d_model), jnp.float32)
        return out
    # decode: one new token against an S-token KV cache / state.
    return {"tokens": _sds((B, 1), jnp.int32),
            "positions": _sds((B, 1), jnp.int32)}
