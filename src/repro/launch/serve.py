"""Serving driver: batched prefill + decode on host devices (smoke scale).

``python -m repro.launch.serve --arch glm4-9b --batch 4 --prompt-len 32
--gen 16`` prefils a batch of synthetic prompts and decodes greedily.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..archs.registry import ARCH_IDS, build_model, get_smoke_config, \
    get_config
from ..launch.mesh import make_host_mesh
from ..train.serve import make_serve_fns


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="glm4-9b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    api = build_model(cfg)
    mesh = make_host_mesh()
    max_len = args.prompt_len + args.gen + \
        (cfg.n_patches if cfg.family == "vlm" else 0)
    sf = make_serve_fns(api, mesh, batch=args.batch, max_len=max_len)

    rng = np.random.default_rng(args.seed)
    params = api.init(jax.random.PRNGKey(args.seed))
    tokens = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)))
    patches = None
    if cfg.family == "vlm":
        patches = jnp.asarray(rng.normal(
            0, 1, (args.batch, cfg.n_patches, cfg.d_model)), jnp.float32)
    if cfg.family == "audio":
        patches = jnp.asarray(rng.normal(
            0, 1, (args.batch, cfg.enc_seq, cfg.d_model)), jnp.float32)

    cache = api.init_cache(args.batch, max_len)
    t0 = time.time()
    logits, cache = sf.prefill(params, tokens, cache, patches)
    nxt = jnp.argmax(logits[:, -1], -1)
    t_prefill = time.time() - t0
    generated = [np.asarray(nxt)]
    pos0 = args.prompt_len + (cfg.n_patches if cfg.family == "vlm" else 0)
    t0 = time.time()
    for t in range(args.gen - 1):
        pos = jnp.full((args.batch, 1), pos0 + t, jnp.int32)
        logits, cache = sf.decode(params, nxt[:, None], cache, pos)
        nxt = jnp.argmax(logits[:, -1], -1)
        generated.append(np.asarray(nxt))
    t_decode = time.time() - t0
    gen = np.stack(generated, 1)
    print(f"{args.arch}: prefill({args.batch}×{args.prompt_len}) "
          f"{t_prefill*1e3:.0f} ms; {args.gen} decode steps "
          f"{t_decode*1e3:.0f} ms "
          f"({args.batch*(args.gen-1)/max(t_decode,1e-9):.0f} tok/s)")
    print("sample:", gen[0][:12].tolist())


if __name__ == "__main__":
    main()
