import os
# The env reads/write below must run before the first jax-touching import:
# jax locks the host platform device count at first init, so import-time
# module scope is the only place this works — suppressed by design.
_flags = os.environ.get("XLA_FLAGS", "")  # repro: allow[TH003] pre-jax-init by design
_n_dev = os.environ.get("DRYRUN_DEVICES", "512")  # repro: allow[TH003] pre-jax-init by design
os.environ["XLA_FLAGS"] = (  # repro: allow[TH003] pre-jax-init by design
    _flags + " --xla_force_host_platform_device_count=" + _n_dev).strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: ``jax.jit(step).lower(...).compile()`` must succeed on the
single-pod (16, 16) and multi-pod (2, 16, 16) production meshes for every
assigned architecture × input shape, with ``memory_analysis()`` showing the
per-device footprint fits HBM and ``cost_analysis()`` + HLO collective
parsing feeding the §Roofline table.

The XLA_FLAGS assignment above MUST run before any other jax-touching
import — jax locks the device count at first init.  Set DRYRUN_DEVICES to
override (e.g. 8 for a fast sanity pass with a (2,2,2)/(4,2) mesh).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
      --shape train_4k [--multi-pod] [--out results/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..archs.registry import ARCH_IDS, build_model, get_config
from ..launch.hlo_analysis import (collective_bytes, hlo_flops_bytes,
                                   roofline_terms)
from ..launch.shapes import (SHAPES, ShapeCell, cell_applicable,
                             serve_input_specs, train_input_specs)
from ..train.optimizer import OptConfig, opt_init
from ..train.serve import make_serve_fns
from ..train.train_loop import make_train_step

__all__ = ["dryrun_cell", "main", "make_meshes"]


def make_meshes(multi_pod: bool):
    """Production meshes, shrunk proportionally when DRYRUN_DEVICES≠512."""
    n = len(jax.devices())
    if n >= 512:
        shape = (2, 16, 16) if multi_pod else (16, 16)
    elif n >= 8:
        if multi_pod:
            m = n // 2
            a = int(2 ** np.floor(np.log2(np.sqrt(m))))
            shape = (2, max(m // a, 1), a)
        else:
            a = int(2 ** np.floor(np.log2(np.sqrt(n))))
            shape = (max(n // a, 1), a)
    else:
        shape = (1, n) if not multi_pod else (1, 1, n)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def _active_params(cfg, params_shape) -> float:
    """Active parameter count (MoE experts weighted by k/E)."""
    total = 0.0
    frac = cfg.top_k / cfg.n_experts if cfg.n_experts else 1.0
    for kp, x in jax.tree_util.tree_flatten_with_path(params_shape)[0]:
        path = "/".join(str(getattr(k, "key", k)) for k in kp)
        n = float(np.prod(x.shape))
        if any(s in path for s in ("e_gate", "e_up", "e_down")):
            n *= frac
        total += n
    return total


def dryrun_cell(arch_id: str, shape_name: str, *, multi_pod: bool = False,
                accum: Optional[int] = None,
                overrides: Optional[Dict[str, Any]] = None,
                verbose: bool = True) -> Dict[str, Any]:
    cell = SHAPES[shape_name]
    cfg = get_config(arch_id, **(overrides or {}))
    if accum is None:
        accum = cfg.train_accum
    if not cell_applicable(cfg, shape_name):
        return {"arch": arch_id, "shape": shape_name, "status": "skipped",
                "reason": "full-attention arch: long_500k requires "
                          "sub-quadratic attention (DESIGN.md)"}
    mesh = make_meshes(multi_pod)
    api = build_model(cfg)
    t0 = time.perf_counter()
    out: Dict[str, Any] = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "multi_pod": multi_pod, "status": "ok",
    }
    try:
        params_shape = jax.eval_shape(api.init, jax.random.PRNGKey(0))
        if cell.kind == "train":
            batch_sds = train_input_specs(cfg, cell)
            opt_cfg = OptConfig(moment_dtype=cfg.moment_dtype)
            fns = make_train_step(api, mesh, batch_sds, opt_cfg,
                                  accum=accum, donate=True)
            opt_shape = jax.eval_shape(
                lambda p: opt_init(p, opt_cfg), params_shape)
            lowered = fns.step.lower(params_shape, opt_shape, batch_sds)
            tokens = cell.global_batch * cell.seq_len
            flops_factor = 6.0
        else:
            # VLM prefill writes patch + token KV: size the cache for both.
            max_len = cell.seq_len + (cfg.n_patches
                                      if cfg.family == "vlm" else 0)
            sf = make_serve_fns(api, mesh, batch=cell.global_batch,
                                max_len=max_len)
            cache_shape = jax.eval_shape(
                lambda: api.init_cache(cell.global_batch, max_len))
            ins = serve_input_specs(cfg, cell)
            if cell.kind == "prefill":
                lowered = sf.prefill.lower(
                    params_shape, ins["tokens"], cache_shape,
                    ins.get("patches"))
                tokens = cell.global_batch * cell.seq_len
                flops_factor = 2.0
            else:
                lowered = sf.decode.lower(
                    params_shape, ins["tokens"], cache_shape,
                    ins["positions"])
                tokens = cell.global_batch * 1
                flops_factor = 2.0
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        hlo = compiled.as_text()
        coll_total, coll_by_type = collective_bytes(hlo)
        n_chips = int(np.prod(mesh.devices.shape))

        # Loop-aware FLOPs/bytes from the partitioned HLO (cost_analysis
        # does not weight while-loop bodies by trip count — see
        # hlo_analysis.hlo_flops_bytes).  Per-device numbers.
        flops_per_dev, bytes_per_dev, _ = hlo_flops_bytes(hlo)
        flops_total = flops_per_dev * n_chips
        bytes_total = bytes_per_dev * n_chips
        # coll_total is parsed from one device's partitioned module (per-chip
        # link traffic); roofline_terms expects the global total.
        terms = roofline_terms(flops_total, bytes_total,
                               coll_total * n_chips, n_chips)

        n_active = _active_params(cfg, params_shape)
        model_flops = flops_factor * n_active * tokens
        out.update({
            "t_lower_s": round(t_lower, 2),
            "t_compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "code_bytes": mem.generated_code_size_in_bytes,
                "peak_per_device_gb": round(
                    (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                     + mem.output_size_in_bytes) / 1e9, 3),
            },
            "flops_per_device": flops_per_dev,
            "bytes_per_device": bytes_per_dev,
            "collective_bytes_per_device": coll_total,
            "collective_by_type": coll_by_type,
            "roofline": {
                "compute_s": terms.compute_s,
                "memory_s": terms.memory_s,
                "collective_s": terms.collective_s,
                "dominant": terms.dominant,
                "bound_s": terms.bound_s,
            },
            "model_flops": model_flops,
            "n_active_params": n_active,
            "useful_flops_ratio": (model_flops / flops_total
                                   if flops_total else 0.0),
            "tokens_per_step": tokens,
        })
        if verbose:
            r = out["roofline"]
            print(f"[{arch_id} × {shape_name} × {out['mesh']}] "
                  f"compile {t_compile:.1f}s | "
                  f"peak/dev {out['memory']['peak_per_device_gb']:.2f} GB | "
                  f"compute {r['compute_s']*1e3:.2f} ms, "
                  f"memory {r['memory_s']*1e3:.2f} ms, "
                  f"collective {r['collective_s']*1e3:.2f} ms "
                  f"→ {r['dominant']}-bound | "
                  f"useful-FLOPs {out['useful_flops_ratio']:.2f}")
    except Exception as exc:  # noqa: BLE001 — record failures as data
        out["status"] = "error"
        out["error"] = f"{type(exc).__name__}: {exc}"
        out["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[{arch_id} × {shape_name}] FAILED: {out['error']}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--override", action="append", default=[],
                    help="ArchConfig override key=value (repeatable)")
    args = ap.parse_args()

    overrides: Dict[str, Any] = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            overrides[k] = json.loads(v)
        except json.JSONDecodeError:
            overrides[k] = v

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    os.makedirs(args.out, exist_ok=True)
    results = []
    for a, s in cells:
        res = dryrun_cell(a, s, multi_pod=args.multi_pod, accum=args.accum,
                          overrides=overrides)
        results.append(res)
        tag = "mp" if args.multi_pod else "sp"
        with open(os.path.join(args.out, f"{a}_{s}_{tag}.json"), "w") as f:
            json.dump(res, f, indent=1)
    ok = sum(r["status"] == "ok" for r in results)
    sk = sum(r["status"] == "skipped" for r in results)
    print(f"\n{ok} ok, {sk} skipped, {len(results)-ok-sk} failed "
          f"of {len(results)} cells")


if __name__ == "__main__":
    main()
