"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Runs a real training loop on the host devices (smoke scale by default —
this box is CPU-only; the same code path lowers to the production mesh).
Supports checkpoint/restart (--resume), elastic mesh shrink (--devices),
and the WSD schedule.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from ..archs.registry import ARCH_IDS, build_model, get_config, \
    get_smoke_config
from ..data.pipeline import data_iterator
from ..launch.mesh import make_host_mesh
from ..train.optimizer import OptConfig
from ..train.train_loop import train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="minicpm-2b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    api = build_model(cfg)
    mesh = make_host_mesh()
    opt_cfg = OptConfig(lr=args.lr, total_steps=args.steps,
                        warmup_steps=max(args.steps // 20, 1),
                        moment_dtype=cfg.moment_dtype)
    it = data_iterator(cfg, global_batch=args.batch, seq_len=args.seq,
                       seed=args.seed)
    t0 = time.time()
    out = train_loop(api, mesh, it, steps=args.steps, opt_cfg=opt_cfg,
                     accum=args.accum, checkpoint_dir=args.ckpt_dir,
                     checkpoint_every=args.ckpt_every)
    hist = out["history"]
    print(f"\n{args.arch}: {args.steps} steps in {time.time()-t0:.1f}s")
    for h in hist[:3] + hist[-3:]:
        print(f"  step {h['step']:4d} loss {h['loss']:.4f} "
              f"lr {h['lr']:.2e} |g| {h['grad_norm']:.3f}")
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"loss {first:.3f} → {last:.3f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
