"""Post-compile HLO analysis: collective bytes + roofline terms.

``compiled.cost_analysis()`` supplies FLOPs and bytes-accessed but not
collective traffic, so collective bytes are extracted from the optimized
(SPMD-partitioned) HLO text: every all-reduce / all-gather / reduce-scatter
/ all-to-all / collective-permute result shape is summed, and ops living in
while-loop bodies (scan over layers, grad accumulation, Mamba chunks) are
multiplied by the loop trip count recovered from the loop condition's
comparison constant.

Hardware constants: TPU v5e-like — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (values from the assignment).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["HW", "collective_bytes", "RooflineTerms", "roofline_terms"]

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link per chip

HW = {"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW, "ici_bw": ICI_BW}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _computations(hlo: str) -> Dict[str, str]:
    """Split HLO text into computation_name -> body."""
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        m = re.match(r"^%?([\w\.\-~]+)\s*(?:\([^)]*\))?\s*->.*{", line) or \
            re.match(r"^(ENTRY\s+)?%?([\w\.\-~]+)\s*\([^)]*\)\s*->", line)
        if line.rstrip().endswith("{") and ("->" in line or
                                            line.startswith("ENTRY")):
            name_m = re.search(r"%?([\w\.\-~]+)\s*\(", line)
            cur = name_m.group(1) if name_m else None
            if cur:
                comps[cur] = []
            continue
        if cur is not None:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line)
    return {k: "\n".join(v) for k, v in comps.items()}


def _shape_bytes_bf16eq(shape_str: str) -> int:
    """Byte count with f32 tensors priced as bf16.

    The CPU backend we compile on converts bf16 dots to f32, so collectives
    on matmul outputs carry 4-byte elements the TPU build would move as
    bf16 — this variant is the TPU-equivalent traffic estimate."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * min(_DTYPE_BYTES[dt], 2)
    return total


def collective_bytes(hlo: str) -> Tuple[int, Dict[str, int]]:
    """Total collective bytes (call-graph loop-weighted) + per-type split.

    Returned in TPU-equivalent terms (f32 priced as bf16 — see
    ``_shape_bytes_bf16eq``); the raw-f32 total is under key "_raw_f32".
    """
    comps = _computations(hlo)
    weight, _, _ = _comp_weights(hlo, comps)
    total = 0
    raw = 0
    by_type: Dict[str, int] = {}
    for name, body in comps.items():
        w = weight.get(name, 0.0)
        if w <= 0:
            continue
        for m in _COLL_RE.finditer(body):
            b = int(_shape_bytes_bf16eq(m.group(1)) * w)
            raw += int(_shape_bytes(m.group(1)) * w)
            total += b
            op = m.group(2)
            by_type[op] = by_type.get(op, 0) + b
    if not comps:
        for m in _COLL_RE.finditer(hlo):
            b = _shape_bytes_bf16eq(m.group(1))
            total += b
            raw += _shape_bytes(m.group(1))
            by_type[m.group(2)] = by_type.get(m.group(2), 0) + b
    by_type["_raw_f32"] = raw
    return total, by_type


_DEF_RE = re.compile(r"^\s*%?([\w\.\-~]+)\s*=\s*(\([^)]*\)|\S+?)\s+"
                     r"([\w\-]+)\(")
_CALL_EDGE_RE = re.compile(
    r"(?:to_apply|calls|body)=\s*%?([\w\.\-~]+)")
_COND_RE = re.compile(r"condition=\s*%?([\w\.\-~]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

# Ops whose results count as HBM traffic on the TPU target.  The CPU
# backend we compile on materializes many layout/convert/elementwise ops a
# TPU build would fuse, so bytes are counted from a WHITELIST of ops that
# genuinely read+write HBM-resident buffers (matmuls, fusions, data
# movement, reductions); everything else is assumed fused.
_BYTES_COUNT = {"dot", "fusion", "scatter", "gather",
                "dynamic-update-slice", "dynamic-slice", "reduce",
                "reduce-window", "sort", "pad", "concatenate", "slice",
                "convolution", "select-and-scatter", "rng",
                "rng-bit-generator"}


def _parse_dims(s: str):
    return [int(d) for d in s.split(",") if d] if s else []


def _comp_weights(hlo: str, comps: Dict[str, str]
                  ) -> Tuple[Dict[str, float], set, Optional[str]]:
    """Execution weight per computation via the call graph.

    While bodies are weighted by the trip count recovered from the loop
    condition's comparison constant; calls/fusions/branches inherit their
    parent's weight.  Returns (weights, fused-computation names, entry).
    """
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.search(r"%?([\w\.\-~]+)\s*\(", line)
            entry = m.group(1) if m else None
            break

    edges: Dict[str, List[Tuple[str, int]]] = {c: [] for c in comps}
    fused: set = set()
    for name, body in comps.items():
        for m in re.finditer(
                r"while\([^)]*\), condition=%?([\w\.\-~]+), "
                r"body=%?([\w\.\-~]+)", body):
            cond, wbody = m.group(1), m.group(2)
            trip = 1
            consts = [int(c) for c in re.findall(r"constant\((\d+)\)",
                                                 comps.get(cond, ""))]
            if consts:
                trip = max(consts)
            edges[name].append((wbody, trip))
        for m in re.finditer(r"(?:to_apply|calls)=%?([\w\.\-~]+)", body):
            callee = m.group(1)
            edges[name].append((callee, 1))
            if f"calls=%{callee}" in body or f"calls={callee}" in body:
                fused.add(callee)
        for m in _BRANCH_RE.finditer(body):
            for c in m.group(1).split(","):
                edges[name].append((c.strip().lstrip("%"), 1))

    weight: Dict[str, float] = {c: 0.0 for c in comps}
    if entry in weight:
        weight[entry] = 1.0
    for _ in range(12):   # HLO call graphs are shallow; fixpoint quickly
        neww = {c: 0.0 for c in comps}
        if entry in neww:
            neww[entry] = 1.0
        for parent, out_edges in edges.items():
            for callee, trip in out_edges:
                if callee in neww:
                    neww[callee] += weight.get(parent, 0.0) * trip
        if neww == weight:
            break
        weight = neww
    return weight, fused, entry


def hlo_flops_bytes(hlo: str) -> Tuple[float, float, Dict[str, float]]:
    """Loop-aware FLOPs + HBM-bytes estimate from optimized HLO text.

    XLA's ``compiled.cost_analysis()`` does NOT multiply while-loop bodies
    by their trip counts, so an 80-layer scan under-reports FLOPs 80×.
    This walks the computation call graph, counts 2·M·N·K per ``dot`` from
    the operand symbol table, and estimates HBM traffic as 2× the result
    bytes of every materializing top-level op (fusion outputs are buffers;
    fused interiors are skipped).
    """
    comps = _computations(hlo)
    weight, fused, entry = _comp_weights(hlo, comps)

    flops_total = 0.0
    bytes_total = 0.0
    per_comp: Dict[str, float] = {}
    for name, body in comps.items():
        w = weight.get(name, 0.0)
        if w <= 0:
            continue
        # Symbol table: op name -> result shape string.
        sym: Dict[str, str] = {}
        for line in body.splitlines():
            dm = _DEF_RE.match(line)
            if dm:
                sym[dm.group(1)] = dm.group(2)
        comp_flops = 0.0
        comp_bytes = 0.0
        for line in body.splitlines():
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            res_shape, op = dm.group(2), dm.group(3)
            if op == "dot":
                ops_m = re.search(r"dot\(%?([\w\.\-~]+),\s*%?([\w\.\-~]+)\)",
                                  line)
                lc = _LHS_CONTRACT_RE.search(line)
                k = 1
                if ops_m and lc:
                    lhs_shape = sym.get(ops_m.group(1), "")
                    sm = _SHAPE_RE.search(lhs_shape)
                    if sm:
                        dims = _parse_dims(sm.group(2))
                        for d in _parse_dims(lc.group(1)):
                            if d < len(dims):
                                k *= dims[d]
                out_elems = 0
                for smm in _SHAPE_RE.finditer(res_shape):
                    n = 1
                    for d in _parse_dims(smm.group(2)):
                        n *= d
                    out_elems += n
                comp_flops += 2.0 * out_elems * k
            if op in _BYTES_COUNT and name not in fused:
                comp_bytes += 2.0 * _shape_bytes(res_shape)
        flops_total += w * comp_flops
        bytes_total += w * comp_bytes
        if comp_flops:
            per_comp[name] = w * comp_flops
    return flops_total, bytes_total, per_comp


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_hbm: float
    bytes_coll: float
    n_chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def row(self) -> Dict[str, float]:
        return {"compute_s": self.compute_s, "memory_s": self.memory_s,
                "collective_s": self.collective_s, "dominant": self.dominant,
                "flops": self.flops, "bytes_hbm": self.bytes_hbm,
                "bytes_coll": self.bytes_coll}


def roofline_terms(flops_total: float, bytes_total: float,
                   coll_bytes_total: float, n_chips: int) -> RooflineTerms:
    """Three roofline terms in seconds for the whole step across the mesh.

    flops/bytes are *global* (whole-module, all chips) — divided by the
    aggregate peak; collective bytes are per-chip link traffic.
    """
    return RooflineTerms(
        compute_s=flops_total / (n_chips * PEAK_FLOPS),
        memory_s=bytes_total / (n_chips * HBM_BW),
        collective_s=coll_bytes_total / (n_chips * ICI_BW),
        flops=flops_total, bytes_hbm=bytes_total,
        bytes_coll=coll_bytes_total, n_chips=n_chips)
