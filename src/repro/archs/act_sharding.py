"""Activation sharding constraints (GSPMD hints inside model code).

Model code is mesh-agnostic; the launcher registers the active mesh here and
``constrain`` applies ``with_sharding_constraint`` with divisibility-checked
axis fallbacks.  The key consumer is the layer-scan carry: constraining it
to P(('pod','data'), None, 'model') shards the per-layer saved activations
(the dominant train-time residency) across the model axis as well as the
batch axes — without it an 80-layer 8k-wide model stacks ~86 GB of carries
per device.
"""
from __future__ import annotations

import threading
from typing import Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["set_activation_mesh", "get_activation_mesh", "constrain",
           "BATCH_AXES"]

BATCH_AXES: Tuple[str, ...] = ("pod", "data")

_CTX = threading.local()


def set_activation_mesh(mesh, pure_dp: bool = False) -> None:
    _CTX.mesh = mesh
    _CTX.pure_dp = pure_dp


def get_activation_mesh():
    return getattr(_CTX, "mesh", None)


def get_pure_dp() -> bool:
    return getattr(_CTX, "pure_dp", False)


def constrain(x, *spec: Union[None, str, Tuple[str, ...]]):
    """Best-effort sharding constraint; no-op without a registered mesh.

    Each entry is an axis name, a tuple of names, or None; names missing
    from the mesh or not dividing the dim are dropped.
    """
    mesh = get_activation_mesh()
    if mesh is None:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    fixed = []
    for dim, ax in zip(x.shape, spec):
        if ax is None:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        axes = tuple(a for a in axes if a in sizes)
        n = int(np.prod([sizes[a] for a in axes])) if axes else 1
        fixed.append(axes if axes and dim % n == 0 else None)
    if len(fixed) < x.ndim:
        fixed += [None] * (x.ndim - len(fixed))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))
