"""Architecture registry: ``--arch <id>`` → ModelApi."""
from __future__ import annotations

import importlib
from typing import Dict, List

from .common import ArchConfig
from .encdec import build_encdec
from .lm import ModelApi, build_lm

__all__ = ["ARCH_IDS", "get_config", "get_smoke_config", "build_model"]

ARCH_IDS: List[str] = [
    "minicpm-2b",
    "deepseek-coder-33b",
    "glm4-9b",
    "qwen2-72b",
    "dbrx-132b",
    "moonshot-v1-16b-a3b",
    "jamba-1.5-large-398b",
    "rwkv6-1.6b",
    "whisper-base",
    "internvl2-76b",
]


def _module(arch_id: str):
    mod_name = arch_id.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod_name}")


def get_config(arch_id: str, **overrides) -> ArchConfig:
    cfg = _module(arch_id).config()
    return cfg.with_(**overrides) if overrides else cfg


def get_smoke_config(arch_id: str, **overrides) -> ArchConfig:
    cfg = _module(arch_id).smoke_config()
    return cfg.with_(**overrides) if overrides else cfg


def build_model(cfg: ArchConfig) -> ModelApi:
    if cfg.family == "audio":
        return build_encdec(cfg)
    return build_lm(cfg)
