"""Transformer / MoE / Mamba / RWKV blocks (init + apply, cache-aware).

Conventions:
  * ``init_*`` returns a param dict for ONE layer; stacks are built by the
    model assembler with ``jax.vmap`` over a key axis (scan-ready leading L).
  * ``apply_*`` signatures take (cfg, params, x, ...) and optionally a
    per-layer cache dict; they return (y, new_cache).
  * Caches use fixed-capacity buffers + a scalar ``len`` so decode steps are
    shape-static under jit.
  * Attention uses an einsum path by default (GSPMD-friendly; what the
    dry-run rooflines) and the Pallas flash kernel when ``cfg.use_flash``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import ArchConfig, DTYPES, init_dense, rmsnorm, rope

Params = Dict[str, Any]
NEG = -1e30


# ---------------------------------------------------------------------------
# Attention (GQA + RoPE + optional sliding window)
# ---------------------------------------------------------------------------

def init_attention(key: jax.Array, cfg: ArchConfig) -> Params:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    dt = DTYPES[cfg.dtype]
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_dense(ks[0], (d, hq * dh), dt),
        "wk": init_dense(ks[1], (d, hkv * dh), dt),
        "wv": init_dense(ks[2], (d, hkv * dh), dt),
        "wo": init_dense(ks[3], (hq * dh, d), dt,
                         scale=1.0 / np.sqrt(hq * dh * 2 * cfg.n_layers)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * dh,), dt)
        p["bk"] = jnp.zeros((hkv * dh,), dt)
        p["bv"] = jnp.zeros((hkv * dh,), dt)
    return p


# Above this many logit elements the einsum path switches to the KV/Q
# chunked online-softmax path (flash-style in jnp — the HLO the dry-run
# rooflines; the Pallas kernel is the TPU execution path).
_CHUNK_THRESHOLD = 1 << 26


def _shard_attn_acts(x: jnp.ndarray) -> jnp.ndarray:
    """Shard (B, H, S, D) attention activations: heads→model when the head
    count divides the axis, else sequence→model (sequence parallelism);
    pure-DP jobs shard batch over the whole mesh instead."""
    from .act_sharding import (BATCH_AXES, constrain, get_activation_mesh,
                               get_pure_dp)
    mesh = get_activation_mesh()
    if mesh is None:
        return x
    if get_pure_dp():
        return constrain(x, BATCH_AXES + ("model",), None, None, None)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m = sizes.get("model", 1)
    if x.shape[1] % m == 0:
        return constrain(x, BATCH_AXES, "model", None, None)
    if x.shape[2] % m == 0:
        return constrain(x, BATCH_AXES, None, "model", None)
    return constrain(x, BATCH_AXES, None, None, None)


def _attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
            causal: bool, window: int, kv_len: Optional[jnp.ndarray],
            q_start=None, use_flash: bool) -> jnp.ndarray:
    """q: (B, Hq, Sq, Dh); k/v: (B, Hkv, Skv, Dh) → (B, Hq, Sq, Dh).

    ``q_start`` is the absolute key-index of query row 0 (defaults to the
    aligned-ends convention Skv − Sq); ``kv_len`` masks cache slots ≥ len.
    """
    B, Hq, Sq, Dh = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    if use_flash and kv_len is None and window == 0:
        from ..kernels.flash_attention.ops import flash_attention
        return flash_attention(q, k, v, causal=causal)
    q = _shard_attn_acts(q)
    if Sq * Skv > _CHUNK_THRESHOLD and Sq > 1:
        return _attend_chunked(q, k, v, causal=causal, window=window,
                               kv_len=kv_len, q_start=q_start)
    group = Hq // Hkv
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kr.astype(jnp.float32)) / np.sqrt(Dh)
    # Additive (S, S) f32 mask: a broadcasted add keeps backward trivial —
    # a `where` with a (B, H, Sq, Skv) predicate would materialize a pred
    # buffer of the full logits shape in the residuals (terabytes at 4k²).
    qi = jnp.arange(Sq)[:, None]
    kj = jnp.arange(Skv)[None, :]
    if q_start is None:
        q_start = Skv - Sq
    add = jnp.zeros((Sq, Skv), jnp.float32)
    if causal:
        add = add + jnp.where(kj <= qi + q_start, 0.0, NEG)
    if window > 0:
        add = add + jnp.where(kj > qi + q_start - window, 0.0, NEG)
    if kv_len is not None:                      # decode: valid cache prefix
        add = add + jnp.where(kj < kv_len, 0.0, NEG)
    logits = logits + add[None, None]
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", w, vr.astype(jnp.float32))
    return out.astype(q.dtype)


def _attend_chunked(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool, window: int, kv_len, q_start,
                    bq: int = 1024, bk: int = 4096) -> jnp.ndarray:
    """Online-softmax attention, chunked over Q and KV (flash in jnp).

    Logit residency drops from O(Sq·Skv) to O(bq·bk) per step — the memory
    shape the Pallas kernel has on real TPUs; XLA sees the same tiling via
    the double scan, so the dry-run rooflines the right working set.  Both
    bodies are checkpointed so training backward recomputes chunk logits.
    """
    B, Hq, Sq, Dh = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    if q_start is None:
        q_start = Skv - Sq
    sq_pad = (-Sq) % bq
    sk_pad = (-Skv) % bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sq_pad), (0, 0)))
    kp = jnp.pad(kr, ((0, 0), (0, 0), (0, sk_pad), (0, 0)))
    vp = jnp.pad(vr, ((0, 0), (0, 0), (0, sk_pad), (0, 0)))
    nq, nk = qp.shape[2] // bq, kp.shape[2] // bk
    scale = 1.0 / np.sqrt(Dh)
    limit = kv_len if kv_len is not None else Skv

    kc = kp.reshape(B, Hq, nk, bk, Dh).transpose(2, 0, 1, 3, 4)
    vc = vp.reshape(B, Hq, nk, bk, Dh).transpose(2, 0, 1, 3, 4)

    def q_body(qi0, qcb):
        qf = qcb.astype(jnp.float32)

        def kv_body(carry, inp):
            m_prev, l_prev, acc = carry
            kj0, kcb, vcb = inp
            s = jnp.einsum("bhqd,bhkd->bhqk", qf,
                           kcb.astype(jnp.float32)) * scale  # (B,H,bq,bk)
            qi = qi0 + jnp.arange(bq)[:, None]
            kj = kj0 + jnp.arange(bk)[None, :]
            add = jnp.where(kj < limit, 0.0, NEG)
            if causal:
                add = add + jnp.where(kj <= qi + q_start, 0.0, NEG)
            if window > 0:
                add = add + jnp.where(kj > qi + q_start - window, 0.0, NEG)
            s = s + add[None, None]
            m_cur = jnp.maximum(m_prev, s.max(-1))
            p = jnp.exp(s - m_cur[..., None])
            alpha = jnp.exp(m_prev - m_cur)
            l_cur = l_prev * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, vcb.astype(jnp.float32))
            return (m_cur, l_cur, acc), None

        m0 = jnp.full((B, Hq, bq), NEG)
        l0 = jnp.zeros((B, Hq, bq))
        a0 = jnp.zeros((B, Hq, bq, Dh))
        kj0s = jnp.arange(nk) * bk
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(kv_body),
                                      (m0, l0, a0), (kj0s, kc, vc))
        return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    # Chunk the query axis with a checkpointed scan.
    qcs = qp.reshape(B, Hq, nq, bq, Dh).transpose(2, 0, 1, 3, 4)
    qi0s = jnp.arange(nq) * bq

    def q_scan_body(_, inp):
        qi0, qcb = inp
        return None, q_body(qi0, qcb)

    _, outs = jax.lax.scan(jax.checkpoint(q_scan_body), None, (qi0s, qcs))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(B, Hq, nq * bq, Dh)
    return out[:, :, :Sq]


def apply_attention(cfg: ArchConfig, p: Params, x: jnp.ndarray,
                    positions: jnp.ndarray,
                    cache: Optional[Params] = None,
                    xattn_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                    causal: bool = True
                    ) -> Tuple[jnp.ndarray, Optional[Params]]:
    """Self- (or cross-) attention with optional KV cache.

    cache: {"k": (B, Hkv, C, Dh), "v": ..., "len": ()} — decode appends at
    ``len`` and attends the valid prefix.  xattn_kv supplies precomputed
    encoder K/V for cross-attention (whisper decoder).
    """
    B, S, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(B, S, hq, dh)
    q = rope(q, positions, cfg.rope_theta).transpose(0, 2, 1, 3)

    if xattn_kv is not None:
        k, v = xattn_kv
        y = _attend(q, k, v, causal=False, window=0, kv_len=None,
                    use_flash=cfg.use_flash)
        out = y.transpose(0, 2, 1, 3).reshape(B, S, hq * dh) @ p["wo"]
        return out, cache

    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bk" in p:
        k = k + p["bk"]
        v = v + p["bv"]
    k = k.reshape(B, S, hkv, dh)
    v = v.reshape(B, S, hkv, dh)
    k = rope(k, positions, cfg.rope_theta)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)

    if cache is None:
        y = _attend(q, k, v, causal=causal, window=cfg.window, kv_len=None,
                    use_flash=cfg.use_flash)
        new_cache = {"k": k, "v": v,
                     "len": jnp.asarray(S, jnp.int32)}
        y = y.transpose(0, 2, 1, 3).reshape(B, S, hq * dh)
        return y @ p["wo"], new_cache

    # Cache path: append S new entries at cache["len"] (prefill-into-buffer
    # when S > 1, single-token decode when S == 1).
    C = cache["k"].shape[2]
    idx = cache["len"]
    if S >= C:
        # Windowed prefill longer than the (rolling) cache: attend over the
        # in-flight K/V and retain only the last C entries.
        y = _attend(q, k, v, causal=causal, window=cfg.window, kv_len=None,
                    use_flash=cfg.use_flash)
        y = y.transpose(0, 2, 1, 3).reshape(B, S, hq * dh)
        new_cache = {"k": k[:, :, S - C:], "v": v[:, :, S - C:],
                     "len": jnp.asarray(C, jnp.int32)}
        return y @ p["wo"], new_cache
    ck = jax.lax.dynamic_update_slice(
        cache["k"], k, (0, 0, idx, 0))
    cv = jax.lax.dynamic_update_slice(
        cache["v"], v, (0, 0, idx, 0))
    kv_len = idx + S
    y = _attend(q, ck, cv, causal=causal, q_start=idx,
                window=cfg.window, kv_len=kv_len, use_flash=False)
    y = y.transpose(0, 2, 1, 3).reshape(B, S, hq * dh)
    return y @ p["wo"], {"k": ck, "v": cv, "len": kv_len}


# ---------------------------------------------------------------------------
# Dense MLP (SwiGLU)
# ---------------------------------------------------------------------------

def init_mlp(key: jax.Array, cfg: ArchConfig) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    dt = DTYPES[cfg.dtype]
    ks = jax.random.split(key, 3)
    return {
        "w_gate": init_dense(ks[0], (d, f), dt),
        "w_up": init_dense(ks[1], (d, f), dt),
        "w_down": init_dense(ks[2], (f, d), dt,
                             scale=1.0 / np.sqrt(f * 2 * cfg.n_layers)),
    }


def apply_mlp(cfg: ArchConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


# ---------------------------------------------------------------------------
# MoE MLP (top-k dispatch with capacity, GSPMD expert parallelism)
# ---------------------------------------------------------------------------

def init_moe(key: jax.Array, cfg: ArchConfig) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = DTYPES[cfg.dtype]
    ks = jax.random.split(key, 4)
    return {
        "router": init_dense(ks[0], (d, e), jnp.float32),
        "e_gate": init_dense(ks[1], (e, d, f), dt),
        "e_up": init_dense(ks[2], (e, d, f), dt),
        "e_down": init_dense(ks[3], (e, f, d), dt,
                             scale=1.0 / np.sqrt(f * 2 * cfg.n_layers)),
    }


def _expert_ffn(p: Params, xe: jnp.ndarray) -> jnp.ndarray:
    """(E, C, D) per-expert SwiGLU FFN → (E, C, D)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["e_gate"])) \
        * jnp.einsum("ecd,edf->ecf", xe, p["e_up"])
    return jnp.einsum("ecf,efd->ecd", h, p["e_down"])


def apply_moe(cfg: ArchConfig, p: Params, x: jnp.ndarray,
              impl: str = "sort") -> jnp.ndarray:
    """Top-k capacity MoE.

    ``sort`` (default): argsort-dispatch — token slots are sorted by expert
    id, ranked within expert (capacity C = T·k/E·cf), scattered into an
    (E, C, D) buffer, run through per-expert SwiGLU einsums (MXU-shaped;
    all-to-all under expert sharding), and combined back with gate weights.
    Memory is O(T·k·D + E·C·D) — independent of the E×C cross product that
    makes one-hot dispatch einsums infeasible for E=64 at 1M tokens.

    ``einsum``: the classic (G, S, E, C) one-hot dispatch (kept for small
    configs and cross-validation tests).
    """
    G, S, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    gates = jax.nn.softmax(
        (x.astype(jnp.float32) @ p["router"]), axis=-1)        # (G, S, E)
    gval, gidx = jax.lax.top_k(gates, k)                       # (G, S, k)
    gval = gval / jnp.maximum(gval.sum(-1, keepdims=True), 1e-9)

    if impl == "einsum":
        C = min(int(np.ceil(S * k / e * cfg.capacity_factor)), S)
        onehot = jax.nn.one_hot(gidx, e, dtype=jnp.float32)    # (G, S, k, E)
        flat = onehot.reshape(G, S * k, e)
        pos = (jnp.cumsum(flat, axis=1) - 1.0).reshape(G, S, k, e)
        within = (pos < C) & (onehot > 0)
        slot = jnp.where(within, pos, 0).astype(jnp.int32)
        slot_oh = jax.nn.one_hot(slot, C, dtype=x.dtype) \
            * within.astype(x.dtype)[..., None]                # (G,S,k,E,C)
        dispatch = slot_oh.sum(2)                              # (G, S, E, C)
        combine = (slot_oh * gval.astype(x.dtype)[..., None, None]).sum(2)
        xe = jnp.einsum("gsec,gsd->gecd", dispatch, x)
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["e_gate"])) \
            * jnp.einsum("gecd,edf->gecf", xe, p["e_up"])
        ye = jnp.einsum("gecf,efd->gecd", h, p["e_down"])
        return jnp.einsum("gsec,gecd->gsd", combine, ye)

    # ---- sort-based dispatch, group-local ------------------------------------
    # The sort/scatter runs independently per group (vmap over G) so GSPMD
    # keeps it local to each batch shard — a single global sort would be
    # replicated/communicated across the whole mesh.  Per-group capacity
    # C = S·k/E·cf; the (G, E, C, D) buffers then meet the model-sharded
    # expert weights in the FFN einsum (all-to-all under expert parallelism).
    C = int(np.ceil(S * k / e * cfg.capacity_factor))

    def dispatch_group(xg, gi, gv):
        e_flat = gi.reshape(S * k)
        w_flat = gv.reshape(S * k).astype(x.dtype)
        order = jnp.argsort(e_flat)                    # stable
        tok = order // k
        e_sorted = e_flat[order]
        w_sorted = w_flat[order]
        starts = jnp.searchsorted(e_sorted, jnp.arange(e))
        pos = jnp.arange(S * k) - starts[e_sorted]
        keep = pos < C
        pos_c = jnp.where(keep, pos, C)                # OOB slot → dropped
        src = xg[tok] * keep[:, None].astype(x.dtype)
        # 3-D scatter keeps the expert dim visible so GSPMD can shard the
        # buffer over the expert-parallel axis (a flat (E·C, D) scatter
        # forces full replication over 'model').
        buf = jnp.zeros((e, C + 1, d), x.dtype).at[e_sorted, pos_c].add(
            src, mode="drop")[:, :C]
        return buf, (tok, e_sorted, pos_c, w_sorted, keep)

    buf, aux = jax.vmap(dispatch_group)(x, gidx, gval)     # (G, E, C, D)

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["e_gate"])) \
        * jnp.einsum("gecd,edf->gecf", buf, p["e_up"])
    ye = jnp.einsum("gecf,efd->gecd", h, p["e_down"])      # (G, E, C, D)

    def combine_group(yeg, auxg):
        tok, e_sorted, pos_c, w_sorted, keep = auxg
        contrib = yeg[e_sorted, jnp.minimum(pos_c, C - 1)] \
            * (w_sorted * keep.astype(x.dtype))[:, None]
        return jnp.zeros((S, d), x.dtype).at[tok].add(contrib, mode="drop")

    return jax.vmap(combine_group)(ye, aux)


# ---------------------------------------------------------------------------
# Mamba (S6 selective scan, chunked)
# ---------------------------------------------------------------------------

def init_mamba(key: jax.Array, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    din = cfg.expand * d
    n = cfg.d_state
    dt_rank = max(d // 16, 1)
    dt = DTYPES[cfg.dtype]
    ks = jax.random.split(key, 6)
    return {
        "in_proj": init_dense(ks[0], (d, 2 * din), dt),
        "conv_w": init_dense(ks[1], (din, cfg.d_conv), dt, scale=0.5),
        "x_proj": init_dense(ks[2], (din, dt_rank + 2 * n), dt),
        "dt_proj": init_dense(ks[3], (dt_rank, din), dt),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32),
                                  (din, 1))),
        "D": jnp.ones((din,), jnp.float32),
        "out_proj": init_dense(ks[5], (din, d), dt,
                               scale=1.0 / np.sqrt(din * 2 * cfg.n_layers)),
    }


def _selective_scan_chunk(A: jnp.ndarray, Bx: jnp.ndarray,
                          h0: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """h_t = A_t ⊙ h_{t-1} + Bx_t over a chunk via associative scan.

    A, Bx: (B, T, din, N) f32; h0: (B, din, N).  Returns (h_all, h_last).
    """
    def comb(a, b):
        a1, x1 = a
        a2, x2 = b
        return a1 * a2, x2 + a2 * x1
    aa, hh = jax.lax.associative_scan(comb, (A, Bx), axis=1)
    h_all = hh + aa * h0[:, None]
    return h_all, h_all[:, -1]


def apply_mamba(cfg: ArchConfig, p: Params, x: jnp.ndarray,
                state: Optional[Params] = None, chunk: int = 256
                ) -> Tuple[jnp.ndarray, Params]:
    """x: (B, S, D).  state: {"h": (B, din, N), "conv": (B, k-1, din)}."""
    B, S, d = x.shape
    din = cfg.expand * d
    n = cfg.d_state
    dt_rank = max(d // 16, 1)
    xz = x @ p["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)                      # (B, S, din)

    # Depthwise causal conv (k taps) with carried context.
    kk = cfg.d_conv
    if state is not None:
        ctx = state["conv"]
    else:
        ctx = jnp.zeros((B, kk - 1, din), xs.dtype)
    xpad = jnp.concatenate([ctx, xs], axis=1)
    conv = sum(xpad[:, i:i + S] * p["conv_w"][:, i] for i in range(kk))
    new_conv = xpad[:, -(kk - 1):] if kk > 1 else ctx
    u = jax.nn.silu(conv)                                  # (B, S, din)

    proj = u @ p["x_proj"]
    dt_in, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    delta = jax.nn.softplus(dt_in @ p["dt_proj"]).astype(jnp.float32)
    A = -jnp.exp(p["A_log"])                               # (din, N)
    dA = jnp.exp(delta[..., None] * A)                     # (B, S, din, N)
    dBx = (delta * u.astype(jnp.float32))[..., None] \
        * Bc.astype(jnp.float32)[..., None, :]             # (B, S, din, N)

    h0 = state["h"] if state is not None else jnp.zeros((B, din, n),
                                                        jnp.float32)
    n_chunks = max(S // chunk, 1)
    if S % chunk == 0 and n_chunks > 1:
        dA_c = dA.reshape(B, n_chunks, chunk, din, n).transpose(1, 0, 2, 3, 4)
        dBx_c = dBx.reshape(B, n_chunks, chunk, din, n).transpose(1, 0, 2, 3, 4)

        def chunk_step(h, ab):
            h_all, h_last = _selective_scan_chunk(ab[0], ab[1], h)
            return h_last, h_all
        # Carry h across chunks sequentially; parallel scan within chunks
        # bounds the materialized state to (B, chunk, din, N).
        h_last, h_seq = jax.lax.scan(chunk_step, h0, (dA_c, dBx_c))
        h_all = h_seq.transpose(1, 0, 2, 3, 4).reshape(B, S, din, n)
    else:
        h_all, h_last = _selective_scan_chunk(dA, dBx, h0)

    y = jnp.einsum("bsdn,bsn->bsd", h_all, Cc.astype(jnp.float32))
    y = y + u.astype(jnp.float32) * p["D"]
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    return y, {"h": h_last, "conv": new_conv}


# ---------------------------------------------------------------------------
# RWKV6 (Finch): data-dependent decay linear attention + channel mix
# ---------------------------------------------------------------------------

def init_rwkv(key: jax.Array, cfg: ArchConfig) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    dt = DTYPES[cfg.dtype]
    ks = jax.random.split(key, 8)
    return {
        "r_proj": init_dense(ks[0], (d, d), dt),
        "k_proj": init_dense(ks[1], (d, d), dt),
        "v_proj": init_dense(ks[2], (d, d), dt),
        "g_proj": init_dense(ks[3], (d, d), dt),
        "w_proj": init_dense(ks[4], (d, d), dt, scale=0.1),
        "w_bias": jnp.full((d,), -2.0, jnp.float32),
        "o_proj": init_dense(ks[5], (d, d), dt,
                             scale=1.0 / np.sqrt(d * 2 * cfg.n_layers)),
        "mu_r": jnp.full((d,), 0.5, dt),
        "mu_k": jnp.full((d,), 0.5, dt),
        "mu_v": jnp.full((d,), 0.5, dt),
        "mu_w": jnp.full((d,), 0.5, dt),
        "ck_proj": init_dense(ks[6], (d, f), dt),
        "cv_proj": init_dense(ks[7], (f, d), dt,
                              scale=1.0 / np.sqrt(f * 2 * cfg.n_layers)),
    }


def apply_rwkv_time(cfg: ArchConfig, p: Params, x: jnp.ndarray,
                    state: Optional[Params] = None, chunk: int = 128
                    ) -> Tuple[jnp.ndarray, Params]:
    """RWKV6 time-mix.  x: (B, S, D).

    state: {"S": (B, H, Dh, Dh) wkv state, "x_prev": (B, 1, D)}.
    Matrix-valued state S accumulates kᵀv with per-channel data-dependent
    decay w_t (the Finch upgrade over static decay).
    """
    B, S, d = x.shape
    dh = cfg.rwkv_head_dim
    H = d // dh
    x_prev = state["x_prev"] if state is not None else \
        jnp.zeros((B, 1, d), x.dtype)
    xs = jnp.concatenate([x_prev, x[:, :-1]], axis=1)      # token shift

    def mix(mu):
        return x + (xs - x) * mu
    r = (mix(p["mu_r"]) @ p["r_proj"]).reshape(B, S, H, dh)
    k = (mix(p["mu_k"]) @ p["k_proj"]).reshape(B, S, H, dh)
    v = (mix(p["mu_v"]) @ p["v_proj"]).reshape(B, S, H, dh)
    g = jax.nn.silu(x @ p["g_proj"])
    w = jnp.exp(-jnp.exp((mix(p["mu_w"]) @ p["w_proj"]).astype(jnp.float32)
                         + p["w_bias"]))                   # (B, S, D) decay
    w = w.reshape(B, S, H, dh)

    S0 = state["S"] if state is not None else \
        jnp.zeros((B, H, dh, dh), jnp.float32)

    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    rf = r.astype(jnp.float32)

    if cfg.rwkv_impl == "chunked" and S > 1 and S % cfg.rwkv_chunk == 0:
        y, S_last = rwkv_wkv_chunked(w, kf, vf, rf, S0, chunk=cfg.rwkv_chunk)
        y = y.reshape(B, S, d)
    else:
        def step(Sm, inp):
            wt, kt, vt, rt = inp                 # (B, H, dh) each
            out = jnp.einsum("bhk,bhkv->bhv", rt, Sm)
            Sm = Sm * wt[..., None] + kt[..., None] * vt[..., None, :]
            return Sm, out
        S_last, y = jax.lax.scan(
            step, S0,
            (w.transpose(1, 0, 2, 3), kf.transpose(1, 0, 2, 3),
             vf.transpose(1, 0, 2, 3), rf.transpose(1, 0, 2, 3)))
        y = y.transpose(1, 0, 2, 3).reshape(B, S, d)
    y = (y.astype(x.dtype) * g) @ p["o_proj"]
    return y, {"S": S_last, "x_prev": x[:, -1:]}


def apply_rwkv_channel(cfg: ArchConfig, p: Params, x: jnp.ndarray
                       ) -> jnp.ndarray:
    h = jnp.square(jax.nn.relu(x @ p["ck_proj"]))
    return h @ p["cv_proj"]


def rwkv_wkv_chunked(w: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     r: jnp.ndarray, S0: jnp.ndarray, chunk: int = 64
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked-parallel WKV recurrence (the TPU-native RWKV form).

    Replaces the per-timestep scan (which materializes the matrix state S
    times and is hopelessly HBM-bound) with the GLA/RWKV chunk form: within
    a chunk of C steps the decay-weighted interactions become two MXU
    matmuls via log-space decay rescaling; the matrix state is carried only
    across S/C chunk boundaries.  Chunk size bounds the exp() dynamic range
    (C·|log w| ≤ ~40 in f32 for C = 64).

    w, k, v, r: (B, S, H, Dh) with w ∈ (0, 1); S0: (B, H, Dh, Dh).
    Returns (out (B, S, H, Dh), S_last).
    """
    B, S, H, Dh = k.shape
    C = min(chunk, S)
    assert S % C == 0, (S, C)
    n_chunks = S // C

    def to_chunks(x):
        return x.reshape(B, n_chunks, C, H, Dh).transpose(1, 0, 3, 2, 4)
    wc, kc, vc, rc = map(to_chunks, (w, k, v, r))   # (N, B, H, C, Dh)
    logw = jnp.log(jnp.clip(wc.astype(jnp.float32), 1e-12, 1.0))
    # L[t] = Σ_{u≤t} log w_u within the chunk (inclusive).
    L = jnp.cumsum(logw, axis=3)                    # (N, B, H, C, Dh)

    def chunk_step(Sm, inp):
        Lc, kcb, vcb, rcb = inp                     # (B, H, C, Dh)
        kf = kcb.astype(jnp.float32)
        vf = vcb.astype(jnp.float32)
        rf = rcb.astype(jnp.float32)
        # Σ_{u<t} convention: state S_prev contributes with decay through
        # steps 1..t-1 → exp(L_{t-1}); within-chunk pair (s < t) decays
        # exp(L_{t-1} - L_s).
        Lprev = jnp.concatenate(
            [jnp.zeros_like(Lc[..., :1, :]), Lc[..., :-1, :]], axis=2)
        r_dec = rf * jnp.exp(Lprev)                  # (B, H, C, Dh)
        k_dec = kf * jnp.exp(-Lc)
        att = jnp.einsum("bhtd,bhsd->bhts", r_dec, k_dec)
        tri = jnp.tril(jnp.ones((C, C), jnp.float32), k=-1)
        att = att * tri
        intra = jnp.einsum("bhts,bhsd->bhtd", att, vf)
        inter = jnp.einsum("bhtd,bhdv->bhtv", r_dec, Sm)
        out = intra + inter
        # State to chunk end: decay through the whole chunk.
        Lend = Lc[..., -1:, :]
        S_new = Sm * jnp.exp(Lend[..., 0, :, None]) + jnp.einsum(
            "bhsd,bhsv->bhdv", kf * jnp.exp(Lend - Lc), vf)
        return S_new, out

    S_last, outs = jax.lax.scan(chunk_step, S0, (L, kc, vc, rc))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, Dh)
    return out, S_last
