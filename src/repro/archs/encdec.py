"""Encoder–decoder LM (whisper-base backbone).

The audio conv frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, enc_seq, D); the encoder is a
bidirectional transformer over them, the decoder a causal transformer with
cross-attention.  Decoder self-attention KV is cached for decode; encoder
output is computed at prefill and carried in the cache.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .blocks import (apply_attention, apply_mlp, init_attention, init_mlp)
from .common import ArchConfig, DTYPES, init_dense, rmsnorm
from .lm import ModelApi, _stack_init

Params = Dict[str, Any]

__all__ = ["build_encdec"]


def _xattn_init(key: jax.Array, cfg: ArchConfig) -> Params:
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    dt = DTYPES[cfg.dtype]
    ks = jax.random.split(key, 4)
    return {"wq": init_dense(ks[0], (d, h * dh), dt),
            "wk": init_dense(ks[1], (d, h * dh), dt),
            "wv": init_dense(ks[2], (d, h * dh), dt),
            "wo": init_dense(ks[3], (h * dh, d), dt,
                             scale=1.0 / np.sqrt(h * dh * 2 * cfg.n_layers))}


def _xattn_apply(cfg: ArchConfig, p: Params, x: jnp.ndarray,
                 enc_out: jnp.ndarray) -> jnp.ndarray:
    B, S, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    Se = enc_out.shape[1]
    q = (x @ p["wq"]).reshape(B, S, h, dh).transpose(0, 2, 1, 3)
    k = (enc_out @ p["wk"]).reshape(B, Se, h, dh).transpose(0, 2, 1, 3)
    v = (enc_out @ p["wv"]).reshape(B, Se, h, dh).transpose(0, 2, 1, 3)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(dh)
    w = jax.nn.softmax(logits, axis=-1)
    y = jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32))
    y = y.astype(x.dtype).transpose(0, 2, 1, 3).reshape(B, S, h * dh)
    return y @ p["wo"]


def _enc_layer_init(key: jax.Array, cfg: ArchConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {"ln_attn": jnp.ones((cfg.d_model,), jnp.float32),
            "ln_mlp": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": init_attention(k1, cfg),
            "mlp": init_mlp(k2, cfg)}


def _dec_layer_init(key: jax.Array, cfg: ArchConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln_attn": jnp.ones((cfg.d_model,), jnp.float32),
            "ln_x": jnp.ones((cfg.d_model,), jnp.float32),
            "ln_mlp": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": init_attention(k1, cfg),
            "xattn": _xattn_init(k2, cfg),
            "mlp": init_mlp(k3, cfg)}


def build_encdec(cfg: ArchConfig) -> ModelApi:
    dt = DTYPES[cfg.dtype]

    def init(key: jax.Array) -> Params:
        k1, k2, k3, k4 = jax.random.split(key, 4)
        return {
            "embed": init_dense(k1, (cfg.vocab, cfg.d_model), dt, 0.02),
            "lm_head": init_dense(k2, (cfg.d_model, cfg.vocab), dt),
            "norm_f": jnp.ones((cfg.d_model,), jnp.float32),
            "norm_enc": jnp.ones((cfg.d_model,), jnp.float32),
            "enc_layers": _stack_init(lambda k: _enc_layer_init(k, cfg),
                                      k3, cfg.enc_layers),
            "dec_layers": _stack_init(lambda k: _dec_layer_init(k, cfg),
                                      k4, cfg.n_layers),
        }

    def encode(params: Params, frames: jnp.ndarray) -> jnp.ndarray:
        B, Se, _ = frames.shape
        x = frames.astype(dt)
        pos = jnp.broadcast_to(jnp.arange(Se), (B, Se))

        def body(carry, lp):
            h, _ = apply_attention(
                cfg, lp["attn"], rmsnorm(carry, lp["ln_attn"], cfg.norm_eps),
                pos, causal=False)
            y = carry + h
            y = y + apply_mlp(cfg, lp["mlp"],
                              rmsnorm(y, lp["ln_mlp"], cfg.norm_eps))
            return y, None
        if cfg.remat == "block":
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
        return rmsnorm(x, params["norm_enc"], cfg.norm_eps)

    def forward(params: Params, tokens: jnp.ndarray,
                patches: Optional[jnp.ndarray] = None,   # = frames
                caches=None, positions: Optional[jnp.ndarray] = None,
                last_only: bool = False
                ) -> Tuple[jnp.ndarray, Any]:
        B, S = tokens.shape
        if patches is not None:
            # Fresh frames → (re)encode; otherwise reuse the cached encoder
            # output from prefill.
            enc_out = encode(params, patches)
            dec_caches = None if caches is None else caches["dec"]
        else:
            assert caches is not None and "enc_out" in caches, \
                "decode without frames requires a prefilled cache"
            enc_out = caches["enc_out"]
            dec_caches = caches["dec"]
        x = params["embed"][tokens]
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))

        def body(carry, inp):
            lp, lc = inp
            h, nc = apply_attention(
                cfg, lp["attn"], rmsnorm(carry, lp["ln_attn"], cfg.norm_eps),
                positions, cache=lc)
            y = carry + h
            y = y + _xattn_apply(cfg, lp["xattn"],
                                 rmsnorm(y, lp["ln_x"], cfg.norm_eps),
                                 enc_out)
            y = y + apply_mlp(cfg, lp["mlp"],
                              rmsnorm(y, lp["ln_mlp"], cfg.norm_eps))
            return y, nc
        if cfg.remat == "block":
            body = jax.checkpoint(body)
        x, new_dec = jax.lax.scan(body, x, (params["dec_layers"], dec_caches))
        if last_only:
            x = x[:, -1:]
        x = rmsnorm(x, params["norm_f"], cfg.norm_eps)
        logits = x @ params["lm_head"]
        return logits, {"enc_out": enc_out, "dec": new_dec}

    def loss(params: Params, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        logits, _ = forward(params, batch["tokens"],
                            patches=batch["patches"])
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        mask = (labels >= 0).astype(jnp.float32)
        return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)

    def init_cache(batch: int, max_len: int):
        hkv, dh = cfg.n_kv, cfg.head_dim
        attn = {"k": jnp.zeros((batch, hkv, max_len, dh), dt),
                "v": jnp.zeros((batch, hkv, max_len, dh), dt),
                "len": jnp.zeros((), jnp.int32)}
        dec = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), attn)
        return {"enc_out": jnp.zeros((batch, cfg.enc_seq, cfg.d_model), dt),
                "dec": dec}

    return ModelApi(cfg=cfg, init=init, forward=forward, loss=loss,
                    init_cache=init_cache)
