"""Decoder-only language models: dense / MoE / hybrid (jamba) / RWKV / VLM.

Layers are scan-stacked (leading L axis) for compact HLO and fast multi-pod
compilation; hybrid models scan over *groups* (one attention layer + 7 Mamba
layers with alternating dense/MoE MLPs — the Jamba 1:7 interleave) so the
stack stays homogeneous.  ``remat="block"`` wraps each scanned body in
``jax.checkpoint`` — the activation-memory knob the cluster autotuner tunes.

The public surface is :class:`ModelApi`: init / forward / loss / init_cache,
all pure functions safe under ``jax.eval_shape`` (the multi-pod dry-run never
materializes parameters).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .act_sharding import BATCH_AXES, constrain
from .blocks import (apply_attention, apply_mamba, apply_mlp, apply_moe,
                     apply_rwkv_channel, apply_rwkv_time, init_attention,
                     init_mamba, init_mlp, init_moe, init_rwkv)
from .common import ArchConfig, DTYPES, init_dense, rmsnorm

Params = Dict[str, Any]

__all__ = ["ModelApi", "build_lm"]


@dataclasses.dataclass
class ModelApi:
    cfg: ArchConfig
    init: Callable[[jax.Array], Params]
    forward: Callable[..., Tuple[jnp.ndarray, Any]]
    loss: Callable[[Params, Dict[str, jnp.ndarray]], jnp.ndarray]
    init_cache: Callable[[int, int], Any]


def _stack_init(fn: Callable[[jax.Array], Params], key: jax.Array,
                n: int) -> Params:
    return jax.vmap(fn)(jax.random.split(key, n))


def _tree_idx(tree: Params, i) -> Params:
    return jax.tree_util.tree_map(lambda x: x[i], tree)


# ---------------------------------------------------------------------------
# Layer bodies
# ---------------------------------------------------------------------------

def _attn_layer_init(key: jax.Array, cfg: ArchConfig, moe: bool) -> Params:
    k1, k2 = jax.random.split(key)
    p = {"ln_attn": jnp.ones((cfg.d_model,), jnp.float32),
         "ln_mlp": jnp.ones((cfg.d_model,), jnp.float32),
         "attn": init_attention(k1, cfg)}
    p["mlp"] = init_moe(k2, cfg) if moe else init_mlp(k2, cfg)
    return p


def _attn_layer_apply(cfg: ArchConfig, p: Params, x: jnp.ndarray,
                      positions: jnp.ndarray, cache, moe: bool):
    h, new_cache = apply_attention(
        cfg, p["attn"], rmsnorm(x, p["ln_attn"], cfg.norm_eps), positions,
        cache=cache)
    x = x + h
    hn = rmsnorm(x, p["ln_mlp"], cfg.norm_eps)
    x = x + (apply_moe(cfg, p["mlp"], hn) if moe
             else apply_mlp(cfg, p["mlp"], hn))
    return x, new_cache


def _mamba_layer_init(key: jax.Array, cfg: ArchConfig, moe: bool) -> Params:
    k1, k2 = jax.random.split(key)
    p = {"ln_attn": jnp.ones((cfg.d_model,), jnp.float32),
         "ln_mlp": jnp.ones((cfg.d_model,), jnp.float32),
         "mamba": init_mamba(k1, cfg)}
    p["mlp"] = init_moe(k2, cfg) if moe else init_mlp(k2, cfg)
    return p


def _mamba_layer_apply(cfg: ArchConfig, p: Params, x: jnp.ndarray,
                       state, moe: bool):
    h, new_state = apply_mamba(
        cfg, p["mamba"], rmsnorm(x, p["ln_attn"], cfg.norm_eps), state)
    x = x + h
    hn = rmsnorm(x, p["ln_mlp"], cfg.norm_eps)
    x = x + (apply_moe(cfg, p["mlp"], hn) if moe
             else apply_mlp(cfg, p["mlp"], hn))
    return x, new_state


def _rwkv_layer_init(key: jax.Array, cfg: ArchConfig) -> Params:
    return {"ln_attn": jnp.ones((cfg.d_model,), jnp.float32),
            "ln_mlp": jnp.ones((cfg.d_model,), jnp.float32),
            "rwkv": init_rwkv(key, cfg)}


def _rwkv_layer_apply(cfg: ArchConfig, p: Params, x: jnp.ndarray, state):
    h, new_state = apply_rwkv_time(
        cfg, p["rwkv"], rmsnorm(x, p["ln_attn"], cfg.norm_eps), state)
    x = x + h
    x = x + apply_rwkv_channel(
        cfg, p["rwkv"], rmsnorm(x, p["ln_mlp"], cfg.norm_eps))
    return x, new_state


# ---------------------------------------------------------------------------
# Hybrid (jamba) group: [attn, mamba×(attn_every-1)], MLP alternates
# dense (even global layer) / MoE (odd global layer).
# ---------------------------------------------------------------------------

def _group_init(key: jax.Array, cfg: ArchConfig) -> Params:
    ae = cfg.attn_every
    n_mamba = ae - 1
    keys = jax.random.split(key, ae)
    p = {"attn_layer": _attn_layer_init(keys[0], cfg, moe=False)}
    # Positions 1..ae-1 are mamba; MoE on odd positions.
    moe_pos = [i for i in range(1, ae) if i % cfg.moe_every == 1 or
               cfg.moe_every == 1]
    dense_pos = [i for i in range(1, ae) if i not in moe_pos]
    if moe_pos:
        p["mamba_moe"] = _stack_init(
            lambda k: _mamba_layer_init(k, cfg, moe=True),
            keys[1], len(moe_pos))
    if dense_pos:
        p["mamba_dense"] = _stack_init(
            lambda k: _mamba_layer_init(k, cfg, moe=False),
            keys[2], len(dense_pos))
    return p


def _group_apply(cfg: ArchConfig, gp: Params, x: jnp.ndarray,
                 positions: jnp.ndarray, gc):
    ae = cfg.attn_every
    moe_pos = [i for i in range(1, ae) if i % cfg.moe_every == 1 or
               cfg.moe_every == 1]
    dense_pos = [i for i in range(1, ae) if i not in moe_pos]
    x, c_attn = _attn_layer_apply(
        cfg, gp["attn_layer"], x, positions,
        None if gc is None else gc["attn"], moe=False)
    new_c: Dict[str, Any] = {"attn": c_attn, "moe": [], "dense": []}
    im = ide = 0
    for i in range(1, ae):
        if i in moe_pos:
            st = None if gc is None else _tree_idx(gc["moe"], im)
            x, ns = _mamba_layer_apply(
                cfg, _tree_idx(gp["mamba_moe"], im), x, st, moe=True)
            new_c["moe"].append(ns)
            im += 1
        else:
            st = None if gc is None else _tree_idx(gc["dense"], ide)
            x, ns = _mamba_layer_apply(
                cfg, _tree_idx(gp["mamba_dense"], ide), x, st, moe=False)
            new_c["dense"].append(ns)
            ide += 1
    stack = lambda lst: (jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *lst) if lst else None)
    return x, {"attn": new_c["attn"], "moe": stack(new_c["moe"]),
               "dense": stack(new_c["dense"])}


# ---------------------------------------------------------------------------
# Model assembly
# ---------------------------------------------------------------------------

def build_lm(cfg: ArchConfig) -> ModelApi:
    dt = DTYPES[cfg.dtype]
    fam = cfg.family
    if fam == "hybrid":
        assert cfg.n_layers % cfg.attn_every == 0
        n_stack = cfg.n_layers // cfg.attn_every
    else:
        n_stack = cfg.n_layers

    # ---- init ---------------------------------------------------------------
    def init(key: jax.Array) -> Params:
        k_emb, k_layers, k_head = jax.random.split(key, 3)
        p: Params = {
            "embed": init_dense(k_emb, (cfg.vocab, cfg.d_model), dt, 0.02),
            "norm_f": jnp.ones((cfg.d_model,), jnp.float32),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = init_dense(k_head, (cfg.d_model, cfg.vocab), dt)
        if fam in ("dense", "vlm"):
            p["layers"] = _stack_init(
                lambda k: _attn_layer_init(k, cfg, moe=False),
                k_layers, n_stack)
        elif fam == "moe":
            p["layers"] = _stack_init(
                lambda k: _attn_layer_init(k, cfg, moe=True),
                k_layers, n_stack)
        elif fam == "hybrid":
            p["layers"] = _stack_init(
                lambda k: _group_init(k, cfg), k_layers, n_stack)
        elif fam == "ssm":
            p["layers"] = _stack_init(
                lambda k: _rwkv_layer_init(k, cfg), k_layers, n_stack)
        else:
            raise ValueError(fam)
        return p

    # ---- layer-stack application ---------------------------------------------
    def run_layers(params: Params, x: jnp.ndarray, positions: jnp.ndarray,
                   caches):
        is_moe = fam == "moe"

        baxes = BATCH_AXES + ("model",) if cfg.pure_dp else BATCH_AXES

        def shard(y):
            # Shard the scan carry (== the per-layer saved activation under
            # remat).  "model": split d_model over TP — cheap HBM, but every
            # matmul input must be all-gathered.  "seq": sequence
            # parallelism — layer math is token-local, only attention K/V
            # (small under GQA) get gathered.  "none": batch axes only.
            mode = "none" if cfg.pure_dp else cfg.carry_sharding
            if mode == "model":
                return constrain(y, baxes, None, "model")
            if mode == "seq":
                return constrain(y, baxes, "model", None)
            return constrain(y, baxes, None, None)

        if fam in ("dense", "vlm", "moe"):
            def body(carry, inp):
                lp, lc = inp
                y, nc = _attn_layer_apply(cfg, lp, carry, positions, lc,
                                          moe=is_moe)
                return shard(y), nc
        elif fam == "hybrid":
            def body(carry, inp):
                lp, lc = inp
                y, nc = _group_apply(cfg, lp, carry, positions, lc)
                return shard(y), nc
        else:  # ssm
            def body(carry, inp):
                lp, lc = inp
                y, nc = _rwkv_layer_apply(cfg, lp, carry, lc)
                return shard(y), nc

        if cfg.remat == "block":
            body = jax.checkpoint(body)
        x, new_caches = jax.lax.scan(body, shard(x), (params["layers"],
                                                      caches))
        return x, new_caches

    # ---- forward --------------------------------------------------------------
    def forward(params: Params, tokens: jnp.ndarray,
                patches: Optional[jnp.ndarray] = None,
                caches=None,
                positions: Optional[jnp.ndarray] = None,
                last_only: bool = False
                ) -> Tuple[jnp.ndarray, Any]:
        B, S = tokens.shape
        x = params["embed"][tokens]
        if fam == "vlm" and patches is not None:
            x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(x.shape[1]),
                                         (B, x.shape[1]))
        x, new_caches = run_layers(params, x, positions, caches)
        if fam == "vlm" and patches is not None:
            x = x[:, patches.shape[1]:]
        if last_only:
            x = x[:, -1:]   # serve prefill: only next-token logits needed
        x = rmsnorm(x, params["norm_f"], cfg.norm_eps)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        logits = x @ head
        return logits, new_caches

    # ---- loss -------------------------------------------------------------------
    def _hidden(params: Params, tokens, patches):
        """Final normed hidden states (B, S, D) — shared by loss paths."""
        B, S = tokens.shape
        x = params["embed"][tokens]
        if fam == "vlm" and patches is not None:
            x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]),
                                     (B, x.shape[1]))
        x, _ = run_layers(params, x, positions, None)
        if fam == "vlm" and patches is not None:
            x = x[:, patches.shape[1]:]
        return rmsnorm(x, params["norm_f"], cfg.norm_eps)

    # Sequence-chunked CE above this many logit elements: never materialize
    # the full (B, S, V) f32 logits (5+ GB/device at 4k × 150k vocab).
    # Chunks are kept as large as memory allows — each chunk costs one
    # vocab-sharded head-gradient all-reduce in backward, so over-chunking
    # (e.g. 1024 tiny chunks) multiplies collective traffic ~30×.
    CE_CHUNK_THRESHOLD = 1 << 31

    def loss(params: Params, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        tokens = batch["tokens"]
        labels = batch["labels"]
        B, S = tokens.shape
        x = _hidden(params, tokens, batch.get("patches"))
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])

        def ce(xc, lc):
            logits = (xc @ head).astype(jnp.float32)
            logp = jax.nn.log_softmax(logits, axis=-1)
            ll = jnp.take_along_axis(
                logp, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
            mask = (lc >= 0).astype(jnp.float32)
            return (-(ll * mask).sum(), mask.sum())

        n_chunks = 1
        while (B * S // n_chunks) * cfg.vocab > CE_CHUNK_THRESHOLD \
                and (S % (2 * n_chunks)) == 0:
            n_chunks *= 2
        if n_chunks == 1:
            tot, cnt = ce(x, labels)
        else:
            xc = x.reshape(B, n_chunks, S // n_chunks, -1).swapaxes(0, 1)
            lc = labels.reshape(B, n_chunks, S // n_chunks).swapaxes(0, 1)

            def body(carry, inp):
                t, c = carry
                dt_, dc = jax.checkpoint(ce)(*inp)
                return (t + dt_, c + dc), None
            (tot, cnt), _ = jax.lax.scan(
                body, (jnp.zeros(()), jnp.zeros(())), (xc, lc))
        return tot / jnp.maximum(cnt, 1.0)

    # ---- cache init ----------------------------------------------------------------
    def init_cache(batch: int, max_len: int):
        hq, hkv, dh = cfg.n_heads, cfg.n_kv, cfg.head_dim
        C = min(max_len, cfg.window) if cfg.window else max_len

        def attn_cache():
            return {"k": jnp.zeros((batch, hkv, C, dh), dt),
                    "v": jnp.zeros((batch, hkv, C, dh), dt),
                    "len": jnp.zeros((), jnp.int32)}

        def mamba_cache():
            din = cfg.expand * cfg.d_model
            return {"h": jnp.zeros((batch, din, cfg.d_state), jnp.float32),
                    "conv": jnp.zeros((batch, cfg.d_conv - 1, din), dt)}

        def rwkv_cache():
            H = cfg.d_model // cfg.rwkv_head_dim
            return {"S": jnp.zeros((batch, H, cfg.rwkv_head_dim,
                                    cfg.rwkv_head_dim), jnp.float32),
                    "x_prev": jnp.zeros((batch, 1, cfg.d_model), dt)}

        def rep(tree, n):
            return jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x, (n,) + x.shape), tree)

        if fam in ("dense", "vlm", "moe"):
            return rep(attn_cache(), n_stack)
        if fam == "ssm":
            return rep(rwkv_cache(), n_stack)
        if fam == "hybrid":
            ae = cfg.attn_every
            moe_pos = [i for i in range(1, ae) if i % cfg.moe_every == 1 or
                       cfg.moe_every == 1]
            n_moe, n_dense = len(moe_pos), ae - 1 - len(moe_pos)
            group = {"attn": attn_cache(),
                     "moe": rep(mamba_cache(), n_moe) if n_moe else None,
                     "dense": rep(mamba_cache(), n_dense) if n_dense else None}
            return rep(group, n_stack)
        raise ValueError(fam)

    return ModelApi(cfg=cfg, init=init, forward=forward, loss=loss,
                    init_cache=init_cache)
