"""Shared architecture machinery: configs, layers, init, sharding rules.

All 10 assigned architectures are built from these primitives.  Parameters
are nested dicts of jnp arrays; scan-stacked layer parameters carry a
leading L axis.  Sharding is assigned by leaf-path pattern rules in
:func:`param_specs`, with divisibility-checked fallbacks so e.g. a 2-KV-head
model on a 16-way model axis degrades that dim to replicated instead of
failing to lower.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

Params = Dict[str, Any]

__all__ = ["ArchConfig", "rmsnorm", "rope", "param_specs", "batch_axes",
           "init_dense", "DTYPES"]

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
          "float16": jnp.float16}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One architecture's full configuration (see src/repro/configs/)."""

    name: str
    family: str                  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int = 0              # 0 → d_model // n_heads
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # Hybrid (jamba): attention layer every `attn_every` layers (else mamba);
    # MoE MLP every `moe_every` layers (else dense MLP).
    attn_every: int = 0
    moe_every: int = 0
    # Mamba (S6)
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    # RWKV6
    rwkv_head_dim: int = 64
    # Encoder–decoder (whisper): encoder layers + stub frontend length.
    enc_layers: int = 0
    enc_seq: int = 0
    cross_attention: bool = False
    # VLM: stub patch embeddings prepended to the token stream.
    n_patches: int = 0
    # Misc
    qkv_bias: bool = False
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # Execution knobs (several are θ parameters of the cluster autotuner).
    dtype: str = "bfloat16"
    moment_dtype: str = "float32"
    remat: str = "block"         # none | block
    use_flash: bool = False      # Pallas flash-attention path (TPU)
    window: int = 0              # sliding-window attention (0 = full)
    act_shard_model: bool = True  # shard layer-scan carry over 'model'
                                  # (saves HBM, costs per-layer all-gathers)
    act_shard: str = ""           # "" → derived from act_shard_model;
                                  # "model" (d_model dim) | "seq" (sequence
                                  # parallelism: only K/V all-gathered at
                                  # attention) | "none"
    train_accum: int = 1          # gradient-accumulation microbatches
    rwkv_impl: str = "scan"       # "scan" (per-step) | "chunked" (GLA form)
    rwkv_chunk: int = 64          # chunk length for the GLA form (≤512:
                                  # exp-range safety in f32)
    pure_dp: bool = False         # no tensor parallelism: batch + FSDP span
                                  # the whole mesh (small-d_model models
                                  # where TP boundaries cost more than they
                                  # save)

    @property
    def carry_sharding(self) -> str:
        if self.act_shard:
            return self.act_shard
        return "model" if self.act_shard_model else "none"
    # Which shapes this arch supports (see DESIGN.md §Arch-applicability).
    supports_long: bool = False
    decoder_only: bool = True

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def n_params_dense(self) -> float:
        """Approximate parameter count (embeddings + blocks)."""
        d, f, L = self.d_model, self.d_ff, self.n_layers
        hd = self.head_dim
        attn = d * hd * (self.n_heads + 2 * self.n_kv) + self.n_heads * hd * d
        mlp = 3 * d * f
        per_layer = attn + mlp
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return L * per_layer + emb

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Primitive layers
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * g).astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray,
         theta: float = 1e4) -> jnp.ndarray:
    """Rotary embedding.  x: (..., S, H, Dh), positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (np.arange(0, half) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)           # (..., S,1,half)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1)


def init_dense(key: jax.Array, shape: Tuple[int, ...], dtype,
               scale: Optional[float] = None) -> jnp.ndarray:
    fan_in = shape[0] if len(shape) >= 2 else 1
    s = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape) * s).astype(dtype)


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------

def batch_axes(mesh) -> Tuple[str, ...]:
    """Axes the global batch shards over ('pod' extends 'data')."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


# (path regex, spec WITHOUT the leading scan axis).  'fsdp' resolves to the
# 'data' axis, 'tp' to 'model'.
_RULES = [
    (r"embed$", ("tp", "fsdp")),            # (V, D)
    (r"pos_embed$", (None, "fsdp")),        # (S, D)
    (r"lm_head$", ("fsdp", "tp")),          # (D, V)
    (r"(wq|wk|wv)$", ("fsdp", "tp")),       # (D, H·Dh)
    (r"(bq|bk|bv)$", ("tp",)),              # (H·Dh,)
    (r"wo$", ("tp", "fsdp")),               # (H·Dh, D)
    (r"(w_gate|w_up)$", ("fsdp", "tp")),    # (D, F)
    (r"w_down$", ("tp", "fsdp")),           # (F, D)
    (r"router$", ("fsdp", None)),           # (D, E)
    (r"(e_gate|e_up)$", ("tp", "fsdp", None)),   # (E, D, F) expert parallel
    (r"e_down$", ("tp", None, "fsdp")),     # (E, F, D)
    (r"in_proj$", ("fsdp", "tp")),          # mamba (D, 2·d_in)
    (r"conv_w$", ("tp", None)),             # (d_in, k)
    (r"x_proj$", ("tp", None)),             # (d_in, dt_rank + 2N)
    (r"dt_proj$", (None, "tp")),            # (dt_rank, d_in)
    (r"A_log$", ("tp", None)),              # (d_in, N)
    (r"D$", ("tp",)),                       # (d_in,)
    (r"out_proj$", ("tp", "fsdp")),         # (d_in, D)
    (r"(r_proj|k_proj|v_proj|g_proj|o_proj)$", ("fsdp", "tp")),  # rwkv (D, D)
    (r"w_proj$", ("fsdp", "tp")),           # rwkv decay (D, D)
    (r"(mu_.*|w_bias)$", ("tp",)),          # rwkv per-channel params (D,)
    (r"(ck_proj)$", ("fsdp", "tp")),        # rwkv channel-mix (D, F)
    (r"(cv_proj)$", ("tp", "fsdp")),        # rwkv channel-mix (F, D)
    (r"(norm.*|scale|ln_.*)$", (None,)),    # norms replicated
]


def _resolve(axis: Optional[str], mesh, pure_dp: bool):
    if axis == "fsdp":
        if pure_dp:
            both = tuple(a for a in ("data", "model")
                         if a in mesh.axis_names)
            return both or None
        return "data" if "data" in mesh.axis_names else None
    if axis == "tp":
        if pure_dp:
            return None
        return "model" if "model" in mesh.axis_names else None
    return axis


def param_specs(params: Params, mesh, *, pure_dp: bool = False) -> Params:
    """Same-structure tree of PartitionSpec chosen by leaf-path rules.

    Leading scan (layer-stack) axes — detected as leaf rank exceeding the
    rule's length — map to None.  Any sharded dim whose size is not
    divisible by the mesh-axis size falls back to replicated on that dim.
    ``pure_dp`` drops tensor parallelism: FSDP spans data×model.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def axsize(ax) -> int:
        if ax is None:
            return 1
        if isinstance(ax, tuple):
            return int(np.prod([sizes.get(a, 1) for a in ax]))
        return sizes.get(ax, 1)

    def leaf_spec(path: str, x) -> P:
        shape = x.shape
        for pat, spec in _RULES:
            if re.search(pat, path):
                axes = [_resolve(a, mesh, pure_dp) for a in spec]
                pad = len(shape) - len(axes)
                axes = [None] * pad + axes
                fixed = []
                for dim, ax in zip(shape, axes):
                    if ax is not None and dim % axsize(ax) != 0:
                        ax = None
                    fixed.append(ax)
                return P(*fixed)
        return P()  # replicate by default

    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    specs = []
    for kp, x in flat:
        path = "/".join(str(getattr(k, "key", k)) for k in kp)
        specs.append(leaf_spec(path, x))
    return jax.tree_util.tree_unflatten(treedef, specs)
