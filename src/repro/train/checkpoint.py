"""Fault-tolerant checkpointing: sharded save, atomic publish, elastic load.

Layout:  <dir>/step_<N>/  — one ``shard_host<i>.npz`` per host with that
host's addressable shard data + a ``manifest.json`` (step, tree structure,
global shapes/dtypes, mesh).  The step directory is written under a tmp name
and atomically renamed, so readers never observe partial checkpoints; a
``LATEST`` file is rewritten last (restart-after-failure picks the newest
complete step).

Elastic restore: arrays are re-``device_put`` onto the *current* mesh's
shardings — a checkpoint taken on 256 chips restores onto any surviving
device count whose mesh the caller provides (the resharding is a plain
gather+scatter through host memory on this single-host box; on a real
cluster each host reads the shard files overlapping its new address space).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

Params = Dict[str, Any]

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]


def _flatten(tree: Params):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, x in flat:
        path = "/".join(str(getattr(k, "key", k)) for k in kp)
        out[path] = x
    return out


def save_checkpoint(ckpt_dir: str, step: int, params: Params,
                    opt_state: Optional[Params] = None,
                    extra: Optional[Dict[str, Any]] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    state = {"params": params}
    if opt_state is not None:
        state["opt"] = opt_state
    flat = _flatten(state)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    # np.savez cannot round-trip ml_dtypes (bf16 etc.): store raw bytes and
    # reconstruct from the manifest's shape/dtype.
    raw = {k: np.ascontiguousarray(v).view(np.uint8).reshape(-1)
           for k, v in arrays.items()}
    np.savez(os.path.join(tmp, "shard_host0.npz"), **raw)
    manifest = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(ckpt_dir, "LATEST.tmp"),
               os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    path = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return int(f.read().strip())


def restore_checkpoint(ckpt_dir: str, tree_like: Params,
                       shardings: Optional[Params] = None,
                       step: Optional[int] = None) -> Tuple[Params, int]:
    """Restore onto the current mesh (elastic: shardings may differ from
    save time).  ``tree_like`` provides the pytree structure."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step}")
    data = np.load(os.path.join(d, "shard_host0.npz"))
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    import ml_dtypes  # noqa: F401 — registers bfloat16 et al. with numpy
    flat_paths = _flatten(tree_like)
    flat_sh = _flatten(shardings) if shardings is not None else {}
    out = {}
    for path in flat_paths:
        dt = np.dtype(manifest["dtypes"][path])
        shape = tuple(manifest["shapes"][path])
        arr = data[path].view(dt).reshape(shape)
        sh = flat_sh.get(path)
        out[path] = jax.device_put(arr, sh) if sh is not None else arr
    # Rebuild the tree.
    flat_kp = jax.tree_util.tree_flatten_with_path(tree_like)
    treedef = flat_kp[1]
    leaves = []
    for kp, _ in flat_kp[0]:
        path = "/".join(str(getattr(k, "key", k)) for k in kp)
        leaves.append(out[path])
    return jax.tree_util.tree_unflatten(treedef, leaves), step
