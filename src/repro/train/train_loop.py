"""pjit'd training step + loop: grad accumulation, WSD AdamW, metrics.

``make_train_step`` returns a jitted (params, opt_state, batch) → (params,
opt_state, metrics) function with explicit in/out shardings — the same
callable the multi-pod dry-run lowers with ShapeDtypeStructs and the smoke
trainers execute on host devices.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..archs.lm import ModelApi
from .optimizer import OptConfig, opt_init, opt_update
from .sharding import (batch_shardings, named, opt_shardings,
                       params_shardings)

Params = Dict[str, Any]

__all__ = ["make_train_step", "make_init", "train_loop", "TrainStepFns"]


@dataclasses.dataclass
class TrainStepFns:
    init: Callable[[jax.Array, Params], Tuple[Params, Params]]
    step: Callable[..., Tuple[Params, Params, Dict[str, jnp.ndarray]]]
    params_sh: Any
    opt_sh: Any
    batch_sh: Any


def _accum_grads(loss_fn, params, batch, accum: int):
    """Microbatch gradient accumulation via scan (memory = 1 microbatch)."""
    def reshape(x):
        return x.reshape((accum, x.shape[0] // accum) + x.shape[1:])
    mbs = jax.tree_util.tree_map(reshape, batch)

    def body(carry, mb):
        g_acc, l_acc = carry
        # Checkpoint the microbatch: without it the scan saves every
        # microbatch's residuals and accumulation wins no memory.
        l, g = jax.checkpoint(
            lambda p, m: jax.value_and_grad(loss_fn)(p, m))(params, mb)
        g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
        return (g_acc, l_acc + l), None

    zeros = jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), params)
    (g, l), _ = jax.lax.scan(body, (zeros, jnp.zeros(())), mbs)
    scale = 1.0 / accum
    return l * scale, jax.tree_util.tree_map(lambda x: x * scale, g)


def make_train_step(api: ModelApi, mesh, batch_shape: Params,
                    opt_cfg: OptConfig = OptConfig(), *,
                    accum: int = 1, donate: bool = True) -> TrainStepFns:
    from ..archs.act_sharding import set_activation_mesh
    set_activation_mesh(mesh, pure_dp=api.cfg.pure_dp)
    pure_dp = api.cfg.pure_dp
    params_shape = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    p_sh = params_shardings(params_shape, mesh, pure_dp=pure_dp)
    o_sh = opt_shardings(params_shape, mesh, pure_dp=pure_dp)
    b_sh = batch_shardings(batch_shape, mesh, pure_dp=pure_dp)
    metr_sh = {"loss": NamedSharding(mesh, P()),
               "lr": NamedSharding(mesh, P()),
               "grad_norm": NamedSharding(mesh, P())}

    def loss_fn(p, mb):
        return api.loss(p, mb)

    def step(params, opt_state, batch):
        if accum > 1:
            loss, grads = _accum_grads(loss_fn, params, batch, accum)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, metrics = opt_update(params, grads, opt_state,
                                                opt_cfg)
        return params, opt_state, {"loss": loss, **metrics}

    step_jit = jax.jit(
        step,
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, metr_sh),
        donate_argnums=(0, 1) if donate else ())

    def init(key, _unused=None):
        params = jax.jit(api.init, out_shardings=p_sh)(key)
        opt_state = jax.jit(functools.partial(opt_init, cfg=opt_cfg),
                            out_shardings=o_sh)(params)
        return params, opt_state

    return TrainStepFns(init=init, step=step_jit, params_sh=p_sh,
                        opt_sh=o_sh, batch_sh=b_sh)


def make_init(api: ModelApi, mesh):
    params_shape = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    p_sh = params_shardings(params_shape, mesh)
    return jax.jit(api.init, out_shardings=p_sh), p_sh


def train_loop(api: ModelApi, mesh, data_iter, *, steps: int,
               opt_cfg: OptConfig = OptConfig(), accum: int = 1,
               checkpoint_dir: Optional[str] = None,
               checkpoint_every: int = 0, log_every: int = 10,
               seed: int = 0,
               on_step: Optional[Callable[[int, Dict], None]] = None
               ) -> Dict[str, Any]:
    """Run a (smoke-scale) training loop on the host mesh; returns history."""
    first = next(data_iter)
    batch_shape = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), first)
    fns = make_train_step(api, mesh, batch_shape, opt_cfg, accum=accum)
    params, opt_state = fns.init(jax.random.PRNGKey(seed))
    history = []
    batch = first
    t0 = time.perf_counter()
    step_idx = 0
    while step_idx < steps:
        params, opt_state, metrics = fns.step(params, opt_state, batch)
        step_idx += 1
        if step_idx % log_every == 0 or step_idx == steps:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step_idx
            m["sec"] = time.perf_counter() - t0
            history.append(m)
        if on_step is not None:
            on_step(step_idx, metrics)
        if checkpoint_dir and checkpoint_every and \
                step_idx % checkpoint_every == 0:
            from .checkpoint import save_checkpoint
            save_checkpoint(checkpoint_dir, step_idx, params, opt_state)
        if step_idx < steps:
            batch = next(data_iter)
    return {"history": history, "params": params, "opt_state": opt_state,
            "fns": fns}
