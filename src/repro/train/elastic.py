"""Elastic scaling + straggler mitigation.

* :func:`plan_elastic_mesh` — given the surviving device count, choose the
  largest viable (data, model) grid (model axis preserved when possible so
  tensor-sharded parameters keep their layout; data axis shrinks).
* :func:`reshard_state` — move params/opt state onto the new mesh (device_put
  with the new shardings; cross-host this is the checkpoint-restore path).
* :func:`assign_data_shards` — deterministic data-shard ownership that
  excludes stragglers and rebalances their shards round-robin, so every
  host computes its assignment independently (no coordinator).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from ..archs.common import param_specs
from .sharding import named

__all__ = ["plan_elastic_mesh", "reshard_state", "assign_data_shards"]


def plan_elastic_mesh(n_devices: int, *, prefer_model: int = 16,
                      axes: Tuple[str, str] = ("data", "model")):
    """Largest (data, model) grid using ≤ n_devices, preferring to keep the
    model axis at ``prefer_model`` (params keep their TP layout)."""
    model = prefer_model
    while model > 1 and n_devices // model == 0:
        model //= 2
    data = max(n_devices // model, 1)
    return (data, model), axes


def reshard_state(state: Dict[str, Any], params_shape, new_mesh):
    """device_put a (params-like) state tree onto a new mesh's shardings."""
    spec = param_specs(params_shape, new_mesh)
    sh = named(new_mesh, spec)
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(np.asarray(jax.device_get(x)), s),
        state, sh)


def assign_data_shards(n_shards: int, hosts: Sequence[int],
                       stragglers: Sequence[int] = ()) -> Dict[int, List[int]]:
    """Deterministic shard→host assignment excluding stragglers.

    Healthy hosts keep their base shards; orphaned shards (from stragglers)
    are redistributed round-robin by shard index — pure function of the
    inputs, so every participant derives the same plan without coordination.
    """
    healthy = [h for h in hosts if h not in set(stragglers)]
    if not healthy:
        raise ValueError("no healthy hosts")
    base = {h: [] for h in healthy}
    orphans = []
    for s in range(n_shards):
        owner = hosts[s % len(hosts)]
        if owner in base:
            base[owner].append(s)
        else:
            orphans.append(s)
    for i, s in enumerate(orphans):
        base[healthy[i % len(healthy)]].append(s)
    return base
