"""AdamW with a WSD (warmup–stable–decay) schedule, fully sharded states.

Optimizer moments live on the same shardings as the parameters (ZeRO-3-style
when params are FSDP-sharded over the 'data' axis).  ``moment_dtype``
controls the moment precision — bf16 moments halve optimizer HBM, which is
what lets the 398B Jamba config fit 256 × 16 GB chips (a distributed-
optimization trick recorded in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..archs.common import DTYPES

Params = Dict[str, Any]

__all__ = ["OptConfig", "wsd_schedule", "opt_init", "opt_update"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    # WSD schedule (minicpm's recipe): linear warmup → stable → 1-sqrt decay.
    total_steps: int = 10000
    warmup_steps: int = 100
    decay_frac: float = 0.1


def wsd_schedule(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Warmup–Stable–Decay learning-rate schedule."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    decay_steps = cfg.decay_frac * cfg.total_steps
    decay_start = cfg.total_steps - decay_steps
    frac = jnp.clip((step - decay_start) / jnp.maximum(decay_steps, 1), 0, 1)
    decay = 1.0 - (1.0 - 0.1) * jnp.sqrt(frac)     # → 0.1·lr at the end
    return cfg.lr * warm * decay


def opt_init(params: Params, cfg: OptConfig) -> Params:
    mdt = DTYPES[cfg.moment_dtype]
    zeros = lambda x: jnp.zeros(x.shape, mdt)
    return {"m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def _global_norm(tree: Params) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def opt_update(params: Params, grads: Params, state: Params,
               cfg: OptConfig) -> Tuple[Params, Params, Dict[str, Any]]:
    """One AdamW step; returns (params, state, metrics)."""
    step = state["step"] + 1
    lr = wsd_schedule(cfg, step)
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    mdt = DTYPES[cfg.moment_dtype]

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g32
        v32 = v.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * g32 * g32
        bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
        bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)
        u = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        newp = p.astype(jnp.float32) - lr * (u + cfg.weight_decay
                                             * p.astype(jnp.float32))
        return newp.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    out = jax.tree_util.tree_map(upd, params, grads, state["m"], state["v"])
    # Unzip the 3-tuples.
    newp = jax.tree_util.tree_map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    newm = jax.tree_util.tree_map(lambda t: t[1], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    newv = jax.tree_util.tree_map(lambda t: t[2], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"lr": lr, "grad_norm": gnorm}
    return newp, {"m": newm, "v": newv, "step": step}, metrics
