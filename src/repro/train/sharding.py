"""Sharding-spec builders for train/serve state (params, optimizer, caches,
batches) with divisibility-checked fallbacks.

Rules follow DESIGN.md §5: parameters FSDP-shard over 'data' and
tensor-shard over 'model'; batches shard over ('pod','data'); KV caches
shard batch→data and heads→model, degrading to sequence→model (decode
sequence parallelism) when the head count doesn't divide the model axis —
the GQA-few-KV-heads case.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..archs.common import batch_axes, param_specs

Params = Dict[str, Any]

__all__ = ["named", "params_shardings", "opt_shardings", "batch_shardings",
           "cache_shardings", "tree_size_bytes"]


def named(mesh, spec_tree):
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                  spec_tree,
                                  is_leaf=lambda x: isinstance(x, P))


def _axsize(mesh, name: Optional[str]) -> int:
    if name is None:
        return 1
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def params_shardings(params_shape: Params, mesh, *, pure_dp: bool = False):
    return named(mesh, param_specs(params_shape, mesh, pure_dp=pure_dp))


def opt_shardings(params_shape: Params, mesh, *, pure_dp: bool = False):
    pspec = param_specs(params_shape, mesh, pure_dp=pure_dp)
    return {"m": named(mesh, pspec), "v": named(mesh, pspec),
            "step": NamedSharding(mesh, P())}


def batch_shardings(batch_shape: Params, mesh, *, pure_dp: bool = False):
    """Leading dim → batch axes (when divisible), rest replicated."""
    baxes = batch_axes(mesh)
    if pure_dp and "model" in mesh.axis_names:
        baxes = baxes + ("model",)
    bsize = int(np.prod([_axsize(mesh, a) for a in baxes]))

    def spec(x):
        if x.ndim == 0:
            return P()
        if x.shape[0] % bsize == 0 and x.shape[0] > 0:
            return P(baxes, *([None] * (x.ndim - 1)))
        return P(*([None] * x.ndim))
    return named(mesh, jax.tree_util.tree_map(spec, batch_shape))


def cache_shardings(cache_shape: Params, mesh, *, pure_dp: bool = False):
    """KV caches: batch→data axes, heads→model (or seq→model fallback)."""
    baxes = batch_axes(mesh)
    msize = _axsize(mesh, "model")
    m_name: Optional[str] = "model"
    if pure_dp and "model" in mesh.axis_names:
        baxes = baxes + ("model",)
        msize = 1
        m_name = None
    bsize = int(np.prod([_axsize(mesh, a) for a in baxes]))

    def spec_leaf(path: str, x) -> P:
        nd = x.ndim
        if nd <= 1:
            return P()
        name = path.split("/")[-1]
        if name in ("k", "v") and nd == 5:          # (L, B, H, C, Dh)
            L, B, H, C, Dh = x.shape
            b_ax = baxes if B % bsize == 0 else None
            if m_name and H % msize == 0:
                return P(None, b_ax, m_name, None, None)
            if m_name and C % msize == 0:
                return P(None, b_ax, None, m_name, None)
            return P(None, b_ax, None, None, None)
        if name == "h" and nd == 4:                 # (L, B, din, N)
            L, B, din, N = x.shape
            b_ax = baxes if B % bsize == 0 else None
            m_ax = m_name if m_name and din % msize == 0 else None
            return P(None, b_ax, m_ax, None)
        if name == "conv" and nd == 4:              # (L, B, k-1, din)
            L, B, K, din = x.shape
            b_ax = baxes if B % bsize == 0 else None
            m_ax = m_name if m_name and din % msize == 0 else None
            return P(None, b_ax, None, m_ax)
        if name == "S" and nd == 5:                 # (L, B, H, dk, dv)
            L, B, H, dk, dv = x.shape
            b_ax = baxes if B % bsize == 0 else None
            m_ax = m_name if m_name and H % msize == 0 else None
            return P(None, b_ax, m_ax, None, None)
        if name == "x_prev" and nd == 4:            # (L, B, 1, D)
            L, B, _, D = x.shape
            b_ax = baxes if B % bsize == 0 else None
            m_ax = m_name if m_name and D % msize == 0 else None
            return P(None, b_ax, None, m_ax)
        if name == "enc_out" and nd == 3:           # (B, Se, D)
            B, Se, D = x.shape
            b_ax = baxes if B % bsize == 0 else None
            m_ax = m_name if m_name and D % msize == 0 else None
            return P(b_ax, None, m_ax)
        return P(*([None] * nd))

    flat = jax.tree_util.tree_flatten_with_path(cache_shape)[0]
    treedef = jax.tree_util.tree_structure(cache_shape)
    specs = []
    for kp, x in flat:
        path = "/".join(str(getattr(k, "key", k)) for k in kp)
        specs.append(spec_leaf(path, x))
    return named(mesh, jax.tree_util.tree_unflatten(treedef, specs))


def tree_size_bytes(tree_shape: Params) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree_shape))
