"""Serving steps: batched prefill + single-token decode, pjit'd.

``make_serve_fns`` returns jitted callables with explicit shardings — the
same functions the dry-run lowers for the ``prefill_*`` / ``decode_*`` /
``long_*`` shape cells.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..archs.lm import ModelApi
from .sharding import (batch_shardings, cache_shardings, params_shardings)

Params = Dict[str, Any]

__all__ = ["ServeFns", "make_serve_fns"]


@dataclasses.dataclass
class ServeFns:
    prefill: Callable[..., Tuple[jnp.ndarray, Any]]
    decode: Callable[..., Tuple[jnp.ndarray, Any]]
    params_sh: Any
    cache_sh: Any


def make_serve_fns(api: ModelApi, mesh, *, batch: int, max_len: int,
                   has_patches: bool = False) -> ServeFns:
    from ..archs.act_sharding import set_activation_mesh
    set_activation_mesh(mesh, pure_dp=api.cfg.pure_dp)
    cfg = api.cfg
    params_shape = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    p_sh = params_shardings(params_shape, mesh, pure_dp=cfg.pure_dp)
    cache_shape = jax.eval_shape(lambda: api.init_cache(batch, max_len))
    c_sh = cache_shardings(cache_shape, mesh, pure_dp=cfg.pure_dp)

    def prefill(params, tokens, cache, patches=None):
        logits, cache = api.forward(params, tokens, patches=patches,
                                    caches=cache, last_only=True)
        return logits, cache

    def decode(params, tokens, cache, positions):
        logits, cache = api.forward(params, tokens, caches=cache,
                                    positions=positions)
        return logits, cache

    tok_sh = lambda shape: batch_shardings(
        {"t": jax.ShapeDtypeStruct(shape, jnp.int32)}, mesh)["t"]
    rep = NamedSharding(mesh, P())

    prefill_jit = jax.jit(
        prefill,
        in_shardings=(p_sh, None, c_sh, None),
        out_shardings=(None, c_sh),
        donate_argnums=(2,))
    decode_jit = jax.jit(
        decode,
        in_shardings=(p_sh, None, c_sh, None),
        out_shardings=(None, c_sh),
        donate_argnums=(2,))
    return ServeFns(prefill=prefill_jit, decode=decode_jit, params_sh=p_sh,
                    cache_sh=c_sh)
