"""Shared serving-layer caches (compile-time and runtime halves).

:class:`EffectiveSetCache` — template-keyed Algorithm 1 artifacts for the
compile-time service.  :class:`CandidatePoolCache` — runtime θp/θs LHS
candidate pools shared across every concurrent query of a session.  Both
are long-lived by design: one instance serves every micro-batch and
admission epoch of a streaming :class:`~repro.serve.server.OptimizerServer`
run, which is where the amortization comes from.

Algorithm 1's candidate sampling (LHS θc set, clustering, crossover
enrichment, θp⊕θs pool) depends only on the parameter spaces and the
:class:`~repro.core.moo.hmooc.HMOOCConfig` — never on the query — so those
artifacts are shareable across *all* queries solved under one config.  The
per-representative optimal-θp banks (``opt_idx``) are computed from one
query's statistics; they are exact to reuse for an identical query (same
template, same parametric variant → same CBO statistics) and a
template-level approximation otherwise.

Cache policy, per (benchmark, template, config, model) key:

* **full hit** — stored fingerprint matches the incoming query: reuse
  candidates *and* banks; the solve skips Algorithm 1 and is bit-identical
  to a cold solve.
* **structure hit** — same template, different parametric variant: reuse
  the candidate samples, recompute banks (exact).  With
  ``reuse_banks_across_variants=True`` the stored banks are reused instead
  (approximate, amortized — the paper's repeated-template serving regime).
* **miss** — first sight of the template: full solve, artifacts stored.

Entries are LRU-evicted above ``max_entries``.
"""
from __future__ import annotations

import dataclasses
import pickle
import zlib
from collections import OrderedDict
from typing import Optional, Tuple

import numpy as np

from ..core.moo.hmooc import EffectiveSet, HMOOCConfig
from ..queryengine.plan import Query

__all__ = ["EffectiveSetCache", "CandidatePoolCache", "query_fingerprint",
           "template_key", "model_fingerprint"]

SNAPSHOT_FORMAT = "repro-cache-snapshot"
SNAPSHOT_VERSION = 1


def pack_snapshot(kind: str, entries: list) -> bytes:
    """Serialize one cache's snapshot-eligible entries to an opaque blob."""
    return pickle.dumps(
        {"format": SNAPSHOT_FORMAT, "version": SNAPSHOT_VERSION,
         "kind": kind, "entries": entries},
        protocol=pickle.HIGHEST_PROTOCOL)


def unpack_snapshot(blob: bytes, kind: str) -> list:
    """Validate and decode a blob produced by :func:`pack_snapshot`."""
    payload = pickle.loads(blob)
    if not isinstance(payload, dict) \
            or payload.get("format") != SNAPSHOT_FORMAT:
        raise ValueError("blob is not a serving-cache snapshot")
    if payload.get("version") != SNAPSHOT_VERSION:
        raise ValueError(
            f"unsupported snapshot version {payload.get('version')!r}")
    if payload.get("kind") != kind:
        raise ValueError(
            f"snapshot of kind {payload.get('kind')!r} cannot restore "
            f"into a {kind!r} cache")
    return payload["entries"]


def model_fingerprint(model) -> Optional[object]:
    """Stable cache identity for an objective model.

    Prefers the model's content fingerprint (weights + config digest) so
    cache keys survive the model object being reloaded, and — critically —
    so a *different* model landing at a recycled ``id()`` can never satisfy
    a key minted under its predecessor.  Models without a ``fingerprint``
    method (test doubles, duck-typed oracles) fall back to ``id``; the
    caches pin those objects for the life of their entries so the id stays
    unique.
    """
    if model is None:
        return None
    fp = getattr(model, "fingerprint", None)
    if callable(fp):
        return fp()
    # repro: allow[RP004] documented live-object pin: id-fingerprinted entries are pinned alive for their lifetime, excluded from snapshots by the `model is None` filter, and the id is never compared across processes or replays
    return id(model)


def query_fingerprint(query: Query) -> int:
    """Hash of the statistics the stage objectives read from a query."""
    h = zlib.crc32(query.qid.encode())
    for sq in query.subqs:
        vals = np.asarray(
            list(sq.est_input_rows) + list(sq.est_input_bytes)
            + list(sq.input_rows) + list(sq.input_bytes)
            + [sq.est_out_rows, sq.est_out_bytes, sq.out_rows, sq.out_bytes,
               sq.cpu_weight, sq.skew, float(sq.depth)], np.float64)
        h = zlib.crc32(vals.tobytes(), h)
    return h


def template_key(query: Query, cfg: HMOOCConfig, model, cost=None) -> Tuple:
    # The banks depend on everything stage_eval reads: query statistics
    # (fingerprinted separately), the objective model, and the cost model.
    return (query.benchmark, query.template, cfg, cost,
            model_fingerprint(model))


def _freeze_eset(es: EffectiveSet) -> None:
    """Re-freeze an unpickled effective set in place.

    Unpickling always yields writable arrays, and a restored entry's
    arrays are handed out by reference to every future cache hit — the
    same shared-mutable-array hazard the pool cache guards against, so
    restores apply the same ``writeable=False`` re-freeze.
    """
    for a in (es.Uc, es.labels, es.reps, es.pool):
        a.setflags(write=False)
    if es.opt_idx is not None:
        for bank in es.opt_idx:
            for idx in bank:
                idx.setflags(write=False)


@dataclasses.dataclass
class _Entry:
    eset: EffectiveSet
    fingerprint: int
    # Strong reference kept only for models keyed by the id() fallback,
    # which CPython may reuse after a model is collected — pinning keeps
    # live entries' ids unique.  Content-fingerprinted models need no pin.
    model: object = None


class EffectiveSetCache:
    """LRU cache of Algorithm 1 artifacts keyed by query template."""

    def __init__(self, max_entries: int = 256, *,
                 reuse_banks_across_variants: bool = False):
        self.max_entries = max_entries
        self.reuse_banks_across_variants = reuse_banks_across_variants
        self._entries: "OrderedDict[Tuple, _Entry]" = OrderedDict()
        self.hits = 0            # full hits (banks reused, exact)
        self.approx_hits = 0     # banks reused across variants (approximate)
        self.structure_hits = 0  # candidates reused, banks recomputed
        self.misses = 0
        self.peek_hits = 0       # degraded-path bank probes that found banks
        self.peek_misses = 0     # degraded-path probes with nothing to reuse

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, query: Query, cfg: HMOOCConfig,
               model=None, cost=None) -> Optional[EffectiveSet]:
        key = template_key(query, cfg, model, cost)
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        if entry.fingerprint == query_fingerprint(query):
            self.hits += 1
            return entry.eset
        if self.reuse_banks_across_variants \
                and entry.eset.opt_idx is not None \
                and len(entry.eset.opt_idx[0]) == query.n_subqs:
            # Cross-variant bank reuse is only shape-valid when the stored
            # banks cover exactly this query's subQ count — the same guard
            # peek() enforces.  A variant with a different plan shape falls
            # through to a structure hit (candidates reused, banks rebuilt).
            self.approx_hits += 1
            return entry.eset
        self.structure_hits += 1
        return entry.eset.without_banks()

    def peek(self, query: Query, cfg: HMOOCConfig,
             model=None, cost=None) -> Optional[Tuple[EffectiveSet, bool]]:
        """Degraded-path probe: banks for this template, or None.

        Unlike :meth:`lookup`, a fingerprint mismatch does *not* strip the
        banks and ``reuse_banks_across_variants`` is ignored — the degraded
        serving path explicitly opts into approximate cross-variant reuse
        (its alternative is no solve at all, never a fresh Algorithm 1).
        Returns ``(effective_set_with_banks, exact)`` where ``exact`` is
        True when the stored fingerprint matches the query (bank reuse is
        then bit-identical to a cold solve); returns None when the
        template has no stored banks usable for this query's subQ count.
        Never mutates LRU order or hit/miss stats of the normal path.
        """
        entry = self._entries.get(template_key(query, cfg, model, cost))
        if entry is None or entry.eset.opt_idx is None \
                or len(entry.eset.opt_idx[0]) != query.n_subqs:
            self.peek_misses += 1
            return None
        self.peek_hits += 1
        return entry.eset, entry.fingerprint == query_fingerprint(query)

    def store(self, query: Query, cfg: HMOOCConfig, eset: EffectiveSet,
              model=None, cost=None) -> None:
        key = template_key(query, cfg, model, cost)
        pin = model if (model is not None
                        and not callable(getattr(model, "fingerprint", None))
                        ) else None
        self._entries[key] = _Entry(eset=eset,
                                    fingerprint=query_fingerprint(query),
                                    model=pin)
        self._entries.move_to_end(key)
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)

    def stats(self) -> dict:
        return {"entries": len(self._entries), "hits": self.hits,
                "approx_hits": self.approx_hits,
                "structure_hits": self.structure_hits,
                "misses": self.misses,
                "peek_hits": self.peek_hits,
                "peek_misses": self.peek_misses}

    def snapshot(self) -> bytes:
        """Opaque blob of this cache's process-external entries (LRU order).

        **Snapshot contract:** only entries minted under a content-
        fingerprinted model (or no model) are included.  Entries keyed by
        the ``id()`` fallback — the ones holding a live-object pin — are
        process-local by construction (the id is meaningless elsewhere and
        the pinned object cannot travel) and are silently excluded; they
        simply stay warm on the worker that built them.
        """
        items = [(k, e.eset, e.fingerprint)
                 for k, e in self._entries.items() if e.model is None]
        return pack_snapshot("eset", items)

    def restore(self, blob: bytes) -> int:
        """Merge a :meth:`snapshot` blob into this cache; returns the
        number of entries inserted.  Existing entries win over snapshot
        entries under the same key (both are exact artifacts for that key,
        so preference only affects LRU age, never results); the merge
        respects ``max_entries`` by evicting from the cold end."""
        n = 0
        for k, es, fp in unpack_snapshot(blob, "eset"):
            if k in self._entries:
                continue
            _freeze_eset(es)
            self._entries[k] = _Entry(eset=es, fingerprint=fp)
            n += 1
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)
        return n


class CandidatePoolCache:
    """Shared runtime candidate pools keyed by (seed, n_candidates, scope).

    The pools are query-independent LHS draws
    (:func:`~repro.core.tuning.runtime.sample_candidate_pools`), so every
    concurrent query in a session — and every admission epoch of a
    streaming server — reuses one draw: the identical arrays a standalone
    per-query backend samples for the same seed.  Entries above
    ``max_entries`` are LRU-evicted (an evicted pool is simply redrawn on
    the next request, bit-identically — eviction never changes results).

    ``scope`` is the multi-tenant isolation dimension: a streaming server
    passes the tenant id, so one tenant's entries are never handed to
    another even under capacity pressure or per-tenant seed overrides.
    Pools for the same ``(seed, n_candidates)`` are bit-identical across
    scopes (the draw ignores the scope), so scoping costs only duplicate
    storage, never changed results.
    """

    def __init__(self, max_entries: int = 64):
        self.max_entries = max_entries
        self._pools: "OrderedDict[Tuple, Tuple[np.ndarray, np.ndarray]]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._pools)

    def get(self, seed: int, n_candidates: int, scope=None
            ) -> Tuple[np.ndarray, np.ndarray]:
        from ..core.tuning.runtime import sample_candidate_pools  # lazy cycle
        key = (seed, n_candidates, scope)
        pools = self._pools.get(key)
        if pools is None:
            self.misses += 1
            pools = sample_candidate_pools(seed, n_candidates)
            # The cached arrays are handed out by reference to every later
            # hit: freeze them so an in-place mutation by one caller raises
            # instead of silently poisoning all other queries and tenants
            # sharing the pool.
            for a in pools:
                a.setflags(write=False)
            self._pools[key] = pools
        else:
            self.hits += 1
        self._pools.move_to_end(key)
        while len(self._pools) > self.max_entries:
            self._pools.popitem(last=False)
        return pools

    def stats(self) -> dict:
        return {"entries": len(self._pools), "hits": self.hits,
                "misses": self.misses}

    def snapshot(self) -> bytes:
        """Opaque blob of every pool entry (pools are pure LHS draws from
        their key — always content-addressed, nothing is excluded)."""
        return pack_snapshot("pools", list(self._pools.items()))

    def restore(self, blob: bytes) -> int:
        """Merge a :meth:`snapshot` blob; returns entries inserted.
        Restored arrays are re-frozen (see :meth:`get`); existing entries
        win under the same key and ``max_entries`` is enforced."""
        n = 0
        for k, v in unpack_snapshot(blob, "pools"):
            if k in self._pools:
                continue
            for a in v:
                a.setflags(write=False)
            self._pools[k] = v
            n += 1
        while len(self._pools) > self.max_entries:
            self._pools.popitem(last=False)
        return n
