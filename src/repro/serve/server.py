"""Streaming-admission optimizer server (compile-time + runtime, unified).

The paper's cloud constraint is a 1–2 s solving budget per query arriving
in an *online stream*; PR 1/PR 2 built the two optimizer halves for fixed,
fully-formed batches.  :class:`OptimizerServer` closes the gap: it accepts
queries as they arrive (a simulated-clock event queue fed by
:func:`~repro.queryengine.workloads.serving_stream` or
:func:`~repro.queryengine.workloads.multi_tenant_stream`), accumulates
them into deadline-aware micro-batches, routes each micro-batch through
the batched compile-time solve (:meth:`TuningService.tune_batch`) and then
drives the resulting AQE generators through one long-lived, shared
:class:`RuntimeSession` — admitting late arrivals into the *running*
session between fusion rounds instead of holding them for the next batch.

Multi-tenant admission (PR 4): requests carry a tenant id and each tenant
(:class:`~repro.queryengine.workloads.TenantSpec`) brings its own MOO
preference weights, weighted-fair share, priority tier, and solve budget.
Waiting-room policy lives in
:class:`~repro.serve.admission.TenantScheduler`: per-tenant queues with
per-tenant deadline reserves, deficit-round-robin micro-batch composition,
and priority tiers bounded by overdue promotion (no tenant starves).
Tenant weights thread through ``tune_batch`` (per-query weights +
tenant-scoped response-cache keys) and into per-entry runtime picks; the
candidate-pool cache is tenant-scoped too.  Fairness shapes *latency*
only: per-query outputs equal the offline pipeline solved under that
tenant's weights, so tenants can never perturb each other's plans.

Admission policy (deadline-aware micro-batching):

* a micro-batch flushes when ``max_batch`` requests are waiting, or
* when the simulated clock reaches some tenant's flush deadline
  ``oldest arrival + tenant budget − reserve``, where the reserve is a
  per-query EWMA of recent solve times scaled by the expected batch size
  (seeded by ``solve_reserve_s``) — i.e. the latest moment solving can
  start and still make that tenant's budget.

Overload handling (PR 5): when a waiting request's budget has become
*unmeetable* (its flush deadline has passed — even solving immediately
would blow the budget), the tenant's SLO class decides: ``strict``
requests are **shed** (``status="shed"``: rejected as first-class
outcomes, never solved, excluded from latency percentiles), ``degrade``
requests are admitted through the **cheap compile path**
(``TuningService.tune_batch(degraded=...)``: cached template banks or the
Spark defaults — zero fresh Algorithm 1 solves), and ``best_effort``
requests keep queueing as before.  Under sustained overload the server
sheds/degrades exactly the excess instead of silently blowing every
tenant's budget; surviving queries' outputs are untouched (the golden
determinism invariant extends to overload).

Clock model: arrivals advance on the simulated clock; optimizer work
(compile solves, fusion rounds, realization) advances it by measured wall
time.  Batch composition therefore depends on timing — but no per-query
*output* does: compile-time results are per-query deterministic (caches
are exact and tenant-scoped) and every runtime decision depends only on
the query's own candidate rows and its tenant's weights, so the served
plans and objectives are bit-identical to the offline ``tune_batch`` →
``RuntimeSession.run_batch`` pipeline per tenant — on the oracle backend
and on the model backend under the default deterministic γ
(``gamma_mode="structural"``) — however the stream is sliced.  (As
everywhere in the serving stack, the guarantee is stated for the default
numpy/float64 kernel routing; forcing the f32 Pallas kernels via the env
thresholds carries the usual f32 tie caveat.)

Caches (:class:`~repro.serve.cache.EffectiveSetCache`,
:class:`~repro.serve.service.ResponseCache`,
:class:`~repro.serve.cache.CandidatePoolCache`) live on the long-lived
service/session objects, so they amortize across micro-batches and
admission epochs — the whole point of serving over per-request solving.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.models.perf_model import PerfModel
from ..core.moo.hmooc import HMOOCConfig
from ..core.tuning.compile_time import CompileTimeResult
from ..queryengine.aqe import AQEResult
from ..queryengine.workloads import StreamRequest, TenantSpec
from .admission import TenantScheduler
from .runtime import RuntimeSession
from .service import TuningService

__all__ = ["OptimizerServer", "ServerConfig", "ServedQuery", "ServerStats",
           "jain_index"]

Weights = Tuple[float, float]


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    """Admission/scheduling policy of the streaming server."""
    max_batch: int = 8                 # flush when this many requests wait
    solve_budget_s: float = 1.0        # the paper's per-query cloud budget
    solve_reserve_s: float = 0.25      # initial per-QUERY solve reserve (EWMA
                                       # seed; deadlines scale it by the
                                       # expected batch size)
    reserve_ewma: float = 0.3          # EWMA weight of the newest solve
    admit_mid_session: bool = True     # late arrivals join the running session
    isolate_tenant_pools: bool = True  # tenant-scoped candidate-pool entries


@dataclasses.dataclass
class ServedQuery:
    """One request's lifecycle through the server (simulated-clock times).

    ``status`` is the request's admission outcome:

    * ``"served"``   — full-quality solve, finished normally;
    * ``"degraded"`` — budget was unmeetable at admission and the tenant's
      SLO class is ``degrade``: solved via the cheap compile path
      (template-cache banks / Spark defaults, no fresh Algorithm 1);
    * ``"shed"``     — budget was unmeetable and the tenant's SLO class is
      ``strict``: rejected without solving (``ct``/``result`` stay None;
      ``finished_s`` records the rejection time).

    Latency reports must aggregate over finished (non-shed) queries only —
    a shed query's ``compiled_s`` is NaN by construction.
    """
    rid: int
    request: StreamRequest
    arrival_s: float
    tenant: str = "default"
    status: str = "served"             # served | degraded | shed
    admitted_s: float = math.nan       # micro-batch flush began
    compiled_s: float = math.nan       # compile-time θ ready
    finished_s: float = math.nan       # final plan realized (or shed time)
    joined_running: bool = False       # admitted into an already-live session
    ct: Optional[CompileTimeResult] = None
    result: Optional[AQEResult] = None

    @property
    def solve_latency_s(self) -> float:
        """Arrival-to-compile-time-θ latency (the paper's solve budget is
        stated against this span: it includes the waiting-room time)."""
        return self.compiled_s - self.arrival_s

    @property
    def plan_latency_s(self) -> float:
        """Arrival-to-final-plan latency (through runtime re-tuning)."""
        return self.finished_s - self.arrival_s


@dataclasses.dataclass
class ServerStats:
    n_queries: int = 0
    n_finished: int = 0                # solved to completion (non-shed)
    n_micro_batches: int = 0
    n_joined_running: int = 0          # admissions into a live session
    n_shed: int = 0                    # strict-SLO rejections
    n_degraded: int = 0                # degrade-SLO cheap-path admissions
    rounds: int = 0                    # fusion rounds over the run
    makespan_s: float = 0.0            # last finish − first arrival (sim)
    wall_time_s: float = 0.0           # real time spent in serve()
    tenant_slots: Dict[str, int] = dataclasses.field(default_factory=dict)
    # Per-flush (charged clock window, batch size): the exact amounts the
    # simulated clock advanced by and note_solve folded into the reserve
    # EWMAs — the reserve regression test replays these.
    flush_windows: List[Tuple[float, int]] = dataclasses.field(
        default_factory=list)
    # Per-flush (tune_batch wall time, batch size): the compile-time solve
    # slice of each flush window, excluding AQE admission — what the
    # jitted-solve benchmarks report p99 solve latency from.
    tune_windows: List[Tuple[float, int]] = dataclasses.field(
        default_factory=list)

    @property
    def qps(self) -> float:
        """Served throughput: *finished* queries over the makespan — a shed
        request is rejected, not served, and must not inflate qps."""
        return self.n_finished / self.makespan_s if self.makespan_s else 0.0


class OptimizerServer:
    """Unified streaming server over both optimizer halves.

    One instance is a long-lived process: :meth:`serve` can be called on
    successive streams and every cache — and the tenant scheduler's
    fairness/reserve state — keeps amortizing.
    """

    def __init__(
        self,
        *,
        config: ServerConfig = ServerConfig(),
        weights: Optional[Weights] = None,
        cfg: Optional[HMOOCConfig] = None,
        model: Optional[PerfModel] = None,
        tuning: Optional[TuningService] = None,
        session: Optional[RuntimeSession] = None,
        tenants: Sequence[TenantSpec] = (),
    ):
        """``weights`` parameterizes the default-built session and is the
        fallback preference for tenants that configure none; ``cfg`` and
        ``model`` parameterize the default-built *compile-time* service
        (``model`` is the §5.1 subQ objective model; the default session
        stays on the oracle runtime backend).  For model-backed runtime
        re-scoring pass a prebuilt ``session`` with
        ``model_subq``/``model_qs`` set; prebuilt ``tuning``/``session``
        objects also share caches across servers.  ``tenants`` registers
        per-tenant admission policy (weights, share, priority, budget);
        tenant ids not listed get default policy on first sight.  Mixing a
        prebuilt object with the knobs it subsumes is rejected rather than
        silently resolved."""
        if tuning is not None and (cfg is not None or model is not None):
            raise ValueError(
                "pass cfg/model or a prebuilt tuning service, not both")
        if session is not None and weights is not None \
                and tuple(weights) != tuple(session.weights):
            raise ValueError(
                f"weights={tuple(weights)} conflicts with the prebuilt "
                f"session's weights={tuple(session.weights)}")
        self.config = config
        self.tuning = tuning if tuning is not None else TuningService(
            model=model, cfg=cfg if cfg is not None else HMOOCConfig())
        self.session = session if session is not None else RuntimeSession(
            weights=weights if weights is not None else (0.9, 0.1))
        self.weights = self.session.weights
        self.scheduler = TenantScheduler(
            tenants, budget_s=config.solve_budget_s,
            reserve_q_s=config.solve_reserve_s,
            reserve_ewma=config.reserve_ewma)
        self.last_run = ServerStats()

    # -- per-tenant policy ---------------------------------------------------
    def tenant_weights(self, tenant: str) -> Weights:
        w = self.scheduler.state(tenant).weights
        return tuple(w) if w is not None else tuple(self.weights)

    # -- main loop -----------------------------------------------------------
    def serve(self, requests: Sequence[StreamRequest]) -> List[ServedQuery]:
        """Serve a timed stream to completion; results in request order.

        Each returned :class:`ServedQuery` carries the compile-time result,
        the realized :class:`AQEResult`, and the simulated-clock lifecycle
        times the latency metrics derive from.
        """
        wall0 = time.perf_counter()
        cfgv = self.config
        sched = self.scheduler
        if self.session.n_active:
            raise RuntimeError(
                f"serve() requires an idle session; {self.session.n_active} "
                "entries are already active (admitted outside this server)")
        if sched.total_waiting():
            raise RuntimeError(
                "serve() requires an empty admission queue; "
                f"{sched.total_waiting()} requests are already waiting")
        served: Dict[int, ServedQuery] = {
            r.rid: ServedQuery(rid=r.rid, request=r, arrival_s=r.arrival_s,
                               tenant=r.tenant)
            for r in requests}
        if len(served) != len(requests):
            raise ValueError(
                f"duplicate rids in request stream: {len(requests)} requests "
                f"but {len(served)} distinct rids")
        incoming = sorted(served.values(),
                          key=lambda s: (s.arrival_s, s.rid))
        pos = 0                                # next unadmitted arrival
        in_flight: Dict[int, ServedQuery] = {}  # rid -> admitted, unrealized
        t = incoming[0].arrival_s if incoming else 0.0
        first_arrival = t
        n_batches = 0
        n_joined_running = 0
        n_shed = 0
        n_degraded = 0
        flush_windows: List[Tuple[float, int]] = []
        tune_windows: List[Tuple[float, int]] = []
        flushes_since_round = 0
        rounds0 = self.session.rounds_total
        slots0 = {st.name: st.slots_granted for st in sched.states()}

        def admit_arrived(now: float) -> None:
            nonlocal pos
            while pos < len(incoming) and incoming[pos].arrival_s <= now:
                s = incoming[pos]
                sched.enqueue(s.tenant, s, s.arrival_s)
                pos += 1

        def flush_due(now: float) -> bool:
            if not sched.total_waiting():
                return False
            if self.session.n_active:
                # A session is live: join it eagerly between fusion rounds
                # (the optimizer is busy either way), unless running
                # batch-only.  At most one flush per round, so sustained
                # arrivals can never starve in-flight queries of the rounds
                # they need to finish.
                return cfgv.admit_mid_session and flushes_since_round < 1
            if sched.total_waiting() >= cfgv.max_batch:
                return True
            if pos >= len(incoming):
                # End of stream: nothing else will arrive, waiting longer
                # only adds latency.
                return True
            return sched.deadline_due(now, cfgv.max_batch)

        def finish(cohort, results, now: float) -> None:
            for e, res in zip(cohort, results):
                s = served[e.tag]
                s.result = res
                s.finished_s = now
                in_flight.pop(s.rid, None)

        admit_arrived(t)
        while pos < len(incoming) or sched.total_waiting() or in_flight:
            if flush_due(t):
                # Overload triage first: strict-SLO requests whose budget is
                # already unmeetable are rejected here — first-class
                # outcomes, never solved, never poisoning latency stats.
                for _, s in sched.shed_unmeetable(t, cfgv.max_batch):
                    s.status = "shed"
                    s.finished_s = t
                    n_shed += 1
                admits = sched.compose(t, cfgv.max_batch)
                if not admits:
                    continue           # everything waiting was shed
                batch = [a.item for a in admits]
                n_batches += 1
                flushes_since_round += 1
                for a, s in zip(admits, batch):
                    s.admitted_s = t
                    if a.degrade:
                        s.status = "degraded"
                        n_degraded += 1
                batch_w = [self.tenant_weights(s.tenant) for s in batch]
                t0 = time.perf_counter()
                cts = self.tuning.tune_batch(
                    [s.request.query for s in batch], batch_w,
                    tenants=[s.tenant for s in batch],
                    degraded=[a.degrade for a in admits])
                tune_windows.append((self.tuning.last_batch.wall_time,
                                     len(batch)))
                joined_running = self.session.n_active > 0
                for s, ct, w in zip(batch, cts, batch_w):
                    s.ct = ct
                    s.joined_running = joined_running
                    if joined_running:
                        n_joined_running += 1
                    self.session.admit(
                        s.request.query, ct, tag=s.rid, weights=w,
                        pool_scope=(s.tenant if cfgv.isolate_tenant_pools
                                    else None))
                    in_flight[s.rid] = s
                # One window measurement feeds both the clock charge and the
                # reserve EWMA: the whole flush — the batched solve plus
                # each query's initial AQE planning step inside admit().
                # (Feeding note_solve only the tune_batch slice made the
                # reserve undershoot the true per-query admission cost.)
                window = time.perf_counter() - t0
                sched.note_solve(window, len(batch),
                                 (s.tenant for s in batch))
                flush_windows.append((window, len(batch)))
                t += window
                for s in batch:
                    s.compiled_s = t
                admit_arrived(t)
                continue
            if self.session.has_pending() or self.session.n_active:
                flushes_since_round = 0
                t0 = time.perf_counter()
                self.session.step_round()
                done = self.session.retire_ready()
                results = self.session.realize(done) if done else []
                t += time.perf_counter() - t0
                if done:
                    finish(done, results, t)
                admit_arrived(t)
                continue
            # Idle: jump the simulated clock to the next event.
            nxt = min(incoming[pos].arrival_s if pos < len(incoming)
                      else math.inf,
                      sched.next_deadline(cfgv.max_batch))
            if not math.isfinite(nxt):
                break
            t = max(t, nxt)
            admit_arrived(t)

        out = [served[r.rid] for r in requests]
        finished = [s.finished_s for s in out if math.isfinite(s.finished_s)]
        self.last_run = ServerStats(
            n_queries=len(out),
            n_finished=sum(1 for s in out if s.status != "shed"
                           and math.isfinite(s.finished_s)),
            n_micro_batches=n_batches,
            n_joined_running=n_joined_running,
            n_shed=n_shed, n_degraded=n_degraded,
            rounds=self.session.rounds_total - rounds0,
            makespan_s=(max(finished) - first_arrival) if finished else 0.0,
            wall_time_s=time.perf_counter() - wall0,
            tenant_slots={st.name: st.slots_granted - slots0.get(st.name, 0)
                          for st in sched.states()
                          if st.slots_granted - slots0.get(st.name, 0)},
            flush_windows=flush_windows,
            tune_windows=tune_windows)
        return out

    # -- reporting -----------------------------------------------------------
    def _goodput(self, sub: Sequence[ServedQuery]) -> float:
        """Fraction of requests finishing inside their tenant's budget.

        Shed requests count against goodput (they never finish); the
        denominator is *all* requests, so goodput + shed rate + late rate
        partition the stream.
        """
        if not sub:
            return math.nan
        ok = sum(1 for s in sub
                 if s.status != "shed" and math.isfinite(s.finished_s)
                 and s.plan_latency_s
                 <= self.scheduler.state(s.tenant).budget_s)
        return ok / len(sub)

    def latency_report(self, served: Sequence[ServedQuery]) -> dict:
        """p50/p99/max of the two latency metrics plus throughput.

        Latency percentiles aggregate over *finished* queries only
        (``status != "shed"``): one rejected request must not NaN-poison
        the whole report.  Shed/degrade are reported as first-class
        counts and rates alongside, plus goodput — the fraction of all
        requests that finished within their tenant's budget.

        With multi-tenant traffic the report adds a per-tenant breakdown
        (including each tenant's SLO class and shed/degrade counts) and
        the Jain fairness index over per-tenant p99 plan latency of
        finished queries (1.0 = perfectly even tails across tenants;
        tenants with nothing finished are excluded).
        """
        fin = [s for s in served
               if s.status != "shed" and math.isfinite(s.finished_s)]
        plan = np.array([s.plan_latency_s for s in fin], np.float64)
        solve = np.array([s.solve_latency_s for s in fin], np.float64)
        n_shed = sum(1 for s in served if s.status == "shed")
        n_degraded = sum(1 for s in served if s.status == "degraded")
        st = self.last_run
        rep = {
            "n_queries": st.n_queries,
            "n_finished": len(fin),
            "n_shed": n_shed,
            "n_degraded": n_degraded,
            "shed_rate": n_shed / len(served) if served else math.nan,
            "degrade_rate": n_degraded / len(served) if served else math.nan,
            "goodput": self._goodput(served),
            "n_micro_batches": st.n_micro_batches,
            "n_joined_running": st.n_joined_running,
            "rounds": st.rounds,
            "makespan_s": st.makespan_s,
            "qps": st.qps,
            "solve_latency_s": _pcts(solve),
            "plan_latency_s": _pcts(plan),
        }
        names = sorted({s.tenant for s in served})
        if len(names) > 1 or (names and names != ["default"]):
            per = {}
            for name in names:
                sub = [s for s in served if s.tenant == name]
                sub_fin = [s for s in sub if s.status != "shed"
                           and math.isfinite(s.finished_s)]
                ts = self.scheduler.state(name)
                shed = sum(1 for s in sub if s.status == "shed")
                degr = sum(1 for s in sub if s.status == "degraded")
                per[name] = {
                    "n_queries": len(sub),
                    "n_finished": len(sub_fin),
                    "slo": ts.slo,
                    "budget_s": ts.budget_s,
                    "n_shed": shed,
                    "n_degraded": degr,
                    "shed_rate": shed / len(sub),
                    "degrade_rate": degr / len(sub),
                    "goodput": self._goodput(sub),
                    "batch_slots": st.tenant_slots.get(name, 0),
                    "solve_latency_s": _pcts(np.array(
                        [s.solve_latency_s for s in sub_fin], np.float64)),
                    "plan_latency_s": _pcts(np.array(
                        [s.plan_latency_s for s in sub_fin], np.float64)),
                }
            rep["tenants"] = per
            rep["fairness_jain"] = jain_index(
                [per[n]["plan_latency_s"]["p99"] for n in names])
        return rep


def jain_index(x: Sequence[float]) -> float:
    """Jain fairness index (Σx)² / (n·Σx²): 1.0 = perfectly even.

    Non-finite entries are dropped (an all-shed tenant's p99 is NaN — it
    must not wipe out the whole fairness report); NaN only when nothing
    finite (or nonzero) remains.
    """
    a = np.asarray(list(x), np.float64)
    a = a[np.isfinite(a)]
    if a.size == 0 or (a == 0).all():
        return math.nan
    return float(a.sum() ** 2 / (a.size * (a * a).sum()))


def _pcts(x: np.ndarray) -> dict:
    if x.size == 0:
        return {"p50": math.nan, "p99": math.nan, "max": math.nan,
                "mean": math.nan}
    return {"p50": float(np.percentile(x, 50)),
            "p99": float(np.percentile(x, 99)),
            "max": float(x.max()),
            "mean": float(x.mean())}
