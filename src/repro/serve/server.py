"""Streaming-admission optimizer server (compile-time + runtime, unified).

The paper's cloud constraint is a 1–2 s solving budget per query arriving
in an *online stream*; PR 1/PR 2 built the two optimizer halves for fixed,
fully-formed batches.  :class:`OptimizerServer` closes the gap: it accepts
queries as they arrive (a simulated-clock event queue fed by
:func:`~repro.queryengine.workloads.serving_stream` with an
:class:`~repro.queryengine.workloads.ArrivalModel`), accumulates them into
deadline-aware micro-batches, routes each micro-batch through the batched
compile-time solve (:meth:`TuningService.tune_batch`) and then drives the
resulting AQE generators through one long-lived, shared
:class:`RuntimeSession` — admitting late arrivals into the *running*
session between fusion rounds instead of holding them for the next batch.

Admission policy (deadline-aware micro-batching):

* a micro-batch flushes when ``max_batch`` requests are waiting, or
* when the simulated clock reaches the oldest waiting request's flush
  deadline ``arrival + solve_budget_s − reserve``, where the reserve is an
  EWMA of recent micro-batch solve times (seeded by ``solve_reserve_s``) —
  i.e. the latest moment solving can start and still make the budget.

Clock model: arrivals advance on the simulated clock; optimizer work
(compile solves, fusion rounds, realization) advances it by measured wall
time.  Batch composition therefore depends on timing — but no per-query
*output* does: compile-time results are per-query deterministic (caches
are exact) and every runtime decision depends only on the query's own
candidate rows, so the served plans and objectives are bit-identical to
the offline ``tune_batch`` → ``RuntimeSession.run_batch`` pipeline on the
oracle backend, however the stream is sliced.

Caches (:class:`~repro.serve.cache.EffectiveSetCache`,
:class:`~repro.serve.service.ResponseCache`,
:class:`~repro.serve.cache.CandidatePoolCache`) live on the long-lived
service/session objects, so they amortize across micro-batches and
admission epochs — the whole point of serving over per-request solving.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.models.perf_model import PerfModel
from ..core.moo.hmooc import HMOOCConfig
from ..core.tuning.compile_time import CompileTimeResult
from ..queryengine.aqe import AQEResult
from ..queryengine.workloads import StreamRequest
from .runtime import RuntimeSession
from .service import TuningService

__all__ = ["OptimizerServer", "ServerConfig", "ServedQuery", "ServerStats"]

Weights = Tuple[float, float]


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    """Admission/scheduling policy of the streaming server."""
    max_batch: int = 8                 # flush when this many requests wait
    solve_budget_s: float = 1.0        # the paper's per-query cloud budget
    solve_reserve_s: float = 0.25      # initial solve-time reserve (EWMA seed)
    reserve_ewma: float = 0.3          # EWMA weight of the newest batch solve
    admit_mid_session: bool = True     # late arrivals join the running session


@dataclasses.dataclass
class ServedQuery:
    """One request's lifecycle through the server (simulated-clock times)."""
    rid: int
    request: StreamRequest
    arrival_s: float
    admitted_s: float = math.nan       # micro-batch flush began
    compiled_s: float = math.nan       # compile-time θ ready
    finished_s: float = math.nan       # final plan + objectives realized
    joined_running: bool = False       # admitted into an already-live session
    ct: Optional[CompileTimeResult] = None
    result: Optional[AQEResult] = None

    @property
    def solve_latency_s(self) -> float:
        """Admission-to-compile-time-θ latency (the paper's solve budget)."""
        return self.compiled_s - self.arrival_s

    @property
    def plan_latency_s(self) -> float:
        """Admission-to-final-plan latency (through runtime re-tuning)."""
        return self.finished_s - self.arrival_s


@dataclasses.dataclass
class ServerStats:
    n_queries: int = 0
    n_micro_batches: int = 0
    n_joined_running: int = 0          # admissions into a live session
    rounds: int = 0                    # fusion rounds over the run
    makespan_s: float = 0.0            # last finish − first arrival (sim)
    wall_time_s: float = 0.0           # real time spent in serve()

    @property
    def qps(self) -> float:
        return self.n_queries / self.makespan_s if self.makespan_s else 0.0


class OptimizerServer:
    """Unified streaming server over both optimizer halves.

    One instance is a long-lived process: :meth:`serve` can be called on
    successive streams and every cache keeps amortizing.
    """

    def __init__(
        self,
        *,
        config: ServerConfig = ServerConfig(),
        weights: Optional[Weights] = None,
        cfg: Optional[HMOOCConfig] = None,
        model: Optional[PerfModel] = None,
        tuning: Optional[TuningService] = None,
        session: Optional[RuntimeSession] = None,
    ):
        """``weights`` parameterizes the default-built session, ``cfg`` and
        ``model`` the default-built *compile-time* service (``model`` is the
        §5.1 subQ objective model; the default session stays on the oracle
        runtime backend).  For model-backed runtime re-scoring pass a
        prebuilt ``session`` with ``model_subq``/``model_qs`` set; prebuilt
        ``tuning``/``session`` objects also share caches across servers.
        Mixing a prebuilt object with the knobs it subsumes is rejected
        rather than silently resolved."""
        if tuning is not None and (cfg is not None or model is not None):
            raise ValueError(
                "pass cfg/model or a prebuilt tuning service, not both")
        if session is not None and weights is not None \
                and tuple(weights) != tuple(session.weights):
            raise ValueError(
                f"weights={tuple(weights)} conflicts with the prebuilt "
                f"session's weights={tuple(session.weights)}")
        self.config = config
        self.tuning = tuning if tuning is not None else TuningService(
            model=model, cfg=cfg if cfg is not None else HMOOCConfig())
        self.session = session if session is not None else RuntimeSession(
            weights=weights if weights is not None else (0.9, 0.1))
        self.weights = self.session.weights
        self._reserve_s = config.solve_reserve_s
        self.last_run = ServerStats()

    # -- scheduling ----------------------------------------------------------
    def _flush_deadline(self, waiting: "deque[ServedQuery]") -> float:
        if not waiting:
            return math.inf
        return (waiting[0].arrival_s + self.config.solve_budget_s
                - self._reserve_s)

    def _note_solve(self, dt: float, n: int) -> None:
        # EWMA of the per-batch solve wall time: the reserve the deadline
        # policy holds back so a flush still meets the budget.
        del n
        a = self.config.reserve_ewma
        self._reserve_s = (1 - a) * self._reserve_s + a * dt

    # -- main loop -----------------------------------------------------------
    def serve(self, requests: Sequence[StreamRequest]) -> List[ServedQuery]:
        """Serve a timed stream to completion; results in request order.

        Each returned :class:`ServedQuery` carries the compile-time result,
        the realized :class:`AQEResult`, and the simulated-clock lifecycle
        times the latency metrics derive from.
        """
        wall0 = time.perf_counter()
        cfgv = self.config
        if self.session.n_active:
            raise RuntimeError(
                f"serve() requires an idle session; {self.session.n_active} "
                "entries are already active (admitted outside this server)")
        served: Dict[int, ServedQuery] = {
            r.rid: ServedQuery(rid=r.rid, request=r, arrival_s=r.arrival_s)
            for r in requests}
        if len(served) != len(requests):
            raise ValueError(
                f"duplicate rids in request stream: {len(requests)} requests "
                f"but {len(served)} distinct rids")
        incoming = deque(sorted(served.values(), key=lambda s: (s.arrival_s,
                                                                s.rid)))
        waiting: "deque[ServedQuery]" = deque()
        in_flight: Dict[int, ServedQuery] = {}   # rid -> admitted, unrealized
        t = incoming[0].arrival_s if incoming else 0.0
        first_arrival = t
        n_batches = 0
        n_joined_running = 0
        flushes_since_round = 0
        rounds0 = self.session.rounds_total

        def admit_arrived(now: float) -> None:
            while incoming and incoming[0].arrival_s <= now:
                waiting.append(incoming.popleft())

        def flush_due(now: float) -> bool:
            if not waiting:
                return False
            if self.session.n_active:
                # A session is live: join it eagerly between fusion rounds
                # (the optimizer is busy either way), unless running
                # batch-only.  At most one flush per round, so sustained
                # arrivals can never starve in-flight queries of the rounds
                # they need to finish.
                return cfgv.admit_mid_session and flushes_since_round < 1
            if len(waiting) >= cfgv.max_batch:
                return True
            if not incoming:
                # End of stream: nothing else will arrive, waiting longer
                # only adds latency.
                return True
            return now >= self._flush_deadline(waiting)

        def finish(cohort, results, now: float) -> None:
            for e, res in zip(cohort, results):
                s = served[e.tag]
                s.result = res
                s.finished_s = now
                in_flight.pop(s.rid, None)

        admit_arrived(t)
        while incoming or waiting or in_flight:
            if flush_due(t):
                batch = [waiting.popleft()
                         for _ in range(min(cfgv.max_batch, len(waiting)))]
                n_batches += 1
                flushes_since_round += 1
                for s in batch:
                    s.admitted_s = t
                t0 = time.perf_counter()
                cts = self.tuning.tune_batch([s.request.query for s in batch],
                                             self.weights)
                self._note_solve(time.perf_counter() - t0, len(batch))
                joined_running = self.session.n_active > 0
                for s, ct in zip(batch, cts):
                    s.ct = ct
                    s.joined_running = joined_running
                    if joined_running:
                        n_joined_running += 1
                    self.session.admit(s.request.query, ct, tag=s.rid)
                    in_flight[s.rid] = s
                # The clock covers the whole window — the solve plus each
                # query's initial AQE planning step inside admit().
                t += time.perf_counter() - t0
                for s in batch:
                    s.compiled_s = t
                admit_arrived(t)
                continue
            if self.session.has_pending() or self.session.n_active:
                flushes_since_round = 0
                t0 = time.perf_counter()
                self.session.step_round()
                done = self.session.retire_ready()
                results = self.session.realize(done) if done else []
                t += time.perf_counter() - t0
                if done:
                    finish(done, results, t)
                admit_arrived(t)
                continue
            # Idle: jump the simulated clock to the next event.
            nxt = min(incoming[0].arrival_s if incoming else math.inf,
                      self._flush_deadline(waiting))
            if not math.isfinite(nxt):
                break
            t = max(t, nxt)
            admit_arrived(t)

        out = [served[r.rid] for r in requests]
        finished = [s.finished_s for s in out if math.isfinite(s.finished_s)]
        self.last_run = ServerStats(
            n_queries=len(out), n_micro_batches=n_batches,
            n_joined_running=n_joined_running,
            rounds=self.session.rounds_total - rounds0,
            makespan_s=(max(finished) - first_arrival) if finished else 0.0,
            wall_time_s=time.perf_counter() - wall0)
        return out

    # -- reporting -----------------------------------------------------------
    def latency_report(self, served: Sequence[ServedQuery]) -> dict:
        """p50/p99/max of the two latency metrics plus throughput."""
        plan = np.array([s.plan_latency_s for s in served], np.float64)
        solve = np.array([s.solve_latency_s for s in served], np.float64)
        st = self.last_run
        return {
            "n_queries": st.n_queries,
            "n_micro_batches": st.n_micro_batches,
            "n_joined_running": st.n_joined_running,
            "rounds": st.rounds,
            "makespan_s": st.makespan_s,
            "qps": st.qps,
            "solve_latency_s": _pcts(solve),
            "plan_latency_s": _pcts(plan),
        }


def _pcts(x: np.ndarray) -> dict:
    if x.size == 0:
        return {"p50": math.nan, "p99": math.nan, "max": math.nan,
                "mean": math.nan}
    return {"p50": float(np.percentile(x, 50)),
            "p99": float(np.percentile(x, 99)),
            "max": float(x.max()),
            "mean": float(x.mean())}
