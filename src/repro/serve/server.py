"""Streaming-admission optimizer server (compile-time + runtime, unified).

The paper's cloud constraint is a 1–2 s solving budget per query arriving
in an *online stream*; PR 1/PR 2 built the two optimizer halves for fixed,
fully-formed batches.  :class:`OptimizerServer` closes the gap: it accepts
queries as they arrive (a simulated-clock event queue fed by
:func:`~repro.queryengine.workloads.serving_stream` or
:func:`~repro.queryengine.workloads.multi_tenant_stream`), accumulates
them into deadline-aware micro-batches, routes each micro-batch through
the batched compile-time solve (:meth:`TuningService.tune_batch`) and then
drives the resulting AQE generators through one long-lived, shared
:class:`RuntimeSession` — admitting late arrivals into the *running*
session between fusion rounds instead of holding them for the next batch.

Multi-tenant admission (PR 4): requests carry a tenant id and each tenant
(:class:`~repro.queryengine.workloads.TenantSpec`) brings its own MOO
preference weights, weighted-fair share, priority tier, and solve budget.
Waiting-room policy lives in
:class:`~repro.serve.admission.TenantScheduler`: per-tenant queues with
per-tenant deadline reserves, deficit-round-robin micro-batch composition,
and priority tiers bounded by overdue promotion (no tenant starves).
Tenant weights thread through ``tune_batch`` (per-query weights +
tenant-scoped response-cache keys) and into per-entry runtime picks; the
candidate-pool cache is tenant-scoped too.  Fairness shapes *latency*
only: per-query outputs equal the offline pipeline solved under that
tenant's weights, so tenants can never perturb each other's plans.

Admission policy (deadline-aware micro-batching):

* a micro-batch flushes when ``max_batch`` requests are waiting, or
* when the simulated clock reaches some tenant's flush deadline
  ``oldest arrival + tenant budget − reserve``, where the reserve is a
  per-query EWMA of recent solve times scaled by the expected batch size
  (seeded by ``solve_reserve_s``) — i.e. the latest moment solving can
  start and still make that tenant's budget.

Overload handling (PR 5): when a waiting request's budget has become
*unmeetable* (its flush deadline has passed — even solving immediately
would blow the budget), the tenant's SLO class decides: ``strict``
requests are **shed** (``status="shed"``: rejected as first-class
outcomes, never solved, excluded from latency percentiles), ``degrade``
requests are admitted through the **cheap compile path**
(``TuningService.tune_batch(degraded=...)``: cached template banks or the
Spark defaults — zero fresh Algorithm 1 solves), and ``best_effort``
requests keep queueing as before.  Under sustained overload the server
sheds/degrades exactly the excess instead of silently blowing every
tenant's budget; surviving queries' outputs are untouched (the golden
determinism invariant extends to overload).

Clock model: arrivals advance on the simulated clock; optimizer work
(compile solves, fusion rounds, realization) advances it by measured wall
time — or, with ``ServerConfig.clock`` set to a :class:`ServiceTimeModel`,
by a calibrated deterministic cost model, making the whole admission
timeline a pure function of the stream and the config.  Batch composition
therefore depends on timing — but no per-query *output* does: compile-time results are per-query deterministic (caches
are exact and tenant-scoped) and every runtime decision depends only on
the query's own candidate rows and its tenant's weights, so the served
plans and objectives are bit-identical to the offline ``tune_batch`` →
``RuntimeSession.run_batch`` pipeline per tenant — on the oracle backend
and on the model backend under the default deterministic γ
(``gamma_mode="structural"``) — however the stream is sliced.  (As
everywhere in the serving stack, the guarantee is stated for the default
numpy/float64 kernel routing; forcing the f32 Pallas kernels via the env
thresholds carries the usual f32 tie caveat.)

Caches (:class:`~repro.serve.cache.EffectiveSetCache`,
:class:`~repro.serve.service.ResponseCache`,
:class:`~repro.serve.cache.CandidatePoolCache`) live on the long-lived
service/session objects, so they amortize across micro-batches and
admission epochs — the whole point of serving over per-request solving.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.models.perf_model import PerfModel
from ..core.moo.hmooc import HMOOCConfig
from ..core.tuning.compile_time import CompileTimeResult
from ..queryengine.aqe import AQEResult
from ..queryengine.workloads import StreamRequest, TenantSpec
from .admission import ElasticController, ElasticPolicy, TenantScheduler
from .runtime import RuntimeSession
from .service import TuningService

__all__ = ["OptimizerServer", "ServerConfig", "ServedQuery", "ServerStats",
           "ServiceTimeModel", "jain_index", "REJECTED_STATUSES"]

Weights = Tuple[float, float]

# Statuses that never produced a plan: excluded from latency percentiles,
# counted against goodput.
REJECTED_STATUSES = ("shed", "rate_limited")


@dataclasses.dataclass(frozen=True)
class ServiceTimeModel:
    """Deterministic charged-time model for the simulated clock.

    By default :meth:`OptimizerServer.serve` charges *measured wall time*
    for optimizer work, so batch composition — and with it every
    shed/degrade/scale decision — inherits host timing noise.  A
    ``ServiceTimeModel`` replaces those charges with a calibrated cost
    model, making ``serve()`` a pure function of the stream and the
    config: two runs over the same scenario charge identical clock
    windows, flush identical batches, and reach identical admission
    outcomes.  Per-query *outputs* are clock-independent either way (the
    golden replay invariant); what the model pins down is the admission
    *timeline*, which is exactly what policy benchmarks (elastic vs
    static capacity) need to compare free of noise.

    ``flush_points`` is a sorted ``((batch_size, seconds), ...)`` table
    of calibrated flush costs (compile solve + admission for one
    micro-batch of that size); charges interpolate linearly between knots
    and extrapolate the outermost segments, clamped at 0.  ``round_s`` is
    charged per fusion round (step + retire + realize).

    Not every batch member costs a full solve: response-cache hits and
    degraded queries (template-bank reuse, default θ) skip the solver and
    cost well under a millisecond where a fresh solve costs tens.
    ``flush_s`` therefore takes the number of such *cheap* members and
    charges ``flush_s(n_full) + n_cheap * cheap_s`` — pricing the very
    mechanism preemptive degradation exploits (converting full solves
    into cheap ones under pressure) instead of flattening it into a
    size-only charge.  Calibrate all three from measured warm flush
    windows — see ``benchmarks/bench_server.py run_scenarios``.

    Worker concurrency: a fleet co-locates ``n_workers`` replicas on the
    shared host, so each replica's optimizer work runs slower than the
    single-process calibration by a contention factor.  ``worker_scale``
    is a ``((n_workers, multiplier), ...)`` knot table (same interpolation
    rules as ``flush_points``; the default single knot ``((1, 1.0),)``
    means no contention at any width) and every charged cost — flush,
    round, cheap member — is scaled by the multiplier at ``n_workers``.
    :meth:`with_workers` re-prices the *same* calibrated model for a
    different replica count, so a fleet's per-worker admission timelines
    stay a pure function of stream + config at every width.
    """
    flush_points: Tuple[Tuple[int, float], ...]
    round_s: float = 0.0
    cheap_s: float = 0.0
    n_workers: int = 1
    worker_scale: Tuple[Tuple[int, float], ...] = ((1, 1.0),)

    def __post_init__(self):
        pts = tuple(sorted((int(n), float(s)) for n, s in self.flush_points))
        object.__setattr__(self, "flush_points", pts)
        if not pts:
            raise ValueError("flush_points needs at least one knot")
        if pts[0][0] < 1 or len({n for n, _ in pts}) != len(pts):
            raise ValueError(f"batch-size knots must be unique and >= 1, "
                             f"got {pts}")
        bad = [s for _, s in pts] + [self.round_s, self.cheap_s]
        if any(not math.isfinite(s) or s < 0.0 for s in bad):
            raise ValueError(f"costs must be finite and >= 0, got {bad}")
        object.__setattr__(self, "n_workers", int(self.n_workers))
        if self.n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {self.n_workers}")
        ws = tuple(sorted((int(n), float(m)) for n, m in self.worker_scale))
        object.__setattr__(self, "worker_scale", ws)
        if not ws or ws[0][0] < 1 or len({n for n, _ in ws}) != len(ws):
            raise ValueError(f"worker-count knots must be unique and >= 1, "
                             f"got {ws}")
        if any(not math.isfinite(m) or m <= 0.0 for _, m in ws):
            raise ValueError(f"worker-scale multipliers must be finite and "
                             f"> 0, got {ws}")

    def flush_s(self, n: int, n_cheap: int = 0) -> float:
        """Charged cost of flushing ``n`` queries, ``n_cheap`` of which
        skipped the full solver (cache hits / degraded paths)."""
        n_cheap = min(max(int(n_cheap), 0), int(n))
        full = int(n) - n_cheap
        return (self._interp(full)
                + n_cheap * self.cheap_s) * self.worker_mult()

    def round_cost_s(self) -> float:
        """Charged cost of one fusion round at the current worker count."""
        return self.round_s * self.worker_mult()

    def worker_mult(self) -> float:
        """Contention multiplier of ``worker_scale`` at ``n_workers``."""
        return self._interp_pts(self.worker_scale, self.n_workers)

    def with_workers(self, n: int) -> "ServiceTimeModel":
        """The same calibrated model re-priced for ``n`` co-located
        workers (idempotent: only ``n_workers`` changes)."""
        return dataclasses.replace(self, n_workers=int(n))

    def _interp(self, n: int) -> float:
        if n <= 0:
            return 0.0
        return self._interp_pts(self.flush_points, n)

    @staticmethod
    def _interp_pts(pts: Tuple[Tuple[int, float], ...], n: int) -> float:
        if len(pts) == 1:
            return pts[0][1]
        if n <= pts[0][0]:
            (n0, s0), (n1, s1) = pts[0], pts[1]
        elif n >= pts[-1][0]:
            (n0, s0), (n1, s1) = pts[-2], pts[-1]
        else:
            i = next(i for i in range(1, len(pts)) if n <= pts[i][0])
            (n0, s0), (n1, s1) = pts[i - 1], pts[i]
        return max(s0 + (s1 - s0) * (n - n0) / (n1 - n0), 0.0)


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    """Admission/scheduling policy of the streaming server."""
    max_batch: int = 8                 # flush when this many requests wait
    solve_budget_s: float = 1.0        # the paper's per-query cloud budget
    solve_reserve_s: float = 0.25      # initial per-QUERY solve reserve (EWMA
                                       # seed; deadlines scale it by the
                                       # expected batch size)
    reserve_ewma: float = 0.3          # EWMA weight of the newest solve
    admit_mid_session: bool = True     # late arrivals join the running session
    isolate_tenant_pools: bool = True  # tenant-scoped candidate-pool entries
    elastic: Optional[ElasticPolicy] = None  # None → static capacity
    clock: Optional[ServiceTimeModel] = None  # None → measured wall time


@dataclasses.dataclass
class ServedQuery:
    """One request's lifecycle through the server (simulated-clock times).

    ``status`` is the request's admission outcome:

    * ``"served"``   — full-quality solve, finished normally;
    * ``"degraded"`` — budget was unmeetable at admission and the tenant's
      SLO class is ``degrade``: solved via the cheap compile path
      (template-cache banks / Spark defaults, no fresh Algorithm 1);
    * ``"shed"``     — budget was unmeetable and the tenant's SLO class is
      ``strict``: rejected without solving (``ct``/``result`` stay None;
      ``finished_s`` records the rejection time);
    * ``"rate_limited"`` — rejected at the door by the tenant's token
      bucket: never enqueued, never composed, never solved
      (``finished_s`` is the arrival time).

    Latency reports must aggregate over finished (non-rejected) queries
    only — a shed query's ``compiled_s`` is NaN by construction.
    """
    rid: int
    request: StreamRequest
    arrival_s: float
    tenant: str = "default"
    status: str = "served"      # served | degraded | shed | rate_limited
    admitted_s: float = math.nan       # micro-batch flush began
    compiled_s: float = math.nan       # compile-time θ ready
    finished_s: float = math.nan       # final plan realized (or shed time)
    joined_running: bool = False       # admitted into an already-live session
    ct: Optional[CompileTimeResult] = None
    result: Optional[AQEResult] = None
    worker: Optional[int] = None       # fleet replica index that served it
                                       # (None outside a fleet)

    @property
    def solve_latency_s(self) -> float:
        """Arrival-to-compile-time-θ latency (the paper's solve budget is
        stated against this span: it includes the waiting-room time)."""
        return self.compiled_s - self.arrival_s

    @property
    def plan_latency_s(self) -> float:
        """Arrival-to-final-plan latency (through runtime re-tuning)."""
        return self.finished_s - self.arrival_s


@dataclasses.dataclass
class ServerStats:
    n_queries: int = 0
    n_finished: int = 0                # solved to completion (non-rejected)
    n_micro_batches: int = 0
    n_joined_running: int = 0          # admissions into a live session
    n_shed: int = 0                    # strict-SLO rejections
    n_degraded: int = 0                # degrade-SLO cheap-path admissions
    n_rate_limited: int = 0            # token-bucket door rejections
    rounds: int = 0                    # fusion rounds over the run
    makespan_s: float = 0.0            # last *served* finish − first arrival
                                       # (sim; rejections don't extend it)
    wall_time_s: float = 0.0           # real time spent in serve()
    tenant_slots: Dict[str, int] = dataclasses.field(default_factory=dict)
    # Per-flush (charged clock window, batch size): the exact amounts the
    # simulated clock advanced by and note_solve folded into the reserve
    # EWMAs — the reserve regression test replays these.
    flush_windows: List[Tuple[float, int]] = dataclasses.field(
        default_factory=list)
    # Per-flush (tune_batch wall time, batch size): the compile-time solve
    # slice of each flush window, excluding AQE admission — what the
    # jitted-solve benchmarks report p99 solve latency from.
    tune_windows: List[Tuple[float, int]] = dataclasses.field(
        default_factory=list)
    # Per-flush batch cap in effect at compose time (capacity events +
    # elastic scaling visible per flush; constant without either).
    flush_caps: List[int] = dataclasses.field(default_factory=list)

    @property
    def qps(self) -> float:
        """Served throughput: *finished* queries over the makespan — a shed
        request is rejected, not served, and must not inflate qps."""
        return self.n_finished / self.makespan_s if self.makespan_s else 0.0


class OptimizerServer:
    """Unified streaming server over both optimizer halves.

    One instance is a long-lived process: :meth:`serve` can be called on
    successive streams and every cache — and the tenant scheduler's
    fairness/reserve state — keeps amortizing.
    """

    def __init__(
        self,
        *,
        config: ServerConfig = ServerConfig(),
        weights: Optional[Weights] = None,
        cfg: Optional[HMOOCConfig] = None,
        model: Optional[PerfModel] = None,
        tuning: Optional[TuningService] = None,
        session: Optional[RuntimeSession] = None,
        tenants: Sequence[TenantSpec] = (),
    ):
        """``weights`` parameterizes the default-built session and is the
        fallback preference for tenants that configure none; ``cfg`` and
        ``model`` parameterize the default-built *compile-time* service
        (``model`` is the §5.1 subQ objective model; the default session
        stays on the oracle runtime backend).  For model-backed runtime
        re-scoring pass a prebuilt ``session`` with
        ``model_subq``/``model_qs`` set; prebuilt ``tuning``/``session``
        objects also share caches across servers.  ``tenants`` registers
        per-tenant admission policy (weights, share, priority, budget);
        tenant ids not listed get default policy on first sight.  Mixing a
        prebuilt object with the knobs it subsumes is rejected rather than
        silently resolved."""
        if tuning is not None and (cfg is not None or model is not None):
            raise ValueError(
                "pass cfg/model or a prebuilt tuning service, not both")
        if session is not None and weights is not None \
                and tuple(weights) != tuple(session.weights):
            raise ValueError(
                f"weights={tuple(weights)} conflicts with the prebuilt "
                f"session's weights={tuple(session.weights)}")
        self.config = config
        self.tuning = tuning if tuning is not None else TuningService(
            model=model, cfg=cfg if cfg is not None else HMOOCConfig())
        self.session = session if session is not None else RuntimeSession(
            weights=weights if weights is not None else (0.9, 0.1))
        self.weights = self.session.weights
        self.scheduler = TenantScheduler(
            tenants, budget_s=config.solve_budget_s,
            reserve_q_s=config.solve_reserve_s,
            reserve_ewma=config.reserve_ewma)
        # Long-lived like the scheduler: the queue-delay forecast keeps
        # amortizing across serve() epochs.
        self.elastic = (ElasticController(config.elastic)
                        if config.elastic is not None else None)
        self.last_run = ServerStats()

    # -- per-tenant policy ---------------------------------------------------
    def tenant_weights(self, tenant: str) -> Weights:
        w = self.scheduler.state(tenant).weights
        return tuple(w) if w is not None else tuple(self.weights)

    # -- main loop -----------------------------------------------------------
    def serve(self, requests: Sequence[StreamRequest], *,
              capacity_events: Sequence[Tuple[float, int]] = ()
              ) -> List[ServedQuery]:
        """Serve a timed stream to completion; results in request order.

        Each returned :class:`ServedQuery` carries the compile-time result,
        the realized :class:`AQEResult`, and the simulated-clock lifecycle
        times the latency metrics derive from.

        ``capacity_events`` is an optional ``(at_s, max_batch)`` timeline
        (e.g. :attr:`~repro.queryengine.scenarios.Scenario.capacity_events`)
        changing the server's *base* batch cap on the simulated clock —
        modelling executors joining/leaving the deployment.  With
        ``config.elastic`` set, an :class:`ElasticController` additionally
        scales the base cap from its queue-delay forecast and arms
        preemptive degradation of ``degrade``-class heads.

        A request whose ``StreamRequest.weights`` is set is solved under
        exactly those weights (scenario streams stamp mid-stream
        preference shifts per request at build time); otherwise the
        tenant's registered weights apply.
        """
        wall0 = time.perf_counter()
        cfgv = self.config
        sched = self.scheduler
        if self.session.n_active:
            raise RuntimeError(
                f"serve() requires an idle session; {self.session.n_active} "
                "entries are already active (admitted outside this server)")
        if sched.total_waiting():
            raise RuntimeError(
                "serve() requires an empty admission queue; "
                f"{sched.total_waiting()} requests are already waiting")
        served: Dict[int, ServedQuery] = {
            r.rid: ServedQuery(rid=r.rid, request=r, arrival_s=r.arrival_s,
                               tenant=r.tenant)
            for r in requests}
        if len(served) != len(requests):
            raise ValueError(
                f"duplicate rids in request stream: {len(requests)} requests "
                f"but {len(served)} distinct rids")
        incoming = sorted(served.values(),
                          key=lambda s: (s.arrival_s, s.rid))
        pos = 0                                # next unadmitted arrival
        in_flight: Dict[int, ServedQuery] = {}  # rid -> admitted, unrealized
        t = incoming[0].arrival_s if incoming else 0.0
        first_arrival = t
        n_batches = 0
        n_joined_running = 0
        n_shed = 0
        n_degraded = 0
        n_rate_limited = 0
        flush_windows: List[Tuple[float, int]] = []
        tune_windows: List[Tuple[float, int]] = []
        flush_caps: List[int] = []
        flushes_since_round = 0
        rounds0 = self.session.rounds_total
        slots0 = {st.name: st.slots_granted for st in sched.states()}
        cap_events = sorted(((float(at), int(mb))
                             for at, mb in capacity_events),
                            key=lambda e: e[0])
        ev_pos = 0
        base_cap = cfgv.max_batch

        def apply_capacity(now: float) -> None:
            nonlocal ev_pos, base_cap
            while ev_pos < len(cap_events) and cap_events[ev_pos][0] <= now:
                base_cap = cap_events[ev_pos][1]
                ev_pos += 1

        def cur_cap() -> int:
            return (self.elastic.batch_cap(base_cap) if self.elastic
                    else base_cap)

        def admit_arrived(now: float) -> None:
            nonlocal pos, n_rate_limited
            while pos < len(incoming) and incoming[pos].arrival_s <= now:
                s = incoming[pos]
                if sched.admit_arrival(s.tenant, s, s.arrival_s):
                    pos += 1
                    continue
                # Door rejection: the token bucket (clocked by arrival
                # times) said no — a first-class outcome, never solved.
                s.status = "rate_limited"
                s.finished_s = s.arrival_s
                n_rate_limited += 1
                pos += 1

        def flush_due(now: float) -> bool:
            if not sched.total_waiting():
                return False
            if self.session.n_active:
                # A session is live: join it eagerly between fusion rounds
                # (the optimizer is busy either way), unless running
                # batch-only.  At most one flush per round, so sustained
                # arrivals can never starve in-flight queries of the rounds
                # they need to finish.
                return cfgv.admit_mid_session and flushes_since_round < 1
            if sched.total_waiting() >= cur_cap():
                return True
            if pos >= len(incoming):
                # End of stream: nothing else will arrive, waiting longer
                # only adds latency.
                return True
            return sched.deadline_due(now, cur_cap())

        def finish(cohort, results, now: float) -> None:
            for e, res in zip(cohort, results):
                s = served[e.tag]
                s.result = res
                s.finished_s = now
                in_flight.pop(s.rid, None)

        admit_arrived(t)
        apply_capacity(t)
        while pos < len(incoming) or sched.total_waiting() or in_flight:
            apply_capacity(t)
            if flush_due(t):
                cap = cur_cap()
                # Overload triage first: strict-SLO requests whose budget is
                # already unmeetable are rejected here — first-class
                # outcomes, never solved, never poisoning latency stats.
                for _, s in sched.shed_unmeetable(t, cap):
                    s.status = "shed"
                    s.finished_s = t
                    n_shed += 1
                lead = (self.elastic.degrade_lead_s(
                            cfgv.solve_budget_s, sched.default_reserve_q_s,
                            base_cap)
                        if self.elastic else 0.0)
                admits = sched.compose(t, cap, lead)
                if not admits:
                    continue           # everything waiting was shed
                batch = [a.item for a in admits]
                n_batches += 1
                flushes_since_round += 1
                flush_caps.append(cap)
                if self.elastic:
                    # Observed queue delay of this flush (mean wait at
                    # compose time) feeds the forecast for the next one.
                    self.elastic.note_flush(
                        sum(t - s.arrival_s for s in batch) / len(batch))
                for a, s in zip(admits, batch):
                    s.admitted_s = t
                    if a.degrade:
                        s.status = "degraded"
                        n_degraded += 1
                batch_w = [tuple(s.request.weights)
                           if s.request.weights is not None
                           else self.tenant_weights(s.tenant)
                           for s in batch]
                t0 = time.perf_counter()
                cts = self.tuning.tune_batch(
                    [s.request.query for s in batch], batch_w,
                    tenants=[s.tenant for s in batch],
                    degraded=[a.degrade for a in admits])
                tune_windows.append((self.tuning.last_batch.wall_time,
                                     len(batch)))
                joined_running = self.session.n_active > 0
                for s, ct, w in zip(batch, cts, batch_w):
                    s.ct = ct
                    s.joined_running = joined_running
                    if joined_running:
                        n_joined_running += 1
                    self.session.admit(
                        s.request.query, ct, tag=s.rid, weights=w,
                        pool_scope=(s.tenant if cfgv.isolate_tenant_pools
                                    else None))
                    in_flight[s.rid] = s
                # One window feeds both the clock charge and the reserve
                # EWMA: the whole flush — the batched solve plus each
                # query's initial AQE planning step inside admit().
                # (Feeding note_solve only the tune_batch slice made the
                # reserve undershoot the true per-query admission cost.)
                # Under a ServiceTimeModel the charged window is the
                # model's, so the admission timeline is deterministic.
                # Cheap members (cache hits + degraded paths, per the
                # tuning service's own accounting of the flush we just
                # ran) are priced at cheap_s instead of the solve curve.
                n_cheap = len(batch) - self.tuning.last_batch.n_solved
                window = (cfgv.clock.flush_s(len(batch), n_cheap)
                          if cfgv.clock is not None
                          else time.perf_counter() - t0)
                sched.note_solve(window, len(batch),
                                 (s.tenant for s in batch))
                flush_windows.append((window, len(batch)))
                t += window
                for s in batch:
                    s.compiled_s = t
                admit_arrived(t)
                continue
            if self.session.has_pending() or self.session.n_active:
                flushes_since_round = 0
                t0 = time.perf_counter()
                self.session.step_round()
                done = self.session.retire_ready()
                results = self.session.realize(done) if done else []
                t += (cfgv.clock.round_cost_s() if cfgv.clock is not None
                      else time.perf_counter() - t0)
                if done:
                    finish(done, results, t)
                admit_arrived(t)
                continue
            # Idle: jump the simulated clock to the next event (arrival,
            # flush deadline, or capacity change — a cap drop can make the
            # waiting pool flush-ready with no new arrival).
            nxt = min(incoming[pos].arrival_s if pos < len(incoming)
                      else math.inf,
                      sched.next_deadline(cur_cap()),
                      cap_events[ev_pos][0] if ev_pos < len(cap_events)
                      else math.inf)
            if not math.isfinite(nxt):
                break
            t = max(t, nxt)
            admit_arrived(t)
            apply_capacity(t)

        out = [served[r.rid] for r in requests]
        # Makespan spans *served* work only: a shed/rate-limited request's
        # finished_s is a rejection timestamp, not service — counting it
        # would stretch the makespan (and deflate qps) on tail-shed streams
        # where the last event is a rejection, not a finish.
        finished = [s.finished_s for s in out
                    if s.status not in REJECTED_STATUSES
                    and math.isfinite(s.finished_s)]
        self.last_run = ServerStats(
            n_queries=len(out),
            n_finished=sum(1 for s in out
                           if s.status not in REJECTED_STATUSES
                           and math.isfinite(s.finished_s)),
            n_micro_batches=n_batches,
            n_joined_running=n_joined_running,
            n_shed=n_shed, n_degraded=n_degraded,
            n_rate_limited=n_rate_limited,
            rounds=self.session.rounds_total - rounds0,
            makespan_s=(max(finished) - first_arrival) if finished else 0.0,
            wall_time_s=time.perf_counter() - wall0,
            tenant_slots={st.name: st.slots_granted - slots0.get(st.name, 0)
                          for st in sched.states()
                          if st.slots_granted - slots0.get(st.name, 0)},
            flush_windows=flush_windows,
            tune_windows=tune_windows,
            flush_caps=flush_caps)
        return out

    # -- reporting -----------------------------------------------------------
    def _goodput(self, sub: Sequence[ServedQuery]) -> float:
        """Fraction of requests finishing inside their tenant's budget.

        Rejected requests (shed or rate-limited) count against goodput —
        they never produced a plan; the denominator is *all* requests, so
        goodput + rejection rate + late rate partition the stream.
        """
        if not sub:
            return math.nan
        ok = sum(1 for s in sub
                 if s.status not in REJECTED_STATUSES
                 and math.isfinite(s.finished_s)
                 and s.plan_latency_s
                 <= self.scheduler.state(s.tenant).budget_s)
        return ok / len(sub)

    @staticmethod
    def _counts(sub: Sequence[ServedQuery]) -> dict:
        """Status counts + rates over one sample of served queries."""
        n_shed = sum(1 for s in sub if s.status == "shed")
        n_deg = sum(1 for s in sub if s.status == "degraded")
        n_rl = sum(1 for s in sub if s.status == "rate_limited")
        n = len(sub)
        return {
            "n_shed": n_shed,
            "n_degraded": n_deg,
            "n_rate_limited": n_rl,
            "shed_rate": n_shed / n if n else math.nan,
            "degrade_rate": n_deg / n if n else math.nan,
            "rate_limited_rate": n_rl / n if n else math.nan,
        }

    def latency_report(self, served: Sequence[ServedQuery], *,
                       window_s: Optional[float] = None) -> dict:
        """p50/p99/max of the two latency metrics plus throughput.

        Latency percentiles aggregate over *finished* queries only
        (status not shed/rate-limited): one rejected request must not
        NaN-poison the whole report.  Shed/degrade/rate-limited are
        reported as first-class counts and rates alongside, plus goodput
        — the fraction of all requests that finished within their
        tenant's budget.

        Every count and rate derives from the ``served`` argument (the
        sample under report), never from run-level state, so a report
        over a slice — one tenant, one phase of a nonstationary stream —
        is internally consistent.  (Run-level fields — micro-batches,
        rounds, makespan, qps — are explicitly about the *last run* and
        keep coming from :attr:`last_run`.)

        With multi-tenant traffic the report adds a per-tenant breakdown
        (including each tenant's SLO class and shed/degrade counts) and
        the Jain fairness index over per-tenant p99 plan latency of
        finished queries (1.0 = perfectly even tails across tenants;
        tenants with nothing finished are excluded).

        ``window_s`` adds a ``windows`` section: the stream is bucketed
        by *arrival* time into consecutive windows of that width and
        p50/p99, goodput, and shed/degrade/rate-limited rates are
        reported per window — stream-wide aggregates mask phase behavior
        under nonstationary load (a flash crowd's recovery is invisible
        in one pooled p99).
        """
        def _fin(sub):
            return [s for s in sub if s.status not in REJECTED_STATUSES
                    and math.isfinite(s.finished_s)]

        fin = _fin(served)
        plan = np.array([s.plan_latency_s for s in fin], np.float64)
        solve = np.array([s.solve_latency_s for s in fin], np.float64)
        st = self.last_run
        rep = {
            "n_queries": len(served),
            "n_finished": len(fin),
            **self._counts(served),
            "goodput": self._goodput(served),
            "n_micro_batches": st.n_micro_batches,
            "n_joined_running": st.n_joined_running,
            "rounds": st.rounds,
            "makespan_s": st.makespan_s,
            "qps": st.qps,
            "solve_latency_s": _pcts(solve),
            "plan_latency_s": _pcts(plan),
        }
        names = sorted({s.tenant for s in served})
        if len(names) > 1 or (names and names != ["default"]):
            per = {}
            for name in names:
                sub = [s for s in served if s.tenant == name]
                sub_fin = _fin(sub)
                ts = self.scheduler.state(name)
                per[name] = {
                    "n_queries": len(sub),
                    "n_finished": len(sub_fin),
                    "slo": ts.slo,
                    "budget_s": ts.budget_s,
                    **self._counts(sub),
                    "goodput": self._goodput(sub),
                    "batch_slots": st.tenant_slots.get(name, 0),
                    "solve_latency_s": _pcts(np.array(
                        [s.solve_latency_s for s in sub_fin], np.float64)),
                    "plan_latency_s": _pcts(np.array(
                        [s.plan_latency_s for s in sub_fin], np.float64)),
                }
            rep["tenants"] = per
            rep["fairness_jain"] = jain_index(
                [per[n]["plan_latency_s"]["p99"] for n in names])
        if window_s is not None and served:
            if window_s <= 0:
                raise ValueError(f"window_s must be positive, got "
                                 f"{window_s}")
            t0 = min(s.arrival_s for s in served)
            t1 = max(s.arrival_s for s in served)
            n_w = int(math.floor((t1 - t0) / window_s)) + 1
            windows = []
            for i in range(n_w):
                lo = t0 + i * window_s
                hi = lo + window_s
                sub = [s for s in served if lo <= s.arrival_s < hi]
                sub_fin = _fin(sub)
                windows.append({
                    "t0_s": lo,
                    "t1_s": hi,
                    "n_arrived": len(sub),
                    "n_finished": len(sub_fin),
                    **self._counts(sub),
                    "goodput": self._goodput(sub),
                    "plan_latency_s": _pcts(np.array(
                        [s.plan_latency_s for s in sub_fin], np.float64)),
                })
            rep["windows"] = windows
        return rep


def jain_index(x: Sequence[float]) -> float:
    """Jain fairness index (Σx)² / (n·Σx²): 1.0 = perfectly even.

    Non-finite entries are dropped (an all-shed tenant's p99 is NaN — it
    must not wipe out the whole fairness report); NaN only when nothing
    finite (or nonzero) remains.
    """
    a = np.asarray(list(x), np.float64)
    a = a[np.isfinite(a)]
    if a.size == 0 or (a == 0).all():
        return math.nan
    return float(a.sum() ** 2 / (a.size * (a * a).sum()))


def _pcts(x: np.ndarray) -> dict:
    if x.size == 0:
        return {"p50": math.nan, "p99": math.nan, "max": math.nan,
                "mean": math.nan}
    return {"p50": float(np.percentile(x, 50)),
            "p99": float(np.percentile(x, 99)),
            "max": float(x.max()),
            "mean": float(x.mean())}
