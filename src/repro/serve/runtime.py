"""Batched runtime re-optimization service (paper §5.2 at serving scale).

PR 1 scaled the compile-time half of the paper's hybrid architecture
(batched HMOOC solves); this module scales the runtime half: the
AQE-triggered θp/θs re-tuning of *many concurrent queries* served through
one shared, vectorized optimizer backend.

Each query advances through its
:func:`~repro.queryengine.aqe.aqe_request_stream` — the generator form of
the AQE planning loop, which yields L̄QP/QS requests instead of invoking
synchronous callbacks.  Every round the session collects the outstanding
request of each still-active query and fuses them:

* same-kind **oracle** requests stack their candidate rows into ONE
  :func:`~repro.queryengine.simulator.simulate_stage_rows` call;
* same-model requests stack into ONE :meth:`PerfModel.predict` call
  (cached GTN embeddings, row-bucketed for the jit cache);
* every pick resolves through
  :func:`~repro.core.tuning.runtime.weighted_pick_batch`, which routes
  dominance filtering and weighted-sum scoring to the Pallas
  ``pareto_filter`` / ``ws_reduce`` kernels above the same env-gated
  thresholds as the compile-time solver (float64 numpy fallback on CPU).

After planning, execution realization fuses the same way: one stage-core
call per stage *kind* across all queries, folded back per query with
:func:`~repro.queryengine.simulator.assemble_query_sim`.

Because the fused paths run the identical code the per-query loop runs
(single-request batches), ``run_batch`` output is bit-identical to calling
:func:`~repro.queryengine.aqe.run_with_aqe` with
:func:`~repro.core.tuning.runtime.make_runtime_optimizers` callbacks per
query on the oracle backend under the default (numpy/float64) kernel
routing; forcing the f32 Pallas kernels via the env thresholds carries the
usual f32 tie caveat.

The session is an *open set*: entries join (:meth:`RuntimeSession.admit`)
and retire (:meth:`RuntimeSession.retire_ready`) independently, and
:meth:`RuntimeSession.step_round` fuses whatever is outstanding *right
now* — so a streaming server can admit late arrivals between fusion rounds
of a running session.  Every per-query decision depends only on that
query's own candidate rows (scoring is row-independent and each weighted
pick normalizes within its own set), so batch composition never changes a
query's outcome: mid-session admission keeps the bit-identity guarantee.
``run_batch`` is the closed-set convenience wrapper over the same
lifecycle.

Seeds flow from the compile-time layer: a
:class:`~repro.serve.TuningService` batch returns per-query
:class:`CompileTimeResult` objects whose per-subQ θp/θs become the runtime
candidate seeds and whose aggregated submission copies
(``core/tuning/aggregation.py``) initialize the live θp/θs.

Multi-tenant serving: every entry may carry its own preference vector
(``admit(..., weights=...)``) — fused picks resolve per-entry weights
through :func:`weighted_pick_batch`'s per-set path — and model-backed
re-scoring consumes the paper's §4.3 contention features γ
(``gamma_mode``: structural per-query siblings by default, live
open-entry-set pressure opt-in, or zeroed).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.models.features import contention_gamma
from ..core.models.perf_model import PerfModel
from ..core.tuning.compile_time import CompileTimeResult
from ..core.tuning.runtime import (RuntimeOptimizerBackend, fusion_key,
                                   score_requests, stage_pressure,
                                   structural_pressure, weighted_pick_batch)
from ..queryengine.aqe import (AQEPlanState, AQEResult, aqe_request_stream)
from ..queryengine.plan import Query
from ..queryengine.simulator import (CostModel, DEFAULT_COST, SubQSim,
                                     assemble_query_sim, decide_join,
                                     join_decision_stats,
                                     simulate_stage_rows, stage_stats_batch)
from .cache import CandidatePoolCache

__all__ = ["RuntimeSession", "RuntimeSessionStats", "CandidatePoolCache"]


@dataclasses.dataclass
class RuntimeSessionStats:
    n_queries: int = 0
    rounds: int = 0                  # lock-step fusion rounds
    fused_calls: int = 0             # backend calls actually issued
    requests_sent: int = 0           # optimizer requests serviced
    requests_total: int = 0          # unpruned baseline (~2m per query)
    wall_time: float = 0.0

    @property
    def prune_rate(self) -> float:
        if self.requests_total == 0:
            return 0.0
        return 1.0 - self.requests_sent / self.requests_total

    @property
    def requests_per_sec(self) -> float:
        return self.requests_sent / self.wall_time if self.wall_time else 0.0


@dataclasses.dataclass
class _Entry:
    query: Query
    ct: CompileTimeResult
    backend: RuntimeOptimizerBackend
    gen: object                              # aqe_request_stream generator
    pending: object = None                   # outstanding LQP/QS request
    state: Optional[AQEPlanState] = None
    final_join: Optional[np.ndarray] = None  # reported (m,) algorithms
    realized: Optional[np.ndarray] = None    # algorithms realized in the sim
    rng: Optional[np.random.Generator] = None
    tag: object = None                       # caller handle (e.g. server rid)
    weights: Optional[tuple] = None          # per-entry (tenant) preference
    gamma_raw: Optional[np.ndarray] = None   # (m, 3) intra-query γ sums
    gamma_depths: Optional[np.ndarray] = None  # (m,) stage depths

    @property
    def done(self) -> bool:
        """Planning finished (generator exhausted, realization pending)."""
        return self.pending is None and self.state is not None


def _slice_subqsim(sim: SubQSim, r: int) -> SubQSim:
    return SubQSim(**{f.name: getattr(sim, f.name)[r:r + 1]
                      for f in dataclasses.fields(SubQSim)})


class RuntimeSession:
    """Runtime (§5.2) re-optimization server for batches of queries."""

    def __init__(
        self,
        *,
        model_subq: Optional[PerfModel] = None,
        model_qs: Optional[PerfModel] = None,
        weights: Tuple[float, float] = (0.9, 0.1),
        n_candidates: int = 64,
        cost: CostModel = DEFAULT_COST,
        seed: int = 0,
        prune: bool = True,
        pool_cache: Optional[CandidatePoolCache] = None,
        gamma_mode: str = "structural",
    ):
        """``gamma_mode`` controls the §4.3 contention features the model
        backends consume (the oracle backend ignores γ entirely):

        * ``"structural"`` (default) — per-stage γ from the query's own
          same-depth sibling stages (:func:`structural_gamma`): nonzero,
          matches the trace-collection definition, and depends only on the
          query — so serving output stays bit-identical to the offline
          pipeline however the stream is sliced.
        * ``"live"`` — structural γ *plus* cross-query pressure from the
          open entry set at each fusion round (co-running queries'
          outstanding stages).  Adaptive to real concurrency, but decisions
          then depend on batch composition: the bit-identity guarantee is
          deliberately traded away.
        * ``"off"`` — γ zeroed (the pre-PR-4 behavior).
        """
        if gamma_mode not in ("off", "structural", "live"):
            raise ValueError(f"unknown gamma_mode: {gamma_mode!r}")
        self.model_subq = model_subq
        self.model_qs = model_qs
        self.weights = weights
        self.n_candidates = n_candidates
        self.cost = cost
        self.seed = seed
        self.prune = prune
        self.gamma_mode = gamma_mode
        self.pool_cache = pool_cache if pool_cache is not None \
            else CandidatePoolCache()
        self.last_batch = RuntimeSessionStats()
        # Open entry set: entries join via admit() and leave via
        # retire_ready(); step_round() fuses whatever is outstanding now.
        self._active: List[_Entry] = []
        self.rounds_total = 0        # fusion rounds over the session's life
        self.fused_total = 0         # fused backend calls, cumulative
        self.admitted_total = 0

    # -- open-set lifecycle --------------------------------------------------
    def admit(
        self,
        query: Query,
        ct: CompileTimeResult,
        *,
        rng: Optional[np.random.Generator] = None,
        tag: object = None,
        weights: Optional[Tuple[float, float]] = None,
        pool_scope: object = None,
    ) -> _Entry:
        """Join ``query`` to the running session (between fusion rounds).

        ``ct`` seeds the entry: θc fixes its cluster, per-subQ θp/θs become
        runtime candidates, and the aggregated submission copies initialize
        the live θp/θs.  Admission order only affects row order inside fused
        calls — never any query's decisions — so joining a running session
        yields the same plan as joining a fresh one.

        ``weights`` is the entry's own preference vector (a tenant's MOO
        weights); ``None`` inherits the session default, reproducing the
        single-stream behavior bit-identically.  ``pool_scope`` scopes the
        candidate-pool cache entry (tenant isolation; the draw itself is
        scope-independent).
        """
        w = tuple(weights) if weights is not None else tuple(self.weights)
        has_model = self.model_subq is not None or self.model_qs is not None
        gamma = None                                  # backend auto/none
        if self.gamma_mode == "off":
            gamma = np.zeros((query.n_subqs, 4), np.float64)
        backend = RuntimeOptimizerBackend(
            query, ct.theta_c, seed_theta_p=ct.theta_p_sub,
            seed_theta_s=ct.theta_s_sub, model_subq=self.model_subq,
            model_qs=self.model_qs, weights=w,
            cost=self.cost,
            pools=self.pool_cache.get(self.seed, self.n_candidates,
                                      scope=pool_scope),
            gamma_by_stage=gamma)
        gen = aqe_request_stream(query, ct.theta_c, ct.theta_p0, ct.theta_s0,
                                 prune=self.prune)
        e = _Entry(query=query, ct=ct, backend=backend, gen=gen, rng=rng,
                   tag=tag, weights=w)
        if self.gamma_mode == "live" and has_model:
            e.gamma_raw, e.gamma_depths = structural_pressure(query)
        self._step(e, None)
        self._active.append(e)
        self.admitted_total += 1
        return e

    @property
    def n_active(self) -> int:
        return len(self._active)

    def has_pending(self) -> bool:
        """True when some active entry has an outstanding optimizer request."""
        return any(e.pending is not None for e in self._active)

    def step_round(self) -> int:
        """One fusion round over every outstanding request; 0 when idle.

        Collects each waiting entry's request, fuses them into batched
        backend calls, resolves the weighted picks, and advances each
        generator.  Returns the number of requests serviced.
        """
        waiting = [e for e in self._active if e.pending is not None]
        if not waiting:
            return 0
        self.rounds_total += 1
        reqs, cands = [], []
        for e in waiting:
            sr, cand = e.backend.request_for(e.pending)
            if e.gamma_raw is not None:
                sr.gamma = self._live_gamma(e, sr.subq.sq_id)
            reqs.append(sr)
            cands.append(cand)
        self.fused_total += len({fusion_key(sr) for sr in reqs}) + 1  # + pick
        Fs = score_requests(reqs)
        picks = weighted_pick_batch(
            Fs, np.asarray([e.weights for e in waiting], np.float64))
        for e, cand, j in zip(waiting, cands, picks):
            self._step(e, cand[j])
        return len(waiting)

    def _live_gamma(self, e: _Entry, sq_id: int) -> np.ndarray:
        """γ for one request under ``gamma_mode="live"``: the entry's
        intra-query sibling sums plus the pressure of every *other* active
        entry's outstanding stage (the open entry set, right now)."""
        cross_t = cross_w = 0.0
        n_co = 0
        for o in self._active:
            if o is e or o.pending is None:
                continue
            t, w = stage_pressure(o.pending.subq)
            cross_t += t
            cross_w += w
            n_co += 1
        raw = e.gamma_raw[sq_id]
        return contention_gamma(raw[0] + cross_t, raw[1] + cross_w,
                                raw[2] + n_co, e.gamma_depths[sq_id])

    def retire_ready(self) -> List[_Entry]:
        """Remove and return entries whose planning pass has finished.

        Returned entries are ready for :meth:`realize`; admission order is
        preserved.
        """
        done = [e for e in self._active if e.done]
        if done:
            self._active = [e for e in self._active if not e.done]
        return done

    def realize(self, entries: Sequence[_Entry]) -> List[AQEResult]:
        """Fused execution realization for a cohort of retired entries.

        Row-independent throughout, so realizing per-retirement cohorts
        (streaming) and realizing one big batch (offline) produce identical
        per-query results.
        """
        return self._realize_batch(list(entries))

    # -- closed-set convenience ---------------------------------------------
    def run_batch(
        self,
        queries: Sequence[Query],
        compile_results: Sequence[CompileTimeResult],
        *,
        rngs: Optional[Sequence[Optional[np.random.Generator]]] = None,
    ) -> List[AQEResult]:
        """Run AQE with runtime re-tuning for every query; aligned results.

        Admits the whole batch, drains the fusion loop, and realizes —
        the fixed-batch wrapper over the open-set lifecycle.
        """
        if len(queries) != len(compile_results):
            raise ValueError(
                f"got {len(compile_results)} compile results for "
                f"{len(queries)} queries")
        if self._active:
            raise RuntimeError(
                f"run_batch on a session with {len(self._active)} active "
                "entries; use admit()/step_round() for streaming admission")
        t0 = time.perf_counter()
        rounds0, fused0 = self.rounds_total, self.fused_total
        entries = [self.admit(q, ct,
                              rng=rngs[i] if rngs is not None else None)
                   for i, (q, ct) in enumerate(zip(queries, compile_results))]
        while self.step_round():
            pass
        self.retire_ready()
        results = self._realize_batch(entries)
        self.last_batch = RuntimeSessionStats(
            n_queries=len(entries), rounds=self.rounds_total - rounds0,
            fused_calls=self.fused_total - fused0,
            requests_sent=sum(r.requests_sent for r in results),
            requests_total=sum(r.requests_total for r in results),
            wall_time=time.perf_counter() - t0)
        return results

    def tune_and_run(self, queries: Sequence[Query], tuning_service
                     ) -> Tuple[List[CompileTimeResult], List[AQEResult]]:
        """Compile-time batch solve (seeds) + runtime batch execution."""
        cts = tuning_service.tune_batch(queries, self.weights)
        return cts, self.run_batch(queries, cts)

    # -- internals -----------------------------------------------------------
    @staticmethod
    def _step(e: _Entry, response) -> None:
        try:
            e.pending = e.gen.send(response)
        except StopIteration as stop:
            e.pending = None
            e.state = stop.value

    def _realize_batch(self, entries: List[_Entry]) -> List[AQEResult]:
        """Fused execution realization: one stage-core call per stage kind."""
        # Join planning first, fused: every (query, join) pair resolves its
        # true-stats and estimates-based decisions in two decide_join calls
        # (the per-query path runs plan_joins twice per query instead).
        jm = [(i, sq) for i, e in enumerate(entries)
              for sq in e.query.subqs if sq.kind == "join"]
        for e in entries:
            e.final_join = e.state.planned.copy()
            e.realized = e.state.planned.copy()
        if jm:
            subqs = [sq for _, sq in jm]
            tp = np.stack([entries[i].state.theta_p_eff[sq.sq_id]
                           for i, sq in jm])
            parts = np.maximum(tp[:, 4], 1.0)
            true_choice = decide_join(
                *join_decision_stats(subqs, from_estimates=False), tp, parts)
            # simulate_query re-upgrades the given plan against the
            # estimates-based choice under the effective θp; replicate so
            # the realized algorithms match the per-query path exactly.
            est_choice = decide_join(
                *join_decision_stats(subqs, from_estimates=True), tp, parts)
            for r, (i, sq) in enumerate(jm):
                e = entries[i]
                fj = max(e.state.planned[sq.sq_id], float(true_choice[r]))
                e.final_join[sq.sq_id] = fj
                e.realized[sq.sq_id] = max(fj, float(est_choice[r]))

        groups: Dict[str, List[Tuple[int, int]]] = {}
        for idx, e in enumerate(entries):
            for sq in e.query.subqs:
                groups.setdefault(sq.kind, []).append((idx, sq.sq_id))

        sims: Dict[Tuple[int, int], SubQSim] = {}
        for kind, members in groups.items():
            stats = stage_stats_batch(
                [entries[i].query.subqs[s] for i, s in members])
            tc = np.stack([np.asarray(entries[i].ct.theta_c, np.float64)
                           for i, s in members])
            tp = np.stack([entries[i].state.theta_p_eff[s]
                           for i, s in members])
            ts = np.stack([entries[i].state.theta_s_eff[s]
                           for i, s in members])
            algo = None
            if kind == "join":
                algo = np.array([entries[i].realized[s] for i, s in members])
            sim = simulate_stage_rows(kind, stats, tc, tp, ts,
                                      cost=self.cost, aqe=True,
                                      join_algo=algo)
            for r, (i, s) in enumerate(members):
                sims[(i, s)] = _slice_subqsim(sim, r)

        results: List[AQEResult] = []
        for idx, e in enumerate(entries):
            st = e.state
            per = [sims[(idx, s)] for s in range(e.query.n_subqs)]
            qsim = assemble_query_sim(
                e.query, np.asarray(e.ct.theta_c, np.float64)[None, :], per,
                e.final_join[None, :], cost=self.cost, rng=e.rng)
            results.append(AQEResult(
                sim=qsim, theta_p_eff=st.theta_p_eff,
                theta_s_eff=st.theta_s_eff, final_join=e.final_join,
                lqp_requests_sent=st.lqp_requests_sent,
                qs_requests_sent=st.qs_requests_sent,
                requests_total=st.requests_total))
        return results
