"""Multi-tenant admission accounting for the streaming server.

The paper's cloud premise makes tuning *per user*: preference weights are a
user's cost/performance trade-off (UDAO), and the 1–2 s solve budget is a
per-request promise the server must keep for every tenant at once.
:class:`TenantScheduler` owns the waiting-room half of that promise for
:class:`~repro.serve.server.OptimizerServer`:

* **Per-tenant queues + deadlines.**  Each tenant's requests wait in their
  own FIFO; the tenant's flush deadline is its oldest request's
  ``arrival + budget − reserve`` where the reserve is a per-*query* EWMA of
  recent solve times scaled by the expected batch size.  (Per-query
  normalization is the PR-4 bugfix: the old whole-batch EWMA let one large
  batch inflate the reserve applied to subsequent small batches.)
* **Weighted-fair composition.**  A micro-batch is composed by
  deficit-round-robin over the tenant queues: every pass credits each
  waiting tenant ``share / max(shares in tier)`` slots and pops while the
  credit covers a whole slot, so long-run batch shares converge to the
  configured ratios without starving fractional shares — and composition
  always makes progress in O(1) passes per slot, however small a share.
* **Priority tiers that cannot starve.**  Higher-priority tenants compose
  first — but any tenant whose head request has passed its deadline is
  promoted ahead of *all* tiers (oldest first).  A lower tier therefore
  waits at most its budget while higher tiers burst: preemption bounds
  latency instead of unbounding it.  Overdue pops are charged against the
  tenant's DRR credit (floored at the standard empty-queue reset), so a
  bursty tenant served via promotion cannot *also* spend its banked
  credit on the next normal pass (the PR-5 double-dip fix).
* **Overload triage (SLO classes).**  A request is *unmeetable* when even
  an immediate flush would blow its budget:
  ``arrival + budget − reserve·E[n] < now``.  What happens then is the
  tenant's :class:`~repro.queryengine.workloads.TenantSpec` ``slo`` class:
  ``strict`` heads are shed (popped and rejected, never solved) by
  :meth:`TenantScheduler.shed_unmeetable`; ``degrade`` heads are composed
  with ``Admit.degrade=True`` so the server routes them through the cheap
  compile path; ``best_effort`` heads queue on as before.  Under sustained
  overload the server therefore *adapts* — strict tenants keep their
  latency promise by dropping excess load, degrade tenants trade plan
  quality for admission, best-effort tenants absorb the queueing — instead
  of silently blowing every tenant's budget.

The scheduler only orders and accounts — it never touches solver state —
so per-query *outputs* remain independent of composition (the golden
determinism invariant); fairness and overload policy shape latency (and
which requests are served at full quality) only.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque, Dict, Iterable, List, NamedTuple, Optional, Tuple

from ..queryengine.workloads import TenantSpec

__all__ = ["TenantScheduler", "TenantState", "Admit", "TokenBucket",
           "ElasticPolicy", "ElasticController"]


class Admit(NamedTuple):
    """One composed batch slot: ``(tenant, item, degrade)``.

    ``degrade`` is True when the item was unmeetable at pop time and its
    tenant's SLO class is ``"degrade"`` — the server must route it through
    the cheap compile path instead of a fresh Algorithm 1 solve.
    """
    tenant: str
    item: object
    degrade: bool = False


@dataclasses.dataclass
class TokenBucket:
    """Per-tenant rate limiter ahead of the waiting room.

    A bucket holds at most ``burst`` tokens and refills continuously at
    ``rate_qps``; each admitted arrival takes one token, and an arrival
    finding less than a whole token is rejected at the door (status
    ``"rate_limited"`` — never enqueued, never solved).  The bucket is
    clocked by *arrival* times, which are a pure function of the stream,
    so the admit/reject pattern is deterministic per seed regardless of
    how fast the server happens to be running.

    Invariants (property-tested in ``tests/test_admission.py``):

    * never admits more than ``burst`` arrivals at one instant;
    * over any span, admits at most ``burst + elapsed · rate_qps`` tokens'
      worth (token conservation);
    * after an idle gap of ``1 / rate_qps`` at least one token is always
      available (no starvation — churny traffic cannot wedge the bucket).
    """
    rate_qps: float
    burst: float
    tokens: float = math.nan         # NaN → starts full (= burst)
    clock_s: float = -math.inf       # last refill instant (monotone)

    def __post_init__(self):
        if self.rate_qps <= 0:
            raise ValueError(f"rate_qps must be positive, got "
                             f"{self.rate_qps}")
        if self.burst < 1.0:
            raise ValueError(f"burst must be >= 1, got {self.burst}")
        if math.isnan(self.tokens):
            self.tokens = self.burst

    def take(self, now: float) -> bool:
        """Refill to ``now`` and take one token; False = rate-limited.

        Out-of-order calls (``now`` before the bucket clock) refill
        nothing — time never runs backwards for the token supply.
        """
        if now > self.clock_s:
            if math.isfinite(self.clock_s):
                self.tokens = min(self.burst, self.tokens
                                  + (now - self.clock_s) * self.rate_qps)
            self.clock_s = now
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False


@dataclasses.dataclass(frozen=True)
class ElasticPolicy:
    """Elastic capacity policy: how the server autoscales per flush.

    The controller keeps an EWMA *forecast* of queue delay over flush
    windows and scales the base ``max_batch`` by the pressure ratio
    ``forecast / target_delay_s`` whenever the forecast exceeds the
    target (the scaling is clipped to ``[min_batch, max_batch]``, but a
    base cap already above the ceiling passes through unclamped) —
    bigger batches amortize the solve when the waiting room is falling
    behind.  The same
    forecast drives *preemptive degradation*: as forecast headroom
    against the solve budget shrinks below ``degrade_frac · budget``,
    degrade-class heads are routed to the cheap path with a positive
    lead time — before the budget actually blows — instead of at the
    deadline.
    """
    min_batch: int = 1
    max_batch: int = 32              # elastic ceiling on the batch cap
    target_delay_s: float = 0.5      # queue-delay forecast target
    ewma: float = 0.4                # EWMA weight of the newest window
    degrade_frac: float = 0.5        # preemptive-degrade headroom fraction

    def __post_init__(self):
        if not 1 <= self.min_batch <= self.max_batch:
            raise ValueError(f"need 1 <= min_batch <= max_batch, got "
                             f"{self.min_batch}, {self.max_batch}")
        if self.target_delay_s <= 0:
            raise ValueError(f"target_delay_s must be positive, got "
                             f"{self.target_delay_s}")
        if not 0.0 < self.ewma <= 1.0:
            raise ValueError(f"ewma must be in (0, 1], got {self.ewma}")
        if not 0.0 <= self.degrade_frac <= 1.0:
            raise ValueError(f"degrade_frac must be in [0, 1], got "
                             f"{self.degrade_frac}")


class ElasticController:
    """Queue-delay forecast + the three controls derived from it.

    Monotonicity contract (property-tested): with everything else fixed,
    a higher forecast never *lowers* :meth:`batch_cap`, never *raises*
    :meth:`headroom_s`, and never lowers :meth:`degrade_lead_s` — the
    controller always reacts to more pressure with at least as much
    capacity and at least as early degradation.
    """

    def __init__(self, policy: ElasticPolicy):
        self.policy = policy
        self.forecast_s = 0.0            # EWMA queue delay over flushes
        self.n_windows = 0

    def note_flush(self, queue_delay_s: float) -> None:
        """Fold one flush's observed queue delay (mean wait of the batch
        at compose time) into the forecast."""
        a = self.policy.ewma
        self.forecast_s = ((1 - a) * self.forecast_s
                           + a * max(queue_delay_s, 0.0))
        self.n_windows += 1

    def batch_cap(self, base_cap: int) -> int:
        """Elastic batch cap: base capacity scaled by forecast pressure.

        ``max_batch`` bounds the *scaling*, never the provisioned base:
        a capacity event that raises ``base_cap`` above the elastic
        ceiling is honored as-is — elasticity only ever adds capacity on
        top of what the deployment provides.
        """
        p = self.policy
        pressure = max(1.0, self.forecast_s / p.target_delay_s)
        cap = min(int(math.floor(base_cap * pressure)), p.max_batch)
        return max(p.min_batch, base_cap, cap)

    def flush_budget_s(self, reserve_q_s: float, base_cap: int) -> float:
        """Expected solve cost of one full elastic flush."""
        return reserve_q_s * self.batch_cap(base_cap)

    def headroom_s(self, budget_s: float, reserve_q_s: float,
                   base_cap: int) -> float:
        """Budget slack left after the forecast delay and a full flush.

        Monotone nonincreasing in the forecast: delay subtracts directly
        and a larger elastic cap only grows the flush cost.
        """
        return (budget_s - self.forecast_s
                - self.flush_budget_s(reserve_q_s, base_cap))

    def degrade_lead_s(self, budget_s: float, reserve_q_s: float,
                       base_cap: int) -> float:
        """How far *ahead of* the deadline degrade-class heads should be
        routed to the cheap path (0 = only at the deadline, the PR-5
        behavior).  Grows as headroom shrinks below
        ``degrade_frac · budget``; clipped to ``[0, budget]``."""
        head = self.headroom_s(budget_s, reserve_q_s, base_cap)
        lead = self.policy.degrade_frac * budget_s - head
        return float(min(max(lead, 0.0), budget_s))


@dataclasses.dataclass
class TenantState:
    """Admission state of one tenant (queue + fairness accounting)."""

    name: str
    weights: Optional[Tuple[float, float]] = None   # None → server default
    share: float = 1.0
    priority: int = 0
    budget_s: float = 1.0
    slo: str = "best_effort"         # strict | degrade | best_effort
    reserve_q_s: float = 0.25        # per-query solve-time EWMA
    deficit: float = 0.0             # DRR credit carried across flushes
    queue: Deque[Tuple[float, object]] = dataclasses.field(
        default_factory=deque)       # (arrival_s, item) FIFO
    bucket: Optional[TokenBucket] = None   # None → no rate limiter
    n_enqueued: int = 0
    n_dequeued: int = 0
    n_shed: int = 0                  # strict-SLO rejections (never solved)
    n_degraded: int = 0              # degrade-SLO cheap-path admissions
    n_rate_limited: int = 0          # door rejections (never enqueued)
    slots_granted: int = 0           # batch slots over the scheduler's life

    @property
    def waiting(self) -> int:
        return len(self.queue)

    def head_arrival(self) -> float:
        return self.queue[0][0] if self.queue else math.inf


class TenantScheduler:
    """Deficit-round-robin admission over per-tenant queues.

    Drives no clock of its own: the server asks ``next_deadline`` when
    idle, tests ``flush_due``-style conditions itself, and calls
    ``shed_unmeetable`` + ``compose`` to draw one micro-batch.  Unknown
    tenant names are auto-registered with default policy, so anonymous
    single-stream traffic needs no configuration.
    """

    def __init__(self, tenants: Iterable[TenantSpec] = (), *,
                 budget_s: float = 1.0, reserve_q_s: float = 0.25,
                 reserve_ewma: float = 0.3):
        self.default_budget_s = budget_s
        self.default_reserve_q_s = reserve_q_s
        self.reserve_ewma = reserve_ewma
        self._states: Dict[str, TenantState] = {}
        for spec in tenants:
            if spec.name in self._states:
                raise ValueError(f"duplicate tenant spec: {spec.name!r}")
            self._states[spec.name] = TenantState(
                name=spec.name, weights=spec.weights, share=spec.share,
                priority=spec.priority,
                budget_s=(spec.solve_budget_s if spec.solve_budget_s
                          is not None else budget_s),
                slo=spec.slo,
                reserve_q_s=reserve_q_s,
                bucket=(TokenBucket(spec.rate_limit_qps,
                                    spec.rate_limit_burst)
                        if spec.rate_limit_qps is not None else None))

    # -- registry ------------------------------------------------------------
    def state(self, name: str) -> TenantState:
        st = self._states.get(name)
        if st is None:
            st = TenantState(name=name, budget_s=self.default_budget_s,
                             reserve_q_s=self.default_reserve_q_s)
            self._states[name] = st
        return st

    def states(self) -> List[TenantState]:
        return list(self._states.values())

    # -- queueing ------------------------------------------------------------
    def enqueue(self, name: str, item: object, arrival_s: float) -> None:
        st = self.state(name)
        st.queue.append((arrival_s, item))
        st.n_enqueued += 1

    def admit_arrival(self, name: str, item: object,
                      arrival_s: float) -> bool:
        """Door admission: rate-limit check, then enqueue.

        Returns False (and enqueues nothing) when the tenant's token
        bucket rejects the arrival — the server records the request as
        ``rate_limited``.  The bucket is clocked by the arrival time, a
        pure function of the stream, so rejections are deterministic per
        seed.  Tenants without a configured bucket always admit.
        """
        st = self.state(name)
        if st.bucket is not None and not st.bucket.take(arrival_s):
            st.n_rate_limited += 1
            return False
        st.queue.append((arrival_s, item))
        st.n_enqueued += 1
        return True

    def total_waiting(self) -> int:
        return sum(st.waiting for st in self._states.values())

    # -- deadlines -----------------------------------------------------------
    def _deadline(self, st: TenantState, expected_n: int) -> float:
        """Latest flush start that still meets ``st``'s head budget."""
        return (st.head_arrival() + st.budget_s
                - st.reserve_q_s * max(expected_n, 1))

    def _expected_n(self, cap: int, picked: int = 0) -> int:
        """Expected size of the flush batch being (or about to be) composed.

        ``picked`` counts slots already drawn into the batch under
        composition: they stay in the same flush (one solve window, one
        ``compiled_s`` for every member), so the head being tested will
        join a batch of ``picked + remaining`` (capped).  Shed items, by
        contrast, leave the batch entirely — the shed loop passes
        ``picked=0`` and sees the genuinely shrunken pool.
        """
        return min(max(picked + self.total_waiting(), 1), cap)

    def next_deadline(self, cap: int) -> float:
        """Earliest flush deadline over all waiting tenants (inf if idle)."""
        n = self._expected_n(cap)
        return min((self._deadline(st, n)
                    for st in self._states.values() if st.queue),
                   default=math.inf)

    def deadline_due(self, now: float, cap: int) -> bool:
        return now >= self.next_deadline(cap)

    def unmeetable(self, st: TenantState, now: float, cap: int,
                   picked: int = 0) -> bool:
        """True when even an immediate flush would blow the head's budget:
        ``head_arrival + budget − reserve·E[n] < now`` (strictly — at
        exactly the deadline, flushing now still meets the budget).
        ``picked`` sizes E[n] for a batch already under composition."""
        return bool(st.queue) \
            and self._deadline(st, self._expected_n(cap, picked)) < now

    # -- overload triage -----------------------------------------------------
    def shed_unmeetable(self, now: float, cap: int
                        ) -> List[Tuple[str, object]]:
        """Pop and return every strict-SLO request whose budget is already
        unmeetable — the server records them as rejected, they are never
        solved.  Queues are FIFO, so popping stops at the first meetable
        head; the expected batch size is re-derived as the pool drains
        (shed items shrink the batch every later head would solve in).
        """
        shed: List[Tuple[str, object]] = []
        while True:
            over = [st for st in self._states.values()
                    if st.slo == "strict" and self.unmeetable(st, now, cap)]
            if not over:
                return shed
            st = min(over, key=lambda s: (s.head_arrival(), s.name))
            _, item = st.queue.popleft()
            st.n_dequeued += 1
            st.n_shed += 1
            if not st.queue:
                st.deficit = 0.0           # standard DRR empty-queue reset
            shed.append((st.name, item))

    # -- batch composition ---------------------------------------------------
    def compose(self, now: float, cap: int,
                degrade_lead_s: float = 0.0) -> List[Admit]:
        """Draw one micro-batch of at most ``cap`` items.

        Overdue heads first (any tier, oldest arrival first — the
        no-starvation guarantee), then priority tiers high→low with
        deficit-round-robin inside each tier.  Overdue pops are charged
        against the tenant's DRR credit (floored at the standard
        empty-queue reset of 0), so a burst served via promotion cannot
        double-dip on the next normal pass.  The expected batch size used
        by the overdue/degrade checks counts slots already composed plus
        the remaining pool (capped): every member of this batch shares one
        flush window, so an item popped late is *not* solving in a smaller
        batch — only genuinely removed items (sheds, between composes)
        shrink E[n].  An overdue head of a ``degrade``-SLO tenant is
        admitted with ``degrade=True`` (its budget is already unmeetable
        at full quality in the batch it joins).  Per-tenant slot grants
        are recorded in :attr:`TenantState.slots_granted`; their sum
        always equals the number of items returned (conservation).

        ``degrade_lead_s`` arms *preemptive* degradation (elastic
        control): degrade-SLO heads are tested against ``now + lead``
        instead of ``now``, routing them to the cheap path before the
        budget actually blows.  The lead shifts only the degrade flag,
        never pop order or shedding — capacity policy, not fairness.
        """
        picked: List[Admit] = []
        while len(picked) < cap:
            n_p = len(picked)
            over = [st for st in self._states.values()
                    if st.queue
                    and self._deadline(st,
                                       self._expected_n(cap, n_p)) <= now]
            if not over:
                break
            st = min(over, key=lambda s: (s.head_arrival(), s.name))
            degrade = st.slo == "degrade" \
                and self.unmeetable(st, now + degrade_lead_s, cap, n_p)
            picked.append(self._pop(st, degrade))
            # Promotion is not free slot-wise: consume any banked credit
            # (never below the standard empty-queue reset of 0, which also
            # applies if the promotion just drained the queue).
            st.deficit = 0.0 if not st.queue else max(st.deficit - 1.0, 0.0)
        while len(picked) < cap:
            busy = [st for st in self._states.values() if st.queue]
            if not busy:
                break
            tier = max(st.priority for st in busy)
            tier_states = sorted((s for s in busy if s.priority == tier),
                                 key=lambda s: s.name)
            # Credits are normalized by the tier's largest share: ratios are
            # preserved (a common factor) and the largest-share tenant
            # reaches a whole slot every pass, so composing one slot costs
            # O(1) passes even for arbitrarily small (but valid) shares.
            qmax = max(st.share for st in tier_states)
            for st in tier_states:
                st.deficit += st.share / qmax
                while st.deficit >= 1.0 and st.queue and len(picked) < cap:
                    degrade = st.slo == "degrade" \
                        and self.unmeetable(st, now + degrade_lead_s, cap,
                                            len(picked))
                    picked.append(self._pop(st, degrade))
                    st.deficit -= 1.0
                if not st.queue:
                    st.deficit = 0.0       # standard DRR: no banked credit
        return picked

    def _pop(self, st: TenantState, degrade: bool = False) -> Admit:
        _, item = st.queue.popleft()
        st.n_dequeued += 1
        st.slots_granted += 1
        if degrade:
            st.n_degraded += 1
        return Admit(st.name, item, degrade)

    # -- solve-time accounting ----------------------------------------------
    def note_solve(self, dt: float, n: int,
                   tenant_names: Iterable[str]) -> None:
        """Fold one micro-batch admission window of ``n`` queries into the
        reserves.

        ``dt`` must be the *full* clock charge of the flush — the batched
        compile solve plus each query's initial AQE planning step inside
        ``session.admit()`` — i.e. exactly what the server's simulated
        clock advances by (the PR-5 fix: feeding only the ``tune_batch``
        slice made the reserve systematically undershoot the true
        per-query admission cost, scheduling deadlines too late and hiding
        overload).  The EWMA tracks *per-query* time (``dt / n``) so a
        large batch cannot inflate the reserve later applied to a small
        one; the deadline scales it back up by the expected batch size.
        """
        dt_q = dt / max(n, 1)
        a = self.reserve_ewma
        # dict.fromkeys, not set(): dedup must preserve arrival order so
        # `state()` auto-registration order (and hence any downstream
        # iteration over the tenant table) is a function of the transcript,
        # not of the hash-randomized set order.
        for name in dict.fromkeys(tenant_names):
            st = self.state(name)      # auto-registers off the OLD default
            st.reserve_q_s = (1 - a) * st.reserve_q_s + a * dt_q
        self.default_reserve_q_s = ((1 - a) * self.default_reserve_q_s
                                    + a * dt_q)
