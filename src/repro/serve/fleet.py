"""Multi-worker optimizer fleet: sharded streaming admission at scale.

One :class:`~repro.serve.server.OptimizerServer` is a single process —
the ceiling the ROADMAP's millions-of-users target has to break through.
:class:`OptimizerFleet` shards the streaming admission loop across N
worker replicas, each wrapping its own ``OptimizerServer`` (caches,
tenant scheduler, elastic controller and all), and merges the served
results back into request order.

Routing is where the fleet either keeps or squanders the cache
amortization the serving stack is built on:

* **affinity** (default) — a consistent-hash ring over the template
  dims of the cache fingerprint (:func:`route_key`): every parametric
  variant and duplicate of a template lands on the same worker, so
  that worker's :class:`~repro.serve.cache.EffectiveSetCache` structure
  hits and :class:`~repro.serve.service.ResponseCache` dedup hits stay
  warm instead of being diluted N ways.  A **work-stealing fallback**
  kicks in when the owning worker's queue-delay forecast exceeds
  ``steal_delay_s``: the request is re-routed to the least-loaded
  worker (losing warmth, winning latency) — safe because per-query
  outputs are composition-independent (the golden-determinism
  invariant), so *where* a query is served can never change *what* is
  served.
* **random** — seeded hash of the request id: the load-balance-only
  baseline the affinity hit-rate claim is measured against.
* **single** — everything to worker 0: the pre-fleet baseline.

Timelines: with :class:`~repro.serve.server.ServiceTimeModel` set, the
fleet re-prices it via ``with_workers(n_workers)`` (co-located replicas
contend for the host), so every worker's admission timeline — and hence
the whole fleet run — is a pure function of stream + config.

Process-external caches: the three serving caches expose
``snapshot()``/``restore()`` (content-fingerprinted entries only — see
each cache's snapshot contract for the id()-pin exclusion), and a
:class:`CacheStore` holds the published blobs.  A fleet constructed with
a store warm-starts every worker from it, and (by default) publishes a
merged snapshot back after each :meth:`OptimizerFleet.serve` — so a new
worker, or a whole new fleet generation, starts with the previous
generation's warmth instead of a cold cache.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
import pickle
import zlib
from typing import Dict, List, Optional, Sequence, Set, Tuple

from ..core.models.perf_model import PerfModel
from ..core.moo.hmooc import HMOOCConfig
from ..queryengine.plan import Query
from ..queryengine.workloads import StreamRequest, TenantSpec
from .cache import query_fingerprint
from .server import (REJECTED_STATUSES, OptimizerServer, ServedQuery,
                     ServerConfig, ServerStats)

__all__ = ["OptimizerFleet", "FleetStats", "FleetRouter", "HashRing",
           "CacheStore", "route_key", "ROUTING_POLICIES", "CACHE_KINDS"]

Weights = Tuple[float, float]

ROUTING_POLICIES = ("affinity", "random", "single")

# Snapshot kinds a CacheStore holds, one per serving cache.
CACHE_KINDS = ("eset", "response", "pools")


def route_key(query: Query) -> Tuple:
    """Template-affinity routing key: the fleet-variable dims of the
    cache fingerprint.

    ``template_key`` is ``(benchmark, template, cfg, cost, model-fp)``
    and the response key adds qid/statistics/weights/tenant on top.  Every
    replica of one fleet is configured identically, so cfg/cost/model can
    never differentiate workers; the dims that decide *which worker's
    caches can be warm for this query* are exactly ``(benchmark,
    template)`` — hashing on them sends every variant and duplicate of a
    template to its one owning worker, which is what keeps structure and
    dedup hits local instead of N-way diluted.
    """
    return (query.benchmark, query.template)


def _h32(*parts) -> int:
    """Stable 32-bit hash of a part tuple (crc32 — process-independent,
    unlike builtin ``hash``)."""
    return zlib.crc32("|".join(str(p) for p in parts).encode()) & 0xFFFFFFFF


class HashRing:
    """Consistent-hash ring over worker indices (virtual-node variant).

    Each worker owns ``replicas`` pseudo-random points on a 32-bit ring;
    a key maps to the first point clockwise from its hash.  Consistency
    is the point: growing the fleet from N to N+1 workers moves only the
    keys the new worker's points capture (~1/(N+1) of the space), so most
    templates keep their warm owner across a resize — a modulo router
    would reshuffle nearly everything.
    """

    def __init__(self, n_workers: int, *, replicas: int = 64,
                 salt: int = 0):
        if n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {n_workers}")
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        self.n_workers = n_workers
        self.replicas = replicas
        self.salt = salt
        pts = [(_h32("vnode", salt, w, r), w)
               for w in range(n_workers) for r in range(replicas)]
        pts.sort()
        self._points = pts
        self._hashes = [h for h, _ in pts]

    def worker_for(self, key: Tuple) -> int:
        h = _h32("key", self.salt, *key)
        i = bisect.bisect_left(self._hashes, h)
        return self._points[i % len(self._points)][1]


class FleetRouter:
    """Assigns each request of a timed stream to a worker replica.

    Routing is deterministic and output-blind: it reads only the stream
    itself (arrival order, request ids, query templates) plus the
    config, never a solve result — so the assignment, like the admission
    timeline under a :class:`~repro.serve.server.ServiceTimeModel`, is a
    pure function of stream + config.

    Work stealing (affinity policy only): the router keeps a per-worker
    backlog forecast — a ready-time clock charged ``est_full_s`` per
    first-seen request and ``est_cheap_s`` per exact repeat (the dedup a
    warm response cache will serve in microseconds).  When the affinity
    target's forecast queue delay at a request's arrival exceeds
    ``steal_delay_s``, the request is stolen by the least-loaded worker
    (ties break to the lowest index).  ``steal_delay_s=None`` disables
    stealing (strict affinity).
    """

    def __init__(self, n_workers: int, *, policy: str = "affinity",
                 seed: int = 0, steal_delay_s: Optional[float] = None,
                 ring_replicas: int = 64, est_full_s: float = 0.25,
                 est_cheap_s: float = 0.001):
        if policy not in ROUTING_POLICIES:
            raise ValueError(f"unknown routing policy {policy!r}; "
                             f"expected one of {ROUTING_POLICIES}")
        if steal_delay_s is not None and (not math.isfinite(steal_delay_s)
                                          or steal_delay_s < 0.0):
            raise ValueError(f"steal_delay_s must be None or finite >= 0, "
                             f"got {steal_delay_s}")
        self.n_workers = n_workers
        self.policy = policy
        self.seed = seed
        self.steal_delay_s = steal_delay_s
        self.est_full_s = float(est_full_s)
        self.est_cheap_s = float(est_cheap_s)
        self.ring = HashRing(n_workers, replicas=ring_replicas, salt=seed)
        self.n_stolen = 0
        self.worker_counts = [0] * n_workers
        self._ready_s = [0.0] * n_workers
        self._seen: List[Set[Tuple]] = [set() for _ in range(n_workers)]

    def assign(self, requests: Sequence[StreamRequest]) -> List[int]:
        """Worker index per request, aligned with ``requests``.

        Requests are routed in arrival order (ties broken by rid, like
        the server's own admission order) so the backlog forecast each
        steal decision reads is the state a live dispatcher would see.
        """
        order = sorted(range(len(requests)),
                       key=lambda i: (requests[i].arrival_s,
                                      requests[i].rid))
        out = [0] * len(requests)
        for i in order:
            out[i] = self._route_one(requests[i])
        return out

    def _route_one(self, r: StreamRequest) -> int:
        if self.policy == "single":
            w = 0
        elif self.policy == "random":
            w = _h32("random", self.seed, r.rid) % self.n_workers
        else:
            w = self.ring.worker_for(route_key(r.query))
            if self.steal_delay_s is not None and self.n_workers > 1:
                delay = max(0.0, self._ready_s[w] - r.arrival_s)
                if delay > self.steal_delay_s:
                    alt = min(range(self.n_workers),
                              key=lambda j: (max(0.0, self._ready_s[j]
                                                 - r.arrival_s), j))
                    if alt != w:
                        w = alt
                        self.n_stolen += 1
        self._charge(r, w)
        self.worker_counts[w] += 1
        return w

    def _charge(self, r: StreamRequest, w: int) -> None:
        dup = (r.tenant, r.query.qid, query_fingerprint(r.query),
               None if r.weights is None else tuple(r.weights))
        cost = self.est_cheap_s if dup in self._seen[w] else self.est_full_s
        self._seen[w].add(dup)
        self._ready_s[w] = max(self._ready_s[w], r.arrival_s) + cost


class CacheStore:
    """Process-external store of published cache snapshots.

    One opaque blob per cache kind (``eset`` / ``response`` / ``pools``
    — the formats are versioned and validated by the caches themselves).
    Workers warm-start from the store and fleets publish merged
    snapshots back to it; :meth:`save`/:meth:`load` round-trip the whole
    store through a file, which is what carries cache warmth across
    *processes* and fleet generations.
    """

    def __init__(self):
        self._blobs: Dict[str, bytes] = {}

    def publish(self, kind: str, blob: bytes) -> None:
        if kind not in CACHE_KINDS:
            raise ValueError(f"unknown cache kind {kind!r}; expected one "
                             f"of {CACHE_KINDS}")
        if not isinstance(blob, bytes):
            raise TypeError(f"snapshot blob must be bytes, got "
                            f"{type(blob).__name__}")
        self._blobs[kind] = blob

    def fetch(self, kind: str) -> Optional[bytes]:
        return self._blobs.get(kind)

    def kinds(self) -> Tuple[str, ...]:
        return tuple(k for k in CACHE_KINDS if k in self._blobs)

    def save(self, path) -> None:
        payload = {"format": "repro-cache-store", "version": 1,
                   "blobs": dict(self._blobs)}
        with open(path, "wb") as f:
            pickle.dump(payload, f, protocol=pickle.HIGHEST_PROTOCOL)

    @classmethod
    def load(cls, path) -> "CacheStore":
        with open(path, "rb") as f:
            payload = pickle.load(f)
        if not isinstance(payload, dict) \
                or payload.get("format") != "repro-cache-store":
            raise ValueError(f"{path} is not a cache-store file")
        if payload.get("version") != 1:
            raise ValueError(f"unsupported cache-store version "
                             f"{payload.get('version')!r}")
        store = cls()
        for kind, blob in sorted(payload["blobs"].items()):
            store.publish(kind, blob)
        return store


@dataclasses.dataclass
class FleetStats:
    """Aggregate outcome of one :meth:`OptimizerFleet.serve` call."""
    n_workers: int = 1
    policy: str = "affinity"
    n_queries: int = 0
    n_finished: int = 0
    n_shed: int = 0
    n_degraded: int = 0
    n_rate_limited: int = 0
    n_stolen: int = 0                  # affinity targets overridden by load
    makespan_s: float = 0.0            # last served finish − first arrival
    worker_counts: List[int] = dataclasses.field(default_factory=list)
    per_worker: List[ServerStats] = dataclasses.field(default_factory=list)

    @property
    def qps(self) -> float:
        """Aggregate served throughput over the fleet makespan."""
        return self.n_finished / self.makespan_s if self.makespan_s else 0.0


class OptimizerFleet:
    """N ``OptimizerServer`` replicas behind a template-affinity router.

    Every replica is configured identically (same config / weights / cfg
    / model / tenant policy); with ``config.clock`` set it is re-priced
    via ``with_workers(n_workers)`` so co-located contention is charged.
    Output safety needs no cross-worker coordination: per-query outputs
    are composition-independent (the golden-determinism invariant), so
    sharding changes only *latency* — each tenant's served plans stay
    bit-identical to the offline per-tenant pipeline under any worker
    count and any routing policy.

    ``cache_store`` (optional) plugs the fleet into a process-external
    :class:`CacheStore`: workers :meth:`warm_start` from it at
    construction, and each :meth:`serve` ends by :meth:`publish`-ing a
    merged snapshot back (disable with ``publish_on_serve=False``).
    """

    def __init__(
        self,
        *,
        n_workers: int,
        config: ServerConfig = ServerConfig(),
        weights: Optional[Weights] = None,
        cfg: Optional[HMOOCConfig] = None,
        model: Optional[PerfModel] = None,
        tenants: Sequence[TenantSpec] = (),
        policy: str = "affinity",
        steal_delay_s: Optional[float] = None,
        ring_replicas: int = 64,
        seed: int = 0,
        cache_store: Optional[CacheStore] = None,
        publish_on_serve: bool = True,
    ):
        if n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {n_workers}")
        if policy not in ROUTING_POLICIES:
            raise ValueError(f"unknown routing policy {policy!r}; "
                             f"expected one of {ROUTING_POLICIES}")
        if config.clock is not None:
            config = dataclasses.replace(
                config, clock=config.clock.with_workers(n_workers))
        self.n_workers = n_workers
        self.config = config
        self.policy = policy
        self.steal_delay_s = steal_delay_s
        self.ring_replicas = ring_replicas
        self.seed = seed
        self.cache_store = cache_store
        self.publish_on_serve = publish_on_serve
        self.workers = [
            OptimizerServer(config=config, weights=weights, cfg=cfg,
                            model=model, tenants=tenants)
            for _ in range(n_workers)]
        clock = config.clock
        # Backlog-forecast cost estimates for the work-stealing router:
        # one full solve per fresh request (the clock model's single-query
        # flush, or the configured reserve seed), the cheap-member cost
        # per exact repeat.
        self._est_full_s = (clock.flush_s(1) if clock is not None
                            else config.solve_reserve_s)
        self._est_cheap_s = (clock.flush_s(1, 1) if clock is not None
                             else 0.0)
        self.last_run = FleetStats(n_workers=n_workers, policy=policy)
        if cache_store is not None:
            self.warm_start()

    # -- cache plumbing ------------------------------------------------------
    def _cache(self, server: OptimizerServer, kind: str):
        if kind == "eset":
            return server.tuning.cache
        if kind == "response":
            return server.tuning._results      # None when dedupe is off
        if kind == "pools":
            return server.session.pool_cache
        raise ValueError(f"unknown cache kind {kind!r}")

    def warm_start(self) -> Dict[str, int]:
        """Restore every published snapshot into every worker's caches.

        Returns per-kind totals of entries inserted (across workers).
        Safe at any time: restore merges, existing entries win, and all
        snapshot entries are exact artifacts for their keys — warmth
        changes hit rates and timing, never outputs.
        """
        counts = {kind: 0 for kind in CACHE_KINDS}
        if self.cache_store is None:
            return counts
        for kind in CACHE_KINDS:
            blob = self.cache_store.fetch(kind)
            if blob is None:
                continue
            for worker in self.workers:
                cache = self._cache(worker, kind)
                if cache is not None:
                    counts[kind] += cache.restore(blob)
        return counts

    def publish(self) -> Dict[str, int]:
        """Merge every worker's snapshot and publish to the cache store.

        Per kind: each worker's snapshot-eligible entries (content-
        fingerprinted only — the snapshot contract) are merged in worker
        order into one cache image, whose snapshot becomes the published
        blob.  Returns per-kind merged entry counts.
        """
        if self.cache_store is None:
            raise RuntimeError("fleet has no cache store to publish to")
        counts: Dict[str, int] = {}
        for kind in CACHE_KINDS:
            caches = [c for c in (self._cache(worker, kind)
                                  for worker in self.workers)
                      if c is not None]
            if not caches:
                continue
            merged = type(caches[0])(max_entries=caches[0].max_entries)
            for c in caches:
                merged.restore(c.snapshot())
            self.cache_store.publish(kind, merged.snapshot())
            counts[kind] = len(merged)
        return counts

    # -- serving -------------------------------------------------------------
    def serve(self, requests: Sequence[StreamRequest], *,
              capacity_events: Sequence[Tuple[float, int]] = ()
              ) -> List[ServedQuery]:
        """Route, shard, serve, and merge back into request order.

        Each worker serves its shard on its own simulated clock (all
        replicas run concurrently in the modelled deployment, so worker
        timelines overlap rather than queue behind each other);
        ``capacity_events`` apply to every worker, modelling a
        deployment-wide capacity change.  Every returned
        :class:`ServedQuery` carries the index of the worker that served
        it in ``worker``.
        """
        router = FleetRouter(
            self.n_workers, policy=self.policy, seed=self.seed,
            steal_delay_s=self.steal_delay_s,
            ring_replicas=self.ring_replicas,
            est_full_s=self._est_full_s, est_cheap_s=self._est_cheap_s)
        assign = router.assign(requests)
        shards: List[List[StreamRequest]] = [[] for _ in
                                             range(self.n_workers)]
        for r, w in zip(requests, assign):
            shards[w].append(r)
        merged: Dict[int, ServedQuery] = {}
        per_worker: List[ServerStats] = []
        for w, (worker, shard) in enumerate(zip(self.workers, shards)):
            for s in worker.serve(shard, capacity_events=capacity_events):
                s.worker = w
                merged[s.rid] = s
            per_worker.append(worker.last_run)
        out = [merged[r.rid] for r in requests]
        fin = [s.finished_s for s in out
               if s.status not in REJECTED_STATUSES
               and math.isfinite(s.finished_s)]
        first = min((s.arrival_s for s in out), default=0.0)
        self.last_run = FleetStats(
            n_workers=self.n_workers,
            policy=self.policy,
            n_queries=len(out),
            n_finished=len(fin),
            n_shed=sum(1 for s in out if s.status == "shed"),
            n_degraded=sum(1 for s in out if s.status == "degraded"),
            n_rate_limited=sum(1 for s in out
                               if s.status == "rate_limited"),
            n_stolen=router.n_stolen,
            makespan_s=(max(fin) - first) if fin else 0.0,
            worker_counts=list(router.worker_counts),
            per_worker=per_worker)
        if self.cache_store is not None and self.publish_on_serve:
            self.publish()
        return out

    # -- reporting -----------------------------------------------------------
    def latency_report(self, served: Sequence[ServedQuery]) -> dict:
        """Fleet-level latency report: worker 0's report shape over the
        merged sample, with run-level fields replaced by fleet
        aggregates (per-worker reports remain available via
        ``workers[i].latency_report``)."""
        rep = self.workers[0].latency_report(served)
        st = self.last_run
        rep.update(n_micro_batches=sum(w.n_micro_batches
                                       for w in st.per_worker),
                   rounds=sum(w.rounds for w in st.per_worker),
                   makespan_s=st.makespan_s, qps=st.qps,
                   n_workers=st.n_workers, policy=st.policy,
                   n_stolen=st.n_stolen,
                   worker_counts=list(st.worker_counts))
        return rep

    def cache_report(self) -> dict:
        """Aggregate cache statistics across workers, with hit rates.

        ``effective_set.warm_rate`` counts any non-miss lookup (full /
        approx / structure hit) — the fraction of solves that skipped at
        least Algorithm 1's candidate sampling; ``response.hit_rate`` is
        exact dedup.  Routing policy is what moves these: affinity keeps
        a template's traffic on one worker's caches, random dilutes it.
        """
        def _sum(dicts: List[dict]) -> Dict[str, int]:
            out: Dict[str, int] = {}
            for d in dicts:
                for k, v in d.items():
                    out[k] = out.get(k, 0) + v
            return out

        eset = _sum([w.tuning.cache.stats() for w in self.workers])
        resp = _sum([w.tuning._results.stats() for w in self.workers
                     if w.tuning._results is not None])
        pools = _sum([w.session.pool_cache.stats() for w in self.workers])
        warm = (eset.get("hits", 0) + eset.get("approx_hits", 0)
                + eset.get("structure_hits", 0))
        eset_total = warm + eset.get("misses", 0)
        resp_total = resp.get("hits", 0) + resp.get("misses", 0)
        return {
            "effective_set": {
                **eset,
                "warm_rate": warm / eset_total if eset_total else math.nan},
            "response": {
                **resp,
                "hit_rate": (resp.get("hits", 0) / resp_total
                             if resp_total else math.nan)},
            "pools": pools,
        }
