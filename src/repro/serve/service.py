"""Batched multi-query compile-time tuning service (paper §5.1 at scale).

``tune_batch`` amortizes solver work across a batch of concurrent tuning
requests, the serving regime of the paper's 1–2 s cloud budget:

* **Request dedup / response cache** — identical requests (byte-identical
  statistics + weights), within a batch or across batches, are solved once
  and the stored result is shared (exact: the solver is deterministic).
* **Effective-set cache** — Algorithm 1 artifacts are reused across
  batches for repeated-template traffic (see :mod:`repro.serve.cache`).
* **Vectorized solver** — the underlying HMOOC solve batches every
  stage-model evaluation to one call per subQ and routes dominance
  filtering / weighted-sum scoring through the Pallas kernels.

Every returned :class:`CompileTimeResult` is bit-identical to what a
standalone ``compile_time_optimize`` call would produce for that query
(dedup shares exact results; cache reuse is exact for identical queries and
disabled across variants unless explicitly opted in).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.models.perf_model import PerfModel
from ..core.moo.hmooc import HMOOCConfig
from ..core.tuning.compile_time import (CompileTimeResult,
                                        compile_time_optimize,
                                        default_theta_result)
from ..queryengine.plan import Query
from ..queryengine.simulator import CostModel, DEFAULT_COST
from .cache import EffectiveSetCache, query_fingerprint

__all__ = ["TuningService", "tune_batch", "ResponseCache"]

Weights = Tuple[float, float]


@dataclasses.dataclass
class BatchStats:
    n_queries: int = 0
    n_solved: int = 0            # actual solver invocations (post-dedup)
    n_deduped: int = 0           # served from an identical request (any age)
    n_cheap: int = 0             # degraded: solved on reused template banks
    n_default_theta: int = 0     # degraded: served the Spark defaults
    wall_time: float = 0.0

    @property
    def qps(self) -> float:
        return self.n_queries / self.wall_time if self.wall_time else 0.0


class ResponseCache:
    """Bounded LRU of finished results keyed by (tenant, fingerprint,
    weights).

    Exact by construction: the solver is deterministic, so an identical
    request (same statistics, weights, config, model) maps to a
    bit-identical :class:`CompileTimeResult`.  Shareable: a streaming
    server passes one instance to its :class:`TuningService` so dedup
    spans micro-batches and admission epochs, not just one batch.  The
    tenant id is part of the key, so one tenant's weighted picks are never
    served to another — even before the preference weights (also in the
    key) would force a miss.
    """

    def __init__(self, max_entries: int = 4096):
        from collections import OrderedDict
        self.max_entries = max_entries
        self._d: "OrderedDict[tuple, CompileTimeResult]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._d)

    def get(self, key):
        r = self._d.get(key)
        if r is not None:
            self.hits += 1
            self._d.move_to_end(key)
        else:
            self.misses += 1
        return r

    def put(self, key, result) -> None:
        self._d[key] = result
        self._d.move_to_end(key)
        while len(self._d) > self.max_entries:
            self._d.popitem(last=False)

    def stats(self) -> dict:
        return {"entries": len(self._d), "hits": self.hits,
                "misses": self.misses}


class TuningService:
    """Long-lived compile-time tuning server with an effective-set cache."""

    def __init__(
        self,
        *,
        model: Optional[PerfModel] = None,
        cfg: HMOOCConfig = HMOOCConfig(),
        cost: CostModel = DEFAULT_COST,
        cache: Optional[EffectiveSetCache] = None,
        reuse_banks_across_variants: bool = False,
        dedupe: bool = True,
        response_cache: Optional[ResponseCache] = None,
    ):
        self.model = model
        self.cfg = cfg
        self.cost = cost
        self.cache = cache if cache is not None else EffectiveSetCache(
            reuse_banks_across_variants=reuse_banks_across_variants)
        self.dedupe = dedupe
        if response_cache is not None:
            self._results: Optional[ResponseCache] = response_cache
        else:
            self._results = ResponseCache() if dedupe else None
        self.last_batch = BatchStats()
        self.totals = BatchStats()     # cumulative over the service's life

    def tune_batch(
        self,
        queries: Sequence[Query],
        weights: Union[Weights, Sequence[Weights]] = (0.9, 0.1),
        *,
        tenants: Optional[Sequence[Optional[str]]] = None,
        degraded: Optional[Sequence[bool]] = None,
    ) -> List[CompileTimeResult]:
        """Solve the compile-time MOO for every query; aligned results.

        ``tenants`` (aligned with ``queries``) scopes response-cache
        entries per tenant: a multi-tenant server passes each request's
        tenant id so cached weighted picks never cross tenants.  ``None``
        keeps the anonymous single-stream behavior.

        ``degraded`` (aligned with ``queries``) marks queries whose solve
        budget is already blown (degrade-SLO overload admissions): they are
        routed through the *cheap* compile path — an exact response-cache
        hit if one exists, else a solve on the template's cached Algorithm 1
        banks (approximate across parametric variants), else the Spark
        default configuration — never a fresh Algorithm 1 bank build.
        Approximate degraded results are cached under a degrade-marked key,
        so they can never be served to a later full-quality request.
        """
        t0 = time.perf_counter()
        per_q_weights = _expand_weights(weights, len(queries))
        if tenants is not None and len(tenants) != len(queries):
            raise ValueError(
                f"got {len(tenants)} tenant ids for {len(queries)} queries")
        if degraded is not None and len(degraded) != len(queries):
            raise ValueError(
                f"got {len(degraded)} degrade flags for {len(queries)} "
                "queries")
        results: List[Optional[CompileTimeResult]] = [None] * len(queries)
        n_solved = n_cheap = n_default = 0
        for qi, (q, w) in enumerate(zip(queries, per_q_weights)):
            # qid + statistics fingerprint: the 32-bit crc alone could
            # collide across distinct queries in a long-lived service.
            # cfg/cost/model complete the inputs the solver reads, so one
            # ResponseCache can be shared across differently-configured
            # services (the model object in the key also pins it live,
            # keeping identity-hashed entries unambiguous).
            key = (tenants[qi] if tenants is not None else None,
                   q.qid, query_fingerprint(q), w, self.cfg, self.cost,
                   self.model)
            if self._results is not None:
                hit = self._results.get(key)
                if hit is not None:
                    results[qi] = hit
                    continue
            if degraded is not None and degraded[qi]:
                results[qi], kind = self._tune_cheap(q, w, key)
                if kind == "cheap":
                    n_cheap += 1
                else:
                    n_default += 1
                continue
            results[qi] = compile_time_optimize(
                q, model=self.model, weights=w, cfg=self.cfg,
                cost=self.cost, cache=self.cache)
            n_solved += 1
            if self._results is not None:
                self._results.put(key, results[qi])
        dt = time.perf_counter() - t0
        self.last_batch = BatchStats(
            n_queries=len(queries), n_solved=n_solved,
            n_deduped=(len(queries) - n_solved - n_cheap - n_default),
            n_cheap=n_cheap, n_default_theta=n_default, wall_time=dt)
        for f in dataclasses.fields(BatchStats):
            setattr(self.totals, f.name,
                    getattr(self.totals, f.name) + getattr(self.last_batch,
                                                           f.name))
        return results  # type: ignore[return-value]

    def _tune_cheap(self, q: Query, w: Weights, exact_key: tuple
                    ) -> Tuple[CompileTimeResult, str]:
        """Budget-blown solve: cached template banks or the Spark defaults.

        Never builds fresh Algorithm 1 banks.  The caller has already
        missed the exact response cache for ``exact_key``; approximate
        results are stored under a degrade-marked variant of that key
        (exact bank reuse — matching fingerprint — is bit-identical to a
        full solve and stored under the exact key itself).
        """
        peeked = self.cache.peek(q, self.cfg, self.model, self.cost)
        if peeked is not None:
            eset, exact = peeked
            key = exact_key if exact else ("degraded",) + exact_key
            if self._results is not None:
                hit = self._results.get(key)
                if hit is not None:
                    return hit, "cheap"
            res = compile_time_optimize(
                q, model=self.model, weights=w, cfg=self.cfg,
                cost=self.cost, effective_set=eset)
            if self._results is not None:
                self._results.put(key, res)
            return res, "cheap"
        key = ("degraded",) + exact_key
        if self._results is not None:
            hit = self._results.get(key)
            if hit is not None:
                return hit, "default"
        res = default_theta_result(q, model=self.model, cost=self.cost)
        if self._results is not None:
            self._results.put(key, res)
        return res, "default"


def tune_batch(
    queries: Sequence[Query],
    weights: Union[Weights, Sequence[Weights]] = (0.9, 0.1),
    cfg: HMOOCConfig = HMOOCConfig(),
    *,
    model: Optional[PerfModel] = None,
    cost: CostModel = DEFAULT_COST,
    cache: Optional[EffectiveSetCache] = None,
    dedupe: bool = True,
) -> List[CompileTimeResult]:
    """One-shot batched solve; see :class:`TuningService` for a server."""
    svc = TuningService(model=model, cfg=cfg, cost=cost, cache=cache,
                        dedupe=dedupe)
    return svc.tune_batch(queries, weights)


def _expand_weights(weights, n: int) -> List[Weights]:
    arr = np.asarray(weights, np.float64)
    if arr.ndim == 1:
        return [tuple(arr.tolist())] * n
    if arr.shape[0] != n:
        raise ValueError(
            f"got {arr.shape[0]} weight rows for {n} queries")
    return [tuple(row.tolist()) for row in arr]
