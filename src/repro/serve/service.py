"""Batched multi-query compile-time tuning service (paper §5.1 at scale).

``tune_batch`` amortizes solver work across a batch of concurrent tuning
requests, the serving regime of the paper's 1–2 s cloud budget:

* **Request dedup / response cache** — identical requests (byte-identical
  statistics + weights), within a batch or across batches, are solved once
  and the stored result is shared (exact: the solver is deterministic).
* **Effective-set cache** — Algorithm 1 artifacts are reused across
  batches for repeated-template traffic (see :mod:`repro.serve.cache`).
* **Vectorized solver** — the underlying HMOOC solve batches every
  stage-model evaluation to one call per subQ and routes dominance
  filtering / weighted-sum scoring through the Pallas kernels.

Every returned :class:`CompileTimeResult` is bit-identical to what a
standalone ``compile_time_optimize`` call would produce for that query
(dedup shares exact results; cache reuse is exact for identical queries and
disabled across variants unless explicitly opted in).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.models.perf_model import PerfModel
from ..core.moo.hmooc import HMOOCConfig, HmoocPlan
from ..core.tuning.compile_time import (CompileTimeResult,
                                        compile_time_optimize,
                                        default_theta_result, finish_result)
from ..core.tuning.objectives import StageObjectives, fused_stage_eval
from ..queryengine.plan import Query
from ..queryengine.simulator import CostModel, DEFAULT_COST
from .cache import (EffectiveSetCache, model_fingerprint, pack_snapshot,
                    query_fingerprint, template_key, unpack_snapshot)

__all__ = ["TuningService", "tune_batch", "ResponseCache"]

Weights = Tuple[float, float]


@dataclasses.dataclass
class BatchStats:
    n_queries: int = 0
    n_solved: int = 0            # actual solver invocations (post-dedup)
    n_deduped: int = 0           # served from an identical request (any age)
    n_cheap: int = 0             # degraded: solved on reused template banks
    n_default_theta: int = 0     # degraded: served the Spark defaults
    wall_time: float = 0.0

    @property
    def qps(self) -> float:
        return self.n_queries / self.wall_time if self.wall_time else 0.0


class ResponseCache:
    """Bounded LRU of finished results keyed by (tenant, fingerprint,
    weights).

    Exact by construction: the solver is deterministic, so an identical
    request (same statistics, weights, config, model) maps to a
    bit-identical :class:`CompileTimeResult`.  Shareable: a streaming
    server passes one instance to its :class:`TuningService` so dedup
    spans micro-batches and admission epochs, not just one batch.  The
    tenant id is part of the key, so one tenant's weighted picks are never
    served to another — even before the preference weights (also in the
    key) would force a miss.

    The model's *content fingerprint* (not its live object identity) is the
    last key element: a reloaded model with identical weights keeps its
    entries valid, while a retrained model can never be served a
    predecessor's picks — even if the old object is collected and its id
    recycled.  :meth:`clear_model` drops every entry minted under a given
    fingerprint (the retire-a-model path).
    """

    def __init__(self, max_entries: int = 4096):
        from collections import OrderedDict
        self.max_entries = max_entries
        self._d: "OrderedDict[tuple, CompileTimeResult]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.model_evictions = 0

    def __len__(self) -> int:
        return len(self._d)

    def get(self, key):
        r = self._d.get(key)
        if r is not None:
            self.hits += 1
            self._d.move_to_end(key)
        else:
            self.misses += 1
        return r

    def put(self, key, result) -> None:
        self._d[key] = result
        self._d.move_to_end(key)
        while len(self._d) > self.max_entries:
            self._d.popitem(last=False)

    def clear_model(self, model_fp) -> int:
        """Evict every entry keyed under model fingerprint ``model_fp``."""
        victims = [k for k in self._d if k and k[-1] == model_fp]
        for k in victims:
            del self._d[k]
        self.model_evictions += len(victims)
        return len(victims)

    def stats(self) -> dict:
        return {"entries": len(self._d), "hits": self.hits,
                "misses": self.misses,
                "model_evictions": self.model_evictions}

    def snapshot(self) -> bytes:
        """Opaque blob of the process-external entries (LRU order).

        **Snapshot contract:** response keys end with the model
        fingerprint; an ``int`` there is the ``id()`` fallback for models
        without a content fingerprint, meaningful only inside this
        process.  Those entries are silently excluded — they stay warm
        locally.  Content-fingerprinted (str) and model-less (None) keys
        serialize, including the degrade-marked ``("degraded", ...)``
        variants (their :class:`_CheapEntry` kind travels with them).
        """
        items = [(k, v) for k, v in self._d.items()
                 if not isinstance(k[-1], int)]
        return pack_snapshot("response", items)

    def restore(self, blob: bytes) -> int:
        """Merge a :meth:`snapshot` blob; returns entries inserted.
        Existing entries win under the same key (both are the solver's
        deterministic output for that key); ``max_entries`` is enforced
        from the cold end."""
        n = 0
        for k, v in unpack_snapshot(blob, "response"):
            if k in self._d:
                continue
            self._d[k] = v
            n += 1
        while len(self._d) > self.max_entries:
            self._d.popitem(last=False)
        return n


@dataclasses.dataclass
class _CheapEntry:
    """Degraded-path response-cache entry: the result plus how it was made.

    The kind travels with the entry because a later hit cannot re-derive
    it: bank availability may have changed between the store and the hit
    (e.g. the effective-set cache evicted the template), so re-probing at
    hit time would relabel a cached cheap solve as a default — corrupting
    the degraded-path accounting the overload controller steers by.
    """
    result: CompileTimeResult
    kind: str                     # "cheap" | "default"


class TuningService:
    """Long-lived compile-time tuning server with an effective-set cache."""

    def __init__(
        self,
        *,
        model: Optional[PerfModel] = None,
        cfg: HMOOCConfig = HMOOCConfig(),
        cost: CostModel = DEFAULT_COST,
        cache: Optional[EffectiveSetCache] = None,
        reuse_banks_across_variants: bool = False,
        dedupe: bool = True,
        response_cache: Optional[ResponseCache] = None,
        jit_solve: Optional[bool] = None,
    ):
        self.model = model
        self.cfg = cfg
        self.cost = cost
        self.cache = cache if cache is not None else EffectiveSetCache(
            reuse_banks_across_variants=reuse_banks_across_variants)
        self.dedupe = dedupe
        if response_cache is not None:
            self._results: Optional[ResponseCache] = response_cache
        else:
            self._results = ResponseCache() if dedupe else None
        # None = batched jitted solve whenever a model backs the service
        # (the oracle backend keeps the sequential per-query loop — its
        # evaluator is already one vectorized simulator call per stage).
        # False forces the legacy sequential path for A/B comparison.
        self.jit_solve = jit_solve
        self.last_batch = BatchStats()
        self.totals = BatchStats()     # cumulative over the service's life

    @property
    def model(self) -> Optional[PerfModel]:
        return self._model

    @model.setter
    def model(self, m: Optional[PerfModel]) -> None:
        # Response-cache keys carry the fingerprint of the model that
        # produced them, so swapping in a retrained model invalidates old
        # entries by key mismatch alone.
        self._model = m
        self._model_fp = model_fingerprint(m)

    def tune_batch(
        self,
        queries: Sequence[Query],
        weights: Union[Weights, Sequence[Weights]] = (0.9, 0.1),
        *,
        tenants: Optional[Sequence[Optional[str]]] = None,
        degraded: Optional[Sequence[bool]] = None,
    ) -> List[CompileTimeResult]:
        """Solve the compile-time MOO for every query; aligned results.

        ``tenants`` (aligned with ``queries``) scopes response-cache
        entries per tenant: a multi-tenant server passes each request's
        tenant id so cached weighted picks never cross tenants.  ``None``
        keeps the anonymous single-stream behavior.

        ``degraded`` (aligned with ``queries``) marks queries whose solve
        budget is already blown (degrade-SLO overload admissions): they are
        routed through the *cheap* compile path — an exact response-cache
        hit if one exists, else a solve on the template's cached Algorithm 1
        banks (approximate across parametric variants), else the Spark
        default configuration — never a fresh Algorithm 1 bank build.
        Approximate degraded results are cached under a degrade-marked key,
        so they can never be served to a later full-quality request.
        """
        t0 = time.perf_counter()
        per_q_weights = _expand_weights(weights, len(queries))
        if tenants is not None and len(tenants) != len(queries):
            raise ValueError(
                f"got {len(tenants)} tenant ids for {len(queries)} queries")
        if degraded is not None and len(degraded) != len(queries):
            raise ValueError(
                f"got {len(degraded)} degrade flags for {len(queries)} "
                "queries")
        results: List[Optional[CompileTimeResult]] = [None] * len(queries)
        use_batched = (self._model is not None
                       and (self.jit_solve is None or self.jit_solve))
        n_solved = n_cheap = n_default = 0
        run: List[int] = []

        def flush_run() -> None:
            nonlocal n_solved
            if run:
                # repro: allow[CK002] batched full solves store under the exact (non-degrade-marked) key on purpose — same contract as the direct put below; `degraded` never reaches _solve_run (degraded queries act as run barriers above)
                n_solved += self._solve_run(queries, per_q_weights, tenants,
                                            run, results)
                run.clear()

        for qi, (q, w) in enumerate(zip(queries, per_q_weights)):
            if use_batched and not (degraded is not None and degraded[qi]):
                # Batched across the run of non-degraded neighbors; any
                # degraded query below acts as a barrier so cache traffic
                # keeps the sequential order (and therefore stats).
                run.append(qi)
                continue
            flush_run()
            key = self._response_key(q, w,
                                     tenants[qi] if tenants is not None
                                     else None)
            if self._results is not None:
                hit = self._results.get(key)
                if hit is not None:
                    results[qi] = hit
                    continue
            if degraded is not None and degraded[qi]:
                # repro: allow[CK002] _tune_cheap stores twice by design: under the degrade-marked key AND under the exact key, so a later exact hit upgrades the degraded answer — the `degraded` dimension is deliberately absent from the exact-key store
                results[qi], kind = self._tune_cheap(q, w, key)
                if kind == "cheap":
                    n_cheap += 1
                else:
                    n_default += 1
                continue
            results[qi] = compile_time_optimize(
                q, model=self._model, weights=w, cfg=self.cfg,
                cost=self.cost, cache=self.cache)
            n_solved += 1
            if self._results is not None:
                # repro: allow[CK002] full solves store under the exact key on purpose: degraded results are minted in _tune_cheap under degrade-marked keys, and an exact hit serving a later degraded request is the intended upgrade path
                self._results.put(key, results[qi])
        flush_run()
        dt = time.perf_counter() - t0
        self.last_batch = BatchStats(
            n_queries=len(queries), n_solved=n_solved,
            n_deduped=(len(queries) - n_solved - n_cheap - n_default),
            n_cheap=n_cheap, n_default_theta=n_default, wall_time=dt)
        for f in dataclasses.fields(BatchStats):
            setattr(self.totals, f.name,
                    getattr(self.totals, f.name) + getattr(self.last_batch,
                                                           f.name))
        return results  # type: ignore[return-value]

    def _response_key(self, q: Query, w: Weights, tenant) -> tuple:
        # qid + statistics fingerprint: the 32-bit crc alone could collide
        # across distinct queries in a long-lived service.  cfg/cost/model
        # fingerprint complete the inputs the solver reads, so one
        # ResponseCache can be shared across differently-configured
        # services and survives model reloads (see ResponseCache).
        return (tenant, q.qid, query_fingerprint(q), w, self.cfg, self.cost,
                self._model_fp)

    def _solve_run(self, queries: Sequence[Query],
                   per_q_weights: Sequence[Weights],
                   tenants: Optional[Sequence[Optional[str]]],
                   idxs: Sequence[int],
                   results: List[Optional[CompileTimeResult]]) -> int:
        """Jitted micro-batch solve of one run of non-degraded queries.

        Semantically a transcript of the sequential loop: every
        response-cache get/put and effective-set lookup/store happens with
        the same keys and — per cache key — in the same order, so hit/miss
        statistics and stored artifacts match the legacy path exactly, and
        each result is bit-identical to its ``compile_time_optimize``
        counterpart.  What changes is the dispatch shape: all queries'
        stage evaluations per solver phase are fused into one bucket-padded
        model call (:func:`fused_stage_eval`), and the HMOOC solves advance
        in lockstep as externally-driven :class:`HmoocPlan` state machines.
        Returns the number of actual solves (post-dedup).
        """
        model = self._model
        # -- response planning: dedup within and across batches ------------
        keys: dict = {}
        pending: dict = {}            # key -> first qi solving it this run
        deferred_gets: List[Tuple[int, tuple]] = []
        solved: List[int] = []
        for qi in idxs:
            key = self._response_key(
                queries[qi], per_q_weights[qi],
                tenants[qi] if tenants is not None else None)
            keys[qi] = key
            if self._results is not None:
                if key in pending:
                    # An identical request is already solving in this run;
                    # resolve the get after its put so the dedup registers
                    # as a response-cache hit, like the sequential order.
                    deferred_gets.append((qi, key))
                    continue
                hit = self._results.get(key)
                if hit is not None:
                    results[qi] = hit
                    continue
                pending[key] = qi
            solved.append(qi)
        if solved:
            # -- embedding prefetch: one GTN dispatch for the whole run ----
            pairs = []
            for qi in solved:
                pairs.extend((queries[qi], i)
                             for i in range(queries[qi].n_subqs))
            model.embed_many(pairs)
            objs = {qi: StageObjectives(queries[qi], model=model,
                                        cost=self.cost) for qi in solved}
            # -- effective-set planning ------------------------------------
            t0s: dict = {}
            plans: dict = {}
            deferred_lookup: set = set()
            pending_eset: dict = {}   # template key -> (owner qi, owner fp)
            waiting: List[Tuple[int, int]] = []   # (qi, owner qi)
            for qi in solved:
                q, obj = queries[qi], objs[qi]
                t0s[qi] = time.perf_counter()
                tk = template_key(q, self.cfg, model, self.cost)
                fp = query_fingerprint(q)
                if tk in pending_eset:
                    # The template's banks are being (re)built by an
                    # earlier query of this run; the cache lookup is
                    # deferred past the owner's store so stats match the
                    # sequential transcript.
                    owner_qi, owner_fp = pending_eset[tk]
                    deferred_lookup.add(qi)
                    if (fp == owner_fp
                            or self.cache.reuse_banks_across_variants):
                        waiting.append((qi, owner_qi))
                        continue
                    # Different variant, no cross-variant reuse: fresh
                    # banks over the owner's (query-independent)
                    # candidates; this query's store supersedes the
                    # owner's, so it becomes the template's new owner.
                    plans[qi] = HmoocPlan(
                        q.n_subqs, obj.d_c, obj.d_ps, self.cfg,
                        snap_c=obj.snap_c, snap_ps=obj.snap_ps,
                        effective_set=plans[owner_qi].eset.without_banks())
                    pending_eset[tk] = (qi, fp)
                    continue
                eset = self.cache.lookup(q, self.cfg, model, self.cost)
                plans[qi] = HmoocPlan(
                    q.n_subqs, obj.d_c, obj.d_ps, self.cfg,
                    snap_c=obj.snap_c, snap_ps=obj.snap_ps,
                    effective_set=eset)
                if not plans[qi].reused_banks:
                    pending_eset[tk] = (qi, fp)
            # -- lockstep rounds: one fused model call per solver phase ----
            while True:
                active = [qi for qi in solved
                          if qi in plans and not plans[qi].done]
                if not active and not waiting:
                    break
                items, spans = [], []
                for qi in active:
                    reqs = plans[qi].requests()
                    items.extend((objs[qi], i, Tc, Tps)
                                 for i, Tc, Tps in reqs)
                    spans.append((qi, len(reqs)))
                evals = fused_stage_eval(items)
                off = 0
                for qi, n in spans:
                    plans[qi].feed(evals[off:off + n])
                    off += n
                still = []
                for qi, owner_qi in waiting:
                    if plans[owner_qi].banks_ready:
                        plans[qi] = HmoocPlan(
                            queries[qi].n_subqs, objs[qi].d_c,
                            objs[qi].d_ps, self.cfg,
                            snap_c=objs[qi].snap_c,
                            snap_ps=objs[qi].snap_ps,
                            effective_set=plans[owner_qi].eset)
                    else:
                        still.append((qi, owner_qi))
                waiting = still
            # -- finalize in request order ---------------------------------
            for qi in solved:
                q, w = queries[qi], per_q_weights[qi]
                if qi in deferred_lookup:
                    # Stats-only replay of the lookup the sequential path
                    # would have issued here (after the owner's store).
                    self.cache.lookup(q, self.cfg, model, self.cost)
                plan = plans[qi]
                res = plan.result
                if not plan.reused_banks and res.effective_set is not None:
                    self.cache.store(q, self.cfg, res.effective_set, model,
                                     self.cost)
                ct = finish_result(q, objs[qi], res, w, t0s[qi])
                results[qi] = ct
                if self._results is not None:
                    self._results.put(keys[qi], ct)
        for qi, key in deferred_gets:
            results[qi] = self._results.get(key)
        return len(solved)

    def _tune_cheap(self, q: Query, w: Weights, exact_key: tuple
                    ) -> Tuple[CompileTimeResult, str]:
        """Budget-blown solve: cached template banks or the Spark defaults.

        Never builds fresh Algorithm 1 banks.  The caller has already
        missed the exact response cache for ``exact_key``; approximate
        results are stored under a degrade-marked variant of that key
        (exact bank reuse — matching fingerprint — is bit-identical to a
        full solve and stored under the exact key itself).  Degrade-marked
        entries carry their kind (:class:`_CheapEntry`) so a hit reports
        how the cached result was actually produced, not what this call's
        bank probe would have done — the two diverge whenever the
        effective-set cache evicted (or gained) the template between the
        store and the hit.
        """
        peeked = self.cache.peek(q, self.cfg, self._model, self.cost)
        if peeked is not None and peeked[1]:
            # Exact bank reuse is bit-identical to a full solve: share the
            # exact key with the full-quality path.
            if self._results is not None:
                hit = self._results.get(exact_key)
                if hit is not None:
                    return hit, "cheap"
            res = compile_time_optimize(
                q, model=self._model, weights=w, cfg=self.cfg,
                cost=self.cost, effective_set=peeked[0])
            if self._results is not None:
                self._results.put(exact_key, res)
            return res, "cheap"
        key = ("degraded",) + exact_key
        if self._results is not None:
            hit = self._results.get(key)
            if hit is not None:
                return hit.result, hit.kind
        if peeked is not None:
            res = compile_time_optimize(
                q, model=self._model, weights=w, cfg=self.cfg,
                cost=self.cost, effective_set=peeked[0])
            kind = "cheap"
        else:
            res = default_theta_result(q, model=self._model, cost=self.cost)
            kind = "default"
        if self._results is not None:
            self._results.put(key, _CheapEntry(res, kind))
        return res, kind


def tune_batch(
    queries: Sequence[Query],
    weights: Union[Weights, Sequence[Weights]] = (0.9, 0.1),
    cfg: HMOOCConfig = HMOOCConfig(),
    *,
    model: Optional[PerfModel] = None,
    cost: CostModel = DEFAULT_COST,
    cache: Optional[EffectiveSetCache] = None,
    dedupe: bool = True,
    jit_solve: Optional[bool] = None,
) -> List[CompileTimeResult]:
    """One-shot batched solve; see :class:`TuningService` for a server."""
    svc = TuningService(model=model, cfg=cfg, cost=cost, cache=cache,
                        dedupe=dedupe, jit_solve=jit_solve)
    return svc.tune_batch(queries, weights)


def _expand_weights(weights, n: int) -> List[Weights]:
    arr = np.asarray(weights, np.float64)
    if arr.ndim == 1:
        return [tuple(arr.tolist())] * n
    if arr.shape[0] != n:
        raise ValueError(
            f"got {arr.shape[0]} weight rows for {n} queries")
    return [tuple(row.tolist()) for row in arr]
