"""Batched tuning services (multi-query serving for both paper halves).

Compile time (§5.1):

* :func:`tune_batch` — solve the compile-time MOO for a batch of queries.
* :class:`TuningService` — long-lived server holding the effective-set
  cache so repeated-template traffic skips Algorithm 1.
* :class:`EffectiveSetCache` — the template-keyed cache itself.

Runtime (§5.2):

* :class:`RuntimeSession` — AQE-triggered θp/θs re-optimization of many
  concurrent queries through one fused, vectorized optimizer backend,
  seeded by the compile-time results.
"""
from .cache import EffectiveSetCache
from .runtime import CandidatePoolCache, RuntimeSession, RuntimeSessionStats
from .service import TuningService, tune_batch

__all__ = ["EffectiveSetCache", "TuningService", "tune_batch",
           "RuntimeSession", "RuntimeSessionStats", "CandidatePoolCache"]
