"""Batched compile-time tuning service (multi-query HMOOC serving).

Entry points:

* :func:`tune_batch` — solve the compile-time MOO for a batch of queries.
* :class:`TuningService` — long-lived server holding the effective-set
  cache so repeated-template traffic skips Algorithm 1.
* :class:`EffectiveSetCache` — the template-keyed cache itself.
"""
from .cache import EffectiveSetCache
from .service import TuningService, tune_batch

__all__ = ["EffectiveSetCache", "TuningService", "tune_batch"]
