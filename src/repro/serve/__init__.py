"""Batched tuning services (multi-query serving for both paper halves).

Compile time (§5.1):

* :func:`tune_batch` — solve the compile-time MOO for a batch of queries.
* :class:`TuningService` — long-lived server holding the effective-set
  cache so repeated-template traffic skips Algorithm 1.
* :class:`EffectiveSetCache` — the template-keyed cache itself.
* :class:`ResponseCache` — shareable exact result-dedup LRU.

Runtime (§5.2):

* :class:`RuntimeSession` — AQE-triggered θp/θs re-optimization of many
  concurrent queries through one fused, vectorized optimizer backend,
  seeded by the compile-time results.  Open entry set: ``admit`` /
  ``step_round`` / ``retire_ready`` / ``realize``.

Streaming (both halves unified):

* :class:`OptimizerServer` — streaming-admission serving loop: deadline-
  aware micro-batches through ``tune_batch``, AQE generators through one
  shared ``RuntimeSession``, late arrivals admitted mid-session.
* :class:`TenantScheduler` — multi-tenant admission accounting: per-tenant
  queues/deadline reserves, deficit-round-robin batch composition,
  priority tiers with overdue promotion (no starvation), and per-tenant
  SLO triage under overload (shed strict heads whose budget is
  unmeetable, flag degrade heads for the cheap compile path).
* Elastic capacity: :class:`ElasticPolicy`/:class:`ElasticController`
  autoscale the batch cap and arm preemptive degradation from a
  queue-delay forecast; :class:`TokenBucket` rate-limits per tenant
  ahead of the waiting room.

Fleet (multi-worker):

* :class:`OptimizerFleet` — N server replicas behind a consistent-hash
  template-affinity router (:class:`FleetRouter`/:class:`HashRing`) with
  a work-stealing fallback; per-tenant outputs stay bit-identical to the
  offline pipeline under any worker count and routing policy.
* :class:`CacheStore` — process-external snapshot store the serving
  caches ``snapshot()``/``restore()`` through (content-fingerprinted
  entries only), carrying cache warmth across workers and processes.
"""
from .admission import (Admit, ElasticController, ElasticPolicy,
                        TenantScheduler, TenantState, TokenBucket)
from .cache import CandidatePoolCache, EffectiveSetCache
from .fleet import (CacheStore, FleetRouter, FleetStats, HashRing,
                    OptimizerFleet, ROUTING_POLICIES, route_key)
from .runtime import RuntimeSession, RuntimeSessionStats
from .server import (REJECTED_STATUSES, OptimizerServer, ServedQuery,
                     ServerConfig, ServerStats, ServiceTimeModel,
                     jain_index)
from .service import ResponseCache, TuningService, tune_batch

__all__ = ["EffectiveSetCache", "TuningService", "tune_batch",
           "ResponseCache", "RuntimeSession", "RuntimeSessionStats",
           "CandidatePoolCache", "OptimizerServer", "ServerConfig",
           "ServedQuery", "ServerStats", "TenantScheduler", "TenantState",
           "Admit", "jain_index", "ElasticPolicy", "ElasticController",
           "TokenBucket", "ServiceTimeModel", "REJECTED_STATUSES",
           "OptimizerFleet", "FleetStats", "FleetRouter", "HashRing",
           "CacheStore", "route_key", "ROUTING_POLICIES"]
