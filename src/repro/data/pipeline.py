"""Synthetic tokenized data pipeline: deterministic, host-sharded.

Batches are a pure function of (seed, step, host) — the property the
elastic/straggler machinery relies on: any host can regenerate any shard,
and restarting from a checkpoint at step N reproduces the exact stream.
A Zipf-ish unigram token distribution gives non-degenerate loss curves.
"""
from __future__ import annotations

from typing import Any, Dict, Iterator, Optional

import numpy as np

from ..archs.common import ArchConfig

__all__ = ["make_batch", "data_iterator"]


def _token_block(rng: np.random.Generator, n: int, vocab: int) -> np.ndarray:
    # Zipf-like marginal over the vocab (clipped), cheap to sample.
    z = rng.zipf(1.3, size=n).astype(np.int64)
    return (z % vocab).astype(np.int32)


def make_batch(cfg: ArchConfig, *, global_batch: int, seq_len: int,
               step: int, seed: int = 0, host: int = 0, n_hosts: int = 1
               ) -> Dict[str, np.ndarray]:
    """This host's slice of the global batch for ``step``."""
    assert global_batch % n_hosts == 0
    b = global_batch // n_hosts
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step, host]))
    tokens = _token_block(rng, b * seq_len, cfg.vocab).reshape(b, seq_len)
    labels = np.roll(tokens, -1, axis=1).astype(np.int32)
    labels[:, -1] = -1                                   # mask final position
    batch: Dict[str, np.ndarray] = {"tokens": tokens, "labels": labels}
    if cfg.family == "vlm":
        batch["patches"] = rng.normal(
            0, 1, (b, cfg.n_patches, cfg.d_model)).astype(np.float32)
    if cfg.family == "audio":
        batch["patches"] = rng.normal(
            0, 1, (b, cfg.enc_seq, cfg.d_model)).astype(np.float32)
    return batch


def data_iterator(cfg: ArchConfig, *, global_batch: int, seq_len: int,
                  seed: int = 0, host: int = 0, n_hosts: int = 1,
                  start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield make_batch(cfg, global_batch=global_batch, seq_len=seq_len,
                         step=step, seed=seed, host=host, n_hosts=n_hosts)
        step += 1
