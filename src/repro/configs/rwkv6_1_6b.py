"""rwkv6-1.6b — Finch: attention-free, data-dependent decay linear attention.

[arXiv:2404.05892; unverified] 24L d_model=2048 d_ff=7168 vocab=65536.
O(1)-state decode → runs the long_500k shape natively.
"""
from repro.archs.common import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-1.6b", family="ssm", n_layers=24, d_model=2048,
        n_heads=32, n_kv=32, d_ff=7168, vocab=65536,
        rwkv_head_dim=64, supports_long=True)


def smoke_config() -> ArchConfig:
    return config().with_(n_layers=2, d_model=128, n_heads=2, n_kv=2,
                          d_ff=256, vocab=512, rwkv_head_dim=64)
