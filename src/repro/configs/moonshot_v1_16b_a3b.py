"""moonshot-v1-16b-a3b — MoE 64 experts top-6 (kimi/moonlight), MHA kv=16.

[hf:moonshotai/Moonlight-16B-A3B] 48L d_model=2048 16H d_ff=1408
vocab=163840.
"""
from repro.archs.common import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="moonshot-v1-16b-a3b", family="moe", n_layers=48, d_model=2048,
        n_heads=16, n_kv=16, d_ff=1408, vocab=163840,
        n_experts=64, top_k=6,
        train_accum=4)


def smoke_config() -> ArchConfig:
    return config().with_(n_layers=2, d_model=128, n_heads=4, n_kv=4,
                          d_head=32, d_ff=128, vocab=512, n_experts=8,
                          top_k=2)
