"""internvl2-76b — VLM: InternViT frontend STUBBED + InternLM2-like backbone.

[arXiv:2404.16821; unverified] 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256.  input_specs() provides precomputed patch embeddings
(n_patches=256) prepended to the token stream.
"""
from repro.archs.common import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="internvl2-76b", family="vlm", n_layers=80, d_model=8192,
        n_heads=64, n_kv=8, d_ff=28672, vocab=128256, n_patches=256,
        train_accum=4)


def smoke_config() -> ArchConfig:
    return config().with_(n_layers=2, d_model=128, n_heads=4, n_kv=2,
                          d_head=32, d_ff=256, vocab=512, n_patches=8)
