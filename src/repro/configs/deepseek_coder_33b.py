"""deepseek-coder-33b — dense, GQA kv=8, llama-arch.

[arXiv:2401.14196; hf] 62L d_model=7168 56H d_ff=19200 vocab=32256.
"""
from repro.archs.common import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-coder-33b", family="dense", n_layers=62, d_model=7168,
        n_heads=56, n_kv=8, d_ff=19200, vocab=32256,
        train_accum=4)


def smoke_config() -> ArchConfig:
    return config().with_(n_layers=2, d_model=128, n_heads=4, n_kv=2,
                          d_head=32, d_ff=256, vocab=512)
