"""dbrx-132b — MoE 16 experts top-4 (fine-grained), GQA kv=8.

[hf:databricks/dbrx-base; unverified] 40L d_model=6144 48H d_ff=10752
vocab=100352.
"""
from repro.archs.common import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="dbrx-132b", family="moe", n_layers=40, d_model=6144,
        n_heads=48, n_kv=8, d_ff=10752, vocab=100352,
        n_experts=16, top_k=4,
        train_accum=4)


def smoke_config() -> ArchConfig:
    return config().with_(n_layers=2, d_model=128, n_heads=4, n_kv=2,
                          d_head=32, d_ff=128, vocab=512, n_experts=4,
                          top_k=2)
