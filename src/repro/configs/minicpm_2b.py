"""minicpm-2b — dense, MHA (kv=36), WSD schedule, tied embeddings.

[arXiv:2404.06395; hf] 40L d_model=2304 36H d_ff=5760 vocab=122753.
"""
from repro.archs.common import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="minicpm-2b", family="dense", n_layers=40, d_model=2304,
        n_heads=36, n_kv=36, d_ff=5760, vocab=122753,
        tie_embeddings=True,
        train_accum=4)


def smoke_config() -> ArchConfig:
    return config().with_(n_layers=2, d_model=96, n_heads=4, n_kv=4,
                          d_head=24, d_ff=192, vocab=512)
