"""whisper-base — encoder–decoder; conv audio frontend STUBBED.

[arXiv:2212.04356; unverified] 6L(enc)+6L(dec) d_model=512 8H d_ff=2048
vocab=51865.  input_specs() provides precomputed 1500-frame embeddings.
The assigned decode/prefill seq lengths exceed the real model's 448-token
decoder cap; honored as stress shapes (see DESIGN.md §Arch-applicability).
"""
from repro.archs.common import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-base", family="audio", n_layers=6, d_model=512,
        n_heads=8, n_kv=8, d_ff=2048, vocab=51865,
        enc_layers=6, enc_seq=1500, cross_attention=True,
        decoder_only=False)


def smoke_config() -> ArchConfig:
    return config().with_(n_layers=2, enc_layers=2, d_model=64, n_heads=2,
                          n_kv=2, d_ff=128, vocab=512, enc_seq=16)
