"""qwen2-72b — dense, GQA kv=8, QKV bias.

[arXiv:2407.10671; hf] 80L d_model=8192 64H d_ff=29568 vocab=152064.
"""
from repro.archs.common import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-72b", family="dense", n_layers=80, d_model=8192,
        n_heads=64, n_kv=8, d_ff=29568, vocab=152064, qkv_bias=True,
        train_accum=4)


def smoke_config() -> ArchConfig:
    return config().with_(n_layers=2, d_model=128, n_heads=4, n_kv=2,
                          d_head=32, d_ff=256, vocab=512)
