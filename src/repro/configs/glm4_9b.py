"""glm4-9b — dense, RoPE, GQA kv=2.

[hf:THUDM/glm-4-9b] 40L d_model=4096 32H d_ff=13696 vocab=151552.
"""
from repro.archs.common import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="glm4-9b", family="dense", n_layers=40, d_model=4096,
        n_heads=32, n_kv=2, d_ff=13696, vocab=151552)


def smoke_config() -> ArchConfig:
    return config().with_(n_layers=2, d_model=128, n_heads=4, n_kv=1,
                          d_head=32, d_ff=256, vocab=512)
