"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887; hf] 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536.  Attention layers get a sliding window for the long_500k shape
(sub-quadratic requirement); Mamba carries the unbounded context.
"""
from repro.archs.common import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="jamba-1.5-large-398b", family="hybrid", n_layers=72,
        d_model=8192, n_heads=64, n_kv=8, d_ff=24576, vocab=65536,
        n_experts=16, top_k=2, attn_every=8, moe_every=2,
        d_state=16, d_conv=4, expand=2,
        moment_dtype="bfloat16",     # 398B: f32 moments would not fit HBM
        supports_long=True, window=4096,
        train_accum=4)


def smoke_config() -> ArchConfig:
    return config().with_(n_layers=8, attn_every=4, d_model=128, n_heads=4,
                          n_kv=2, d_head=32, d_ff=128, vocab=512,
                          n_experts=4, top_k=2, window=0,
                          moment_dtype="float32")
