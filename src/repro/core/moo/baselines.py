"""Baseline MOO solvers the paper compares against (§6.2).

* :func:`solve_ws`   — MO-WS: weighted sum over a random sample bank
  (10k samples, 11 evenly spaced weight pairs), the strongest query-level
  baseline in the paper's prior work [40].
* :func:`solve_evo`  — Evo: NSGA-II (population 100, 500 evaluations).
* :func:`solve_pf`   — Progressive Frontier [40]: recursive middle-point
  probing of constrained single-objective subproblems.
* :func:`solve_so_fw`— SO-FW: single-objective scalarization with *fixed*
  weights (returns exactly one configuration) — the common practical
  approach the paper shows is poorly adaptive.

All solvers minimize ``query_eval : (n, D) unit-cube rows -> (n, k)`` and
return (front, configs, solve_time, n_evals).
"""
from __future__ import annotations

import time
from typing import Callable, Optional, Tuple

import numpy as np

from .pareto import pareto_mask_np

__all__ = ["solve_ws", "solve_evo", "solve_pf", "solve_so_fw"]

QueryEval = Callable[[np.ndarray], np.ndarray]


def _lhs(rng: np.random.Generator, n: int, d: int) -> np.ndarray:
    u = (rng.permuted(np.tile(np.arange(n), (d, 1)), axis=1).T
         + rng.random((n, d))) / n
    return u


def _normalize(F: np.ndarray) -> np.ndarray:
    lo = F.min(0)
    hi = F.max(0)
    span = np.where(hi > lo, hi - lo, 1.0)
    return (F - lo) / span


# ---------------------------------------------------------------------------
# MO-WS
# ---------------------------------------------------------------------------

def solve_ws(query_eval: QueryEval, dims: int, *, n_samples: int = 10000,
             n_weights: int = 11, seed: int = 0,
             batch: int = 4096) -> Tuple[np.ndarray, np.ndarray, float, int]:
    """Weighted Sum: k-1 simplex of evenly spaced weights over a sample bank.

    Each weight vector yields one SO problem solved by exhaustive evaluation
    of the shared sample bank; the union of per-weight optima is returned
    (each is Pareto optimal, but coverage may collapse — paper Fig. 4).
    """
    t0 = time.perf_counter()
    rng = np.random.default_rng(seed)
    U = _lhs(rng, n_samples, dims)
    F = np.concatenate([query_eval(U[i:i + batch])
                        for i in range(0, n_samples, batch)], 0)
    Fn = _normalize(F)
    ws = np.linspace(0, 1, n_weights)
    picks = []
    for w in ws:
        picks.append(int(np.argmin(w * Fn[:, 0] + (1 - w) * Fn[:, 1])))
    picks = sorted(set(picks))
    Fp = F[picks]
    mask = pareto_mask_np(Fp)
    keep = np.nonzero(mask)[0]
    dt = time.perf_counter() - t0
    return Fp[keep], U[picks][keep], dt, n_samples


# ---------------------------------------------------------------------------
# Evo: NSGA-II
# ---------------------------------------------------------------------------

def _nd_sort(F: np.ndarray) -> np.ndarray:
    """Non-dominated rank per row (0 = first front)."""
    n = F.shape[0]
    rank = np.zeros(n, int)
    remaining = np.arange(n)
    r = 0
    while remaining.size:
        mask = pareto_mask_np(F[remaining])
        front = remaining[mask]
        rank[front] = r
        remaining = remaining[~mask]
        r += 1
    return rank


def _crowding(F: np.ndarray) -> np.ndarray:
    n, k = F.shape
    d = np.zeros(n)
    for j in range(k):
        order = np.argsort(F[:, j])
        span = F[order[-1], j] - F[order[0], j]
        d[order[0]] = d[order[-1]] = np.inf
        if span <= 0 or n < 3:
            continue
        d[order[1:-1]] += (F[order[2:], j] - F[order[:-2], j]) / span
    return d


def solve_evo(query_eval: QueryEval, dims: int, *, pop: int = 100,
              n_evals: int = 500, seed: int = 0,
              eta_c: float = 15.0, eta_m: float = 20.0
              ) -> Tuple[np.ndarray, np.ndarray, float, int]:
    """NSGA-II with SBX crossover + polynomial mutation."""
    t0 = time.perf_counter()
    rng = np.random.default_rng(seed)
    P = _lhs(rng, pop, dims)
    FP = query_eval(P)
    used = pop

    while used < n_evals:
        rank = _nd_sort(FP)
        crowd = _crowding(FP)

        def tourney() -> int:
            a, b = rng.integers(0, P.shape[0], 2)
            if rank[a] != rank[b]:
                return a if rank[a] < rank[b] else b
            return a if crowd[a] > crowd[b] else b

        n_child = min(pop, n_evals - used)
        children = np.empty((n_child, dims))
        for c in range(0, n_child, 2):
            p1, p2 = P[tourney()], P[tourney()]
            # SBX
            u = rng.random(dims)
            beta = np.where(u <= 0.5, (2 * u) ** (1 / (eta_c + 1)),
                            (1 / (2 * (1 - u))) ** (1 / (eta_c + 1)))
            c1 = 0.5 * ((1 + beta) * p1 + (1 - beta) * p2)
            c2 = 0.5 * ((1 - beta) * p1 + (1 + beta) * p2)
            # polynomial mutation (prob 1/d per gene)
            for child in (c1, c2):
                mm = rng.random(dims) < (1.0 / dims)
                if mm.any():
                    u2 = rng.random(mm.sum())
                    delta = np.where(
                        u2 < 0.5, (2 * u2) ** (1 / (eta_m + 1)) - 1,
                        1 - (2 * (1 - u2)) ** (1 / (eta_m + 1)))
                    child[mm] = child[mm] + delta
            children[c] = np.clip(c1, 0, 1)
            if c + 1 < n_child:
                children[c + 1] = np.clip(c2, 0, 1)
        FC = query_eval(children)
        used += n_child
        # Environmental selection on the union.
        P = np.concatenate([P, children], 0)
        FP = np.concatenate([FP, FC], 0)
        rank = _nd_sort(FP)
        crowd = _crowding(FP)
        order = np.lexsort((-crowd, rank))
        P, FP = P[order[:pop]], FP[order[:pop]]

    mask = pareto_mask_np(FP)
    dt = time.perf_counter() - t0
    return FP[mask], P[mask], dt, used


# ---------------------------------------------------------------------------
# Progressive Frontier (UDAO [40])
# ---------------------------------------------------------------------------

def _constrained_min(query_eval: QueryEval, dims: int, obj: int,
                     ub: np.ndarray, rng: np.random.Generator,
                     n_probe: int = 512,
                     bank: Optional[Tuple[np.ndarray, np.ndarray]] = None
                     ) -> Tuple[Optional[np.ndarray], Optional[np.ndarray], int]:
    """min f_obj subject to F <= ub, by sampling + local refinement."""
    U = _lhs(rng, n_probe, dims)
    F = query_eval(U)
    if bank is not None:
        U = np.concatenate([U, bank[0]], 0)
        F = np.concatenate([F, bank[1]], 0)
    ok = (F <= ub[None, :]).all(-1)
    if not ok.any():
        return None, None, n_probe
    i = int(np.argmin(np.where(ok, F[:, obj], np.inf)))
    # Local refinement around the incumbent.
    best_u, best_f = U[i], F[i]
    local = np.clip(best_u[None, :] +
                    rng.normal(0, 0.05, (64, dims)), 0, 1)
    FL = query_eval(local)
    okl = (FL <= ub[None, :]).all(-1)
    if okl.any():
        j = int(np.argmin(np.where(okl, FL[:, obj], np.inf)))
        if FL[j, obj] < best_f[obj]:
            best_u, best_f = local[j], FL[j]
    return best_u, best_f, n_probe + 64


def solve_pf(query_eval: QueryEval, dims: int, *, n_points: int = 9,
             seed: int = 0, n_probe: int = 512
             ) -> Tuple[np.ndarray, np.ndarray, float, int]:
    """Progressive Frontier: recursive middle-point constrained probes (k=2)."""
    t0 = time.perf_counter()
    rng = np.random.default_rng(seed)
    evals = 0
    # Utopia/nadir probes: unconstrained minima of each objective.
    big = np.array([np.inf, np.inf])
    sols = []
    bank_u = _lhs(rng, n_probe, dims)
    bank_f = query_eval(bank_u)
    evals += n_probe
    bank = (bank_u, bank_f)
    for obj in (0, 1):
        u, f, ne = _constrained_min(query_eval, dims, obj, big, rng,
                                    n_probe, bank)
        evals += ne
        if u is not None:
            sols.append((u, f))
    rects = []
    if len(sols) == 2:
        rects.append((sols[0][1], sols[1][1]))
    while len(sols) < n_points and rects:
        # Pop the rectangle with the largest area.
        areas = [abs((b[0] - a[0]) * (b[1] - a[1])) for a, b in rects]
        ridx = int(np.argmax(areas))
        fa, fb = rects.pop(ridx)
        mid = 0.5 * (np.asarray(fa) + np.asarray(fb))
        ub = np.array([max(fa[0], fb[0]), mid[1]])
        u, f, ne = _constrained_min(query_eval, dims, 0, ub, rng,
                                    n_probe // 2, bank)
        evals += ne
        if u is None:
            continue
        sols.append((u, f))
        rects.append((fa, f))
        rects.append((f, fb))
    F = np.stack([f for _, f in sols])
    U = np.stack([u for u, _ in sols])
    mask = pareto_mask_np(F)
    dt = time.perf_counter() - t0
    return F[mask], U[mask], dt, evals


# ---------------------------------------------------------------------------
# SO-FW
# ---------------------------------------------------------------------------

def solve_so_fw(query_eval: QueryEval, dims: int, weights: np.ndarray, *,
                n_samples: int = 3000, seed: int = 0
                ) -> Tuple[np.ndarray, np.ndarray, float, int]:
    """Fixed-weight scalarization returning a single configuration."""
    t0 = time.perf_counter()
    rng = np.random.default_rng(seed)
    U = _lhs(rng, n_samples, dims)
    F = query_eval(U)
    Fn = _normalize(F)
    w = np.asarray(weights, np.float64)
    i = int(np.argmin((Fn * w[None, :]).sum(-1)))
    dt = time.perf_counter() - t0
    return F[i:i + 1], U[i:i + 1], dt, n_samples
