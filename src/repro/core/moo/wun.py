"""Weighted Utopia Nearest (WUN) recommendation (paper §3.3.2, [40]).

Given a Pareto front and a user preference weight vector, normalize each
objective to [0, 1] over the front (utopia = per-objective min, nadir = max),
then return the point minimizing the weighted Euclidean distance to the
utopia point.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = ["wun_select"]


def wun_select(F: np.ndarray, weights: np.ndarray) -> Tuple[int, np.ndarray]:
    """Pick one Pareto point.

    Args:
      F: (n, k) Pareto-front objective values (minimization).
      weights: (k,) nonnegative preference weights (sum need not be 1).

    Returns:
      (index, objective row) of the recommended solution.
    """
    F = np.asarray(F, np.float64)
    w = np.asarray(weights, np.float64)
    if F.ndim != 2 or F.shape[0] == 0:
        raise ValueError("empty Pareto front")
    finite = np.isfinite(F).all(-1)
    if not finite.any():
        raise ValueError("no finite Pareto points")
    lo = F[finite].min(0)
    hi = F[finite].max(0)
    span = np.where(hi > lo, hi - lo, 1.0)
    Fn = (F - lo) / span  # utopia at the origin
    dist = np.sqrt(((w * Fn) ** 2).sum(-1))
    dist = np.where(finite, dist, np.inf)
    i = int(np.argmin(dist))
    return i, F[i]
