"""K-means clustering of θc candidates (HMOOC subQ-tuning, Algorithm 1 line 2).

Small, deterministic, dependency-free implementation.  Operates in the unit
hypercube, k-means++ seeding, fixed iteration count (jit-friendly shape-wise
but run host-side: candidate counts are a few hundred at most).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

__all__ = ["KMeans", "kmeans_fit"]


@dataclasses.dataclass
class KMeans:
    centers: np.ndarray  # (C, d)

    def assign(self, X: np.ndarray) -> np.ndarray:
        """(n, d) -> (n,) nearest-center labels."""
        d2 = ((X[:, None, :] - self.centers[None, :, :]) ** 2).sum(-1)
        return np.argmin(d2, axis=1)


def _kmeanspp_init(X: np.ndarray, k: int, rng: np.random.Generator) -> np.ndarray:
    n = X.shape[0]
    centers = [X[rng.integers(n)]]
    for _ in range(1, k):
        d2 = np.min(((X[:, None, :] - np.array(centers)[None]) ** 2).sum(-1), axis=1)
        tot = d2.sum()
        if tot <= 0:
            centers.append(X[rng.integers(n)])
            continue
        probs = d2 / tot
        centers.append(X[rng.choice(n, p=probs)])
    return np.array(centers)


def kmeans_fit(
    X: np.ndarray, k: int, rng: np.random.Generator, iters: int = 25
) -> Tuple[KMeans, np.ndarray]:
    """Fit k-means; returns (model, labels).  k is clipped to n distinct rows."""
    X = np.asarray(X, np.float64)
    n = X.shape[0]
    k = int(min(k, n))
    centers = _kmeanspp_init(X, k, rng)
    labels = np.zeros(n, int)
    for _ in range(iters):
        d2 = ((X[:, None, :] - centers[None, :, :]) ** 2).sum(-1)
        new_labels = np.argmin(d2, axis=1)
        if (new_labels == labels).all() and _ > 0:
            break
        labels = new_labels
        for c in range(k):
            m = labels == c
            if m.any():
                centers[c] = X[m].mean(0)
    return KMeans(centers=centers), labels
