"""Pareto-set primitives used throughout HMOOC.

All objective arrays are *minimization* problems of shape ``(n, k)``.
Padded / invalid entries are handled through explicit validity masks so the
solver can run with fixed shapes under ``jax.jit``.

Three implementations of dominance filtering are provided:

* :func:`pareto_mask` — chunked O(n^2 k) jnp implementation, O(n * chunk)
  memory, jit/vmap friendly.  The default inside jitted solver code.
* :func:`pareto_mask_np` — plain numpy, used host-side for small dynamic sets.
* ``repro.kernels.pareto_filter`` — Pallas TPU kernel with the same semantics
  (imported lazily in :func:`pareto_mask_fast` to avoid circular imports).

Also includes Kung's O(n log n) algorithm for k=2 (host-side oracle) and
hypervolume computation used by the benchmarks.
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "pareto_mask",
    "pareto_mask_np",
    "pareto_mask_fast",
    "kung_2d_np",
    "filter_dominated_np",
    "compact_bank",
    "hypervolume_2d",
    "hypervolume",
]

def backend() -> str:
    # Resolved lazily — jax.default_backend() initializes the XLA runtime,
    # which must not happen as an import side effect — and per *call*:
    # caching the answer (the pre-PR-7 lru_cache) froze routing at the
    # first use, so a backend initialized or overridden later was ignored.
    return jax.default_backend()


# Row count above which dominance masks route to the Pallas kernel.  On TPU
# the kernel wins early; on CPU hosts the interpret-mode kernel never beats
# the O(n log n) numpy sweep, so the default keeps the numpy path (and its
# float64 determinism) unless explicitly overridden.  None = resolve from
# the env var / backend per call (tests monkeypatch this directly).
_KERNEL_MIN_N = None


def _default_kernel_min_n() -> int:
    # Read per call, never cached: REPRO_PARETO_KERNEL_MIN_N flipped after
    # import (tests, operators re-tuning a live process) must take effect.
    return int(os.environ.get(
        "REPRO_PARETO_KERNEL_MIN_N",
        "512" if backend() == "tpu" else str(1 << 30)))


# ---------------------------------------------------------------------------
# jnp implementations
# ---------------------------------------------------------------------------

def _dominates_block(Fj: jnp.ndarray, Fi: jnp.ndarray, vj: jnp.ndarray) -> jnp.ndarray:
    """dom[i] |= exists j in block with F[j] <= F[i] (all) and < in one.

    Fj: (c, k) candidate dominators, Fi: (n, k), vj: (c,) validity of block.
    Returns (n,) bool.
    """
    le = (Fj[:, None, :] <= Fi[None, :, :]).all(-1)  # (c, n)
    lt = (Fj[:, None, :] < Fi[None, :, :]).any(-1)   # (c, n)
    return ((le & lt) & vj[:, None]).any(0)


@functools.partial(jax.jit, static_argnames=("chunk",))
def pareto_mask(
    F: jnp.ndarray,
    valid: Optional[jnp.ndarray] = None,
    chunk: int = 256,
) -> jnp.ndarray:
    """Boolean mask of Pareto-optimal (non-dominated) rows of ``F``.

    Args:
      F: (n, k) objective values, minimization.  ``inf`` rows are never optimal.
      valid: optional (n,) bool; invalid rows are neither optimal nor dominate.
      chunk: j-block size; memory is O(n * chunk).
    """
    n, _ = F.shape
    if valid is None:
        valid = jnp.isfinite(F).all(-1)
    else:
        valid = valid & jnp.isfinite(F).all(-1)
    # Pad to a multiple of chunk.
    pad = (-n) % chunk
    Fp = jnp.pad(F, ((0, pad), (0, 0)), constant_values=jnp.inf)
    vp = jnp.pad(valid, (0, pad), constant_values=False)
    nblocks = Fp.shape[0] // chunk

    def body(b, dom):
        Fj = jax.lax.dynamic_slice_in_dim(Fp, b * chunk, chunk, 0)
        vj = jax.lax.dynamic_slice_in_dim(vp, b * chunk, chunk, 0)
        return dom | _dominates_block(Fj, F, vj)

    dom = jax.lax.fori_loop(0, nblocks, body, jnp.zeros((n,), bool))
    return valid & ~dom


def compact_bank(
    F: jnp.ndarray,
    mask: jnp.ndarray,
    p: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Gather up to ``p`` masked rows of ``F`` to the front, padding with +inf.

    Returns (Fout (p, k), valid (p,), idx (p,)) where idx are source row
    indices (arbitrary for padded slots).  Jit-safe (fixed output shape).
    If more than ``p`` rows are selected the surplus is dropped in index order.
    """
    n, k = F.shape
    order = jnp.argsort(~mask, stable=True)  # non-dominated first
    idx = order[:p]
    take_valid = mask[idx]
    Fout = jnp.where(take_valid[:, None], F[idx], jnp.inf)
    return Fout, take_valid, idx


# ---------------------------------------------------------------------------
# numpy implementations (host-side, dynamic shapes)
# ---------------------------------------------------------------------------

def pareto_mask_np(F: np.ndarray, valid: Optional[np.ndarray] = None) -> np.ndarray:
    """Numpy dominance mask; O(n log n) sweep for k=2, O(n² k) otherwise."""
    F = np.asarray(F, dtype=np.float64)
    n = F.shape[0]
    if valid is None:
        valid = np.isfinite(F).all(-1)
    else:
        valid = np.asarray(valid, bool) & np.isfinite(F).all(-1)
    if n == 0:
        return valid
    if F.shape[1] == 2 and n > 64:
        return _pareto_mask_2d_np(F, valid)
    le = (F[:, None, :] <= F[None, :, :]).all(-1)
    lt = (F[:, None, :] < F[None, :, :]).any(-1)
    dom = ((le & lt) & valid[:, None]).any(0)
    return valid & ~dom


def _pareto_mask_2d_np(F: np.ndarray, valid: np.ndarray) -> np.ndarray:
    """O(n log n) two-objective dominance mask (duplicate optima survive)."""
    n = F.shape[0]
    mask = np.zeros(n, bool)
    idx = np.nonzero(valid)[0]
    if idx.size == 0:
        return mask
    order = idx[np.lexsort((F[idx, 1], F[idx, 0]))]
    f0 = F[order, 0]
    f1 = F[order, 1]
    # Group by distinct f0; group minimum of f1 (within-group dominance).
    new_grp = np.empty(order.size, bool)
    new_grp[0] = True
    new_grp[1:] = f0[1:] != f0[:-1]
    grp = np.cumsum(new_grp) - 1
    n_grp = grp[-1] + 1
    grp_min = np.full(n_grp, np.inf)
    np.minimum.at(grp_min, grp, f1)
    # Running strict-prefix min of f1 over earlier (strictly smaller f0) groups.
    prev_best = np.empty(n_grp)
    prev_best[0] = np.inf
    if n_grp > 1:
        prev_best[1:] = np.minimum.accumulate(grp_min)[:-1]
    keep = (f1 == grp_min[grp]) & (f1 < prev_best[grp])
    mask[order[keep]] = True
    return mask


def pareto_mask_fast(F: np.ndarray,
                     valid: Optional[np.ndarray] = None) -> np.ndarray:
    """Dominance mask dispatcher: Pallas kernel for large n, numpy below.

    Same semantics as :func:`pareto_mask_np`.  Rows are bucket-padded to a
    power of two before hitting the jitted kernel so the compile cache sees
    only O(log n) distinct shapes across a serving session.  The kernel
    compares in float32; the numpy fallback keeps float64 — callers that
    need bit-stable fronts on CPU get them by default (see ``_KERNEL_MIN_N``).

    Routing is tie-tolerant: when any objective column holds values that
    are distinct in float64 but collide after the kernel's float32 cast,
    the dominance relation itself would change under the cast (a strictly
    dominated point can tie its dominator and survive), so such inputs
    take the float64 numpy path regardless of size.  This keeps the mask a
    pure function of the input values rather than of the backend the batch
    happened to route to.
    """
    F = np.asarray(F, np.float64)
    n = F.shape[0]
    thr = _KERNEL_MIN_N if _KERNEL_MIN_N is not None \
        else _default_kernel_min_n()
    if n < thr or n == 0:
        return pareto_mask_np(F, valid)
    if _f32_tie_hazard(F):
        return pareto_mask_np(F, valid)
    return _pareto_mask_kernel(F, valid)


def _f32_tie_hazard(F: np.ndarray) -> bool:
    """True if float64-distinct values in some column tie as float32."""
    for j in range(F.shape[1]):
        col = F[:, j]
        u = np.unique(col[np.isfinite(col)])
        if np.unique(u.astype(np.float32)).size < u.size:
            return True
    return False


def _pareto_mask_kernel(F: np.ndarray,
                        valid: Optional[np.ndarray] = None) -> np.ndarray:
    from ...kernels.pareto_filter import pareto_filter  # lazy: optional layer
    n, k = F.shape
    if valid is None:
        v = np.isfinite(F).all(-1)
    else:
        v = np.asarray(valid, bool) & np.isfinite(F).all(-1)
    bucket = max(128, 1 << int(np.ceil(np.log2(max(n, 2)))))
    Fp = np.full((bucket, k), np.inf)
    Fp[:n] = np.where(np.isfinite(F), F, np.inf)
    vp = np.zeros(bucket, bool)
    vp[:n] = v
    mask = np.asarray(pareto_filter(jnp.asarray(Fp, jnp.float32),
                                    jnp.asarray(vp)))
    return mask[:n]


def kung_2d_np(F: np.ndarray) -> np.ndarray:
    """Kung's O(n log n) Pareto mask for k=2 minimization (numpy, oracle)."""
    F = np.asarray(F, dtype=np.float64)
    n = F.shape[0]
    mask = np.zeros(n, bool)
    finite = np.isfinite(F).all(-1)
    idx = np.nonzero(finite)[0]
    if idx.size == 0:
        return mask
    # sort by (f0 asc, f1 asc); sweep keeping running min of f1
    order = idx[np.lexsort((F[idx, 1], F[idx, 0]))]
    best = np.inf
    for i in order:
        if F[i, 1] < best:
            mask[i] = True
            best = F[i, 1]
    # Equal points: the sweep keeps the first of duplicates only, which is a
    # valid Pareto subset; mark exact duplicates of kept points as optimal too.
    kept = F[mask]
    for i in idx:
        if not mask[i] and kept.size and (kept == F[i]).all(-1).any():
            mask[i] = True
    return mask


def filter_dominated_np(
    F: np.ndarray, payload: Optional[np.ndarray] = None
) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    """Return the non-dominated subset of F (and aligned payload rows)."""
    m = pareto_mask_np(F)
    if payload is None:
        return F[m], None
    return F[m], payload[m]


# ---------------------------------------------------------------------------
# Hypervolume (benchmark metric; paper's HV)
# ---------------------------------------------------------------------------

def hypervolume_2d(F: np.ndarray, ref: np.ndarray) -> float:
    """Exact 2-objective hypervolume dominated by F w.r.t. reference point.

    Points not dominating ``ref`` contribute nothing.
    """
    F = np.asarray(F, np.float64)
    ref = np.asarray(ref, np.float64)
    if F.size == 0:
        return 0.0
    F = F[np.isfinite(F).all(-1)]
    F = F[(F < ref).all(-1)]
    if F.shape[0] == 0:
        return 0.0
    m = pareto_mask_np(F)
    P = np.unique(F[m], axis=0)  # sorted by f0 asc then f1 asc
    hv = 0.0
    prev_f1 = ref[1]
    for f0, f1 in P:
        if f1 < prev_f1:
            hv += (ref[0] - f0) * (prev_f1 - f1)
            prev_f1 = f1
    return float(hv)


def hypervolume(F: np.ndarray, ref: np.ndarray, n_mc: int = 200_000, seed: int = 0) -> float:
    """Hypervolume for k objectives: exact for k=2, Monte-Carlo otherwise."""
    F = np.asarray(F, np.float64)
    ref = np.asarray(ref, np.float64)
    if F.shape[-1] == 2:
        return hypervolume_2d(F, ref)
    F = F[np.isfinite(F).all(-1)]
    F = F[(F < ref).all(-1)]
    if F.shape[0] == 0:
        return 0.0
    lo = F.min(0)
    rng = np.random.default_rng(seed)
    pts = rng.uniform(lo, ref, size=(n_mc, F.shape[1]))
    dominated = np.zeros(n_mc, bool)
    for f in F:
        dominated |= (pts >= f).all(-1)
    box = np.prod(ref - lo)
    return float(box * dominated.mean())
