"""Hierarchical MOO with Constraints (paper §5.1, Algorithms 1–4).

Solves the compile-time fine-grained tuning problem

    argmin_{θc, {θp_i}, {θs_i}}  [ Σ_i φ_1(subQ_i, θc, θp_i, θs_i),
                                   Σ_i φ_2(subQ_i, θc, θp_i, θs_i) ]

by (1) *subQ tuning* — Algorithm 1's effective-set generation with θc
clustering, per-representative θp MOO over a shared sample pool, optimal-θp
assignment to cluster members, and crossover-based θc enrichment — and
(2) *DAG aggregation* — HMOOC1 (exact divide-and-conquer Minkowski merge),
HMOOC2 (weighted-sum over functions), HMOOC3 (boundary/extreme-point
approximation), exploiting that analytical latency and cost are sums over
subQs so the DAG reduces to a list.

The stage evaluator abstracts the objective model:

    stage_eval(i, Tc, Tps) -> (n, k) objective rows for subQ i,
        Tc: (n, d_c) unit-space θc, Tps: (n, d_p + d_s) unit-space θp⊕θs.

In production it wraps the trained subQ PerfModel; tests can plug the
analytic simulator or synthetic functions.

Hot paths are array-level: every stage_eval call covers a whole
representative set or candidate population at once (m calls per phase
instead of C·m), dominance masks route through the Pallas ``pareto_filter``
kernel above the small-n threshold (``pareto_mask_fast``), and HMOOC2's
per-weight bank argmin runs on the ``ws_reduce`` kernel when enabled.

The candidate-sampling half of Algorithm 1 (LHS, clustering, crossover) is
query-independent; :class:`EffectiveSet` captures it — together with the
per-representative optimal-θp banks — so a serving layer can reuse it across
repeated-template traffic (see ``repro.serve``).
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .clustering import kmeans_fit
from . import pareto as _pareto
from .pareto import _f32_tie_hazard, pareto_mask_fast, pareto_mask_np

__all__ = ["HMOOCConfig", "HMOOCResult", "EffectiveSet", "hmooc_solve",
           "HmoocPlan", "subq_tuning", "build_candidates", "dag_aggregate",
           "minkowski_merge_2d"]

StageEval = Callable[[int, np.ndarray, np.ndarray], np.ndarray]

# Score-matrix volume (N·m·B·nw) above which HMOOC2 uses the ws_reduce
# Pallas kernel.  CPU hosts default to the float64 numpy einsum (exact and
# faster than interpret mode); TPU routes to the MXU kernel.  None =
# resolve lazily from the env var / backend (tests monkeypatch directly).
_WS_MIN_SCORES = None


def _ws_min_scores() -> int:
    if _WS_MIN_SCORES is not None:
        return _WS_MIN_SCORES
    return int(os.environ.get(
        "REPRO_WS_KERNEL_MIN_SCORES",
        str(1 << 18) if _pareto.backend() == "tpu" else str(1 << 60)))


@dataclasses.dataclass(frozen=True)
class HMOOCConfig:
    n_c_init: int = 64          # initial θc candidates (LHS)
    n_clusters: int = 10        # θc clusters (Alg. 1 line 2)
    n_p_pool: int = 256         # shared θp⊕θs sample pool size
    n_c_enrich: int = 64        # crossover-generated θc candidates
    max_bank: int = 48          # per-(θc, subQ) Pareto bank cap
    dag_method: str = "hmooc3"  # "hmooc1" | "hmooc2" | "hmooc3"
    n_ws_weights: int = 11      # weight vectors for hmooc2
    seed: int = 0


@dataclasses.dataclass
class EffectiveSet:
    """Reusable Algorithm 1 artifacts.

    ``Uc``/``labels``/``reps``/``pool`` depend only on the parameter spaces
    and :class:`HMOOCConfig` (the rng never touches the query), so they are
    valid for *any* query.  ``opt_idx`` (per-representative per-subQ
    Pareto-optimal pool indices) is computed from one query's statistics;
    reusing it is exact for an identical query and a template-level
    approximation otherwise.
    """
    Uc: np.ndarray                                 # (N, d_c) θc candidates
    labels: np.ndarray                             # (N,) cluster ids
    reps: np.ndarray                               # (C, d_c) representatives
    pool: np.ndarray                               # (P, d_ps) θp⊕θs samples
    opt_idx: Optional[List[List[np.ndarray]]] = None   # [C][m] pool indices
    k_obj: int = 2

    def without_banks(self) -> "EffectiveSet":
        return dataclasses.replace(self, opt_idx=None)


@dataclasses.dataclass
class HMOOCResult:
    front: np.ndarray           # (q, k) query-level Pareto objective values
    theta_c: np.ndarray         # (q, d_c) unit
    theta_ps: np.ndarray        # (q, m, d_ps) unit per-subQ θp⊕θs
    solve_time: float
    n_evals: int
    extras: Dict[str, float]
    effective_set: Optional[EffectiveSet] = None


# ---------------------------------------------------------------------------
# Subquery tuning (Algorithm 1)
# ---------------------------------------------------------------------------

def _snap_unique(U: np.ndarray, snap) -> np.ndarray:
    Us = snap(U) if snap is not None else U
    return np.unique(np.round(Us, 9), axis=0)


def _crossover(Uc: np.ndarray, n_new: int, d: int,
               rng: np.random.Generator) -> np.ndarray:
    """θc crossover (App. C.1): random cut + Cartesian-product recombination."""
    if Uc.shape[0] < 2:
        return np.zeros((0, d))
    out = []
    for _ in range(4):  # a few cut positions
        cut = int(rng.integers(1, d))
        pre = np.unique(Uc[:, :cut], axis=0)
        suf = np.unique(Uc[:, cut:], axis=0)
        ii = rng.integers(0, pre.shape[0], size=n_new)
        jj = rng.integers(0, suf.shape[0], size=n_new)
        out.append(np.concatenate([pre[ii], suf[jj]], axis=1))
    cand = np.unique(np.concatenate(out, 0), axis=0)
    rng.shuffle(cand)
    return cand[:n_new]


def _pareto_bank(F: np.ndarray, cap: int) -> np.ndarray:
    """Indices of the non-dominated rows of F (capped, best-first)."""
    mask = pareto_mask_fast(F)
    idx = np.nonzero(mask)[0]
    if idx.size > cap:
        # Keep a spread: sort by first objective, take evenly spaced.
        order = idx[np.argsort(F[idx, 0])]
        keep = np.linspace(0, order.size - 1, cap).round().astype(int)
        idx = order[keep]
    return idx


def _lhs(rng: np.random.Generator, n: int, d: int) -> np.ndarray:
    u = (rng.permuted(np.tile(np.arange(n), (d, 1)), axis=1).T
         + rng.random((n, d))) / n
    return u


def build_candidates(
    d_c: int,
    d_ps: int,
    cfg: HMOOCConfig,
    *,
    snap_c=None,
    snap_ps=None,
    rng: Optional[np.random.Generator] = None,
) -> EffectiveSet:
    """Query-independent half of Algorithm 1: θc candidates + θp⊕θs pool.

    Covers lines 1–2 plus the crossover enrichment of lines 5–6 (the rng
    stream is never consumed by stage evaluation, so sampling the enriched
    set up front is identical to interleaving it with the evaluations).
    """
    rng = rng or np.random.default_rng(cfg.seed)
    # Line 1: init_c (LHS over the unit cube, snapped to valid raw values).
    Uc0 = _lhs(rng, cfg.n_c_init, d_c)
    Uc0 = _snap_unique(Uc0, snap_c)
    # Line 2: cluster.
    km, labels0 = kmeans_fit(Uc0, cfg.n_clusters, rng)
    reps = km.centers
    if snap_c is not None:
        reps = snap_c(reps)
    # Shared θp⊕θs pool.
    pool = _lhs(rng, cfg.n_p_pool, d_ps)
    if snap_ps is not None:
        pool = snap_ps(pool)
    # Lines 5-6: enrich via crossover, assign to existing clusters.
    Uc1 = _crossover(Uc0, cfg.n_c_enrich, d_c, rng)
    if snap_c is not None and Uc1.size:
        Uc1 = _snap_unique(Uc1, snap_c)
    if Uc1.size:
        # Drop duplicates of the initial set.
        dup = (Uc1[:, None, :] == Uc0[None, :, :]).all(-1).any(1)
        Uc1 = Uc1[~dup]
    if Uc1.size:
        labels1 = km.assign(Uc1)
        Uc = np.concatenate([Uc0, Uc1], 0)
        labels = np.concatenate([labels0, labels1], 0)
    else:
        Uc, labels = Uc0, labels0
    return EffectiveSet(Uc=Uc, labels=labels, reps=reps, pool=pool)


def _rep_bank_requests(m: int, eset: EffectiveSet
                       ) -> List[Tuple[int, np.ndarray, np.ndarray]]:
    """The stage-eval rows of the representative-MOO phase, per subQ."""
    reps, pool = eset.reps, eset.pool
    C, P = reps.shape[0], pool.shape[0]
    Tc = np.repeat(reps, P, axis=0)
    Tp = np.tile(pool, (C, 1))
    return [(i, Tc, Tp) for i in range(m)]


def _optimize_rep_banks(
    stage_eval: StageEval,
    m: int,
    eset: EffectiveSet,
    cfg: HMOOCConfig,
) -> Tuple[List[List[np.ndarray]], int, int]:
    """Line 3: per-representative θp MOO, batched to one eval per subQ.

    Returns (opt_idx [C][m], k_obj, n_evals).
    """
    C, P = eset.reps.shape[0], eset.pool.shape[0]
    opt_idx: List[List[np.ndarray]] = [[] for _ in range(C)]
    k_obj = 2
    n_evals = 0
    for i, Tc, Tp in _rep_bank_requests(m, eset):
        F = stage_eval(i, Tc, Tp)
        n_evals += F.shape[0]
        k_obj = F.shape[1]
        Fr = F.reshape(C, P, k_obj)
        for r in range(C):
            opt_idx[r].append(_pareto_bank(Fr[r], cfg.max_bank))
    return opt_idx, k_obj, n_evals


def _assign_requests(m: int, eset: EffectiveSet, cfg: HMOOCConfig) -> List[
        Optional[Tuple[np.ndarray, np.ndarray,
                       List[Tuple[np.ndarray, np.ndarray]]]]]:
    """Per-subQ (θc rows, θp⊕θs rows, scatter chunks) of the assign phase.

    Entry i is None when subQ i has nothing to evaluate (no members or all
    banks empty).  Deterministic in ``eset``: rebuilding the requests for
    the same effective set yields the same rows, which is what lets a batch
    driver evaluate them externally and replay the results into
    :func:`_assign_banks`.
    """
    Uc, labels, pool = eset.Uc, eset.labels, eset.pool
    opt_idx = eset.opt_idx
    assert opt_idx is not None
    C = eset.reps.shape[0]
    B = cfg.max_bank
    members_by_rep = [np.nonzero(labels == r)[0] for r in range(C)]
    out = []
    for i in range(m):
        rows_c: List[np.ndarray] = []
        rows_p: List[np.ndarray] = []
        chunks: List[Tuple[np.ndarray, np.ndarray]] = []
        for r in range(C):
            members = members_by_rep[r]
            sel = opt_idx[r][i] if i < len(opt_idx[r]) else np.zeros(0, int)
            if members.size == 0 or sel.size == 0:
                continue
            sel = sel[:min(sel.size, B)]
            rows_c.append(np.repeat(members, sel.size))
            rows_p.append(np.tile(sel, members.size))
            chunks.append((members, sel))
        if not chunks:
            out.append(None)
            continue
        out.append((Uc[np.concatenate(rows_c)],
                    pool[np.concatenate(rows_p)], chunks))
    return out


def _assign_banks(
    stage_eval: StageEval,
    m: int,
    eset: EffectiveSet,
    cfg: HMOOCConfig,
    k_obj: int,
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Lines 4/7: evaluate members against their rep's optimal θp sets.

    One stage_eval per subQ covering every (member, bank slot) pair at once.
    """
    N, B = eset.Uc.shape[0], cfg.max_bank
    F_bank = np.full((N, m, B, k_obj), np.inf)
    idx_bank = np.full((N, m, B), -1, int)
    n_evals = 0
    for i, req in enumerate(_assign_requests(m, eset, cfg)):
        if req is None:
            continue
        Tc_rows, Tp_rows, chunks = req
        F = stage_eval(i, Tc_rows, Tp_rows)
        n_evals += F.shape[0]
        off = 0
        for members, sel in chunks:
            nb = sel.size
            cnt = members.size * nb
            F_bank[members, i, :nb] = \
                F[off:off + cnt].reshape(members.size, nb, k_obj)
            idx_bank[members, i, :nb] = sel
            off += cnt
    return F_bank, idx_bank, n_evals


def subq_tuning(
    stage_eval: StageEval,
    m: int,
    d_c: int,
    d_ps: int,
    cfg: HMOOCConfig,
    *,
    snap_c=None,
    snap_ps=None,
    rng: Optional[np.random.Generator] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, int]:
    """Effective-set generation (Algorithm 1).

    Returns (Uc, pool, F_bank, idx_bank, n_evals) where
      Uc: (N, d_c) θc candidates,
      pool: (P, d_ps) shared θp⊕θs samples,
      F_bank: (N, m, B, k) objective values (+inf padded),
      idx_bank: (N, m, B) pool indices (−1 padded).
    """
    eset = build_candidates(d_c, d_ps, cfg, snap_c=snap_c, snap_ps=snap_ps,
                            rng=rng)
    opt_idx, k_obj, n1 = _optimize_rep_banks(stage_eval, m, eset, cfg)
    eset.opt_idx, eset.k_obj = opt_idx, k_obj
    F_bank, idx_bank, n2 = _assign_banks(stage_eval, m, eset, cfg, k_obj)
    return eset.Uc, eset.pool, F_bank, idx_bank, n1 + n2


# ---------------------------------------------------------------------------
# DAG aggregation (paper §5.1.2, Appendix B)
# ---------------------------------------------------------------------------

def minkowski_merge_2d(F1: np.ndarray, S1: np.ndarray,
                       F2: np.ndarray, S2: np.ndarray
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Pf(Pf(F)⊕Pf(G)) — enumerate sums, keep non-dominated (Alg. 3).

    S1/S2 are (n, m) per-subQ pool-index selections (−1 = unset); merged
    entries take whichever side set each subQ.
    """
    n1, n2 = F1.shape[0], F2.shape[0]
    F = (F1[:, None, :] + F2[None, :, :]).reshape(n1 * n2, -1)
    mask = pareto_mask_fast(F)
    keep = np.nonzero(mask)[0]
    i1, i2 = keep // n2, keep % n2
    sel = np.where(S1[i1] >= 0, S1[i1], S2[i2])
    return F[keep], sel


def _hmooc1_fixed_c(Fb: np.ndarray, Ib: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Exact divide-and-conquer aggregation under one θc (Alg. 2).

    Returns (front (q, k), sel (q, m)) with ``sel[:, i]`` the pool index
    chosen for subQ i.
    """
    m = Fb.shape[0]
    nodes = []
    for i in range(m):
        valid = np.isfinite(Fb[i]).all(-1)
        # Only local Pareto points can contribute (Prop. 5.1).
        valid &= pareto_mask_np(Fb[i], valid)
        idx = np.nonzero(valid)[0]
        if idx.size == 0:
            return np.zeros((0, Fb.shape[-1])), np.zeros((0, m), int)
        F = Fb[i][idx]
        sel = np.full((idx.size, m), -1, int)
        sel[:, i] = Ib[i][idx]
        nodes.append((F, sel))
    while len(nodes) > 1:
        nxt = []
        for a in range(0, len(nodes) - 1, 2):
            F, S = minkowski_merge_2d(nodes[a][0], nodes[a][1],
                                      nodes[a + 1][0], nodes[a + 1][1])
            nxt.append((F, S))
        if len(nodes) % 2:
            nxt.append(nodes[-1])
        nodes = nxt
    return nodes[0]


def _ws_pick(Fn: np.ndarray, W: np.ndarray) -> np.ndarray:
    """argmin_b  W[w] · Fn[c, i, b]  →  (nw, N, m) int.

    Routes through the ws_reduce Pallas kernel (one MXU matmul per bank)
    above the score-volume threshold; otherwise a float64 numpy einsum that
    reproduces the reference arithmetic bit-for-bit.

    Routing is tie-tolerant, like ``pareto_mask_fast``: when any objective
    column of ``Fn`` holds values that are distinct in float64 but collide
    after the kernel's float32 cast, the weighted argmin itself could flip
    under the cast, so such inputs take the float64 einsum regardless of
    volume.  (Conservative input-level check — it catches the cast-
    collision class; sums that tie only after f32 accumulation remain the
    kernel regime's documented f32 semantics.)
    """
    N, m, B, k = Fn.shape
    nw = W.shape[0]
    if N * m * B * nw >= _ws_min_scores() \
            and not _f32_tie_hazard(Fn.reshape(-1, k)):
        from ...kernels.ws_reduce import ws_reduce  # lazy: optional layer
        _, idx = ws_reduce(Fn.reshape(N * m, B, k), W)   # (nw, N*m)
        return np.asarray(idx, int).reshape(nw, N, m)
    scores = np.einsum("wk,cibk->wcib", W, Fn)           # (nw, N, m, B)
    return np.argmin(scores, axis=-1)


def _ws_weights(n_weights: int) -> np.ndarray:
    ws = np.linspace(0.0, 1.0, n_weights)
    return np.stack([ws, 1.0 - ws], axis=1)              # (nw, 2)


def _hmooc2_normalize(F_bank: np.ndarray) -> np.ndarray:
    # Normalize per OBJECTIVE over each candidate's whole bank (one affine
    # transform shared by every subQ).  The paper's Alg. 4 normalizes per
    # subQ, but per-subQ scales give each subQ different effective weights
    # and void Lemma 1's guarantee that each WS pick is query-level Pareto
    # optimal (hypothesis-tested in tests/test_hmooc.py); a shared affine
    # transform commutes with the sum aggregator and preserves the proof.
    finite = np.isfinite(F_bank)
    lo = np.min(np.where(finite, F_bank, np.inf), axis=(1, 2), keepdims=True)
    hi = np.max(np.where(finite, F_bank, -np.inf), axis=(1, 2), keepdims=True)
    span = np.where(hi > lo, hi - lo, 1.0)
    with np.errstate(invalid="ignore"):
        Fn = (F_bank - lo) / span
    return np.where(finite, Fn, 1e18)


def _hmooc2_all(F_bank: np.ndarray, idx_bank: np.ndarray, n_weights: int
                ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """WS-over-functions aggregation (Alg. 4), batched over θc candidates.

    Returns per-candidate (front (q, k), sel (q, m)) pairs.
    """
    N, m, B, k = F_bank.shape
    assert k == 2
    W = _ws_weights(n_weights)
    Fn = _hmooc2_normalize(F_bank)
    j = _ws_pick(Fn, W)                                  # (nw, N, m)
    jj = np.transpose(j, (1, 0, 2))                      # (N, nw, m)
    cc = np.arange(N)[:, None, None]
    ii = np.arange(m)[None, None, :]
    G = F_bank[cc, ii, jj]                               # (N, nw, m, k)
    S = idx_bank[cc, ii, jj]                             # (N, nw, m)
    ok = np.isfinite(G).all(axis=(2, 3))                 # (N, nw)
    P_all = G.sum(axis=2)                                # (N, nw, k)
    out: List[Tuple[np.ndarray, np.ndarray]] = []
    for c in range(N):
        rows = np.nonzero(ok[c])[0]
        if rows.size == 0:
            out.append((np.zeros((0, k)), np.zeros((0, m), int)))
            continue
        P = P_all[c, rows]
        mask = pareto_mask_fast(P)
        keep = np.nonzero(mask)[0]
        out.append((P[keep], S[c, rows][keep]))
    return out


def _hmooc2_fixed_c(Fb: np.ndarray, Ib: np.ndarray, n_weights: int
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """WS-over-functions aggregation under one θc (Alg. 4)."""
    return _hmooc2_all(Fb[None], Ib[None], n_weights)[0]


def _hmooc2_all_fused(Uc: np.ndarray, pool: np.ndarray, F_bank: np.ndarray,
                      idx_bank: np.ndarray, n_weights: int
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Kernel-regime HMOOC2: the whole aggregation in one compiled solve.

    Composes the ``ws_reduce`` picks, the objective-sum gather, the
    per-candidate dominance mask and the final global Pareto filter under a
    single jit (``repro.kernels.fused_solve``) instead of bouncing
    intermediate banks between host and device per candidate.  Returns the
    already-globally-filtered (front, theta_c, theta_ps) in the same row
    order the per-candidate numpy route produces (candidate-major, weight
    ascending), with its same f32 score/compare semantics.
    """
    from ...kernels.fused_solve import fused_ws_front  # lazy: optional layer
    N, m, B, k = F_bank.shape
    assert k == 2
    W = _ws_weights(n_weights)
    Fn = _hmooc2_normalize(F_bank)
    jj, P_all, keep = fused_ws_front(Fn, F_bank, W)
    cc = np.arange(N)[:, None, None]
    ii = np.arange(m)[None, None, :]
    S = idx_bank[cc, ii, jj]                             # (N, nw, m)
    keep_c, keep_w = np.nonzero(keep)
    theta_ps = pool[np.maximum(S[keep_c, keep_w], 0)]    # (q, m, d_ps)
    return P_all[keep_c, keep_w], Uc[keep_c], theta_ps


def _hmooc3_extremes(F_bank: np.ndarray, idx_bank: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Extreme points per θc (Prop. 5.2/5.3), fully vectorized.

    Returns (E, J): E (N, k, k) extreme objective vectors, J (N, k, m)
    per-subQ bank choices; E[c, v] is the query-level point minimizing
    objective v under θc candidate c.
    """
    N, m, B, k = F_bank.shape
    E = np.full((N, k, k), np.inf)
    J = np.full((N, k, m), -1, int)
    for v in range(k):
        j = np.argmin(np.where(np.isfinite(F_bank[..., v]),
                               F_bank[..., v], np.inf), axis=2)  # (N, m)
        gather = np.take_along_axis(
            F_bank, j[:, :, None, None].repeat(k, -1), axis=2)[:, :, 0, :]
        E[:, v, :] = gather.sum(1)
        J[:, v, :] = j
    return E, J


def dag_aggregate(
    Uc: np.ndarray,
    pool: np.ndarray,
    F_bank: np.ndarray,
    idx_bank: np.ndarray,
    method: str,
    *,
    n_ws_weights: int = 11,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Recover query-level Pareto solutions from per-subQ banks.

    Returns (front (q, k), theta_c (q, d_c), theta_ps (q, m, d_ps)).
    """
    N, m, B, k = F_bank.shape
    d_ps = pool.shape[1]

    if method == "hmooc3":
        E, J = _hmooc3_extremes(F_bank, idx_bank)
        pts = E.reshape(N * k, k)
        finite = np.isfinite(pts).all(-1)
        mask = pareto_mask_fast(pts) & finite
        keep = np.nonzero(mask)[0]
        front = pts[keep]
        theta_c = Uc[keep // k]
        c, v = keep // k, keep % k
        sel = np.take_along_axis(idx_bank[c], J[c, v][:, :, None],
                                 axis=2)[:, :, 0]          # (q, m)
        theta_ps = pool[np.maximum(sel, 0)]                # (q, m, d_ps)
        return front, theta_c, theta_ps

    fronts, tcs, sels = [], [], []
    if method == "hmooc2":
        # Tie-tolerant routing (same contract as `pareto_mask_fast`): the
        # fused kernel casts the bank to f32 for both the ws picks and the
        # global Pareto filter, so banks whose f64-distinct objective values
        # collide as f32 must take the per-candidate f64 numpy route even in
        # the kernel volume regime.  Input-level check on F_bank covers Fn
        # too (Fn is an affine renormalization of F_bank).
        if N * m * B * n_ws_weights >= _ws_min_scores() \
                and not _f32_tie_hazard(F_bank.reshape(-1, k)):
            return _hmooc2_all_fused(Uc, pool, F_bank, idx_bank,
                                     n_ws_weights)
        per_c: Sequence[Tuple[np.ndarray, np.ndarray]] = \
            _hmooc2_all(F_bank, idx_bank, n_ws_weights)
    elif method == "hmooc1":
        per_c = [_hmooc1_fixed_c(F_bank[c], idx_bank[c]) for c in range(N)]
    else:
        raise ValueError(method)
    for c, (F, S) in enumerate(per_c):
        if F.shape[0]:
            fronts.append(F)
            tcs.append(np.tile(Uc[c], (F.shape[0], 1)))
            sels.append(S)
    if not fronts:
        z = np.zeros((0, k))
        return z, np.zeros((0, Uc.shape[1])), np.zeros((0, m, d_ps))
    F = np.concatenate(fronts, 0)
    TC = np.concatenate(tcs, 0)
    SEL = np.concatenate(sels, 0)
    mask = pareto_mask_fast(F)
    keep = np.nonzero(mask)[0]
    theta_ps = pool[np.maximum(SEL[keep], 0)]   # (q, m, d_ps)
    return F[keep], TC[keep], theta_ps


# ---------------------------------------------------------------------------
# Full solve
# ---------------------------------------------------------------------------

def hmooc_solve(
    stage_eval: StageEval,
    m: int,
    d_c: int,
    d_ps: int,
    cfg: HMOOCConfig = HMOOCConfig(),
    *,
    snap_c=None,
    snap_ps=None,
    effective_set: Optional[EffectiveSet] = None,
) -> HMOOCResult:
    """Compile-time fine-grained MOO (subQ tuning + DAG aggregation).

    ``effective_set`` reuses Algorithm 1 artifacts from a previous solve:
    the candidate samples are always safe to share (they are
    query-independent for a fixed config); if ``opt_idx`` banks are present
    they are reused too, which skips the per-representative MOO entirely —
    exact when the query is identical to the one they were computed on.
    """
    t0 = time.perf_counter()
    reused_banks = False
    if effective_set is None:
        rng = np.random.default_rng(cfg.seed)
        eset = build_candidates(d_c, d_ps, cfg, snap_c=snap_c,
                                snap_ps=snap_ps, rng=rng)
    else:
        eset = effective_set
    n_evals = 0
    if eset.opt_idx is not None and len(eset.opt_idx[0]) == m:
        k_obj = eset.k_obj
        reused_banks = True
    else:
        opt_idx, k_obj, n_evals = _optimize_rep_banks(stage_eval, m, eset,
                                                      cfg)
        eset = dataclasses.replace(eset, opt_idx=opt_idx, k_obj=k_obj)
    F_bank, idx_bank, n2 = _assign_banks(stage_eval, m, eset, cfg, k_obj)
    n_evals += n2
    front, theta_c, theta_ps = dag_aggregate(
        eset.Uc, eset.pool, F_bank, idx_bank, cfg.dag_method,
        n_ws_weights=cfg.n_ws_weights)
    dt = time.perf_counter() - t0
    return HMOOCResult(front=front, theta_c=theta_c, theta_ps=theta_ps,
                       solve_time=dt, n_evals=n_evals,
                       extras={"n_theta_c": float(eset.Uc.shape[0]),
                               "reused_banks": float(reused_banks)},
                       effective_set=eset)


class HmoocPlan:
    """Externally-driven :func:`hmooc_solve`: one query's solve as a
    two-phase state machine whose stage evaluations are surfaced as request
    lists instead of executed inline.

    A batch driver (``repro.serve.service``) holds one plan per in-flight
    query, fuses every plan's pending requests into a single batched model
    dispatch per round, and feeds the results back — so a micro-batch of M
    queries costs two regressor calls total instead of 2·M·m.  The
    arithmetic is :func:`hmooc_solve`'s exactly: each phase replays the fed
    results through the same :func:`_optimize_rep_banks` /
    :func:`_assign_banks` the sequential solve calls (request row-building
    is deterministic in the effective set, so the replayed rows are the
    rows the results were computed on).

    Protocol: while ``not plan.done``, call ``requests()`` (a list of
    ``(i, Tc, Tps)`` stage requests), evaluate them externally, and pass
    the aligned objective arrays to ``feed()``.  ``banks_ready`` flips
    after the first phase, at which point ``eset`` carries the optimal-θp
    banks — a driver hands it to same-template plans to reuse, mirroring a
    sequential store→lookup between their solves.
    """

    def __init__(self, m: int, d_c: int, d_ps: int,
                 cfg: HMOOCConfig = HMOOCConfig(), *,
                 snap_c=None, snap_ps=None,
                 effective_set: Optional[EffectiveSet] = None):
        self._t0 = time.perf_counter()
        self.m, self.cfg = m, cfg
        self.n_evals = 0
        self.reused_banks = False
        self.result: Optional[HMOOCResult] = None
        if effective_set is None:
            rng = np.random.default_rng(cfg.seed)
            self.eset = build_candidates(d_c, d_ps, cfg, snap_c=snap_c,
                                         snap_ps=snap_ps, rng=rng)
        else:
            self.eset = effective_set
        if self.eset.opt_idx is not None and len(self.eset.opt_idx[0]) == m:
            self.k_obj = self.eset.k_obj
            self.reused_banks = True
            self._phase = "assign"
        else:
            self.k_obj = 2
            self._phase = "banks"
        self._reqs: Optional[List[Tuple[int, np.ndarray, np.ndarray]]] = None

    @property
    def done(self) -> bool:
        return self._phase == "done"

    @property
    def banks_ready(self) -> bool:
        return self._phase in ("assign", "done")

    def requests(self) -> List[Tuple[int, np.ndarray, np.ndarray]]:
        # Row-building is deterministic in (eset, cfg), so the per-phase
        # request list is memoized: the driver calls this once to collect
        # work and feed() consumes it again to align results.
        if self._reqs is not None:
            return self._reqs
        if self._phase == "banks":
            self._reqs = _rep_bank_requests(self.m, self.eset)
        elif self._phase == "assign":
            self._reqs = [(i, req[0], req[1]) for i, req in
                          enumerate(_assign_requests(self.m, self.eset,
                                                     self.cfg))
                          if req is not None]
        else:
            raise RuntimeError("plan is already done")
        return self._reqs

    def feed(self, results: Sequence[np.ndarray]) -> None:
        """Advance one phase with the objective arrays for ``requests()``."""
        fmap = {i: F for (i, _, _), F in zip(self.requests(), results)}

        def replay(i, Tc, Tps):
            return fmap[i]

        if self._phase == "banks":
            opt_idx, k_obj, n1 = _optimize_rep_banks(replay, self.m,
                                                     self.eset, self.cfg)
            self.eset = dataclasses.replace(self.eset, opt_idx=opt_idx,
                                            k_obj=k_obj)
            self.k_obj = k_obj
            self.n_evals += n1
            self._phase = "assign"
            self._reqs = None
            return
        F_bank, idx_bank, n2 = _assign_banks(replay, self.m, self.eset,
                                             self.cfg, self.k_obj)
        self.n_evals += n2
        front, theta_c, theta_ps = dag_aggregate(
            self.eset.Uc, self.eset.pool, F_bank, idx_bank,
            self.cfg.dag_method, n_ws_weights=self.cfg.n_ws_weights)
        self.result = HMOOCResult(
            front=front, theta_c=theta_c, theta_ps=theta_ps,
            solve_time=time.perf_counter() - self._t0, n_evals=self.n_evals,
            extras={"n_theta_c": float(self.eset.Uc.shape[0]),
                    "reused_banks": float(self.reused_banks)},
            effective_set=self.eset)
        self._phase = "done"
        self._reqs = None
