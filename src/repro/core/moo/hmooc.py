"""Hierarchical MOO with Constraints (paper §5.1, Algorithms 1–4).

Solves the compile-time fine-grained tuning problem

    argmin_{θc, {θp_i}, {θs_i}}  [ Σ_i φ_1(subQ_i, θc, θp_i, θs_i),
                                   Σ_i φ_2(subQ_i, θc, θp_i, θs_i) ]

by (1) *subQ tuning* — Algorithm 1's effective-set generation with θc
clustering, per-representative θp MOO over a shared sample pool, optimal-θp
assignment to cluster members, and crossover-based θc enrichment — and
(2) *DAG aggregation* — HMOOC1 (exact divide-and-conquer Minkowski merge),
HMOOC2 (weighted-sum over functions), HMOOC3 (boundary/extreme-point
approximation), exploiting that analytical latency and cost are sums over
subQs so the DAG reduces to a list.

The stage evaluator abstracts the objective model:

    stage_eval(i, Tc, Tps) -> (n, k) objective rows for subQ i,
        Tc: (n, d_c) unit-space θc, Tps: (n, d_p + d_s) unit-space θp⊕θs.

In production it wraps the trained subQ PerfModel; tests can plug the
analytic simulator or synthetic functions.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .clustering import kmeans_fit
from .pareto import pareto_mask_np

__all__ = ["HMOOCConfig", "HMOOCResult", "hmooc_solve",
           "dag_aggregate", "minkowski_merge_2d"]

StageEval = Callable[[int, np.ndarray, np.ndarray], np.ndarray]


@dataclasses.dataclass(frozen=True)
class HMOOCConfig:
    n_c_init: int = 64          # initial θc candidates (LHS)
    n_clusters: int = 10        # θc clusters (Alg. 1 line 2)
    n_p_pool: int = 256         # shared θp⊕θs sample pool size
    n_c_enrich: int = 64        # crossover-generated θc candidates
    max_bank: int = 48          # per-(θc, subQ) Pareto bank cap
    dag_method: str = "hmooc3"  # "hmooc1" | "hmooc2" | "hmooc3"
    n_ws_weights: int = 11      # weight vectors for hmooc2
    seed: int = 0


@dataclasses.dataclass
class HMOOCResult:
    front: np.ndarray           # (q, k) query-level Pareto objective values
    theta_c: np.ndarray         # (q, d_c) unit
    theta_ps: np.ndarray        # (q, m, d_ps) unit per-subQ θp⊕θs
    solve_time: float
    n_evals: int
    extras: Dict[str, float]


# ---------------------------------------------------------------------------
# Subquery tuning (Algorithm 1)
# ---------------------------------------------------------------------------

def _snap_unique(U: np.ndarray, snap) -> np.ndarray:
    Us = snap(U) if snap is not None else U
    return np.unique(np.round(Us, 9), axis=0)


def _crossover(Uc: np.ndarray, n_new: int, d: int,
               rng: np.random.Generator) -> np.ndarray:
    """θc crossover (App. C.1): random cut + Cartesian-product recombination."""
    if Uc.shape[0] < 2:
        return np.zeros((0, d))
    out = []
    for _ in range(4):  # a few cut positions
        cut = int(rng.integers(1, d))
        pre = np.unique(Uc[:, :cut], axis=0)
        suf = np.unique(Uc[:, cut:], axis=0)
        ii = rng.integers(0, pre.shape[0], size=n_new)
        jj = rng.integers(0, suf.shape[0], size=n_new)
        out.append(np.concatenate([pre[ii], suf[jj]], axis=1))
    cand = np.unique(np.concatenate(out, 0), axis=0)
    rng.shuffle(cand)
    return cand[:n_new]


def _pareto_bank(F: np.ndarray, cap: int) -> np.ndarray:
    """Indices of the non-dominated rows of F (capped, best-first)."""
    mask = pareto_mask_np(F)
    idx = np.nonzero(mask)[0]
    if idx.size > cap:
        # Keep a spread: sort by first objective, take evenly spaced.
        order = idx[np.argsort(F[idx, 0])]
        keep = np.linspace(0, order.size - 1, cap).round().astype(int)
        idx = order[keep]
    return idx


def subq_tuning(
    stage_eval: StageEval,
    m: int,
    d_c: int,
    d_ps: int,
    cfg: HMOOCConfig,
    *,
    snap_c=None,
    snap_ps=None,
    rng: Optional[np.random.Generator] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, int]:
    """Effective-set generation (Algorithm 1).

    Returns (Uc, pool, F_bank, idx_bank, n_evals) where
      Uc: (N, d_c) θc candidates,
      pool: (P, d_ps) shared θp⊕θs samples,
      F_bank: (N, m, B, k) objective values (+inf padded),
      idx_bank: (N, m, B) pool indices (−1 padded).
    """
    rng = rng or np.random.default_rng(cfg.seed)
    # Line 1: init_c (LHS over the unit cube, snapped to valid raw values).
    Uc0 = _lhs(rng, cfg.n_c_init, d_c)
    Uc0 = _snap_unique(Uc0, snap_c)
    # Line 2: cluster.
    km, labels0 = kmeans_fit(Uc0, cfg.n_clusters, rng)
    reps = km.centers
    if snap_c is not None:
        reps = snap_c(reps)
    # Shared θp⊕θs pool.
    pool = _lhs(rng, cfg.n_p_pool, d_ps)
    if snap_ps is not None:
        pool = snap_ps(pool)

    n_evals = 0
    C = reps.shape[0]
    # Line 3: optimize_p_moo for each representative × subQ.
    opt_idx: List[List[np.ndarray]] = []
    k_obj = None
    for r in range(C):
        Tc = np.tile(reps[r], (pool.shape[0], 1))
        per_subq = []
        for i in range(m):
            F = stage_eval(i, Tc, pool)
            n_evals += F.shape[0]
            k_obj = F.shape[1]
            per_subq.append(_pareto_bank(F, cfg.max_bank))
        opt_idx.append(per_subq)

    def assign(Uc: np.ndarray, labels: np.ndarray
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Line 4/7: evaluate members against their rep's optimal θp sets."""
        nonlocal n_evals
        N = Uc.shape[0]
        B = cfg.max_bank
        F_bank = np.full((N, m, B, k_obj), np.inf)
        idx_bank = np.full((N, m, B), -1, int)
        for r in range(C):
            members = np.nonzero(labels == r)[0]
            if members.size == 0:
                continue
            for i in range(m):
                sel = opt_idx[r][i]
                if sel.size == 0:
                    continue
                nb = min(sel.size, B)
                sel = sel[:nb]
                Tc = np.repeat(Uc[members], nb, axis=0)
                Tp = np.tile(pool[sel], (members.size, 1))
                F = stage_eval(i, Tc, Tp).reshape(members.size, nb, k_obj)
                n_evals += members.size * nb
                F_bank[members, i, :nb] = F
                idx_bank[members, i, :nb] = sel
        return F_bank, idx_bank

    F0, I0 = assign(Uc0, labels0)

    # Line 5-7: enrich via crossover, assign to existing clusters.
    Uc1 = _crossover(Uc0, cfg.n_c_enrich, d_c, rng)
    if snap_c is not None and Uc1.size:
        Uc1 = _snap_unique(Uc1, snap_c)
    if Uc1.size:
        # Drop duplicates of the initial set.
        mask = ~(Uc1[:, None, :] == Uc0[None, :, :]).all(-1).any(1)
        Uc1 = Uc1[mask]
    if Uc1.size:
        labels1 = km.assign(Uc1)
        F1, I1 = assign(Uc1, labels1)
        Uc = np.concatenate([Uc0, Uc1], 0)
        F_bank = np.concatenate([F0, F1], 0)
        idx_bank = np.concatenate([I0, I1], 0)
    else:
        Uc, F_bank, idx_bank = Uc0, F0, I0
    return Uc, pool, F_bank, idx_bank, n_evals


def _lhs(rng: np.random.Generator, n: int, d: int) -> np.ndarray:
    u = (rng.permuted(np.tile(np.arange(n), (d, 1)), axis=1).T
         + rng.random((n, d))) / n
    return u


# ---------------------------------------------------------------------------
# DAG aggregation (paper §5.1.2, Appendix B)
# ---------------------------------------------------------------------------

def minkowski_merge_2d(F1: np.ndarray, S1: np.ndarray,
                       F2: np.ndarray, S2: np.ndarray
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Pf(Pf(F)⊕Pf(G)) — enumerate sums, keep non-dominated (Alg. 3).

    S1/S2 are (n, m) per-subQ pool-index selections (−1 = unset); merged
    entries take whichever side set each subQ.
    """
    n1, n2 = F1.shape[0], F2.shape[0]
    F = (F1[:, None, :] + F2[None, :, :]).reshape(n1 * n2, -1)
    mask = pareto_mask_np(F)
    keep = np.nonzero(mask)[0]
    i1, i2 = keep // n2, keep % n2
    sel = np.where(S1[i1] >= 0, S1[i1], S2[i2])
    return F[keep], sel


def _hmooc1_fixed_c(Fb: np.ndarray, Ib: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Exact divide-and-conquer aggregation under one θc (Alg. 2).

    Returns (front (q, k), sel (q, m)) with ``sel[:, i]`` the pool index
    chosen for subQ i.
    """
    m = Fb.shape[0]
    nodes = []
    for i in range(m):
        valid = np.isfinite(Fb[i]).all(-1)
        # Only local Pareto points can contribute (Prop. 5.1).
        valid &= pareto_mask_np(Fb[i], valid)
        idx = np.nonzero(valid)[0]
        if idx.size == 0:
            return np.zeros((0, Fb.shape[-1])), np.zeros((0, m), int)
        F = Fb[i][idx]
        sel = np.full((idx.size, m), -1, int)
        sel[:, i] = Ib[i][idx]
        nodes.append((F, sel))
    while len(nodes) > 1:
        nxt = []
        for a in range(0, len(nodes) - 1, 2):
            F, S = minkowski_merge_2d(nodes[a][0], nodes[a][1],
                                      nodes[a + 1][0], nodes[a + 1][1])
            nxt.append((F, S))
        if len(nodes) % 2:
            nxt.append(nodes[-1])
        nodes = nxt
    return nodes[0]


def _hmooc2_fixed_c(Fb: np.ndarray, Ib: np.ndarray, n_weights: int
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """WS-over-functions aggregation under one θc (Alg. 4)."""
    m, B, k = Fb.shape
    assert k == 2
    ws = np.linspace(0.0, 1.0, n_weights)
    # Normalize per OBJECTIVE over the whole bank (one affine transform
    # shared by every subQ).  The paper's Alg. 4 normalizes per subQ, but
    # per-subQ scales give each subQ different effective weights and void
    # Lemma 1's guarantee that each WS pick is query-level Pareto optimal
    # (hypothesis-tested in tests/test_hmooc.py); a shared affine transform
    # commutes with the sum aggregator and preserves the proof.
    finite = np.where(np.isfinite(Fb), Fb, np.nan)
    lo = np.nanmin(finite, axis=(0, 1), keepdims=True)
    hi = np.nanmax(finite, axis=(0, 1), keepdims=True)
    span = np.where(hi > lo, hi - lo, 1.0)
    Fn = (Fb - lo) / span
    Fn = np.where(np.isfinite(Fb), Fn, 1e18)
    points, sels = [], []
    for w in ws:
        score = w * Fn[..., 0] + (1 - w) * Fn[..., 1]     # (m, B)
        j = np.argmin(score, axis=1)                      # per-subQ argmin
        F = Fb[np.arange(m), j]
        if not np.isfinite(F).all():
            continue
        points.append(F.sum(0))
        sels.append(Ib[np.arange(m), j])
    if not points:
        return np.zeros((0, k)), np.zeros((0, m), int)
    P = np.stack(points)
    mask = pareto_mask_np(P)
    keep = np.nonzero(mask)[0]
    return P[keep], np.stack(sels)[keep]


def _hmooc3_extremes(F_bank: np.ndarray, idx_bank: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Extreme points per θc (Prop. 5.2/5.3), fully vectorized.

    Returns (E, J): E (N, k, k) extreme objective vectors, J (N, k, m)
    per-subQ bank choices; E[c, v] is the query-level point minimizing
    objective v under θc candidate c.
    """
    N, m, B, k = F_bank.shape
    E = np.full((N, k, k), np.inf)
    J = np.full((N, k, m), -1, int)
    for v in range(k):
        j = np.argmin(np.where(np.isfinite(F_bank[..., v]),
                               F_bank[..., v], np.inf), axis=2)  # (N, m)
        gather = np.take_along_axis(
            F_bank, j[:, :, None, None].repeat(k, -1), axis=2)[:, :, 0, :]
        E[:, v, :] = gather.sum(1)
        J[:, v, :] = j
    return E, J


def dag_aggregate(
    Uc: np.ndarray,
    pool: np.ndarray,
    F_bank: np.ndarray,
    idx_bank: np.ndarray,
    method: str,
    *,
    n_ws_weights: int = 11,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Recover query-level Pareto solutions from per-subQ banks.

    Returns (front (q, k), theta_c (q, d_c), theta_ps (q, m, d_ps)).
    """
    N, m, B, k = F_bank.shape
    d_ps = pool.shape[1]

    if method == "hmooc3":
        E, J = _hmooc3_extremes(F_bank, idx_bank)
        pts = E.reshape(N * k, k)
        finite = np.isfinite(pts).all(-1)
        mask = pareto_mask_np(pts) & finite
        keep = np.nonzero(mask)[0]
        front = pts[keep]
        theta_c = Uc[keep // k]
        theta_ps = np.zeros((keep.size, m, d_ps))
        for o, K in enumerate(keep):
            c, v = K // k, K % k
            sel = np.take_along_axis(idx_bank[c], J[c, v][:, None],
                                     axis=1)[:, 0]
            theta_ps[o] = pool[np.maximum(sel, 0)]
        return front, theta_c, theta_ps

    fronts, tcs, sels = [], [], []
    for c in range(N):
        if method == "hmooc1":
            F, S = _hmooc1_fixed_c(F_bank[c], idx_bank[c])
        elif method == "hmooc2":
            F, S = _hmooc2_fixed_c(F_bank[c], idx_bank[c], n_ws_weights)
        else:
            raise ValueError(method)
        if F.shape[0]:
            fronts.append(F)
            tcs.append(np.tile(Uc[c], (F.shape[0], 1)))
            sels.append(S)
    if not fronts:
        z = np.zeros((0, k))
        return z, np.zeros((0, Uc.shape[1])), np.zeros((0, m, d_ps))
    F = np.concatenate(fronts, 0)
    TC = np.concatenate(tcs, 0)
    SEL = np.concatenate(sels, 0)
    mask = pareto_mask_np(F)
    keep = np.nonzero(mask)[0]
    theta_ps = pool[np.maximum(SEL[keep], 0)]   # (q, m, d_ps)
    return F[keep], TC[keep], theta_ps


# ---------------------------------------------------------------------------
# Full solve
# ---------------------------------------------------------------------------

def hmooc_solve(
    stage_eval: StageEval,
    m: int,
    d_c: int,
    d_ps: int,
    cfg: HMOOCConfig = HMOOCConfig(),
    *,
    snap_c=None,
    snap_ps=None,
) -> HMOOCResult:
    """Compile-time fine-grained MOO (subQ tuning + DAG aggregation)."""
    t0 = time.perf_counter()
    rng = np.random.default_rng(cfg.seed)
    Uc, pool, F_bank, idx_bank, n_evals = subq_tuning(
        stage_eval, m, d_c, d_ps, cfg, snap_c=snap_c, snap_ps=snap_ps,
        rng=rng)
    front, theta_c, theta_ps = dag_aggregate(
        Uc, pool, F_bank, idx_bank, cfg.dag_method,
        n_ws_weights=cfg.n_ws_weights)
    dt = time.perf_counter() - t0
    return HMOOCResult(front=front, theta_c=theta_c, theta_ps=theta_ps,
                       solve_time=dt, n_evals=n_evals,
                       extras={"n_theta_c": float(Uc.shape[0])})
