"""The 19 Spark parameters of the paper (Table 6), in three categories.

Raw units: memory/sizes in MB, fractions in [0,1], counts as integers.
Defaults follow Spark 3.5.0 documentation (the paper's "default configuration").
"""
from __future__ import annotations

from .spaces import Param, ParamSpace

__all__ = [
    "theta_c_space",
    "theta_p_space",
    "theta_s_space",
    "THETA_C",
    "THETA_P",
    "THETA_S",
]

# --------------------------------------------------------------------------
# θc — context parameters (set at Spark-context initialization)
# --------------------------------------------------------------------------
THETA_C = [
    Param("spark.executor.cores", "int", 1, 8, default=2),                       # k1
    Param("spark.executor.memory", "int", 1, 32, log=True, default=4),           # k2 (GB)
    Param("spark.executor.instances", "int", 2, 20, default=4),                  # k3
    Param("spark.default.parallelism", "int", 8, 512, log=True, default=40),     # k4
    Param("spark.reducer.maxSizeInFlight", "int", 8, 256, log=True, default=48), # k5 (MB)
    Param("spark.shuffle.sort.bypassMergeThreshold", "int", 50, 1000, default=200),  # k6
    Param("spark.shuffle.compress", "bool", default=1),                          # k7
    Param("spark.memory.fraction", "float", 0.4, 0.9, default=0.6),              # k8
]

# --------------------------------------------------------------------------
# θp — logical-query-plan parameters (AQE parametric rules on LQP)
# --------------------------------------------------------------------------
THETA_P = [
    Param("spark.sql.adaptive.advisoryPartitionSizeInBytes", "int", 8, 512, log=True, default=64),   # s1 (MB)
    Param("spark.sql.adaptive.nonEmptyPartitionRatioForBroadcastJoin", "float", 0.0, 1.0, default=0.2),  # s2
    Param("spark.sql.adaptive.maxShuffledHashJoinLocalMapThreshold", "int", 0, 1024, default=0),     # s3 (MB)
    Param("spark.sql.adaptive.autoBroadcastJoinThreshold", "int", 0, 1024, default=10),              # s4 (MB)
    Param("spark.sql.shuffle.partitions", "int", 8, 2048, log=True, default=200),                    # s5
    Param("spark.sql.adaptive.skewJoin.skewedPartitionThresholdInBytes", "int", 16, 1024, log=True, default=256),  # s6 (MB)
    Param("spark.sql.adaptive.skewJoin.skewedPartitionFactor", "int", 2, 10, default=5),             # s7
    Param("spark.sql.files.maxPartitionBytes", "int", 16, 1024, log=True, default=128),              # s8 (MB)
    Param("spark.sql.files.openCostInBytes", "int", 1, 64, log=True, default=4),                     # s9 (MB)
]

# --------------------------------------------------------------------------
# θs — query-stage parameters (AQE parametric rules on QS)
# --------------------------------------------------------------------------
THETA_S = [
    Param("spark.sql.adaptive.rebalancePartitionsSmallPartitionFactor", "float", 0.1, 0.9, default=0.2),  # s10
    Param("spark.sql.adaptive.coalescePartitions.minPartitionSize", "int", 1, 64, log=True, default=1),   # s11 (MB)
]


def theta_c_space() -> ParamSpace:
    return ParamSpace(THETA_C)


def theta_p_space() -> ParamSpace:
    return ParamSpace(THETA_P)


def theta_s_space() -> ParamSpace:
    return ParamSpace(THETA_S)
