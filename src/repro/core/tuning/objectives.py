"""Objective adapters: build stage/query evaluators for the MOO solvers.

Two backends expose the same interface:

* **model** — the trained subQ :class:`PerfModel` (the production path;
  sub-second solving via cached GTN embeddings + batched regressor);
* **oracle** — the analytic simulator evaluated on *CBO-estimated* inputs
  (what a perfect compile-time model would believe), used by algorithm
  benchmarks and tests to isolate MOO behavior from model error.

Objectives (minimization), matching the paper's latency/cloud-cost pair:
  f1 = analytical latency (s)      — Σ over subQs at the query level
  f2 = cloud cost ($)              — latency·(core+mem rates) + IO·io rate

Both are *sums* over subQs for fixed θc, which is what licenses HMOOC's
list-structured DAG aggregation (paper §5.1.2).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from ...queryengine.plan import Query
from ...queryengine.simulator import CostModel, DEFAULT_COST, simulate_subq
from ...queryengine.trace import _alpha_stats
from ..models.perf_model import PerfModel, make_nondecision
from .spark_space import theta_c_space, theta_p_space, theta_s_space

__all__ = ["StageObjectives", "resource_rate", "QueryObjective",
           "fused_stage_eval", "StageRequest"]


def resource_rate(tc_raw: np.ndarray, cost: CostModel = DEFAULT_COST
                  ) -> np.ndarray:
    """$(per second) of the allocated cluster for raw θc rows."""
    k1, k2, k3 = tc_raw[:, 0], tc_raw[:, 1], tc_raw[:, 2]
    return (k1 * k3 * cost.price_core_h + k2 * k3 * cost.price_mem_gb_h) \
        / 3600.0


class StageObjectives:
    """stage_eval factory for one query (model- or oracle-backed)."""

    def __init__(self, query: Query, *, model: Optional[PerfModel] = None,
                 cost: CostModel = DEFAULT_COST):
        self.query = query
        self.model = model
        self.cost = cost
        self.cs = theta_c_space()
        self.ps = theta_p_space()
        self.ss = theta_s_space()
        self.d_c = self.cs.dim
        self.d_ps = self.ps.dim + self.ss.dim
        self.m = query.n_subqs
        if model is not None:
            # One batched GTN dispatch covers all subQs (a cache no-op when
            # the serving layer already prefetched the whole micro-batch).
            model.embed_many([(query, i) for i in range(self.m)])
            self._embs = [model.embed(query, i) for i in range(self.m)]
            self._nond = [make_nondecision(_alpha_stats(
                sq.est_input_rows, sq.est_input_bytes))
                for sq in query.subqs]

    # -- unit→raw helpers ----------------------------------------------------
    def snap_c(self, U: np.ndarray) -> np.ndarray:
        return self.cs.snap_unit(U)

    def snap_ps(self, U: np.ndarray) -> np.ndarray:
        out = U.copy()
        out[..., :self.ps.dim] = self.ps.snap_unit(U[..., :self.ps.dim])
        out[..., self.ps.dim:] = self.ss.snap_unit(U[..., self.ps.dim:])
        return out

    def split_raw(self, Tc: np.ndarray, Tps: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        tc = self.cs.to_raw(Tc)
        tp = self.ps.to_raw(Tps[..., :self.ps.dim])
        ts = self.ss.to_raw(Tps[..., self.ps.dim:])
        return tc, tp, ts

    # -- evaluators ------------------------------------------------------------
    def stage_eval(self, i: int, Tc: np.ndarray, Tps: np.ndarray
                   ) -> np.ndarray:
        """(n, d_c) ⊕ (n, d_ps) unit rows → (n, 2) [latency, cost]."""
        tc_raw, tp_raw, ts_raw = self.split_raw(Tc, Tps)
        if self.model is not None:
            theta = self.theta_rows(Tc, Tps)
            pred = self.model.predict(self._embs[i], theta, self._nond[i])
            lat, io = pred[:, 0], pred[:, 1]
        else:
            sim = simulate_subq(self.query.subqs[i], tc_raw, tp_raw, ts_raw,
                                cost=self.cost, aqe=True,
                                use_est_inputs=True)
            lat, io = sim.ana_latency, sim.io_gb
        dollars = lat * resource_rate(tc_raw, self.cost) \
            + io * self.cost.price_io_gb
        return np.stack([lat, dollars], -1)

    def theta_rows(self, Tc: np.ndarray, Tps: np.ndarray) -> np.ndarray:
        """Regressor θ layout for unit rows: [θc ⊕ θp ⊕ θs], float32."""
        return np.concatenate(
            [Tc, Tps[..., :self.ps.dim], Tps[..., self.ps.dim:]],
            -1).astype(np.float32)

    # -- flat query-level evaluators for the baselines -------------------------
    def query_eval_fine(self) -> Tuple[Callable[[np.ndarray], np.ndarray], int]:
        """Fine-grained flat space: θc ⊕ m × (θp ⊕ θs); D = d_c + m·d_ps."""
        D = self.d_c + self.m * self.d_ps

        def ev(U: np.ndarray) -> np.ndarray:
            n = U.shape[0]
            Tc = U[:, :self.d_c]
            total = np.zeros((n, 2))
            for i in range(self.m):
                lo = self.d_c + i * self.d_ps
                total += self.stage_eval(i, Tc, U[:, lo:lo + self.d_ps])
            return total
        return ev, D

    def query_eval_coarse(self) -> Tuple[Callable[[np.ndarray], np.ndarray], int]:
        """Query-level control: one shared θp ⊕ θs; D = d_c + d_ps."""
        D = self.d_c + self.d_ps

        def ev(U: np.ndarray) -> np.ndarray:
            n = U.shape[0]
            Tc = U[:, :self.d_c]
            Tps = U[:, self.d_c:]
            total = np.zeros((n, 2))
            for i in range(self.m):
                total += self.stage_eval(i, Tc, Tps)
            return total
        return ev, D


QueryObjective = Callable[[np.ndarray], np.ndarray]

# One stage-evaluation request: (objectives, subQ index, θc rows, θp⊕θs rows).
StageRequest = Tuple["StageObjectives", int, np.ndarray, np.ndarray]


def fused_stage_eval(items: Sequence[StageRequest]) -> List[np.ndarray]:
    """Evaluate many stage requests — across subQs *and* queries — at once.

    The model-backed path concatenates every request's regressor rows
    (per-row embedding ⊕ θ ⊕ nondecision) into a single bucket-padded
    :meth:`PerfModel.predict_rows` dispatch, then finishes the float64
    latency→dollars arithmetic per request.  Per-request outputs are
    identical to calling ``obj.stage_eval(i, Tc, Tps)`` one by one: row j of
    a padded batch equals row j of the per-request call, and the cost
    arithmetic is element-wise.  All requests must share one model (the
    serving layer batches per service); the oracle backend (``model is
    None``) falls back to per-request evaluation, which is already one
    vectorized simulator call each.
    """
    if not items:
        return []
    model = items[0][0].model
    if model is None:
        return [obj.stage_eval(i, Tc, Tps) for obj, i, Tc, Tps in items]
    if any(it[0].model is not model for it in items):
        raise ValueError("fused_stage_eval requires one shared model")
    thetas, metas = [], []
    for obj, i, Tc, Tps in items:
        tc_raw, _, _ = obj.split_raw(Tc, Tps)
        theta = obj.theta_rows(Tc, Tps)
        thetas.append(theta)
        metas.append((obj, i, theta.shape[0], tc_raw))
    total = sum(n for _, _, n, _ in metas)
    emb0 = items[0][0]._embs[items[0][1]]
    nond0 = items[0][0]._nond[items[0][1]]
    # Per-row emb/nond are broadcast straight into the dispatch buffers —
    # no per-request np.repeat intermediates on the host.
    emb_all = np.empty((total, emb0.shape[0]), np.float32)
    nond_all = np.empty((total, nond0.shape[0]), np.float32)
    off = 0
    for obj, i, n, _ in metas:
        emb_all[off:off + n] = obj._embs[i]
        nond_all[off:off + n] = obj._nond[i]
        off += n
    pred = model.predict_rows(emb_all, np.concatenate(thetas, 0), nond_all)
    out: List[np.ndarray] = []
    off = 0
    for obj, _, n, tc_raw in metas:
        p = pred[off:off + n]
        off += n
        lat, io = p[:, 0], p[:, 1]
        dollars = lat * resource_rate(tc_raw, obj.cost) \
            + io * obj.cost.price_io_gb
        out.append(np.stack([lat, dollars], -1))
    return out
