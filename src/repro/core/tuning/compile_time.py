"""Compile-time optimization: HMOOC solve + WUN recommendation (paper §5.1).

Produces the optimal Spark context θc*, the fine-grained per-subQ θp/θs the
runtime optimizer is seeded with, and the aggregated submission copies.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Tuple

import numpy as np

from ...queryengine.plan import Query
from ...queryengine.simulator import CostModel, DEFAULT_COST
from ..models.perf_model import PerfModel
from ..moo.hmooc import EffectiveSet, HMOOCConfig, HMOOCResult, hmooc_solve
from ..moo.wun import wun_select
from .aggregation import aggregate_submission_theta
from .objectives import StageObjectives, fused_stage_eval

__all__ = ["CompileTimeResult", "compile_time_optimize",
           "default_theta_result", "finish_result"]


@dataclasses.dataclass
class CompileTimeResult:
    # Pareto front (model/believed objective space) + chosen point.
    front: np.ndarray             # (q, 2)
    choice: int                   # WUN index into the front
    # Raw-space configuration of the chosen point.
    theta_c: np.ndarray           # (8,)
    theta_p_sub: np.ndarray       # (m, 9) fine-grained
    theta_s_sub: np.ndarray       # (m, 2)
    theta_p0: np.ndarray          # (9,) aggregated submission copy
    theta_s0: np.ndarray          # (2,)
    solve_time: float
    n_evals: int

    @property
    def chosen_objectives(self) -> np.ndarray:
        return self.front[self.choice]


def compile_time_optimize(
    query: Query,
    *,
    model: Optional[PerfModel] = None,
    weights: Tuple[float, float] = (0.9, 0.1),
    cfg: HMOOCConfig = HMOOCConfig(),
    cost: CostModel = DEFAULT_COST,
    cache=None,
    effective_set: Optional[EffectiveSet] = None,
) -> CompileTimeResult:
    """Solve the fine-grained compile-time MOO and pick a WUN recommendation.

    ``model=None`` uses the oracle (simulator-on-estimates) objective — used
    by algorithm studies; pass the trained subQ model for the paper pipeline.

    ``cache`` is an optional effective-set cache (duck-typed, see
    ``repro.serve.EffectiveSetCache``): ``cache.lookup(query, cfg, model,
    cost)`` returns Algorithm 1 artifacts to reuse (or None) and
    ``cache.store(query, cfg, eset, model, cost)`` records them after a
    solve.  A lookup hit on an identical query skips Algorithm 1 entirely
    and is bit-identical to a cold solve.

    ``effective_set`` forces reuse of the given Algorithm 1 artifacts
    directly (no cache consulted, nothing stored): the degraded serving
    path uses it to reuse a template's banks across parametric variants —
    approximate unless the query matches the one the banks were computed
    from — without ever triggering a fresh Algorithm 1 bank build.
    """
    if effective_set is not None and cache is not None:
        raise ValueError("pass cache or effective_set, not both")
    t0 = time.perf_counter()
    obj = StageObjectives(query, model=model, cost=cost)
    if effective_set is not None:
        eset = effective_set
    else:
        eset = cache.lookup(query, cfg, model, cost) if cache is not None \
            else None
    res: HMOOCResult = hmooc_solve(
        obj.stage_eval, obj.m, obj.d_c, obj.d_ps, cfg,
        snap_c=obj.snap_c, snap_ps=obj.snap_ps, effective_set=eset)
    # Don't re-store after a bank-reuse solve: the stored fingerprint must
    # stay that of the query the banks were actually computed from, else an
    # approximate cross-variant reuse would later be served as an exact hit.
    if (cache is not None and res.effective_set is not None
            and not res.extras.get("reused_banks")):
        cache.store(query, cfg, res.effective_set, model, cost)
    return finish_result(query, obj, res, weights, t0)


def finish_result(query: Query, obj: StageObjectives, res: HMOOCResult,
                  weights: Tuple[float, float], t0: float
                  ) -> CompileTimeResult:
    """WUN selection + raw-space extraction after an HMOOC solve.

    Shared by :func:`compile_time_optimize` and the serving layer's batched
    solve driver, so both finish a solve with identical arithmetic.
    """
    if res.front.shape[0] == 0:
        raise RuntimeError(f"HMOOC produced no solutions for {query.qid}")
    choice, _ = wun_select(res.front, np.asarray(weights))

    tc_u = res.theta_c[choice]
    tps_u = res.theta_ps[choice]              # (m, d_ps)
    tc_raw, tp_raw, ts_raw = obj.split_raw(
        tc_u[None, :], tps_u)
    theta_p0, theta_s0 = aggregate_submission_theta(query, tp_raw, ts_raw)
    dt = time.perf_counter() - t0
    return CompileTimeResult(
        front=res.front, choice=choice, theta_c=tc_raw[0],
        theta_p_sub=tp_raw, theta_s_sub=ts_raw,
        theta_p0=theta_p0, theta_s0=theta_s0,
        solve_time=dt, n_evals=res.n_evals)


def default_theta_result(
    query: Query,
    *,
    model: Optional[PerfModel] = None,
    cost: CostModel = DEFAULT_COST,
) -> CompileTimeResult:
    """Spark-default configuration as a :class:`CompileTimeResult` — no MOO.

    The last-resort degraded serving path: when a tenant's solve budget is
    already unmeetable and not even cached Algorithm 1 artifacts exist for
    the query's template, the server admits the query under the paper's
    "default configuration" (Spark 3.5.0 documentation defaults, Table 6)
    instead of queueing it into a blown budget.  Cost is one stage-model
    evaluation per subQ (to report believed objectives) — no sampling,
    no clustering, no banks, no DAG aggregation.
    """
    t0 = time.perf_counter()
    obj = StageObjectives(query, model=model, cost=cost)
    tc_u = obj.cs.default_unit()[None, :]                       # (1, d_c)
    tps_u = np.tile(np.concatenate([obj.ps.default_unit(),
                                    obj.ss.default_unit()]),
                    (obj.m, 1))                                 # (m, d_ps)
    # One batched dispatch across all subQs (the oracle backend keeps the
    # exact per-subQ evaluation); the sum stays a left-to-right
    # accumulation so the reduction order matches the historical loop.
    evals = fused_stage_eval(
        [(obj, i, tc_u, tps_u[i:i + 1]) for i in range(obj.m)])
    front = np.zeros((1, 2), np.float64)
    for F in evals:
        front[0] += F[0]
    tc_raw, tp_raw, ts_raw = obj.split_raw(tc_u, tps_u)
    theta_p0, theta_s0 = aggregate_submission_theta(query, tp_raw, ts_raw)
    return CompileTimeResult(
        front=front, choice=0, theta_c=tc_raw[0],
        theta_p_sub=tp_raw, theta_s_sub=ts_raw,
        theta_p0=theta_p0, theta_s0=theta_s0,
        solve_time=time.perf_counter() - t0, n_evals=query.n_subqs)
