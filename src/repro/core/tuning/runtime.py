"""Runtime optimization: the AQE plugin that re-tunes θp / θs (paper §5.2).

Invoked by :func:`repro.queryengine.aqe.run_with_aqe` each time a collapsed
plan (L̄QP) or a new query stage (QS) needs optimization.  The optimizer sees
*true* statistics (AQE has revealed the completed stages' cardinalities) and
re-solves a small MOO for the stage at hand, picking the weighted-best
candidate under the user preference — mirroring the paper's client/server
design where the server runs model inference + MOO per request.

Backends:
  * oracle — simulate the stage on true inputs (used for algorithm studies);
  * model  — the trained runtime QS model (θp dropped; θc ⊕ θs decision) and
    the subQ model re-evaluated with true statistics for θp choices.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import numpy as np

from ...queryengine.plan import Query, SubQ
from ...queryengine.simulator import CostModel, DEFAULT_COST, simulate_subq
from ...queryengine.trace import _alpha_stats
from ..models.perf_model import PerfModel, make_nondecision
from .objectives import resource_rate
from .spark_space import theta_p_space, theta_s_space

__all__ = ["make_runtime_optimizers"]


def _weighted_pick(F: np.ndarray, weights: Tuple[float, float]) -> int:
    lo, hi = F.min(0), F.max(0)
    span = np.where(hi > lo, hi - lo, 1.0)
    Fn = (F - lo) / span
    w = np.asarray(weights, np.float64)
    return int(np.argmin((Fn * w).sum(-1)))


def make_runtime_optimizers(
    query: Query,
    theta_c_raw: np.ndarray,
    *,
    seed_theta_p: Optional[np.ndarray] = None,   # (m, 9) compile-time seeds
    seed_theta_s: Optional[np.ndarray] = None,   # (m, 2)
    model_subq: Optional[PerfModel] = None,
    model_qs: Optional[PerfModel] = None,
    weights: Tuple[float, float] = (0.9, 0.1),
    n_candidates: int = 64,
    cost: CostModel = DEFAULT_COST,
    seed: int = 0,
):
    """Build (lqp_optimizer, qs_optimizer) callbacks for ``run_with_aqe``."""
    ps, ss = theta_p_space(), theta_s_space()
    rng = np.random.default_rng(seed)
    tc_row = np.asarray(theta_c_raw, np.float64).reshape(1, -1)
    rate = resource_rate(tc_row, cost)[0]

    # Candidate pools are fixed per query (one LHS draw), plus per-stage
    # compile-time seeds — the runtime MOO just rescores them on true stats.
    pool_p_unit = ps.sample_lhs(rng, n_candidates)
    pool_p = ps.to_raw(pool_p_unit)
    pool_s_unit = ss.sample_lhs(rng, n_candidates)
    pool_s = ss.to_raw(pool_s_unit)

    def _stage_objectives_raw(sq: SubQ, tp: np.ndarray, ts: np.ndarray
                              ) -> np.ndarray:
        """True-statistics stage objectives for n candidate rows."""
        n = max(tp.shape[0], ts.shape[0])
        tc = np.broadcast_to(tc_row, (n, 8))
        if model_qs is not None and model_subq is not None:
            # Model path: subQ model re-scored with true stats drives θp;
            # (QS model is used for θs where θp is already fixed.)
            alpha = _alpha_stats(sq.input_rows, sq.input_bytes)
            nond = make_nondecision(alpha)
            from .spark_space import theta_c_space
            cs = theta_c_space()
            theta = np.concatenate([
                np.broadcast_to(cs.to_unit(tc_row)[0], (n, 8)),
                ps.to_unit(np.broadcast_to(tp, (n, 9))),
                ss.to_unit(np.broadcast_to(ts, (n, 2)))], -1)
            emb = model_subq.embed(query, sq.sq_id)
            pred = model_subq.predict(emb, theta.astype(np.float32), nond)
            lat, io = pred[:, 0], pred[:, 1]
        else:
            sim = simulate_subq(sq, tc, np.broadcast_to(tp, (n, 9)),
                                np.broadcast_to(ts, (n, 2)), cost=cost,
                                aqe=True, use_est_inputs=False)
            lat, io = sim.ana_latency, sim.io_gb
        return np.stack([lat * 1.0, lat * rate + io * cost.price_io_gb], -1)

    def lqp_optimizer(*, query: Query, subq: SubQ, theta_c: np.ndarray,
                      theta_p: np.ndarray) -> Optional[np.ndarray]:
        """Re-tune θp for the collapsed plan exposing ``subq`` (a join)."""
        cands = [pool_p, theta_p[None, :]]
        if seed_theta_p is not None:
            cands.append(seed_theta_p[subq.sq_id][None, :])
        tp = np.concatenate(cands, 0)
        ts = (seed_theta_s[subq.sq_id] if seed_theta_s is not None
              else ss.default_raw())[None, :]
        F = _stage_objectives_raw(subq, tp, ts)
        return tp[_weighted_pick(F, weights)]

    def qs_optimizer(*, query: Query, subq: SubQ, theta_c: np.ndarray,
                     theta_s: np.ndarray) -> Optional[np.ndarray]:
        """Re-tune θs for a newly created query stage."""
        cands = [pool_s, theta_s[None, :]]
        if seed_theta_s is not None:
            cands.append(seed_theta_s[subq.sq_id][None, :])
        ts = np.concatenate(cands, 0)
        tp = (seed_theta_p[subq.sq_id] if seed_theta_p is not None
              else theta_p_space().default_raw())[None, :]
        F = _stage_objectives_raw(subq, tp, ts)
        return ts[_weighted_pick(F, weights)]

    return lqp_optimizer, qs_optimizer
