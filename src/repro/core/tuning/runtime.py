"""Runtime optimization: the AQE plugin that re-tunes θp / θs (paper §5.2).

Invoked by :func:`repro.queryengine.aqe.run_with_aqe` each time a collapsed
plan (L̄QP) or a new query stage (QS) needs optimization.  The optimizer sees
*true* statistics (AQE has revealed the completed stages' cardinalities) and
re-solves a small MOO for the stage at hand, picking the weighted-best
candidate under the user preference — mirroring the paper's client/server
design where the server runs model inference + MOO per request.

Backends:
  * oracle — simulate the stage on true inputs (used for algorithm studies);
  * model  — θp decisions (L̄QP requests) re-score the subQ model with true
    statistics; θs decisions (QS requests) use the runtime QS model (θp
    dropped; θc ⊕ θs decision).

The scoring path is request-shaped so a serving layer can fuse it across
queries: :func:`score_requests` stacks same-kind oracle requests into one
:func:`~repro.queryengine.simulator.simulate_stage_rows` call and same-model
requests into one :meth:`PerfModel.predict` call, and
:func:`weighted_pick_batch` resolves every pick through the Pareto /
weighted-sum kernels.  :func:`make_runtime_optimizers` drives the identical
code with single-request batches, so per-query and fused serving results
match bit-for-bit on the oracle backend.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ...queryengine.plan import Query, SubQ
from ...queryengine.simulator import (CostModel, DEFAULT_COST, StageStats,
                                      simulate_stage_rows, stage_stats_batch)
from ...queryengine.trace import _alpha_stats
from ..models.perf_model import PerfModel, make_nondecision
from ..moo import hmooc as _hmooc
from ..moo import pareto as _pareto
from ..moo.pareto import pareto_mask_fast
from .objectives import resource_rate
from .spark_space import theta_c_space, theta_p_space, theta_s_space

__all__ = ["RuntimeOptimizerBackend", "ScoreRequest", "score_requests",
           "weighted_pick_batch", "sample_candidate_pools", "fusion_key",
           "make_runtime_optimizers", "stage_pressure", "structural_gamma",
           "structural_pressure"]

# Reference partition size for the γ task-pressure proxy: the runtime does
# not know a co-running stage's final partition count (it depends on that
# stage's own θ decisions), so pressure is measured against a fixed
# 128 MB advisory partition — θ-independent, hence deterministic and
# identical however requests are batched.
GAMMA_REF_PART_BYTES = 128e6


def stage_pressure(subq: SubQ) -> Tuple[float, float]:
    """(task, work) pressure proxy of one stage, from its true statistics.

    Tasks ≈ input bytes over the reference partition size; work ≈ input GB
    weighted by the stage CPU weight (the simulator's c_* coefficients are
    O(seconds/GB), so this lands on the task-seconds scale the trace-time γ
    was computed on).
    """
    b = float(sum(subq.input_bytes))
    tasks = max(1.0, b / GAMMA_REF_PART_BYTES)
    work = (b / 1e9) * float(subq.cpu_weight)
    return tasks, work


def structural_pressure(query: Query) -> Tuple[np.ndarray, np.ndarray]:
    """Per-stage raw contention sums: ((m, 3) [tasks, work, n_sib], (m,) d).

    A stage's concurrent companions are its same-depth siblings — the
    stages a scheduler would run alongside it — mirroring the trace-time
    definition (:func:`repro.queryengine.trace.collect_traces`), but with
    statistics-based pressure proxies (:func:`stage_pressure`) instead of
    simulated task counts, so the sums are available *before* execution
    and depend only on the query.
    """
    depths = query.subq_depths()
    m = query.n_subqs
    pres = np.asarray([stage_pressure(sq) for sq in query.subqs], np.float64)
    d = np.asarray([depths[i] for i in range(m)], np.float64)
    raw = np.zeros((m, 3), np.float64)
    for i in range(m):
        sib = [j for j in range(m) if d[j] == d[i] and j != i]
        raw[i] = [pres[sib, 0].sum() if sib else 0.0,
                  pres[sib, 1].sum() if sib else 0.0, len(sib)]
    return raw, d


def structural_gamma(query: Query) -> np.ndarray:
    """(m, 4) per-stage γ from the query's own co-running stages.

    Depends only on the query, so it is bit-identical however the serving
    layer slices or fuses requests — the parity-preserving default.
    """
    from ...core.models.features import contention_gamma
    raw, d = structural_pressure(query)
    return contention_gamma(raw[:, 0], raw[:, 1], raw[:, 2], d)


def fusion_key(rq: "ScoreRequest") -> tuple:
    """Group key under which :func:`score_requests` fuses a request."""
    model = rq.backend.model_for(rq.decision)
    if model is not None:
        # repro: allow[RP004] within-process fusion grouping token: only group *membership* affects batching, outputs are row-independent, and the key is never serialized or compared across workers
        return ("model", rq.decision, id(model))
    # repro: allow[RP004] same within-process grouping token as above for the oracle cost object
    return ("oracle", rq.subq.kind, id(rq.backend.cost))


def sample_candidate_pools(seed: int, n_candidates: int
                           ) -> Tuple[np.ndarray, np.ndarray]:
    """One LHS draw of the runtime θp/θs candidate pools.

    Query-independent (the pools only depend on the parameter spaces), so a
    serving session shares one draw across every concurrent query — exactly
    the arrays a standalone per-query backend would draw for the same seed.
    """
    ps, ss = theta_p_space(), theta_s_space()
    rng = np.random.default_rng(seed)
    pool_p = ps.to_raw(ps.sample_lhs(rng, n_candidates))
    pool_s = ss.to_raw(ss.sample_lhs(rng, n_candidates))
    return pool_p, pool_s


def weighted_pick_batch(Fs: Sequence[np.ndarray],
                        weights) -> List[int]:
    """Weighted-best row index per candidate objective set.

    ``weights`` is one (2,) preference vector shared by every set, or a
    per-set (R, 2) stack — the multi-tenant serving shape, where each
    request carries its tenant's preference.  Per-set weights fuse by
    distinct weight row; every pick normalizes and scores within its own
    candidate set only, so on the numpy routing (the CPU default) grouping
    never changes any set's winner: a single-tenant batch resolves
    bit-identically to the shared-weights path.  Above the env-gated
    kernel thresholds the usual f32 caveat (below) additionally applies to
    the *group size*: splitting by weight row shrinks the fused score
    volume, which can route a group to numpy f64 where the homogeneous
    batch would hit the f32 kernel.

    Per set: dominated rows are dropped (``pareto_mask_fast`` — the Pallas
    ``pareto_filter`` kernel above ``REPRO_PARETO_KERNEL_MIN_N``), all rows
    are min-max normalized over the full set, and the weighted-sum argmin
    over the survivors routes through the ``ws_reduce`` kernel when the
    fused score volume (sets × bank) clears ``REPRO_WS_KERNEL_MIN_SCORES``
    (float64 numpy below) — the same env-gated thresholds as the
    compile-time solver.  Single-request and fused serving calls share
    this code, so on the numpy routing (the CPU default) their picks are
    identical; above the kernel thresholds the fused call may score in
    float32 while a lone request stays on numpy, the same f32-vs-f64
    caveat the compile-time kernel routing documents.
    """
    R = len(Fs)
    if R == 0:
        return []
    w = np.asarray(weights, np.float64)
    if w.ndim == 2:
        if w.shape[0] != R:
            raise ValueError(
                f"got {w.shape[0]} weight rows for {R} candidate sets")
        groups: Dict[tuple, List[int]] = {}
        for r, row in enumerate(map(tuple, w.tolist())):
            groups.setdefault(row, []).append(r)
        if len(groups) == 1:
            return weighted_pick_batch(Fs, next(iter(groups)))
        out = [0] * R
        for row, idxs in groups.items():
            for i, j in zip(idxs, weighted_pick_batch([Fs[i] for i in idxs],
                                                      row)):
                out[i] = j
        return out
    # Dominance prefiltering only pays when the set is large enough to hit
    # the Pallas kernel; below the threshold the weighted argmin alone is
    # already exact (a dominated row cannot win the weighted sum).
    thr = _pareto._KERNEL_MIN_N if _pareto._KERNEL_MIN_N is not None \
        else _pareto._default_kernel_min_n()
    kept: List[np.ndarray] = []
    Fn_kept: List[np.ndarray] = []
    for F in Fs:
        F = np.asarray(F, np.float64)
        lo, hi = F.min(0), F.max(0)
        span = np.where(hi > lo, hi - lo, 1.0)
        if F.shape[0] >= thr:
            keep = np.nonzero(pareto_mask_fast(F))[0]
            if keep.size == 0:
                keep = np.arange(F.shape[0])
        else:
            keep = np.arange(F.shape[0])
        kept.append(keep)
        Fn_kept.append((F[keep] - lo) / span)
    k = Fn_kept[0].shape[1]
    B = max(f.shape[0] for f in Fn_kept)
    Fb = np.full((R, B, k), 1e18)
    for r, f in enumerate(Fn_kept):
        Fb[r, :f.shape[0]] = f
    # Tie-tolerant routing (same contract as `pareto_mask_fast`): the
    # kernel computes the weighted argmin in f32, so batches whose
    # f64-distinct normalized scores collide as f32 take the f64 numpy
    # argmin regardless of volume.
    if R * B >= _hmooc._ws_min_scores() \
            and not _pareto._f32_tie_hazard(Fb.reshape(-1, k)):
        from ...kernels.ws_reduce import ws_reduce  # lazy: optional layer
        _, idx = ws_reduce(Fb, w[None, :])           # (1, R)
        j = np.asarray(idx, int)[0]
    else:
        j = np.argmin((Fb * w).sum(-1), axis=-1)
    return [int(kept[r][j[r]]) for r in range(R)]


class RuntimeOptimizerBackend:
    """Per-query runtime re-optimization state: pools, seeds, scoring."""

    def __init__(
        self,
        query: Query,
        theta_c_raw: np.ndarray,
        *,
        seed_theta_p: Optional[np.ndarray] = None,   # (m, 9) compile seeds
        seed_theta_s: Optional[np.ndarray] = None,   # (m, 2)
        model_subq: Optional[PerfModel] = None,
        model_qs: Optional[PerfModel] = None,
        weights: Tuple[float, float] = (0.9, 0.1),
        n_candidates: int = 64,
        cost: CostModel = DEFAULT_COST,
        seed: int = 0,
        pools: Optional[Tuple[np.ndarray, np.ndarray]] = None,
        gamma_by_stage: Optional[np.ndarray] = None,
    ):
        """``gamma_by_stage`` is the (m, 4) per-stage contention vector fed
        to model-backed re-scoring.  ``None`` (the default) derives it with
        :func:`structural_gamma` when any model is attached — the paper's
        §4.3 γ features, no longer zeroed at runtime; pass an explicit
        ``np.zeros((m, 4))`` to restore the zeroed-γ behavior."""
        self.query = query
        self.cost = cost
        self.weights = weights
        self.model_subq = model_subq
        self.model_qs = model_qs
        if gamma_by_stage is None and (model_subq is not None
                                       or model_qs is not None):
            gamma_by_stage = structural_gamma(query)
        self.gamma_by_stage = gamma_by_stage
        self.seed_theta_p = seed_theta_p
        self.seed_theta_s = seed_theta_s
        self.cs, self.ps, self.ss = (theta_c_space(), theta_p_space(),
                                     theta_s_space())
        self.tc_row = np.asarray(theta_c_raw, np.float64).reshape(1, -1)
        self.tc_unit = self.cs.to_unit(self.tc_row)[0]
        self.rate = resource_rate(self.tc_row, cost)[0]
        # Candidate pools are fixed per query (one LHS draw), plus per-stage
        # compile-time seeds — the runtime MOO just rescores them on true
        # stats.  ``pools`` lets a serving session share the draw.
        if pools is None:
            pools = sample_candidate_pools(seed, n_candidates)
        self.pool_p, self.pool_s = pools

    # -- candidate sets ------------------------------------------------------
    def lqp_candidates(self, subq: SubQ, theta_p_cur: np.ndarray
                       ) -> Tuple[np.ndarray, np.ndarray]:
        """θp candidates for an L̄QP request (θs pinned to the stage seed)."""
        cands = [self.pool_p, theta_p_cur[None, :]]
        if self.seed_theta_p is not None:
            cands.append(self.seed_theta_p[subq.sq_id][None, :])
        tp = np.concatenate(cands, 0)
        ts = (self.seed_theta_s[subq.sq_id]
              if self.seed_theta_s is not None
              else self.ss.default_raw())[None, :]
        return tp, ts

    def qs_candidates(self, subq: SubQ, theta_s_cur: np.ndarray
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """θs candidates for a QS request (θp pinned to the stage seed)."""
        cands = [self.pool_s, theta_s_cur[None, :]]
        if self.seed_theta_s is not None:
            cands.append(self.seed_theta_s[subq.sq_id][None, :])
        ts = np.concatenate(cands, 0)
        tp = (self.seed_theta_p[subq.sq_id]
              if self.seed_theta_p is not None
              else self.ps.default_raw())[None, :]
        return tp, ts

    def request_for(self, req) -> Tuple["ScoreRequest", np.ndarray]:
        """AQE request → (scoring request, the candidate rows it ranks).

        ``req`` is an :class:`~repro.queryengine.aqe.LQPRequest` /
        ``QSRequest`` (duck-typed on ``kind``); the returned candidate rows
        are what the optimizer's response is drawn from.
        """
        if req.kind == "lqp":
            tp, ts = self.lqp_candidates(req.subq, req.theta_p)
            return ScoreRequest(self, req.subq, tp, ts, "lqp"), tp
        tp, ts = self.qs_candidates(req.subq, req.theta_s)
        return ScoreRequest(self, req.subq, tp, ts, "qs"), ts

    # -- scoring helpers -----------------------------------------------------
    def model_for(self, decision: str) -> Optional[PerfModel]:
        return self.model_subq if decision == "lqp" else self.model_qs

    def model_theta(self, rq: "ScoreRequest", n: int) -> np.ndarray:
        """Unit decision vector rows for the request's model family."""
        tcu = np.broadcast_to(self.tc_unit, (n, self.cs.dim))
        tsu = self.ss.to_unit(np.broadcast_to(rq.theta_s, (n, self.ss.dim)))
        if rq.decision == "lqp":
            tpu = self.ps.to_unit(
                np.broadcast_to(rq.theta_p, (n, self.ps.dim)))
            return np.concatenate([tcu, tpu, tsu], -1)
        # QS decision: θp is already fixed when a QS is optimized — the QS
        # model drops it (θc ⊕ θs).
        return np.concatenate([tcu, tsu], -1)

    def nondecision(self, subq: SubQ,
                    gamma: Optional[np.ndarray] = None) -> np.ndarray:
        """Runtime non-decision vector: α from *true* statistics, γ from
        the request (live contention) or the backend's per-stage default."""
        if gamma is None and self.gamma_by_stage is not None:
            gamma = self.gamma_by_stage[subq.sq_id]
        return make_nondecision(
            _alpha_stats(subq.input_rows, subq.input_bytes), gamma=gamma)

    def objectives(self, lat: np.ndarray, io: np.ndarray) -> np.ndarray:
        return np.stack(
            [lat * 1.0, lat * self.rate + io * self.cost.price_io_gb], -1)


@dataclasses.dataclass
class ScoreRequest:
    """One stage re-scoring request over a candidate θ set."""

    backend: RuntimeOptimizerBackend
    subq: SubQ
    theta_p: np.ndarray          # (np_rows, 9) raw; 1 row when pinned
    theta_s: np.ndarray          # (ns_rows, 2) raw; 1 row when pinned
    decision: str                # "lqp" | "qs"
    gamma: Optional[np.ndarray] = None   # (4,) live-contention override

    @property
    def n(self) -> int:
        return max(self.theta_p.shape[0], self.theta_s.shape[0])


def score_requests(reqs: Sequence[ScoreRequest]) -> List[np.ndarray]:
    """True-statistics objectives, (n, 2) per request, fused across requests.

    Requests group by backend mode — oracle requests by stage kind (and cost
    model), model requests by model — and each group resolves in ONE
    ``simulate_stage_rows`` / ``PerfModel.predict`` call over the stacked
    candidate rows of every member: the serving layer's cross-query fusion.
    """
    out: List[Optional[np.ndarray]] = [None] * len(reqs)
    groups: Dict[tuple, List[int]] = {}
    for i, rq in enumerate(reqs):
        groups.setdefault(fusion_key(rq), []).append(i)
    for key, members in groups.items():
        if key[0] == "oracle":
            _score_oracle_group(reqs, members, out)
        else:
            _score_model_group(reqs, members, key[1], out)
    return out  # type: ignore[return-value]


def _score_oracle_group(reqs: Sequence[ScoreRequest], members: List[int],
                        out: List[Optional[np.ndarray]]) -> None:
    ns = [reqs[i].n for i in members]
    base = stage_stats_batch([reqs[i].subq for i in members])
    stats = StageStats(**{
        f.name: np.repeat(getattr(base, f.name), ns)
        for f in dataclasses.fields(StageStats)})
    tc = np.concatenate([np.broadcast_to(reqs[i].backend.tc_row, (n, 8))
                         for i, n in zip(members, ns)])
    tp = np.concatenate([np.broadcast_to(reqs[i].theta_p, (n, 9))
                         for i, n in zip(members, ns)])
    ts = np.concatenate([np.broadcast_to(reqs[i].theta_s, (n, 2))
                         for i, n in zip(members, ns)])
    sim = simulate_stage_rows(
        reqs[members[0]].subq.kind, stats, tc, tp, ts,
        cost=reqs[members[0]].backend.cost, aqe=True)
    lo = 0
    for i, n in zip(members, ns):
        sl = slice(lo, lo + n)
        lo += n
        out[i] = reqs[i].backend.objectives(sim.ana_latency[sl],
                                            sim.io_gb[sl])


def _score_model_group(reqs: Sequence[ScoreRequest], members: List[int],
                       decision: str,
                       out: List[Optional[np.ndarray]]) -> None:
    model = reqs[members[0]].backend.model_for(decision)
    ns = [reqs[i].n for i in members]
    thetas, embs, nonds = [], [], []
    for i, n in zip(members, ns):
        rq = reqs[i]
        b = rq.backend
        emb = model.embed(b.query, rq.subq.sq_id)
        nond = b.nondecision(rq.subq, gamma=rq.gamma)
        thetas.append(b.model_theta(rq, n))
        embs.append(np.broadcast_to(emb, (n, emb.shape[0])))
        nonds.append(np.broadcast_to(nond, (n, nond.shape[0])))
    theta = np.concatenate(thetas).astype(np.float32)
    emb = np.concatenate(embs)
    nond = np.concatenate(nonds)
    # Row-bucket to a power of two so the jitted regressor head compiles
    # O(log n) shapes across a serving session.
    total = theta.shape[0]
    bucket = max(64, 1 << int(np.ceil(np.log2(max(total, 2)))))
    if bucket > total:
        pad = bucket - total
        theta = np.concatenate(
            [theta, np.zeros((pad, theta.shape[1]), theta.dtype)])
        emb = np.concatenate([emb, np.zeros((pad, emb.shape[1]), emb.dtype)])
        nond = np.concatenate(
            [nond, np.zeros((pad, nond.shape[1]), nond.dtype)])
    pred = model.predict(emb, theta, nond)[:total]
    lo = 0
    for i, n in zip(members, ns):
        sl = slice(lo, lo + n)
        lo += n
        out[i] = reqs[i].backend.objectives(pred[sl, 0], pred[sl, 1])


def make_runtime_optimizers(
    query: Query,
    theta_c_raw: np.ndarray,
    *,
    seed_theta_p: Optional[np.ndarray] = None,   # (m, 9) compile-time seeds
    seed_theta_s: Optional[np.ndarray] = None,   # (m, 2)
    model_subq: Optional[PerfModel] = None,
    model_qs: Optional[PerfModel] = None,
    weights: Tuple[float, float] = (0.9, 0.1),
    n_candidates: int = 64,
    cost: CostModel = DEFAULT_COST,
    seed: int = 0,
    pools: Optional[Tuple[np.ndarray, np.ndarray]] = None,
    gamma_by_stage: Optional[np.ndarray] = None,
):
    """Build (lqp_optimizer, qs_optimizer) callbacks for ``run_with_aqe``."""
    b = RuntimeOptimizerBackend(
        query, theta_c_raw, seed_theta_p=seed_theta_p,
        seed_theta_s=seed_theta_s, model_subq=model_subq, model_qs=model_qs,
        weights=weights, n_candidates=n_candidates, cost=cost, seed=seed,
        pools=pools, gamma_by_stage=gamma_by_stage)

    def lqp_optimizer(*, query: Query, subq: SubQ, theta_c: np.ndarray,
                      theta_p: np.ndarray) -> Optional[np.ndarray]:
        """Re-tune θp for the collapsed plan exposing ``subq`` (a join)."""
        tp, ts = b.lqp_candidates(subq, theta_p)
        F = score_requests([ScoreRequest(b, subq, tp, ts, "lqp")])[0]
        return tp[weighted_pick_batch([F], b.weights)[0]]

    def qs_optimizer(*, query: Query, subq: SubQ, theta_c: np.ndarray,
                     theta_s: np.ndarray) -> Optional[np.ndarray]:
        """Re-tune θs for a newly created query stage."""
        tp, ts = b.qs_candidates(subq, theta_s)
        F = score_requests([ScoreRequest(b, subq, tp, ts, "qs")])[0]
        return ts[weighted_pick_batch([F], b.weights)[0]]

    return lqp_optimizer, qs_optimizer
