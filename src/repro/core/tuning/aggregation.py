"""Aggregating fine-grained θp/θs into the single submission copy.

Spark accepts exactly one copy of θp and θs at query submission (paper §5.2,
App. C.2).  The compile-time optimizer produces per-subQ copies; this module
folds them into the initial submission values:

* Join thresholds (s3 maxShuffledHashJoinLocalMapThreshold, s4
  autoBroadcastJoinThreshold): take the **smallest** value among join-rooted
  subQs — a high threshold applied query-wide could force a broadcast from
  wrong compile-time cardinalities that AQE can never undo, while a low one
  only defers the decision to runtime where statistics are exact.  Values are
  **capped at the Spark defaults** (10 MB broadcast / 0 MB shuffled-hash) so
  small scan-rooted joins still broadcast promptly.
* All other θp/θs entries: element-wise median across subQs (robust center;
  the runtime optimizer re-tunes them per stage anyway).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from ...queryengine.plan import Query

__all__ = ["aggregate_submission_theta"]

# Indices in the θp vector (see spark_space.THETA_P).
_IDX_S3 = 2   # maxShuffledHashJoinLocalMapThreshold (MB)
_IDX_S4 = 3   # autoBroadcastJoinThreshold (MB)
_CAP_S3_MB = 0.0
_CAP_S4_MB = 10.0


def aggregate_submission_theta(
    query: Query,
    theta_p_sub: np.ndarray,
    theta_s_sub: np.ndarray,
) -> Tuple[np.ndarray, np.ndarray]:
    """(m, 9) raw θp + (m, 2) raw θs → submission copies (9,), (2,)."""
    theta_p_sub = np.asarray(theta_p_sub, np.float64)
    theta_s_sub = np.asarray(theta_s_sub, np.float64)
    theta_p0 = np.median(theta_p_sub, axis=0)
    theta_s0 = np.median(theta_s_sub, axis=0)

    join_ids = [sq.sq_id for sq in query.subqs if sq.kind == "join"]
    if join_ids:
        # Smallest threshold among join subQs, capped at the defaults.
        theta_p0[_IDX_S3] = min(float(theta_p_sub[join_ids, _IDX_S3].min()),
                                _CAP_S3_MB) if _CAP_S3_MB > 0 else 0.0
        theta_p0[_IDX_S4] = min(float(theta_p_sub[join_ids, _IDX_S4].min()),
                                _CAP_S4_MB)
    return theta_p0, theta_s0
