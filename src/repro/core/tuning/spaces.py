"""Tunable-parameter spaces.

A :class:`ParamSpace` is an ordered set of scalar parameters.  The MOO solvers
and learned models operate on the **unit hypercube** ``[0, 1]^d``; the
environment (query simulator / cluster cost model) consumes **raw** values.
Integer and boolean parameters round on conversion, log-scaled parameters map
exponentially — so the solvers stay fully continuous/vectorized while the
environment sees realistic knob values.

This module is shared between the Spark reproduction (``spark_space``)
and the cluster autotuner (``repro.cluster.params``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

__all__ = ["Param", "ParamSpace"]


@dataclasses.dataclass(frozen=True)
class Param:
    """One tunable scalar.

    kind: "float" | "int" | "bool" | "cat".
    For "cat", ``choices`` holds the raw values; unit value indexes into it.
    """

    name: str
    kind: str = "float"
    lo: float = 0.0
    hi: float = 1.0
    log: bool = False
    default: float = 0.0
    choices: Optional[Sequence[float]] = None

    def to_raw(self, u: np.ndarray) -> np.ndarray:
        u = np.clip(u, 0.0, 1.0)
        if self.kind == "bool":
            return (u >= 0.5).astype(np.float64)
        if self.kind == "cat":
            c = np.asarray(self.choices, np.float64)
            idx = np.minimum((u * len(c)).astype(int), len(c) - 1)
            return c[idx]
        if self.log:
            lo, hi = np.log(self.lo), np.log(self.hi)
            raw = np.exp(lo + u * (hi - lo))
        else:
            raw = self.lo + u * (self.hi - self.lo)
        if self.kind == "int":
            raw = np.rint(raw)
        return raw

    def to_unit(self, raw: np.ndarray) -> np.ndarray:
        raw = np.asarray(raw, np.float64)
        if self.kind == "bool":
            return raw.astype(np.float64)
        if self.kind == "cat":
            c = np.asarray(self.choices, np.float64)
            idx = np.array([int(np.argmin(np.abs(c - r))) for r in np.atleast_1d(raw)])
            u = (idx + 0.5) / len(c)
            return u.reshape(raw.shape)
        if self.log:
            lo, hi = np.log(self.lo), np.log(self.hi)
            return (np.log(np.clip(raw, self.lo, self.hi)) - lo) / (hi - lo)
        return (np.clip(raw, self.lo, self.hi) - self.lo) / (self.hi - self.lo)


class ParamSpace:
    """Ordered collection of :class:`Param` with vectorized conversions."""

    def __init__(self, params: Sequence[Param]):
        self.params: List[Param] = list(params)
        self.names = [p.name for p in self.params]
        self._index = {p.name: i for i, p in enumerate(self.params)}

    @property
    def dim(self) -> int:
        return len(self.params)

    def index(self, name: str) -> int:
        return self._index[name]

    def __getitem__(self, name: str) -> Param:
        return self.params[self._index[name]]

    # -- conversions ------------------------------------------------------
    def to_raw(self, unit: np.ndarray) -> np.ndarray:
        """(..., d) unit -> (..., d) raw."""
        unit = np.asarray(unit, np.float64)
        out = np.empty_like(unit)
        for i, p in enumerate(self.params):
            out[..., i] = p.to_raw(unit[..., i])
        return out

    def to_unit(self, raw: np.ndarray) -> np.ndarray:
        raw = np.asarray(raw, np.float64)
        out = np.empty_like(raw)
        for i, p in enumerate(self.params):
            out[..., i] = p.to_unit(raw[..., i])
        return out

    def default_unit(self) -> np.ndarray:
        return self.to_unit(np.array([p.default for p in self.params]))

    def default_raw(self) -> np.ndarray:
        return np.array([p.default for p in self.params], np.float64)

    def raw_dict(self, raw_row: np.ndarray) -> Dict[str, float]:
        return {p.name: float(raw_row[i]) for i, p in enumerate(self.params)}

    # -- sampling ---------------------------------------------------------
    def sample_lhs(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """Latin Hypercube Sample in the unit cube, shape (n, d)."""
        d = self.dim
        u = (rng.permuted(np.tile(np.arange(n), (d, 1)), axis=1).T + rng.random((n, d))) / n
        return u

    def sample_uniform(self, rng: np.random.Generator, n: int) -> np.ndarray:
        return rng.random((n, self.dim))

    def sample_grid(self, levels: int) -> np.ndarray:
        """Full-factorial grid with ``levels`` points/dim (use for small d)."""
        axes = [np.linspace(0.05, 0.95, levels)] * self.dim
        mesh = np.meshgrid(*axes, indexing="ij")
        return np.stack([m.ravel() for m in mesh], -1)

    # -- snapping ---------------------------------------------------------
    def snap_unit(self, unit: np.ndarray) -> np.ndarray:
        """Round unit values through raw space (ints/bools/cats quantize)."""
        return self.to_unit(self.to_raw(unit))

    def quantized_levels(self, i: int) -> Optional[np.ndarray]:
        """Unit-space levels for discrete param i (None for continuous)."""
        p = self.params[i]
        if p.kind == "bool":
            return np.array([0.0, 1.0])
        if p.kind == "cat":
            n = len(p.choices)
            return (np.arange(n) + 0.5) / n
        if p.kind == "int":
            n_levels = int(p.hi - p.lo) + 1
            if n_levels <= 64:
                return p.to_unit(np.arange(p.lo, p.hi + 1))
        return None


def concat_unit(*arrays: np.ndarray) -> np.ndarray:
    return np.concatenate([np.asarray(a, np.float64) for a in arrays], axis=-1)


def as_jnp(x: np.ndarray) -> jnp.ndarray:
    return jnp.asarray(x, jnp.float32)
