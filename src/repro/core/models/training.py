"""Training + evaluation for the three performance-model targets.

Reproduces paper §6.1: traces split 8:1:1 by query, AdamW on Huber(log1p),
metrics = WMAPE / P50 / P90 relative error / Pearson correlation / inference
throughput (paper Table 3).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...queryengine.trace import TraceSet
from .features import batch_graphs, featurize_plan, featurize_subq
from .gtn import GTNConfig
from .nn import adamw_init, adamw_update
from .perf_model import (TARGET_EPS, ModelConfig, PerfModel,
                         make_nondecision)

__all__ = ["RowDataset", "build_dataset", "train_model", "evaluate",
           "Metrics", "train_all_models"]


@dataclasses.dataclass
class RowDataset:
    """Row-wise dataset with shared (deduplicated) graph tensors."""

    graphs: Tuple[np.ndarray, ...]   # (G, N, ·) stacked graph tensors
    graph_id: np.ndarray             # (S,) row -> graph index
    theta: np.ndarray                # (S, θd) unit decision variables
    nond: np.ndarray                 # (S, 12)
    y: np.ndarray                    # (S, 2) raw targets
    masks: Dict[str, np.ndarray]     # train/val/test row masks

    def subset(self, name: str) -> "RowDataset":
        m = self.masks[name]
        return RowDataset(self.graphs, self.graph_id[m], self.theta[m],
                          self.nond[m], self.y[m],
                          {name: np.ones(m.sum(), bool)})

    @property
    def n(self) -> int:
        return self.theta.shape[0]


def _lqp_pad(traces: TraceSet) -> int:
    mx = max(len(q.ops) for q in traces.queries)
    return int(np.ceil(mx / 16) * 16)


def build_dataset(traces: TraceSet, kind: str, seed: int = 0) -> Tuple[
        RowDataset, ModelConfig]:
    """Assemble the row dataset + model config for one target kind."""
    splits = traces.split(seed=seed)
    if kind in ("subq", "qs"):
        use_est = kind == "subq"
        pad = 4
        # Distinct graphs: one per (query, subq).
        keys = {}
        glist = []
        gid = np.zeros(traces.query_idx.shape[0], int)
        for r, (qi, si) in enumerate(zip(traces.query_idx, traces.subq_idx)):
            k = (int(qi), int(si))
            if k not in keys:
                keys[k] = len(glist)
                glist.append(featurize_subq(traces.queries[qi], si,
                                            use_est=use_est, n_pad=pad))
            gid[r] = keys[k]
        gb = batch_graphs(glist)
        if kind == "subq":
            theta = np.concatenate(
                [traces.theta_c, traces.theta_p, traces.theta_s], -1)
            nond = make_nondecision(traces.alpha_cbo)
        else:
            theta = np.concatenate([traces.theta_c, traces.theta_s], -1)
            nond = make_nondecision(traces.alpha_true, traces.beta,
                                    traces.gamma)
        y = traces.y_subq
        masks = {k: v[0] for k, v in splits.items()}
    elif kind == "lqp":
        pad = _lqp_pad(traces)
        glist = [featurize_plan(q, use_est=False, n_pad=pad)
                 for q in traces.queries]
        gb = batch_graphs(glist)
        gid = traces.q_query_idx.copy()
        theta = np.concatenate(
            [traces.q_theta_c, traces.q_theta_p, traces.q_theta_s], -1)
        nond = make_nondecision(traces.q_alpha)
        y = traces.y_query
        masks = {k: v[1] for k, v in splits.items()}
    else:
        raise ValueError(kind)

    ds = RowDataset((gb.X, gb.pe, gb.bias, gb.mask), gid,
                    theta.astype(np.float32), nond.astype(np.float32),
                    y.astype(np.float32), masks)
    cfg = ModelConfig(kind=kind, theta_dim=theta.shape[1],
                      gtn=GTNConfig())
    return ds, cfg


def _huber(res: jnp.ndarray, delta: float = 1.0) -> jnp.ndarray:
    a = jnp.abs(res)
    return jnp.where(a <= delta, 0.5 * a * a, delta * (a - 0.5 * delta))


def train_model(ds: RowDataset, cfg: ModelConfig, *, steps: int = 1500,
                batch: int = 512, lr: float = 2e-3, seed: int = 0,
                verbose: bool = False) -> PerfModel:
    # Target normalization from the train split (z = (log(y+eps) - mu) / sd).
    tr_rows = ds.masks["train"]
    y_tr = ds.y[tr_rows] if tr_rows.any() else ds.y
    logy = np.log(np.maximum(y_tr, 0.0) + TARGET_EPS)
    stats = np.stack([logy.mean(0), np.maximum(logy.std(0), 1e-3)])
    model = PerfModel(cfg, seed=seed, target_stats=stats)
    params = model.params
    opt = adamw_init(params)
    apply_rows = model.apply_rows
    mu = jnp.asarray(stats[0]),
    z_mu = jnp.asarray(stats[0])
    z_sd = jnp.asarray(stats[1])

    def loss_fn(p, graphs, theta, nond, y):
        pred = apply_rows(p, graphs, theta, nond)
        z = (jnp.log(jnp.maximum(y, 0.0) + TARGET_EPS) - z_mu) / z_sd
        return _huber(pred - z).mean()

    @jax.jit
    def step_fn(p, opt, graphs, theta, nond, y, lr_now):
        loss, g = jax.value_and_grad(loss_fn)(p, graphs, theta, nond, y)
        p, opt = adamw_update(p, g, opt, lr_now)
        return p, opt, loss

    rng = np.random.default_rng(seed)
    tr = ds.masks["train"]
    idx_all = np.nonzero(tr)[0]
    if idx_all.size == 0:
        idx_all = np.arange(ds.n)
    batch = min(batch, idx_all.size)
    GX, GP, GB, GM = ds.graphs
    losses = []
    for t in range(steps):
        idx = rng.choice(idx_all, size=batch, replace=idx_all.size < batch * 2)
        gi = ds.graph_id[idx]
        graphs = (GX[gi], GP[gi], GB[gi], GM[gi])
        warm = min(1.0, (t + 1) / 100.0)
        decay = 0.5 * (1 + np.cos(np.pi * t / steps))
        lr_now = np.float32(lr * warm * (0.1 + 0.9 * decay))
        params, opt, loss = step_fn(params, opt, graphs, ds.theta[idx],
                                    ds.nond[idx], ds.y[idx], lr_now)
        losses.append(float(loss))
        if verbose and (t + 1) % 200 == 0:
            print(f"  step {t+1}/{steps} loss {np.mean(losses[-100:]):.4f}")
    return PerfModel(cfg, params=params, target_stats=stats)


@dataclasses.dataclass
class Metrics:
    wmape: np.ndarray     # per-target
    p50: np.ndarray
    p90: np.ndarray
    corr: np.ndarray
    xput: float           # regressor rows/s

    def row(self, i: int) -> str:
        return (f"WMAPE={self.wmape[i]:.3f} P50={self.p50[i]:.3f} "
                f"P90={self.p90[i]:.3f} Corr={self.corr[i]:.3f}")


def evaluate(model: PerfModel, ds: RowDataset, split: str = "test",
             max_rows: int = 20000) -> Metrics:
    m = ds.masks[split]
    idx = np.nonzero(m)[0]
    if idx.size > max_rows:
        idx = idx[:max_rows]
    GX, GP, GB, GM = ds.graphs
    preds = []
    for lo in range(0, idx.size, 2048):
        ii = idx[lo:lo + 2048]
        gi = ds.graph_id[ii]
        z = model.apply_rows(model.params, (GX[gi], GP[gi], GB[gi], GM[gi]),
                             ds.theta[ii], ds.nond[ii])
        preds.append(model.from_z(np.asarray(z)))
    pred = np.concatenate(preds, 0)
    truth = ds.y[idx]
    eps = 1e-6
    ae = np.abs(pred - truth)
    rel = ae / np.maximum(np.abs(truth), eps)
    wmape = ae.sum(0) / np.maximum(np.abs(truth).sum(0), eps)
    p50 = np.percentile(rel, 50, axis=0)
    p90 = np.percentile(rel, 90, axis=0)
    corr = np.array([np.corrcoef(pred[:, j], truth[:, j])[0, 1]
                     for j in range(truth.shape[1])])
    # Throughput of the solver-facing path (cached embedding + regressor).
    emb = np.zeros(model.cfg.gtn.d_model, np.float32)
    theta = np.random.default_rng(0).random(
        (8192, model.cfg.theta_dim)).astype(np.float32)
    nond = np.zeros(12, np.float32)
    model.predict(emb, theta, nond)  # compile
    t0 = time.perf_counter()
    for _ in range(5):
        model.predict(emb, theta, nond)
    xput = 5 * 8192 / (time.perf_counter() - t0)
    return Metrics(wmape, p50, p90, corr, xput)


def train_all_models(traces: TraceSet, *, steps: int = 1500,
                     lqp_steps: Optional[int] = None, seed: int = 0,
                     verbose: bool = False
                     ) -> Dict[str, Tuple[PerfModel, RowDataset, Metrics]]:
    """Train subQ / QS / L̄QP models from one trace set (paper Table 3)."""
    out = {}
    for kind in ("subq", "qs", "lqp"):
        ds, cfg = build_dataset(traces, kind, seed=seed)
        n_steps = steps if kind != "lqp" else (lqp_steps or max(300, steps // 3))
        bs = 512 if kind != "lqp" else 64
        model = train_model(ds, cfg, steps=n_steps, batch=bs, seed=seed,
                            verbose=verbose)
        met = evaluate(model, ds)
        if verbose:
            print(f"[{kind}] latency {met.row(0)} | io {met.row(1)} | "
                  f"xput {met.xput/1e3:.0f}K/s")
        out[kind] = (model, ds, met)
    return out
