"""Graph Transformer Network embedder (paper §4.3, [6, 56]).

Multi-head self-attention over operator nodes with (i) learned per-head
additive biases on graph-structure flags (forward edge, backward edge, self)
and (ii) Laplacian positional encodings added to the input projection —
the Dwivedi–Bresson graph-transformer recipe.  Masked mean-pool over valid
nodes produces the plan embedding that feeds the regressor.

Pure JAX; parameters are nested dicts (see ``nn.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .features import LAPPE_K, OP_FEAT_DIM
from .nn import Params, dense, dense_init, layernorm, layernorm_init, mlp, mlp_init

__all__ = ["GTNConfig", "gtn_init", "gtn_apply", "gtn_apply_batch"]


@dataclasses.dataclass(frozen=True)
class GTNConfig:
    d_model: int = 48
    n_heads: int = 4
    n_layers: int = 2
    d_ff: int = 96
    feat_dim: int = OP_FEAT_DIM
    pe_dim: int = LAPPE_K


def gtn_init(key: jax.Array, cfg: GTNConfig) -> Params:
    keys = jax.random.split(key, 2 + cfg.n_layers)
    p: Params = {
        "in_proj": dense_init(keys[0], cfg.feat_dim, cfg.d_model),
        "pe_proj": dense_init(keys[1], cfg.pe_dim, cfg.d_model, scale=0.5),
    }
    for i, k in enumerate(keys[2:]):
        ks = jax.random.split(k, 5)
        p[f"layer{i}"] = {
            "qkv": dense_init(ks[0], cfg.d_model, 3 * cfg.d_model),
            "out": dense_init(ks[1], cfg.d_model, cfg.d_model),
            "bias": 0.1 * jax.random.normal(ks[2], (cfg.n_heads, 3)),
            "ln1": layernorm_init(cfg.d_model),
            "ln2": layernorm_init(cfg.d_model),
            "ffn": mlp_init(ks[3], [cfg.d_model, cfg.d_ff, cfg.d_model]),
        }
    return p


def gtn_apply(p: Params, cfg: GTNConfig, X: jnp.ndarray, pe: jnp.ndarray,
              bias: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """One graph -> (d_model,) embedding.

    X: (N, F), pe: (N, K), bias: (N, N, 3) structure flags, mask: (N,).
    """
    N = X.shape[0]
    h = dense(p["in_proj"], X) + dense(p["pe_proj"], pe)
    dh = cfg.d_model // cfg.n_heads
    neg = jnp.float32(-1e9)
    attn_mask = jnp.where(mask[None, :], 0.0, neg)  # (1, N) key mask

    for i in range(cfg.n_layers):
        lp = p[f"layer{i}"]
        hn = layernorm(lp["ln1"], h)
        qkv = dense(lp["qkv"], hn).reshape(N, 3, cfg.n_heads, dh)
        q, k, v = qkv[:, 0], qkv[:, 1], qkv[:, 2]        # (N, H, dh)
        logits = jnp.einsum("nhd,mhd->hnm", q, k) / jnp.sqrt(dh)
        struct = jnp.einsum("nmf,hf->hnm", bias, lp["bias"])
        logits = logits + struct + attn_mask[None, :, :]
        w = jax.nn.softmax(logits, axis=-1)
        ctx = jnp.einsum("hnm,mhd->nhd", w, v).reshape(N, cfg.d_model)
        h = h + dense(lp["out"], ctx)
        hn = layernorm(lp["ln2"], h)
        h = h + mlp(lp["ffn"], hn)

    w = mask.astype(h.dtype)
    return (h * w[:, None]).sum(0) / jnp.maximum(w.sum(), 1.0)


def gtn_apply_batch(p: Params, cfg: GTNConfig, X: jnp.ndarray,
                    pe: jnp.ndarray, bias: jnp.ndarray,
                    mask: jnp.ndarray) -> jnp.ndarray:
    """(B, N, ·) batch -> (B, d_model)."""
    return jax.vmap(lambda x, e, b, m: gtn_apply(p, cfg, x, e, b, m))(
        X, pe, bias, mask)
