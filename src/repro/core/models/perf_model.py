"""GTN-embedder + regressor performance models (paper Fig. 6).

One :class:`PerfModel` per modeling target:

* ``subq`` — compile time: subQ operator group with CBO cardinalities;
  decision vars θc⊕θp⊕θs (19); α from CBO, β = 0, γ = 0.
* ``qs``   — runtime query stage: true cardinalities; θp dropped (already
  fixed when a QS is optimized) → θc⊕θs (10); α/β/γ observed.
* ``lqp``  — runtime collapsed plan: whole-plan graph; θc⊕θp⊕θs; predicts
  end-to-end latency of the (remaining) plan.

Targets are predicted in log1p space: [latency (s), IO (GB)].

The embedding of a plan/subQ does not depend on θ, so MOO solving caches the
GTN output once per (query, stage) and sweeps thousands of θ rows through the
small regressor — this is what makes sub-second solving feasible (paper's
60–462K inference/s).
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
from typing import Any, Dict, Iterable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...queryengine.plan import Query
from .features import batch_graphs, featurize_plan, featurize_subq
from .gtn import GTNConfig, gtn_apply, gtn_apply_batch, gtn_init
from .nn import Params, mlp, mlp_init

__all__ = ["ModelConfig", "PerfModel", "NONDECISION_DIM", "pow2_bucket"]

ALPHA_DIM = 5
BETA_DIM = 3
GAMMA_DIM = 4
NONDECISION_DIM = ALPHA_DIM + BETA_DIM + GAMMA_DIM


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    kind: str                      # "subq" | "qs" | "lqp"
    theta_dim: int                 # 19 for subq/lqp, 10 for qs
    gtn: GTNConfig = GTNConfig()
    hidden: Tuple[int, ...] = (128, 96)
    n_targets: int = 2

    @property
    def reg_in(self) -> int:
        return self.gtn.d_model + self.theta_dim + NONDECISION_DIM

    @property
    def pad(self) -> int:
        return 4 if self.kind in ("subq", "qs") else 128

    @property
    def use_est(self) -> bool:
        return self.kind == "subq"


TARGET_EPS = 1e-3


def pow2_bucket(n: int, lo: int = 64) -> int:
    """Smallest power of two ≥ max(n, lo).

    Batched inference pads its row axis to these buckets so a serving
    session only ever compiles O(log n_max) distinct signatures per jitted
    function, however request sizes vary.
    """
    return max(lo, 1 << (max(n, 1) - 1).bit_length())


def _head_max_bucket() -> int:
    """Row cap per regressor dispatch (``REPRO_HEAD_MAX_BUCKET``).

    Fused micro-batch solves can concatenate 100k+ rows; padding that to
    the next power of two wastes up to 2× compute.  Instead the rows are
    dispatched in chunks of at most this bucket: full chunks need no
    padding at all, only the tail pads (to its own pow2 bucket ≤ the cap),
    and the compiled-signature set stays the fixed ladder {64 … cap}.
    Resolved per call so tests/benchmarks can re-tune it.
    """
    import os

    b = int(os.environ.get("REPRO_HEAD_MAX_BUCKET", "8192"))
    return pow2_bucket(b)


class PerfModel:
    """Parameter container + jitted apply/predict paths.

    Targets are modeled in z-normalized log space:
    ``z = (log(y + eps) - mu) / sd`` with (mu, sd) from the training split —
    so optimizing the loss optimizes *relative* error across scales.
    """

    def __init__(self, cfg: ModelConfig, params: Optional[Params] = None,
                 seed: int = 0,
                 target_stats: Optional[np.ndarray] = None):
        self.cfg = cfg
        if params is None:
            key = jax.random.PRNGKey(seed)
            k1, k2 = jax.random.split(key)
            params = {
                "gtn": gtn_init(k1, cfg.gtn),
                "reg": mlp_init(k2, [cfg.reg_in, *cfg.hidden, cfg.n_targets]),
            }
        self.params = params
        # (2, n_targets): row 0 = mu, row 1 = sd of log(y + eps).
        if target_stats is None:
            target_stats = np.stack([np.zeros(cfg.n_targets),
                                     np.ones(cfg.n_targets)])
        self.target_stats = np.asarray(target_stats, np.float32)
        self._emb_cache: Dict[Any, np.ndarray] = {}
        self._fp: Optional[str] = None
        # Shape buckets seen by the padded batch paths (the recompilation
        # bound the serving benchmarks assert against).
        self.head_buckets: set = set()
        self.embed_buckets: set = set()

        cfg_gtn = cfg.gtn

        @jax.jit
        def _embed_batch(p, X, pe, bias, mask):
            return gtn_apply_batch(p["gtn"], cfg_gtn, X, pe, bias, mask)

        def _head_fn(p, emb, theta, nond):
            x = jnp.concatenate([emb, theta, nond], axis=-1)
            return mlp(p["reg"], x)

        self._head = jax.jit(_head_fn)
        # Padded batches are throwaway buffers: donate them on accelerators
        # (XLA reuses the space for the activations); CPU does not support
        # donation, so the plain variant is kept for it.
        self._head_donated = jax.jit(_head_fn, donate_argnums=(1, 2, 3))
        self._embed_batch = _embed_batch

    # -- forward -------------------------------------------------------------
    def apply_rows(self, params: Params, graphs, theta: jnp.ndarray,
                   nond: jnp.ndarray) -> jnp.ndarray:
        """Training path: embed per-row graphs and regress. Returns log1p y."""
        X, pe, bias, mask = graphs
        emb = gtn_apply_batch(params["gtn"], self.cfg.gtn, X, pe, bias, mask)
        x = jnp.concatenate([emb, theta, nond], axis=-1)
        return mlp(params["reg"], x)

    # -- identity -------------------------------------------------------------
    def fingerprint(self) -> str:
        """Stable content hash of the model (params + config + target stats).

        Serving caches key entries by this instead of ``id(model)``: the
        fingerprint survives process restarts and model reloads, never pins
        the live object, and an atomically swapped-in refreshed model gets a
        different fingerprint so stale entries can never be served (see
        ``ResponseCache.clear_model``).
        """
        if self._fp is None:
            h = hashlib.sha1()
            h.update(repr(self.cfg).encode())
            h.update(np.ascontiguousarray(self.target_stats).tobytes())
            for leaf in jax.tree_util.tree_leaves(self.params):
                a = np.asarray(leaf)
                h.update(str(a.shape).encode())
                h.update(np.ascontiguousarray(a).tobytes())
            self._fp = h.hexdigest()
        return self._fp

    # -- inference -----------------------------------------------------------
    def embed(self, query: Query, sq_id: Optional[int] = None) -> np.ndarray:
        """Cached GTN embedding for a subQ group or whole plan."""
        # repro: allow[RP004] id(query) only scopes the process-local embedding memo to one live Query object (qid alone can recur with different stats); the memo is never snapshotted and embeddings do not depend on the id value
        key = (id(query), query.qid, sq_id, self.cfg.kind)
        if key not in self._emb_cache:
            if self.cfg.kind in ("subq", "qs"):
                g = featurize_subq(query, sq_id, use_est=self.cfg.use_est,
                                   n_pad=self.cfg.pad)
            else:
                g = featurize_plan(query, use_est=True, n_pad=self.cfg.pad)
            gb = batch_graphs([g])
            emb = self._embed_batch(self.params, gb.X, gb.pe, gb.bias,
                                    gb.mask)
            self._emb_cache[key] = np.asarray(emb[0])
        return self._emb_cache[key]

    def embed_many(self, pairs: Sequence[Tuple[Query, Optional[int]]]) -> None:
        """Fill the embedding cache for many (query, sq_id) pairs at once.

        One padded GTN dispatch replaces the per-subQ batch-of-one calls of
        :meth:`embed` — the cold-path hotspot of a model-backed micro-batch
        solve.  The batch axis is padded to a power-of-two bucket (replicas
        of the first graph, sliced off afterwards) so varying batch sizes
        reuse a small fixed set of compiled signatures.  Per-row outputs are
        identical to :meth:`embed`'s: row j of a padded batch equals the
        batch-of-one embedding of graph j.
        """
        todo = []
        seen = set()
        for query, sq_id in pairs:
            # repro: allow[RP004] same process-local memo key as `embed` (see above); replay-invariant because only membership is observable, never the id value
            key = (id(query), query.qid, sq_id, self.cfg.kind)
            if key in self._emb_cache or key in seen:
                continue
            seen.add(key)
            if self.cfg.kind in ("subq", "qs"):
                g = featurize_subq(query, sq_id, use_est=self.cfg.use_est,
                                   n_pad=self.cfg.pad)
            else:
                g = featurize_plan(query, use_est=True, n_pad=self.cfg.pad)
            todo.append((key, g))
        if not todo:
            return
        n = len(todo)
        b = pow2_bucket(n, lo=8)
        graphs = [g for _, g in todo] + [todo[0][1]] * (b - n)
        gb = batch_graphs(graphs)
        self.embed_buckets.add(b)
        emb = np.asarray(self._embed_batch(self.params, gb.X, gb.pe,
                                           gb.bias, gb.mask))
        for j, (key, _) in enumerate(todo):
            self._emb_cache[key] = emb[j]

    # -- target transform ------------------------------------------------------
    def to_z(self, y: np.ndarray) -> np.ndarray:
        mu, sd = self.target_stats
        return (np.log(np.maximum(y, 0.0) + TARGET_EPS) - mu) / sd

    def from_z(self, z: np.ndarray) -> np.ndarray:
        mu, sd = self.target_stats
        return np.maximum(np.exp(z * sd + mu) - TARGET_EPS, 0.0)

    def predict(self, emb: np.ndarray, theta: np.ndarray,
                nond: np.ndarray) -> np.ndarray:
        """(n, θd) unit θ + (n, 12) or (12,) nondecision → (n, 2) raw targets.

        ``emb`` is one cached embedding (d,) broadcast over the rows, or a
        per-row (n, d) stack — the serving layer fuses re-scoring requests
        from different (query, stage) pairs into one call this way.
        """
        theta = np.asarray(theta, np.float32)
        n = theta.shape[0]
        if nond.ndim == 1:
            nond = np.broadcast_to(nond, (n, nond.shape[0]))
        emb = np.asarray(emb, np.float32)
        embb = emb if emb.ndim == 2 \
            else np.broadcast_to(emb, (n, emb.shape[0]))
        z = self._head(self.params, embb, theta,
                       np.asarray(nond, np.float32))
        return self.from_z(np.asarray(z))

    def predict_rows(self, emb: np.ndarray, theta: np.ndarray,
                     nond: np.ndarray) -> np.ndarray:
        """Like :meth:`predict` but per-row emb/nond, bucket-padded.

        The fused solve path concatenates regressor rows from every
        (query, subQ, candidate) of a micro-batch into one call here.  Rows
        are zero-padded to a power-of-two bucket so the compile cache sees
        O(log n_max) signatures across a serving session, and the padded
        buffers are donated to XLA on accelerator backends.  Per-row
        outputs equal :meth:`predict`'s on the same rows.
        """
        emb = np.ascontiguousarray(emb, np.float32)
        theta = np.ascontiguousarray(theta, np.float32)
        nond = np.ascontiguousarray(nond, np.float32)
        n = theta.shape[0]
        cap = _head_max_bucket()
        head = self._head if jax.default_backend() == "cpu" \
            else self._head_donated
        outs = []
        for off in range(0, n, cap):
            e = emb[off:off + cap]
            t = theta[off:off + cap]
            d = nond[off:off + cap]
            c = t.shape[0]
            # Calls larger than the cap reuse the cap signature for their
            # tail too (waste < cap rows on a multi-cap call); only calls
            # that fit in one chunk get a smaller bucket of the ladder.
            b = cap if n > cap else pow2_bucket(c)
            if b != c:
                ep = np.zeros((b, e.shape[1]), np.float32)
                ep[:c] = e
                tp = np.zeros((b, t.shape[1]), np.float32)
                tp[:c] = t
                dp = np.zeros((b, d.shape[1]), np.float32)
                dp[:c] = d
                e, t, d = ep, tp, dp
            self.head_buckets.add((b, theta.shape[1]))
            z = head(self.params, e, t, d)
            outs.append(np.asarray(z[:c]))
        return self.from_z(outs[0] if len(outs) == 1
                           else np.concatenate(outs, 0))

    def compile_stats(self) -> dict:
        """Signature accounting for the recompilation-bound assertions."""
        def _cache_size(f):
            try:
                return int(f._cache_size())
            except Exception:
                return -1
        return {"head_buckets": sorted(self.head_buckets),
                "embed_buckets": sorted(self.embed_buckets),
                "head_compiles": _cache_size(self._head),
                "embed_compiles": _cache_size(self._embed_batch)}

    # -- persistence ----------------------------------------------------------
    def save(self, path: str) -> None:
        flat, treedef = jax.tree_util.tree_flatten(self.params)
        np.savez(path, n=len(flat), target_stats=self.target_stats,
                 **{f"a{i}": np.asarray(x) for i, x in enumerate(flat)})

    @classmethod
    def load(cls, cfg: ModelConfig, path: str) -> "PerfModel":
        data = np.load(path)
        proto = cls(cfg)  # for treedef
        flat, treedef = jax.tree_util.tree_flatten(proto.params)
        loaded = [jnp.asarray(data[f"a{i}"]) for i in range(int(data["n"]))]
        params = jax.tree_util.tree_unflatten(treedef, loaded)
        return cls(cfg, params=params, target_stats=data["target_stats"])


def make_nondecision(alpha: np.ndarray, beta: Optional[np.ndarray] = None,
                     gamma: Optional[np.ndarray] = None) -> np.ndarray:
    """Assemble [α, β, γ] with paper's compile-time zeros convention."""
    alpha = np.asarray(alpha, np.float32)
    lead = alpha.shape[:-1]
    if beta is None:
        beta = np.zeros(lead + (BETA_DIM,), np.float32)
    if gamma is None:
        gamma = np.zeros(lead + (GAMMA_DIM,), np.float32)
    return np.concatenate([alpha, beta, gamma], axis=-1).astype(np.float32)
