"""Minimal neural-net building blocks in raw JAX (no flax/optax on box).

Parameters are nested dicts of jnp arrays ("pytrees").  Everything here is
jit/vmap-friendly and deterministic given a PRNGKey.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]

__all__ = ["dense_init", "dense", "mlp_init", "mlp", "layernorm_init",
           "layernorm", "adamw_init", "adamw_update", "tree_l2"]


def dense_init(key: jax.Array, d_in: int, d_out: int,
               scale: float = 1.0) -> Params:
    w = jax.random.normal(key, (d_in, d_out)) * scale / np.sqrt(d_in)
    return {"w": w, "b": jnp.zeros((d_out,))}


def dense(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["w"] + p["b"]


def mlp_init(key: jax.Array, dims: Sequence[int]) -> Params:
    keys = jax.random.split(key, len(dims) - 1)
    return {f"l{i}": dense_init(k, dims[i], dims[i + 1])
            for i, k in enumerate(keys)}


def mlp(p: Params, x: jnp.ndarray,
        act: Callable = jax.nn.gelu) -> jnp.ndarray:
    n = len(p)
    for i in range(n):
        x = dense(p[f"l{i}"], x)
        if i < n - 1:
            x = act(x)
    return x


def layernorm_init(d: int) -> Params:
    return {"g": jnp.ones((d,)), "b": jnp.zeros((d,))}


def layernorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    mu = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * p["g"] + p["b"]


# ---------------------------------------------------------------------------
# AdamW (pytree optimizer)
# ---------------------------------------------------------------------------

def adamw_init(params: Params) -> Params:
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adamw_update(params: Params, grads: Params, state: Params, lr: float,
                 *, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
                 wd: float = 1e-4) -> Tuple[Params, Params]:
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                               state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                               state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t)
    vhat_scale = 1.0 / (1 - b2 ** t)

    def upd(p, m_, v_):
        return p - lr * (m_ * mhat_scale / (jnp.sqrt(v_ * vhat_scale) + eps)
                         + wd * p)

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


def tree_l2(tree: Params) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l * l) for l in leaves))
