"""Plan featurization for the GTN embedder (paper §4.3).

Per-operator composite encoding:
  one-hot op type (10) ⊕ log cardinality (rows, bytes) ⊕ hashed predicate
  embedding (8) — the paper uses word2vec predicate averages; offline we use
  a seeded random hash table, which plays the same role (a fixed lexical
  embedding).

Graph structure: directed adjacency (child→parent) plus Laplacian positional
encodings (K smallest non-trivial eigenvectors of the symmetric normalized
Laplacian), exactly the Dwivedi–Bresson Graph-Transformer recipe the paper
cites.

Two granularities are featurized:
  * whole-plan graphs (the L̄QP model),
  * per-subQ operator groups (subQ / QS models), padded to a small fixed
    size — subQ groups contain ≤ 4 operators by construction.
"""
from __future__ import annotations

import dataclasses
import functools
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ...queryengine.plan import OP_TYPES, Operator, Query

__all__ = ["PRED_DIM", "OP_FEAT_DIM", "LAPPE_K", "encode_ops",
           "lap_positional_encoding", "GraphBatch", "featurize_subq",
           "featurize_plan", "batch_graphs", "contention_gamma"]

PRED_DIM = 8
LAPPE_K = 4
OP_FEAT_DIM = len(OP_TYPES) + 2 + PRED_DIM

_HASH_SEED = 1234

# Contention-feature scales (γ, paper §4.3): log-task / log-work pressure of
# co-running stages, sibling count, and stage depth.  One definition shared
# by trace collection (training distribution) and runtime serving (inference
# distribution) — the feature is only meaningful if both sides compute it
# identically.
GAMMA_TASK_SCALE = 10.0
GAMMA_WORK_SCALE = 10.0
GAMMA_SIB_SCALE = 4.0
GAMMA_DEPTH_SCALE = 8.0


def contention_gamma(sib_tasks, sib_work, n_sib, depth) -> np.ndarray:
    """γ contention vector(s): (..., 4) from broadcastable pressure stats.

    ``sib_tasks`` / ``sib_work`` aggregate the task count and task-seconds
    of the stages co-running with the modeled stage; ``n_sib`` counts them;
    ``depth`` is the stage's depth in its query DAG.
    """
    t, w, s, d = np.broadcast_arrays(
        np.asarray(sib_tasks, np.float64), np.asarray(sib_work, np.float64),
        np.asarray(n_sib, np.float64), np.asarray(depth, np.float64))
    return np.stack([np.log1p(t) / GAMMA_TASK_SCALE,
                     np.log1p(w) / GAMMA_WORK_SCALE,
                     s / GAMMA_SIB_SCALE,
                     d / GAMMA_DEPTH_SCALE], -1)


@functools.lru_cache(maxsize=65536)
def _token_vec(token: str) -> np.ndarray:
    # crc32: Python's str hash is process-randomized (PYTHONHASHSEED) and
    # would break saved-model reproducibility across processes.
    seed = (zlib.crc32(token.encode()) ^ _HASH_SEED) % (2 ** 32)
    rng = np.random.default_rng(seed)
    return rng.normal(0, 1, PRED_DIM) / np.sqrt(PRED_DIM)


def encode_ops(ops: Sequence[Operator], *, use_est: bool) -> np.ndarray:
    """(n_ops, OP_FEAT_DIM) composite operator encoding."""
    out = np.zeros((len(ops), OP_FEAT_DIM), np.float32)
    for i, op in enumerate(ops):
        out[i, op.type_index] = 1.0
        rows = op.est_rows if use_est else op.rows
        bys = op.est_bytes if use_est else op.bytes
        out[i, len(OP_TYPES)] = np.log1p(max(rows, 0.0)) / 25.0
        out[i, len(OP_TYPES) + 1] = np.log1p(max(bys, 0.0)) / 30.0
        if op.pred_tokens:
            vec = np.mean([_token_vec(t) for t in op.pred_tokens], axis=0)
            out[i, len(OP_TYPES) + 2:] = vec
    return out


def lap_positional_encoding(A: np.ndarray, k: int = LAPPE_K) -> np.ndarray:
    """(n, k) Laplacian PE from undirected normalized Laplacian eigvectors."""
    n = A.shape[0]
    und = ((A + A.T) > 0).astype(np.float64)
    deg = und.sum(1)
    d_inv_sqrt = np.where(deg > 0, 1.0 / np.sqrt(np.maximum(deg, 1e-9)), 0.0)
    L = np.eye(n) - d_inv_sqrt[:, None] * und * d_inv_sqrt[None, :]
    vals, vecs = np.linalg.eigh(L)
    order = np.argsort(vals)
    pe = vecs[:, order[1:k + 1]] if n > 1 else np.zeros((n, 0))
    # Deterministic sign: first max-|entry| positive per vector.
    for j in range(pe.shape[1]):
        i = int(np.argmax(np.abs(pe[:, j])))
        if pe[i, j] < 0:
            pe[:, j] = -pe[:, j]
    out = np.zeros((n, k), np.float32)
    out[:, :pe.shape[1]] = pe
    return out


@dataclasses.dataclass
class GraphBatch:
    """Padded graph batch for vmap'd GTN application."""

    X: np.ndarray        # (B, N, F) node features
    pe: np.ndarray       # (B, N, K) Laplacian PE
    bias: np.ndarray     # (B, N, N, 3) [fwd edge, bwd edge, self] flags
    mask: np.ndarray     # (B, N) node validity


def _build_graph(X: np.ndarray, A: np.ndarray, n_pad: int
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    n = X.shape[0]
    pe = lap_positional_encoding(A)
    Xp = np.zeros((n_pad, X.shape[1]), np.float32)
    Xp[:n] = X
    pep = np.zeros((n_pad, LAPPE_K), np.float32)
    pep[:n] = pe
    bias = np.zeros((n_pad, n_pad, 3), np.float32)
    bias[:n, :n, 0] = A
    bias[:n, :n, 1] = A.T
    bias[range(n), range(n), 2] = 1.0
    mask = np.zeros((n_pad,), bool)
    mask[:n] = True
    return Xp, pep, bias, mask


def featurize_subq(query: Query, sq_id: int, *, use_est: bool,
                   n_pad: int = 4) -> Tuple[np.ndarray, ...]:
    """Per-subQ operator-group graph (local ids, local edges)."""
    sq = query.subqs[sq_id]
    ops = [query.ops[i] for i in sq.op_ids]
    local = {op.op_id: j for j, op in enumerate(ops)}
    X = encode_ops(ops, use_est=use_est)
    A = np.zeros((len(ops), len(ops)), np.float32)
    for op in ops:
        for c in op.children:
            if c in local:
                A[local[c], local[op.op_id]] = 1.0
    return _build_graph(X, A, n_pad)


def featurize_plan(query: Query, *, use_est: bool,
                   n_pad: int = 128,
                   op_ids: Optional[Sequence[int]] = None
                   ) -> Tuple[np.ndarray, ...]:
    """Whole-plan (or collapsed-plan subset) graph."""
    if op_ids is None:
        ops = query.ops
        local = {op.op_id: j for j, op in enumerate(ops)}
    else:
        ops = [query.ops[i] for i in op_ids]
        local = {op.op_id: j for j, op in enumerate(ops)}
    if len(ops) > n_pad:
        ops = ops[:n_pad]
        local = {op.op_id: j for j, op in enumerate(ops)}
    X = encode_ops(ops, use_est=use_est)
    A = np.zeros((len(ops), len(ops)), np.float32)
    for op in ops:
        for c in op.children:
            if c in local and op.op_id in local:
                A[local[c], local[op.op_id]] = 1.0
    return _build_graph(X, A, n_pad)


def batch_graphs(graphs: Sequence[Tuple[np.ndarray, ...]]) -> GraphBatch:
    X, pe, bias, mask = (np.stack([g[i] for g in graphs]) for i in range(4))
    return GraphBatch(X=X, pe=pe, bias=bias, mask=mask)
