"""CLI: ``python -m repro.analysis [--strict] [paths...]``.

Exit status 0 when every finding is suppressed (with a written
justification under ``--strict``), 1 otherwise.  Prints each finding as
``path:line: RULE message`` plus a per-rule summary table (``--json``
for machine-readable output, ``--rules`` for the rules reference).
"""
from __future__ import annotations

import argparse
import sys

from .core import render_json, render_report, render_rules, run_paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Invariant-checking static analysis "
                    "(trace hazards, cache keys, determinism, kernel "
                    "parity, replay purity, snapshot safety).")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to scan (default: src)")
    ap.add_argument("--strict", action="store_true",
                    help="suppressions must carry a written justification "
                    "and must still silence something (SUP001/SUP002)")
    ap.add_argument("--tests", default=None,
                    help="parity-test file for the kernel registry "
                    "(default: auto-discover tests/test_kernels.py)")
    ap.add_argument("--select", default=None, metavar="PREFIXES",
                    help="comma-separated rule-id prefixes to activate "
                    "(e.g. TH,CK,SUP); default: all rules")
    ap.add_argument("--exclude", action="append", default=[],
                    metavar="SUBSTR",
                    help="skip files whose path contains this substring "
                    "(repeatable; e.g. tests/fixtures)")
    ap.add_argument("--json", action="store_true",
                    help="emit machine-readable JSON findings for CI "
                    "annotation instead of the text report")
    ap.add_argument("--rules", action="store_true",
                    help="print the generated rules reference and exit")
    args = ap.parse_args(argv)
    if args.rules:
        print(render_rules())
        return 0
    select = ([p.strip() for p in args.select.split(",") if p.strip()]
              if args.select else None)
    result = run_paths(args.paths, strict=args.strict, tests_dir=args.tests,
                       select=select, exclude=args.exclude)
    print(render_json(result) if args.json else render_report(result))
    return 0 if result.ok else 1


if __name__ == "__main__":
    sys.exit(main())
