"""CLI: ``python -m repro.analysis [--strict] [paths...]``.

Exit status 0 when every finding is suppressed (with a written
justification under ``--strict``), 1 otherwise.  Prints each finding as
``path:line: RULE message`` plus a per-rule summary table.
"""
from __future__ import annotations

import argparse
import sys

from .core import render_report, run_paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Invariant-checking static analysis "
                    "(trace hazards, cache keys, determinism, kernel "
                    "parity).")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to scan (default: src)")
    ap.add_argument("--strict", action="store_true",
                    help="suppressions must carry a written justification")
    ap.add_argument("--tests", default=None,
                    help="parity-test file for the kernel registry "
                    "(default: auto-discover tests/test_kernels.py)")
    args = ap.parse_args(argv)
    result = run_paths(args.paths, strict=args.strict, tests_dir=args.tests)
    print(render_report(result))
    return 0 if result.ok else 1


if __name__ == "__main__":
    sys.exit(main())
