"""Kernel parity-registry checker.

Every kernel package under ``src/repro/kernels/`` is an alternative
implementation of arithmetic that also exists (or must exist) as a plain
reference — that is what makes the Pallas routing *checkable*.  This
checker enforces the registry contract:

* ``KP001`` **missing-ref** — a kernel package (a directory with an
  ``ops.py``) ships no ``ref.py`` reference implementation.
* ``KP002`` **unregistered-parity-test** — ``tests/test_kernels.py`` has
  no test that exercises the package against a ``*ref*`` oracle (a test
  function must use a symbol imported from the package *and* reference a
  name containing ``ref``).
* ``KP003`` **tie-blind-routing** — a routing site outside ``kernels/``
  (recognized by the project idiom: a function-local lazy ``from
  ...kernels.<pkg> import``) dispatches to a float32-comparing kernel
  (``pareto_filter`` / ``ws_reduce`` / ``fused_solve``) without a
  ``*tie_hazard*`` guard reachable from that function or a same-module
  caller.  Without the guard, values that are distinct in float64 but
  collide in float32 make the result depend on which side of the size
  threshold the batch landed — the f32/f64 near-tie routing bug class.

``flash_attention`` is exempt from KP003 by registry: its inputs are
natively f32/bf16 and it has no dtype-changing numpy fallback, so routing
cannot change the compare semantics.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set

from .core import Finding, SourceFile, register_rules

__all__ = ["check_file", "check_tree", "RULES", "ROUTED_F32_KERNELS"]

RULES = {
    "KP001": "kernel package ships no numpy/jnp ref.py reference",
    "KP002": "kernel package has no registered parity test against its ref",
    "KP003": "f32 kernel routing site without a tie-hazard guard",
}
register_rules(RULES)

# Kernel packages whose kernel path compares in float32 while the numpy
# fallback compares in float64 — the packages KP003 guards.
ROUTED_F32_KERNELS = {"pareto_filter", "ws_reduce", "fused_solve"}


def _dotted(node: ast.AST) -> Optional[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


# ---------------------------------------------------------------------------
# Tree-scoped rules: KP001 / KP002
# ---------------------------------------------------------------------------

def _kernel_packages(paths: Sequence[str]) -> List[Path]:
    pkgs: List[Path] = []
    seen: Set[Path] = set()
    for p in paths:
        p = Path(p)
        roots = [p] if p.is_dir() else [p.parent]
        for root in roots:
            for ops in root.rglob("ops.py"):
                pkg = ops.parent
                if pkg.parent.name == "kernels" and pkg not in seen:
                    seen.add(pkg)
                    pkgs.append(pkg)
    return sorted(pkgs)


def _find_tests_file(pkgs: Sequence[Path],
                     tests_dir: Optional[str]) -> Optional[Path]:
    if tests_dir is not None:
        t = Path(tests_dir)
        return t if t.is_file() else t / "test_kernels.py"
    for pkg in pkgs:
        # .../src/repro/kernels/<pkg> -> repo root three levels above src
        for anc in pkg.parents:
            cand = anc / "tests" / "test_kernels.py"
            if cand.is_file():
                return cand
    cand = Path("tests/test_kernels.py")
    return cand if cand.is_file() else None


def _parity_tested_packages(tests_file: Path) -> Set[str]:
    """Packages exercised against a ``*ref*`` symbol by some test fn."""
    try:
        tree = ast.parse(tests_file.read_text(), filename=str(tests_file))
    except (SyntaxError, OSError):
        return set()
    module_imports: Dict[str, str] = {}   # imported name -> kernel pkg
    for node in tree.body:
        if isinstance(node, ast.ImportFrom) and node.module \
                and "kernels." in node.module:
            pkg = node.module.split("kernels.")[1].split(".")[0]
            for alias in node.names:
                module_imports[alias.asname or alias.name] = pkg
    tested: Set[str] = set()
    for fn in tree.body:
        if not isinstance(fn, ast.FunctionDef) \
                or not fn.name.startswith("test"):
            continue
        local_imports = dict(module_imports)
        used: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.ImportFrom) and node.module \
                    and "kernels." in node.module:
                pkg = node.module.split("kernels.")[1].split(".")[0]
                for alias in node.names:
                    local_imports[alias.asname or alias.name] = pkg
            elif isinstance(node, ast.Name):
                used.add(node.id)
            elif isinstance(node, ast.Attribute):
                used.add(node.attr)
        has_ref = any("ref" in u.lower() for u in used)
        if not has_ref:
            continue
        for name, pkg in local_imports.items():
            if name in used:
                tested.add(pkg)
    return tested


def check_tree(paths: Sequence[str],
               tests_dir: Optional[str] = None) -> List[Finding]:
    pkgs = _kernel_packages(paths)
    if not pkgs:
        return []
    findings: List[Finding] = []
    tests_file = _find_tests_file(pkgs, tests_dir)
    tested = _parity_tested_packages(tests_file) if tests_file else set()
    for pkg in pkgs:
        ops = pkg / "ops.py"
        if not (pkg / "ref.py").is_file():
            findings.append(Finding(
                str(ops), 1, "KP001",
                f"kernel package `{pkg.name}` has no ref.py reference "
                "implementation"))
        if pkg.name not in tested:
            where = tests_file or "tests/test_kernels.py"
            findings.append(Finding(
                str(ops), 1, "KP002",
                f"no parity test in {where} exercises "
                f"`{pkg.name}` against a ref oracle"))
    return findings


# ---------------------------------------------------------------------------
# File-scoped rule: KP003
# ---------------------------------------------------------------------------

def _fn_tokens(fn: ast.FunctionDef) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Name):
            out.add(node.id)
        elif isinstance(node, ast.Attribute):
            out.add(node.attr)
    return out


def check_file(src: SourceFile) -> List[Finding]:
    if "kernels" in Path(src.path).parts:
        return []                      # intra-package composition is exempt
    fns = [n for n in ast.walk(src.tree) if isinstance(n, ast.FunctionDef)]
    tokens = {fn.name: _fn_tokens(fn) for fn in fns}
    # Same-module caller graph: caller -> callees (by referenced name).
    names = set(tokens)
    callers: Dict[str, Set[str]] = {n: set() for n in names}
    for fn in fns:
        for callee in tokens[fn.name] & names:
            if callee != fn.name:
                callers.setdefault(callee, set()).add(fn.name)

    def guarded(name: str, seen: Set[str]) -> bool:
        if name in seen:
            return False
        seen.add(name)
        if any("tie_hazard" in t for t in tokens.get(name, ())):
            return True
        return any(guarded(c, seen) for c in callers.get(name, ()))

    findings: List[Finding] = []
    for fn in fns:
        for node in ast.walk(fn):
            if isinstance(node, ast.ImportFrom) and node.module \
                    and "kernels." in node.module:
                pkg = node.module.split("kernels.")[1].split(".")[0]
                if pkg in ROUTED_F32_KERNELS \
                        and not guarded(fn.name, set()):
                    findings.append(Finding(
                        src.path, node.lineno, "KP003",
                        f"`{fn.name}` routes to the f32 `{pkg}` kernel "
                        "with no tie-hazard guard: near-tie results would "
                        "depend on which side of the size threshold the "
                        "batch lands"))
    return findings
