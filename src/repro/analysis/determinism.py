"""Determinism checker for transcript-order code paths.

The golden bit-identity proofs (streaming output ≡ offline pipeline,
batched solve ≡ sequential transcript, per-tenant determinism under any
interleaving, scenario replay-equivalence) only hold if nothing in the
solver or serving transcript depends on wall-clock time, unseeded
randomness, or hash-iteration order.  This checker guards the
transcript-ordered subtrees — ``serve/`` (the whole subtree, including
``serve/fleet.py``'s routing/merge paths), ``core/moo/``,
``core/tuning/``, and the scenario engine
(``queryengine/scenarios.py``, whose builds must be pure functions of
their seeds) — against all three leak classes; the scope is pinned by
explicit ``in_scope`` assertions in ``tests/test_analysis.py``.  The
call-graph-scoped replay-purity checker (:mod:`.replay_purity`) covers
the same leak classes in serve-reachable code *outside* these subtrees
and defers to DT001/DT002 inside them.

Rules:

* ``DT001`` **wall-clock** — ``time.time()`` / ``datetime.now()`` /
  ``utcnow()`` / ``today()`` in a transcript path.  ``time.perf_counter``
  is allowed: it only feeds *reported* timing stats, never decisions, and
  monotonic timing is the project idiom for that (enforced by review, not
  by this rule).
* ``DT002`` **unseeded-rng** — ``np.random.default_rng()`` with no seed,
  the legacy ``np.random.*`` global-state functions, or the stdlib
  ``random`` module: any of them makes the transcript irreproducible.
* ``DT003`` **set-iteration-order** — iterating a ``set``/``frozenset``
  (directly, via ``list``/``tuple``/``enumerate``, or through a local
  variable holding one) in a transcript path.  Python set iteration order
  varies with hash seeding across processes; ``sorted(...)`` over a set is
  the deterministic idiom and is exempt.  Membership tests are fine.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Optional, Set

from .core import Finding, SourceFile, register_rules

__all__ = ["check", "RULES", "in_scope"]

RULES = {
    "DT001": "wall-clock read in a transcript-order path",
    "DT002": "unseeded / global-state RNG in a transcript-order path",
    "DT003": "set-iteration-order dependence in a transcript-order path",
}
register_rules(RULES)

# Transcript-ordered subtrees (path-part sequences; a sequence ending in
# a ``.py`` part pins one module file).
_SCOPES = (("serve",), ("core", "moo"), ("core", "tuning"),
           ("queryengine", "scenarios.py"))

_LEGACY_NP_RANDOM = {"rand", "randn", "randint", "random", "choice",
                     "shuffle", "permutation", "normal", "uniform",
                     "standard_normal", "seed", "random_sample"}
_STDLIB_RANDOM = {"random", "randint", "choice", "shuffle", "uniform",
                  "randrange", "sample", "seed", "getrandbits"}


def in_scope(path: str) -> bool:
    parts = Path(path).parts
    for scope in _SCOPES:
        for i in range(len(parts) - len(scope) + 1):
            if tuple(parts[i:i + len(scope)]) == scope:
                return True
    return False


def _dotted(node: ast.AST) -> Optional[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_set_expr(node: ast.AST, set_vars: Set[str]) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        d = (_dotted(node.func) or "").rsplit(".", 1)[-1]
        if d in ("set", "frozenset"):
            return True
        # set-producing methods: a.union(b), a.intersection(b), ...
        if isinstance(node.func, ast.Attribute) and node.func.attr in (
                "union", "intersection", "difference",
                "symmetric_difference"):
            return _is_set_expr(node.func.value, set_vars) \
                or any(_is_set_expr(a, set_vars) for a in node.args)
    if isinstance(node, ast.Name):
        return node.id in set_vars
    return False


def _collect_set_vars(scope: ast.AST) -> Set[str]:
    """Local names assigned a set literal/constructor in this scope."""
    out: Set[str] = set()
    # Two passes so `a = set(); b = a` resolves.
    for _ in range(2):
        for node in ast.walk(scope):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                if _is_set_expr(node.value, out):
                    out.add(node.targets[0].id)
            elif isinstance(node, ast.AnnAssign) \
                    and isinstance(node.target, ast.Name) \
                    and node.value is not None:
                if _is_set_expr(node.value, out):
                    out.add(node.target.id)
    return out


def _check_scope(src: SourceFile, scope: ast.AST,
                 findings: List[Finding]) -> None:
    set_vars = _collect_set_vars(scope)
    nested = {id(x) for n in ast.walk(scope)
              if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
              and n is not scope
              for x in ast.walk(n)}

    def flag_iteration(iter_expr: ast.AST, line: int) -> None:
        if _is_set_expr(iter_expr, set_vars):
            findings.append(Finding(
                src.path, line, "DT003",
                "iteration over a set is hash-order dependent; sort it "
                "(`sorted(...)`) or use an ordered container"))

    for node in ast.walk(scope):
        if id(node) in nested:
            continue
        if isinstance(node, ast.Call):
            d = _dotted(node.func) or ""
            leaf = d.rsplit(".", 1)[-1]
            if d in ("time.time", "time.time_ns"):
                findings.append(Finding(
                    src.path, node.lineno, "DT001",
                    f"`{d}()` in a transcript path; use the simulated "
                    "clock (or perf_counter for reported timings only)"))
            elif leaf in ("now", "utcnow", "today") and "date" in d.lower():
                findings.append(Finding(
                    src.path, node.lineno, "DT001",
                    f"`{d}()` wall-clock read in a transcript path"))
            elif leaf == "default_rng" and not node.args \
                    and not node.keywords:
                findings.append(Finding(
                    src.path, node.lineno, "DT002",
                    "`default_rng()` without a seed: transcript is not "
                    "reproducible"))
            elif d.startswith(("np.random.", "numpy.random.")) \
                    and leaf in _LEGACY_NP_RANDOM:
                findings.append(Finding(
                    src.path, node.lineno, "DT002",
                    f"global-state `{d}` in a transcript path; use a "
                    "seeded `np.random.default_rng`"))
            elif d.startswith("random.") and leaf in _STDLIB_RANDOM:
                findings.append(Finding(
                    src.path, node.lineno, "DT002",
                    f"stdlib `{d}` in a transcript path; use a seeded "
                    "`np.random.default_rng`"))
            elif leaf in ("list", "tuple", "enumerate", "iter") \
                    and node.args:
                flag_iteration(node.args[0], node.lineno)
        elif isinstance(node, (ast.For, ast.AsyncFor)):
            flag_iteration(node.iter, node.lineno)
        elif isinstance(node, (ast.ListComp, ast.GeneratorExp,
                               ast.DictComp, ast.SetComp)):
            for gen in node.generators:
                flag_iteration(gen.iter, node.lineno)


def check(src: SourceFile) -> List[Finding]:
    if not in_scope(src.path):
        return []
    findings: List[Finding] = []
    # Module level + each function get their own set-variable scope.
    _check_scope(src, src.tree, findings)
    seen_lines = {(f.line, f.rule) for f in findings}
    for node in ast.walk(src.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            fn_findings: List[Finding] = []
            _check_scope(src, node, fn_findings)
            for f in fn_findings:
                if (f.line, f.rule) not in seen_lines:
                    findings.append(f)
                    seen_lines.add((f.line, f.rule))
    return findings
