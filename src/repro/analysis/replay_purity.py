"""Replay-purity checker for the serving call graph.

The serving stack's core guarantee (PR 8) is that ``serve()`` is a pure,
replay-deterministic function of the query stream + config under the
*simulated* ``ServiceTimeModel`` clock: two runs over the same stream
must be bit-identical, and a run replayed from a snapshot must match the
original.  That guarantee dies the moment any function reachable from the
serving entrypoints reads ambient process state — the wall clock, the
environment, global RNG, object identities, or mutable module globals.

This checker makes the guarantee a CI gate: it walks the project call
graph from the serving entrypoints (``OptimizerServer.serve``,
``OptimizerFleet.serve``, and every ``RuntimeSession`` method) and flags
impure reads *anywhere in the reachable set* — including helpers in
modules the path-scoped determinism checker (DT00x) never looks at.

Rules (all scoped to serve-reachable functions):

* ``RP001`` **wall-clock read** — ``time.time`` / ``time_ns`` /
  ``datetime.now`` / ``utcnow`` / ``today`` on the serving path.  The
  monotonic ``perf_counter`` is exempt: it only feeds *measured* solve
  times, which the replay harness ignores in favour of the
  ``ServiceTimeModel`` (replay compares decisions, not latencies).
* ``RP002`` **ambient env read** — ``os.environ`` / ``os.getenv`` reads
  of keys outside the registered ``REPRO_*`` namespace.  ``REPRO_*``
  keys are the project's ambient-config registry (kernel routing
  thresholds, read per-call by design — the TH003/TH004 fix idiom) and
  are held fixed across a replay by contract.
* ``RP003`` **unseeded RNG** — legacy ``np.random.*`` globals, stdlib
  ``random.*``, or ``default_rng()`` with no seed argument.  Files
  already covered by the determinism checker's path scopes are skipped
  (DT001/DT002 own them); the value added here is reachable code
  *outside* those scopes.
* ``RP004`` **object-identity read** — ``id(x)``: process-local by
  definition, differs across replays and across fleet workers.  Uses
  where the id is a pure within-process grouping token (never compared
  across processes, never serialized) carry written justifications.
* ``RP005`` **module-global mutation** — rebinding a module global
  (``global`` + assignment) or writing ``os.environ[...]`` from the
  serving path.  Module-level *dict* memoization is exempt: filling a
  deterministic memo is idempotent across replays, rebinding a global is
  not.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Sequence, Set, Tuple

from . import determinism
from .core import CallGraph, Finding, SourceFile, dotted, register_rules

__all__ = ["check_project", "RULES", "ENTRYPOINTS", "AMBIENT_ENV_PREFIXES"]

RULES = {
    "RP001": "wall-clock read reachable from the serving entrypoints",
    "RP002": "non-REPRO_* env read reachable from the serving entrypoints",
    "RP003": "unseeded RNG reachable from the serving entrypoints",
    "RP004": "id() read reachable from the serving entrypoints",
    "RP005": "module-global mutation reachable from the serving entrypoints",
}
register_rules(RULES)

# Dotted suffixes resolved against the call graph; a class name expands to
# all of its methods.
ENTRYPOINTS: Tuple[str, ...] = (
    "OptimizerServer.serve", "OptimizerFleet.serve", "RuntimeSession")

# Env keys under these prefixes are the registered ambient-config
# namespace: read per-call on purpose and pinned for the life of a replay.
AMBIENT_ENV_PREFIXES: Tuple[str, ...] = ("REPRO_",)

_WALL_CLOCK = {"time.time", "time.time_ns", "datetime.now",
               "datetime.utcnow", "datetime.today", "datetime.datetime.now",
               "datetime.datetime.utcnow", "datetime.datetime.today"}
_ENV_READ = {"os.environ.get", "os.getenv", "environ.get"}
_UNSEEDED_RNG_PREFIXES = ("np.random.", "numpy.random.", "random.")
_RNG_SEEDED_FACTORIES = {"default_rng", "PRNGKey", "key", "fold_in", "Random",
                         "seed"}


def _env_key(call: ast.Call) -> object:
    """Literal env-key string of a read, or None when non-literal."""
    if call.args and isinstance(call.args[0], ast.Constant) \
            and isinstance(call.args[0].value, str):
        return call.args[0].value
    return None


def _check_fn(src: SourceFile, qname: str, fn: ast.AST,
              findings: List[Finding]) -> None:
    in_dt_scope = determinism.in_scope(src.path)
    name = qname.rsplit(".", 1)[-1]
    assigned: Set[str] = {t.id for node in ast.walk(fn)
                          if isinstance(node, (ast.Assign, ast.AugAssign,
                                               ast.AnnAssign))
                          for t in ast.walk(node)
                          if isinstance(t, ast.Name)
                          and isinstance(t.ctx, ast.Store)}
    for node in ast.walk(fn):
        if isinstance(node, ast.Global):
            # RP005: `global` + rebinding in the same scope.
            hits = [n for n in node.names if n in assigned]
            if hits:
                findings.append(Finding(
                    src.path, node.lineno, "RP005",
                    f"`{name}` rebinds module global(s) "
                    f"{', '.join(sorted(hits))} on the serving path"))
            continue
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                if isinstance(t, ast.Subscript) \
                        and (dotted(t.value) or "").endswith("environ"):
                    findings.append(Finding(
                        src.path, node.lineno, "RP005",
                        f"`{name}` writes os.environ on the serving path"))
            continue
        if not isinstance(node, ast.Call):
            continue
        d = dotted(node.func) or ""
        leaf = d.rsplit(".", 1)[-1]
        # RP001 — wall clock (perf_counter exempt, see module docstring).
        if not in_dt_scope and (d in _WALL_CLOCK
                                or d.endswith(".time.time")):
            findings.append(Finding(
                src.path, node.lineno, "RP001",
                f"`{name}` reads the wall clock (`{d}`) on the serving "
                "path; replay must run on the ServiceTimeModel clock"))
        # RP002 — env reads outside the ambient-config namespace.
        if d in _ENV_READ or d.endswith(".environ.get") or d == "getenv":
            key = _env_key(node)
            ambient = isinstance(key, str) and key.startswith(
                tuple(AMBIENT_ENV_PREFIXES))
            if not ambient:
                shown = key if isinstance(key, str) else "<non-literal>"
                findings.append(Finding(
                    src.path, node.lineno, "RP002",
                    f"`{name}` reads env key `{shown}` outside the "
                    "registered REPRO_* ambient-config namespace"))
        # RP002 — subscript read os.environ["K"] (an expression, not the
        # RP005 write case handled above).
        # RP003 — unseeded / global-state RNG.
        if not in_dt_scope:
            if any(d.startswith(p) for p in _UNSEEDED_RNG_PREFIXES) \
                    and leaf not in _RNG_SEEDED_FACTORIES:
                findings.append(Finding(
                    src.path, node.lineno, "RP003",
                    f"`{name}` draws from global RNG state (`{d}`) on "
                    "the serving path"))
            elif leaf == "default_rng" and not node.args \
                    and not node.keywords:
                findings.append(Finding(
                    src.path, node.lineno, "RP003",
                    f"`{name}` creates an OS-entropy-seeded generator "
                    "(`default_rng()` with no seed) on the serving path"))
        # RP004 — object identity.
        if isinstance(node.func, ast.Name) and node.func.id == "id" \
                and len(node.args) == 1:
            findings.append(Finding(
                src.path, node.lineno, "RP004",
                f"`{name}` reads an object identity (`id(...)`) on the "
                "serving path; ids differ across replays and workers"))
    # RP002 — bare subscript reads os.environ["K"] in Load context.
    for node in ast.walk(fn):
        if isinstance(node, ast.Subscript) \
                and isinstance(node.ctx, ast.Load) \
                and (dotted(node.value) or "").endswith("environ"):
            key = (node.slice.value
                   if isinstance(node.slice, ast.Constant) else None)
            ambient = isinstance(key, str) and key.startswith(
                tuple(AMBIENT_ENV_PREFIXES))
            if not ambient:
                shown = key if isinstance(key, str) else "<non-literal>"
                findings.append(Finding(
                    src.path, node.lineno, "RP002",
                    f"`{name}` reads env key `{shown}` outside the "
                    "registered REPRO_* ambient-config namespace"))


def check_project(srcs: Sequence[SourceFile], graph: CallGraph,
                  entrypoints: Sequence[str] = ENTRYPOINTS
                  ) -> List[Finding]:
    """Flag ambient-state reads in every function reachable from the
    serving entrypoints.  Nested defs/lambdas are scanned as part of
    their enclosing function (a closure defined on the serving path is
    assumed callable from it)."""
    findings: List[Finding] = []
    reach = graph.reachable_from(entrypoints)
    scanned: Set[Tuple[str, str]] = set()
    by_path: Dict[str, SourceFile] = {s.path: s for s in srcs}
    for qname in sorted(reach):
        src, fn = graph.functions[qname]
        # A method reached both directly and via its class entrypoint is
        # scanned once per distinct def node.
        key = (src.path, f"{fn.lineno}:{fn.name}")
        if key in scanned or src.path not in by_path:
            continue
        scanned.add(key)
        _check_fn(src, qname, fn, findings)
    return findings
