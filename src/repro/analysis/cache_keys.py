"""Cache-key completeness checker for the serving-layer caches.

Every hand-fixed cache bug so far was the same shape: a context dimension
the cached computation *reads* (tenant in PR 4, model fingerprint and the
degraded flag in PR 6) was missing from the *key*, so entries minted under
one context were served under another.  This checker pins the key
constructions of the registered serving caches (``ResponseCache``,
``EffectiveSetCache``, ``CandidatePoolCache`` and the degrade-marked
``_CheapEntry`` keys) and audits store sites for unkeyed context reads.

Rules:

* ``CK001`` **incomplete-key-builder** — a registered key builder
  (``template_key``, ``_response_key``, ``CandidatePoolCache.get``'s key
  tuple) no longer references one of its required context dimensions.
* ``CK002`` **unkeyed-context-read** — a function stores into a registered
  cache (``.put(key, v)`` or ``self._entries[key] = v``) while reading a
  context dimension (tenant / weights / gamma_mode / degraded / scope /
  seed / model) that does not flow into the key expression.  Key
  expressions are resolved through local assignments and same-module key
  builders; a key passed in whole as a parameter is trusted locally and
  the *callers* of the enclosing function are audited instead, through
  the project call graph (``check_project``) — closing the old blind
  spot where a helper stored under a caller-composed key and neither
  side was checked.

The context-dimension vocabulary is a name-pattern registry, not type
inference: a dimension counts as *read* when an identifier matching it
appears in the function, and as *keyed* when one appears in the key's
identifier closure.  That is exactly the granularity the historical bugs
had (the missing dimension was simply absent from the key tuple).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .core import (CallGraph, Finding, SourceFile, param_names,
                   register_rules)

__all__ = ["check", "check_project", "RULES", "KEY_BUILDERS",
           "CONTEXT_DIMS"]

RULES = {
    "CK001": "registered cache-key builder is missing a required dimension",
    "CK002": "context dimension read in a cached computation but absent "
             "from the cache key",
}
register_rules(RULES)

# Key builders pinned by CK001: function name -> required identifier
# tokens (matched against Name ids / Attribute attrs in the returned or
# assigned key expression).
KEY_BUILDERS: Dict[str, Set[str]] = {
    # EffectiveSetCache: (benchmark, template, cfg, cost, model fingerprint)
    "template_key": {"benchmark", "template", "cfg", "cost",
                     "model_fingerprint"},
    # ResponseCache: (tenant, qid, stats fingerprint, weights, cfg, cost,
    # model fingerprint)
    "_response_key": {"tenant", "qid", "query_fingerprint", "w", "cfg",
                      "cost", "_model_fp"},
    # Fleet router: the template-affinity dims of the cache fingerprint
    # (cfg/cost/model are fleet-constant and must NOT differentiate
    # workers; benchmark+template decide cache ownership).
    "route_key": {"benchmark", "template"},
}
# Method-scoped builders: (class, method, key variable) -> required tokens.
KEY_METHOD_BUILDERS: Dict[Tuple[str, str], Set[str]] = {
    ("CandidatePoolCache", "get"): {"seed", "n_candidates", "scope"},
}

# Context-dimension name classes (substring match, lowercased) plus exact
# single-letter weight idiom.  A name hits a class if it contains the
# pattern: `tenants`, `per_q_weights`, `_model_fp`, `gamma_mode` all match.
CONTEXT_DIMS: Dict[str, Sequence[str]] = {
    "tenant": ("tenant",),
    "weights": ("weight",),
    "gamma": ("gamma",),
    "degraded": ("degrad",),
    "scope": ("scope",),
    "seed": ("seed",),
    "model": ("model",),
}
_EXACT_DIMS = {"w": "weights"}

# Attribute / name fragments that identify a registered cache object.
# ``_blobs`` is the fleet CacheStore's published-snapshot map: its store
# sites are audited like any serving cache (the key must carry every
# context dimension the publishing function reads).
_CACHE_ATTRS = ("cache", "_results", "_pools", "_entries", "_d", "_blobs")


def _dotted(node: ast.AST) -> Optional[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _tokens(node: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            out.add(sub.id)
        elif isinstance(sub, ast.Attribute):
            out.add(sub.attr)
    return out


def _dims_of(tokens: Set[str]) -> Set[str]:
    hit: Set[str] = set()
    for t in tokens:
        tl = t.lower()
        if t in _EXACT_DIMS:
            hit.add(_EXACT_DIMS[t])
        for dim, pats in CONTEXT_DIMS.items():
            if any(p in tl for p in pats):
                hit.add(dim)
    return hit


def _is_cache_store(node: ast.AST) -> Optional[Tuple[ast.AST, int]]:
    """(key expr, line) when ``node`` stores into a registered cache."""
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
            and node.func.attr == "put" and len(node.args) == 2:
        base = _dotted(node.func.value) or ""
        leaf = base.rsplit(".", 1)[-1]
        if any(c in leaf for c in _CACHE_ATTRS):
            return node.args[0], node.lineno
    if isinstance(node, ast.Assign) and len(node.targets) == 1 \
            and isinstance(node.targets[0], ast.Subscript):
        tgt = node.targets[0]
        base = _dotted(tgt.value) or ""
        leaf = base.rsplit(".", 1)[-1]
        if leaf in ("_entries", "_pools", "_d", "_blobs"):
            return tgt.slice, node.lineno
    return None


class _FnIndex:
    """Same-module function defs + their key-expression token closures."""

    def __init__(self, tree: ast.Module):
        self.fns: Dict[str, ast.FunctionDef] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.FunctionDef):
                # last definition wins; good enough for module-local builders
                self.fns[node.name] = node

    def return_tokens(self, name: str) -> Set[str]:
        fn = self.fns.get(name)
        if fn is None:
            return set()
        out: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Return) and node.value is not None:
                out |= _tokens(node.value)
        return out


def _assignments(fn: ast.FunctionDef) -> Dict[str, List[ast.AST]]:
    """name -> rhs exprs, from plain, subscript-target and for-loop binds."""
    out: Dict[str, List[ast.AST]] = {}

    def bind(target: ast.AST, value: ast.AST) -> None:
        if isinstance(target, ast.Name):
            out.setdefault(target.id, []).append(value)
        elif isinstance(target, ast.Subscript):
            base = target.value
            if isinstance(base, ast.Name):
                out.setdefault(base.id, []).append(value)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                bind(el, value)

    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                bind(t, node.value)
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            bind(node.target, node.value)
        elif isinstance(node, ast.For):
            bind(node.target, node.iter)
    return out


def _top_operands(expr: ast.AST) -> List[ast.AST]:
    """Flatten top-level tuple concatenation: ``("x",) + key`` -> both."""
    ops: List[ast.AST] = []
    stack = [expr]
    while stack:
        e = stack.pop()
        if isinstance(e, ast.BinOp) and isinstance(e.op, ast.Add):
            stack.extend([e.left, e.right])
        else:
            ops.append(e)
    return ops


def _key_closure(key: ast.AST, fn: ast.FunctionDef, index: _FnIndex,
                 params: Set[str]) -> Tuple[Set[str], Set[str],
                                            Optional[str]]:
    """(identifier closure, string literals, trusted param name) of a key.

    Trusted: the key — directly or through local assignments — is a
    parameter (or a tuple-concat including one): its composition is the
    caller's responsibility, so the file-scoped pass exempts the store
    site and ``check_project`` audits the call sites binding that
    parameter instead.  The closure and literals of any *locally*
    composed part (e.g. the ``("degraded",)`` prefix of
    ``("degraded",) + exact_key``) are still collected — they count as
    keyed when the callers are audited.
    """
    assigns = _assignments(fn)
    closure: Set[str] = set()
    literals: Set[str] = set()
    trusted: Optional[str] = None
    frontier = [key]
    seen_names: Set[str] = set()
    while frontier:
        expr = frontier.pop()
        for op in _top_operands(expr):
            if isinstance(op, ast.Name) and op.id in params:
                trusted = op.id
        toks = _tokens(expr)
        closure |= toks
        for sub in ast.walk(expr):
            if isinstance(sub, ast.Call):
                fname = (_dotted(sub.func) or "").rsplit(".", 1)[-1]
                closure |= index.return_tokens(fname)
            elif isinstance(sub, ast.Constant) and isinstance(sub.value,
                                                             str):
                literals.add(sub.value.lower())
        for t in toks:
            if t in seen_names or t in params:
                continue
            seen_names.add(t)
            frontier.extend(assigns.get(t, []))
    return closure, literals, trusted


def _literal_dims(literals: Set[str]) -> Set[str]:
    """Dimensions encoded as string markers in the key (("degraded", ...))."""
    out: Set[str] = set()
    for dim, pats in CONTEXT_DIMS.items():
        if any(p in l for l in literals for p in pats):
            out.add(dim)
    return out


def _check_builder_fn(src: SourceFile, fn: ast.FunctionDef,
                      required: Set[str], findings: List[Finding]) -> None:
    tokens: Set[str] = set()
    line = fn.lineno
    for node in ast.walk(fn):
        if isinstance(node, ast.Return) and node.value is not None:
            tokens |= _tokens(node.value)
            line = node.lineno
    missing = required - tokens
    for dim in sorted(missing):
        findings.append(Finding(
            src.path, line, "CK001",
            f"key builder `{fn.name}` no longer references required "
            f"dimension `{dim}`"))


def _check_method_builder(src: SourceFile, cls: ast.ClassDef,
                          method: ast.FunctionDef, required: Set[str],
                          findings: List[Finding]) -> None:
    key_exprs = [node.value for node in ast.walk(method)
                 if isinstance(node, ast.Assign)
                 and any(isinstance(t, ast.Name) and t.id == "key"
                         for t in node.targets)]
    if not key_exprs:
        findings.append(Finding(
            src.path, method.lineno, "CK001",
            f"`{cls.name}.{method.name}` has no recognizable `key = ...` "
            "tuple to audit"))
        return
    tokens: Set[str] = set()
    for e in key_exprs:
        tokens |= _tokens(e)
    for dim in sorted(required - tokens):
        findings.append(Finding(
            src.path, key_exprs[0].lineno, "CK001",
            f"`{cls.name}.{method.name}` key tuple is missing required "
            f"dimension `{dim}`"))


def check(src: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    index = _FnIndex(src.tree)

    # CK001 — pinned builders.
    for name, required in KEY_BUILDERS.items():
        fn = index.fns.get(name)
        if fn is not None:
            _check_builder_fn(src, fn, required, findings)
    for node in ast.walk(src.tree):
        if isinstance(node, ast.ClassDef):
            for item in node.body:
                if isinstance(item, ast.FunctionDef) \
                        and (node.name, item.name) in KEY_METHOD_BUILDERS:
                    _check_method_builder(
                        src, node, item,
                        KEY_METHOD_BUILDERS[(node.name, item.name)],
                        findings)

    # CK002 — unkeyed context reads at store sites.
    for fn in ast.walk(src.tree):
        if not isinstance(fn, ast.FunctionDef):
            continue
        a = fn.args
        params = {p.arg for p in a.args + a.kwonlyargs + a.posonlyargs}
        params.discard("self")
        stores: List[Tuple[ast.AST, int]] = []
        for node in ast.walk(fn):
            hit = _is_cache_store(node)
            if hit is not None:
                stores.append(hit)
        if not stores:
            continue
        fn_dims = _dims_of(_tokens(fn))
        for key_expr, line in stores:
            closure, lits, trusted = _key_closure(key_expr, fn, index,
                                                  params)
            if trusted is not None:
                continue       # caller-composed key: check_project audits it
            # String-literal markers in the key (e.g. ("degraded", ...))
            # count: the dimension is encoded even without a variable.
            keyed = _dims_of(closure) | _literal_dims(lits)
            for dim in sorted(fn_dims - keyed):
                findings.append(Finding(
                    src.path, line, "CK002",
                    f"`{fn.name}` reads context dimension `{dim}` but the "
                    "stored cache key does not include it"))
    return findings


# ---------------------------------------------------------------------------
# Interprocedural CK002: audit the callers of trusted-param store sites
# ---------------------------------------------------------------------------

def _bind_arg(fn: ast.AST, call: ast.Call,
              pname: str) -> Optional[ast.AST]:
    """The argument expression a call site binds to parameter ``pname``."""
    for kw in call.keywords:
        if kw.arg == pname:
            return kw.value
    names = [a.arg for a in fn.args.args]
    skip = 1 if names and names[0] in ("self", "cls") \
        and isinstance(call.func, ast.Attribute) else 0
    try:
        idx = names.index(pname) - skip
    except ValueError:
        return None
    if 0 <= idx < len(call.args):
        arg = call.args[idx]
        if isinstance(arg, ast.Starred):
            return None
        return arg
    return None


_MAX_PROPAGATION_DEPTH = 3


def check_project(srcs: Sequence[SourceFile],
                  graph: CallGraph) -> List[Finding]:
    """CK002 across function boundaries.

    The file-scoped pass trusts a store whose key is a parameter.  This
    pass picks those sites up: for every caller binding that parameter
    (found through the call graph), the argument's identifier closure in
    the *caller* must carry every context dimension read anywhere on the
    store path (callee reads and caller reads both count); dimensions
    already encoded locally at the store site — e.g. the ``("degraded",)``
    literal prefix — count as keyed.  Call sites that are themselves
    recognized cache stores (direct ``.put(key, v)``) are skipped: the
    file-scoped pass already audited them.  When the caller's argument is
    again a whole parameter, the audit recurses one level up
    (depth-limited).
    """
    findings: List[Finding] = []
    indexes: Dict[str, _FnIndex] = {}

    def fn_index(src: SourceFile) -> _FnIndex:
        if src.path not in indexes:
            indexes[src.path] = _FnIndex(src.tree)
        return indexes[src.path]

    # (store-path fn qname, trusted param, keyed dims so far, dims read on
    # the store path so far, depth)
    work: List[Tuple[str, str, frozenset, frozenset, int]] = []
    for qname, (src, fn) in graph.functions.items():
        params = param_names(fn)
        fn_dims = _dims_of(_tokens(fn))
        for node in ast.walk(fn):
            hit = _is_cache_store(node)
            if hit is None:
                continue
            key_expr, _line = hit
            closure, lits, trusted = _key_closure(key_expr, fn,
                                                  fn_index(src), params)
            if trusted is None:
                continue
            keyed = _dims_of(closure) | _literal_dims(lits)
            work.append((qname, trusted, frozenset(keyed),
                         frozenset(fn_dims), 0))
    seen: Set[Tuple[str, str, frozenset, frozenset]] = set()
    while work:
        qname, pname, keyed0, required0, depth = work.pop()
        state = (qname, pname, keyed0, required0)
        if state in seen or depth > _MAX_PROPAGATION_DEPTH:
            continue
        seen.add(state)
        _store_src, store_fn = graph.functions[qname]
        for site in graph.call_sites(qname):
            if _is_cache_store(site.node) is not None:
                continue
            arg = _bind_arg(store_fn, site.node, pname)
            if arg is None:
                continue
            caller_src, caller_fn = graph.functions[site.caller]
            cparams = param_names(caller_fn)
            closure, lits, trusted = _key_closure(arg, caller_fn,
                                                  fn_index(caller_src),
                                                  cparams)
            keyed = set(keyed0) | _dims_of(closure) | _literal_dims(lits)
            required = set(required0) | _dims_of(_tokens(caller_fn))
            if trusted is not None:
                work.append((site.caller, trusted, frozenset(keyed),
                             frozenset(required), depth + 1))
                continue
            callee = qname.rsplit(".", 1)[-1]
            caller = site.caller.rsplit(".", 1)[-1]
            for dim in sorted(required - keyed):
                findings.append(Finding(
                    caller_src.path, site.node.lineno, "CK002",
                    f"`{caller}` passes `{callee}` a cache key that does "
                    f"not include context dimension `{dim}` read on the "
                    "store path"))
    return findings
