"""Trace-hazard checker: jit/trace-time pitfalls and frozen routing state.

The serving stack's 1-2 s compile-time budget depends on jitted solver code
never falling back to host round-trips mid-trace, and on backend/env
routing decisions staying *live* — resolved per call, not captured once at
import (or first call) and silently stale for the rest of the process.

Rules:

* ``TH001`` **traced-branch** — inside a jit-compiled / vmapped / Pallas
  kernel function, a Python ``if``/``while`` on a non-static parameter.
  Under tracing this either raises ``TracerBoolConversionError`` or, worse,
  burns the branch taken at trace time into every later call.  ``x is
  None`` tests, shape-derived values (``len``, ``.shape``, ``.ndim``,
  ``.size``, ``.dtype``), declared-static argnames and parameters annotated
  as plain Python scalars (``bool``/``int``/``str``) are exempt.
* ``TH002`` **host-sync** — ``.item()``, ``np.asarray``/``np.array``, or
  ``float()``/``int()``/``bool()`` applied to a traced parameter inside a
  jitted function: a device→host sync that blocks the trace.
* ``TH003`` **import-frozen-routing** — module-level
  ``jax.default_backend()`` / ``jax.devices()`` / ``os.environ`` reads.
  The answer is captured at import, so later backend selection or env
  changes are ignored (the ``_ON_TPU`` bug class).
* ``TH004`` **first-call-frozen-routing** — an ``lru_cache``/``cache``
  wrapped function whose body reads env vars or the backend: same bug one
  call later (the frozen ``_default_kernel_min_n`` class).
* ``TH005`` **unbucketed-dispatch** — a function that dispatches to a
  Pallas/jitted entry and allocates padded device buffers with
  data-dependent sizes, with no pow2/bucket discipline in sight: every
  distinct shape compiles a fresh signature, bypassing the bucket ladder
  that bounds recompilation.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .core import Finding, SourceFile, register_rules

__all__ = ["check", "RULES"]

RULES = {
    "TH001": "Python branch on a traced value inside a jitted function",
    "TH002": "host sync (.item()/np.asarray/float()) inside a jitted function",
    "TH003": "backend/env detection at import time freezes routing",
    "TH004": "lru_cache over an env/backend read freezes routing after one call",
    "TH005": "data-dependent device buffer sizes bypass the pow2 bucket ladder",
}
register_rules(RULES)

_ENV_READ_FUNCS = {"os.environ.get", "os.getenv", "environ.get", "getenv"}
_BACKEND_FUNCS = {"jax.default_backend", "jax.devices", "jax.local_devices",
                  "default_backend", "devices", "local_devices"}
_TRACING_WRAPPERS = {"jax.jit", "jit", "jax.vmap", "vmap", "jax.pmap", "pmap",
                     "pl.pallas_call", "pallas_call"}
_STATIC_ANNOTATIONS = {"bool", "int", "str", "float"}
_SHAPE_ATTRS = {"shape", "ndim", "size", "dtype"}
_ALLOC_FUNCS = {"zeros", "full", "empty", "ones", "pad"}


def _dotted(node: ast.AST) -> Optional[str]:
    """'jax.jit' for Attribute chains / Names; None for anything else."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_env_read(call: ast.Call) -> bool:
    d = _dotted(call.func)
    if d in _ENV_READ_FUNCS:
        return True
    # os.environ["X"] subscripts (read or write targets are both captures).
    return False


def _has_env_subscript(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Subscript) \
                and _dotted(sub.value) in ("os.environ", "environ"):
            return True
    return False


def _reads_env_or_backend(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            d = _dotted(sub.func)
            if d in _ENV_READ_FUNCS or d in _BACKEND_FUNCS:
                return True
    return _has_env_subscript(node)


def _static_argnames(call: ast.Call) -> Set[str]:
    """static_argnames=("a", "b") keyword of a jit call/partial."""
    out: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for sub in ast.walk(kw.value):
                if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
                    out.add(sub.value)
    return out


def _collect_traced(tree: ast.Module) -> Dict[str, Set[str]]:
    """Function name -> static argnames, for every traced function:
    jit/vmap decorated, jit-wrapped in an assignment, or passed by name to
    a tracing wrapper / lax control-flow combinator anywhere in the module.
    """
    traced: Dict[str, Set[str]] = {}

    def mark(name: Optional[str], statics: Set[str]) -> None:
        if name:
            traced[name] = traced.get(name, set()) | statics

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                call = dec if isinstance(dec, ast.Call) else None
                d = _dotted(call.func if call else dec)
                if d in _TRACING_WRAPPERS:
                    mark(node.name, _static_argnames(call) if call else set())
                elif d in ("functools.partial", "partial") and call \
                        and call.args and _dotted(call.args[0]) \
                        in _TRACING_WRAPPERS:
                    mark(node.name, _static_argnames(call))
        elif isinstance(node, ast.Call):
            d = _dotted(node.func)
            args = node.args
            if d in _TRACING_WRAPPERS and args \
                    and isinstance(args[0], ast.Name):
                mark(args[0].id, _static_argnames(node))
            elif d in ("jax.lax.scan", "lax.scan") and args \
                    and isinstance(args[0], ast.Name):
                mark(args[0].id, set())
            elif d in ("jax.lax.fori_loop", "lax.fori_loop") \
                    and len(args) >= 3 and isinstance(args[2], ast.Name):
                mark(args[2].id, set())
            elif d in ("jax.lax.while_loop", "lax.while_loop"):
                for a in args[:2]:
                    if isinstance(a, ast.Name):
                        mark(a.id, set())
    return traced


def _scalar_annotated(fn: ast.FunctionDef) -> Set[str]:
    out: Set[str] = set()
    for arg in list(fn.args.args) + list(fn.args.kwonlyargs):
        ann = arg.annotation
        if ann is None:
            continue
        d = _dotted(ann)
        if d in _STATIC_ANNOTATIONS:
            out.add(arg.arg)
    return out


def _param_names(fn: ast.FunctionDef) -> Set[str]:
    a = fn.args
    names = [p.arg for p in a.args + a.kwonlyargs + a.posonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return {n for n in names if n != "self"}


def _traced_names_in(expr: ast.AST, traced_params: Set[str]) -> List[ast.Name]:
    """Name nodes of traced params in ``expr``, skipping shape-derived and
    ``is None`` subtrees (static under tracing)."""
    hits: List[ast.Name] = []

    def visit(node: ast.AST) -> None:
        if isinstance(node, ast.Attribute) and node.attr in _SHAPE_ATTRS:
            return                     # x.shape / x.ndim: static under jit
        if isinstance(node, ast.Call):
            d = _dotted(node.func)
            if d == "len":
                return                 # len(x): static shape info
        if isinstance(node, ast.Compare) \
                and all(isinstance(op, (ast.Is, ast.IsNot))
                        for op in node.ops):
            return                     # x is None: resolved at trace time
        if isinstance(node, ast.Name) and node.id in traced_params:
            hits.append(node)
            return
        for child in ast.iter_child_nodes(node):
            visit(child)

    visit(expr)
    return hits


def _check_traced_fn(src: SourceFile, fn: ast.FunctionDef,
                     statics: Set[str], findings: List[Finding]) -> None:
    traced_params = _param_names(fn) - statics - _scalar_annotated(fn)
    # Nested defs are separate scopes (often themselves traced bodies with
    # their own params); exclude their nodes from this function's walk.
    nested_nodes = {id(x) for n in ast.walk(fn)
                    if isinstance(n, ast.FunctionDef) and n is not fn
                    for x in ast.walk(n)}

    for node in ast.walk(fn):
        if id(node) in nested_nodes:
            continue
        if isinstance(node, (ast.If, ast.While)):
            for hit in _traced_names_in(node.test, traced_params):
                findings.append(Finding(
                    src.path, node.lineno, "TH001",
                    f"branch on traced value `{hit.id}` inside jitted "
                    f"`{fn.name}` (declare it static or use lax.cond/where)"))
                break
        elif isinstance(node, ast.Call):
            d = _dotted(node.func)
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "item" and not node.args:
                findings.append(Finding(
                    src.path, node.lineno, "TH002",
                    f".item() host sync inside jitted `{fn.name}`"))
            elif d in ("np.asarray", "np.array", "numpy.asarray",
                       "numpy.array", "float", "int", "bool") and node.args:
                if _traced_names_in(node.args[0], traced_params):
                    findings.append(Finding(
                        src.path, node.lineno, "TH002",
                        f"`{d}` on a traced value inside jitted "
                        f"`{fn.name}` forces a host round-trip"))


def _check_module_level(src: SourceFile, findings: List[Finding]) -> None:
    for stmt in src.tree.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef, ast.Import, ast.ImportFrom)):
            continue
        for node in ast.walk(stmt):
            if isinstance(node, ast.Call):
                d = _dotted(node.func)
                if d in _BACKEND_FUNCS and d.startswith("jax."):
                    findings.append(Finding(
                        src.path, node.lineno, "TH003",
                        f"module-level `{d}()` freezes backend routing at "
                        "import; resolve per call"))
                elif d in _ENV_READ_FUNCS:
                    findings.append(Finding(
                        src.path, node.lineno, "TH003",
                        f"module-level env read `{d}` freezes the flag at "
                        "import; resolve per call"))
            elif isinstance(node, ast.Subscript) \
                    and _dotted(node.value) in ("os.environ", "environ"):
                findings.append(Finding(
                    src.path, node.lineno, "TH003",
                    "module-level os.environ access freezes the flag at "
                    "import; resolve per call"))


def _check_frozen_caches(src: SourceFile, findings: List[Finding]) -> None:
    for node in ast.walk(src.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for dec in node.decorator_list:
            d = _dotted(dec.func if isinstance(dec, ast.Call) else dec)
            if d in ("functools.lru_cache", "lru_cache", "functools.cache",
                     "cache"):
                if _reads_env_or_backend(node):
                    findings.append(Finding(
                        src.path, node.lineno, "TH004",
                        f"`{node.name}` caches an env/backend read: the "
                        "routing flag freezes after the first call"))


def _check_unbucketed(src: SourceFile, traced: Dict[str, Set[str]],
                      findings: List[Finding]) -> None:
    # Scope: the serving/solver dispatch paths plus the kernel packages,
    # where query-dependent shapes arrive at jitted entries.  Arch/train
    # builders compile once per fixed model config by design.
    parts = src.path.split("/")
    if not any(p in ("serve", "core", "kernels") for p in parts):
        return
    for fn in ast.walk(src.tree):
        if not isinstance(fn, ast.FunctionDef):
            continue
        tokens: Set[str] = set()
        dispatches = False
        allocs: List[Tuple[int, ast.Call]] = []
        for node in ast.walk(fn):
            if isinstance(node, ast.Name):
                tokens.add(node.id)
            elif isinstance(node, ast.Attribute):
                tokens.add(node.attr)
            if isinstance(node, ast.Call):
                d = _dotted(node.func) or ""
                leaf = d.rsplit(".", 1)[-1]
                if leaf.endswith("_pallas") or leaf == "pallas_call" \
                        or leaf in traced:
                    dispatches = True
                if leaf in _ALLOC_FUNCS and node.args \
                        and any(isinstance(s, ast.Name)
                                for s in ast.walk(node.args[0])):
                    allocs.append((node.lineno, node))
        # Referencing a jit-wrapped module symbol (e.g. `_fused`) counts as
        # a dispatch even when called through an alias.
        if tokens & set(traced):
            dispatches = True
        if not (dispatches and allocs):
            continue
        # Accepted shape disciplines: an explicit pow2/bucket ladder, a
        # fixed chunk size, or Pallas block tiling (BlockSpec et al.).
        if any(d in t.lower() for t in tokens
               for d in ("pow2", "bucket", "chunk", "block")):
            continue
        line = allocs[0][0]
        findings.append(Finding(
            src.path, line, "TH005",
            f"`{fn.name}` pads device buffers with data-dependent sizes "
            "and dispatches to a kernel without a pow2/bucket ladder: "
            "every distinct shape compiles a fresh signature"))


def check(src: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    traced = _collect_traced(src.tree)
    fns = {n.name: n for n in ast.walk(src.tree)
           if isinstance(n, ast.FunctionDef)}
    for name, statics in traced.items():
        if name in fns:
            _check_traced_fn(src, fns[name], statics, findings)
    _check_module_level(src, findings)
    _check_frozen_caches(src, findings)
    _check_unbucketed(src, traced, findings)
    return findings
