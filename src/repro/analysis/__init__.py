"""``repro.analysis`` — invariant-checking static analysis for the repo.

Four checkers over the source tree, each pinning a bug class every earlier
PR has hand-fixed at least once:

* :mod:`.trace_hazards` (``TH*``) — traced-value branches, host syncs,
  import/first-call-frozen backend & env routing, unbucketed dispatch.
* :mod:`.cache_keys` (``CK*``) — serving-cache key completeness against
  the context dimensions the cached computations read.
* :mod:`.determinism` (``DT*``) — wall-clock, unseeded RNG and
  set-iteration-order leaks in transcript-order paths.
* :mod:`.kernel_parity` (``KP*``) — every kernel package ships a ref,
  a registered parity test, and tie-tolerant f32 routing.

Run ``python -m repro.analysis [--strict] [paths...]`` (default ``src``);
suppress an intentional finding inline with
``# repro: allow[RULE] written justification``.
"""
from .core import (Finding, RunResult, SourceFile, RULES, render_report,
                   run_files, run_paths)

__all__ = ["Finding", "RunResult", "SourceFile", "RULES", "render_report",
           "run_files", "run_paths"]
