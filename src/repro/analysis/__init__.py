"""``repro.analysis`` — invariant-checking static analysis for the repo.

Six checkers over the source tree, each pinning a bug class every earlier
PR has hand-fixed at least once:

* :mod:`.trace_hazards` (``TH*``) — traced-value branches, host syncs,
  import/first-call-frozen backend & env routing, unbucketed dispatch.
* :mod:`.cache_keys` (``CK*``) — serving-cache key completeness against
  the context dimensions the cached computations read, file-scoped and
  interprocedurally through the call graph.
* :mod:`.determinism` (``DT*``) — wall-clock, unseeded RNG and
  set-iteration-order leaks in transcript-order paths.
* :mod:`.kernel_parity` (``KP*``) — every kernel package ships a ref,
  a registered parity test, and tie-tolerant f32 routing.
* :mod:`.replay_purity` (``RP*``) — ambient process state (wall clock,
  env, global RNG, ``id()``, module-global mutation) read anywhere
  reachable from the serving entrypoints on the project call graph.
* :mod:`.snapshot_safety` (``SN*``) — fleet snapshot blobs: pin filters
  at pack sites, no ``id()`` flows into blobs, restores re-freeze arrays.

The dataflow layer the project-scoped checkers share — per-module symbol
tables, def-use chains, call graph + reachability — lives in
:mod:`.core` (:class:`~.core.CallGraph`).

Run ``python -m repro.analysis [--strict] [paths...]`` (default ``src``);
suppress an intentional finding inline with
``# repro: allow[RULE] written justification``.  ``--rules`` prints the
generated rules reference; ``--json`` emits machine-readable findings;
``--select TH,CK`` scopes the active rule set.
"""
from .core import (CallGraph, Finding, RunResult, SourceFile, RULES,
                   render_json, render_report, render_rules, run_files,
                   run_paths)

__all__ = ["CallGraph", "Finding", "RunResult", "SourceFile", "RULES",
           "render_json", "render_report", "render_rules", "run_files",
           "run_paths"]
