"""Snapshot-safety checker for the fleet cache exchange.

PR 9's snapshot contract: caches cross process boundaries only as
content-addressed blobs.  Entries keyed or fingerprinted by process-local
state (live-object ``id()`` pins) must stay process-local — a snapshot
carrying one would collide or silently mismatch when restored elsewhere —
and numpy arrays coming back out of a blob are shared by reference among
every future cache hit, so a restore path that does not re-freeze them
(``setflags(write=False)``) reintroduces the exact mutable-shared-array
bug class PR 9 fixed by hand.

Rules (file-scoped over every ``pack_snapshot`` / ``unpack_snapshot``
site; ``CacheStore.publish`` sites are covered because blobs only enter
the store through ``pack_snapshot``):

* ``SN001`` **unfiltered snapshot** — a ``pack_snapshot(kind, entries)``
  call whose entries expression (traced through local def-use chains)
  contains no comprehension filter referencing a pin discriminator
  (``model`` / ``fingerprint`` / ``isinstance`` / ``_fp`` / ``pin``).
  Kinds registered content-pure in ``CONTENT_PURE_KINDS`` are exempt:
  every entry of such a cache is content-addressed by construction, so
  there is nothing process-local to filter out.
* ``SN002`` **identity in blob** — an ``id(...)`` call in the entries
  expression's def-use closure: an object identity is flowing into a
  serialized snapshot.
* ``SN003`` **unfrozen restore** — a function unpacks an array-carrying
  kind (``ARRAY_KINDS``) without calling ``setflags(write=False)``
  before the entries go live.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set

from .core import Finding, SourceFile, assignments, dotted, register_rules

__all__ = ["check", "RULES", "CONTENT_PURE_KINDS", "ARRAY_KINDS",
           "PIN_TOKENS"]

RULES = {
    "SN001": "snapshot packs entries without a process-local exclusion "
             "filter",
    "SN002": "id()-derived value flows into a snapshot blob",
    "SN003": "restored snapshot arrays are not re-frozen "
             "(setflags(write=False))",
}
register_rules(RULES)

# Snapshot kinds whose every entry is content-addressed by construction
# (candidate pools are pure functions of (seed, n_candidates, scope)):
# no pin filter required when packing.
CONTENT_PURE_KINDS: Set[str] = {"pools"}
# Snapshot kinds whose blobs carry numpy arrays that cache hits hand out
# by reference: restores must re-freeze.
ARRAY_KINDS: Set[str] = {"pools", "eset"}
# A comprehension `if` mentioning any of these counts as a pin filter.
PIN_TOKENS = ("model", "fingerprint", "isinstance", "_fp", "pin")


def _call_name(node: ast.Call) -> str:
    return (dotted(node.func) or "").rsplit(".", 1)[-1]


def _literal_kind(call: ast.Call) -> Optional[str]:
    """The snapshot-kind argument when it is a string literal."""
    args = list(call.args)
    for kw in call.keywords:
        if kw.arg == "kind":
            args.append(kw.value)
    for a in args:
        if isinstance(a, ast.Constant) and isinstance(a.value, str):
            return a.value
    return None


def _closure_exprs(expr: ast.AST, fn: ast.AST) -> List[ast.AST]:
    """The expression plus every rhs its names resolve to (def-use)."""
    assigns = assignments(fn)
    out: List[ast.AST] = []
    frontier = [expr]
    seen: Set[str] = set()
    while frontier:
        e = frontier.pop()
        out.append(e)
        for sub in ast.walk(e):
            if isinstance(sub, ast.Name) and sub.id not in seen:
                seen.add(sub.id)
                frontier.extend(assigns.get(sub.id, []))
    return out

def _has_pin_filter(exprs: Sequence[ast.AST]) -> bool:
    for e in exprs:
        for sub in ast.walk(e):
            if isinstance(sub, (ast.ListComp, ast.GeneratorExp,
                                ast.SetComp, ast.DictComp)):
                for gen in sub.generators:
                    for cond in gen.ifs:
                        toks = {t.lower() for t in _tokens(cond)}
                        if any(p in t for t in toks for p in PIN_TOKENS):
                            return True
    return False


def _tokens(node: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            out.add(sub.id)
        elif isinstance(sub, ast.Attribute):
            out.add(sub.attr)
    return out


def _has_id_call(exprs: Sequence[ast.AST]) -> bool:
    for e in exprs:
        for sub in ast.walk(e):
            if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name) \
                    and sub.func.id == "id" and len(sub.args) == 1:
                return True
    return False


def _freezes_arrays(fn: ast.AST, module_fns: Dict[str, ast.AST]) -> bool:
    """True when the function — or a same-module helper it calls — calls
    ``x.setflags(write=False)``."""
    stack: List[ast.AST] = [fn]
    seen: Set[int] = set()
    while stack:
        f = stack.pop()
        if id(f) in seen:
            continue
        seen.add(id(f))
        for node in ast.walk(f):
            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "setflags":
                for kw in node.keywords:
                    if kw.arg == "write" \
                            and isinstance(kw.value, ast.Constant) \
                            and kw.value.value is False:
                        return True
            leaf = _call_name(node)
            if leaf in module_fns:
                stack.append(module_fns[leaf])
    return False


def check(src: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    fns: List[ast.AST] = [n for n in ast.walk(src.tree)
                          if isinstance(n, (ast.FunctionDef,
                                            ast.AsyncFunctionDef))]
    module_fns: Dict[str, ast.AST] = {f.name: f for f in fns}
    for fn in fns:
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = _call_name(node)
            if name == "pack_snapshot":
                kind = _literal_kind(node)
                entries = (node.args[1] if len(node.args) > 1 else
                           next((kw.value for kw in node.keywords
                                 if kw.arg == "entries"), None))
                if entries is None:
                    continue
                exprs = _closure_exprs(entries, fn)
                if kind not in CONTENT_PURE_KINDS \
                        and not _has_pin_filter(exprs):
                    findings.append(Finding(
                        src.path, node.lineno, "SN001",
                        f"`{fn.name}` packs kind `{kind}` without a "
                        "filter excluding process-local (id-pinned) "
                        "entries"))
                if _has_id_call(exprs):
                    findings.append(Finding(
                        src.path, node.lineno, "SN002",
                        f"`{fn.name}` lets an `id(...)` value flow into "
                        f"the `{kind}` snapshot blob"))
            elif name == "unpack_snapshot":
                kind = _literal_kind(node)
                if kind in ARRAY_KINDS \
                        and not _freezes_arrays(fn, module_fns):
                    findings.append(Finding(
                        src.path, node.lineno, "SN003",
                        f"`{fn.name}` restores array-carrying kind "
                        f"`{kind}` without re-freezing "
                        "(`setflags(write=False)`); restored arrays are "
                        "shared by reference across cache hits"))
    return findings
