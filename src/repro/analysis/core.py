"""Shared dataflow / reporting core for the invariant checkers.

The suite is a set of *invariant pins*, not a general linter: each checker
encodes one determinism or correctness contract the serving stack depends
on (see the checker modules' docstrings), and the golden fixture tests in
``tests/test_analysis.py`` pin the exact findings each rule produces.

Two analysis layers share this module:

* **file-scoped checkers** (``SourceFile -> [Finding]``) — the original
  per-module walkers (trace hazards, determinism, kernel routing);
* **project-scoped checkers** (``(files, CallGraph) -> [Finding]``) — the
  dataflow layer: per-module symbol tables (:class:`ModuleSymbols`),
  def-use chains (:func:`assignments`), and a project-wide call graph
  (:class:`CallGraph`) with a reachability API
  (:meth:`CallGraph.reachable_from`) that the replay-purity,
  snapshot-safety and interprocedural cache-key checkers are built on.

Findings carry ``path:line`` and a rule id.  A finding is silenced with an
inline suppression on the flagged statement (anywhere in a multi-line
statement's span), or on a comment-only line directly above it::

    _FLAGS = os.environ.get("X")  # repro: allow[TH003] read before jax init

In ``--strict`` mode a suppression without a written justification is
itself a finding (rule ``SUP001``), and a suppression that no longer
silences anything is flagged as dead (rule ``SUP002``) — every silenced
invariant must say why, and justified exceptions cannot rot in place
after the underlying code is fixed.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import json
import re
import tokenize
from collections import Counter
from pathlib import Path
from typing import (Callable, Dict, Iterable, List, Optional, Sequence, Set,
                    Tuple)

__all__ = ["Finding", "SourceFile", "Suppression", "run_paths", "run_files",
           "render_report", "render_json", "render_rules",
           "iter_python_files", "RULES", "register_rules",
           "ModuleSymbols", "CallGraph", "assignments", "dotted", "tokens"]

# rule id -> one-line description; checker modules register theirs on import.
RULES: Dict[str, str] = {
    "SUP001": "inline suppression carries no written justification",
    "SUP002": "dead suppression: the allow[...] no longer silences any "
              "finding",
}


def register_rules(rules: Dict[str, str]) -> None:
    RULES.update(rules)


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str          # as given to the runner (repo-relative in CI)
    line: int          # 1-indexed
    rule: str
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


@dataclasses.dataclass
class Suppression:
    line: int          # line the comment sits on
    rules: tuple       # rule ids listed in allow[...]
    reason: str        # justification text after the bracket
    covers: Tuple[int, int]   # full line span of the suppressed statement
    used: bool = False        # silenced at least one finding this run

_SUPPRESS_RE = re.compile(
    r"#\s*repro:\s*allow\[([A-Za-z0-9_,\s]+)\]\s*(.*?)\s*$")

_COMPOUND = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef, ast.If,
             ast.While, ast.For, ast.AsyncFor, ast.With, ast.AsyncWith,
             ast.Try)


class SourceFile:
    """One parsed python file: text, AST, and its inline suppressions."""

    def __init__(self, path, text: Optional[str] = None):
        self.path = str(path)
        if text is None:
            text = Path(path).read_text()
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=self.path)
        self._spans = self._statement_spans()
        self.suppressions: List[Suppression] = []
        # Only genuine COMMENT tokens count — an ``allow[...]`` example
        # inside a docstring must not register as a suppression.
        comments: List[Tuple[int, str]] = []
        try:
            for tok in tokenize.generate_tokens(io.StringIO(text).readline):
                if tok.type == tokenize.COMMENT:
                    comments.append((tok.start[0], tok.string))
        except (tokenize.TokenError, IndentationError):
            pass
        for i, comment in comments:
            raw = self.lines[i - 1]
            m = _SUPPRESS_RE.search(comment)
            if not m:
                continue
            rules = tuple(r.strip() for r in m.group(1).split(",") if r.strip())
            # A comment-only line covers the next line; a trailing comment
            # covers its own line.  Either way the suppression extends to
            # the *full line span* of the statement it lands on, so a
            # finding reported on a continuation line of a multi-line
            # call/def is covered by an allow on any line of the statement.
            target = i + 1 if raw.lstrip().startswith("#") else i
            self.suppressions.append(
                Suppression(line=i, rules=rules, reason=m.group(2),
                            covers=self._spans.get(target, (target, target))))

    def _statement_spans(self) -> Dict[int, Tuple[int, int]]:
        """line -> (start, end) span of its innermost enclosing statement.

        Compound statements (def/class/if/for/...) span their *header*
        only — a suppression on a ``def`` must never silence the whole
        body.  ``ast.walk`` yields parents before children, so children
        overwrite and the innermost statement wins.
        """
        spans: Dict[int, Tuple[int, int]] = {}
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.stmt):
                continue
            start = node.lineno
            for dec in getattr(node, "decorator_list", []):
                start = min(start, dec.lineno)
            if isinstance(node, _COMPOUND) and node.body:
                end = max(start, node.body[0].lineno - 1)
            else:
                end = node.end_lineno or node.lineno
            for ln in range(start, end + 1):
                spans[ln] = (start, end)
        return spans

    def suppression_for(self, finding: Finding) -> Optional[Suppression]:
        for s in self.suppressions:
            if s.covers[0] <= finding.line <= s.covers[1] \
                    and finding.rule in s.rules:
                return s
        return None


# A file-scoped checker is a callable SourceFile -> List[Finding].  A
# project-scoped checker takes the whole parsed file set plus the call
# graph built over it: (Sequence[SourceFile], CallGraph) -> List[Finding].
Checker = Callable[[SourceFile], List[Finding]]


def iter_python_files(paths: Sequence[str],
                      exclude: Sequence[str] = ()) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            out.extend(sorted(f for f in p.rglob("*.py")
                              if "__pycache__" not in f.parts))
        elif p.suffix == ".py":
            out.append(p)
    if exclude:
        out = [f for f in out
               if not any(pat in str(f) for pat in exclude)]
    return out


# ---------------------------------------------------------------------------
# Dataflow engine: symbol tables, def-use chains, project call graph
# ---------------------------------------------------------------------------

def dotted(node: ast.AST) -> Optional[str]:
    """'jax.jit' for Attribute chains / Names; None for anything else."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def tokens(node: ast.AST) -> Set[str]:
    """Every Name id / Attribute attr in the subtree."""
    out: Set[str] = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            out.add(sub.id)
        elif isinstance(sub, ast.Attribute):
            out.add(sub.attr)
    return out


def assignments(fn: ast.AST) -> Dict[str, List[ast.AST]]:
    """Def-use chains of one scope: name -> rhs exprs, from plain,
    subscript-target, annotated, augmented and for-loop binds."""
    out: Dict[str, List[ast.AST]] = {}

    def bind(target: ast.AST, value: ast.AST) -> None:
        if isinstance(target, ast.Name):
            out.setdefault(target.id, []).append(value)
        elif isinstance(target, ast.Subscript):
            base = target.value
            if isinstance(base, ast.Name):
                out.setdefault(base.id, []).append(value)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                bind(el, value)

    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                bind(t, node.value)
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            bind(node.target, node.value)
        elif isinstance(node, ast.AugAssign):
            bind(node.target, node.value)
        elif isinstance(node, (ast.For, ast.AsyncFor)):
            bind(node.target, node.iter)
    return out


def param_names(fn: ast.AST) -> Set[str]:
    a = fn.args
    names = [p.arg for p in a.args + a.kwonlyargs + a.posonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return {n for n in names if n != "self"}


# Ubiquitous container/stdlib method names: an attribute call on a
# receiver whose type cannot be inferred never falls back to a project
# method of one of these names (the near-certain match is a dict / list /
# ndarray, not a project class).
_GENERIC_METHODS = frozenset({
    "get", "pop", "update", "copy", "clear", "items", "keys", "values",
    "append", "extend", "add", "remove", "discard", "insert", "index",
    "count", "sort", "join", "split", "strip", "lstrip", "rstrip",
    "format", "encode", "decode", "setdefault", "popitem", "move_to_end",
    "startswith", "endswith", "lower", "upper", "tolist", "astype",
    "reshape", "setflags", "mean", "send", "close", "read", "write",
})


class ModuleSymbols:
    """Per-module symbol table: functions, classes + methods, imports
    (aliased, relative imports resolved against the module path), class
    attribute types, and module-level bindings."""

    def __init__(self, src: SourceFile, module: str):
        self.src = src
        self.module = module
        self.functions: Dict[str, ast.AST] = {}    # "f" / "Cls.meth" -> def
        self.classes: Dict[str, ast.ClassDef] = {}
        self.bases: Dict[str, List[str]] = {}      # class -> base name tokens
        self.imports: Dict[str, str] = {}          # local alias -> dotted
        self.attr_types: Dict[Tuple[str, str], Set[str]] = {}
        self.module_names: Set[str] = set()        # module-level bindings
        for node in src.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.functions[node.name] = node
            elif isinstance(node, ast.ClassDef):
                self.classes[node.name] = node
                self.bases[node.name] = [t for b in node.bases
                                         for t in tokens(b)]
                for item in node.body:
                    if isinstance(item, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                        self.functions[f"{node.name}.{item.name}"] = item
                self._collect_attr_types(node)
            elif isinstance(node, (ast.Assign, ast.AnnAssign)):
                for t in ast.walk(node):
                    if isinstance(t, ast.Name) \
                            and isinstance(t.ctx, ast.Store):
                        self.module_names.add(t.id)
        # Imports anywhere in the module (function-local lazy imports are
        # the project idiom for breaking cycles).
        pkg = module.rsplit(".", 1)[0] if "." in module else ""
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    self.imports[alias.asname or alias.name.split(".")[0]] \
                        = alias.name
            elif isinstance(node, ast.ImportFrom):
                base = node.module or ""
                if node.level:
                    up = module.split(".")
                    up = up[:len(up) - node.level]
                    base = ".".join(up + ([node.module] if node.module
                                          else []))
                elif not base:
                    base = pkg
                for alias in node.names:
                    self.imports[alias.asname or alias.name] = \
                        f"{base}.{alias.name}" if base else alias.name

    def _collect_attr_types(self, cls: ast.ClassDef) -> None:
        """``self.x = ClassName(...)`` / dataclass field annotations ->
        candidate type-name tokens for ``self.x`` receivers."""
        for node in ast.walk(cls):
            if isinstance(node, ast.AnnAssign) \
                    and isinstance(node.target, ast.Name):
                self.attr_types.setdefault(
                    (cls.name, node.target.id), set()).update(
                    tokens(node.annotation))
            elif isinstance(node, (ast.Assign, ast.AnnAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                value = node.value
                if value is None:
                    continue
                for t in targets:
                    if isinstance(t, ast.Attribute) \
                            and isinstance(t.value, ast.Name) \
                            and t.value.id == "self":
                        cands = {dotted(sub.func).rsplit(".", 1)[-1]
                                 for sub in ast.walk(value)
                                 if isinstance(sub, ast.Call)
                                 and dotted(sub.func)}
                        if isinstance(node, ast.AnnAssign):
                            cands |= tokens(node.annotation)
                        self.attr_types.setdefault(
                            (cls.name, t.attr), set()).update(cands)


@dataclasses.dataclass(frozen=True)
class CallSite:
    caller: str               # qualified name of the calling function
    node: ast.Call            # the call expression
    src: "SourceFile"


class CallGraph:
    """Project-wide call graph over qualified function names.

    Qualified names are ``<dotted module>.<func>`` or
    ``<dotted module>.<Class>.<method>`` where the module path is the
    file's path parts joined with dots (suffix matching makes the root
    irrelevant — see :meth:`resolve`).  Resolution order per call site:
    local/imported names, ``self``/``cls`` receivers (methods + base
    classes), typed receivers (``x = ClassName(...)`` def-use chains,
    parameter annotations, class attribute types), then a project-wide
    method-name fallback for unknown receivers (over-approximate by
    design; gated by :data:`_GENERIC_METHODS`).
    """

    def __init__(self, files: Sequence[SourceFile]):
        self.modules: Dict[str, ModuleSymbols] = {}
        self.functions: Dict[str, Tuple[SourceFile, ast.AST]] = {}
        self._method_index: Dict[str, List[str]] = {}  # meth name -> qnames
        self._class_index: Dict[str, List[str]] = {}   # class name -> modules
        for src in files:
            module = ".".join(Path(src.path).with_suffix("").parts)
            if module.endswith(".__init__"):
                module = module[: -len(".__init__")]
            sym = ModuleSymbols(src, module)
            self.modules[module] = sym
            for suffix, fn in sym.functions.items():
                qname = f"{module}.{suffix}"
                self.functions[qname] = (src, fn)
                self._method_index.setdefault(
                    suffix.rsplit(".", 1)[-1], []).append(qname)
            for cname in sym.classes:
                self._class_index.setdefault(cname, []).append(module)
        self.edges: Dict[str, Set[str]] = {q: set() for q in self.functions}
        self.rev: Dict[str, Set[str]] = {q: set() for q in self.functions}
        self.sites: Dict[str, List[CallSite]] = {q: [] for q in self.functions}
        for module, sym in self.modules.items():
            for suffix, fn in sym.functions.items():
                self._link(module, sym, suffix, fn)

    # -- construction --------------------------------------------------------
    def _link(self, module: str, sym: ModuleSymbols, suffix: str,
              fn: ast.AST) -> None:
        caller = f"{module}.{suffix}"
        cls = suffix.rsplit(".", 1)[0] if "." in suffix else None
        local_types = self._local_types(sym, fn)
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            for callee in self._resolve_call(sym, cls, node, local_types):
                self.edges[caller].add(callee)
                self.rev[callee].add(caller)
                self.sites[callee].append(CallSite(caller, node, sym.src))

    def _local_types(self, sym: ModuleSymbols,
                     fn: ast.AST) -> Dict[str, Set[str]]:
        """name -> candidate class-name tokens, from ``x = Cls(...)``
        assignments and parameter annotations."""
        out: Dict[str, Set[str]] = {}
        for arg in fn.args.args + fn.args.kwonlyargs + fn.args.posonlyargs:
            if arg.annotation is not None:
                hits = tokens(arg.annotation) & set(self._class_index)
                if hits:
                    out.setdefault(arg.arg, set()).update(hits)
        for name, exprs in assignments(fn).items():
            for e in exprs:
                for sub in ast.walk(e):
                    if isinstance(sub, ast.Call) and dotted(sub.func):
                        leaf = dotted(sub.func).rsplit(".", 1)[-1]
                        if leaf in self._class_index:
                            out.setdefault(name, set()).add(leaf)
        return out

    def _method_qnames(self, cname: str, meth: str,
                       seen: Optional[Set[str]] = None) -> List[str]:
        """``Cls.meth`` qnames for class ``cname``, searching bases."""
        seen = seen if seen is not None else set()
        if cname in seen:
            return []
        seen.add(cname)
        out = []
        for module in self._class_index.get(cname, ()):
            q = f"{module}.{cname}.{meth}"
            if q in self.functions:
                out.append(q)
        if not out:
            for module in self._class_index.get(cname, ()):
                for base in self.modules[module].bases.get(cname, ()):
                    out.extend(self._method_qnames(base, meth, seen))
        return out

    def _resolve_call(self, sym: ModuleSymbols, cls: Optional[str],
                      node: ast.Call,
                      local_types: Dict[str, Set[str]]) -> List[str]:
        d = dotted(node.func)
        if d is None:
            return []
        module = sym.module
        parts = d.split(".")
        head, leaf = parts[0], parts[-1]
        # Direct name: local function, local class constructor, or import.
        if len(parts) == 1:
            if d in sym.functions:
                return [f"{module}.{d}"]
            if d in sym.classes:
                return self._method_qnames(d, "__init__")
            target = sym.imports.get(d)
            if target:
                return self._qnames_for_target(target)
            return []
        # self.meth() / cls.meth() and self.attr.meth().
        if head in ("self", "cls") and cls is not None:
            if len(parts) == 2:
                hits = self._method_qnames(cls, leaf)
                if hits:
                    return hits
            elif len(parts) == 3:
                types = sym.attr_types.get((cls, parts[1]), set())
                hits = [q for t in sorted(types)
                        for q in self._method_qnames(t, leaf)]
                if hits:
                    return hits
            return self._fallback(leaf)
        # module-alias call: mod.f() with `import mod` / `from .. import mod`
        target = sym.imports.get(head)
        if target and len(parts) == 2:
            hits = self._qnames_for_target(f"{target}.{leaf}")
            if hits:
                return hits
        # typed receiver: x.meth() where x = ClassName(...) or annotated.
        if len(parts) == 2 and head in local_types:
            hits = [q for t in sorted(local_types[head])
                    for q in self._method_qnames(t, leaf)]
            if hits:
                return hits
        return self._fallback(leaf)

    def _qnames_for_target(self, target: str) -> List[str]:
        """Project qnames whose dotted name matches an imported target
        (by exact suffix, so the scan root never matters)."""
        out = [q for q in (target,) if q in self.functions]
        if out:
            return out
        suffix = "." + target
        return [q for q in self.functions if q.endswith(suffix)]

    def _fallback(self, meth: str) -> List[str]:
        if meth in _GENERIC_METHODS:
            return []
        return list(self._method_index.get(meth, ()))

    # -- queries -------------------------------------------------------------
    def resolve(self, suffix: str) -> List[str]:
        """Qualified names matching a dotted suffix.  A suffix naming a
        class expands to every method of that class."""
        hits = [q for q in self.functions
                if q == suffix or q.endswith("." + suffix)]
        if hits:
            return sorted(hits)
        out = []
        for module, sym in self.modules.items():
            for cname in sym.classes:
                q = f"{module}.{cname}"
                if q == suffix or q.endswith("." + suffix):
                    out.extend(f"{q}.{m.rsplit('.', 1)[-1]}"
                               for m in sym.functions
                               if m.startswith(cname + "."))
        return sorted(out)

    def callees(self, qname: str) -> Set[str]:
        return self.edges.get(qname, set())

    def callers(self, qname: str) -> Set[str]:
        return self.rev.get(qname, set())

    def call_sites(self, qname: str) -> List[CallSite]:
        return self.sites.get(qname, [])

    def reachable_from(self, entrypoints: Iterable[str]) -> Set[str]:
        """Every function reachable (transitively, including the
        entrypoints themselves) from dotted-suffix entrypoints."""
        frontier: List[str] = []
        for ep in entrypoints:
            frontier.extend(self.resolve(ep))
        seen: Set[str] = set()
        while frontier:
            q = frontier.pop()
            if q in seen:
                continue
            seen.add(q)
            frontier.extend(self.edges.get(q, ()))
        return seen


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RunResult:
    findings: List[Finding]                 # unsuppressed (actionable)
    suppressed: List[Finding]               # silenced by an inline allow
    parse_errors: List[Finding]             # unreadable / unparsable files

    @property
    def ok(self) -> bool:
        return not self.findings and not self.parse_errors

    def counts(self) -> Dict[str, Dict[str, int]]:
        live = Counter(f.rule for f in self.findings + self.parse_errors)
        supp = Counter(f.rule for f in self.suppressed)
        return {r: {"findings": live.get(r, 0), "suppressed": supp.get(r, 0)}
                for r in sorted(set(live) | set(supp))}


def _rule_selected(rule: str, select: Optional[Sequence[str]]) -> bool:
    return select is None or any(rule.startswith(p) for p in select)


def run_files(files: Iterable, checkers: Sequence[Checker],
              *, strict: bool = False,
              project_checkers: Sequence[Callable] = (),
              extra_findings: Sequence[Finding] = (),
              select: Optional[Sequence[str]] = None) -> RunResult:
    """Run checkers over ``files``; split findings by suppression status.

    ``project_checkers`` run once over the whole parsed file set with the
    :class:`CallGraph` built over it.  ``extra_findings`` (tree-scoped
    results computed by the caller) join the same suppression pipeline.
    ``select`` is an optional list of rule-id prefixes: findings outside
    the selection are dropped, and suppression liveness (``SUP002``) is
    only judged against selected rules.
    """
    live: List[Finding] = []
    suppressed: List[Finding] = []
    errors: List[Finding] = []
    srcs: List[SourceFile] = []
    for f in files:
        if isinstance(f, SourceFile):
            srcs.append(f)
            continue
        try:
            srcs.append(SourceFile(f))
        except (SyntaxError, UnicodeDecodeError, OSError) as e:
            errors.append(Finding(str(f), getattr(e, "lineno", 1) or 1,
                                  "PARSE", f"unparsable file: {e}"))
    all_findings: List[Finding] = []
    for src in srcs:
        for checker in checkers:
            all_findings.extend(checker(src))
    if project_checkers:
        graph = CallGraph(srcs)
        for pc in project_checkers:
            all_findings.extend(pc(srcs, graph))
    all_findings.extend(extra_findings)
    by_path = {src.path: src for src in srcs}
    seen: Set[Tuple[str, int, str, str]] = set()
    for fd in all_findings:
        if not _rule_selected(fd.rule, select):
            continue
        key = (fd.path, fd.line, fd.rule, fd.message)
        if key in seen:            # one finding per (site, rule, message):
            continue               # file and project passes can overlap
        seen.add(key)
        src = by_path.get(fd.path)
        s = src.suppression_for(fd) if src is not None else None
        if s is None:
            live.append(fd)
        else:
            suppressed.append(fd)
            s.used = True
    sup_active = strict and _rule_selected("SUP001", select)
    if sup_active:
        for src in srcs:
            for s in src.suppressions:
                checkable = select is None or any(
                    _rule_selected(r, select) for r in s.rules)
                if not checkable:
                    continue
                if s.used and not s.reason:
                    live.append(Finding(
                        src.path, s.line, "SUP001",
                        f"suppression of {', '.join(s.rules)} has no "
                        "justification"))
                elif not s.used:
                    live.append(Finding(
                        src.path, s.line, "SUP002",
                        f"suppression of {', '.join(s.rules)} silences "
                        "nothing; remove it or re-justify"))
    live.sort(key=lambda f: (f.path, f.line, f.rule))
    return RunResult(findings=live, suppressed=suppressed,
                     parse_errors=errors)


def run_paths(paths: Sequence[str], *, strict: bool = False,
              tests_dir: Optional[str] = None,
              select: Optional[Sequence[str]] = None,
              exclude: Sequence[str] = ()) -> RunResult:
    """Full suite over ``paths``: file checkers, the project-scoped
    dataflow checkers (replay purity, snapshot safety, interprocedural
    cache keys), and the kernel-parity tree checker."""
    from . import (cache_keys, determinism, kernel_parity, replay_purity,
                   snapshot_safety, trace_hazards)

    files = iter_python_files(paths, exclude=exclude)
    tree_findings = kernel_parity.check_tree(paths, tests_dir=tests_dir)
    result = run_files(
        files,
        [trace_hazards.check, cache_keys.check, determinism.check,
         kernel_parity.check_file, snapshot_safety.check],
        strict=strict,
        project_checkers=[cache_keys.check_project,
                          replay_purity.check_project],
        extra_findings=tree_findings,
        select=select)
    result.findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return result


def render_report(result: RunResult) -> str:
    """Findings list + the per-rule summary table printed in CI logs."""
    out: List[str] = []
    for f in result.parse_errors + result.findings:
        out.append(f.format())
    counts = result.counts()
    if counts:
        out.append("")
    width = max([len("rule")] + [len(r) for r in counts])
    out.append(f"{'rule':<{width}}  findings  suppressed  description")
    for rule, c in counts.items():
        desc = RULES.get(rule, "")
        out.append(f"{rule:<{width}}  {c['findings']:>8}  "
                   f"{c['suppressed']:>10}  {desc}")
    total = len(result.findings) + len(result.parse_errors)
    out.append(f"{'total':<{width}}  {total:>8}  "
               f"{len(result.suppressed):>10}")
    return "\n".join(out)


def render_json(result: RunResult) -> str:
    """Machine-readable report for CI annotation (one JSON object)."""
    def enc(fs: Sequence[Finding]) -> List[dict]:
        return [{"path": f.path, "line": f.line, "rule": f.rule,
                 "message": f.message} for f in fs]

    return json.dumps({
        "ok": result.ok,
        "findings": enc(result.findings),
        "parse_errors": enc(result.parse_errors),
        "suppressed": enc(result.suppressed),
        "summary": result.counts(),
    }, indent=2)


def render_rules() -> str:
    """The generated rules-reference table (every registered rule id with
    its one-line contract; the README embeds this output)."""
    from . import (cache_keys, determinism, kernel_parity,  # noqa: F401
                   replay_purity, snapshot_safety, trace_hazards)

    width = max(len(r) for r in RULES)
    out = [f"{'rule':<{width}}  contract",
           f"{'-' * width}  {'-' * 8}"]
    for rule in sorted(RULES):
        out.append(f"{rule:<{width}}  {RULES[rule]}")
    return "\n".join(out)
