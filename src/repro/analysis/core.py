"""Shared walker / reporting core for the invariant checkers.

The suite is a set of *invariant pins*, not a general linter: each checker
encodes one determinism or correctness contract the serving stack depends
on (see the checker modules' docstrings), and the golden fixture tests in
``tests/test_analysis.py`` pin the exact findings each rule produces.

Findings carry ``path:line`` and a rule id.  A finding is silenced with an
inline suppression on the flagged line, or on a comment-only line directly
above it::

    _FLAGS = os.environ.get("X")  # repro: allow[TH003] read before jax init

In ``--strict`` mode a suppression without a written justification is
itself a finding (rule ``SUP001``) — every silenced invariant must say why.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from collections import Counter
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence

__all__ = ["Finding", "SourceFile", "Suppression", "run_paths", "run_files",
           "render_report", "iter_python_files", "RULES", "register_rules"]

# rule id -> one-line description; checker modules register theirs on import.
RULES: Dict[str, str] = {
    "SUP001": "inline suppression carries no written justification",
}


def register_rules(rules: Dict[str, str]) -> None:
    RULES.update(rules)


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str          # as given to the runner (repo-relative in CI)
    line: int          # 1-indexed
    rule: str
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


@dataclasses.dataclass(frozen=True)
class Suppression:
    line: int          # line the comment sits on
    rules: tuple       # rule ids listed in allow[...]
    reason: str        # justification text after the bracket
    covers: int        # line the suppression applies to

_SUPPRESS_RE = re.compile(
    r"#\s*repro:\s*allow\[([A-Za-z0-9_,\s]+)\]\s*(.*?)\s*$")


class SourceFile:
    """One parsed python file: text, AST, and its inline suppressions."""

    def __init__(self, path, text: Optional[str] = None):
        self.path = str(path)
        if text is None:
            text = Path(path).read_text()
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=self.path)
        self.suppressions: List[Suppression] = []
        for i, raw in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(raw)
            if not m:
                continue
            rules = tuple(r.strip() for r in m.group(1).split(",") if r.strip())
            # A comment-only line covers the next line; a trailing comment
            # covers its own line.
            covers = i + 1 if raw.lstrip().startswith("#") else i
            self.suppressions.append(
                Suppression(line=i, rules=rules, reason=m.group(2),
                            covers=covers))

    def suppression_for(self, finding: Finding) -> Optional[Suppression]:
        for s in self.suppressions:
            if s.covers == finding.line and finding.rule in s.rules:
                return s
        return None


# A checker is a callable SourceFile -> List[Finding].  Project-scoped
# checkers (kernel parity) are run separately by the CLI over the tree.
Checker = Callable[[SourceFile], List[Finding]]


def iter_python_files(paths: Sequence[str]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            out.extend(sorted(f for f in p.rglob("*.py")
                              if "__pycache__" not in f.parts))
        elif p.suffix == ".py":
            out.append(p)
    return out


@dataclasses.dataclass
class RunResult:
    findings: List[Finding]                 # unsuppressed (actionable)
    suppressed: List[Finding]               # silenced by an inline allow
    parse_errors: List[Finding]             # unreadable / unparsable files

    @property
    def ok(self) -> bool:
        return not self.findings and not self.parse_errors

    def counts(self) -> Dict[str, Dict[str, int]]:
        live = Counter(f.rule for f in self.findings + self.parse_errors)
        supp = Counter(f.rule for f in self.suppressed)
        return {r: {"findings": live.get(r, 0), "suppressed": supp.get(r, 0)}
                for r in sorted(set(live) | set(supp))}


def run_files(files: Iterable, checkers: Sequence[Checker],
              *, strict: bool = False) -> RunResult:
    """Run file-scoped checkers; split findings by suppression status."""
    live: List[Finding] = []
    suppressed: List[Finding] = []
    errors: List[Finding] = []
    for f in files:
        if isinstance(f, SourceFile):
            src = f
        else:
            try:
                src = SourceFile(f)
            except (SyntaxError, UnicodeDecodeError, OSError) as e:
                errors.append(Finding(str(f), getattr(e, "lineno", 1) or 1,
                                      "PARSE", f"unparsable file: {e}"))
                continue
        file_findings: List[Finding] = []
        for checker in checkers:
            file_findings.extend(checker(src))
        for fd in file_findings:
            s = src.suppression_for(fd)
            if s is None:
                live.append(fd)
            else:
                suppressed.append(fd)
                if strict and not s.reason:
                    live.append(Finding(
                        src.path, s.line, "SUP001",
                        f"suppression of {fd.rule} has no justification"))
    live.sort(key=lambda f: (f.path, f.line, f.rule))
    return RunResult(findings=live, suppressed=suppressed,
                     parse_errors=errors)


def run_paths(paths: Sequence[str], *, strict: bool = False,
              tests_dir: Optional[str] = None) -> RunResult:
    """Full suite over ``paths``: file checkers + the kernel-parity tree
    checker (which needs the kernels package and the parity-test file)."""
    from . import cache_keys, determinism, kernel_parity, trace_hazards

    files = iter_python_files(paths)
    result = run_files(
        files,
        [trace_hazards.check, cache_keys.check, determinism.check,
         kernel_parity.check_file],
        strict=strict)
    result.findings.extend(
        kernel_parity.check_tree(paths, tests_dir=tests_dir))
    result.findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return result


def render_report(result: RunResult) -> str:
    """Findings list + the per-rule summary table printed in CI logs."""
    out: List[str] = []
    for f in result.parse_errors + result.findings:
        out.append(f.format())
    counts = result.counts()
    if counts:
        out.append("")
    width = max([len("rule")] + [len(r) for r in counts])
    out.append(f"{'rule':<{width}}  findings  suppressed  description")
    for rule, c in counts.items():
        desc = RULES.get(rule, "")
        out.append(f"{rule:<{width}}  {c['findings']:>8}  "
                   f"{c['suppressed']:>10}  {desc}")
    total = len(result.findings) + len(result.parse_errors)
    out.append(f"{'total':<{width}}  {total:>8}  "
               f"{len(result.suppressed):>10}")
    return "\n".join(out)
