"""Analytical roofline cost model for cluster autotuning.

Mirrors the dry-run's three-term analysis (compute / HBM / ICI) as closed
forms over (arch, shape, θc, θp, θs) so the HMOOC solver can evaluate tens
of thousands of candidates in milliseconds — the same role the GTN models
play for Spark queries.  Latency decomposes into per-block terms (embed /
attention / ffn / head) whose SUM is the step latency, which is exactly the
structure HMOOC's DAG aggregation requires.

Infeasible configurations (projected HBM > capacity) evaluate to +inf.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from ..archs.common import ArchConfig
from ..launch.hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS
from ..launch.shapes import SHAPES, ShapeCell
from .params import BLOCKS, cluster_theta_c, cluster_theta_p, cluster_theta_s

__all__ = ["ClusterCostModel", "CHIP_PRICE_H", "HBM_CAP"]

CHIP_PRICE_H = 1.2      # $/chip-hour (v5e-like on-demand)
HBM_CAP = 16e9          # bytes per chip
MXU_EFF = 0.6           # achievable fraction of peak on real blocks


@dataclasses.dataclass
class ClusterCostModel:
    cfg: ArchConfig
    cell: ShapeCell

    def __post_init__(self):
        c = self.cfg
        self.cs = cluster_theta_c()
        self.ps = cluster_theta_p()
        self.ss = cluster_theta_s()
        self.tokens = self.cell.global_batch * self.cell.seq_len
        self.params_block: Dict[str, float] = self._params_by_block()
        self.train = self.cell.kind == "train"

    # -- static parameter accounting ------------------------------------------
    def _params_by_block(self) -> Dict[str, float]:
        c = self.cfg
        d, f, L = c.d_model, c.d_ff, c.n_layers
        hd = c.head_dim
        attn = L * (d * hd * (c.n_heads + 2 * c.n_kv) + c.n_heads * hd * d)
        if c.n_experts:
            ffn = L * (3 * d * f * c.n_experts + d * c.n_experts)
        else:
            ffn = L * 3 * d * f
        emb = c.vocab * d
        head = 0 if c.tie_embeddings else c.vocab * d
        return {"embed": emb, "attention": attn, "ffn": ffn, "head": head}

    def _flops_by_block(self, cap: np.ndarray) -> Dict[str, np.ndarray]:
        c = self.cfg
        T = self.tokens
        d, f, L = c.d_model, c.d_ff, c.n_layers
        hd = c.head_dim
        S = self.cell.seq_len if self.cell.kind != "decode" else \
            self.cell.seq_len  # decode context length
        Tq = T
        proj = 2 * Tq * d * hd * (c.n_heads + 2 * c.n_kv) \
            + 2 * Tq * c.n_heads * hd * d
        ctx = S if self.cell.kind != "train" else S
        attn_mm = 4 * Tq * ctx * c.n_heads * hd * \
            (0.5 if self.cell.kind == "train" else 1.0)   # causal half
        attn = L * (proj + attn_mm)
        if c.n_experts:
            ffn = L * (6 * Tq * c.top_k * d * f * cap
                       + 2 * Tq * d * c.n_experts)
        else:
            ffn = L * 6 * Tq * d * f
        embed = np.zeros_like(cap) + 2 * Tq * d
        head = np.zeros_like(cap) + 2 * Tq * d * c.vocab
        return {"embed": embed, "attention": attn + np.zeros_like(cap),
                "ffn": ffn, "head": head}

    # -- evaluation --------------------------------------------------------------
    def stage_eval(self, block_idx: int, Tc_unit: np.ndarray,
                   Tps_unit: np.ndarray) -> np.ndarray:
        """HMOOC stage evaluator: (n, d_c) ⊕ (n, d_p + d_s) → (n, 2)."""
        block = BLOCKS[block_idx]
        tc = self.cs.to_raw(Tc_unit)
        dp_p = self.ps.dim
        tp_raw = self.ps.to_raw(Tps_unit[..., :dp_p])
        ts_raw = self.ss.to_raw(Tps_unit[..., dp_p:])

        chips = tc[:, 0]
        tp = np.minimum(tc[:, 1], chips)
        moment_bf16 = tc[:, 2] > 0.5
        act_shard = tc[:, 3] > 0.5
        remat = tp_raw[:, 0] > 0.5
        chunked = tp_raw[:, 1] > 0.5
        cap = np.clip(tp_raw[:, 2], 1.0, 2.0)
        accum = ts_raw[:, 0]
        dp = np.maximum(chips / tp, 1.0)

        c = self.cfg
        T = self.tokens
        d, L = c.d_model, c.n_layers
        P_b = self.params_block[block]
        flops = self._flops_by_block(cap)[block]

        # --- compute term ------------------------------------------------------
        bwd = (3.0 if self.train else 1.0)
        re = np.where(remat & self.train, 4.0 / 3.0, 1.0)
        compute_s = flops * bwd * re / (chips * PEAK_FLOPS * MXU_EFF)

        # --- HBM term ----------------------------------------------------------
        passes = (2.0 + accum if self.train else 1.0)   # fwd+bwd(+per-μb re-read)
        w_bytes = P_b * 2.0 * passes / chips
        act_traffic = {"embed": 4, "attention": 12, "ffn": 10, "head": 6}[block]
        act_traffic = act_traffic + np.where(
            (block == "attention") & chunked, 4.0, 0.0)  # KV re-streamed
        a_bytes = T * d * 2.0 * act_traffic * bwd / chips
        memory_s = (w_bytes + a_bytes) / HBM_BW

        # --- collective term -----------------------------------------------------
        coll = np.zeros_like(chips, dtype=np.float64)
        if self.train:
            # grad reduce-scatter + param all-gather across dp (per chip).
            coll += 2.0 * (P_b * 2.0 / tp) * (dp - 1) / dp
        if block in ("attention", "ffn"):
            # TP boundary all-reduces: 2 per layer on (T/dp, d) activations.
            coll += 2.0 * L * (T / dp) * d * 2.0 * (tp - 1) / tp * bwd / 8.0
            # Model-sharded scan carry: per-layer activation all-gather.
            coll += np.where(act_shard,
                             L * (T / dp) * d * 2.0 * bwd / 8.0, 0.0)
        if c.n_experts and block == "ffn":
            coll += 2.0 * (T / dp) * c.top_k * d * 2.0 * bwd
        collective_s = coll / ICI_BW

        # --- feasibility ----------------------------------------------------------
        P_total = sum(self.params_block.values())
        mom = np.where(moment_bf16, 4.0, 8.0)
        state = P_total * (2.0 + mom) / chips
        act_res = np.where(
            self.train,
            L * (T / (dp * np.maximum(accum, 1.0))) * d * 2.0
            / np.where(act_shard, tp, 1.0),
            (T / dp) * d * 2.0)
        transient = np.where(chunked, 1e9, 3e9)
        peak = state + act_res + transient
        feasible = peak <= HBM_CAP

        # Roofline: the block is bound by its slowest engine (partial
        # overlap of compute with comm/HBM is the optimistic max model).
        lat = np.maximum.reduce([compute_s, memory_s, collective_s])
        dollars = lat * chips * CHIP_PRICE_H / 3600.0
        lat = np.where(feasible, lat, np.inf)
        dollars = np.where(feasible, dollars, np.inf)
        return np.stack([lat, dollars], -1)
