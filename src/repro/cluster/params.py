"""Parameter space for cluster autotuning — the paper's θc/θp/θs taxonomy
mapped onto a JAX training job (DESIGN.md §2b).

θc (launch-time context — fixes the job's "Spark context"):
    n_chips        — chips leased for the job (cost ↔ latency tradeoff)
    model_par      — TP axis size (data axis = n_chips / model_par)
    moment_dtype   — optimizer moment precision (bf16 halves opt HBM)
    act_shard_model— shard layer carries over TP (HBM ↔ all-gather tradeoff)

θp (per layer-block, re-jit to change — the "collapsed plan" analogue):
    remat          — recompute policy for the block
    attn_impl      — einsum vs chunked attention (working-set shape)
    capacity_factor— MoE expert capacity

θs (per-step runtime knobs — the "query stage" analogue):
    accum          — gradient-accumulation microbatches
    unroll         — scan unroll factor
"""
from __future__ import annotations

from ..core.tuning.spaces import Param, ParamSpace

__all__ = ["cluster_theta_c", "cluster_theta_p", "cluster_theta_s",
           "BLOCKS"]

# Layer blocks = the "subQs" of a training step (sum-aggregating latency).
BLOCKS = ["embed", "attention", "ffn", "head"]


def cluster_theta_c() -> ParamSpace:
    return ParamSpace([
        Param("n_chips", "cat", choices=[64, 128, 256, 512], default=256),
        Param("model_par", "cat", choices=[4, 8, 16, 32], default=16),
        Param("moment_bf16", "bool", default=0),
        Param("act_shard_model", "bool", default=1),
    ])


def cluster_theta_p() -> ParamSpace:
    return ParamSpace([
        Param("remat", "bool", default=1),
        Param("chunked_attn", "bool", default=0),
        Param("capacity_factor", "float", 1.0, 2.0, default=1.25),
    ])


def cluster_theta_s() -> ParamSpace:
    return ParamSpace([
        Param("accum", "cat", choices=[1, 2, 4, 8, 16], default=1),
        Param("unroll", "cat", choices=[1, 2, 4], default=1),
    ])
