"""HMOOC-driven cluster autotuning (the paper's optimizer, re-targeted).

Compile-time: solve the (θc, {θp}, {θs}) MOO over [step latency, $ cost]
with HMOOC3 — θc (chips, TP split, moment dtype, carry sharding) is shared
across all layer blocks, θp/θs tuned per block — then pick a launch plan by
WUN under the user's latency/cost preference.  Runtime: between steps the
θs knobs (accum, unroll) can be re-picked from *observed* step metrics, the
AQE analogue (a re-jit is the "new physical plan").
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import numpy as np

from ..archs.common import ArchConfig
from ..archs.registry import get_config
from ..core.moo.hmooc import HMOOCConfig, hmooc_solve
from ..core.moo.wun import wun_select
from ..launch.shapes import SHAPES
from .costmodel import ClusterCostModel
from .params import BLOCKS, cluster_theta_c, cluster_theta_p, cluster_theta_s

__all__ = ["LaunchPlan", "autotune"]


@dataclasses.dataclass
class LaunchPlan:
    arch: str
    shape: str
    theta_c: Dict[str, float]           # launch-time knobs
    theta_p: Dict[str, Dict[str, float]]  # per-block
    theta_s: Dict[str, Dict[str, float]]
    predicted: Tuple[float, float]      # (latency s, $ per step)
    front: np.ndarray
    solve_time: float

    def summary(self) -> str:
        tc = self.theta_c
        return (f"{self.arch}×{self.shape}: chips={int(tc['n_chips'])} "
                f"tp={int(tc['model_par'])} "
                f"moments={'bf16' if tc['moment_bf16'] else 'f32'} "
                f"carry_shard={'tp' if tc['act_shard_model'] else 'batch'} "
                f"→ {self.predicted[0]*1e3:.0f} ms/step, "
                f"${self.predicted[1]*1e3:.2f}e-3/step "
                f"({self.front.shape[0]} Pareto pts, "
                f"{self.solve_time:.2f}s solve)")


def autotune(arch_id: str, shape: str = "train_4k",
             weights: Tuple[float, float] = (0.5, 0.5),
             cfg: Optional[HMOOCConfig] = None,
             arch_cfg: Optional[ArchConfig] = None) -> LaunchPlan:
    arch_cfg = arch_cfg or get_config(arch_id)
    cell = SHAPES[shape]
    model = ClusterCostModel(arch_cfg, cell)
    cs, ps, ss = cluster_theta_c(), cluster_theta_p(), cluster_theta_s()
    hm = cfg or HMOOCConfig(n_c_init=48, n_clusters=8, n_p_pool=128,
                            n_c_enrich=48, seed=0)

    def snap_ps(U):
        out = U.copy()
        out[..., :ps.dim] = ps.snap_unit(U[..., :ps.dim])
        out[..., ps.dim:] = ss.snap_unit(U[..., ps.dim:])
        return out

    t0 = time.perf_counter()
    res = hmooc_solve(model.stage_eval, m=len(BLOCKS), d_c=cs.dim,
                      d_ps=ps.dim + ss.dim, cfg=hm,
                      snap_c=cs.snap_unit, snap_ps=snap_ps)
    finite = np.isfinite(res.front).all(-1)
    if not finite.any():
        raise RuntimeError("no feasible launch plan")
    front = res.front[finite]
    tcs = res.theta_c[finite]
    tps = res.theta_ps[finite]
    choice, _ = wun_select(front, np.asarray(weights))
    dt = time.perf_counter() - t0

    tc_raw = cs.to_raw(tcs[choice])
    theta_c = cs.raw_dict(tc_raw)
    theta_p = {}
    theta_s = {}
    for i, b in enumerate(BLOCKS):
        tp_raw = ps.to_raw(tps[choice, i, :ps.dim])
        ts_raw = ss.to_raw(tps[choice, i, ps.dim:])
        theta_p[b] = ps.raw_dict(tp_raw)
        theta_s[b] = ss.raw_dict(ts_raw)
    return LaunchPlan(arch=arch_id, shape=shape, theta_c=theta_c,
                      theta_p=theta_p, theta_s=theta_s,
                      predicted=tuple(front[choice]),
                      front=front, solve_time=dt)
