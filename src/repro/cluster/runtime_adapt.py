"""Runtime adaptation of θs for training jobs — the AQE analogue.

The paper's runtime optimizer re-tunes θp/θs whenever precise statistics
arrive.  For a training job, the "precise statistics" are observed step
metrics (wall-clock, grad-norm variance, MoE expert-load balance); the θs
knobs (grad-accumulation, scan unroll) can be re-picked between steps —
a re-jit is the analogue of AQE producing a new physical plan.

:class:`StepAdapter` keeps an online estimate of step time per θs choice
(bandit-style with optimistic initialization from the analytical cost
model) and recommends re-jitting when a different accumulation factor is
projected ≥ ``min_gain`` faster — with a hysteresis budget so the tuner
never thrashes (each re-jit costs one compile).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

__all__ = ["StepAdapter"]


@dataclasses.dataclass
class StepAdapter:
    candidates: List[int] = dataclasses.field(
        default_factory=lambda: [1, 2, 4, 8])
    min_gain: float = 0.1          # ≥10% projected speedup to re-jit
    max_rejits: int = 3
    ema: float = 0.3

    def __post_init__(self):
        self._est: Dict[int, float] = {}
        self._current: Optional[int] = None
        self._rejits = 0

    def observe(self, accum: int, step_time_s: float) -> None:
        """Feed one observed step time for the live configuration."""
        self._current = accum
        if accum in self._est:
            self._est[accum] = ((1 - self.ema) * self._est[accum]
                                + self.ema * step_time_s)
        else:
            self._est[accum] = step_time_s
            # Optimistic neighbors: memory-feasible larger accum assumed
            # mildly slower (weight re-reads), smaller mildly faster.
            for c in self.candidates:
                if c not in self._est:
                    ratio = 1.0 + 0.05 * abs(np.log2(c / accum))
                    self._est[c] = step_time_s * ratio * 0.95

    def recommend(self) -> Optional[int]:
        """Return a new accum to re-jit with, or None to keep the current."""
        if self._current is None or self._rejits >= self.max_rejits:
            return None
        cur = self._est[self._current]
        best = min(self._est, key=self._est.get)
        if best != self._current and \
                self._est[best] <= cur * (1 - self.min_gain):
            self._rejits += 1
            return best
        return None
