"""Analytical Spark-cluster simulator: the reproduction's ground truth.

Maps (query, θc, {θp}, {θs}) → per-stage and end-to-end latency / IO / cost,
vectorized over a batch of configurations (numpy, config axis first).  It
encodes the mechanisms the paper's tuning problem lives on:

* **Mixed control.**  θc fixes the cluster (cores = k1·k3, memory = k2·k3,
  shuffle behaviors k5–k8) for the whole query; θp decides join algorithms
  and partition counts per collapsed plan; θs rebalances partitions per stage.
* **Correlation.**  The optimal shuffle-partition count (s5) and advisory
  partition size (s1) shift with total cores (k1·k3) — paper Fig. 3(c) —
  because task overhead, wave quantization, and per-task memory all couple
  them.
* **Cardinality-estimation risk.**  Join algorithms planned from CBO
  estimates can broadcast a relation that is *actually* huge (paper
  Fig. 3(b)); AQE may upgrade SMJ→SHJ→BHJ at runtime from true statistics
  but can never downgrade a planned broadcast.
* **Resource sharing.**  Stages at the same DAG depth share executors; the
  *analytical* latency (Σ task-seconds / total cores) stays stable under
  sharing while wall-clock latency varies — why the paper models analytical
  latency (§4.2, Fig. 5).

Units: bytes for sizes, seconds for time, GB for IO accounting; θ arrays are
**raw** values as produced by ``repro.core.tuning.spark_space``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .plan import Query, SubQ

__all__ = ["CostModel", "SubQSim", "QuerySim", "StageStats", "stage_stats",
           "stage_stats_batch",
           "simulate_stage_rows", "simulate_query", "assemble_query_sim",
           "join_decision_stats",
           "JOIN_SMJ", "JOIN_SHJ", "JOIN_BHJ", "default_theta"]

MB = 1e6
GB = 1e9

# Join algorithm codes (ordered by AQE convertibility: can only move up).
JOIN_SMJ, JOIN_SHJ, JOIN_BHJ = 0, 1, 2


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Calibration constants (seconds per GB on one core unless noted)."""

    c_scan: float = 1.1          # read + decode + filter/project
    c_hash_build: float = 1.4    # hash-table build
    c_hash_probe: float = 0.55   # hash probe
    c_sort: float = 0.22         # per GB per log2(rows/part) factor
    c_merge: float = 0.45        # merge-join pass
    c_agg: float = 0.9           # aggregation
    c_shuffle_write: float = 0.55
    c_shuffle_read: float = 0.45
    c_net_broadcast: float = 0.30   # per GB per receiving executor
    compress_ratio: float = 0.45    # shuffle bytes kept when k7 on
    compress_cpu: float = 0.35      # extra CPU fraction when k7 on
    task_overhead: float = 0.09     # seconds per task (schedule+launch)
    spill_penalty: float = 1.8      # extra passes when data > task memory
    oom_penalty: float = 6.0        # broadcast build exceeding executor heap
    fetch_wait_c: float = 14.0      # maxSizeInFlight (MB) diminishing factor
    # Cloud pricing (per hour, arbitrary $ units; IO per GB).  Resource-hours
    # dominate so that latency (buy more cores) trades against cost (pay for
    # more core-hours: core-hours = work + overhead·cores grows with cores).
    price_core_h: float = 0.30
    price_mem_gb_h: float = 0.012
    price_io_gb: float = 0.0005


DEFAULT_COST = CostModel()


@dataclasses.dataclass
class SubQSim:
    """Vectorized per-stage outcome; every field has shape (n_configs,)."""

    ana_latency: np.ndarray      # task-seconds / total cores
    wall_latency: np.ndarray     # wave-quantized stage wall-clock (isolated)
    task_seconds: np.ndarray
    io_gb: np.ndarray
    n_tasks: np.ndarray
    join_algo: np.ndarray        # -1 for non-join stages
    shuffle_gb: np.ndarray
    beta: np.ndarray             # (n, 3) partition-size distribution metrics


@dataclasses.dataclass
class QuerySim:
    """Vectorized end-to-end outcome."""

    ana_latency: np.ndarray      # (n,) sum over stages
    actual_latency: np.ndarray   # (n,) wall clock under shared execution
    io_gb: np.ndarray            # (n,)
    cost: np.ndarray             # (n,) cloud cost $
    per_subq: List[SubQSim]      # stage-level detail, query subQ order
    planned_join: np.ndarray     # (n, m) planned algos (-1 non-join)


def _as2d(theta: np.ndarray, d: int) -> np.ndarray:
    theta = np.asarray(theta, np.float64)
    if theta.ndim == 1:
        theta = theta[None, :]
    assert theta.shape[-1] == d, f"expected {d} params, got {theta.shape}"
    return theta


def _beta_metrics(mean_part: np.ndarray, skew: np.ndarray) -> np.ndarray:
    """Partition-size distribution metrics (σ/μ, (max-μ)/μ, (max-min)/μ)."""
    skew = np.broadcast_to(np.asarray(skew, np.float64), mean_part.shape)
    return np.stack([skew * 1.2, skew * 4.0 + 0.05, skew * 5.0 + 0.1], -1)


def decide_join(build_bytes: np.ndarray, probe_rows: np.ndarray,
                theta_p: np.ndarray, n_parts: np.ndarray) -> np.ndarray:
    """Join-algorithm selection from statistics + θp thresholds.

    BHJ if build ≤ s4 (autoBroadcastJoinThreshold, MB) and the non-empty
    partition ratio gate (s2) passes; else SHJ if per-partition build map
    ≤ s3 (maxShuffledHashJoinLocalMapThreshold); else SMJ.
    """
    s2 = theta_p[:, 1]
    s3 = theta_p[:, 2] * MB
    s4 = theta_p[:, 3] * MB
    nonempty_ratio = np.clip(probe_rows / np.maximum(n_parts, 1.0), 0, 1)
    nonempty_ratio = np.where(probe_rows >= n_parts, 1.0, nonempty_ratio)
    bhj = (build_bytes <= s4) & (nonempty_ratio >= np.minimum(s2, 0.99))
    shj = build_bytes / np.maximum(n_parts, 1.0) <= s3
    return np.where(bhj, JOIN_BHJ, np.where(shj, JOIN_SHJ, JOIN_SMJ))


def _post_shuffle_parts(shuffle_bytes: np.ndarray, theta_p: np.ndarray,
                        theta_s: np.ndarray,
                        aqe: bool) -> Tuple[np.ndarray, np.ndarray]:
    """Partition count after exchange (+ θs coalesce/rebalance at runtime).

    Returns (n_parts, small_part_overhead_factor).
    """
    s5 = np.maximum(theta_p[:, 4], 1.0)             # shuffle.partitions
    if not aqe:
        return s5, np.ones_like(s5)
    s1 = np.maximum(theta_p[:, 0], 1.0) * MB        # advisory partition size
    s11 = np.maximum(theta_s[:, 1], 0.25) * MB      # min partition size
    target = np.clip(np.ceil(shuffle_bytes / s1), 1.0, s5)
    # Coalescing can't create partitions smaller than s11: cap count.
    cap = np.maximum(np.floor(shuffle_bytes / s11), 1.0)
    parts = np.minimum(target, cap)
    # Rebalance small partitions: factor s10 merges the tail of tiny
    # partitions, trimming per-task overhead on skewed stages.
    s10 = np.clip(theta_s[:, 0], 0.05, 0.95)
    overhead_factor = 1.0 - 0.35 * (1.0 - s10)
    return parts, overhead_factor


@dataclasses.dataclass
class StageStats:
    """Per-row stage statistics for the batched core; every field is (n,).

    A stage's statistics are scalars; lifting them to per-row arrays lets
    same-kind stages from *different* queries share one
    :func:`simulate_stage_rows` call (the serving layer's cross-query
    fusion) while staying bit-identical to the per-stage path — both run
    the same elementwise arithmetic.
    """

    in_bytes0: np.ndarray        # first (or only) input, bytes
    in_bytes1: np.ndarray        # second input (joins); 0 otherwise
    in_rows0: np.ndarray
    in_rows1: np.ndarray
    in_bytes_sum: np.ndarray     # Σ inputs, bytes (skew gate)
    out_bytes: np.ndarray
    cpu_weight: np.ndarray
    skew: np.ndarray


def stage_stats(sq: SubQ, n: int, *, use_est_inputs: bool = False
                ) -> StageStats:
    """Lift one subQ's scalar statistics to ``n`` rows."""
    inp = sq.est_input_bytes if use_est_inputs else sq.input_bytes
    inr = sq.est_input_rows if use_est_inputs else sq.input_rows
    out_bytes = sq.est_out_bytes if use_est_inputs else sq.out_bytes
    full = lambda v: np.full(n, float(v))
    return StageStats(
        in_bytes0=full(inp[0]),
        in_bytes1=full(inp[1] if len(inp) > 1 else 0.0),
        in_rows0=full(inr[0]),
        in_rows1=full(inr[1] if len(inr) > 1 else 0.0),
        in_bytes_sum=full(sum(inp)),
        out_bytes=full(out_bytes),
        cpu_weight=full(sq.cpu_weight),
        skew=full(sq.skew),
    )


def stage_stats_batch(subqs: Sequence[SubQ], *, use_est_inputs: bool = False
                      ) -> StageStats:
    """One statistics row per subQ (n = len(subqs)), built in a single pass.

    The subQs may come from different queries; only the caller's grouping
    by ``kind`` matters for :func:`simulate_stage_rows`.
    """
    rows = []
    for sq in subqs:
        inp = sq.est_input_bytes if use_est_inputs else sq.input_bytes
        inr = sq.est_input_rows if use_est_inputs else sq.input_rows
        ob = sq.est_out_bytes if use_est_inputs else sq.out_bytes
        rows.append((inp[0], inp[1] if len(inp) > 1 else 0.0,
                     inr[0], inr[1] if len(inr) > 1 else 0.0,
                     sum(inp), ob, sq.cpu_weight, sq.skew))
    a = np.asarray(rows, np.float64).reshape(len(rows), 8)
    return StageStats(*(a[:, i] for i in range(8)))


def simulate_stage_rows(
    kind: str,
    st: StageStats,
    theta_c: np.ndarray,
    theta_p: np.ndarray,
    theta_s: np.ndarray,
    *,
    cost: CostModel = DEFAULT_COST,
    aqe: bool = True,
    join_algo: Optional[np.ndarray] = None,
) -> SubQSim:
    """Row-batched stage core: row i is an independent (stats, θ) sample.

    All stages in one call share ``kind`` (the fusion group key); statistics
    and θ vary per row, so stacked candidate sets from many queries resolve
    in a single pass.
    """
    n = st.in_bytes0.shape[0]
    theta_c = np.broadcast_to(_as2d(theta_c, 8), (n, 8))
    theta_p = np.broadcast_to(_as2d(theta_p, 9), (n, 9))
    theta_s = np.broadcast_to(_as2d(theta_s, 2), (n, 2))

    k1 = np.maximum(theta_c[:, 0], 1.0)              # cores/executor
    k2 = np.maximum(theta_c[:, 1], 0.5) * GB         # heap/executor
    k3 = np.maximum(theta_c[:, 2], 1.0)              # executors
    k4 = np.maximum(theta_c[:, 3], 1.0)              # default parallelism
    k5 = np.maximum(theta_c[:, 4], 1.0)              # maxSizeInFlight MB
    k6 = theta_c[:, 5]                               # bypassMergeThreshold
    k7 = theta_c[:, 6] >= 0.5                        # shuffle compress
    k8 = np.clip(theta_c[:, 7], 0.05, 0.95)          # memory fraction
    cores = k1 * k3
    task_mem = k2 * k8 / k1

    out_bytes = st.out_bytes
    cw = st.cpu_weight

    compress_ratio = np.where(k7, cost.compress_ratio, 1.0)
    compress_cpu = np.where(k7, 1.0 + cost.compress_cpu, 1.0)
    # Shuffle fetch efficiency: small in-flight buffers stall the reader.
    fetch_eff = 1.0 + cost.fetch_wait_c / (cost.fetch_wait_c + k5)

    io_gb = np.zeros(n)
    cpu_sec = np.zeros(n)
    shuffle_gb = np.zeros(n)
    algo_out = np.full(n, -1.0)

    if kind == "scan":
        B = st.in_bytes0
        s8 = np.maximum(theta_p[:, 7], 1.0) * MB     # maxPartitionBytes
        s9 = np.maximum(theta_p[:, 8], 0.25) * MB    # openCostInBytes
        n_files = np.maximum(B / (128 * MB), 1.0)
        eff_bytes = B + n_files * s9
        parts = np.maximum(np.ceil(eff_bytes / s8), 1.0)
        parts = np.maximum(parts, np.minimum(k4, 4 * cores))  # parallelism floor
        per_task = B / parts
        spill = np.where(per_task > task_mem,
                         1.0 + cost.spill_penalty *
                         np.clip(per_task / np.maximum(task_mem, 1.0) - 1, 0, 4),
                         1.0)
        cpu_sec = (B / GB) * cost.c_scan * cw * spill
        io_gb = B / GB
        # Stage output feeds an exchange: shuffle write.
        w_bytes = out_bytes * compress_ratio
        cpu_sec += (out_bytes / GB) * cost.c_shuffle_write * compress_cpu
        # Sort-based shuffle merge unless partition count under bypass thresh.
        s5 = np.maximum(theta_p[:, 4], 1.0)
        merge_f = np.where(s5 <= k6, 0.85, 1.0)
        cpu_sec *= merge_f
        io_gb += w_bytes / GB
        shuffle_gb = w_bytes / GB
        small_f = np.ones(n)

    elif kind == "join":
        bl, br = st.in_bytes0, st.in_bytes1
        rl, rr = st.in_rows0, st.in_rows1
        left_small = bl <= br
        build_b = np.where(left_small, bl, br)
        probe_b = np.where(left_small, br, bl)
        probe_r = np.where(left_small, rr, rl)
        shuffle_in = (bl + br) * compress_ratio
        parts, small_f = _post_shuffle_parts(shuffle_in, theta_p, theta_s,
                                             aqe)
        if join_algo is None:
            algo = decide_join(build_b, probe_r, theta_p, parts)
        else:
            algo = np.broadcast_to(np.asarray(join_algo), (n,))
        algo_out = algo.astype(np.float64)

        # ---- broadcast hash join: ship build to every executor ----------
        bhj_net = (build_b / GB) * cost.c_net_broadcast * k3
        bhj_build = (build_b / GB) * cost.c_hash_build * k3
        bhj_probe = (probe_b / GB) * cost.c_hash_probe
        bhj_oom = np.where(build_b > k2 * k8,
                           cost.oom_penalty * (build_b / GB), 0.0)
        bhj_cpu = bhj_net + bhj_build + bhj_probe + bhj_oom
        bhj_io = build_b * k3 / GB
        bhj_shuffle = np.zeros(n)
        bhj_parts = np.maximum(np.ceil(probe_b / (128 * MB)), 1.0)

        # ---- shuffled hash join ------------------------------------------
        per_part_build = build_b / np.maximum(parts, 1.0)
        shj_spill = np.where(per_part_build > task_mem,
                             1.0 + cost.spill_penalty, 1.0)
        shj_cpu = ((bl + br) / GB) * (cost.c_shuffle_write * compress_cpu
                                      + cost.c_shuffle_read * fetch_eff) \
            + (build_b / GB) * cost.c_hash_build * shj_spill \
            + (probe_b / GB) * cost.c_hash_probe
        shj_io = 2 * shuffle_in / GB
        shj_shuffle = shuffle_in / GB

        # ---- sort-merge join ---------------------------------------------
        rows_per_part = (rl + rr) / np.maximum(parts, 1.0)
        logf = np.log2(np.maximum(rows_per_part, 2.0))
        smj_cpu = ((bl + br) / GB) * (cost.c_shuffle_write * compress_cpu
                                      + cost.c_shuffle_read * fetch_eff
                                      + cost.c_sort * logf / 8.0
                                      + cost.c_merge)
        smj_io = 2 * shuffle_in / GB
        smj_shuffle = shuffle_in / GB

        cpu_sec = np.select([algo == JOIN_BHJ, algo == JOIN_SHJ],
                            [bhj_cpu, shj_cpu], smj_cpu)
        io_gb = np.select([algo == JOIN_BHJ, algo == JOIN_SHJ],
                          [bhj_io, shj_io], smj_io)
        shuffle_gb = np.select([algo == JOIN_BHJ, algo == JOIN_SHJ],
                               [bhj_shuffle, shj_shuffle], smj_shuffle)
        parts = np.where(algo == JOIN_BHJ, bhj_parts, parts)
        # Join work + output write; the stage CPU weight applies exactly
        # once to each term.
        cpu_sec = cpu_sec * cw + (out_bytes / GB) * 0.25 * cw

    else:  # agg (and sort)
        B = st.in_bytes0
        shuffle_in = B * compress_ratio
        parts, small_f = _post_shuffle_parts(shuffle_in, theta_p, theta_s,
                                             aqe)
        per_part = B / np.maximum(parts, 1.0)
        spill = np.where(per_part > task_mem, 1.0 + cost.spill_penalty, 1.0)
        cpu_sec = (B / GB) * (cost.c_shuffle_write * compress_cpu
                              + cost.c_shuffle_read * fetch_eff
                              + cost.c_agg * spill) * cw
        io_gb = 2 * shuffle_in / GB
        shuffle_gb = shuffle_in / GB

    # ---- skew: AQE skew-split (s6 threshold, s7 factor) mitigates the tail.
    skew = st.skew
    if aqe and kind != "scan":
        s6 = theta_p[:, 5] * MB
        s7 = np.maximum(theta_p[:, 6], 2.0)
        # Mean partition size from the *post-coalesce* partition count, so
        # s1/s11 coalescing feeds the skew-split decision.
        mean_part_b = st.in_bytes_sum / np.maximum(parts, 1.0)
        split = (skew * 5.0 * mean_part_b > s6)
        skew_eff = np.where(split, skew / s7, skew)
    else:
        skew_eff = skew

    # ---- assemble stage timing ------------------------------------------
    parts = np.maximum(parts, 1.0)
    overhead = cost.task_overhead * parts * small_f
    task_seconds = cpu_sec + overhead
    ana_latency = task_seconds / cores
    mean_task = task_seconds / parts
    waves = np.ceil(parts / cores)
    wall = waves * mean_task * (1.0 + 2.5 * skew_eff)
    wall = np.maximum(wall, ana_latency)

    return SubQSim(
        ana_latency=ana_latency,
        wall_latency=wall,
        task_seconds=task_seconds,
        io_gb=io_gb,
        n_tasks=parts,
        join_algo=algo_out,
        shuffle_gb=shuffle_gb,
        beta=_beta_metrics(task_seconds / parts, skew),
    )


def simulate_subq(
    sq: SubQ,
    theta_c: np.ndarray,
    theta_p: np.ndarray,
    theta_s: np.ndarray,
    *,
    cost: CostModel = DEFAULT_COST,
    aqe: bool = True,
    join_algo: Optional[np.ndarray] = None,
    use_est_inputs: bool = False,
) -> SubQSim:
    """Simulate one stage for a batch of configurations.

    ``join_algo`` overrides the algorithm (the *planned* decision realized on
    true bytes); ``use_est_inputs`` sizes work from CBO estimates (used by
    compile-time "what the optimizer believes" evaluations, never for ground
    truth).
    """
    theta_c = _as2d(theta_c, 8)
    theta_p = _as2d(theta_p, 9)
    theta_s = _as2d(theta_s, 2)
    n = max(theta_c.shape[0], theta_p.shape[0], theta_s.shape[0])
    return simulate_stage_rows(
        sq.kind, stage_stats(sq, n, use_est_inputs=use_est_inputs),
        theta_c, theta_p, theta_s, cost=cost, aqe=aqe, join_algo=join_algo)


def plan_joins(query: Query, theta_p_sub: np.ndarray,
               *, from_estimates: bool) -> np.ndarray:
    """Planned join algorithm per subQ (−1 for non-joins), (n, m).

    ``theta_p_sub`` is (n, m, 9): the θp copy in effect for each subQ's
    planning decision.  ``from_estimates`` selects CBO stats (submission
    time) vs true stats (AQE re-planning).  All joins resolve in one
    :func:`decide_join` call over the flattened (config, join) rows.
    """
    n, m = theta_p_sub.shape[0], query.n_subqs
    out = np.full((n, m), -1.0)
    joins = [sq for sq in query.subqs if sq.kind == "join"]
    if not joins:
        return out
    ids = [sq.sq_id for sq in joins]
    build, probe = join_decision_stats(joins, from_estimates=from_estimates)
    tp = np.asarray(theta_p_sub[:, ids, :], np.float64).reshape(-1, 9)
    parts = np.maximum(tp[:, 4], 1.0)
    algo = decide_join(np.tile(build, n), np.tile(probe, n), tp, parts)
    out[:, ids] = algo.reshape(n, len(joins))
    return out


def join_decision_stats(subqs: Sequence[SubQ], *, from_estimates: bool
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """(build_bytes, probe_rows) rows for :func:`decide_join`, one per join.

    Build side is the smaller input; probe rows come from the other side
    (ties go left-as-build).  Shared by :func:`plan_joins` and the serving
    layer's fused realization so the tie-breaking can never diverge.
    """
    build = np.empty(len(subqs))
    probe = np.empty(len(subqs))
    for j, sq in enumerate(subqs):
        inp = sq.est_input_bytes if from_estimates else sq.input_bytes
        inr = sq.est_input_rows if from_estimates else sq.input_rows
        bl, br = float(inp[0]), float(inp[1])
        build[j] = min(bl, br)
        probe[j] = float(inr[1] if bl <= br else inr[0])
    return build, probe


def upgrade_joins(planned: np.ndarray, runtime_choice: np.ndarray) -> np.ndarray:
    """AQE convertibility: SMJ→{SHJ,BHJ}, SHJ→BHJ, BHJ fixed (paper §5.2)."""
    return np.where(planned < 0, planned, np.maximum(planned, runtime_choice))


def simulate_query(
    query: Query,
    theta_c: np.ndarray,
    theta_p_sub: np.ndarray,
    theta_s_sub: np.ndarray,
    *,
    cost: CostModel = DEFAULT_COST,
    aqe: bool = True,
    runtime_reopt: bool = False,
    planned_join: Optional[np.ndarray] = None,
    rng: Optional[np.random.Generator] = None,
) -> QuerySim:
    """End-to-end execution for a batch of configurations.

    Args:
      theta_c: (n, 8) raw context parameters.
      theta_p_sub: (n, m, 9) or (n, 9) raw plan parameters per subQ (a
        query-level copy broadcasts).
      theta_s_sub: (n, m, 2) or (n, 2) raw stage parameters per subQ.
      aqe: adaptive execution on (partition coalescing + join upgrades).
      runtime_reopt: join re-planning sees *true* statistics (AQE); when
        False, the submission-time decision from CBO estimates is realized.
      planned_join: optionally force the submission-time decisions (n, m).
    """
    theta_c = _as2d(theta_c, 8)
    n = theta_c.shape[0]
    m = query.n_subqs
    if theta_p_sub.ndim == 2:
        theta_p_sub = np.broadcast_to(theta_p_sub[:, None, :], (n, m, 9))
    if theta_s_sub.ndim == 2:
        theta_s_sub = np.broadcast_to(theta_s_sub[:, None, :], (n, m, 2))
    theta_p_sub = np.asarray(theta_p_sub, np.float64)
    theta_s_sub = np.asarray(theta_s_sub, np.float64)

    if planned_join is None:
        planned_join = plan_joins(query, theta_p_sub, from_estimates=True)
    if aqe:
        runtime_stats = runtime_reopt
        runtime_choice = plan_joins(query, theta_p_sub,
                                    from_estimates=not runtime_stats)
        final_join = upgrade_joins(planned_join, runtime_choice)
    else:
        final_join = planned_join

    per: List[SubQSim] = []
    for sq in query.subqs:
        algo = final_join[:, sq.sq_id] if sq.kind == "join" else None
        per.append(simulate_subq(
            sq, theta_c, theta_p_sub[:, sq.sq_id, :],
            theta_s_sub[:, sq.sq_id, :], cost=cost, aqe=aqe, join_algo=algo))

    return assemble_query_sim(query, theta_c, per, planned_join,
                              cost=cost, rng=rng)


def assemble_query_sim(
    query: Query,
    theta_c: np.ndarray,
    per: List[SubQSim],
    planned_join: np.ndarray,
    *,
    cost: CostModel = DEFAULT_COST,
    rng: Optional[np.random.Generator] = None,
) -> QuerySim:
    """Fold per-stage outcomes into the end-to-end :class:`QuerySim`.

    Shared by :func:`simulate_query` and the serving layer's fused
    realization path (which computes ``per`` from cross-query stacked
    stage calls).
    """
    n = theta_c.shape[0]
    ana = np.sum([p.ana_latency for p in per], axis=0)
    io = np.sum([p.io_gb for p in per], axis=0)

    # Wall clock with resource sharing: stages grouped by DAG depth run
    # concurrently on shared cores; each depth-group takes
    # max(work-conserving time, longest skew-tail stage).
    depths = query.subq_depths()
    actual = np.zeros(n)
    for d in sorted(set(depths)):
        grp = [i for i, dd in enumerate(depths) if dd == d]
        work = np.sum([per[i].task_seconds for i in grp], axis=0)
        k1 = np.maximum(theta_c[:, 0], 1.0)
        k3 = np.maximum(theta_c[:, 2], 1.0)
        cores = k1 * k3
        tail = np.max([per[i].wall_latency for i in grp], axis=0)
        actual += np.maximum(work / cores, tail)
    if rng is not None:
        actual = actual * np.exp(rng.normal(0.0, 0.03, size=n))

    k1, k2, k3 = theta_c[:, 0], theta_c[:, 1], theta_c[:, 2]
    dollars = (actual / 3600.0) * (k1 * k3 * cost.price_core_h
                                   + k2 * k3 * cost.price_mem_gb_h) \
        + io * cost.price_io_gb
    return QuerySim(ana_latency=ana, actual_latency=actual, io_gb=io,
                    cost=dollars, per_subq=per, planned_join=planned_join)


def default_theta(n: int = 1) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Spark-default (θc, θp, θs) raw rows, tiled to n."""
    from ..core.tuning.spark_space import theta_c_space, theta_p_space, theta_s_space
    tc = np.tile(theta_c_space().default_raw(), (n, 1))
    tp = np.tile(theta_p_space().default_raw(), (n, 1))
    ts = np.tile(theta_s_space().default_raw(), (n, 1))
    return tc, tp, ts
